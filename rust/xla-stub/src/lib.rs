//! Offline stub of the `xla` PJRT binding.
//!
//! The real crate links `xla_extension` (a multi-GB native bundle that is
//! not vendorable offline). This stub reproduces exactly the API surface
//! `hgpipe`'s `runtime::pjrt` module uses, so `--features pjrt` still
//! *type-checks* the whole PJRT integration; every entry point that would
//! need the native library returns [`Error::Unavailable`] at runtime.
//! Swap the `xla` path dependency in `rust/Cargo.toml` for a real binding
//! to execute HLO artifacts.

use std::borrow::Borrow;
use std::fmt;

/// Stub error: either "native XLA not linked" or a local usage error.
#[derive(Debug)]
pub enum Error {
    Unavailable,
    Msg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable => write!(
                f,
                "xla stub: native xla_extension is not linked in this build \
                 (the `pjrt` feature resolves the in-repo stub crate)"
            ),
            Error::Msg(m) => write!(f, "xla stub: {m}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can carry (subset hgpipe uses).
pub trait NativeType: Copy {
    fn to_le_bytes_vec(xs: &[Self]) -> Vec<u8>;
    fn from_le_bytes_vec(raw: &[u8]) -> Vec<Self>;
}

macro_rules! native {
    ($t:ty) => {
        impl NativeType for $t {
            fn to_le_bytes_vec(xs: &[Self]) -> Vec<u8> {
                xs.iter().flat_map(|x| x.to_le_bytes()).collect()
            }
            fn from_le_bytes_vec(raw: &[u8]) -> Vec<Self> {
                raw.chunks_exact(std::mem::size_of::<Self>())
                    .map(|c| Self::from_le_bytes(c.try_into().unwrap()))
                    .collect()
            }
        }
    };
}

native!(f32);
native!(f64);
native!(i32);
native!(i64);

/// Host-side tensor literal. Fully functional in the stub (it is pure
/// host data); only device transfer / execution is unavailable.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<u8>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1<T: NativeType>(xs: &[T]) -> Literal {
        Literal { data: T::to_le_bytes_vec(xs), dims: vec![xs.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let cur: i64 = self.dims.iter().product();
        if n != cur {
            return Err(Error::Msg(format!("reshape {:?} -> {:?}", self.dims, dims)));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::Unavailable)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(T::from_le_bytes_vec(&self.data))
    }
}

/// Parsed HLO module (stub: the text is retained but never compiled).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| Error::Msg(e.to_string()))?;
        Ok(Self { _text: text })
    }
}

pub struct XlaComputation {
    _p: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _p: () }
    }
}

/// Device buffer handle (never constructible in the stub).
pub struct PjRtBuffer {
    _p: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable)
    }
}

pub struct PjRtLoadedExecutable {
    _p: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable)
    }
}

pub struct PjRtClient {
    _p: (),
}

impl PjRtClient {
    /// Always fails in the stub: there is no native PJRT CPU client.
    pub fn cpu() -> Result<Self> {
        Err(Error::Unavailable)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable)
    }
}
