//! Hot model zoo behind the [`Router`]: versioned `load` / `swap` /
//! `unload` against the golden fixture.
//!
//! 1. **leak regression** — repeated load/swap/unload cycles return the
//!    shared [`ModelArtifact`]'s `Arc::strong_count` to 1 and the
//!    process-wide `live_workers` / `live_stages` counters to their
//!    baselines, in both execution modes;
//! 2. **drain-then-swap delivery** — every request submitted across a
//!    mid-stream swap receives exactly one reply (success or explicit
//!    failure, never a silent drop), and the per-version metrics
//!    decompose the lifetime total without double counting;
//! 3. **explicit errors** — duplicate load, unknown unload/swap, and a
//!    swap whose replacement fails to start all error out while leaving
//!    the previously-serving fleet untouched.
//!
//! Tests serialize on a lock: `pipeline::live_stages` and
//! `LanePool::live_workers` are process-wide counters, and concurrent
//! replica-creating tests would make their baseline assertions racy.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use hgpipe::artifacts::Manifest;
use hgpipe::coordinator::Router;
use hgpipe::runtime::fabric::LanePool;
use hgpipe::runtime::{pipeline, BackendKind, ExecMode, ModelArtifact, RuntimeConfig};

static SERIAL: Mutex<()> = Mutex::new(());

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join("golden")
}

fn manifest() -> Manifest {
    Manifest::load(&fixture_dir()).expect("committed golden fixture")
}

fn config() -> RuntimeConfig {
    RuntimeConfig::new(BackendKind::Interpreter).with_lanes(Some(1)).with_replicas(Some(2))
}

#[test]
fn load_swap_unload_cycles_return_refcounts_and_threads_to_baseline() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let manifest = manifest();
    let stage_baseline = pipeline::live_stages();
    let worker_baseline = LanePool::live_workers();
    for mode in [ExecMode::LaneParallel, ExecMode::Pipeline { stages: 0, queue_depth: 2 }] {
        let cfg = config().with_mode(mode);
        let router = Router::new(Vec::new());
        for cycle in 0..3 {
            router.load(&manifest, "tiny-synth", 2, cfg).unwrap();
            assert_eq!(router.version("tiny-synth"), Some(1));
            let per = router.server("tiny-synth").unwrap().tokens_per_image();
            router.infer_all("tiny-synth", vec![vec![0.5; per]; 2]).unwrap();
            assert_eq!(router.swap(&manifest, "tiny-synth", 2, cfg).unwrap(), 2);
            router.infer_all("tiny-synth", vec![vec![0.5; per]; 2]).unwrap();
            // hold one outside clone of the live artifact so the
            // refcount stays observable across the unload
            let held = {
                let server = router.server("tiny-synth").unwrap();
                server.artifact().expect("interpreter backend shares an artifact").clone()
            };
            assert!(held.strong_count() > 1, "the fleet holds shared references");
            router.unload("tiny-synth").unwrap();
            assert!(router.server("tiny-synth").is_none());
            assert_eq!(
                held.strong_count(),
                1,
                "{mode:?} cycle {cycle}: unload must free every fleet reference"
            );
            assert_eq!(
                pipeline::live_stages(),
                stage_baseline,
                "{mode:?} cycle {cycle}: stage threads leaked"
            );
            assert_eq!(
                LanePool::live_workers(),
                worker_baseline,
                "{mode:?} cycle {cycle}: fabric workers leaked"
            );
        }
    }
}

#[test]
fn mid_stream_swap_delivers_every_request_exactly_once() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let manifest = manifest();
    let cfg = config();
    let router = Router::start(&manifest, &["tiny-synth".to_string()], 2, cfg).unwrap();
    let per = router.server("tiny-synth").unwrap().tokens_per_image();
    let total = 32usize;
    let mut rxs = Vec::with_capacity(total);
    for i in 0..total {
        if i == total / 2 {
            // swap with half the traffic submitted: the old fleet
            // drains (replies or fails explicitly), the new one takes
            // the rest
            assert_eq!(router.swap(&manifest, "tiny-synth", 2, cfg).unwrap(), 2);
        }
        let image = vec![0.25f32; per];
        // a submit racing the closing queue errs explicitly; one
        // resubmit routes it to the new version — nothing is dropped
        let rx = match router.submit("tiny-synth", image.clone()) {
            Ok(rx) => rx,
            Err(_) => router.submit("tiny-synth", image).unwrap(),
        };
        rxs.push(rx);
    }
    let (mut ok, mut failed) = (0usize, 0usize);
    for (i, rx) in rxs.into_iter().enumerate() {
        // exactly one reply per accepted request: a dropped sender here
        // would be a silently lost request
        match rx.recv().unwrap_or_else(|_| panic!("request {i}: reply sender dropped")) {
            Ok(_) => ok += 1,
            Err(_) => failed += 1,
        }
    }
    assert_eq!(ok + failed, total, "every request resolves exactly once");

    // the per-version decomposition covers the lifetime totals: each
    // request was recorded by exactly one version (drain failures land
    // in the version that owned the queue), so the sums match with no
    // double counting
    let versions = router.version_metrics("tiny-synth").unwrap();
    assert_eq!(versions.len(), 2);
    assert_eq!(versions[0].0, 1);
    assert_eq!(versions[1].0, 2);
    let counted: usize = versions.iter().map(|(_, m)| m.count() + m.failed as usize).sum();
    assert_eq!(counted, total, "per-version metrics must sum to the lifetime total");
    let failed_sum: usize = versions.iter().map(|(_, m)| m.failed as usize).sum();
    assert_eq!(failed_sum, failed, "per-version failures must sum to observed failures");

    // versioned labels appear only once a swap happened: the retired
    // version first, then the live fleet with its replica breakdown
    let lines = router.metrics_lines();
    assert!(lines[0].starts_with("[tiny-synth@v1] "), "retired line first: {}", lines[0]);
    assert!(lines[1].starts_with("[tiny-synth@v2] "), "live rollup second: {}", lines[1]);
    assert!(lines[2].starts_with("[tiny-synth@v2/replica0] "), "replica lines: {}", lines[2]);
    assert_eq!(lines.len(), 2 + 2, "v1 rollup + v2 rollup + two v2 replica lines");
}

#[test]
fn zoo_errors_are_explicit_and_leave_serving_untouched() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let manifest = manifest();
    let cfg = config();
    let router = Router::start(&manifest, &["tiny-synth".to_string()], 2, cfg).unwrap();

    // duplicate load: the zoo already serves this name
    let err = router.load(&manifest, "tiny-synth", 2, cfg).unwrap_err().to_string();
    assert!(err.contains("already served"), "unexpected error: {err}");

    // unknown unload: actionable error naming what is being served
    let err = router.unload("nope").unwrap_err().to_string();
    assert!(err.contains("no server") && err.contains("tiny-synth"), "unexpected error: {err}");

    // a swap whose replacement cannot start fails before routing ever
    // changes: version and serving stay exactly as they were
    assert!(router.swap(&manifest, "nope", 2, cfg).is_err());
    assert_eq!(router.version("tiny-synth"), Some(1));
    let per = router.server("tiny-synth").unwrap().tokens_per_image();
    router.infer_all("tiny-synth", vec![vec![0.5; per]; 1]).unwrap();
    assert_eq!(router.version_metrics("tiny-synth").unwrap().len(), 1, "no retired versions");

    // a swap for a name the zoo does not serve is rejected even when
    // the replacement starts fine (the fresh fleet drains trivially)
    let empty = Router::new(Vec::new());
    let err = empty.swap(&manifest, "tiny-synth", 2, cfg).unwrap_err().to_string();
    assert!(err.contains("to swap"), "unexpected error: {err}");
    assert!(empty.models().is_empty());
}

#[test]
fn distinct_loads_do_not_share_weights_but_a_fleet_does() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let manifest = manifest();
    let a = ModelArtifact::load(&manifest, "tiny-synth").unwrap();
    let b = ModelArtifact::load(&manifest, "tiny-synth").unwrap();
    assert!(!a.shares_weights_with(&b), "independent loads are distinct copies");
    let a2 = a.clone();
    assert!(a.shares_weights_with(&a2), "clones share the same weights");
    assert_eq!(a.strong_count(), 2);
    drop(a2);
    assert_eq!(a.strong_count(), 1);
    assert_eq!(a.footprint_bytes(), b.footprint_bytes());
    assert!(a.footprint_bytes() > 0, "footprint accounts for resident panels and tables");
}
