//! The committed `BENCH_baseline.json` must stay a valid gate input:
//! `make bench-check` reads it in CI right after the smoke bench, and a
//! malformed baseline would either crash the gate or (worse) silently
//! stop gating. The checker logic itself is unit-tested in
//! `util::benchcheck`; this test pins the committed artifact.

use std::path::Path;

use hgpipe::util::json::Json;

fn baseline() -> Json {
    // the baseline lives at the repository root, next to the Makefile
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_baseline.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("committed baseline {} unreadable: {e}", path.display()));
    Json::parse(&text).expect("BENCH_baseline.json parses")
}

#[test]
fn baseline_has_every_gate_key_with_sane_values() {
    let b = baseline();
    let tol = b
        .get("tolerance")
        .and_then(Json::as_f64)
        .expect("baseline carries an explicit tolerance");
    assert!(
        (0.0..1.0).contains(&tol),
        "tolerance {tol} must be a fraction in [0, 1)"
    );
    for key in ["fabric_pooled_img_s", "pipeline_img_s"] {
        let floor = b
            .get(key)
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("baseline missing gate key {key}"));
        assert!(floor > 0.0, "{key} floor must be positive, got {floor}");
        assert!(
            floor < 1e6,
            "{key} floor {floor} is implausibly high for the smoke workload — \
             the gate would fail every runner"
        );
    }
}

#[test]
fn baseline_passes_the_checker_against_its_own_floors() {
    // a bench artifact sitting exactly at the floors must pass: the
    // tolerance only ever relaxes the gate, never tightens it
    let b = baseline();
    let pooled = b.get("fabric_pooled_img_s").and_then(Json::as_f64).unwrap();
    let pipe = b.get("pipeline_img_s").and_then(Json::as_f64).unwrap();
    let current = Json::obj(vec![
        ("fabric_pooled_img_s", Json::Num(pooled)),
        (
            "pipeline",
            Json::obj(vec![("img_s", Json::Num(pipe))]),
        ),
    ]);
    let errs = hgpipe::util::benchcheck::regression_errors(&current, &b);
    assert_eq!(errs, Vec::<String>::new());
}
