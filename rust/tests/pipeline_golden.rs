//! The hybrid-grained pipeline executor against the golden fixture:
//!
//! 1. **bit-exactness** — logits are bit-identical to the python
//!    reference at stage counts 1, 2, 4 and max (clamped), at queue
//!    depth 1 and the default, and with fine-grained lanes inside the
//!    stages;
//! 2. **backpressure liveness** — depth-1 FIFOs fully serialize the
//!    hand-offs: no deadlock, no reordering, every image answered;
//! 3. **lifecycle** — dropping a pipeline (or a `ModelServer` whose
//!    model runs in pipeline mode, including mid-stream with requests
//!    in flight) drains the stages and joins every stage thread and
//!    every inner fabric worker.
//!
//! Tests serialize on a lock: `pipeline::live_stages` and
//! `LanePool::live_workers` are process-wide counters, and concurrent
//! pipeline-creating tests would make their baseline assertions racy.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use hgpipe::artifacts::Manifest;
use hgpipe::coordinator::ModelServer;
use hgpipe::runtime::fabric::LanePool;
use hgpipe::runtime::interpreter::QuantViT;
use hgpipe::runtime::kernels;
use hgpipe::runtime::pipeline::{self, PartitionStrategy, Pipeline, PipelineConfig};
use hgpipe::runtime::{BackendKind, ExecMode, RuntimeConfig};

static SERIAL: Mutex<()> = Mutex::new(());

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join("golden")
}

fn golden() -> (Arc<QuantViT>, Vec<f32>, Vec<f64>) {
    let dir = fixture_dir();
    let net = Arc::new(QuantViT::load(&dir.join("tinyvit_bundle.json")).expect("bundle loads"));
    let tokens = std::fs::read(dir.join("golden_tokens.bin"))
        .unwrap()
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
        .collect();
    let logits = std::fs::read(dir.join("golden_logits.bin"))
        .unwrap()
        .chunks_exact(8)
        .map(|b| f64::from_le_bytes(b.try_into().unwrap()))
        .collect();
    (net, tokens, logits)
}

fn assert_logits(got: &[f64], want: &[f64], ctx: &str) {
    for (k, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{ctx} logit {k}: {g:e} != {w:e}");
    }
}

#[test]
fn pipeline_bit_exact_at_every_stage_count() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let (net, tokens, expected) = golden();
    let per = net.tokens_per_image();
    let nc = net.num_classes;
    // 4 blocks for tiny-synth: "max" = fully unrolled = a dedicated
    // patch-embed stage plus one stage per block = 5
    let depth = net.depth;
    let n = 16usize;
    // stage counts the acceptance pins: 1, 2, 4, and max (0 = auto)
    for &stages in &[1usize, 2, 4, 0] {
        let pipe = Pipeline::new(
            net.clone(),
            PipelineConfig { stages, queue_depth: 2, lanes: 1, ..Default::default() },
        );
        let want_stages = if stages == 0 { depth + 1 } else { stages.clamp(1, depth + 1) };
        assert_eq!(pipe.stage_count(), want_stages, "requested {stages}");
        let out = pipe.run_batch(&tokens[..n * per], n).unwrap();
        for i in 0..n {
            assert_logits(
                &out[i * nc..(i + 1) * nc],
                &expected[i * nc..(i + 1) * nc],
                &format!("stages {stages} img {i}"),
            );
        }
    }
}

#[test]
fn pipeline_bit_exact_with_fine_grained_lanes_inside_stages() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let (net, tokens, expected) = golden();
    let per = net.tokens_per_image();
    let nc = net.num_classes;
    // 2 stages x 2 lanes each: both grains of the hybrid pipeline active
    let pipe = Pipeline::new(
        net.clone(),
        PipelineConfig { stages: 2, queue_depth: 2, lanes: 4, ..Default::default() },
    );
    assert_eq!(pipe.lanes_per_stage(), 2);
    let n = 8usize;
    let out = pipe.run_batch(&tokens[..n * per], n).unwrap();
    for i in 0..n {
        assert_logits(
            &out[i * nc..(i + 1) * nc],
            &expected[i * nc..(i + 1) * nc],
            &format!("hybrid img {i}"),
        );
    }
}

#[test]
fn pipeline_bit_exact_under_scalar_and_detected_kernels() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let (net, tokens, expected) = golden();
    let per = net.tokens_per_image();
    let nc = net.num_classes;
    let n = 8usize;
    // scalar oracle vs whatever CPU detection picks, at stage counts
    // 1 (monolithic) and 0 = max (fully unrolled, one segment per
    // stage), with fine-grained lanes active inside the stages — every
    // combination must reproduce the python logits bit-for-bit
    for kern in [kernels::scalar(), kernels::detect()] {
        for &stages in &[1usize, 0] {
            let pipe = Pipeline::new(
                net.clone(),
                PipelineConfig {
                    stages,
                    queue_depth: 2,
                    lanes: 4,
                    kernels: kern,
                    ..Default::default()
                },
            );
            let out = pipe.run_batch(&tokens[..n * per], n).unwrap();
            for i in 0..n {
                assert_logits(
                    &out[i * nc..(i + 1) * nc],
                    &expected[i * nc..(i + 1) * nc],
                    &format!("kernels {} stages {stages} img {i}", kern.name),
                );
            }
        }
    }
}

#[test]
fn excess_stage_request_clamps_to_depth_plus_embed() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let (net, tokens, expected) = golden();
    let per = net.tokens_per_image();
    let nc = net.num_classes;
    let pipe = Pipeline::new(
        net.clone(),
        PipelineConfig { stages: 99, queue_depth: 1, lanes: 1, ..Default::default() },
    );
    assert_eq!(
        pipe.stage_count(),
        net.depth + 1,
        "99 stages clamp to one per block plus the dedicated embed stage"
    );
    assert_eq!(pipe.queue_depth(), 1);
    let out = pipe.run_batch(&tokens[..per], 1).unwrap();
    assert_logits(&out[..nc], &expected[..nc], "clamped");
}

#[test]
fn both_partition_strategies_are_bit_exact_and_embed_stage_is_dedicated() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let (net, tokens, expected) = golden();
    let per = net.tokens_per_image();
    let nc = net.num_classes;
    let n = 8usize;
    for strategy in [PartitionStrategy::WorkProportional, PartitionStrategy::NearEven] {
        let pipe = Pipeline::new(
            net.clone(),
            PipelineConfig {
                stages: 0,
                queue_depth: 2,
                lanes: 1,
                partition: strategy,
                ..Default::default()
            },
        );
        assert_eq!(pipe.partition_strategy(), strategy);
        let out = pipe.run_batch(&tokens[..n * per], n).unwrap();
        for i in 0..n {
            assert_logits(
                &out[i * nc..(i + 1) * nc],
                &expected[i * nc..(i + 1) * nc],
                &format!("{strategy:?} img {i}"),
            );
        }
        let stats = pipe.stats();
        match strategy {
            // fully unrolled, the cost model gives patch-embed its own
            // block-less stage 0 and one block to each later stage
            PartitionStrategy::WorkProportional => {
                assert_eq!(stats.stages[0].blocks, (0, 0), "dedicated embed stage");
                for (si, s) in stats.stages.iter().enumerate().skip(1) {
                    assert_eq!(s.blocks.1 - s.blocks.0, 1, "stage {si} holds one block");
                }
            }
            // the legacy slicing packs a block next to embed and leaves
            // the tail stage block-less (head only)
            PartitionStrategy::NearEven => {
                assert_eq!(stats.stages[0].blocks, (0, 1));
                let last = stats.stages.last().unwrap();
                assert_eq!(last.blocks.0, last.blocks.1, "near-even tail stage is empty");
            }
        }
    }
}

#[test]
fn queue_depth_one_backpressure_no_deadlock_no_reordering() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let (net, tokens, expected) = golden();
    let per = net.tokens_per_image();
    let nc = net.num_classes;
    // depth-1 FIFOs: every hand-off serializes on backpressure; a
    // batch much larger than pipeline capacity must still stream
    // through, in order, with every logit pinned to its own image
    let pipe = Pipeline::new(
        net.clone(),
        PipelineConfig { stages: 0, queue_depth: 1, lanes: 1, ..Default::default() },
    );
    let n = 48usize;
    let s0 = pipe.stats();
    let out = pipe.run_batch(&tokens[..n * per], n).unwrap();
    for i in 0..n {
        assert_logits(
            &out[i * nc..(i + 1) * nc],
            &expected[i * nc..(i + 1) * nc],
            &format!("qd1 img {i}"),
        );
    }
    // every stage saw every image exactly once (no drops, no dupes)
    let d = pipe.stats().delta(&s0);
    for s in &d.stages {
        assert_eq!(s.images, n as u64, "{} image count", s.name);
    }
}

#[test]
fn repeated_batches_reuse_buffers_and_stay_pinned() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let (net, tokens, expected) = golden();
    let per = net.tokens_per_image();
    let nc = net.num_classes;
    let pipe = Pipeline::new(
        net.clone(),
        PipelineConfig { stages: 0, queue_depth: 2, lanes: 1, ..Default::default() },
    );
    for round in 0..3 {
        let n = 8usize;
        let out = pipe.run_batch(&tokens[..n * per], n).unwrap();
        for i in 0..n {
            assert_logits(
                &out[i * nc..(i + 1) * nc],
                &expected[i * nc..(i + 1) * nc],
                &format!("round {round} img {i}"),
            );
        }
    }
}

#[test]
fn dropping_the_pipeline_joins_all_stage_threads() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let (net, tokens, _) = golden();
    let per = net.tokens_per_image();
    let stage_baseline = pipeline::live_stages();
    let worker_baseline = LanePool::live_workers();
    for round in 0..3 {
        // 2 lanes per stage (10 lanes over 5 resident stages): each
        // stage owns an inner fabric worker that must be joined through
        // the same drop cascade
        let pipe = Pipeline::new(
            net.clone(),
            PipelineConfig { stages: 0, queue_depth: 1, lanes: 10, ..Default::default() },
        );
        assert_eq!(
            pipeline::live_stages(),
            stage_baseline + pipe.stage_count(),
            "round {round}: one resident thread per stage"
        );
        let _ = pipe.run_batch(&tokens[..4 * per], 4).unwrap();
        drop(pipe);
        assert_eq!(
            pipeline::live_stages(),
            stage_baseline,
            "round {round}: pipeline drop must join its stage threads"
        );
        assert_eq!(
            LanePool::live_workers(),
            worker_baseline,
            "round {round}: stage drop must join its inner fabric workers"
        );
    }
}

#[test]
fn model_server_in_pipeline_mode_matches_golden() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let manifest = Manifest::load(&fixture_dir()).unwrap();
    let (net, tokens, expected) = golden();
    let per = net.tokens_per_image();
    let nc = net.num_classes;
    let config = RuntimeConfig::new(BackendKind::Interpreter)
        .with_lanes(Some(2))
        .with_mode(ExecMode::Pipeline { stages: 2, queue_depth: 2 });
    let server = ModelServer::start_with_config(&manifest, "tiny-synth", 2, config).unwrap();
    let n = 16usize;
    let images: Vec<Vec<f32>> = tokens.chunks(per).take(n).map(|c| c.to_vec()).collect();
    let responses = server.infer_all(images).unwrap();
    assert_eq!(responses.len(), n);
    for (i, r) in responses.iter().enumerate() {
        for (k, (&g, &w)) in r.logits.iter().zip(&expected[i * nc..(i + 1) * nc]).enumerate() {
            assert_eq!(g.to_bits(), (w as f32).to_bits(), "image {i} logit {k}");
        }
    }
    drop(server);
    // the coordinator's unload cascade reaches the stage threads
    assert_eq!(pipeline::live_stages(), 0, "server drop must join pipeline stages");
}

#[test]
fn drop_mid_stream_drains_answers_everything_and_joins_cleanly() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let manifest = Manifest::load(&fixture_dir()).unwrap();
    let (net, tokens, _) = golden();
    let per = net.tokens_per_image();
    let stage_baseline = pipeline::live_stages();
    let worker_baseline = LanePool::live_workers();
    let config = RuntimeConfig::new(BackendKind::Interpreter)
        .with_lanes(Some(4))
        .with_mode(ExecMode::Pipeline { stages: 0, queue_depth: 1 });
    let server = ModelServer::start_with_config(&manifest, "tiny-synth", 50, config).unwrap();
    // flood the server, then drop it with requests still in flight: the
    // delivery guarantee says every reply channel gets exactly one
    // answer (logits if the dispatch ran, an explicit error otherwise)
    let rxs: Vec<_> = (0..24usize)
        .map(|i| server.submit(tokens[i * per..(i + 1) * per].to_vec()).unwrap())
        .collect();
    drop(server);
    // not asserting how many succeeded: what dispatched before the drop
    // is timing-dependent — only that every reply arrived, exactly once
    let mut answered = 0usize;
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx
            .recv()
            .unwrap_or_else(|_| panic!("request {i}: reply sender dropped without a message"));
        if reply.is_ok() {
            answered += 1;
        }
    }
    assert!(answered <= 24);
    // whatever ran, ran to completion; nothing hung, nothing leaked
    assert_eq!(pipeline::live_stages(), stage_baseline, "stage threads leaked past drop");
    assert_eq!(LanePool::live_workers(), worker_baseline, "fabric workers leaked past drop");
}
