//! Fault-tolerance acceptance suite against the golden fixture:
//! supervised replica restarts, deadline-aware bounded admission, and
//! graceful total degradation, all driven by the deterministic
//! fault-injection harness (`RuntimeConfig::with_faults` /
//! `HGPIPE_FAULTS`).
//!
//! 1. **chaos** — with seeded replica panics injected at a 10% dispatch
//!    rate under a 256-request load, every accepted request still gets
//!    exactly one bit-exact reply, in both execution modes and at 1/2/4
//!    replicas, and the fleet is back to full strength afterwards;
//! 2. **admission** — a bounded front queue sheds with a downcastable
//!    `Overloaded` error instead of queueing unboundedly, and every
//!    request it *did* accept completes;
//! 3. **deadlines** — an expired request is answered with
//!    `DeadlineExceeded` at pop time without ever spending a forward
//!    pass on it;
//! 4. **degradation** — a fleet whose replicas all flap to retirement
//!    fails outstanding requests explicitly (nobody hangs on `recv`)
//!    and closes the front door;
//! 5. **atomic startup** — injected artifact-load failures surface as a
//!    `start_with_config` error without leaking threads.
//!
//! Tests serialize on a lock: `pipeline::live_stages` and
//! `LanePool::live_workers` are process-wide counters, and concurrent
//! replica-creating tests would make their baseline assertions racy.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use hgpipe::artifacts::Manifest;
use hgpipe::coordinator::faults::FaultPlan;
use hgpipe::coordinator::{DeadlineExceeded, ModelServer, Overloaded};
use hgpipe::runtime::fabric::LanePool;
use hgpipe::runtime::interpreter::QuantViT;
use hgpipe::runtime::{pipeline, BackendKind, ExecMode, RuntimeConfig};

static SERIAL: Mutex<()> = Mutex::new(());

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join("golden")
}

fn manifest() -> Manifest {
    Manifest::load(&fixture_dir()).expect("committed golden fixture")
}

fn golden() -> (Arc<QuantViT>, Vec<f32>, Vec<f64>) {
    let dir = fixture_dir();
    let net = Arc::new(QuantViT::load(&dir.join("tinyvit_bundle.json")).expect("bundle loads"));
    let tokens = std::fs::read(dir.join("golden_tokens.bin"))
        .unwrap()
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
        .collect();
    let logits = std::fs::read(dir.join("golden_logits.bin"))
        .unwrap()
        .chunks_exact(8)
        .map(|b| f64::from_le_bytes(b.try_into().unwrap()))
        .collect();
    (net, tokens, logits)
}

/// Injected panics are *expected* here; the default hook would spray a
/// backtrace per restart. Filter exactly those, keep the hook's real
/// output for anything else (a genuine bug must stay loud).
fn silence_injected_panics() {
    static HOOK: std::sync::Once = std::sync::Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<&str>()
                .is_some_and(|m| m.contains("faults harness"));
            if !injected {
                prev(info);
            }
        }));
    });
}

#[test]
fn injected_panics_never_lose_a_request_in_either_mode() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    silence_injected_panics();
    let manifest = manifest();
    let (net, tokens, expected) = golden();
    let per = net.tokens_per_image();
    let nc = net.num_classes;
    let stage_baseline = pipeline::live_stages();
    let worker_baseline = LanePool::live_workers();
    let n = 256usize;
    let plan = FaultPlan { panic_rate: 0.1, seed: 42, ..FaultPlan::default() };
    let mut total_restarts = 0u64;
    for &replicas in &[1usize, 2, 4] {
        for mode in [ExecMode::LaneParallel, ExecMode::Pipeline { stages: 0, queue_depth: 2 }] {
            let config = RuntimeConfig::new(BackendKind::Interpreter)
                .with_lanes(Some(2))
                .with_mode(mode)
                .with_replicas(Some(replicas))
                .with_faults(Some(plan));
            let server = ModelServer::start_with_config(&manifest, "tiny-synth", 2, config)
                .unwrap_or_else(|e| panic!("start {replicas} replicas / {mode:?}: {e:#}"));
            let rxs: Vec<_> = (0..n)
                .map(|i| server.submit(tokens[(i % 16) * per..(i % 16 + 1) * per].to_vec()))
                .collect::<Result<_, _>>()
                .expect("all submits accepted (unbounded queue)");
            // exactly-once with the correct bits: a request requeued by
            // a dying replica re-runs the same pure forward pass, so a
            // retry is indistinguishable from a first attempt
            for (i, rx) in rxs.into_iter().enumerate() {
                let reply = rx
                    .recv()
                    .unwrap_or_else(|_| panic!("request {i}: reply sender dropped"))
                    .unwrap_or_else(|e| panic!("request {i} failed under chaos: {e:#}"));
                for (k, (&g, &w)) in reply
                    .logits
                    .iter()
                    .zip(&expected[(i % 16) * nc..(i % 16 + 1) * nc])
                    .enumerate()
                {
                    assert_eq!(
                        g.to_bits(),
                        (w as f32).to_bits(),
                        "{replicas} replicas / {mode:?}: image {i} logit {k}"
                    );
                }
            }
            let rollup = server.metrics.lock().unwrap().clone();
            assert_eq!(rollup.count(), n, "{replicas} replicas / {mode:?}");
            assert_eq!(rollup.failed, 0, "{replicas} replicas / {mode:?}");
            // a 10% per-dispatch panic rate cannot retire anyone (that
            // takes 7 consecutive deaths): the fleet ends at strength
            assert_eq!(server.live_replicas(), replicas, "{replicas} replicas / {mode:?}");
            assert_eq!(rollup.retired, 0, "{replicas} replicas / {mode:?}");
            total_restarts += rollup.restarts;
            drop(server);
        }
    }
    assert!(total_restarts > 0, "the harness must actually have killed replicas");
    assert_eq!(pipeline::live_stages(), stage_baseline, "stage threads leaked past restarts");
    assert_eq!(LanePool::live_workers(), worker_baseline, "fabric workers leaked past restarts");
}

#[test]
fn bounded_queue_sheds_overload_with_a_downcastable_error() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let manifest = manifest();
    let (net, tokens, _) = golden();
    let per = net.tokens_per_image();
    // one replica wedged by a 100%-rate stall holds the queue full long
    // enough to observe deterministic shedding
    let plan = FaultPlan { stall_rate: 1.0, stall_ms: 300, seed: 7, ..FaultPlan::default() };
    let config = RuntimeConfig::new(BackendKind::Interpreter)
        .with_lanes(Some(1))
        .with_replicas(Some(1))
        .with_queue_capacity(Some(2))
        .with_faults(Some(plan));
    let server = ModelServer::start_with_config(&manifest, "tiny-synth", 0, config).unwrap();
    assert_eq!(server.queue_capacity(), Some(2));
    let first = server.submit(tokens[..per].to_vec()).expect("empty queue admits");
    // wait for the replica to pop it (the stall begins right after),
    // then give it a beat to get past its batch top-up
    let t0 = std::time::Instant::now();
    while server.queue_len() != 0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "replica never picked up request");
        std::thread::yield_now();
    }
    std::thread::sleep(Duration::from_millis(50));
    let second = server.submit(tokens[..per].to_vec()).expect("capacity 2: slot 1");
    let third = server.submit(tokens[per..2 * per].to_vec()).expect("capacity 2: slot 2");
    let err = server
        .submit(tokens[..per].to_vec())
        .expect_err("queue at capacity must shed, not grow");
    assert_eq!(err.downcast_ref::<Overloaded>(), Some(&Overloaded { capacity: 2 }));
    assert_eq!(server.metrics.lock().unwrap().shed, 1);
    // pushback is about *admission*, never about accepted work: all
    // three admitted requests complete once the stalls drain
    for (name, rx) in [("first", first), ("second", second), ("third", third)] {
        rx.recv()
            .unwrap_or_else(|_| panic!("{name}: reply sender dropped"))
            .unwrap_or_else(|e| panic!("{name} failed: {e:#}"));
    }
    assert_eq!(server.metrics.lock().unwrap().count(), 3);
}

#[test]
fn expired_deadlines_are_answered_without_a_forward_pass() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let manifest = manifest();
    let (net, tokens, _) = golden();
    let per = net.tokens_per_image();
    let config =
        RuntimeConfig::new(BackendKind::Interpreter).with_lanes(Some(1)).with_replicas(Some(1));
    let server = ModelServer::start_with_config(&manifest, "tiny-synth", 0, config).unwrap();
    // a zero budget is expired the instant a replica pops it
    let rxs: Vec<_> = (0..4usize)
        .map(|_| {
            server.submit_with_deadline(tokens[..per].to_vec(), Some(Duration::ZERO)).unwrap()
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx.recv().unwrap_or_else(|_| panic!("request {i}: reply sender dropped"));
        let err = reply.expect_err("zero deadline cannot be met");
        assert!(
            err.downcast_ref::<DeadlineExceeded>().is_some(),
            "request {i}: expected DeadlineExceeded, got: {err:#}"
        );
    }
    {
        let m = server.metrics.lock().unwrap();
        assert_eq!(m.expired, 4);
        assert_eq!(m.count(), 0, "expired requests are not latency samples");
        assert!(m.exec_ms_total == 0.0, "no forward pass may have run");
    }
    // expiry is per-request: live work sharing the queue still computes
    let live = server.submit(tokens[..per].to_vec()).unwrap();
    let doomed =
        server.submit_with_deadline(tokens[per..2 * per].to_vec(), Some(Duration::ZERO)).unwrap();
    live.recv().unwrap().expect("undeadlined request completes");
    assert!(doomed.recv().unwrap().is_err());
    let m = server.metrics.lock().unwrap();
    assert_eq!(m.expired, 5);
    assert_eq!(m.count(), 1);
}

#[test]
fn flapping_fleet_retires_gracefully_and_fails_requests_explicitly() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    silence_injected_panics();
    let manifest = manifest();
    let (net, tokens, _) = golden();
    let per = net.tokens_per_image();
    let worker_baseline = LanePool::live_workers();
    // every dispatch panics: no replica can ever complete a request, so
    // both flap through 7 consecutive deaths to retirement
    let plan = FaultPlan { panic_rate: 1.0, seed: 11, ..FaultPlan::default() };
    let config = RuntimeConfig::new(BackendKind::Interpreter)
        .with_lanes(Some(1))
        .with_replicas(Some(2))
        .with_faults(Some(plan));
    let server = ModelServer::start_with_config(&manifest, "tiny-synth", 0, config).unwrap();
    assert_eq!(server.live_replicas(), 2);
    let n = 6usize;
    let rxs: Vec<_> = (0..n)
        .map(|i| server.submit(tokens[(i % 16) * per..(i % 16 + 1) * per].to_vec()).unwrap())
        .collect();
    // nobody hangs: once the last replica retires it closes the front
    // door and fails whatever is still queued, explicitly
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx.recv().unwrap_or_else(|_| panic!("request {i}: reply sender dropped"));
        assert!(reply.is_err(), "request {i} cannot have computed (all dispatches panic)");
    }
    assert_eq!(server.live_replicas(), 0, "the whole fleet must have retired");
    {
        let m = server.metrics.lock().unwrap();
        assert_eq!(m.retired, 2);
        // each replica dies exactly MAX_CONSECUTIVE_DEATHS + 1 times
        // before retiring, and every death was a supervised restart
        assert_eq!(m.restarts, 14);
        assert_eq!(m.failed, n as u64);
        assert!(m.retried > 0, "dying replicas must have requeued their batches");
    }
    // the front door is closed: new work is refused, fast
    let err = server.submit(tokens[..per].to_vec()).expect_err("retired fleet accepts nothing");
    assert!(err.to_string().contains("server stopped"), "got: {err:#}");
    drop(server);
    assert_eq!(LanePool::live_workers(), worker_baseline, "retired fleets must join workers");
}

#[test]
fn injected_load_failures_fail_startup_atomically() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let manifest = manifest();
    let stage_baseline = pipeline::live_stages();
    let worker_baseline = LanePool::live_workers();
    let plan = FaultPlan { load_fail_rate: 1.0, seed: 3, ..FaultPlan::default() };
    let config = RuntimeConfig::new(BackendKind::Interpreter)
        .with_replicas(Some(3))
        .with_faults(Some(plan));
    let err = ModelServer::start_with_config(&manifest, "tiny-synth", 2, config)
        .expect_err("every replica's artifact load is injected to fail");
    assert!(format!("{err:#}").contains("injected artifact-load failure"), "got: {err:#}");
    assert_eq!(pipeline::live_stages(), stage_baseline, "failed startup leaked stage threads");
    assert_eq!(LanePool::live_workers(), worker_baseline, "failed startup leaked workers");
}

#[test]
fn fault_and_capacity_config_resolution() {
    // resolution only (no server): explicit config beats the env
    // fallback, and an all-zero plan resolves to "off"
    let plan = FaultPlan { panic_rate: 0.5, ..FaultPlan::default() };
    let config = RuntimeConfig::new(BackendKind::Interpreter).with_faults(Some(plan));
    assert_eq!(config.resolve_faults(), Some(plan));
    assert_eq!(
        RuntimeConfig::new(BackendKind::Interpreter)
            .with_faults(Some(FaultPlan::default()))
            .resolve_faults(),
        None,
        "an all-zero-rate plan is OFF, not an active injector"
    );
    assert_eq!(
        RuntimeConfig::new(BackendKind::Interpreter)
            .with_queue_capacity(Some(8))
            .resolve_queue_capacity(),
        Some(8)
    );
    assert_eq!(
        RuntimeConfig::new(BackendKind::Interpreter)
            .with_queue_capacity(Some(0))
            .resolve_queue_capacity(),
        None,
        "zero capacity means unbounded, not a queue that rejects everything"
    );
}
