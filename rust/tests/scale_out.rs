//! Multi-executor scale-out against the golden fixture: N executor
//! replicas per model behind one shared MPMC front queue
//! (`RuntimeConfig::replicas` / `HGPIPE_REPLICAS` / `--replicas`).
//!
//! 1. **bit-exactness** — logits are bit-identical to the python
//!    reference at replicas 1, 2 and 4, in both the lane-parallel and
//!    pipeline execution modes (each replica owns its own fabric or
//!    resident pipeline);
//! 2. **lifecycle** — dropping a replicated server (including
//!    mid-stream with requests in flight) answers every reply exactly
//!    once and joins every executor, stage and fabric worker thread;
//! 3. **metrics** — per-replica metrics decompose the rollup exactly:
//!    every request (successes *and* failed dispatches) is recorded by
//!    exactly one replica, so sums never double count.
//!
//! Tests serialize on a lock: `pipeline::live_stages` and
//! `LanePool::live_workers` are process-wide counters, and concurrent
//! replica-creating tests would make their baseline assertions racy.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use hgpipe::artifacts::Manifest;
use hgpipe::coordinator::ModelServer;
use hgpipe::runtime::fabric::LanePool;
use hgpipe::runtime::interpreter::QuantViT;
use hgpipe::runtime::{faulty, pipeline, BackendKind, ExecMode, RuntimeConfig};

static SERIAL: Mutex<()> = Mutex::new(());

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join("golden")
}

fn manifest() -> Manifest {
    Manifest::load(&fixture_dir()).expect("committed golden fixture")
}

fn golden() -> (Arc<QuantViT>, Vec<f32>, Vec<f64>) {
    let dir = fixture_dir();
    let net = Arc::new(QuantViT::load(&dir.join("tinyvit_bundle.json")).expect("bundle loads"));
    let tokens = std::fs::read(dir.join("golden_tokens.bin"))
        .unwrap()
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
        .collect();
    let logits = std::fs::read(dir.join("golden_logits.bin"))
        .unwrap()
        .chunks_exact(8)
        .map(|b| f64::from_le_bytes(b.try_into().unwrap()))
        .collect();
    (net, tokens, logits)
}

#[test]
fn replicas_bit_exact_in_both_execution_modes() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let manifest = manifest();
    let (net, tokens, expected) = golden();
    let per = net.tokens_per_image();
    let nc = net.num_classes;
    let n = 16usize;
    let images: Vec<Vec<f32>> = tokens.chunks(per).take(n).map(|c| c.to_vec()).collect();
    for &replicas in &[1usize, 2, 4] {
        for mode in [ExecMode::LaneParallel, ExecMode::Pipeline { stages: 0, queue_depth: 2 }] {
            let config = RuntimeConfig::new(BackendKind::Interpreter)
                .with_lanes(Some(2))
                .with_mode(mode)
                .with_replicas(Some(replicas));
            let server = ModelServer::start_with_config(&manifest, "tiny-synth", 2, config)
                .unwrap_or_else(|e| panic!("start {replicas} replicas / {mode:?}: {e:#}"));
            assert_eq!(server.replicas(), replicas);
            let responses = server.infer_all(images.clone()).expect("replicated inference");
            assert_eq!(responses.len(), n);
            for (i, r) in responses.iter().enumerate() {
                for (k, (&g, &w)) in
                    r.logits.iter().zip(&expected[i * nc..(i + 1) * nc]).enumerate()
                {
                    assert_eq!(
                        g.to_bits(),
                        (w as f32).to_bits(),
                        "{replicas} replicas / {mode:?}: image {i} logit {k}"
                    );
                }
            }
            // one replica fleet per server: unload must join everything
            drop(server);
        }
    }
    assert_eq!(pipeline::live_stages(), 0, "unload joined all pipeline stages");
}

#[test]
fn drop_mid_stream_with_replicas_answers_everything_and_leaks_no_threads() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let manifest = manifest();
    let (net, tokens, _) = golden();
    let per = net.tokens_per_image();
    let stage_baseline = pipeline::live_stages();
    let worker_baseline = LanePool::live_workers();
    let config = RuntimeConfig::new(BackendKind::Interpreter)
        .with_lanes(Some(10))
        .with_mode(ExecMode::Pipeline { stages: 0, queue_depth: 1 })
        .with_replicas(Some(3));
    let server = ModelServer::start_with_config(&manifest, "tiny-synth", 50, config).unwrap();
    // 3 replicas x 5 resident stages each, 2 inner lanes per stage
    assert_eq!(pipeline::live_stages(), stage_baseline + 3 * (net.depth + 1));
    // flood, then drop with requests in flight: the delivery guarantee
    // says every reply channel gets exactly one answer, whichever
    // replica (or the shutdown drain) ends up owning the request
    let rxs: Vec<_> = (0..24usize)
        .map(|i| server.submit(tokens[(i % 16) * per..(i % 16 + 1) * per].to_vec()).unwrap())
        .collect();
    drop(server);
    let mut answered = 0usize;
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx
            .recv()
            .unwrap_or_else(|_| panic!("request {i}: reply sender dropped without a message"));
        if reply.is_ok() {
            answered += 1;
        }
    }
    assert!(answered <= 24);
    assert_eq!(pipeline::live_stages(), stage_baseline, "stage threads leaked past drop");
    assert_eq!(LanePool::live_workers(), worker_baseline, "fabric workers leaked past drop");
}

#[test]
fn failed_dispatches_are_counted_exactly_once_across_replicas() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let config = RuntimeConfig::new(BackendKind::Faulty).with_replicas(Some(3));
    let server = ModelServer::start_with_config(&manifest(), "any", 1, config).unwrap();
    assert_eq!(server.replicas(), 3);
    let n = 6usize;
    let rxs: Vec<_> = (0..n)
        .map(|_| server.submit(vec![0.5; faulty::TOKENS_PER_IMAGE]).unwrap())
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx.recv().unwrap_or_else(|_| panic!("request {i}: reply lost"));
        assert!(reply.is_err(), "faulty backend cannot succeed");
    }
    // every failure lands in the rollup once and in exactly one
    // replica's own metrics — the decomposition must sum, not double
    let rollup_failed = server.metrics.lock().unwrap().failed;
    assert_eq!(rollup_failed, n as u64);
    let per_replica = server.replica_metrics();
    assert_eq!(per_replica.len(), 3);
    assert_eq!(per_replica.iter().map(|m| m.failed).sum::<u64>(), n as u64);
}

#[test]
fn successful_requests_decompose_across_replica_metrics() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let manifest = manifest();
    let (net, tokens, _) = golden();
    let per = net.tokens_per_image();
    let config = RuntimeConfig::new(BackendKind::Interpreter)
        .with_lanes(Some(1))
        .with_replicas(Some(2));
    let server = ModelServer::start_with_config(&manifest, "tiny-synth", 2, config).unwrap();
    let n = 12usize;
    let images: Vec<Vec<f32>> =
        (0..n).map(|i| tokens[(i % 16) * per..(i % 16 + 1) * per].to_vec()).collect();
    server.infer_all(images).expect("replicated inference");
    let rollup = server.metrics.lock().unwrap().clone();
    assert_eq!(rollup.count(), n);
    assert_eq!(rollup.failed, 0);
    let per_replica = server.replica_metrics();
    assert_eq!(per_replica.iter().map(|m| m.count()).sum::<usize>(), n);
    let exec_sum: f64 = per_replica.iter().map(|m| m.exec_ms_total).sum();
    assert!(
        (exec_sum - rollup.exec_ms_total).abs() < 1e-6,
        "exec breakdown must sum to the rollup: {exec_sum} vs {}",
        rollup.exec_ms_total
    );
}

#[test]
fn replicas_share_one_model_artifact() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let manifest = manifest();
    let (net, tokens, _) = golden();
    let per = net.tokens_per_image();
    for mode in [ExecMode::LaneParallel, ExecMode::Pipeline { stages: 0, queue_depth: 2 }] {
        let config = RuntimeConfig::new(BackendKind::Interpreter)
            .with_lanes(Some(1))
            .with_mode(mode)
            .with_replicas(Some(4));
        let server = ModelServer::start_with_config(&manifest, "tiny-synth", 2, config).unwrap();
        let artifact = server.artifact().expect("interpreter backend shares an artifact");
        // one weight copy for the whole fleet: every replica's
        // executors hold Arc clones of the server's artifact, never a
        // reload, so the refcount is bounded above the fleet size and
        // the footprint is paid exactly once
        assert!(
            artifact.strong_count() >= 1 + 4,
            "4 replicas must all hold the shared artifact (refs: {})",
            artifact.strong_count()
        );
        let solo = hgpipe::runtime::ModelArtifact::load(&manifest, "tiny-synth").unwrap();
        assert_eq!(
            artifact.footprint_bytes(),
            solo.footprint_bytes(),
            "fleet footprint is one artifact, not replicas x artifact"
        );
        assert!(!artifact.shares_weights_with(&solo), "independent loads are distinct");
        // sharing must not change the numbers: still bit-stable across
        // the replicated fleet
        let responses = server.infer_all(vec![tokens[..per].to_vec(); 4]).unwrap();
        let first = &responses[0].logits;
        for r in &responses[1..] {
            assert_eq!(&r.logits, first, "shared-artifact replicas disagree");
        }
        drop(server);
    }
}

#[test]
fn explicit_replicas_beat_the_env_fallback_and_clamp_to_one() {
    // resolution only (no server): explicit wins over HGPIPE_REPLICAS,
    // zero clamps to one; the CI matrix exercises the env route itself
    assert_eq!(
        RuntimeConfig::new(BackendKind::Interpreter).with_replicas(Some(3)).resolve_replicas(),
        3
    );
    assert_eq!(
        RuntimeConfig::new(BackendKind::Interpreter).with_replicas(Some(0)).resolve_replicas(),
        1,
        "zero replicas clamps to one"
    );
    assert!(
        RuntimeConfig::new(BackendKind::Interpreter).resolve_replicas() >= 1,
        "unset resolves to at least one replica"
    );
}
