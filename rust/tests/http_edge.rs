//! The network front door over the committed golden fixture: bit-exact
//! replies across the socket, typed-error → status-code mapping,
//! malformed-input robustness (no wedged or leaked workers — pinned by
//! the `live_workers` gauge), keep-alive, overload shedding with
//! per-source accounting, admission-time deadline expiry, and the
//! exactly-one-reply invariant across a graceful drain.
//!
//! Bind address honors the `HGPIPE_HTTP` env fallback (the CI
//! chaos-over-HTTP matrix entry routes through it with `127.0.0.1:0`),
//! defaulting to an ephemeral loopback port.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use hgpipe::artifacts::Manifest;
use hgpipe::coordinator::faults::FaultPlan;
use hgpipe::coordinator::Router;
use hgpipe::runtime::{BackendKind, RuntimeConfig};
use hgpipe::server::{HttpConfig, HttpServer, PROMETHEUS_CONTENT_TYPE};

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join("golden")
}

fn manifest() -> Manifest {
    Manifest::load(&fixture_dir()).expect("committed golden fixture")
}

fn config() -> RuntimeConfig {
    RuntimeConfig::new(BackendKind::Interpreter).with_lanes(Some(2))
}

fn bind_addr() -> String {
    hgpipe::server::addr_from_env().unwrap_or_else(|| "127.0.0.1:0".into())
}

fn start(cfg: RuntimeConfig, http: HttpConfig) -> (HttpServer, Arc<Router>) {
    let router =
        Arc::new(Router::start(&manifest(), &["tiny-synth".to_string()], 2, cfg).unwrap());
    let server = HttpServer::bind(&bind_addr(), router.clone(), http).unwrap();
    (server, router)
}

fn read_f32(path: &Path) -> Vec<f32> {
    let bytes = std::fs::read(path).unwrap();
    bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

fn read_f64(path: &Path) -> Vec<f64> {
    let bytes = std::fs::read(path).unwrap();
    bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect()
}

/// Golden fixture: per-image token slices and the expected (argmax,
/// f32 logits) the interpreter must reproduce bit-exactly.
fn golden() -> (Vec<Vec<f32>>, Vec<(usize, Vec<f32>)>) {
    let dir = fixture_dir();
    let tokens = read_f32(&dir.join("golden_tokens.bin"));
    let logits = read_f64(&dir.join("golden_logits.bin"));
    let server = Router::start(&manifest(), &["tiny-synth".to_string()], 2, config()).unwrap();
    let per = server.server("tiny-synth").unwrap().tokens_per_image();
    let nc = server.server("tiny-synth").unwrap().num_classes();
    drop(server);
    let images: Vec<Vec<f32>> = tokens.chunks_exact(per).map(<[f32]>::to_vec).collect();
    let expected: Vec<(usize, Vec<f32>)> = logits
        .chunks_exact(nc)
        .map(|row| {
            let row: Vec<f32> = row.iter().map(|&v| v as f32).collect();
            // same reduction as the coordinator: total_cmp, last max wins
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(j, _)| j)
                .unwrap_or(0);
            (argmax, row)
        })
        .collect();
    (images, expected)
}

// ---------------- tiny blocking HTTP/1.1 client ----------------

struct Reply {
    status: u16,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl Reply {
    fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    fn text(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

/// Read exactly one response off `stream` (keep-alive safe: stops at
/// Content-Length, never waits for EOF).
fn read_reply(stream: &mut TcpStream) -> Reply {
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = Vec::new();
    let head_end = loop {
        if let Some(p) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break p;
        }
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk).expect("response head");
        assert!(n > 0, "connection closed before a full response head: {buf:?}");
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8(buf[..head_end].to_vec()).unwrap();
    let mut lines = head.split("\r\n");
    let status: u16 =
        lines.next().unwrap().split(' ').nth(1).expect("status code").parse().unwrap();
    let headers: Vec<(String, String)> = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
        .collect();
    let len: usize = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| v.parse().unwrap())
        .unwrap_or(0);
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < len {
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk).expect("response body");
        assert!(n > 0, "connection closed mid-body");
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(len);
    Reply { status, headers, body }
}

fn send_raw(addr: &str, raw: &[u8]) -> Reply {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(raw).unwrap();
    read_reply(&mut stream)
}

fn request_on(
    stream: &mut TcpStream,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> Reply {
    let mut head = format!("{method} {path} HTTP/1.1\r\nHost: t\r\n");
    for (k, v) in headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str(&format!("Content-Length: {}\r\n\r\n", body.len()));
    stream.write_all(head.as_bytes()).unwrap();
    stream.write_all(body).unwrap();
    read_reply(stream)
}

fn request(addr: &str, method: &str, path: &str, hs: &[(&str, &str)], body: &[u8]) -> Reply {
    let mut stream = TcpStream::connect(addr).unwrap();
    request_on(&mut stream, method, path, hs, body)
}

fn infer_path() -> &'static str {
    "/v1/models/tiny-synth/infer"
}

fn image_bytes(image: &[f32]) -> Vec<u8> {
    image.iter().flat_map(|v| v.to_le_bytes()).collect()
}

fn parse_reply_logits(body: &str) -> Vec<f32> {
    body.split("\"logits\":[")
        .nth(1)
        .expect("logits array in reply")
        .split(']')
        .next()
        .unwrap()
        .split(',')
        .map(|t| t.parse().unwrap())
        .collect()
}

fn parse_reply_argmax(body: &str) -> usize {
    body.split("\"argmax\":")
        .nth(1)
        .expect("argmax in reply")
        .split(|c: char| !c.is_ascii_digit())
        .next()
        .unwrap()
        .parse()
        .unwrap()
}

// ---------------- the tests ----------------

#[test]
fn binary_bodies_reply_bit_exact_vs_golden() {
    let (server, _router) = start(config(), HttpConfig::default());
    let addr = server.local_addr().to_string();
    let (images, expected) = golden();
    for (image, (want_argmax, want_logits)) in images.iter().zip(&expected).take(4) {
        let reply = request(&addr, "POST", infer_path(), &[], &image_bytes(image));
        assert_eq!(reply.status, 200, "{}", reply.text());
        let body = reply.text();
        assert_eq!(parse_reply_argmax(&body), *want_argmax);
        let logits = parse_reply_logits(&body);
        assert_eq!(logits.len(), want_logits.len());
        for (got, want) in logits.iter().zip(want_logits) {
            assert_eq!(got.to_bits(), want.to_bits(), "logits must cross the socket bit-exact");
        }
    }
}

#[test]
fn json_bodies_decode_like_binary_ones() {
    let (server, _router) = start(config(), HttpConfig::default());
    let addr = server.local_addr().to_string();
    let (images, expected) = golden();
    let json = format!(
        "[{}]",
        images[0].iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",")
    );
    let reply = request(
        &addr,
        "POST",
        infer_path(),
        &[("Content-Type", "application/json")],
        json.as_bytes(),
    );
    assert_eq!(reply.status, 200, "{}", reply.text());
    assert_eq!(parse_reply_argmax(&reply.text()), expected[0].0);
}

#[test]
fn keep_alive_serves_many_requests_on_one_connection() {
    let (server, _router) = start(config(), HttpConfig::default());
    let addr = server.local_addr().to_string();
    let (images, expected) = golden();
    let mut stream = TcpStream::connect(&addr).unwrap();
    for (image, (want_argmax, _)) in images.iter().zip(&expected).take(3) {
        let reply = request_on(&mut stream, "POST", infer_path(), &[], &image_bytes(image));
        assert_eq!(reply.status, 200);
        assert_eq!(reply.header("connection"), Some("keep-alive"));
        assert_eq!(parse_reply_argmax(&reply.text()), *want_argmax);
    }
    // a GET rides the same connection
    let health = request_on(&mut stream, "GET", "/healthz", &[], b"");
    assert_eq!(health.status, 200);
}

#[test]
fn unknown_model_maps_to_404_naming_whats_served() {
    let (server, _router) = start(config(), HttpConfig::default());
    let addr = server.local_addr().to_string();
    let per = _router.server("tiny-synth").unwrap().tokens_per_image();
    let reply =
        request(&addr, "POST", "/v1/models/nope/infer", &[], &image_bytes(&vec![0.0; per]));
    assert_eq!(reply.status, 404);
    let body = reply.text();
    assert!(body.contains("nope") && body.contains("tiny-synth"), "{body}");
}

#[test]
fn unknown_routes_404_and_wrong_methods_405() {
    let (server, _router) = start(config(), HttpConfig::default());
    let addr = server.local_addr().to_string();
    assert_eq!(request(&addr, "GET", "/nope", &[], b"").status, 404);
    let infer_get = request(&addr, "GET", infer_path(), &[], b"");
    assert_eq!(infer_get.status, 405);
    assert_eq!(infer_get.header("allow"), Some("POST"));
    let metrics_del = request(&addr, "DELETE", "/metrics", &[], b"");
    assert_eq!(metrics_del.status, 405);
    assert_eq!(metrics_del.header("allow"), Some("GET"));
}

#[test]
fn malformed_input_is_answered_and_never_wedges_a_worker() {
    // small caps so every violation fits in one client write (nothing
    // is left unread when the server answers-and-closes)
    let http = HttpConfig {
        workers: 3,
        max_head_bytes: 256,
        // big enough for a real tiny-synth image (12 KiB), small
        // enough that an oversized declaration is cheap to make
        max_body_bytes: 16 * 1024,
        read_timeout: Duration::from_millis(500),
        ..HttpConfig::default()
    };
    let (server, _router) = start(config(), http);
    let addr = server.local_addr().to_string();
    assert_eq!(server.live_workers(), 3);

    // truncated request line
    assert_eq!(send_raw(&addr, b"GET /\r\n\r\n").status, 400);
    // garbage Content-Length
    let r = send_raw(
        &addr,
        b"POST /v1/models/tiny-synth/infer HTTP/1.1\r\nContent-Length: x\r\n\r\n",
    );
    assert_eq!(r.status, 400);
    // missing Content-Length on POST
    let r = send_raw(&addr, b"POST /v1/models/tiny-synth/infer HTTP/1.1\r\n\r\n");
    assert_eq!(r.status, 411);
    // declared body over the cap: 413 before any body byte is read
    let r = send_raw(
        &addr,
        b"POST /v1/models/tiny-synth/infer HTTP/1.1\r\nContent-Length: 32768\r\n\r\n",
    );
    assert_eq!(r.status, 413);
    // unsupported protocol version
    assert_eq!(send_raw(&addr, b"GET /healthz HTTP/3.0\r\n\r\n").status, 505);
    // oversized head (complete, over the 256-byte cap)
    let big = format!("GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "a".repeat(1024));
    assert_eq!(send_raw(&addr, big.as_bytes()).status, 431);
    // body not a multiple of 4 / wrong token count are 400s from decode
    assert_eq!(request(&addr, "POST", infer_path(), &[], &[1, 2, 3]).status, 400);
    assert_eq!(request(&addr, "POST", infer_path(), &[], &image_bytes(&[0.5; 3])).status, 400);

    // the pool survived all of it and still serves real work
    assert_eq!(server.live_workers(), 3, "malformed input must not kill or leak workers");
    let (images, expected) = golden();
    let reply = request(&addr, "POST", infer_path(), &[], &image_bytes(&images[0]));
    assert_eq!(reply.status, 200);
    assert_eq!(parse_reply_argmax(&reply.text()), expected[0].0);
}

#[test]
fn slow_client_is_disconnected_within_the_read_budget() {
    let http = HttpConfig {
        workers: 2,
        read_timeout: Duration::from_millis(300),
        ..HttpConfig::default()
    };
    let (server, _router) = start(config(), http);
    let addr = server.local_addr().to_string();

    // trickle half a request head, then stall: the server must hang up
    let mut slow = TcpStream::connect(&addr).unwrap();
    slow.write_all(b"POST /v1/models/tiny-synth/inf").unwrap();
    slow.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let t0 = Instant::now();
    let mut sink = [0u8; 64];
    let n = slow.read(&mut sink).expect("server should close, not error");
    assert_eq!(n, 0, "expected EOF from the server's slow-client disconnect");
    assert!(t0.elapsed() < Duration::from_secs(4), "disconnect must come from the budget");

    // the worker that served the slow client is free again
    assert_eq!(server.live_workers(), 2);
    assert_eq!(request(&addr, "GET", "/healthz", &[], b"").status, 200);
}

#[test]
fn overload_sheds_429_with_retry_after_and_http_source_accounting() {
    // one replica stalled 300ms per dispatch behind a capacity-1 queue:
    // concurrent posts must shed. Explicit faults beat any env chaos.
    let cfg = config()
        .with_replicas(Some(1))
        .with_queue_capacity(Some(1))
        .with_faults(Some(FaultPlan::parse("stall:1.0:300,seed:7").unwrap()));
    let (server, _router) = start(cfg, HttpConfig::default());
    let addr = server.local_addr().to_string();
    let (images, _) = golden();
    let body = Arc::new(image_bytes(&images[0]));

    let results: Vec<u16> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let addr = addr.clone();
                let body = body.clone();
                s.spawn(move || {
                    let reply = request(&addr, "POST", infer_path(), &[], &body);
                    if reply.status == 429 {
                        assert_eq!(reply.header("retry-after"), Some("1"));
                        assert!(reply.text().contains("overloaded"), "{}", reply.text());
                    }
                    reply.status
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // exactly one reply per request, and overload produced at least one 429
    assert_eq!(results.len(), 8);
    assert!(results.iter().all(|s| *s == 200 || *s == 429), "{results:?}");
    let sheds = results.iter().filter(|s| **s == 429).count();
    assert!(sheds >= 1, "a capacity-1 queue under 8 concurrent posts must shed: {results:?}");

    // the shed shows up in /metrics, attributed to the http source
    let metrics = request(&addr, "GET", "/metrics", &[], b"").text();
    let line = metrics
        .lines()
        .find(|l| l.starts_with("hgpipe_requests_shed_total{"))
        .expect("shed family present");
    let total: usize = line.rsplit(' ').next().unwrap().parse().unwrap();
    assert!(total >= sheds, "scraped shed {total} < observed 429s {sheds}");
    let by_source = metrics
        .lines()
        .find(|l| {
            l.starts_with("hgpipe_requests_shed_by_source_total{")
                && l.contains("source=\"http\"")
        })
        .expect("per-source shed family present");
    let per_src: usize = by_source.rsplit(' ').next().unwrap().parse().unwrap();
    assert_eq!(per_src, total, "every shed came over http");
}

#[test]
fn deadline_zero_is_504_at_admission_without_enqueueing() {
    let (server, router) = start(config(), HttpConfig::default());
    let addr = server.local_addr().to_string();
    let (images, _) = golden();
    let reply = request(
        &addr,
        "POST",
        infer_path(),
        &[("Deadline-Ms", "0")],
        &image_bytes(&images[0]),
    );
    assert_eq!(reply.status, 504, "{}", reply.text());
    assert!(reply.text().contains("deadline exceeded"), "{}", reply.text());

    let m = &router.metrics()[0].1;
    assert_eq!(m.expired, 1, "dead-on-arrival deadlines count as expired");
    assert_eq!(m.shed, 0, "...not as shed");
    assert_eq!(m.count(), 0, "...and never execute");
    // garbage deadlines are a client error, not a 5xx
    let bad = request(
        &addr,
        "POST",
        infer_path(),
        &[("Deadline-Ms", "soon")],
        &image_bytes(&images[0]),
    );
    assert_eq!(bad.status, 400);
}

#[test]
fn graceful_drain_answers_the_in_flight_request() {
    // a 300ms stall guarantees the drain begins while the request is
    // mid-dispatch; the shutdown must still deliver its one reply
    let cfg = config()
        .with_replicas(Some(1))
        .with_faults(Some(FaultPlan::parse("stall:1.0:300,seed:7").unwrap()));
    let (server, _router) = start(cfg, HttpConfig::default());
    let addr = server.local_addr().to_string();
    let (images, expected) = golden();
    let body = image_bytes(&images[0]);

    let inflight = std::thread::spawn({
        let addr = addr.clone();
        move || request(&addr, "POST", infer_path(), &[], &body)
    });
    std::thread::sleep(Duration::from_millis(100));
    server.shutdown(); // blocks until the in-flight request is answered

    let reply = inflight.join().unwrap();
    assert_eq!(reply.status, 200, "{}", reply.text());
    assert_eq!(parse_reply_argmax(&reply.text()), expected[0].0);
    assert_eq!(reply.header("connection"), Some("close"), "drain closes the connection");
    // and the door is actually closed
    assert!(TcpStream::connect(&addr).is_err() || {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
        s.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        matches!(s.read(&mut [0u8; 16]), Ok(0) | Err(_))
    });
}

#[test]
fn metrics_endpoint_is_prometheus_text_with_request_counts() {
    let (server, _router) = start(config(), HttpConfig::default());
    let addr = server.local_addr().to_string();
    let (images, _) = golden();
    for image in images.iter().take(3) {
        assert_eq!(request(&addr, "POST", infer_path(), &[], &image_bytes(image)).status, 200);
    }
    let reply = request(&addr, "GET", "/metrics", &[], b"");
    assert_eq!(reply.status, 200);
    assert_eq!(reply.header("content-type"), Some(PROMETHEUS_CONTENT_TYPE));
    let text = reply.text();
    assert!(
        text.contains("hgpipe_requests_total{model=\"tiny-synth\",version=\"v1\"} 3"),
        "{text}"
    );
    for family in [
        "# TYPE hgpipe_requests_total counter",
        "# TYPE hgpipe_live_replicas gauge",
        "# TYPE hgpipe_request_latency_seconds summary",
    ] {
        assert!(text.contains(family), "missing {family} in:\n{text}");
    }
}

#[test]
fn healthz_reflects_live_replicas() {
    let (server, router) = start(config().with_replicas(Some(2)), HttpConfig::default());
    let addr = server.local_addr().to_string();
    let reply = request(&addr, "GET", "/healthz", &[], b"");
    assert_eq!(reply.status, 200);
    let body = reply.text();
    let live = router.server("tiny-synth").unwrap().live_replicas();
    assert!(body.contains("\"status\":\"ok\""), "{body}");
    assert!(body.contains(&format!("\"live_replicas\":{live}")), "{body}");
    assert!(body.contains("tiny-synth"), "{body}");
}
