//! Telemetry acceptance suite against the golden fixture:
//!
//! 1. **span tree** — a traced pipeline-mode serve emits Chrome-trace
//!    JSONL that `util::tracecheck` validates end to end: every line
//!    parses, spans nest per thread lane, every request is admitted
//!    exactly once, and the expected span kinds (admission, queue wait,
//!    dispatch, stage residency, per-op kernels) are all present;
//! 2. **chaos** — the same holds with the fault harness killing
//!    replicas: requeued requests show up as `retry` instants, never as
//!    duplicate admissions;
//! 3. **zero cost when off** — logits are bit-identical to the golden
//!    fixture with tracing off, explicitly disabled, and on;
//! 4. **Prometheus exposition** — `Router::prometheus_text()` renders
//!    every metric family with `model`/`version` labels (and
//!    `replica`/`stage` for pipeline occupancy), pinned by exact line.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use hgpipe::artifacts::Manifest;
use hgpipe::coordinator::faults::FaultPlan;
use hgpipe::coordinator::{ModelServer, Router};
use hgpipe::runtime::{BackendKind, ExecMode, RuntimeConfig};
use hgpipe::util::tracecheck;

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join("golden")
}

fn manifest() -> Manifest {
    Manifest::load(&fixture_dir()).expect("committed golden fixture")
}

/// The fixture's 16 input images (flat) and their expected logits.
fn golden_io() -> (Vec<f32>, Vec<f64>) {
    let dir = fixture_dir();
    let tokens = std::fs::read(dir.join("golden_tokens.bin"))
        .unwrap()
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
        .collect();
    let logits = std::fs::read(dir.join("golden_logits.bin"))
        .unwrap()
        .chunks_exact(8)
        .map(|b| f64::from_le_bytes(b.try_into().unwrap()))
        .collect();
    (tokens, logits)
}

/// A per-test trace path, leaked to the `&'static str` the `Copy`
/// config carries (one small leak per test process).
fn trace_path(name: &str) -> (String, &'static str) {
    let path = std::env::temp_dir()
        .join(format!("hgpipe_tele_test_{}_{name}.jsonl", std::process::id()))
        .to_string_lossy()
        .into_owned();
    let leaked: &'static str = Box::leak(path.clone().into_boxed_str());
    (path, leaked)
}

/// Injected panics are *expected* in the chaos test; filter exactly
/// those from the hook, keep everything else loud.
fn silence_injected_panics() {
    static HOOK: std::sync::Once = std::sync::Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<&str>()
                .is_some_and(|m| m.contains("faults harness"));
            if !injected {
                prev(info);
            }
        }));
    });
}

#[test]
fn traced_pipeline_serve_emits_a_valid_span_tree() {
    let manifest = manifest();
    let (tokens, _) = golden_io();
    let (path, leaked) = trace_path("pipeline");
    let config = RuntimeConfig::new(BackendKind::Interpreter)
        .with_lanes(Some(2))
        .with_mode(ExecMode::Pipeline { stages: 0, queue_depth: 2 })
        .with_replicas(Some(1))
        .with_trace(Some(leaked));
    let server = ModelServer::start_with_config(&manifest, "tiny-synth", 2, config)
        .expect("traced pipeline server");
    let per = server.tokens_per_image();
    let n = 24usize;
    let rxs: Vec<_> = (0..n)
        .map(|i| server.submit(tokens[(i % 16) * per..(i % 16 + 1) * per].to_vec()).unwrap())
        .collect();
    for rx in rxs {
        rx.recv().expect("reply").expect("inference ok");
    }
    // dropping the server joins replicas and stages (their rings flush
    // on thread exit), then the last sink handle joins the writer
    drop(server);

    let text = std::fs::read_to_string(&path).expect("trace file written");
    let (sum, errors) = tracecheck::check(&text);
    assert!(errors.is_empty(), "trace must validate: {errors:#?}");
    assert_eq!(sum.admits, n, "one admission instant per accepted request");
    assert_eq!(sum.sheds, 0);
    assert_eq!(sum.queue_waits, n, "one queue-wait span per dispatched request");
    assert!(sum.execs >= 1, "at least one dispatch span");
    assert!(sum.tiles >= n, "every image crosses at least one resident stage");
    assert!(sum.op_spans > 0, "per-op kernel spans nest inside stage tiles");
    // the lanes are named for Perfetto's track labels
    assert!(text.contains("process_name") && text.contains("tiny-synth"));
    assert!(text.contains("\"name\":\"client\""));
    assert!(text.contains("replica0") && text.contains("stage0"));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn chaos_trace_admits_each_request_exactly_once() {
    silence_injected_panics();
    let manifest = manifest();
    let (tokens, _) = golden_io();
    let (path, leaked) = trace_path("chaos");
    let plan = FaultPlan { panic_rate: 0.15, seed: 42, ..FaultPlan::default() };
    let config = RuntimeConfig::new(BackendKind::Interpreter)
        .with_lanes(Some(1))
        .with_replicas(Some(2))
        .with_faults(Some(plan))
        .with_trace(Some(leaked));
    let server = ModelServer::start_with_config(&manifest, "tiny-synth", 2, config)
        .expect("traced chaos server");
    let per = server.tokens_per_image();
    let n = 64usize;
    let rxs: Vec<_> = (0..n)
        .map(|i| server.submit(tokens[(i % 16) * per..(i % 16 + 1) * per].to_vec()).unwrap())
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        rx.recv()
            .unwrap_or_else(|_| panic!("request {i}: reply sender dropped"))
            .unwrap_or_else(|e| panic!("request {i} failed under chaos: {e:#}"));
    }
    let retried = server.metrics.lock().unwrap().retried;
    drop(server);

    let text = std::fs::read_to_string(&path).expect("trace file written");
    // tracecheck's exactly-one-admission rule is the real assertion
    // here: a replica death must requeue (traced as `retry`), never
    // re-admit
    let (sum, errors) = tracecheck::check(&text);
    assert!(errors.is_empty(), "chaos trace must validate: {errors:#?}");
    assert_eq!(sum.admits, n);
    if retried > 0 {
        assert!(sum.retries > 0, "requeued requests must leave retry instants");
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn tracing_is_invisible_to_results() {
    let manifest = manifest();
    let (tokens, expected) = golden_io();
    let images: Vec<Vec<f32>> = {
        let per = tokens.len() / 16;
        (0..16).map(|i| tokens[i * per..(i + 1) * per].to_vec()).collect()
    };
    let run = |trace: Option<&'static str>| -> Vec<Vec<f32>> {
        let config = RuntimeConfig::new(BackendKind::Interpreter)
            .with_lanes(Some(2))
            .with_trace(trace);
        let server = ModelServer::start_with_config(&manifest, "tiny-synth", 2, config)
            .expect("server");
        let responses = server.infer_all(images.clone()).expect("inference");
        responses.into_iter().map(|r| r.logits).collect()
    };
    // explicitly off (shields the comparison from a CI-set HGPIPE_TRACE)
    let off = run(Some(""));
    let (path, leaked) = trace_path("bitexact");
    let on = run(Some(leaked));
    let nc = expected.len() / 16;
    for (i, (a, b)) in off.iter().zip(&on).enumerate() {
        for (k, (&x, &y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "image {i} logit {k}: tracing changed bits");
            let want = expected[i * nc + k] as f32;
            assert_eq!(x.to_bits(), want.to_bits(), "image {i} logit {k}: golden mismatch");
        }
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn prometheus_text_exposes_every_family_with_model_version_labels() {
    let manifest = manifest();
    let (tokens, _) = golden_io();
    let config = RuntimeConfig::new(BackendKind::Interpreter)
        .with_lanes(Some(2))
        .with_mode(ExecMode::Pipeline { stages: 0, queue_depth: 2 })
        .with_replicas(Some(1));
    let router = Router::start(&manifest, &["tiny-synth".to_string()], 2, config)
        .expect("router");
    let server = router.server("tiny-synth").expect("routed");
    let per = server.tokens_per_image();
    let n = 8usize;
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            router
                .submit("tiny-synth", tokens[(i % 16) * per..(i % 16 + 1) * per].to_vec())
                .unwrap()
        })
        .collect();
    for rx in rxs {
        rx.recv().expect("reply").expect("inference ok");
    }
    // a reply can arrive a beat before the replica records its metrics
    let t0 = Instant::now();
    while server.metrics.lock().unwrap().count() < n {
        assert!(t0.elapsed() < Duration::from_secs(5), "metrics never caught up");
        std::thread::yield_now();
    }

    let text = router.prometheus_text();
    let labels = "model=\"tiny-synth\",version=\"v1\"";
    // counters, with exact values
    assert!(text.contains("# TYPE hgpipe_requests_total counter"), "{text}");
    assert!(text.contains(&format!("hgpipe_requests_total{{{labels}}} {n}\n")), "{text}");
    for zeroed in [
        "hgpipe_requests_failed_total",
        "hgpipe_requests_shed_total",
        "hgpipe_requests_expired_total",
        "hgpipe_requests_retried_total",
        "hgpipe_replica_restarts_total",
        "hgpipe_replicas_retired_total",
    ] {
        assert!(text.contains(&format!("{zeroed}{{{labels}}} 0\n")), "{zeroed}: {text}");
    }
    // gauges exist for the live version
    assert!(text.contains("# TYPE hgpipe_live_replicas gauge"), "{text}");
    assert!(text.contains(&format!("hgpipe_live_replicas{{{labels}}} 1\n")), "{text}");
    assert!(text.contains(&format!("hgpipe_queue_depth{{{labels}}} 0\n")), "{text}");
    assert!(text.contains("# TYPE hgpipe_throughput_images_per_second gauge"), "{text}");
    // the latency summary: quantile series + _sum/_count
    assert!(text.contains("# TYPE hgpipe_request_latency_seconds summary"), "{text}");
    for q in ["0.5", "0.95", "0.99", "0.999"] {
        assert!(
            text.contains(&format!(
                "hgpipe_request_latency_seconds{{{labels},quantile=\"{q}\"}}"
            )),
            "quantile {q}: {text}"
        );
    }
    assert!(
        text.contains(&format!("hgpipe_request_latency_seconds_count{{{labels}}} {n}\n")),
        "{text}"
    );
    assert!(text.contains(&format!("hgpipe_request_latency_seconds_sum{{{labels}}}")), "{text}");
    // pipeline mode: the per-stage occupancy families carry
    // replica/stage labels (promoted from the bench into ServeMetrics)
    for fam in [
        "hgpipe_stage_images_total",
        "hgpipe_stage_busy_seconds_total",
        "hgpipe_stage_occupancy_ratio",
        "hgpipe_stage_stalls_empty_total",
        "hgpipe_stage_stalls_full_total",
    ] {
        assert!(
            text.contains(&format!("{fam}{{{labels},replica=\"0\",stage=\"")),
            "{fam}: {text}"
        );
    }
}

#[test]
fn lane_parallel_prometheus_omits_stage_families() {
    let manifest = manifest();
    let (tokens, _) = golden_io();
    let config = RuntimeConfig::new(BackendKind::Interpreter)
        .with_lanes(Some(2))
        .with_mode(ExecMode::LaneParallel)
        .with_replicas(Some(1));
    let router = Router::start(&manifest, &["tiny-synth".to_string()], 2, config)
        .expect("router");
    let per = router.server("tiny-synth").expect("routed").tokens_per_image();
    let rx = router.submit("tiny-synth", tokens[..per].to_vec()).unwrap();
    rx.recv().expect("reply").expect("inference ok");
    let text = router.prometheus_text();
    assert!(text.contains("hgpipe_requests_total"), "{text}");
    assert!(
        !text.contains("hgpipe_stage_occupancy_ratio"),
        "lane-parallel replicas have no stages to report: {text}"
    );
}
