//! Integration: the PJRT runtime + coordinator over real AOT artifacts.
//! These tests skip gracefully when `make artifacts` has not run, and the
//! whole file only builds with `--features pjrt` (the default build's
//! coordinator coverage lives in `interpreter_golden.rs`).
#![cfg(feature = "pjrt")]

use std::path::Path;

use hgpipe::artifacts::Manifest;
use hgpipe::coordinator::ModelServer;
use hgpipe::runtime::BackendKind;
use hgpipe::util::json::Json;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipped: run `make artifacts` first");
        return None;
    }
    // the committed golden fixture is bundle-only; the PJRT tests need
    // the HLO artifacts from a full `make artifacts` run, plus a real
    // (non-stub) xla binding
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipped: manifest unreadable: {e}");
            return None;
        }
    };
    if manifest.variants("tiny-synth").is_empty() {
        eprintln!("skipped: no HLO artifacts — run `make artifacts`");
        return None;
    }
    if hgpipe::runtime::pjrt::Engine::cpu().is_err() {
        eprintln!("skipped: PJRT client unavailable (stub xla binding)");
        return None;
    }
    Some(dir)
}

fn load_eval(dir: &Path) -> Option<(Vec<f32>, Vec<u8>, usize)> {
    let v = Json::parse(&std::fs::read_to_string(dir.join("manifest.json")).ok()?).ok()?;
    let es = v.get("eval_set")?;
    let sh: Vec<usize> = es
        .get("shape")?
        .as_arr()?
        .iter()
        .map(|x| x.as_i64().unwrap() as usize)
        .collect();
    let tokens_raw = std::fs::read(dir.join(es.get("tokens")?.as_str()?)).ok()?;
    let labels = std::fs::read(dir.join(es.get("labels")?.as_str()?)).ok()?;
    let tokens: Vec<f32> = tokens_raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Some((tokens, labels, sh[1] * sh[2]))
}

#[test]
fn tinyvit_accuracy_through_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let Some((tokens, labels, per)) = load_eval(&dir) else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let server =
        ModelServer::start_with_backend(&manifest, "tiny-synth", 2, BackendKind::Pjrt).unwrap();
    let images: Vec<Vec<f32>> = tokens.chunks(per).map(|c| c.to_vec()).collect();
    let responses = server.infer_all(images).unwrap();
    let correct = responses.iter().zip(&labels).filter(|(r, &l)| r.argmax == l as usize).count();
    let acc = correct as f64 / labels.len() as f64;
    // the python build measured ~0.80 on the full eval set; the bit-exact
    // AOT path must agree well beyond chance (10 classes)
    assert!(acc > 0.70, "accuracy through PJRT = {acc}");
}

#[test]
fn deterministic_across_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let Some((tokens, _, per)) = load_eval(&dir) else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let server =
        ModelServer::start_with_backend(&manifest, "tiny-synth", 2, BackendKind::Pjrt).unwrap();
    let img: Vec<f32> = tokens[..per].to_vec();
    let a = server.submit(img.clone()).unwrap().recv().unwrap().unwrap();
    let b = server.submit(img).unwrap().recv().unwrap().unwrap();
    assert_eq!(a.logits, b.logits, "quantized inference must be bit-deterministic");
}

#[test]
fn block_pallas_artifact_loads_and_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let path = dir.join("deit_tiny_block_pallas.hlo.txt");
    if !path.exists() {
        return;
    }
    // the Pallas-lowered block is int32 -> int32, so drive it through the
    // raw runtime rather than the f32 server
    let Ok(engine) = hgpipe::runtime::pjrt::Engine::cpu() else { return };
    let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = engine_compile(&engine, &comp);
    let x: Vec<i32> = (0..196 * 192).map(|i| (i % 15) as i32 - 7).collect();
    let lit = xla::Literal::vec1(&x).reshape(&[196, 192]).unwrap();
    let out = exe.execute::<xla::Literal>(&[lit]).unwrap()[0][0].to_literal_sync().unwrap();
    let out = out.to_tuple1().unwrap();
    let v = out.to_vec::<i32>().unwrap();
    assert_eq!(v.len(), 196 * 192);
    // residual-add output: not all zeros, bounded by the residual range
    assert!(v.iter().any(|&x| x != 0));
    assert!(v.iter().all(|&x| x.abs() < 1 << 20));
}

// Engine::compile is private; go through the public load path with a
// scratch manifest entry instead.
fn engine_compile(
    engine: &hgpipe::runtime::pjrt::Engine,
    comp: &xla::XlaComputation,
) -> xla::PjRtLoadedExecutable {
    let _ = engine;
    let client = xla::PjRtClient::cpu().unwrap();
    client.compile(comp).unwrap()
}

#[test]
fn mismatched_input_shape_is_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let server =
        ModelServer::start_with_backend(&manifest, "tiny-synth", 2, BackendKind::Pjrt).unwrap();
    assert!(server.submit(vec![0.0; 7]).is_err());
}

#[test]
fn unknown_model_fails_to_start() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let started = ModelServer::start_with_backend(&manifest, "no-such-model", 2, BackendKind::Pjrt);
    assert!(started.is_err());
}
