//! Randomized differential tests for the runtime-dispatched SIMD
//! kernel layer: every op in the [`Kernels`] vtable of the backend CPU
//! detection picks is compared against the scalar oracle
//! (`kernels::scalar()`, bit-for-bit the pre-refactor inner loops) over
//! randomized inputs — GEMM shapes and sparsity sweeps, softmax rows
//! including the `t = 1` and all-equal-max degenerate cases, requant
//! saturation boundaries, and every non-multiple-of-vector-width tail
//! length from 1 to 33.
//!
//! On an x86_64 host with AVX2 (or an aarch64 host with NEON) these
//! tests genuinely cross-check vectorized code against scalar; on a
//! host where detection falls back to scalar they degenerate to
//! self-comparison and still pass — the CI matrix covers the forced
//! `HGPIPE_KERNELS=scalar` configuration separately.

use hgpipe::lut::LutTable;
use hgpipe::runtime::fabric::gemm::PackedGemm;
use hgpipe::runtime::fabric::LanePool;
use hgpipe::runtime::kernels::{self, Kernels};
use hgpipe::util::prng::Prng;

fn mk_lut(alpha: i64, shift: u32, n_bits: u32, inverted: bool, entries: Vec<i64>) -> LutTable {
    assert_eq!(entries.len(), 1usize << n_bits, "entry count must fill the index range");
    LutTable {
        name: "test".to_string(),
        alpha,
        shift,
        n_bits,
        inverted,
        out_scale: 1.0,
        out_zp: 0,
        entries,
    }
}

/// A plausible requant-style table: 6-bit index space, non-trivial
/// alpha/shift, entries spanning negative and positive i32 values.
fn requant_lut() -> LutTable {
    mk_lut(-300, 3, 6, false, (0..64i64).map(|i| i * 7 - 200).collect())
}

/// An inverted exp-style table (alpha stores beta): softmax feeds it
/// `score - max`, always <= 0.
fn exp_lut() -> LutTable {
    mk_lut(0, 2, 5, true, (0..32i64).map(|i| 1000 - i * 31).collect())
}

/// The tail lengths the SIMD backends must get right: everything from
/// a single element to one past a full 32-element sweep, covering every
/// remainder class of the 4- and 8-wide vector loops.
const LENS: &[usize] = &[1, 2, 3, 4, 5, 6, 7, 8, 9, 15, 16, 17, 23, 24, 25, 31, 32, 33];

fn fill_i32(rng: &mut Prng, n: usize, lo: i64, hi: i64) -> Vec<i32> {
    (0..n).map(|_| rng.range_i64(lo, hi) as i32).collect()
}

fn fill_i64(rng: &mut Prng, n: usize, lo: i64, hi: i64) -> Vec<i64> {
    (0..n).map(|_| rng.range_i64(lo, hi)).collect()
}

/// Drive every vtable op of `simd` and `scalar` on identical inputs of
/// length `n` and assert bit-identical outputs.
fn check_ops_at_len(rng: &mut Prng, simd: &Kernels, scalar: &Kernels, n: usize) {
    let rq = requant_lut();
    let exp = exp_lut();

    // axpy: accumulate into identical pre-filled i64 rows
    let a = rng.range_i64(-1000, 1000) as i32;
    let w = fill_i32(rng, n, -1000, 1000);
    let mut o_s = fill_i64(rng, n, -(1 << 40), 1 << 40);
    let mut o_v = o_s.clone();
    (scalar.axpy)(a, &w, &mut o_s);
    (simd.axpy)(a, &w, &mut o_v);
    assert_eq!(o_s, o_v, "axpy len {n}");

    // axpy4: four rows sharing one weight row
    let a4 = [
        rng.range_i64(-1000, 1000) as i32,
        rng.range_i64(-1000, 1000) as i32,
        rng.range_i64(-1000, 1000) as i32,
        rng.range_i64(-1000, 1000) as i32,
    ];
    let base: Vec<Vec<i64>> = (0..4).map(|_| fill_i64(rng, n, -(1 << 40), 1 << 40)).collect();
    let mut rows_s = base.clone();
    let mut rows_v = base;
    {
        let (s0, rest) = rows_s.split_at_mut(1);
        let (s1, rest) = rest.split_at_mut(1);
        let (s2, s3) = rest.split_at_mut(1);
        (scalar.axpy4)(a4, &w, &mut s0[0], &mut s1[0], &mut s2[0], &mut s3[0]);
        let (v0, rest) = rows_v.split_at_mut(1);
        let (v1, rest) = rest.split_at_mut(1);
        let (v2, v3) = rest.split_at_mut(1);
        (simd.axpy4)(a4, &w, &mut v0[0], &mut v1[0], &mut v2[0], &mut v3[0]);
    }
    assert_eq!(rows_s, rows_v, "axpy4 len {n}");

    // requant / requant_add over wide-range accumulators (the `as i32`
    // narrowing wraps — both backends must wrap identically)
    let acc = fill_i64(rng, n, -(1 << 40), 1 << 40);
    let mut q_s = vec![0i32; n];
    let mut q_v = vec![0i32; n];
    (scalar.requant)(&rq, &acc, &mut q_s);
    (simd.requant)(&rq, &acc, &mut q_v);
    assert_eq!(q_s, q_v, "requant len {n}");
    let mut add_s = fill_i32(rng, n, -(1 << 20), 1 << 20);
    let mut add_v = add_s.clone();
    (scalar.requant_add)(&rq, &acc, &mut add_s);
    (simd.requant_add)(&rq, &acc, &mut add_v);
    assert_eq!(add_s, add_v, "requant_add len {n}");

    // dot / max / sum reductions
    let x = fill_i32(rng, n, -1000, 1000);
    let y = fill_i32(rng, n, -1000, 1000);
    assert_eq!((scalar.dot_i32)(&x, &y), (simd.dot_i32)(&x, &y), "dot len {n}");
    assert_eq!((scalar.max_i32)(&x), (simd.max_i32)(&x), "max len {n}");
    assert_eq!((scalar.sum_i32)(&x), (simd.sum_i32)(&x), "sum len {n}");

    // softmax pair: exp-LUT + total, then the probability requant
    let m = (scalar.max_i32)(&x);
    let mut e_s = vec![0i32; n];
    let mut e_v = vec![0i32; n];
    let tot_s = (scalar.exp_lut_sum)(&exp, m, &x, &mut e_s);
    let tot_v = (simd.exp_lut_sum)(&exp, m, &x, &mut e_v);
    assert_eq!(tot_s, tot_v, "exp_lut_sum total len {n}");
    assert_eq!(e_s, e_v, "exp_lut_sum row len {n}");
    let r = rng.range_i64(-(1 << 16), 1 << 16) as i32;
    let mut p_s = vec![0i32; n];
    let mut p_v = vec![0i32; n];
    (scalar.prob_lut)(&rq, r, &e_s, &mut p_s);
    (simd.prob_lut)(&rq, r, &e_v, &mut p_v);
    assert_eq!(p_s, p_v, "prob_lut len {n}");

    // LayerNorm center + finish passes
    let row = fill_i32(rng, n, -1000, 1000);
    let sum = (scalar.sum_i32)(&row);
    let d = rng.range_i64(1, 256) as i32;
    let guard = rng.below(4) as u32;
    let mut c_s = vec![0i64; n];
    let mut c_v = vec![0i64; n];
    let v_s = (scalar.ln_center)(d, sum, guard, &row, &mut c_s);
    let v_v = (simd.ln_center)(d, sum, guard, &row, &mut c_v);
    assert_eq!(v_s, v_v, "ln_center variance len {n}");
    assert_eq!(c_s, c_v, "ln_center row len {n}");
    let rr = rng.range_i64(-(1 << 20), 1 << 20);
    let mut ln_s = vec![0i32; n];
    let mut ln_v = vec![0i32; n];
    (scalar.ln_finish)(&rq, rr, &c_s, &mut ln_s);
    (simd.ln_finish)(&rq, rr, &c_v, &mut ln_v);
    assert_eq!(ln_s, ln_v, "ln_finish len {n}");
}

#[test]
fn every_vtable_op_matches_the_scalar_oracle_across_tail_lengths() {
    let simd = kernels::detect();
    let scalar = kernels::scalar();
    let mut rng = Prng::new(0x5EED);
    for &n in LENS {
        for _ in 0..8 {
            check_ops_at_len(&mut rng, simd, scalar, n);
        }
    }
}

#[test]
fn gemm_matmul_agrees_across_backends_shapes_and_sparsity() {
    let simd = kernels::detect();
    let scalar = kernels::scalar();
    // lane-count 1 pools pinned to each backend: every row kernel
    // (zero-skip, dense single-row, 4-row microkernel) runs on the
    // caller thread through the chosen vtable
    let pool_s = LanePool::with_kernels(1, scalar);
    let pool_v = LanePool::with_kernels(1, simd);
    let mut rng = Prng::new(0xD1FF);
    // shapes cross the TILE_CO=64 panel boundary (co 65, 130) and hit
    // 1-, 2-, 3-row dense remainders plus full 4-row microkernel runs
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 5, 2),
        (8, 64, 4),
        (17, 65, 5),
        (16, 100, 8),
        (7, 130, 13),
        (64, 63, 3),
    ];
    // zero-density sweep: dense rows, rows near the sparse crossover,
    // and almost-all-zero rows (the GELU-output regime)
    for &(ci, co, t) in shapes {
        for &zero_pct in &[0u64, 40, 95] {
            let raw = fill_i32(&mut rng, ci * co, -500, 500);
            let bias = fill_i64(&mut rng, co, -(1 << 30), 1 << 30);
            let g = PackedGemm::pack(raw, ci, co, bias);
            let x: Vec<i32> = (0..t * ci)
                .map(|_| {
                    if rng.below(100) < zero_pct {
                        0
                    } else {
                        rng.range_i64(-500, 500) as i32
                    }
                })
                .collect();
            let want = g.matmul_naive(&x, t);
            let got_s = g.matmul(&x, t, &pool_s);
            let got_v = g.matmul(&x, t, &pool_v);
            assert_eq!(want, got_s, "scalar pool ({ci},{co},{t}) zeros {zero_pct}%");
            assert_eq!(want, got_v, "{} pool ({ci},{co},{t}) zeros {zero_pct}%", simd.name);
        }
    }
}

#[test]
fn softmax_degenerate_rows_agree() {
    let simd = kernels::detect();
    let scalar = kernels::scalar();
    let exp = exp_lut();
    let rq = requant_lut();
    // t = 1: a single-score row (the smallest attention row possible)
    // and all-equal rows (every score IS the max, diff identically 0)
    let rows: [&[i32]; 7] =
        [&[42], &[-7], &[5; 4], &[-123; 7], &[0; 16], &[i32::MAX; 9], &[i32::MIN; 5]];
    for row in rows {
        let n = row.len();
        let m_s = (scalar.max_i32)(row);
        let m_v = (simd.max_i32)(row);
        assert_eq!(m_s, m_v, "max over {row:?}");
        let mut e_s = vec![0i32; n];
        let mut e_v = vec![0i32; n];
        let tot_s = (scalar.exp_lut_sum)(&exp, m_s, row, &mut e_s);
        let tot_v = (simd.exp_lut_sum)(&exp, m_v, row, &mut e_v);
        assert_eq!(tot_s, tot_v, "exp total over {row:?}");
        assert_eq!(e_s, e_v, "exp row over {row:?}");
        let mut p_s = vec![0i32; n];
        let mut p_v = vec![0i32; n];
        (scalar.prob_lut)(&rq, 77, &e_s, &mut p_s);
        (simd.prob_lut)(&rq, 77, &e_v, &mut p_v);
        assert_eq!(p_s, p_v, "prob row over {row:?}");
    }
}

#[test]
fn requant_saturation_and_wrap_boundaries_agree() {
    let simd = kernels::detect();
    let scalar = kernels::scalar();
    let span = 64i64 << 3; // index range x shift of requant_lut()
    for inverted in [false, true] {
        let t = mk_lut(-300, 3, 6, inverted, (0..64i64).map(|i| i * 7 - 200).collect());
        // every clamp edge of the index computation, the exact
        // saturation boundaries one below/above, and accumulators whose
        // `as i32` narrowing wraps the sign
        let acc = [
            t.alpha - 1,
            t.alpha,
            t.alpha + 1,
            t.alpha + span - 1,
            t.alpha + span,
            t.alpha + span + 1,
            i32::MIN as i64,
            i32::MAX as i64,
            i32::MIN as i64 - 1, // wraps to i32::MAX
            i32::MAX as i64 + 1, // wraps to i32::MIN
            (1i64 << 40) + 12345,
            -(1i64 << 40) - 12345,
            0,
        ];
        let n = acc.len();
        let mut q_s = vec![0i32; n];
        let mut q_v = vec![0i32; n];
        (scalar.requant)(&t, &acc, &mut q_s);
        (simd.requant)(&t, &acc, &mut q_v);
        assert_eq!(q_s, q_v, "requant boundaries, inverted {inverted}");
        let mut a_s = vec![i32::MAX - 10; n];
        let mut a_v = a_s.clone();
        (scalar.requant_add)(&t, &acc, &mut a_s);
        (simd.requant_add)(&t, &acc, &mut a_v);
        assert_eq!(a_s, a_v, "requant_add near-overflow residual, inverted {inverted}");
    }
}

#[test]
fn backend_selection_surface_is_sound() {
    // scalar is selectable everywhere and the auto-detected backend is
    // one of the three known tables
    let s = kernels::select(kernels::KernelPref::Scalar).unwrap();
    assert_eq!(s.name, "scalar");
    let d = kernels::detect();
    assert!(
        ["scalar", "avx2", "neon"].contains(&d.name),
        "unexpected backend '{}'",
        d.name
    );
    // Auto never fails
    assert_eq!(kernels::select(kernels::KernelPref::Auto).unwrap().name, d.name);
    // a pool reports the backend it was pinned to
    assert_eq!(LanePool::with_kernels(2, s).kernels().name, "scalar");
}
