//! Lifecycle guarantees of the persistent worker fabric:
//!
//! 1. one pool serves many sequential forwards, bit-exactly, without
//!    allocating new scratch once warmed up (the zero-alloc contract);
//! 2. two threads can share one fabric concurrently and each still gets
//!    its own image's logits;
//! 3. dropping the last handle (or unloading a model) joins every
//!    worker — repeated load/unload leaks no threads.
//!
//! Tests in this file serialize on a lock: [`LanePool::live_workers`] is
//! a process-wide counter, and concurrent pool-creating tests would make
//! its baseline assertions racy. (Each integration-test file is its own
//! process, so other test binaries don't interfere.)

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use hgpipe::artifacts::Manifest;
use hgpipe::runtime::fabric::LanePool;
use hgpipe::runtime::interpreter::{self, QuantViT};

static SERIAL: Mutex<()> = Mutex::new(());

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join("golden")
}

fn golden() -> (QuantViT, Vec<f32>, Vec<f64>) {
    let dir = fixture_dir();
    let net = QuantViT::load(&dir.join("tinyvit_bundle.json")).expect("bundle loads");
    let tokens = std::fs::read(dir.join("golden_tokens.bin"))
        .unwrap()
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
        .collect();
    let logits = std::fs::read(dir.join("golden_logits.bin"))
        .unwrap()
        .chunks_exact(8)
        .map(|b| f64::from_le_bytes(b.try_into().unwrap()))
        .collect();
    (net, tokens, logits)
}

fn assert_logits(got: &[f64], want: &[f64], ctx: &str) {
    for (k, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{ctx} logit {k}: {g:e} != {w:e}");
    }
}

#[test]
fn persistent_pool_reused_across_sequential_forwards() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let (net, tokens, expected) = golden();
    let per = net.tokens_per_image();
    let nc = net.num_classes;
    let pool = LanePool::new(4);
    // the same parked workers serve every forward; results stay pinned
    for round in 0..3 {
        for i in 0..4usize {
            let got = net.forward_image_pooled(&tokens[i * per..(i + 1) * per], &pool).unwrap();
            assert_logits(&got, &expected[i * nc..(i + 1) * nc], &format!("round {round} img {i}"));
        }
    }
}

#[test]
fn steady_state_forward_allocates_no_scratch() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let (net, tokens, expected) = golden();
    let per = net.tokens_per_image();
    let nc = net.num_classes;

    // serial pool: fully deterministic — exactly ONE box exists (the
    // serial forward borrows its pass half and its band half
    // simultaneously, so the old per-region inline box is gone), and
    // after one warmup forward neither the box count nor any buffer
    // capacity moves again: steady-state forwards do no heap allocation
    // in GEMM/attention scratch
    let pool = LanePool::serial();
    net.forward_image_pooled(&tokens[..per], &pool).unwrap();
    assert_eq!(pool.scratch_allocs(), 1, "the serial forward runs in one box");
    let footprint = pool.scratch_footprint();
    assert!(footprint > 0);
    for i in 0..12usize {
        let got = net.forward_image_pooled(&tokens[i * per..(i + 1) * per], &pool).unwrap();
        assert_logits(&got, &expected[i * nc..(i + 1) * nc], &format!("serial img {i}"));
    }
    assert_eq!(pool.scratch_allocs(), 1, "steady state allocated new scratch boxes");
    assert_eq!(pool.scratch_footprint(), footprint, "a steady-state scratch buffer regrew");

    // multi-lane pool: box count is bounded by concurrency (pass box +
    // caller band + one per worker), never by image count — 12 forwards
    // through a 4-lane fabric may create at most 5 boxes, not 12+
    let pool = LanePool::new(4);
    for i in 0..12usize {
        let got = net.forward_image_pooled(&tokens[i * per..(i + 1) * per], &pool).unwrap();
        assert_logits(&got, &expected[i * nc..(i + 1) * nc], &format!("pooled img {i}"));
    }
    assert!(
        pool.scratch_allocs() <= 5,
        "4-lane arena grew past its concurrency bound: {} boxes",
        pool.scratch_allocs()
    );
}

#[test]
fn two_threads_share_one_fabric_concurrently() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let (net, tokens, expected) = golden();
    let per = net.tokens_per_image();
    let nc = net.num_classes;
    let pool = LanePool::new(4);
    let net = &net;
    let tokens = &tokens;
    let expected = &expected;
    std::thread::scope(|s| {
        for tid in 0..2usize {
            let pool = pool.clone();
            s.spawn(move || {
                for j in 0..6usize {
                    let i = tid * 6 + j; // disjoint image sets per thread
                    let got =
                        net.forward_image_pooled(&tokens[i * per..(i + 1) * per], &pool).unwrap();
                    assert_logits(
                        &got,
                        &expected[i * nc..(i + 1) * nc],
                        &format!("thread {tid} img {i}"),
                    );
                }
            });
        }
    });
}

#[test]
fn dropping_the_pool_joins_all_workers() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let baseline = LanePool::live_workers();
    for _ in 0..3 {
        let pool = LanePool::new(8);
        assert_eq!(LanePool::live_workers(), baseline + 7);
        let mut v = vec![0u8; 32];
        pool.par_chunks_mut(&mut v, 1, |_s, _, band| band.fill(1));
        assert!(v.iter().all(|&x| x == 1));
        drop(pool);
        assert_eq!(LanePool::live_workers(), baseline, "workers leaked across pool drop");
    }
}

#[test]
fn repeated_model_load_unload_leaks_no_threads() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let manifest = Manifest::load(&fixture_dir()).unwrap();
    let (net, tokens, expected) = golden();
    let per = net.tokens_per_image();
    let nc = net.num_classes;
    let baseline = LanePool::live_workers();
    for round in 0..3 {
        let loaded = interpreter::load_model_with_lanes(&manifest, "tiny-synth", 4).unwrap();
        assert_eq!(
            LanePool::live_workers(),
            baseline + 3,
            "round {round}: one fabric per loaded model"
        );
        // drive each batch variant once through the persistent fabric
        for exe in &loaded.executors {
            let b = exe.batch();
            let out = exe.run_f32(&tokens[..b * per]).unwrap();
            for i in 0..b {
                for (k, &g) in out[i * nc..(i + 1) * nc].iter().enumerate() {
                    let w = expected[i * nc + k] as f32;
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "round {round} batch {b} img {i} logit {k}"
                    );
                }
            }
        }
        drop(loaded);
        assert_eq!(
            LanePool::live_workers(),
            baseline,
            "round {round}: model unload must join its fabric workers"
        );
    }
}
