//! The multi-model front door: `coordinator::Router` wired into serving
//! (one `ModelServer` per model, requests routed by name, per-model
//! metrics export) — over the committed golden fixture.

use std::path::{Path, PathBuf};

use hgpipe::artifacts::Manifest;
use hgpipe::coordinator::Router;
use hgpipe::runtime::{faulty, BackendKind, ExecMode, RuntimeConfig};

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join("golden")
}

fn manifest() -> Manifest {
    Manifest::load(&fixture_dir()).expect("committed golden fixture")
}

fn config() -> RuntimeConfig {
    RuntimeConfig::new(BackendKind::Interpreter).with_lanes(Some(2))
}

#[test]
fn routes_by_model_name_and_exports_per_model_metrics() {
    let router = Router::start(&manifest(), &["tiny-synth".to_string()], 2, config()).unwrap();
    assert_eq!(router.models(), vec!["tiny-synth"]);
    let per = router.server("tiny-synth").unwrap().tokens_per_image();

    let images: Vec<Vec<f32>> = (0..6).map(|i| vec![0.01 * i as f32; per]).collect();
    let responses = router.infer_all("tiny-synth", images).unwrap();
    assert_eq!(responses.len(), 6);

    let metrics = router.metrics();
    assert_eq!(metrics.len(), 1);
    let (name, m) = &metrics[0];
    assert_eq!(name, "tiny-synth");
    assert_eq!(m.count(), 6, "per-model metrics must attribute the routed requests");
    assert_eq!(m.failed, 0);
}

#[test]
fn unknown_model_is_a_routing_error_naming_whats_served() {
    let router = Router::start(&manifest(), &["tiny-synth".to_string()], 2, config()).unwrap();
    let per = router.server("tiny-synth").unwrap().tokens_per_image();
    let err = router.submit("no-such-model", vec![0.0; per]).unwrap_err().to_string();
    assert!(err.contains("no-such-model"), "error names the missing model: {err}");
    assert!(err.contains("tiny-synth"), "error names what IS served: {err}");
}

#[test]
fn unknown_model_in_startup_list_fails_router_start() {
    assert!(Router::start(&manifest(), &["nope".to_string()], 2, config()).is_err());
}

#[test]
fn duplicate_models_are_rejected() {
    let models = vec!["tiny-synth".to_string(), "tiny-synth".to_string()];
    let err = Router::start(&manifest(), &models, 2, config()).unwrap_err().to_string();
    assert!(err.contains("duplicate"), "{err}");
}

#[test]
fn empty_model_list_is_rejected() {
    assert!(Router::start(&manifest(), &[], 2, config()).is_err());
}

#[test]
fn metrics_lines_report_per_model_and_per_replica_without_double_counting() {
    // two executor replicas behind one queue: the rollup line is the
    // total and the replica lines are its exact decomposition — failed
    // dispatches included (each request is popped by exactly one
    // replica, so nothing is counted twice)
    let cfg = config().with_replicas(Some(2));
    let router = Router::start(&manifest(), &["tiny-synth".to_string()], 2, cfg).unwrap();
    let per = router.server("tiny-synth").unwrap().tokens_per_image();
    let images: Vec<Vec<f32>> = (0..8).map(|i| vec![0.01 * i as f32; per]).collect();
    let responses = router.infer_all("tiny-synth", images).unwrap();
    assert_eq!(responses.len(), 8);

    let metrics = router.metrics();
    let (name, rollup) = &metrics[0];
    assert_eq!(name, "tiny-synth");
    assert_eq!(rollup.count(), 8);
    assert_eq!(rollup.failed, 0);

    let server = router.server("tiny-synth").unwrap();
    assert_eq!(server.replicas(), 2);
    let per_replica = server.replica_metrics();
    assert_eq!(per_replica.len(), 2);
    assert_eq!(
        per_replica.iter().map(|m| m.count()).sum::<usize>(),
        rollup.count(),
        "replica request counts must sum to the rollup, not double it"
    );
    assert_eq!(per_replica.iter().map(|m| m.failed).sum::<u64>(), rollup.failed);
    let exec_sum: f64 = per_replica.iter().map(|m| m.exec_ms_total).sum();
    assert!(
        (exec_sum - rollup.exec_ms_total).abs() < 1e-6,
        "per-replica exec breakdown must decompose the rollup"
    );

    // the serve-loop report: one rollup line plus one line per replica
    let lines = router.metrics_lines();
    assert_eq!(lines.len(), 3, "{lines:?}");
    assert!(lines[0].starts_with("[tiny-synth]"), "{}", lines[0]);
    assert!(lines[1].starts_with("[tiny-synth/replica0]"), "{}", lines[1]);
    assert!(lines[2].starts_with("[tiny-synth/replica1]"), "{}", lines[2]);
    for line in &lines {
        assert!(line.contains("exec=") && line.contains("queue="), "breakdown in: {line}");
    }
}

#[test]
fn single_replica_metrics_lines_stay_one_per_model() {
    let router = Router::start(&manifest(), &["tiny-synth".to_string()], 2, config()).unwrap();
    let per = router.server("tiny-synth").unwrap().tokens_per_image();
    router.infer_all("tiny-synth", vec![vec![0.5; per]; 2]).unwrap();
    if router.server("tiny-synth").unwrap().replicas() == 1 {
        // (under the HGPIPE_REPLICAS CI matrix this server is replicated
        // and the line count is covered by the test above)
        assert_eq!(router.metrics_lines().len(), 1);
    }
}

#[test]
fn drain_then_swap_failures_are_counted_exactly_once_across_versions() {
    // every faulty dispatch fails, so each version's failure ledger is
    // fully deterministic: after a hot swap, v1's retired metrics must
    // keep exactly the failures it answered and the v2 lines must count
    // only post-swap traffic — summing the report can never exceed the
    // requests actually submitted
    let cfg = RuntimeConfig::new(BackendKind::Faulty).with_replicas(Some(2));
    let router = Router::start(&manifest(), &["any".to_string()], 1, cfg).unwrap();
    let submit_n = |n: usize| -> usize {
        let rxs: Vec<_> = (0..n)
            .map(|_| router.submit("any", vec![0.5; faulty::TOKENS_PER_IMAGE]).unwrap())
            .collect();
        rxs.into_iter().filter(|rx| rx.recv().expect("exactly one reply").is_err()).count()
    };
    assert_eq!(submit_n(5), 5, "faulty backend fails every dispatch");
    assert_eq!(router.swap(&manifest(), "any", 1, cfg).unwrap(), 2);
    assert_eq!(submit_n(3), 3);

    let versions = router.version_metrics("any").unwrap();
    assert_eq!(versions.len(), 2);
    assert_eq!(versions[0].1.failed, 5, "v1 keeps exactly its own failures after retiring");
    assert_eq!(versions[1].1.failed, 3, "v2 counts only post-swap traffic");
    assert_eq!(versions.iter().map(|(_, m)| m.failed).sum::<u64>(), 8);

    // line-level decomposition: the failed= counts printed per version
    // sum to the lifetime total (a failure appears on its version's
    // line and nowhere else), and replica lines decompose their
    // version's line, not the lifetime
    let failed_of = |line: &str| -> u64 {
        let rest = line.split("failed=").nth(1).expect("summary line has failed=");
        rest.split_whitespace().next().unwrap().parse().unwrap()
    };
    let lines = router.metrics_lines();
    let v1 = lines.iter().find(|l| l.starts_with("[any@v1] ")).expect("retired v1 line");
    let v2 = lines.iter().find(|l| l.starts_with("[any@v2] ")).expect("live v2 line");
    assert_eq!(failed_of(v1) + failed_of(v2), 8, "version lines decompose the total: {lines:?}");
    let replica_sum: u64 = lines
        .iter()
        .filter(|l| l.contains("@v2/replica"))
        .map(|l| failed_of(l.as_str()))
        .sum();
    assert_eq!(replica_sum, failed_of(v2), "replica lines decompose their version line");
}

#[test]
fn router_works_in_pipeline_mode_too() {
    // the per-model RuntimeConfig carries the execution mode: the same
    // front door can put a model on the spatial pipeline executor
    let cfg = config().with_mode(ExecMode::Pipeline { stages: 2, queue_depth: 2 });
    let router = Router::start(&manifest(), &["tiny-synth".to_string()], 2, cfg).unwrap();
    let per = router.server("tiny-synth").unwrap().tokens_per_image();
    let responses = router.infer_all("tiny-synth", vec![vec![0.25; per]; 3]).unwrap();
    assert_eq!(responses.len(), 3);
    assert_eq!(router.metrics()[0].1.count(), 3);
}
