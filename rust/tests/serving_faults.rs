//! Regression tests for the serving-loop request-loss fixes (PR 2):
//!
//! 1. a failed `run_f32` dispatch must answer every drained request with
//!    an explicit error (previously the senders were dropped and clients
//!    hung on `recv` until an opaque "reply lost"),
//! 2. dropping a `ModelServer` must deterministically fail queued +
//!    pending requests instead of silently discarding them,
//! 3. a lone request parked behind the batching deadline must dispatch
//!    at the deadline (the executor now blocks in `recv_timeout` for the
//!    residual head-of-line wait instead of busy-spinning; the deadline
//!    arithmetic itself is unit-tested in `coordinator::batcher`).

use std::path::{Path, PathBuf};
use std::time::Duration;

use hgpipe::artifacts::Manifest;
use hgpipe::coordinator::ModelServer;
use hgpipe::runtime::{faulty, BackendKind};

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join("golden")
}

fn manifest() -> Manifest {
    Manifest::load(&fixture_dir()).expect("committed golden fixture")
}

#[test]
fn failed_dispatch_replies_error_to_every_request() {
    // the Faulty backend loads fine and fails every execution — the only
    // way to drive the dispatch-error path end to end
    let server =
        ModelServer::start_with_backend(&manifest(), "any", 1, BackendKind::Faulty).unwrap();
    assert_eq!(server.tokens_per_image(), faulty::TOKENS_PER_IMAGE);
    let rx1 = server.submit(vec![0.5; faulty::TOKENS_PER_IMAGE]).unwrap();
    let rx2 = server.submit(vec![0.25; faulty::TOKENS_PER_IMAGE]).unwrap();
    for (i, rx) in [rx1, rx2].into_iter().enumerate() {
        let reply = rx
            .recv()
            .unwrap_or_else(|_| panic!("request {i}: reply sender dropped without a message"));
        let err = reply.expect_err("a failed dispatch must surface its error");
        let msg = format!("{err:#}");
        assert!(msg.contains("injected fabric fault"), "request {i}: unexpected error: {msg}");
    }
    assert_eq!(server.metrics.lock().unwrap().failed, 2);
}

#[test]
fn infer_all_propagates_dispatch_errors() {
    let server =
        ModelServer::start_with_backend(&manifest(), "any", 1, BackendKind::Faulty).unwrap();
    let images = vec![vec![0.0; faulty::TOKENS_PER_IMAGE]; 3];
    let err = server.infer_all(images).expect_err("faulty backend cannot succeed");
    assert!(format!("{err:#}").contains("injected fabric fault"));
}

#[test]
fn dropping_server_fails_queued_requests_deterministically() {
    // a 10 s batching deadline plus fewer requests than the smallest full
    // batch keeps all three parked in the queue until the drop
    let server = ModelServer::start(&manifest(), "tiny-synth", 10_000).unwrap();
    let per = server.tokens_per_image();
    let metrics = server.metrics.clone();
    let rxs: Vec<_> = (0..3).map(|_| server.submit(vec![0.0; per]).unwrap()).collect();
    drop(server);
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx
            .recv()
            .unwrap_or_else(|_| panic!("request {i}: reply sender dropped without a message"));
        let err = reply.expect_err("queued request must fail on shutdown, not hang");
        assert!(format!("{err:#}").contains("shut down"), "request {i}");
    }
    assert_eq!(metrics.lock().unwrap().failed, 3);
}

#[test]
fn single_request_dispatches_at_the_deadline() {
    // batch variants are {1, 8}: a lone request can never fill the large
    // variant, so it must be held exactly until the head-of-line deadline
    // and then dispatched on the batch-1 variant
    let wait = Duration::from_millis(80);
    let server = ModelServer::start(&manifest(), "tiny-synth", wait.as_millis() as u64).unwrap();
    let per = server.tokens_per_image();
    let rx = server.submit(vec![0.1; per]).unwrap();
    let resp = rx.recv().unwrap().expect("lone request must eventually run");
    assert!(
        resp.latency >= wait,
        "dispatched before the batching deadline: {:?} < {wait:?}",
        resp.latency
    );
    let m = server.metrics.lock().unwrap();
    assert_eq!(m.count(), 1);
    assert_eq!(m.failed, 0);
    assert_eq!(m.batch_hist.keys().copied().collect::<Vec<_>>(), vec![1]);
}
