//! Integration: the cycle-accurate simulator must reproduce the paper's
//! headline timing numbers on the full DeiT-tiny network (Sec. 5.2 /
//! Fig. 12), and the paradigm comparisons of Fig. 2c.

use hgpipe::arch::parallelism::{design_network, design_table1};
use hgpipe::model::{Precision, ViTConfig};
use hgpipe::sim::{self, builder::Paradigm, SimConfig, StopReason};

fn deit_hybrid_report(images: u64) -> sim::SimReport {
    let cfg = ViTConfig::deit_tiny();
    let d = design_network(&cfg, Precision::A4W3, 2);
    let p = sim::build_vit(&d, &cfg, Paradigm::Hybrid, SimConfig::matched(&d, &cfg));
    sim::run(&p, images, 50_000_000)
}

#[test]
fn stable_ii_is_exactly_57624() {
    let r = deit_hybrid_report(3);
    assert_eq!(r.stop, StopReason::Completed);
    assert_eq!(r.stable_ii(), Some(57_624)); // paper Fig. 12
}

#[test]
fn first_image_within_2_percent_of_paper() {
    let r = deit_hybrid_report(1);
    let first = r.first_image_latency().unwrap() as f64;
    let paper = 824_843.0;
    assert!(
        (first - paper).abs() / paper < 0.02,
        "first image {first} vs paper {paper}"
    );
}

#[test]
fn ideal_fps_matches_paper_7353() {
    let r = deit_hybrid_report(3);
    let s = sim::trace::summarize(&r, 425e6).unwrap();
    assert!((s.ideal_fps - 7353.0).abs() / 7353.0 < 0.01, "fps {}", s.ideal_fps);
    assert!((s.latency_ms - 0.136).abs() < 0.002, "latency {}", s.latency_ms);
}

#[test]
fn table1_design_and_simulated_ii_agree() {
    // the analytical Table-1 II and the simulated steady state must agree
    let d = design_table1();
    let r = deit_hybrid_report(3);
    assert_eq!(d.accelerator_ii(), r.stable_ii().unwrap());
}

#[test]
fn coarse_grained_latency_exceeds_hybrid() {
    let cfg = ViTConfig::deit_tiny();
    let d = design_network(&cfg, Precision::A4W3, 2);
    let sim_cfg = SimConfig::matched(&d, &cfg);
    let h = sim::run(&sim::build_vit(&d, &cfg, Paradigm::Hybrid, sim_cfg), 2, 100_000_000);
    let c = sim::run(&sim::build_vit(&d, &cfg, Paradigm::CoarseGrained, sim_cfg), 2, 200_000_000);
    assert_eq!(c.stop, StopReason::Completed);
    let (hl, cl) = (h.first_image_latency().unwrap(), c.first_image_latency().unwrap());
    // Fig 2c: coarse latency "Mid" vs hybrid "Low" — whole-tensor
    // handoffs serialize each block
    assert!(cl > 2 * hl, "coarse {cl} vs hybrid {hl}");
}

#[test]
fn fine_grained_deadlocks_on_deit() {
    let cfg = ViTConfig::deit_tiny();
    let d = design_network(&cfg, Precision::A4W3, 2);
    let p = sim::build_vit(&d, &cfg, Paradigm::FineGrained, SimConfig::matched(&d, &cfg));
    let r = sim::run(&p, 1, 100_000_000);
    assert!(matches!(r.stop, StopReason::Deadlock { .. }), "{:?}", r.stop);
}

#[test]
fn deep_fifo_highwater_supports_512_token_sizing() {
    // the deep FIFOs' observed high-water mark must be close to one
    // image's groups (98 at TP=2) — the paper's 512-token (256-group)
    // sizing is a power-of-two with margin above it
    let r = deit_hybrid_report(3);
    let max_res = r
        .channel_names
        .iter()
        .zip(&r.channel_max_occupancy)
        .filter(|(n, _)| n.ends_with(".res") || n.ends_with(".res2") || n.ends_with(".q"))
        .map(|(_, &m)| m)
        .max()
        .unwrap();
    assert!((90..=256).contains(&max_res), "deep-FIFO high water {max_res}");
}

#[test]
fn throughput_scales_with_more_images() {
    let r5 = deit_hybrid_report(5);
    let done = &r5.image_done;
    // after the fill, every image takes exactly one stable II
    for w in done.windows(2).skip(1) {
        assert_eq!(w[1] - w[0], 57_624, "{done:?}");
    }
}
