//! Property-based tests (hand-rolled driver; proptest is not vendored in
//! this offline environment) over the coordinator-side invariants the
//! system prompt calls out: routing/batching decisions, channel state
//! machines, simulator conservation laws, LUT index safety, JSON codec.

use hgpipe::coordinator::batcher::BatchPolicy;
use hgpipe::lut::{generate, numerics, OutQuant};
use hgpipe::sim::channel::{Channel, ChannelKind};
use hgpipe::sim::engine::{run, Pipeline, StopReason};
use hgpipe::sim::stage::StageSpec;
use hgpipe::util::json::Json;
use hgpipe::util::prng::{for_all_seeds, Prng};
use std::time::Duration;

#[test]
fn prop_batcher_never_exceeds_queue_or_variants() {
    for_all_seeds(300, |rng| {
        let mut variants: Vec<usize> =
            (0..rng.range_i64(1, 4)).map(|_| rng.range_i64(1, 32) as usize).collect();
        variants.sort_unstable();
        variants.dedup();
        let policy = BatchPolicy::new(variants.clone(), Duration::from_millis(2)).unwrap();
        let queued = rng.range_i64(0, 100) as usize;
        let waited = Duration::from_micros(rng.range_i64(0, 5000) as u64);
        if let Some(b) = policy.decide(queued, waited) {
            assert!(variants.contains(&b), "batch {b} not a variant {variants:?}");
            // a dispatch larger than the queue is only allowed as the
            // padded-smallest-variant escape hatch for a starving head
            if b > queued {
                assert_eq!(b, variants[0], "oversized dispatch must be the smallest variant");
                assert!(queued < variants[0]);
            }
        } else {
            // only legitimate reasons to wait: empty queue, or a partial
            // batch whose head hasn't timed out
            assert!(
                queued == 0 || (queued < policy.largest() && waited < Duration::from_millis(2))
            );
        }
    });
}

#[test]
fn prop_head_of_line_always_progresses_after_deadline() {
    for_all_seeds(200, |rng| {
        let variants: Vec<usize> = vec![rng.range_i64(1, 8) as usize, 16];
        let policy = BatchPolicy::new(variants, Duration::from_millis(1)).unwrap();
        let queued = rng.range_i64(1, 15) as usize;
        let b = policy.decide(queued, Duration::from_millis(5));
        assert!(b.is_some(), "head request starved at queue depth {queued}");
    });
}

#[test]
fn prop_fifo_occupancy_bounded_and_conserved() {
    for_all_seeds(200, |rng| {
        let cap = rng.range_i64(1, 16) as u64;
        let mut c = Channel::new("f", ChannelKind::Fifo { cap });
        let mut pushed = 0u64;
        let mut popped = 0u64;
        for _ in 0..200 {
            if rng.f64() < 0.55 && c.can_push() {
                c.push();
                pushed += 1;
            } else if c.can_consume(0) {
                c.consume(0);
                popped += 1;
            }
            assert!(c.occupancy <= cap);
            assert_eq!(c.occupancy, pushed - popped, "conservation");
        }
        assert!(c.max_occupancy <= cap);
    });
}

#[test]
fn prop_pipo_reader_never_sees_partial_image() {
    for_all_seeds(200, |rng| {
        let gpi = rng.range_i64(1, 8) as u64;
        let mut c = Channel::new("p", ChannelKind::Pipo { groups_per_image: gpi });
        let mut written = 0u64;
        let mut released = 0u64;
        for _ in 0..300 {
            if rng.f64() < 0.6 && c.can_push() {
                c.push();
                written += 1;
            } else if let Some(img) = c.readable_image {
                // readable => that image must be fully written
                assert!(written >= (img + 1) * gpi, "partial image readable");
                if rng.f64() < 0.5 {
                    c.release(img);
                    released += 1;
                }
            }
        }
        assert!(released * gpi <= written);
    });
}

#[test]
fn prop_linear_pipelines_always_complete_and_conserve_groups() {
    for_all_seeds(60, |rng| {
        // random linear pipeline: 2-5 stages, random costs/caps
        let n_stages = rng.range_i64(2, 5) as usize;
        let firings = rng.range_i64(1, 6) as u64;
        let images = rng.range_i64(1, 3) as u64;
        let mut p = Pipeline::default();
        let mut prev: Option<usize> = None;
        for s in 0..n_stages {
            let out = if s + 1 < n_stages {
                Some(p.add_channel(format!("c{s}"), ChannelKind::Fifo {
                    cap: rng.range_i64(1, 6) as u64,
                }))
            } else {
                None
            };
            let idx = p.add_stage(StageSpec {
                name: format!("s{s}"),
                block: format!("s{s}"),
                cost: rng.range_i64(1, 9) as u64,
                firings_per_image: firings,
                inputs: prev.into_iter().collect(),
                outputs: out.into_iter().collect(),
                is_source: s == 0,
            });
            if out.is_none() {
                p.sink = idx;
            }
            prev = out;
        }
        let r = run(&p, images, 10_000_000);
        assert_eq!(r.stop, StopReason::Completed, "linear pipeline wedged");
        // conservation: every stage fired exactly firings * images times
        for st in &r.stage_states {
            assert_eq!(st.total_firings, firings * images);
        }
    });
}

#[test]
fn prop_lut_lookup_always_in_table() {
    for_all_seeds(300, |rng| {
        let alpha = rng.range_i64(-1_000_000, 1_000_000);
        let span = rng.range_i64(1, 2_000_000);
        let t = generate::requant_table(
            "t",
            alpha,
            alpha + span,
            0.01,
            OutQuant::symmetric(0.125, 4),
        );
        for _ in 0..50 {
            let x = rng.range_i64(i64::MIN / 4, i64::MAX / 4);
            let v = t.lookup(x);
            assert!(t.entries.contains(&v));
            assert!((-8..=7).contains(&v));
        }
    });
}

#[test]
fn prop_pot_shift_index_safety() {
    for_all_seeds(500, |rng| {
        let alpha = rng.range_i64(-(1 << 40), 1 << 40);
        let span = rng.range_i64(1, 1 << 40);
        let n = rng.range_i64(2, 12) as u32;
        let s = numerics::pot_shift(alpha, alpha + span, n);
        // every in-range input maps into the table without clamping need
        let raw_max = span >> s;
        assert!(raw_max <= (1 << n) - 1, "overflow: span {span} shift {s} bits {n}");
        if s > 0 {
            assert!(span >> (s - 1) > (1 << n) - 1, "shift not minimal");
        }
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    for_all_seeds(200, |rng| {
        let v = random_json(rng, 3);
        let s = v.to_string_compact();
        let back = Json::parse(&s).unwrap_or_else(|e| panic!("parse back failed: {e}\n{s}"));
        assert_eq!(v, back);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    });
}

fn random_json(rng: &mut Prng, depth: usize) -> Json {
    match if depth == 0 { rng.range_i64(0, 3) } else { rng.range_i64(0, 5) } {
        0 => Json::Null,
        1 => Json::Bool(rng.f64() < 0.5),
        2 => Json::Num((rng.range_i64(-1 << 50, 1 << 50)) as f64),
        3 => {
            let n = rng.range_i64(0, 12) as usize;
            Json::Str((0..n).map(|_| *rng.pick(&['a', '"', '\\', '\n', 'é', 'z'])).collect())
        }
        4 => Json::Arr((0..rng.range_i64(0, 4)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.range_i64(0, 4))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}
