//! Golden cross-check: the rust table generators must reproduce the
//! python-emitted fixture (`artifacts/golden_tables.json`) — alpha /
//! shift / pivot / scales exactly, entries within ±1 LSB (libm exp/sqrt
//! may differ by an ulp across languages).

use std::path::Path;

use hgpipe::lut::{generate, LutTable, OutQuant, SegmentedTable};
use hgpipe::util::json::Json;

fn fixture() -> Option<Json> {
    // prefer a fresh `make artifacts` emission; fall back to the
    // committed copy under golden/ so this runs in default CI too
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    for cand in ["golden_tables.json", "golden/golden_tables.json"] {
        if let Ok(text) = std::fs::read_to_string(dir.join(cand)) {
            return Some(Json::parse(&text).expect("fixture parses"));
        }
    }
    None
}

fn assert_tables_match(ours: &LutTable, golden: &LutTable, case: &str) {
    assert_eq!(ours.alpha, golden.alpha, "{case}: alpha");
    assert_eq!(ours.shift, golden.shift, "{case}: shift");
    assert_eq!(ours.n_bits, golden.n_bits, "{case}: n_bits");
    assert_eq!(ours.inverted, golden.inverted, "{case}: inverted");
    assert_eq!(ours.out_scale, golden.out_scale, "{case}: out_scale (exact f64)");
    assert_eq!(ours.out_zp, golden.out_zp, "{case}: out_zp");
    assert_eq!(ours.entries.len(), golden.entries.len(), "{case}: depth");
    for (i, (a, b)) in ours.entries.iter().zip(&golden.entries).enumerate() {
        assert!(
            (a - b).abs() <= 1,
            "{case}: entry {i} differs by more than 1 LSB: ours {a}, python {b}"
        );
    }
}

fn golden_lut(fx: &Json, case: &str) -> LutTable {
    LutTable::from_json(fx.get(case).unwrap().get("table").unwrap()).unwrap()
}

#[test]
fn requant_matches_python() {
    let Some(fx) = fixture() else {
        eprintln!("skipped: run `make artifacts` first");
        return;
    };
    let golden = golden_lut(&fx, "requant");
    let ours =
        generate::requant_table("rq", -1000, 2000, 0.03125, OutQuant::symmetric(0.125, 4));
    assert_tables_match(&ours, &golden, "requant");
}

#[test]
fn requant_calibrated_matches_python() {
    let Some(fx) = fixture() else { return };
    let golden = golden_lut(&fx, "requant_calibrated");
    let ours = generate::joint_calibrate(
        "rq_cal",
        |x| x,
        -4000,
        4000,
        0.03125,
        6,
        OutQuant::symmetric(0.125, 4),
    );
    assert_tables_match(&ours, &golden, "requant_calibrated");
}

#[test]
fn gelu_matches_python() {
    let Some(fx) = fixture() else { return };
    let golden = golden_lut(&fx, "gelu");
    let ours =
        generate::gelu_requant_table("gelu", -800, 800, 0.0078125, OutQuant::symmetric(0.125, 4));
    assert_tables_match(&ours, &golden, "gelu");
}

#[test]
fn exp_inverted_matches_python() {
    let Some(fx) = fixture() else { return };
    let golden = golden_lut(&fx, "exp_inverted");
    let ours = generate::exp_table_inverted("exp", -5000, 0, 0.001953125);
    assert_tables_match(&ours, &golden, "exp_inverted");
    assert!(ours.inverted);
}

#[test]
fn recip_segmented_matches_python() {
    let Some(fx) = fixture() else { return };
    let golden =
        SegmentedTable::from_json(fx.get("recip_segmented").unwrap().get("table").unwrap())
            .unwrap();
    let ours = generate::recip_table_segmented("recip", 200, 40000, 0.00390625);
    assert_eq!(ours.pivot, golden.pivot, "pivot");
    assert_tables_match(&ours.steep, &golden.steep, "recip.steep");
    assert_tables_match(&ours.flat, &golden.flat, "recip.flat");
}

#[test]
fn rsqrt_matches_python() {
    let Some(fx) = fixture() else { return };
    let golden = golden_lut(&fx, "rsqrt");
    let ours = generate::rsqrt_table("rsqrt", 50, 100000, 0.0625);
    assert_tables_match(&ours, &golden, "rsqrt");
}

#[test]
fn full_deit_table_set_loads() {
    // the complete 159-table DeiT-tiny set emitted by the build must load
    // and be structurally sane
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tables_deit_tiny_a4w4.json");
    if !p.exists() {
        eprintln!("skipped: run `make artifacts` first");
        return;
    }
    let tables = hgpipe::lut::load_tables(&p).unwrap();
    assert!(tables.len() > 100, "{}", tables.len());
    // every attention block carries an inverted exp table and a segmented
    // recip table
    for i in 0..12 {
        match tables.get(&format!("b{i}.attn.exp")) {
            Some(hgpipe::lut::AnyTable::Lut(t)) => assert!(t.inverted, "b{i} exp inverted"),
            other => panic!("b{i}.attn.exp wrong kind: {other:?}"),
        }
        assert!(
            matches!(
                tables.get(&format!("b{i}.attn.recip")),
                Some(hgpipe::lut::AnyTable::Segmented(_))
            ),
            "b{i}.attn.recip must be segmented"
        );
    }
}
