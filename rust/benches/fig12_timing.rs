//! Bench + regeneration of Figure 12 (cycle-accurate timing diagram) —
//! also the simulator's end-to-end throughput benchmark.

use std::time::Duration;

use hgpipe::arch::parallelism::design_network;
use hgpipe::model::{Precision, ViTConfig};
use hgpipe::sim::{self, builder::Paradigm, SimConfig};
use hgpipe::util::bench::bench;

fn main() {
    println!("=== Figure 12: timing diagram (DeiT-tiny, hybrid paradigm) ===\n");
    let cfg = ViTConfig::deit_tiny();
    let d = design_network(&cfg, Precision::A4W3, 2);
    let sim_cfg = SimConfig::matched(&d, &cfg);
    let pipeline = sim::build_vit(&d, &cfg, Paradigm::Hybrid, sim_cfg);

    let r = sim::run(&pipeline, 3, 5_000_000);
    let s = sim::trace::summarize(&r, 425e6).expect("completes");
    println!("{}", sim::trace::render_gantt(&r, 100));
    println!(
        "stable II {} (paper 57,624) | image1 {} cycles (paper 824,843)",
        s.stable_ii, s.first_image_cycles
    );
    println!(
        "latency {:.3} ms (paper 0.136) | ideal {:.0} img/s (paper 7,353)",
        s.latency_ms, s.ideal_fps
    );

    println!("\n--- simulator throughput (before/after the §Perf pass) ---");
    let cycles = r.cycles as f64;
    let res = bench("cycle-stepped reference (run)", Duration::from_secs(3), || {
        let rep = sim::run(&pipeline, 3, 5_000_000);
        assert_eq!(rep.stop, sim::StopReason::Completed);
    });
    println!("{res}");
    println!("    => {:.1} M simulated cycles/s", cycles / res.mean.as_secs_f64() / 1e6);
    let fast = bench("event-driven (run_fast)", Duration::from_secs(3), || {
        let rep = sim::run_fast(&pipeline, 3, 5_000_000);
        assert_eq!(rep.stop, sim::StopReason::Completed);
    });
    println!("{fast}");
    println!(
        "    => {:.1} M simulated cycles/s  ({:.0}x speedup)",
        cycles / fast.mean.as_secs_f64() / 1e6,
        res.mean.as_secs_f64() / fast.mean.as_secs_f64()
    );
}
