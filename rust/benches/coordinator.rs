//! Coordinator benchmarks: batching-policy microbench + end-to-end
//! serving throughput on the AOT tiny-ViT (skips if artifacts missing).

use std::time::Duration;

use hgpipe::artifacts::Manifest;
use hgpipe::coordinator::batcher::BatchPolicy;
use hgpipe::coordinator::ModelServer;
use hgpipe::util::bench::{bench, black_box};
use hgpipe::util::prng::Prng;

fn main() {
    println!("=== coordinator benches ===\n");

    // pure policy micro-bench (the per-request decision cost)
    let policy = BatchPolicy::new(vec![1, 8], Duration::from_millis(2)).unwrap();
    let r = bench("batch policy decide() x1000", Duration::from_millis(300), || {
        for q in 0..1000usize {
            black_box(policy.decide(q % 17, Duration::from_micros((q % 3000) as u64)));
        }
    });
    println!("{r}");

    // end-to-end serving throughput on the real artifact
    let Some(dir) = Manifest::discover() else {
        println!("(no artifacts found — run `make artifacts` for the serving bench)");
        return;
    };
    let manifest = Manifest::load(&dir).expect("manifest");
    let server = ModelServer::start(&manifest, "tiny-synth", 2).expect("server");
    let backend = server.backend().label();
    let n_tok = server.tokens_per_image();
    let mut rng = Prng::new(3);
    let images: Vec<Vec<f32>> =
        (0..64).map(|_| (0..n_tok).map(|_| rng.f64() as f32).collect()).collect();
    let n_images = images.len();

    // warm up (load already done at start; prime caches)
    server.infer_all(images[..16].to_vec()).unwrap();

    let name = format!("serve {n_images} tiny-synth images ({backend})");
    let r = bench(&name, Duration::from_secs(5), || {
        black_box(server.infer_all(images.clone()).unwrap());
    });
    println!("{r}");
    println!("    => {:.0} img/s through the full coordinator", r.throughput(n_images as f64));
    println!("{}", server.metrics.lock().unwrap().summary());

    // coordinator overhead: exec time vs wall time share
    let m = server.metrics.lock().unwrap();
    let exec_share = m.exec_ms_total / 1e3 / (m.count() as f64 / m.throughput().unwrap_or(1.0));
    println!("    => {backend}-execute share of wall time ~ {:.0}%", 100.0 * exec_share.min(1.0));
}
