//! Bench + regeneration of Table 2 (cross-accelerator comparison).

use std::time::Duration;

use hgpipe::metrics::{deploy, table2};
use hgpipe::model::{Precision, ViTConfig};
use hgpipe::platform::Fpga;
use hgpipe::util::bench::{bench, black_box};

fn main() {
    println!("=== Table 2: comparison with prior art ===\n");
    println!(
        "{:<24} {:<8} {:>5} {:<11} {:<7} {:>7} {:>8} {:>7} {:>6} {:>6} {:>6} {:>9} {:>8} {:>7}",
        "accelerator", "device", "MHz", "network", "prec", "FPS", "GOPs", "kLUT", "DSP", "BRAM",
        "W", "GOPs/kLUT", "GOPs/DSPn", "GOPs/W"
    );
    for r in table2() {
        println!(
            "{:<24} {:<8} {:>5.0} {:<11} {:<7} {:>7.0} {:>8.0} {:>7} {:>6} {:>6} {:>6.1} {:>9.2} {:>8.3} {:>7.1}",
            r.name,
            r.platform,
            r.freq_mhz,
            r.network,
            r.precision,
            r.fps,
            r.gops,
            if r.luts_k.is_nan() { "-".into() } else { format!("{:.0}", r.luts_k) },
            r.dsps,
            if r.brams.is_nan() { "-".into() } else { format!("{:.0}", r.brams) },
            r.power_w,
            if r.luts_k.is_nan() { f64::NAN } else { r.gops_per_klut() },
            r.gops_per_dsp_norm(),
            r.gops_per_w(),
        );
    }

    // headline claims
    let ours = deploy(&ViTConfig::deit_tiny(), Precision::A3W3, &Fpga::vck190(), 425e6);
    let zcu = deploy(&ViTConfig::deit_tiny(), Precision::A4W4, &Fpga::zcu102(), 375e6);
    println!("\nheadline ratios (ours vs paper):");
    println!("  vs V100 GPU        : {:.2}x  (paper 2.81x)", ours.fps / 2529.0);
    println!("  GOPs/kLUT vs AutoViTAcc: {:.2}x  (paper 2.52x)", zcu.gops_per_klut() / 7.35);
    println!("  GOPs/W vs SSR      : {:.2}x  (paper 1.55x)", ours.gops_per_w() / 246.15);

    println!("\n--- timing ---");
    let r = bench("full table2 assembly (4 deployments)", Duration::from_secs(2), || {
        black_box(table2());
    });
    println!("{r}");
}
