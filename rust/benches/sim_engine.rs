//! Simulator engine throughput across paradigms and network sizes — the
//! L3 hot-path benchmark driving the §Perf optimization pass.

use std::time::Duration;

use hgpipe::arch::parallelism::design_network;
use hgpipe::model::{Precision, ViTConfig};
use hgpipe::sim::{self, builder::Paradigm, SimConfig};
use hgpipe::util::bench::bench;

fn main() {
    println!("=== simulator engine throughput ===\n");
    for (cfg, label) in
        [(ViTConfig::tiny_synth(), "tiny-synth"), (ViTConfig::deit_tiny(), "deit-tiny")]
    {
        let d = design_network(&cfg, Precision::A4W3, 2);
        let sim_cfg = SimConfig::matched(&d, &cfg);
        for (par, pl) in
            [(Paradigm::Hybrid, "hybrid"), (Paradigm::CoarseGrained, "coarse")]
        {
            let pipeline = sim::build_vit(&d, &cfg, par, sim_cfg);
            let probe = sim::run_fast(&pipeline, 3, 500_000_000);
            let cycles = probe.cycles as f64;
            for (engine, ename) in [
                (sim::run as fn(&sim::Pipeline, u64, u64) -> sim::SimReport, "run"),
                (sim::run_fast as fn(&sim::Pipeline, u64, u64) -> sim::SimReport, "run_fast"),
            ] {
                let r = bench(
                    &format!("{label}/{pl}/{ename}: 3 images ({:.2}M cycles)", cycles / 1e6),
                    Duration::from_secs(2),
                    || {
                        let rep = engine(&pipeline, 3, 500_000_000);
                        assert!(matches!(rep.stop, sim::StopReason::Completed));
                    },
                );
                println!("{r}\n    => {:>8.1} Mcycles/s", cycles / r.mean.as_secs_f64() / 1e6);
            }
        }
    }

    // deadlock detection cost: the fine-grained paradigm wedges early
    println!("\n--- deadlock detection ---");
    let cfg = ViTConfig::deit_tiny();
    let d = design_network(&cfg, Precision::A4W3, 2);
    let pipeline = sim::build_vit(&d, &cfg, Paradigm::FineGrained, SimConfig::matched(&d, &cfg));
    let r = bench("fine-grained deadlock detection", Duration::from_secs(1), || {
        let rep = sim::run(&pipeline, 1, 500_000_000);
        assert!(matches!(rep.stop, sim::StopReason::Deadlock { .. }));
    });
    println!("{r}");
}
