//! Bench + regeneration of Table 1 (parallelism design).

use std::time::Duration;

use hgpipe::arch::parallelism::{design_network, design_table1};
use hgpipe::model::{Precision, ViTConfig};
use hgpipe::util::bench::{bench, black_box};

fn main() {
    println!("=== Table 1: parallelism design on DeiT-tiny ===\n");
    let d = design_table1();
    println!(
        "{:<16} {:>9} {:>11} {:>11} {:>7} {:>5} {:>7} {:>7}",
        "module", "T/TP=TT", "CI/CIP=CIT", "CO/COP=COT", "MOPs", "P", "II", "eta"
    );
    for m in &d.modules {
        println!(
            "{:<16} {:>3}/{}={:<4} {:>4}/{:<2}={:<4} {:>9} {:>7.2} {:>5} {:>7} {:>7}",
            m.spec.name,
            m.spec.t,
            m.tp,
            m.tt,
            m.spec.ci,
            m.cip,
            m.cit,
            if m.spec.is_mm() { format!("{}/{}={}", m.spec.co, m.cop, m.cot) } else { "-".into() },
            m.mops(),
            m.p,
            m.ii,
            if m.spec.is_mm() { format!("{:.1}%", m.eta * 100.0) } else { "-".into() },
        );
    }
    println!("\naccelerator II = {} (paper: 57624)", d.accelerator_ii());

    println!("\n--- timing ---");
    let cfg_t = ViTConfig::deit_tiny();
    let cfg_s = ViTConfig::deit_small();
    let r1 = bench("design_table1 (hand layout, derived columns)", Duration::from_millis(200), || {
        black_box(design_table1());
    });
    println!("{r1}");
    let r2 = bench("auto designer, deit-tiny (289 modules)", Duration::from_millis(400), || {
        black_box(design_network(&cfg_t, Precision::A4W3, 2));
    });
    println!("{r2}");
    let r3 = bench("auto designer, deit-small", Duration::from_millis(400), || {
        black_box(design_network(&cfg_s, Precision::A3W3, 2));
    });
    println!("{r3}");
}
