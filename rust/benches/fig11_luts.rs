//! Bench + regeneration of Figure 11 (LUT optimization techniques):
//! the DSP ladder (11a), the resource-reduction table (11c), the
//! segmented-recip MSE experiment (10d companion), and table-generation
//! throughput.

use std::time::Duration;

use hgpipe::arch::dsp::dsp_ladder;
use hgpipe::arch::parallelism::design_network;
use hgpipe::lut::cost::fig11c;
use hgpipe::lut::{generate, OutQuant};
use hgpipe::model::{Precision, ViTConfig};
use hgpipe::util::bench::{bench, black_box};

fn main() {
    println!("=== Figure 11a: DSP ladder ===");
    let d = design_network(&ViTConfig::deit_tiny(), Precision::A4W3, 2);
    for s in dsp_ladder(&d) {
        println!(
            "  {:<42} {:>7} DSPs   (paper {})",
            s.name,
            s.dsps,
            s.paper_dsps.map(|p| p.to_string()).unwrap_or_default()
        );
    }

    println!("\n=== Figure 11c: resource reduction ===");
    println!(
        "{:<10} {:>6} {:>5} {:>20} {:>14} {:>16}",
        "function", "depth", "bits", "LUT-6 naive->table", "paper table", "DSP naive->table"
    );
    for r in fig11c() {
        println!(
            "{:<10} {:>6} {:>5} {:>13} -> {:<4} {:>14} {:>10} -> {}",
            r.function, r.table_depth, r.table_bits, r.naive.lut6, r.table.lut6,
            r.paper_table_lut6, r.naive.dsp, r.table.dsp
        );
    }

    println!("\n=== Figure 10d companion: segmented recip MSE ===");
    let (a, b, s) = (200i64, 40_000i64, 1.0 / 255.0);
    let seg = generate::recip_table_segmented("r", a, b, s);
    let flat = generate::recip_table_flat("r", a, b, s);
    let xs: Vec<i64> = (0..20_000)
        .map(|i| {
            let u = (i as f64 + 0.5) / 20_000.0;
            ((a as f64) * (1.0 / u).powf(1.4)).min(b as f64) as i64
        })
        .collect();
    let f = |x: f64| 1.0 / x;
    println!(
        "  flat MSE {:.6}  segmented MSE {:.6}  ({:.1}x; paper 0.032 -> 0.0034)",
        flat.mse(&xs, f, s),
        seg.mse(&xs, f, s),
        flat.mse(&xs, f, s) / seg.mse(&xs, f, s)
    );

    println!("\n--- table generation throughput ---");
    let out = OutQuant::symmetric(0.125, 4);
    let r = bench("requant_table (64 entries)", Duration::from_millis(300), || {
        black_box(generate::requant_table("rq", -1000, 2000, 0.03125, out));
    });
    println!("{r}");
    let r = bench("gelu_requant_table (erf per entry)", Duration::from_millis(300), || {
        black_box(generate::gelu_requant_table("g", -800, 800, 0.0078125, out));
    });
    println!("{r}");
    let r = bench("joint_calibrate (iterative)", Duration::from_millis(300), || {
        black_box(generate::joint_calibrate("jc", |x| x, -100_000, 100_000, 0.001, 6, out));
    });
    println!("{r}");
    let r = bench("recip_table_segmented", Duration::from_millis(300), || {
        black_box(generate::recip_table_segmented("rs", 200, 40_000, 1.0 / 255.0));
    });
    println!("{r}");
}
