//! Interpreter fabric throughput bench: pre-fabric scalar kernels vs the
//! blocked + lane-pooled fabric, with a per-op time breakdown.
//!
//! Run directly (`cargo bench --bench interpreter`) for a human summary,
//! or via `make bench-json` to also emit `BENCH_interpreter.json` — the
//! machine-readable perf trajectory tracked from PR 2 onward. Flags
//! (after `--`):
//!
//!   --json PATH   write the JSON report to PATH
//!   --smoke       tiny workload + short budget (CI smoke mode)
//!   --lanes N     pool width (default: HGPIPE_LANES, else
//!                 max(4, available parallelism))
//!
//! The bench self-validates before timing: the fabric path must be
//! logit-for-logit bit-identical to the naive baseline on its own input.

use std::time::Duration;

use hgpipe::artifacts::Manifest;
use hgpipe::runtime::fabric::LanePool;
use hgpipe::runtime::interpreter::{self, OpProfile, QuantViT};
use hgpipe::util::bench::{bench, black_box};
use hgpipe::util::prng::Prng;

struct Opts {
    json: Option<String>,
    smoke: bool,
    lanes: usize,
}

fn parse_opts() -> Opts {
    let mut json = None;
    let mut smoke = false;
    let mut lanes = None;
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--json" if i + 1 < argv.len() => {
                json = Some(argv[i + 1].clone());
                i += 1;
            }
            "--smoke" => smoke = true,
            "--lanes" if i + 1 < argv.len() => {
                lanes = argv[i + 1].parse().ok();
                i += 1;
            }
            "--bench" => {} // appended by `cargo bench`
            _ => {}
        }
        i += 1;
    }
    let lanes = lanes.unwrap_or_else(|| {
        std::env::var("HGPIPE_LANES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| {
                4usize.max(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
            })
    });
    Opts { json, smoke, lanes: lanes.max(1) }
}

fn main() {
    let opts = parse_opts();
    println!("=== interpreter fabric bench ({} lanes) ===\n", opts.lanes);

    // the golden fixture is committed, so not finding it is an error (a
    // silent skip would surface later as a confusing missing-JSON failure)
    let Some(dir) = Manifest::discover() else {
        eprintln!("error: no artifacts found — the committed golden fixture should be \
                   discoverable from the package or repo root");
        std::process::exit(2);
    };
    let manifest = Manifest::load(&dir).expect("manifest");
    let Some(info) = manifest.bundle_for("tiny-synth") else {
        eprintln!("error: no tiny-synth bundle in {}", dir.display());
        std::process::exit(2);
    };
    let net = QuantViT::load(&info.path).expect("bundle loads");
    let per = net.tokens_per_image();

    let n_images: usize = if opts.smoke { 16 } else { 64 };
    let budget = Duration::from_millis(if opts.smoke { 200 } else { 2000 });
    let mut rng = Prng::new(17);
    let flat: Vec<f32> = (0..n_images * per).map(|_| rng.f64() as f32).collect();

    // self-check: fabric output must be bit-identical to the baseline
    let want = net.forward_image_naive(&flat[..per]).unwrap();
    for lanes in [1usize, opts.lanes] {
        let got = net.forward_image_pooled(&flat[..per], &LanePool::new(lanes)).unwrap();
        assert_eq!(
            want.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            "fabric logits diverged from the naive baseline at {lanes} lanes"
        );
    }

    // 1. scalar baseline: the pre-fabric kernels, fully serial
    let r_naive = bench("scalar naive forward (pre-fabric kernels)", budget, || {
        for img in flat.chunks_exact(per) {
            black_box(net.forward_image_naive(img).unwrap());
        }
    });
    println!("{r_naive}");
    let naive_ips = n_images as f64 / r_naive.mean.as_secs_f64();

    // 2. fabric, serial: blocked GEMM + hoisted scratch, one lane
    let r_serial = bench("fabric forward, 1 lane (blocked GEMM)", budget, || {
        for img in flat.chunks_exact(per) {
            black_box(net.forward_image(img).unwrap());
        }
    });
    println!("{r_serial}");
    let serial_ips = n_images as f64 / r_serial.mean.as_secs_f64();

    // 3. fabric, pooled: through the real executor at its widest batch
    // variant (batch-lane grain, exactly what the coordinator dispatches)
    let loaded =
        interpreter::load_model_with_lanes(&manifest, "tiny-synth", opts.lanes).expect("load");
    let exe = loaded.executors.iter().max_by_key(|e| e.batch()).expect("an executor");
    let batch = exe.batch();
    let rounds = n_images / batch;
    assert!(rounds > 0, "image count {n_images} smaller than batch {batch}");
    let name = format!("fabric run_f32, {} lanes, batch {batch}", opts.lanes);
    let r_pooled = bench(&name, budget, || {
        for c in 0..rounds {
            black_box(exe.run_f32(&flat[c * batch * per..(c + 1) * batch * per]).unwrap());
        }
    });
    println!("{r_pooled}");
    let pooled_ips = (rounds * batch) as f64 / r_pooled.mean.as_secs_f64();

    // per-op breakdown (serial, so attribution is not interleaved)
    let prof_images = n_images.min(8);
    let mut prof = OpProfile::default();
    for img in flat.chunks_exact(per).take(prof_images) {
        let (_, p) = net.forward_profiled(img, &LanePool::serial()).unwrap();
        prof.merge(&p);
    }
    let scale = 1.0 / prof_images as f64;
    let total = prof.total_ms().max(1e-12);

    println!("\n    scalar naive     {naive_ips:8.1} img/s");
    println!("    fabric 1 lane    {serial_ips:8.1} img/s   ({:.2}x)", serial_ips / naive_ips);
    println!(
        "    fabric {} lanes   {pooled_ips:8.1} img/s   ({:.2}x vs naive, {:.2}x vs 1 lane)",
        opts.lanes,
        pooled_ips / naive_ips,
        pooled_ips / serial_ips
    );
    println!(
        "    per-op (1 lane): gemm {:.0}%  attention {:.0}%  layernorm {:.0}%  requant {:.0}%",
        100.0 * prof.gemm_ms / total,
        100.0 * prof.attention_ms / total,
        100.0 * prof.layernorm_ms / total,
        100.0 * prof.requant_ms / total,
    );

    if let Some(path) = &opts.json {
        let json = format!(
            "{{\n  \"model\": \"tiny-synth\",\n  \"smoke\": {},\n  \"images\": {},\n  \
             \"lanes\": {},\n  \"batch\": {},\n  \"scalar_naive_img_s\": {:.3},\n  \
             \"fabric_serial_img_s\": {:.3},\n  \"fabric_pooled_img_s\": {:.3},\n  \
             \"speedup_pooled_vs_naive\": {:.3},\n  \"speedup_pooled_vs_serial\": {:.3},\n  \
             \"per_op_ms_per_image\": {{\n    \"quantize\": {:.4},\n    \"gemm\": {:.4},\n    \
             \"layernorm\": {:.4},\n    \"attention\": {:.4},\n    \"requant\": {:.4},\n    \
             \"head\": {:.4}\n  }}\n}}\n",
            opts.smoke,
            n_images,
            opts.lanes,
            batch,
            naive_ips,
            serial_ips,
            pooled_ips,
            pooled_ips / naive_ips,
            pooled_ips / serial_ips,
            prof.quantize_ms * scale,
            prof.gemm_ms * scale,
            prof.layernorm_ms * scale,
            prof.attention_ms * scale,
            prof.requant_ms * scale,
            prof.head_ms * scale,
        );
        std::fs::write(path, &json).expect("write bench json");
        println!("\nwrote {path}");
    }
}
