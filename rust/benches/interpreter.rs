//! Interpreter fabric throughput bench: pre-fabric scalar kernels vs the
//! persistent lane-pooled fabric with its register-blocked GEMM
//! microkernel, plus a spawn-per-region reference (the PR-2 fabric), a
//! lane-scaling sweep, and per-op time breakdowns.
//!
//! Run directly (`cargo bench --bench interpreter`) for a human summary,
//! or via `make bench-json` to also emit `BENCH_interpreter.json` — the
//! machine-readable perf trajectory tracked from PR 2 onward. Flags
//! (after `--`):
//!
//!   --json PATH   write the JSON report to PATH
//!   --smoke       tiny workload + short budget (CI smoke mode)
//!   --lanes N     pool width (default: HGPIPE_LANES, else
//!                 max(4, available parallelism))
//!
//! The bench self-validates before timing: the fabric path must be
//! logit-for-logit bit-identical to the naive baseline on its own input.
//!
//! JSON fields (see README for the full schema):
//!   scalar_naive_img_s      pre-fabric scalar kernels, serial
//!   fabric_serial_img_s     persistent fabric, 1 lane (microkernel on)
//!   spawn_pooled_img_s      PR-2-style scoped-spawn-per-dispatch pool
//!   fabric_pooled_img_s     persistent fabric through the executor
//!   lane_sweep[]            {lanes, persistent_img_s, spawn_img_s}
//!   gemm_microkernel        blocked-vs-naive speedup, dense + sparse
//!   pipeline                hybrid-grained spatial executor: img/s vs
//!                           the lane-parallel fabric, a stage-count
//!                           sweep, per-stage occupancy over an explicit
//!                           measurement window, and fill/drain bubble +
//!                           backpressure stall counts
//!   scale_out               multi-executor scale-out: img/s at 1/2/4
//!                           replicas behind one model queue (with
//!                           per-replica occupancy), and the near-even
//!                           vs work-proportional partition compared by
//!                           per-stage busy_ms at stages = max
//!   kernels                 SIMD kernel dispatch: detected backend name,
//!                           scalar-oracle vs detected-backend img/s at
//!                           one lane, and per-op breakdowns under each
//!   memory                  shared-artifact accounting: the weight/LUT
//!                           footprint of one `ModelArtifact`, what a
//!                           4-replica fleet would cost unshared, and
//!                           the Arc refcount proving every replica
//!                           borrows the same copy
//!   telemetry               tracing overhead: the same closed-loop
//!                           server window with tracing off vs tracing
//!                           to a scratch JSONL, and their ratio (the
//!                           "zero cost when off" claim, measured)
//!   http                    network front door overhead: the same
//!                           window driven in-process (Router::submit)
//!                           vs over HTTP loopback on 8 keep-alive
//!                           connections, and the inproc/loopback ratio
//!   per_op_ms_per_image / per_op_pooled_ms_per_image

use std::fmt::Write as _;
use std::io::Write as _;
use std::sync::Arc;
use std::time::{Duration, Instant};

use hgpipe::artifacts::Manifest;
use hgpipe::coordinator::{ModelServer, Router};
use hgpipe::runtime::fabric::gemm::PackedGemm;
use hgpipe::runtime::fabric::LanePool;
use hgpipe::runtime::interpreter::{self, OpProfile, QuantViT};
use hgpipe::runtime::kernels;
use hgpipe::runtime::pipeline::{
    PartitionStrategy, Pipeline, PipelineConfig, DEFAULT_QUEUE_DEPTH,
};
use hgpipe::runtime::{BackendKind, ModelArtifact, RuntimeConfig};
use hgpipe::server::{HttpConfig, HttpServer};
use hgpipe::util::bench::{bench, black_box};
use hgpipe::util::prng::Prng;

struct Opts {
    json: Option<String>,
    smoke: bool,
    lanes: usize,
}

fn parse_opts() -> Opts {
    let mut json = None;
    let mut smoke = false;
    let mut lanes = None;
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--json" if i + 1 < argv.len() => {
                json = Some(argv[i + 1].clone());
                i += 1;
            }
            "--smoke" => smoke = true,
            "--lanes" if i + 1 < argv.len() => {
                lanes = argv[i + 1].parse().ok();
                i += 1;
            }
            "--bench" => {} // appended by `cargo bench`
            _ => {}
        }
        i += 1;
    }
    let lanes = lanes.unwrap_or_else(|| {
        std::env::var("HGPIPE_LANES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| {
                4usize.max(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
            })
    });
    Opts { json, smoke, lanes: lanes.max(1) }
}

/// The PR-2 fabric, reconstructed as a reference: one scoped-thread
/// spawn per dispatch region (batch-lane grain), each lane forwarding
/// its share of images serially. Measures what the persistent pool
/// saves.
fn spawn_pooled_round(net: &QuantViT, flat: &[f32], per: usize, lanes: usize) {
    let n_images = flat.len() / per;
    let lanes = lanes.min(n_images).max(1);
    let base = n_images / lanes;
    let extra = n_images % lanes;
    std::thread::scope(|s| {
        let mut i0 = 0usize;
        for lane in 0..lanes {
            let take = base + usize::from(lane < extra);
            let slice = &flat[i0 * per..(i0 + take) * per];
            i0 += take;
            s.spawn(move || {
                let serial = LanePool::serial();
                for img in slice.chunks_exact(per) {
                    black_box(net.forward_image_pooled(img, &serial).unwrap());
                }
            });
        }
    });
}

/// img/s of the persistent fabric at a given lane count, through the
/// real executor at its widest batch variant (exactly what the
/// coordinator dispatches).
fn persistent_img_s(
    manifest: &Manifest,
    lanes: usize,
    flat: &[f32],
    per: usize,
    budget: Duration,
    label: &str,
) -> f64 {
    let loaded = interpreter::load_model_with_lanes(manifest, "tiny-synth", lanes).expect("load");
    let exe = loaded.executors.iter().max_by_key(|e| e.batch()).expect("an executor");
    let batch = exe.batch();
    let n_images = flat.len() / per;
    let rounds = n_images / batch;
    assert!(rounds > 0, "image count {n_images} smaller than batch {batch}");
    let r = bench(label, budget, || {
        for c in 0..rounds {
            black_box(exe.run_f32(&flat[c * batch * per..(c + 1) * batch * per]).unwrap());
        }
    });
    println!("{r}");
    (rounds * batch) as f64 / r.mean.as_secs_f64()
}

fn main() {
    let opts = parse_opts();
    println!("=== interpreter fabric bench ({} lanes) ===\n", opts.lanes);

    // the golden fixture is committed, so not finding it is an error (a
    // silent skip would surface later as a confusing missing-JSON failure)
    let Some(dir) = Manifest::discover() else {
        eprintln!("error: no artifacts found — the committed golden fixture should be \
                   discoverable from the package or repo root");
        std::process::exit(2);
    };
    let manifest = Manifest::load(&dir).expect("manifest");
    let Some(info) = manifest.bundle_for("tiny-synth") else {
        eprintln!("error: no tiny-synth bundle in {}", dir.display());
        std::process::exit(2);
    };
    let net = Arc::new(QuantViT::load(&info.path).expect("bundle loads"));
    let per = net.tokens_per_image();

    let n_images: usize = if opts.smoke { 16 } else { 64 };
    let budget = Duration::from_millis(if opts.smoke { 200 } else { 2000 });
    let sweep_budget = budget / 2;
    let mut rng = Prng::new(17);
    let flat: Vec<f32> = (0..n_images * per).map(|_| rng.f64() as f32).collect();

    // self-check: fabric output must be bit-identical to the baseline
    let want = net.forward_image_naive(&flat[..per]).unwrap();
    for lanes in [1usize, opts.lanes] {
        let pool = LanePool::new(lanes);
        let got = net.forward_image_pooled(&flat[..per], &pool).unwrap();
        assert_eq!(
            want.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            "fabric logits diverged from the naive baseline at {lanes} lanes"
        );
    }

    // 1. scalar baseline: the pre-fabric kernels, fully serial
    let r_naive = bench("scalar naive forward (pre-fabric kernels)", budget, || {
        for img in flat.chunks_exact(per) {
            black_box(net.forward_image_naive(img).unwrap());
        }
    });
    println!("{r_naive}");
    let naive_ips = n_images as f64 / r_naive.mean.as_secs_f64();

    // 2. fabric, serial: microkernel GEMM + arena scratch, one lane
    let serial_pool = LanePool::serial();
    let r_serial = bench("fabric forward, 1 lane (GEMM microkernel)", budget, || {
        for img in flat.chunks_exact(per) {
            black_box(net.forward_image_pooled(img, &serial_pool).unwrap());
        }
    });
    println!("{r_serial}");
    let serial_ips = n_images as f64 / r_serial.mean.as_secs_f64();

    // 3. spawn-per-region reference (the PR-2 fabric) at the headline
    // lane count
    let r_spawn = bench(
        &format!("spawn-per-dispatch pool, {} lanes (PR-2 ref)", opts.lanes),
        budget,
        || spawn_pooled_round(&net, &flat, per, opts.lanes),
    );
    println!("{r_spawn}");
    let spawn_ips = n_images as f64 / r_spawn.mean.as_secs_f64();

    // 4. persistent fabric through the real executor at its widest batch
    let pooled_ips = persistent_img_s(
        &manifest,
        opts.lanes,
        &flat,
        per,
        budget,
        &format!("persistent fabric run_f32, {} lanes", opts.lanes),
    );

    // 5. lane-scaling sweep: persistent vs spawn at 1/2/4/available
    let mut sweep_lanes = vec![1usize, 2, 4, opts.lanes];
    sweep_lanes.sort_unstable();
    sweep_lanes.dedup();
    let mut sweep: Vec<(usize, f64, f64)> = Vec::new();
    for &lanes in &sweep_lanes {
        let p_ips = persistent_img_s(
            &manifest,
            lanes,
            &flat,
            per,
            sweep_budget,
            &format!("  sweep: persistent, {lanes} lanes"),
        );
        let r_sp = bench(&format!("  sweep: spawn, {lanes} lanes"), sweep_budget, || {
            spawn_pooled_round(&net, &flat, per, lanes)
        });
        println!("{r_sp}");
        let s_ips = n_images as f64 / r_sp.mean.as_secs_f64();
        sweep.push((lanes, p_ips, s_ips));
    }

    // 6. GEMM microkernel vs the scalar oracle, dense and sparse inputs
    // (deit-tiny MLP shape when not smoking; panels + remainder edges)
    let (gt, gci, gco) = if opts.smoke { (16usize, 64usize, 192usize) } else { (197, 192, 768) };
    let mut grng = Prng::new(0xBE);
    let gw: Vec<i32> = (0..gci * gco).map(|_| grng.range_i64(-100, 100) as i32).collect();
    let gb: Vec<i64> = (0..gco).map(|_| grng.range_i64(-1000, 1000)).collect();
    let g = PackedGemm::pack(gw, gci, gco, gb);
    let dense_x: Vec<i32> = (0..gt * gci).map(|_| grng.range_i64(1, 15) as i32).collect();
    let sparse_x: Vec<i32> = (0..gt * gci)
        .map(|_| if grng.below(10) < 7 { 0 } else { grng.range_i64(-15, 15) as i32 })
        .collect();
    assert_eq!(g.matmul(&dense_x, gt, &serial_pool), g.matmul_naive(&dense_x, gt));
    assert_eq!(g.matmul(&sparse_x, gt, &serial_pool), g.matmul_naive(&sparse_x, gt));
    let gemm_speedup = |x: &[i32], tag: &str| -> f64 {
        let rb = bench(&format!("gemm microkernel ({gt}x{gci}x{gco}, {tag})"), sweep_budget, || {
            black_box(g.matmul(x, gt, &serial_pool));
        });
        println!("{rb}");
        let rn = bench(&format!("gemm naive scalar ({gt}x{gci}x{gco}, {tag})"), sweep_budget, || {
            black_box(g.matmul_naive(x, gt));
        });
        println!("{rn}");
        rn.mean.as_secs_f64() / rb.mean.as_secs_f64()
    };
    let gemm_dense_speedup = gemm_speedup(&dense_x, "dense");
    let gemm_sparse_speedup = gemm_speedup(&sparse_x, "70% zeros");

    // 7. hybrid-grained pipeline executor: resident stages + bounded
    // queues, vs the lane-parallel fabric. Sweep stage counts, then
    // measure the fully-unrolled pipeline over an explicit window so
    // per-stage occupancy and bubble counts attribute to that window.
    let queue_depth = DEFAULT_QUEUE_DEPTH;
    let mut pipe_sweep: Vec<(usize, f64)> = Vec::new();
    let mut headline: Option<Pipeline> = None;
    // requested counts, ascending; 0 = fully unrolled. Dedup happens on
    // the RESOLVED pipe.stage_count() so the bench never re-measures a
    // count a shallow model clamps to, whatever the resolution policy
    for &stages in &[1usize, 2, 0] {
        let pipe = Pipeline::new(
            net.clone(),
            PipelineConfig { stages, queue_depth, lanes: opts.lanes, ..Default::default() },
        );
        if pipe_sweep.iter().any(|&(s, _)| s == pipe.stage_count()) {
            continue; // resolved to a count already measured
        }
        let resolved = pipe.stage_count();
        // self-check: pipeline logits bit-identical to the naive baseline
        let got = pipe.run_batch(&flat[..per], 1).unwrap();
        assert_eq!(
            want.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            "pipeline logits diverged from the naive baseline at {resolved} stages"
        );
        let r = bench(
            &format!("  pipeline, {resolved} stages (depth {queue_depth} FIFOs)"),
            sweep_budget,
            || {
                black_box(pipe.run_batch(&flat, n_images).unwrap());
            },
        );
        println!("{r}");
        pipe_sweep.push((pipe.stage_count(), n_images as f64 / r.mean.as_secs_f64()));
        headline = Some(pipe); // ascending sweep: the last benched entry is the most unrolled
    }
    // headline window: reuse the sweep's fully-unrolled pipeline (already
    // constructed and warmed by its bench rounds); occupancy and
    // fill/drain bubbles are diffed across exactly this window
    let pipe = headline.expect("stage sweep is non-empty");
    let pipe_rounds: usize = if opts.smoke { 3 } else { 10 };
    let s0 = pipe.stats();
    let tw = Instant::now();
    for _ in 0..pipe_rounds {
        black_box(pipe.run_batch(&flat, n_images).unwrap());
    }
    let pipe_wall_ms = tw.elapsed().as_secs_f64() * 1e3;
    let pd = pipe.stats().delta(&s0);
    let pipeline_ips = (pipe_rounds * n_images) as f64 / (pipe_wall_ms / 1e3);

    // 8. multi-executor scale-out: N executor replicas behind one shared
    // model queue, through the real ModelServer (exactly what
    // `--replicas` serves). Each replica is pinned to 1 lane so the
    // sweep isolates replica scaling from intra-replica banding.
    let scale_requests = n_images * if opts.smoke { 2 } else { 4 };
    let scale_images: Vec<Vec<f32>> = (0..scale_requests)
        .map(|i| flat[(i % n_images) * per..(i % n_images + 1) * per].to_vec())
        .collect();
    struct ReplicaPoint {
        replicas: usize,
        img_s: f64,
        /// Per replica over the timed window: (images, exec_ms, occupancy).
        per_replica: Vec<(u64, f64, f64)>,
    }
    let mut replica_sweep: Vec<ReplicaPoint> = Vec::new();
    // fault-tolerance counters aggregated across the sweep's servers:
    // all zero in a clean run, non-zero when the run is executed under
    // HGPIPE_FAULTS (the chaos CI lane) — the JSON records both so a
    // perf regression can be told apart from a perf-under-chaos number
    let faults_enabled =
        hgpipe::coordinator::faults::FaultPlan::from_env().is_some();
    let (mut f_restarts, mut f_retried, mut f_shed, mut f_expired) = (0u64, 0u64, 0u64, 0u64);
    for &replicas in &[1usize, 2, 4] {
        let cfg = RuntimeConfig::new(BackendKind::Interpreter)
            .with_lanes(Some(1))
            .with_replicas(Some(replicas));
        let server = ModelServer::start_with_config(&manifest, "tiny-synth", 1, cfg)
            .expect("scale-out server");
        assert_eq!(server.replicas(), replicas);
        // self-check: replicated serving must stay bit-identical to the
        // naive baseline (in the coordinator's f32 reply view)
        let check = server
            .infer_all(vec![flat[..per].to_vec(); 2 * replicas])
            .expect("scale-out self-check inference");
        for resp in &check {
            for (k, (&g, &w)) in resp.logits.iter().zip(&want).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    (w as f32).to_bits(),
                    "scale-out logits diverged from naive at {replicas} replicas (logit {k})"
                );
            }
        }
        server.infer_all(scale_images.clone()).expect("scale-out warm-up");
        let before = server.replica_metrics();
        let t0 = Instant::now();
        server.infer_all(scale_images.clone()).expect("scale-out window");
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let img_s = scale_requests as f64 / (wall_ms / 1e3);
        let per_replica: Vec<(u64, f64, f64)> = server
            .replica_metrics()
            .iter()
            .zip(&before)
            .map(|(now, was)| {
                let images = (now.count() - was.count()) as u64;
                let exec_ms = now.exec_ms_total - was.exec_ms_total;
                (images, exec_ms, exec_ms / wall_ms)
            })
            .collect();
        println!("  scale-out: {replicas} replica(s), 1 lane each   {img_s:8.1} img/s");
        {
            let m = server.metrics.lock().unwrap();
            f_restarts += m.restarts;
            f_retried += m.retried;
            f_shed += m.shed;
            f_expired += m.expired;
        }
        replica_sweep.push(ReplicaPoint { replicas, img_s, per_replica });
    }
    let scale_base_ips = replica_sweep[0].img_s;

    // 9. stage partition: near-even block slicing vs the
    // work-proportional cost model, compared by per-stage busy time
    // over identical windows. Three layouts keep the comparison honest:
    // near-even at stages=max (same thread budget as the cost model —
    // block-count slicing parks an empty tail stage there, which IS its
    // behavior at that resource count), near-even at PR-4's natural
    // fully-unrolled count (stages=depth, embed riding stage 0 — the
    // pre-cost-model baseline), and work-proportional at stages=max.
    struct PartitionPoint {
        stages: usize,
        img_s: f64,
        busy_ms: Vec<f64>,
        max_min_ratio: f64,
    }
    let mut part_cmp: Vec<PartitionPoint> = Vec::new();
    for (label, strategy, req_stages) in [
        ("near_even", PartitionStrategy::NearEven, 0usize),
        ("near_even_pr4", PartitionStrategy::NearEven, net.depth),
        ("work_proportional", PartitionStrategy::WorkProportional, 0),
    ] {
        let pipe = Pipeline::new(
            net.clone(),
            PipelineConfig {
                stages: req_stages,
                queue_depth,
                lanes: 1,
                partition: strategy,
                ..Default::default()
            },
        );
        pipe.run_batch(&flat, n_images).expect("partition warm-up");
        let s0 = pipe.stats();
        let t0 = Instant::now();
        for _ in 0..pipe_rounds {
            black_box(pipe.run_batch(&flat, n_images).unwrap());
        }
        let wall = t0.elapsed().as_secs_f64() * 1e3;
        let d = pipe.stats().delta(&s0);
        let busy_ms: Vec<f64> = d.stages.iter().map(|s| s.busy_ms).collect();
        let mx = busy_ms.iter().cloned().fold(f64::MIN, f64::max);
        let mn = busy_ms.iter().cloned().fold(f64::MAX, f64::min);
        let max_min_ratio = mx / mn.max(1e-6);
        let img_s = (pipe_rounds * n_images) as f64 / (wall / 1e3);
        println!(
            "  partition {label:<18} {:2} stages  {img_s:8.1} img/s  busy max/min {max_min_ratio:.1}x  bottleneck {mx:.1} ms",
            pipe.stage_count(),
        );
        part_cmp.push(PartitionPoint {
            stages: pipe.stage_count(),
            img_s,
            busy_ms,
            max_min_ratio,
        });
    }

    // 10. artifact memory: every replica borrows one immutable
    // `ModelArtifact` (weights, packed GEMM panels, requant tables), so
    // a replicated fleet pays the footprint once; the unshared number
    // is the pre-sharing cost of loading one copy per replica.
    let mem_replicas = 4usize;
    let solo_artifact = ModelArtifact::load(&manifest, "tiny-synth").expect("artifact load");
    let artifact_footprint = solo_artifact.footprint_bytes();
    drop(solo_artifact);
    let mem_cfg = RuntimeConfig::new(BackendKind::Interpreter)
        .with_lanes(Some(1))
        .with_replicas(Some(mem_replicas));
    let mem_server =
        ModelServer::start_with_config(&manifest, "tiny-synth", 1, mem_cfg).expect("memory fleet");
    let shared = mem_server.artifact().expect("interpreter backend shares an artifact");
    assert_eq!(
        shared.footprint_bytes(),
        artifact_footprint,
        "the fleet serves the same artifact a solo load produces"
    );
    let artifact_refs = shared.strong_count();
    assert!(
        artifact_refs >= 1 + mem_replicas,
        "every replica must hold the shared artifact (refs: {artifact_refs})"
    );
    let unshared_bytes = artifact_footprint * mem_replicas;
    let memory_savings = unshared_bytes as f64 / artifact_footprint as f64;
    drop(mem_server);

    // 11. SIMD kernel dispatch: the scalar oracle vs whatever backend
    // CPU detection picked, pinned through single-lane pools so the
    // comparison isolates the vectorized kernels from threading. Logits
    // are asserted bit-identical before timing (the vtable contract).
    let kern_scalar = kernels::scalar();
    let kern_simd = kernels::detect();
    let kpool_scalar = LanePool::with_kernels(1, kern_scalar);
    let kpool_simd = LanePool::with_kernels(1, kern_simd);
    {
        let got = net.forward_image_pooled(&flat[..per], &kpool_simd).unwrap();
        assert_eq!(
            want.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            "{} kernel backend diverged from the naive baseline",
            kern_simd.name
        );
    }
    let r_kscalar = bench("kernels: scalar oracle, 1 lane", sweep_budget, || {
        for img in flat.chunks_exact(per) {
            black_box(net.forward_image_pooled(img, &kpool_scalar).unwrap());
        }
    });
    println!("{r_kscalar}");
    let kscalar_ips = n_images as f64 / r_kscalar.mean.as_secs_f64();
    let r_ksimd =
        bench(&format!("kernels: {} backend, 1 lane", kern_simd.name), sweep_budget, || {
            for img in flat.chunks_exact(per) {
                black_box(net.forward_image_pooled(img, &kpool_simd).unwrap());
            }
        });
    println!("{r_ksimd}");
    let ksimd_ips = n_images as f64 / r_ksimd.mean.as_secs_f64();
    let kernel_speedup = ksimd_ips / kscalar_ips;

    // 12. telemetry overhead: the same closed-loop server window with
    // tracing explicitly off (`Some("")` shields the bench from a stray
    // HGPIPE_TRACE) vs tracing to a scratch JSONL. The "zero cost when
    // off" claim is the off/on ratio staying near 1; the on run also
    // exercises a traced server end to end.
    let tele_requests = n_images * if opts.smoke { 2 } else { 4 };
    let tele_images: Vec<Vec<f32>> = (0..tele_requests)
        .map(|i| flat[(i % n_images) * per..(i % n_images + 1) * per].to_vec())
        .collect();
    let tele_window = |trace: Option<&'static str>| -> f64 {
        let cfg = RuntimeConfig::new(BackendKind::Interpreter)
            .with_lanes(Some(1))
            .with_trace(trace);
        let server = ModelServer::start_with_config(&manifest, "tiny-synth", 1, cfg)
            .expect("telemetry server");
        server.infer_all(tele_images.clone()).expect("telemetry warm-up");
        let t0 = Instant::now();
        server.infer_all(tele_images.clone()).expect("telemetry window");
        tele_requests as f64 / t0.elapsed().as_secs_f64()
    };
    let tele_off_ips = tele_window(Some(""));
    let trace_scratch: &'static str = Box::leak(
        std::env::temp_dir()
            .join("hgpipe-bench-trace.jsonl")
            .to_string_lossy()
            .into_owned()
            .into_boxed_str(),
    );
    let tele_on_ips = tele_window(Some(trace_scratch));
    let tele_overhead = tele_off_ips / tele_on_ips;
    let _ = std::fs::remove_file(trace_scratch);

    // 13. network front door overhead: the same closed-loop window
    // driven in-process (Router::submit, 8 outstanding) vs over HTTP
    // loopback (8 keep-alive connections posting binary bodies against
    // one shared fleet). The quotient is what the hand-rolled HTTP/1.1
    // edge costs on top of the router it fronts.
    let http_batch = 8usize;
    let http_requests = n_images * if opts.smoke { 2 } else { 4 };
    let http_images: Vec<Vec<f32>> = (0..http_requests)
        .map(|i| flat[(i % n_images) * per..(i % n_images + 1) * per].to_vec())
        .collect();
    let http_cfg =
        RuntimeConfig::new(BackendKind::Interpreter).with_lanes(Some(1)).with_trace(Some(""));
    let http_router = Arc::new(
        Router::start(&manifest, &["tiny-synth".to_string()], 1, http_cfg)
            .expect("http bench fleet"),
    );
    let inproc_window = |images: &[Vec<f32>]| -> f64 {
        let t0 = Instant::now();
        for wave in images.chunks(http_batch) {
            let rxs: Vec<_> = wave
                .iter()
                .map(|img| {
                    http_router
                        .submit_with_deadline("tiny-synth", img.clone(), None)
                        .expect("in-process submit")
                })
                .collect();
            for rx in rxs {
                rx.recv().expect("reply").expect("in-process inference");
            }
        }
        images.len() as f64 / t0.elapsed().as_secs_f64()
    };
    inproc_window(&http_images[..http_batch.min(http_images.len())]); // warm-up
    let http_inproc_ips = inproc_window(&http_images);
    let http_server = HttpServer::bind("127.0.0.1:0", http_router.clone(), HttpConfig::default())
        .expect("bench http edge");
    let http_addr = http_server.local_addr().to_string();
    let loopback_window = |images: &[Vec<f32>]| -> f64 {
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for c in 0..http_batch.min(images.len()) {
                let addr = &http_addr;
                s.spawn(move || {
                    let mut stream = std::net::TcpStream::connect(addr).expect("bench connect");
                    let _ = stream.set_nodelay(true);
                    for img in images.iter().skip(c).step_by(http_batch) {
                        let body: Vec<u8> = img.iter().flat_map(|v| v.to_le_bytes()).collect();
                        let head = format!(
                            "POST /v1/models/tiny-synth/infer HTTP/1.1\r\nHost: bench\r\n\
                             Content-Length: {}\r\n\r\n",
                            body.len()
                        );
                        stream.write_all(head.as_bytes()).expect("bench post head");
                        stream.write_all(&body).expect("bench post body");
                        read_http_reply(&mut stream);
                    }
                });
            }
        });
        images.len() as f64 / t0.elapsed().as_secs_f64()
    };
    loopback_window(&http_images[..http_batch.min(http_images.len())]); // warm-up
    let http_loopback_ips = loopback_window(&http_images);
    let http_overhead = http_inproc_ips / http_loopback_ips;
    drop(http_server);
    drop(http_router);

    // per-op breakdowns: serial (clean attribution) and pooled (what the
    // serving path actually spends per op at the headline lane count)
    let prof_images = n_images.min(8);
    let mut prof = OpProfile::default();
    for img in flat.chunks_exact(per).take(prof_images) {
        let (_, p) = net.forward_profiled(img, &serial_pool).unwrap();
        prof.merge(&p);
    }
    let pooled_pool = LanePool::new(opts.lanes);
    let mut prof_pooled = OpProfile::default();
    for img in flat.chunks_exact(per).take(prof_images) {
        let (_, p) = net.forward_profiled(img, &pooled_pool).unwrap();
        prof_pooled.merge(&p);
    }
    // per-op under each kernel backend: where the SIMD time goes
    let mut prof_kscalar = OpProfile::default();
    let mut prof_ksimd = OpProfile::default();
    for img in flat.chunks_exact(per).take(prof_images) {
        let (_, p) = net.forward_profiled(img, &kpool_scalar).unwrap();
        prof_kscalar.merge(&p);
        let (_, p) = net.forward_profiled(img, &kpool_simd).unwrap();
        prof_ksimd.merge(&p);
    }
    let scale = 1.0 / prof_images as f64;
    let total = prof.total_ms().max(1e-12);

    println!("\n    scalar naive         {naive_ips:8.1} img/s");
    println!(
        "    fabric 1 lane        {serial_ips:8.1} img/s   ({:.2}x vs naive)",
        serial_ips / naive_ips
    );
    println!(
        "    spawn pool {:2} lanes  {spawn_ips:8.1} img/s   ({:.2}x vs naive)",
        opts.lanes,
        spawn_ips / naive_ips
    );
    println!(
        "    persistent {:2} lanes  {pooled_ips:8.1} img/s   ({:.2}x vs naive, {:.2}x vs spawn)",
        opts.lanes,
        pooled_ips / naive_ips,
        pooled_ips / spawn_ips
    );
    println!("    gemm microkernel     {gemm_dense_speedup:.2}x dense, {gemm_sparse_speedup:.2}x sparse (vs naive)");
    println!(
        "    kernels ({:<6})     {ksimd_ips:8.1} img/s vs scalar {kscalar_ips:8.1} \
         ({kernel_speedup:.2}x, 1 lane)",
        kern_simd.name
    );
    println!(
        "    pipeline {:2} stages  {pipeline_ips:8.1} img/s   ({:.2}x vs lane-parallel fabric)",
        pipe.stage_count(),
        pipeline_ips / pooled_ips
    );
    println!(
        "    telemetry            off {tele_off_ips:8.1} | on {tele_on_ips:8.1} img/s \
         (off/on ratio {tele_overhead:.3}, 1 lane)"
    );
    println!(
        "    http edge            inproc {http_inproc_ips:8.1} | loopback \
         {http_loopback_ips:8.1} img/s (inproc/loopback {http_overhead:.3}, \
         {http_batch} conns)"
    );
    println!("    lane sweep (persistent | spawn img/s):");
    for &(lanes, p, s) in &sweep {
        println!("      {lanes:2} lanes   {p:8.1} | {s:8.1}");
    }
    println!("    pipeline stage sweep (img/s):");
    for &(stages, ips) in &pipe_sweep {
        println!("      {stages:2} stages  {ips:8.1}");
    }
    println!(
        "    pipeline occupancy ({pipe_rounds} x {n_images} imgs): bubbles {} backpressure {}",
        pd.fill_drain_bubbles, pd.backpressure_stalls
    );
    for s in &pd.stages {
        println!(
            "      {:<8} blocks {:?}  occ {:5.1}%  empty {:5}  full {:5}",
            s.name,
            s.blocks,
            100.0 * s.busy_ms / pipe_wall_ms,
            s.stalls_empty,
            s.stalls_full,
        );
    }
    println!("    scale-out replica sweep (1 lane per replica):");
    for p in &replica_sweep {
        println!(
            "      {:2} replicas {:8.1} img/s   ({:.2}x vs 1 replica)",
            p.replicas,
            p.img_s,
            p.img_s / scale_base_ips
        );
    }
    if faults_enabled {
        println!(
            "    fault injection ON (HGPIPE_FAULTS): restarts={f_restarts} \
             retried={f_retried} shed={f_shed} expired={f_expired}"
        );
    }
    println!(
        "    partition busy max/min @ {} stages: near-even {:.1}x -> work-proportional {:.1}x \
         (PR-4 near-even @ {} stages: {:.1}x)",
        part_cmp[0].stages,
        part_cmp[0].max_min_ratio,
        part_cmp[2].max_min_ratio,
        part_cmp[1].stages,
        part_cmp[1].max_min_ratio
    );
    println!(
        "    artifact memory: {:.2} MiB shared across {mem_replicas} replicas \
         ({:.2} MiB unshared, {memory_savings:.1}x saved, {artifact_refs} refs)",
        artifact_footprint as f64 / (1024.0 * 1024.0),
        unshared_bytes as f64 / (1024.0 * 1024.0),
    );
    println!(
        "    per-op (1 lane): gemm {:.0}%  attention {:.0}%  layernorm {:.0}%  requant {:.0}%",
        100.0 * prof.gemm_ms / total,
        100.0 * prof.attention_ms / total,
        100.0 * prof.layernorm_ms / total,
        100.0 * prof.requant_ms / total,
    );

    if let Some(path) = &opts.json {
        let mut sweep_json = String::new();
        for (i, &(lanes, p, s)) in sweep.iter().enumerate() {
            let _ = write!(
                sweep_json,
                "{}\n    {{\"lanes\": {lanes}, \"persistent_img_s\": {p:.3}, \
                 \"spawn_img_s\": {s:.3}}}",
                if i == 0 { "" } else { "," },
            );
        }
        let mut pipe_sweep_json = String::new();
        for (i, &(stages, ips)) in pipe_sweep.iter().enumerate() {
            let _ = write!(
                pipe_sweep_json,
                "{}\n      {{\"stages\": {stages}, \"img_s\": {ips:.3}}}",
                if i == 0 { "" } else { "," },
            );
        }
        let mut per_stage_json = String::new();
        for (i, s) in pd.stages.iter().enumerate() {
            let _ = write!(
                per_stage_json,
                "{}\n      {{\"name\": \"{}\", \"blocks\": [{}, {}], \"lanes\": {}, \
                 \"images\": {}, \"busy_ms\": {:.3}, \"occupancy\": {:.4}, \
                 \"stalls_empty\": {}, \"stalls_full\": {}}}",
                if i == 0 { "" } else { "," },
                s.name,
                s.blocks.0,
                s.blocks.1,
                s.lanes,
                s.images,
                s.busy_ms,
                s.busy_ms / pipe_wall_ms,
                s.stalls_empty,
                s.stalls_full,
            );
        }
        let pipeline_json = format!(
            "{{\n    \"stages\": {},\n    \"queue_depth\": {queue_depth},\n    \
             \"lanes_per_stage\": {},\n    \"img_s\": {pipeline_ips:.3},\n    \
             \"speedup_vs_lane_parallel\": {:.3},\n    \
             \"window\": {{\"rounds\": {pipe_rounds}, \"images_per_round\": {n_images}, \
             \"wall_ms\": {pipe_wall_ms:.3}}},\n    \
             \"fill_drain_bubbles\": {},\n    \"backpressure_stalls\": {},\n    \
             \"stage_sweep\": [{pipe_sweep_json}\n    ],\n    \
             \"per_stage\": [{per_stage_json}\n    ]\n  }}",
            pipe.stage_count(),
            pipe.lanes_per_stage(),
            pipeline_ips / pooled_ips,
            pd.fill_drain_bubbles,
            pd.backpressure_stalls,
        );
        let mut replica_sweep_json = String::new();
        for (i, p) in replica_sweep.iter().enumerate() {
            let mut pr = String::new();
            for (j, &(images, exec_ms, occ)) in p.per_replica.iter().enumerate() {
                let _ = write!(
                    pr,
                    "{}{{\"images\": {images}, \"exec_ms\": {exec_ms:.3}, \
                     \"occupancy\": {occ:.4}}}",
                    if j == 0 { "" } else { ", " },
                );
            }
            let _ = write!(
                replica_sweep_json,
                "{}\n      {{\"replicas\": {}, \"img_s\": {:.3}, \"speedup_vs_1\": {:.3}, \
                 \"per_replica\": [{pr}]}}",
                if i == 0 { "" } else { "," },
                p.replicas,
                p.img_s,
                p.img_s / scale_base_ips,
            );
        }
        let partition_entry = |p: &PartitionPoint| -> String {
            let mut busy = String::new();
            for (i, b) in p.busy_ms.iter().enumerate() {
                let _ = write!(busy, "{}{b:.3}", if i == 0 { "" } else { ", " });
            }
            format!(
                "{{\"stages\": {}, \"img_s\": {:.3}, \"per_stage_busy_ms\": [{busy}], \
                 \"max_min_busy_ratio\": {:.3}}}",
                p.stages, p.img_s, p.max_min_ratio,
            )
        };
        let scale_out_json = format!(
            "{{\n    \"replica_sweep\": [{replica_sweep_json}\n    ],\n    \
             \"partition\": {{\n      \"stages\": {},\n      \
             \"near_even\": {},\n      \"near_even_pr4\": {},\n      \
             \"work_proportional\": {}\n    }}\n  }}",
            part_cmp[0].stages,
            partition_entry(&part_cmp[0]),
            partition_entry(&part_cmp[1]),
            partition_entry(&part_cmp[2]),
        );
        let per_op = |p: &OpProfile| {
            format!(
                "{{\n    \"quantize\": {:.4},\n    \"gemm\": {:.4},\n    \
                 \"layernorm\": {:.4},\n    \"attention\": {:.4},\n    \
                 \"requant\": {:.4},\n    \"head\": {:.4}\n  }}",
                p.quantize_ms * scale,
                p.gemm_ms * scale,
                p.layernorm_ms * scale,
                p.attention_ms * scale,
                p.requant_ms * scale,
                p.head_ms * scale,
            )
        };
        let kernels_json = format!(
            "{{\n    \"detected\": \"{}\",\n    \"scalar_img_s\": {kscalar_ips:.3},\n    \
             \"simd_img_s\": {ksimd_ips:.3},\n    \"speedup\": {kernel_speedup:.3},\n    \
             \"per_op_scalar_ms_per_image\": {},\n    \
             \"per_op_simd_ms_per_image\": {}\n  }}",
            kern_simd.name,
            per_op(&prof_kscalar),
            per_op(&prof_ksimd),
        );
        let json = format!(
            "{{\n  \"model\": \"tiny-synth\",\n  \"smoke\": {},\n  \"images\": {},\n  \
             \"lanes\": {},\n  \"scalar_naive_img_s\": {:.3},\n  \
             \"fabric_serial_img_s\": {:.3},\n  \"spawn_pooled_img_s\": {:.3},\n  \
             \"fabric_pooled_img_s\": {:.3},\n  \
             \"speedup_pooled_vs_naive\": {:.3},\n  \"speedup_pooled_vs_serial\": {:.3},\n  \
             \"speedup_persistent_vs_spawn\": {:.3},\n  \
             \"gemm_microkernel\": {{\"shape\": [{}, {}, {}], \
             \"dense_speedup_vs_naive\": {:.3}, \"sparse_speedup_vs_naive\": {:.3}}},\n  \
             \"lane_sweep\": [{}\n  ],\n  \
             \"pipeline\": {},\n  \
             \"scale_out\": {},\n  \
             \"kernels\": {},\n  \
             \"memory\": {{\n    \"artifact_footprint_bytes\": {artifact_footprint},\n    \
             \"replicas\": {mem_replicas},\n    \
             \"unshared_bytes\": {unshared_bytes},\n    \
             \"shared_bytes\": {artifact_footprint},\n    \
             \"savings_ratio\": {memory_savings:.3},\n    \
             \"artifact_refs\": {artifact_refs}\n  }},\n  \
             \"faults\": {{\n    \"enabled\": {faults_enabled},\n    \
             \"restarts\": {f_restarts},\n    \"retried\": {f_retried},\n    \
             \"shed\": {f_shed},\n    \"expired\": {f_expired}\n  }},\n  \
             \"telemetry\": {{\n    \"tracing_off_img_s\": {tele_off_ips:.3},\n    \
             \"tracing_on_img_s\": {tele_on_ips:.3},\n    \
             \"overhead_ratio\": {tele_overhead:.3}\n  }},\n  \
             \"http\": {{\n    \"inproc_img_s\": {http_inproc_ips:.3},\n    \
             \"loopback_img_s\": {http_loopback_ips:.3},\n    \
             \"overhead_ratio\": {http_overhead:.3},\n    \
             \"connections\": {http_batch},\n    \
             \"requests\": {http_requests}\n  }},\n  \
             \"per_op_ms_per_image\": {},\n  \
             \"per_op_pooled_ms_per_image\": {}\n}}\n",
            opts.smoke,
            n_images,
            opts.lanes,
            naive_ips,
            serial_ips,
            spawn_ips,
            pooled_ips,
            pooled_ips / naive_ips,
            pooled_ips / serial_ips,
            pooled_ips / spawn_ips,
            gt,
            gci,
            gco,
            gemm_dense_speedup,
            gemm_sparse_speedup,
            sweep_json,
            pipeline_json,
            scale_out_json,
            kernels_json,
            per_op(&prof),
            per_op(&prof_pooled),
        );
        std::fs::write(path, &json).expect("write bench json");
        println!("\nwrote {path}");
    }
}

/// Drain exactly one HTTP/1.1 response (which must be a 200) so the
/// bench connection can be reused for its next request.
fn read_http_reply(stream: &mut std::net::TcpStream) {
    use std::io::Read as _;
    let mut buf = Vec::new();
    let head_end = loop {
        if let Some(p) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break p;
        }
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk).expect("http reply head");
        assert!(n > 0, "server closed mid-reply");
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end]).expect("ascii reply head");
    assert!(head.starts_with("HTTP/1.1 200"), "bench expects 200s, got: {head}");
    let len: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("content-length").then(|| v.trim().parse().ok())?
        })
        .expect("content-length in reply");
    let mut have = buf.len() - (head_end + 4);
    while have < len {
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk).expect("http reply body");
        assert!(n > 0, "server closed mid-body");
        have += n;
    }
}
