//! Bench + regeneration of Figure 1 (roofline model).
//!
//! Prints the same series the paper plots (design point -> achievable
//! TOP/s) and times the model evaluation.

use std::time::Duration;

use hgpipe::arch::parallelism::design_network;
use hgpipe::model::{Precision, ViTConfig};
use hgpipe::platform::Fpga;
use hgpipe::roofline::fig1;
use hgpipe::util::bench::{bench, black_box};

fn main() {
    println!("=== Figure 1: roofline model (VCK190, DeiT-tiny) ===\n");
    let cfg = ViTConfig::deit_tiny();
    let design = design_network(&cfg, Precision::A4W4, 2);
    let fpga = Fpga::vck190();

    let points = fig1(&design, &cfg, &fpga);
    println!(
        "{:<34} {:>10} {:>12} {:>14} {:>12}",
        "design point", "ops/byte", "roof TOP/s", "achiev. TOP/s", "paper TOP/s"
    );
    for p in &points {
        println!(
            "{:<34} {:>10.1} {:>12.2} {:>14.2} {:>12.1}",
            p.label,
            p.intensity,
            p.compute_roof / 1e12,
            p.achievable / 1e12,
            p.paper_tops
        );
    }

    println!("\n--- timing ---");
    let r = bench("fig1 roofline evaluation", Duration::from_millis(300), || {
        black_box(fig1(&design, &cfg, &fpga));
    });
    println!("{r}");
}
