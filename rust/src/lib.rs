//! # HG-PIPE — hybrid-grained pipelined ViT acceleration, reproduced
//!
//! This crate is the Layer-3 (rust) side of a three-layer reproduction of
//! *HG-PIPE: Vision Transformer Acceleration with Hybrid-Grained Pipeline*
//! (Guo et al., 2024). The paper's system is an FPGA accelerator; since the
//! hardware itself is the contribution, this crate contains:
//!
//! * [`model`] — the ViT workload IR (modules, shapes, op counts),
//! * [`quant`] / [`lut`] — the paper's quantization + LUT approximation
//!   stack (Sec. 4.4), bit-exact mirror of the python table generators,
//! * [`platform`] — FPGA/GPU device resource models (ZCU102, VCK190, V100),
//! * [`arch`] — the parallelism designer (Table 1: TP/CIP/COP, II, BRAM η),
//! * [`sim`] — a cycle-accurate simulator of the hybrid-grained pipeline
//!   (deep buffers + deep FIFOs + decentralized FSM stages, Sec. 4.2),
//! * [`paradigms`] — temporal / coarse / fine / hybrid baselines (Fig. 2),
//! * [`roofline`] — the Fig. 1 roofline model,
//! * [`metrics`] / [`report`] — Table 2 & figure regeneration,
//! * [`runtime`] — PJRT execution of the AOT-compiled quantized ViT
//!   (`artifacts/*.hlo.txt`, produced by `python/compile/aot.py`),
//! * [`coordinator`] — the serving loop: request router, dynamic batcher,
//!   pipelined execution with per-stage metrics.
//!
//! Python never runs on the request path: `make artifacts` runs once, and
//! the `hgpipe` binary is self-contained afterwards.

pub mod arch;
pub mod artifacts;
pub mod coordinator;
pub mod lut;
pub mod metrics;
pub mod model;
pub mod paradigms;
pub mod platform;
pub mod quant;
pub mod report;
pub mod roofline;
pub mod runtime;
pub mod sim;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
