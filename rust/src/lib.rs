//! # HG-PIPE — hybrid-grained pipelined ViT acceleration, reproduced
//!
//! This crate is the Layer-3 (rust) side of a three-layer reproduction of
//! *HG-PIPE: Vision Transformer Acceleration with Hybrid-Grained Pipeline*
//! (Guo et al., 2024). The paper's system is an FPGA accelerator; since the
//! hardware itself is the contribution, this crate contains:
//!
//! * [`model`] — the ViT workload IR (modules, shapes, op counts),
//! * [`quant`] / [`lut`] — the paper's quantization + LUT approximation
//!   stack (Sec. 4.4), bit-exact mirror of the python table generators,
//! * [`platform`] — FPGA/GPU device resource models (ZCU102, VCK190, V100),
//! * [`arch`] — the parallelism designer (Table 1: TP/CIP/COP, II, BRAM η),
//! * [`sim`] — a cycle-accurate simulator of the hybrid-grained pipeline
//!   (deep buffers + deep FIFOs + decentralized FSM stages, Sec. 4.2),
//! * [`paradigms`] — temporal / coarse / fine / hybrid baselines (Fig. 2),
//! * [`roofline`] — the Fig. 1 roofline model,
//! * [`metrics`] / [`report`] — Table 2 & figure regeneration,
//! * [`runtime`] — pluggable execution backends for the quantized ViT,
//! * [`coordinator`] — the serving loop: request router, dynamic batcher,
//!   pipelined execution with per-stage metrics, generic over the backend.
//!
//! ## Execution backend matrix
//!
//! | backend | build | model source | notes |
//! |---|---|---|---|
//! | `runtime::interpreter` | default | weight/LUT bundle JSON (`python -m compile.export`) | pure rust, zero native deps; bit-exact with the python integer reference; the committed golden fixture in `rust/artifacts/` makes `cargo test` self-contained |
//! | `runtime::pjrt` | `--features pjrt` | HLO text (`python/compile/aot.py`, via `make artifacts`) | XLA CPU client; the `xla` dependency resolves to the in-repo stub (`rust/xla-stub`) which type-checks the integration — swap in a real binding to execute |
//!
//! ## Interpreter fabric & `HGPIPE_LANES`
//!
//! The interpreter executes on [`runtime::fabric`]: weight matrices are
//! re-packed into blocked GEMM panels at bundle load, and a
//! [`runtime::fabric::LanePool`] of `std::thread` workers parallelizes
//! either whole batch lanes (one image per worker, when a dispatch
//! carries at least as many images as lanes) or token-row bands inside a
//! single image. The lane count is read from the **`HGPIPE_LANES`**
//! environment variable when a model loads (the `hgpipe serve`/`eval`
//! `--lanes N` flag sets it); unset, it defaults to the machine's
//! available parallelism. `HGPIPE_LANES=1` forces fully serial
//! execution. Results are bit-identical at every lane count — `cargo
//! test` pins lane counts 1, 2 and 7 against the golden fixture — and
//! `make bench-json` reports scalar-vs-pooled throughput plus a per-op
//! breakdown into `BENCH_interpreter.json`.
//!
//! Python never runs on the request path: the build pipeline (`make
//! artifacts` for the full set, `make golden` for the interpreter
//! fixture) runs once, and the `hgpipe` binary is self-contained
//! afterwards — `hgpipe serve`/`eval` work out of a clean checkout on the
//! interpreter backend.

pub mod arch;
pub mod artifacts;
pub mod coordinator;
pub mod lut;
pub mod metrics;
pub mod model;
pub mod paradigms;
pub mod platform;
pub mod quant;
pub mod report;
pub mod roofline;
pub mod runtime;
pub mod sim;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
