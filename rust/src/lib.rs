//! # HG-PIPE — hybrid-grained pipelined ViT acceleration, reproduced
//!
//! This crate is the Layer-3 (rust) side of a three-layer reproduction of
//! *HG-PIPE: Vision Transformer Acceleration with Hybrid-Grained Pipeline*
//! (Guo et al., 2024). The paper's system is an FPGA accelerator; since the
//! hardware itself is the contribution, this crate contains:
//!
//! * [`model`] — the ViT workload IR (modules, shapes, op counts),
//! * [`quant`] / [`lut`] — the paper's quantization + LUT approximation
//!   stack (Sec. 4.4), bit-exact mirror of the python table generators,
//! * [`platform`] — FPGA/GPU device resource models (ZCU102, VCK190, V100),
//! * [`arch`] — the parallelism designer (Table 1: TP/CIP/COP, II, BRAM η),
//! * [`sim`] — a cycle-accurate simulator of the hybrid-grained pipeline
//!   (deep buffers + deep FIFOs + decentralized FSM stages, Sec. 4.2),
//! * [`paradigms`] — temporal / coarse / fine / hybrid baselines (Fig. 2),
//! * [`roofline`] — the Fig. 1 roofline model,
//! * [`metrics`] / [`report`] — Table 2 & figure regeneration,
//! * [`runtime`] — pluggable execution backends for the quantized ViT,
//! * [`coordinator`] — the serving loop: request router, dynamic batcher,
//!   pipelined execution with per-stage metrics, generic over the backend,
//! * [`server`] — the network front door: a dependency-free HTTP/1.1
//!   edge (`hgpipe serve --http ADDR` / `HGPIPE_HTTP`) mapping
//!   `POST /v1/models/{name}/infer`, `GET /metrics` and `GET /healthz`
//!   onto the router with typed-error → status-code downcasts,
//! * [`telemetry`] — zero-cost-when-off tracing: per-request span trees
//!   (admission, queue wait, dispatch, stage residency, stalls, per-op
//!   kernel timings) recorded into per-thread ring buffers and written
//!   as Chrome-trace JSONL (`--trace` / `HGPIPE_TRACE`), plus the
//!   always-on `Router::prometheus_text()` exposition.
//!
//! ## Execution backend matrix
//!
//! | backend | build | model source | notes |
//! |---|---|---|---|
//! | `runtime::interpreter` | default | weight/LUT bundle JSON (`python -m compile.export`) | pure rust, zero native deps; bit-exact with the python integer reference; the committed golden fixture in `rust/artifacts/` makes `cargo test` self-contained |
//! | `runtime::pjrt` | `--features pjrt` | HLO text (`python/compile/aot.py`, via `make artifacts`) | XLA CPU client; the `xla` dependency resolves to the in-repo stub (`rust/xla-stub`) which type-checks the integration — swap in a real binding to execute |
//!
//! ## Interpreter execution modes, fabric & lane count
//!
//! The interpreter has three execution modes (all bit-identical):
//! **scalar** (the `*_naive` oracle kernels), **lane-parallel**
//! (temporal — default), and **pipeline** (spatial — the paper's
//! architecture, [`runtime::pipeline`]): the model unrolled into
//! resident stages connected by bounded SPSC queues, selected via
//! [`runtime::ExecMode`], `--pipeline [--stages N] [--queue-depth N]`,
//! or `HGPIPE_MODE=pipeline`.
//!
//! Temporal execution runs on [`runtime::fabric`]: weight matrices are
//! re-packed into blocked GEMM panels at bundle load (with a 4-row ×
//! 8-wide register-blocked microkernel and a per-row activation-density
//! fallback to the zero-skip path), the elementwise requant LUT passes
//! are fused into the GEMM band that produces them, and a
//! [`runtime::fabric::LanePool`] of **persistent parked workers** —
//! created once per loaded model, joined deterministically on unload —
//! parallelizes either whole batch lanes (one image per worker, when a
//! dispatch carries at least as many images as lanes) or token-row bands
//! inside a single image. Every intermediate buffer comes from the
//! pool's scratch arena, so steady-state serving performs no per-image
//! heap allocation in GEMM/attention scratch; a fully-serial forward
//! runs lock-free in a single scratch box.
//!
//! Spatial execution slices the encoder **work-proportionally**: a
//! per-segment GEMM-MAC cost model picks the contiguous block partition
//! with the smallest bottleneck stage, dedicating a resident stage to
//! patch-embed when that evens occupancy out (fully unrolled =
//! `depth + 1` stages). And one model can scale **out**: `--replicas N`
//! (env fallback `HGPIPE_REPLICAS`) runs N executor replicas per
//! [`coordinator::ModelServer`], pulling from one shared MPMC front
//! queue, each replica owning its own fabric or resident pipeline, with
//! per-replica metrics rolled up without double counting.
//!
//! Lane-count precedence: the `hgpipe serve`/`eval` **`--lanes N`** flag
//! (threaded explicitly via [`runtime::RuntimeConfig`] — the binary
//! never mutates its environment), then the **`HGPIPE_LANES`** env var
//! (read-only fallback), then the machine's available parallelism.
//! `--lanes 1` / `HGPIPE_LANES=1` forces fully serial execution. The
//! execution mode and replica count resolve the same way (`--pipeline` /
//! `--replicas`, then `HGPIPE_MODE` / `HGPIPE_REPLICAS`). Results are
//! bit-identical at every lane count, stage count, queue depth and
//! replica count — `cargo test` pins lane counts 1, 2, 7 and 16, stage
//! counts 1, 2, 4 and max, and replica counts 1, 2 and 4 against the
//! golden fixture — and `make bench-json` reports scalar / spawn-pool /
//! persistent-pool / pipeline throughput, lane-, stage- and
//! replica-scaling sweeps, per-stage occupancy + bubble counts and
//! per-op breakdowns into `BENCH_interpreter.json` (`make bench-check`
//! gates CI on it against `BENCH_baseline.json`).
//!
//! Python never runs on the request path: the build pipeline (`make
//! artifacts` for the full set, `make golden` for the interpreter
//! fixture) runs once, and the `hgpipe` binary is self-contained
//! afterwards — `hgpipe serve`/`eval` work out of a clean checkout on the
//! interpreter backend.

// This crate is index-heavy numeric code mirroring numpy semantics; the
// explicit-index loops are deliberate (they state the accumulation order
// the bit-exactness contract is defined over), so the iterator-style
// pedantic lints are opted out crate-wide rather than per-loop.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod arch;
pub mod artifacts;
pub mod coordinator;
pub mod lut;
pub mod metrics;
pub mod model;
pub mod paradigms;
pub mod platform;
pub mod quant;
pub mod report;
pub mod roofline;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod telemetry;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
