//! ViT workload IR: network configurations, per-module shapes, op counts.
//!
//! This mirrors `python/compile/model.ViTConfig` and expands a network
//! into the *module list* the accelerator instantiates (Table 1): every
//! block becomes LayerNorm / StMM / DyMM / Softmax / GeLU / Residual
//! modules with concrete (T, CI, CO) shapes.



/// Network architecture configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ViTConfig {
    pub name: String,
    pub img_size: usize,
    pub patch: usize,
    pub dim: usize,
    pub depth: usize,
    pub heads: usize,
    pub mlp_ratio: usize,
    pub num_classes: usize,
}

impl ViTConfig {
    pub fn deit_tiny() -> Self {
        Self {
            name: "deit-tiny".into(),
            img_size: 224,
            patch: 16,
            dim: 192,
            depth: 12,
            heads: 3,
            mlp_ratio: 4,
            num_classes: 1000,
        }
    }

    pub fn deit_small() -> Self {
        Self { name: "deit-small".into(), dim: 384, heads: 6, ..Self::deit_tiny() }
    }

    pub fn tiny_synth() -> Self {
        Self {
            name: "tiny-synth".into(),
            img_size: 32,
            patch: 8,
            dim: 64,
            depth: 4,
            heads: 2,
            mlp_ratio: 4,
            num_classes: 10,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "deit-tiny" => Some(Self::deit_tiny()),
            "deit-small" => Some(Self::deit_small()),
            "tiny-synth" => Some(Self::tiny_synth()),
            _ => None,
        }
    }

    pub fn tokens(&self) -> usize {
        (self.img_size / self.patch).pow(2)
    }

    pub fn patch_dim(&self) -> usize {
        self.patch * self.patch * 3
    }

    pub fn head_dim(&self) -> usize {
        self.dim / self.heads
    }

    pub fn hidden(&self) -> usize {
        self.dim * self.mlp_ratio
    }

    /// Total op count per inference (2 ops per MAC) — paper "OPs/inf".
    pub fn ops_per_inference(&self) -> u64 {
        let (t, d, h) = (self.tokens() as u64, self.dim as u64, self.hidden() as u64);
        let per_block = 2 * t * d * (3 * d)   // QKV Gen
            + 2 * t * t * d * 2               // QK + RV
            + 2 * t * d * d                   // Output Proj
            + 2 * t * d * h * 2; // MatMul1 + MatMul2
        self.depth as u64 * per_block
            + 2 * t * (self.patch_dim() as u64) * d
            + 2 * d * self.num_classes as u64
    }

    /// Parameter count.
    pub fn param_count(&self) -> u64 {
        let d = self.dim as u64;
        let h = self.hidden() as u64;
        let per_block = d * 3 * d + 3 * d   // qkv
            + d * d + d                      // proj
            + d * h + h + h * d + d          // mlp
            + 4 * d; // ln gammas/betas
        self.depth as u64 * per_block
            + (self.patch_dim() as u64) * d + d
            + d * self.num_classes as u64 + self.num_classes as u64
            + 2 * d
    }

    /// Expand into the accelerator's module list (all blocks).
    pub fn modules(&self) -> Vec<ModuleSpec> {
        let mut v = Vec::new();
        let t = self.tokens();
        let d = self.dim;
        let dh = self.head_dim();
        let hid = self.hidden();
        v.push(ModuleSpec::st_mm("PatchEmbed", t, self.patch_dim(), d, 1));
        for blk in 0..self.depth {
            let p = |n: &str| format!("b{blk}.{n}");
            v.push(ModuleSpec::elementwise(&p("LayerNorm1"), t, d, 3));
            // one QKV Gen instance per head per projection (9 for 3 heads)
            for inst in 0..(3 * self.heads) {
                v.push(ModuleSpec::st_mm(&p(&format!("QKVGen{inst}")), t, d, dh, 1));
            }
            for hh in 0..self.heads {
                v.push(ModuleSpec::dy_mm(&p(&format!("QKMatMul{hh}")), t, dh, t));
            }
            v.push(ModuleSpec::softmax(&p("Softmax"), t, t));
            for hh in 0..self.heads {
                v.push(ModuleSpec::dy_mm(&p(&format!("RVMatMul{hh}")), t, t, dh));
            }
            v.push(ModuleSpec::st_mm(&p("OutputProj"), t, d, d, 1));
            v.push(ModuleSpec::residual(&p("ResidualAdd1"), t, d));
            v.push(ModuleSpec::elementwise(&p("LayerNorm2"), t, d, 3));
            v.push(ModuleSpec::st_mm(&p("MatMul1"), t, d, hid, 1));
            v.push(ModuleSpec::gelu(&p("GeLU"), t, hid));
            v.push(ModuleSpec::st_mm(&p("MatMul2"), t, hid, d, 1));
            v.push(ModuleSpec::residual(&p("ResidualAdd2"), t, d));
        }
        v.push(ModuleSpec::elementwise("LayerNormF", t, d, 3));
        v.push(ModuleSpec::st_mm("Head", 1, d, self.num_classes, 1));
        v
    }
}

/// Operator class of a pipeline module.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModuleKind {
    /// MM with static (ROM-frozen) weights.
    StMM,
    /// MM with dynamic weights streamed from a deep buffer (QK^T, R*V).
    DyMM,
    /// LayerNorm (3 passes) or other elementwise reduction.
    Elementwise,
    /// Softmax (3 passes + exp/recip tables).
    Softmax,
    /// GeLU (fused GeLU-ReQuant table).
    Gelu,
    /// Residual add.
    Residual,
}

/// One accelerator module with concrete shapes (a Table 1 row).
#[derive(Debug, Clone, PartialEq)]
pub struct ModuleSpec {
    pub name: String,
    pub kind: ModuleKind,
    /// Tokens processed per image.
    pub t: usize,
    /// Input channels.
    pub ci: usize,
    /// Output channels (MM only; elementwise: co == ci).
    pub co: usize,
    /// Passes over the data per token (LayerNorm/Softmax: 3).
    pub passes: usize,
}

impl ModuleSpec {
    pub fn st_mm(name: &str, t: usize, ci: usize, co: usize, _inst: usize) -> Self {
        Self { name: name.into(), kind: ModuleKind::StMM, t, ci, co, passes: 1 }
    }

    pub fn dy_mm(name: &str, t: usize, ci: usize, co: usize) -> Self {
        Self { name: name.into(), kind: ModuleKind::DyMM, t, ci, co, passes: 1 }
    }

    pub fn elementwise(name: &str, t: usize, ci: usize, passes: usize) -> Self {
        Self { name: name.into(), kind: ModuleKind::Elementwise, t, ci, co: ci, passes }
    }

    pub fn softmax(name: &str, t: usize, ci: usize) -> Self {
        Self { name: name.into(), kind: ModuleKind::Softmax, t, ci, co: ci, passes: 3 }
    }

    pub fn gelu(name: &str, t: usize, ci: usize) -> Self {
        Self { name: name.into(), kind: ModuleKind::Gelu, t, ci, co: ci, passes: 1 }
    }

    pub fn residual(name: &str, t: usize, ci: usize) -> Self {
        Self { name: name.into(), kind: ModuleKind::Residual, t, ci, co: ci, passes: 1 }
    }

    pub fn is_mm(&self) -> bool {
        matches!(self.kind, ModuleKind::StMM | ModuleKind::DyMM)
    }

    /// MACs per image for MMs; elementwise ops for the rest (paper MOPs).
    pub fn ops(&self) -> u64 {
        if self.is_mm() {
            (self.t * self.ci * self.co) as u64
        } else {
            (self.t * self.ci * self.passes.max(1)) as u64
        }
    }

    /// Static weight bits stored on chip (StMM only).
    pub fn weight_count(&self) -> u64 {
        if self.kind == ModuleKind::StMM { (self.ci * self.co) as u64 } else { 0 }
    }
}

/// Quantization precision of a deployment (paper "A4W4" notation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Precision {
    pub act_bits: u32,
    pub weight_bits: u32,
}

impl Precision {
    pub const A8W8: Self = Self { act_bits: 8, weight_bits: 8 };
    pub const A4W4: Self = Self { act_bits: 4, weight_bits: 4 };
    /// Table-1 configuration: 4-bit activations, 3-bit static weights.
    pub const A4W3: Self = Self { act_bits: 4, weight_bits: 3 };
    pub const A3W3: Self = Self { act_bits: 3, weight_bits: 3 };

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "a8w8" => Some(Self::A8W8),
            "a4w4" => Some(Self::A4W4),
            "a4w3" => Some(Self::A4W3),
            "a3w3" => Some(Self::A3W3),
            _ => None,
        }
    }

    pub fn label(&self) -> String {
        format!("A{}W{}", self.act_bits, self.weight_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deit_tiny_matches_paper() {
        let c = ViTConfig::deit_tiny();
        assert_eq!(c.tokens(), 196);
        assert_eq!(c.head_dim(), 64);
        assert_eq!(c.hidden(), 768);
        // Table 2: 2.5 GOPs, 5.5 M params
        let ops = c.ops_per_inference();
        assert!((2_300_000_000..2_700_000_000).contains(&ops), "{ops}");
        let p = c.param_count();
        assert!((5_200_000..5_800_000).contains(&p), "{p}");
    }

    #[test]
    fn deit_small_matches_paper() {
        let c = ViTConfig::deit_small();
        let ops = c.ops_per_inference();
        assert!((8_500_000_000..10_000_000_000).contains(&ops), "{ops}");
        let p = c.param_count();
        assert!((21_000_000..23_000_000).contains(&p), "{p}");
    }

    #[test]
    fn module_expansion_counts() {
        let c = ViTConfig::deit_tiny();
        let mods = c.modules();
        // per block: 2 LN + 9 QKV + 3 QK + 1 SM + 3 RV + proj + 2 res +
        // mm1 + gelu + mm2 = 24; + PE + LNf + Head
        assert_eq!(mods.len(), 12 * 24 + 3);
        // paper MOPs check (Table 1): QKV Gen instance = 2.41 M MACs
        let qkv = mods.iter().find(|m| m.name == "b0.QKVGen0").unwrap();
        assert_eq!(qkv.ops(), 196 * 192 * 64);
        let mm1 = mods.iter().find(|m| m.name == "b0.MatMul1").unwrap();
        assert_eq!(mm1.ops(), 196 * 192 * 768); // 28.9 M
    }

    #[test]
    fn total_mops_consistent_with_ops_per_inference() {
        let c = ViTConfig::deit_tiny();
        let mm_macs: u64 = c.modules().iter().filter(|m| m.is_mm()).map(|m| m.ops()).sum();
        let diff = (2 * mm_macs) as i64 - c.ops_per_inference() as i64;
        // ops_per_inference uses dim*classes for the pooled head; module
        // expansion matches within the head contribution
        assert!(diff.abs() < 1_000_000, "{diff}");
    }

    #[test]
    fn precision_parse_roundtrip() {
        assert_eq!(Precision::parse("a4w4"), Some(Precision::A4W4));
        assert_eq!(Precision::parse("A3W3"), Some(Precision::A3W3));
        assert_eq!(Precision::A4W3.label(), "A4W3");
        assert_eq!(Precision::parse("a2w2"), None);
    }
}
