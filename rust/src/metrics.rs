//! Table 2 assembly: throughput / resource / power metrics for HG-PIPE
//! deployments and the prior-art comparators.
//!
//! Our rows are **computed**: the parallelism design fixes the stable II
//! (validated cycle-accurately by `sim`), the LUT/DSP/BRAM models decide
//! how much of the design fits a platform (scaling parallelism by powers
//! of two exactly like the paper halves/quarters the deployment on
//! LUT-starved devices), and a calibrated linear power model gives W.
//! Prior-art rows are the numbers those papers report (documented
//! constants), used only as comparison targets.

use crate::arch::dsp::{dsp_ladder, inventory};
use crate::arch::parallelism::{design_network, Design};
use crate::lut::cost::{self, lut_mac_cost};
use crate::model::{Precision, ViTConfig};
use crate::paradigms::{activation_buffer_brams, ParadigmKind};
use crate::platform::Fpga;

/// Empirical control/interconnect overhead on top of datapath LUTs
/// (FSMs, AXI-Stream handshakes, routing margin) — calibrated so the
/// full DeiT-tiny A3W3 deployment lands at the paper's 669k LUTs.
pub const CONTROL_OVERHEAD: f64 = 2.2;
/// Usable fraction of a device's LUTs before timing collapses.
pub const FIT_FRAC: f64 = 0.95;
/// Measured-to-ideal throughput ratio (the paper reports 7118/7353 =
/// 96.8% on the VCK190; host-side feeding overhead).
pub const MEASURED_RATIO: f64 = 0.968;

/// One Table-2 column.
#[derive(Debug, Clone)]
pub struct AcceleratorRow {
    pub name: String,
    pub paradigm: &'static str,
    pub platform: String,
    pub freq_mhz: f64,
    pub network: String,
    pub gops_per_inf: f64,
    pub precision: String,
    pub fps: f64,
    pub gops: f64,
    pub luts_k: f64,
    pub dsps: u64,
    pub brams: f64,
    pub power_w: f64,
    pub is_ours: bool,
    /// Parallelism/partition scale applied to fit the device (1 = full).
    pub scale: u64,
}

impl AcceleratorRow {
    pub fn gops_per_klut(&self) -> f64 {
        self.gops / self.luts_k
    }

    /// Normalized GOPs/DSP (Table 2 footnote 7: 1 DSP = 32 LUTs).
    pub fn gops_per_dsp_norm(&self) -> f64 {
        self.gops / (self.dsps as f64 + self.luts_k * 1000.0 / 32.0)
    }

    pub fn gops_per_w(&self) -> f64 {
        self.gops / self.power_w
    }
}

/// Datapath LUT demand of a design (MAC units + non-linear tables).
pub fn datapath_luts(design: &Design) -> u64 {
    let inv = inventory(design);
    let mac_bits = design.precision.act_bits.max(design.precision.weight_bits);
    let macs = inv.mac_units * lut_mac_cost(mac_bits);
    let tables = inv.exp * cost::table_cost(64, 8, 24).lut6
        + inv.recip * cost::segmented_cost(64, 8, 16).lut6
        + inv.rsqrt * cost::table_cost(64, 12, 22).lut6
        + inv.gelu * cost::table_cost(64, 3, 24).lut6
        + inv.requant * cost::table_cost(64, design.precision.act_bits, 0).lut6;
    macs + tables
}

/// Linear power model calibrated on the paper's four measured deployments.
pub fn power_model(luts: f64, freq_hz: f64) -> f64 {
    10.0 + luts * freq_hz * 1.3e-13
}

/// Deploy a network design onto a platform: scale parallelism by powers
/// of two until the LUT demand fits, exactly as the paper halves the
/// VCK190 A4W4 deployment and quarters the ZCU102 one (footnote 3).
pub fn deploy(cfg: &ViTConfig, prec: Precision, fpga: &Fpga, freq_hz: f64) -> AcceleratorRow {
    let design = design_network(cfg, prec, 2);
    let full_luts = datapath_luts(&design) as f64 * CONTROL_OVERHEAD;
    let budget = fpga.luts as f64 * FIT_FRAC;
    let mut scale = 1u64;
    while full_luts / scale as f64 > budget {
        scale *= 2;
        assert!(scale <= 64, "design cannot fit {} at any scale", fpga.name);
    }
    let luts = full_luts / scale as f64;
    let ii = design.accelerator_ii() * scale;
    let fps = freq_hz / ii as f64 * MEASURED_RATIO;
    let ops_g = cfg.ops_per_inference() as f64 / 1e9;

    // DSPs: the post-LUT-optimization residual multipliers (Fig. 11a step
    // 3), scaled with the deployed parallelism fraction
    let dsps = dsp_ladder(&design).last().unwrap().dsps / scale;

    // BRAMs: frozen weights + hybrid activation buffers, scaled
    let weight_brams = design.total_brams();
    let act_brams = activation_buffer_brams(&design, cfg, ParadigmKind::HybridGrained);
    let brams = (weight_brams + act_brams) as f64 / scale as f64;

    let power = power_model(luts, freq_hz);
    AcceleratorRow {
        name: format!("HG-PIPE ({})", fpga.name),
        paradigm: "Hybrid-Grained Pipeline",
        platform: fpga.name.clone(),
        freq_mhz: freq_hz / 1e6,
        network: cfg.name.clone(),
        gops_per_inf: ops_g,
        precision: prec.label(),
        fps,
        gops: fps * ops_g,
        luts_k: luts / 1e3,
        dsps,
        brams,
        power_w: power,
        is_ours: true,
        scale,
    }
}

/// The paper's Table 2 prior-art comparators (reported constants).
pub fn prior_art() -> Vec<AcceleratorRow> {
    let row = |name: &str,
               paradigm: &'static str,
               platform: &str,
               freq: f64,
               network: &str,
               ops_g: f64,
               precision: &str,
               fps: f64,
               gops: f64,
               luts_k: f64,
               dsps: u64,
               brams: f64,
               power_w: f64| AcceleratorRow {
        name: name.into(),
        paradigm,
        platform: platform.into(),
        freq_mhz: freq,
        network: network.into(),
        gops_per_inf: ops_g,
        precision: precision.into(),
        fps,
        gops,
        luts_k,
        dsps,
        brams,
        power_w,
        is_ours: false,
        scale: 1,
    };
    vec![
        row(
            "Deit GPU baseline",
            "GPU",
            "V100",
            1455.0,
            "deit-tiny",
            2.5,
            "fp32",
            2529.0,
            6322.5,
            f64::NAN,
            0,
            f64::NAN,
            250.0,
        ),
        row(
            "TCAS-I 2023",
            "GeMM",
            "ZCU102",
            300.0,
            "vit-tiny",
            2.5,
            "A8W8",
            245.0,
            762.7,
            114.0,
            1268,
            648.0,
            29.6,
        ),
        row(
            "AutoViTAcc (FPL22)",
            "GeMM",
            "ZCU102",
            150.0,
            "deit-small",
            9.2,
            "A4W4+A4W3",
            155.8,
            1418.4,
            193.0,
            1549,
            f64::NAN,
            10.34,
        ),
        row(
            "HeatViT (HPCA23)",
            "GeMM",
            "ZCU102",
            150.0,
            "deit-tiny",
            2.5,
            "A8W8",
            183.4,
            366.8,
            137.6,
            1968,
            355.5,
            9.45,
        ),
        row(
            "SSR (FPGA24)",
            "Coarse-Grained Pipeline",
            "VCK190",
            250.0,
            "deit-tiny",
            2.5,
            "A8W8",
            4545.0,
            11362.5,
            619.0,
            14405,
            1456.0,
            46.0,
        ),
    ]
}

/// Assemble the full Table 2: prior art + our four deployments.
pub fn table2() -> Vec<AcceleratorRow> {
    let mut rows = prior_art();
    let tiny = ViTConfig::deit_tiny();
    let small = ViTConfig::deit_small();
    rows.push(deploy(&tiny, Precision::A4W4, &Fpga::zcu102(), 375e6));
    rows.push(deploy(&tiny, Precision::A4W4, &Fpga::vck190(), 425e6));
    rows.push(deploy(&tiny, Precision::A3W3, &Fpga::vck190(), 425e6));
    rows.push(deploy(&small, Precision::A3W3, &Fpga::vck190(), 350e6));
    rows
}

/// GOPs of a design's MM modules that the `sim` stable II implies
/// (cross-check between the analytical FPS and the simulator).
pub fn tops_at_ii(cfg: &ViTConfig, ii: u64, freq_hz: f64) -> f64 {
    cfg.ops_per_inference() as f64 * freq_hz / ii as f64 / 1e12
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vck190_a3w3_matches_paper_7118_fps() {
        let r = deploy(&ViTConfig::deit_tiny(), Precision::A3W3, &Fpga::vck190(), 425e6);
        assert_eq!(r.scale, 1, "full design must fit at 3 bits");
        assert!((r.fps - 7118.0).abs() / 7118.0 < 0.05, "fps {}", r.fps);
        assert!((r.gops / 1000.0 - 17.8).abs() < 1.5, "gops {}", r.gops);
    }

    #[test]
    fn vck190_a4w4_halves_to_match_paper_3629_fps() {
        let r = deploy(&ViTConfig::deit_tiny(), Precision::A4W4, &Fpga::vck190(), 425e6);
        assert_eq!(r.scale, 2, "4-bit MACs force a half-parallelism deployment");
        assert!((r.fps - 3629.0).abs() / 3629.0 < 0.05, "fps {}", r.fps);
    }

    #[test]
    fn zcu102_quarters_to_match_paper_1579_fps() {
        let r = deploy(&ViTConfig::deit_tiny(), Precision::A4W4, &Fpga::zcu102(), 375e6);
        assert_eq!(r.scale, 4, "ZCU102 runs the network in 4 parts (footnote 3)");
        assert!((r.fps - 1579.0).abs() / 1579.0 < 0.05, "fps {}", r.fps);
    }

    #[test]
    fn deit_small_matches_paper_1490_fps() {
        let r = deploy(&ViTConfig::deit_small(), Precision::A3W3, &Fpga::vck190(), 350e6);
        assert!((r.fps - 1490.0).abs() / 1490.0 < 0.10, "fps {} (scale {})", r.fps, r.scale);
    }

    #[test]
    fn beats_v100_by_about_2_8x() {
        let r = deploy(&ViTConfig::deit_tiny(), Precision::A3W3, &Fpga::vck190(), 425e6);
        let ratio = r.fps / 2529.0;
        assert!((2.5..3.2).contains(&ratio), "vs GPU ratio {ratio}");
    }

    #[test]
    fn lut_efficiency_beats_autovitacc_2_5x() {
        // paper: 18.55 GOPs/kLUT on ZCU102 = 2.52x AutoViTAcc's 7.35
        let r = deploy(&ViTConfig::deit_tiny(), Precision::A4W4, &Fpga::zcu102(), 375e6);
        let ratio = r.gops_per_klut() / 7.35;
        assert!(ratio > 2.0, "ratio {ratio} (ours {})", r.gops_per_klut());
    }

    #[test]
    fn power_efficiency_beats_ssr() {
        // paper: 381 GOPs/W vs SSR 246.15
        let r = deploy(&ViTConfig::deit_tiny(), Precision::A3W3, &Fpga::vck190(), 425e6);
        assert!(r.gops_per_w() > 246.15, "{}", r.gops_per_w());
    }

    #[test]
    fn table2_has_9_rows() {
        assert_eq!(table2().len(), 9);
    }

    #[test]
    fn power_model_near_paper_measurements() {
        // (luts, freq, paper W): the four measured deployments
        for (luts, f, w) in [
            (669e3, 425e6, 46.7),
            (514e3, 425e6, 43.4),
            (212.7e3, 375e6, 21.9),
            (869e3, 350e6, 48.1),
        ] {
            let p = power_model(luts, f);
            assert!((p - w).abs() / w < 0.25, "P({luts},{f}) = {p} vs paper {w}");
        }
    }

    #[test]
    fn dsp_count_magnitude_matches_paper() {
        let r = deploy(&ViTConfig::deit_tiny(), Precision::A3W3, &Fpga::vck190(), 425e6);
        // paper: 312 DSPs on the full VCK190 deployment; our inventory
        // counts only the surviving datapath multipliers (LN normalize +
        // softmax probability product) — tens, not thousands; the paper's
        // extra ~240 are DMA/addressing infrastructure we don't model
        assert!((40..800).contains(&r.dsps), "dsps {}", r.dsps);
    }
}
