//! L4 network front door: a hand-rolled, dependency-free HTTP/1.1
//! server over [`std::net::TcpListener`] in front of the
//! [`crate::coordinator::Router`] — the socket edge of the paper's
//! end-to-end serving claim. One acceptor thread plus a small pool of
//! connection workers (`HttpConfig::workers`) handle keep-alive
//! connections pulled from a bounded [`FrontQueue`], the same MPMC
//! primitive the coordinator's admission queue uses.
//!
//! Endpoints:
//!
//! | route | method | reply |
//! |---|---|---|
//! | `/v1/models/{name}/infer` | POST | run one image (binary LE f32 or JSON array body), JSON logits reply |
//! | `/metrics` | GET | [`Router::prometheus_text`] verbatim (`text/plain; version=0.0.4`) |
//! | `/healthz` | GET | liveness JSON from [`ModelServer::live_replicas`] per model |
//!
//! The coordinator's typed admission errors are downcast *at the
//! edge* and mapped onto the wire: [`Overloaded`] → `429` +
//! `Retry-After`, [`DeadlineExceeded`] (from a `Deadline-Ms` request
//! header) → `504`, [`UnknownModel`] → `404`; shutdown rejections →
//! `503`; malformed bodies and the wire limits of [`http::Wire`] →
//! `400`/`411`/`413`/`431`. Shed accounting is per-source
//! ([`AdmitSource::Http`]), so `/metrics` shows who overload hit.
//!
//! Exactly-one-reply, extended across the socket: every request read
//! off an accepted connection is answered with exactly one HTTP
//! response, and graceful shutdown ([`HttpServer::shutdown`] or drop)
//! drains — the acceptor stops, already-accepted connections are
//! served until their in-flight request completes (idle keep-alive
//! connections close immediately), and only then do the workers
//! (and, at the caller's leisure, the Router) go away.
//!
//! Traces gain an `http` lane per connection (`http-conn-N`): each
//! served request is one `X` span noting `METHOD path -> status`,
//! bracketing the coordinator's `admit` instant and `exec` span so a
//! trace shows socket→admit→exec end to end.

pub mod http;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::queue::{FrontQueue, Pop};
use crate::coordinator::{AdmitSource, DeadlineExceeded, Overloaded, Router, UnknownModel};
use crate::telemetry::{Telemetry, TraceEvent};
use crate::util::json::Json;
use http::{read_request, write_response, ReadError, Request, Response, Wire};

/// Content type of the `/metrics` exposition (Prometheus text 0.0.4).
pub const PROMETHEUS_CONTENT_TYPE: &str = "text/plain; version=0.0.4";

/// The `HGPIPE_HTTP` env fallback for `serve --http` (read-only, like
/// every other `HGPIPE_*` fallback; the explicit flag wins). Empty
/// means disabled, mirroring `--http ""`.
pub fn addr_from_env() -> Option<String> {
    std::env::var("HGPIPE_HTTP").ok().filter(|v| !v.is_empty())
}

/// Front-door tuning. The defaults suit tests and the CI smoke; a
/// real deployment would size `workers` to its expected concurrent
/// connection count (one blocked worker per in-flight request).
#[derive(Debug, Clone, Copy)]
pub struct HttpConfig {
    /// Connection worker threads (each owns one connection at a time).
    pub workers: usize,
    /// Per-request read budget against slow clients (see
    /// [`Wire::read_timeout`]); also the socket write timeout.
    pub read_timeout: Duration,
    /// Request head cap (`431` beyond it).
    pub max_head_bytes: usize,
    /// Request body cap (`413` beyond it, before reading the body).
    pub max_body_bytes: usize,
    /// Accepted-but-unclaimed connection bound; beyond it new
    /// connections are dropped at accept (the TCP analogue of shed).
    pub pending_conns: usize,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            workers: 8,
            read_timeout: Duration::from_secs(5),
            max_head_bytes: 16 * 1024,
            max_body_bytes: 8 * 1024 * 1024,
            pending_conns: 1024,
        }
    }
}

struct Shared {
    router: Arc<Router>,
    /// The trace handle of the first routed model: `HGPIPE_TRACE` /
    /// `--trace` point every fleet at one JSONL sink, so the edge
    /// lane records into that shared file regardless of which model a
    /// request routes to.
    tele: Telemetry,
    wire: Wire,
    conns: FrontQueue<TcpStream>,
    stop: AtomicBool,
    live_workers: AtomicUsize,
    conn_seq: AtomicU64,
}

/// The running front door. Dropping it performs the graceful drain
/// documented on the module; the [`Router`] behind it is untouched
/// and can keep serving in-process callers.
pub struct HttpServer {
    shared: Arc<Shared>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    addr: SocketAddr,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and
    /// start the acceptor + worker pool in front of `router`.
    pub fn bind(addr: &str, router: Arc<Router>, cfg: HttpConfig) -> crate::Result<HttpServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("http: cannot bind {addr}: {e}"))?;
        // nonblocking accept so the acceptor can poll the stop flag;
        // accepted sockets are switched back to blocking-with-timeout
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let tele = router
            .models()
            .first()
            .and_then(|m| router.server(m))
            .map(|s| s.telemetry().clone())
            .unwrap_or_default();
        let shared = Arc::new(Shared {
            router,
            tele,
            wire: Wire {
                max_head_bytes: cfg.max_head_bytes,
                max_body_bytes: cfg.max_body_bytes,
                read_timeout: cfg.read_timeout,
            },
            conns: FrontQueue::bounded(cfg.pending_conns.max(1)),
            stop: AtomicBool::new(false),
            live_workers: AtomicUsize::new(0),
            conn_seq: AtomicU64::new(0),
        });
        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for i in 0..cfg.workers.max(1) {
            // counted before spawn so `live_workers()` reads the full
            // pool size the moment `bind` returns
            shared.live_workers.fetch_add(1, Ordering::SeqCst);
            let s = shared.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("http-worker-{i}"))
                    .spawn(move || worker_loop(&s))?,
            );
        }
        let s = shared.clone();
        let acceptor = std::thread::Builder::new()
            .name("http-acceptor".into())
            .spawn(move || acceptor_loop(listener, &s))?;
        Ok(HttpServer { shared, acceptor: Some(acceptor), workers, addr: local })
    }

    /// The bound address (with the real port when bound to port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connection workers currently alive — the leak gauge the edge
    /// tests pin: malformed input must never wedge or kill a worker,
    /// so this stays at the configured pool size until shutdown.
    pub fn live_workers(&self) -> usize {
        self.shared.live_workers.load(Ordering::SeqCst)
    }

    /// Graceful shutdown (also runs on drop): stop accepting, serve
    /// every already-accepted connection's in-flight request, join
    /// the pool. Named so call sites read as intent, not cleanup.
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        // close-drains-before-EOS: workers serve every connection the
        // acceptor already queued, then see `Closed` and exit
        self.shared.conns.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn acceptor_loop(listener: TcpListener, shared: &Shared) {
    while !shared.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // blocking + budgeted from here on; a socket that
                // cannot even be configured is dropped on the floor
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                let _ = stream.set_write_timeout(Some(shared.wire.read_timeout));
                let _ = stream.set_nodelay(true);
                // a push rejected by the bound (or by close during
                // shutdown) drops the socket: the peer sees EOF, the
                // pool never learns the connection existed
                let _ = shared.conns.push(stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

fn worker_loop(shared: &Shared) {
    // decrement on every exit path (including a handler panic
    // unwinding through this frame) so `live_workers` is truthful
    struct LiveGuard<'a>(&'a AtomicUsize);
    impl Drop for LiveGuard<'_> {
        fn drop(&mut self) {
            self.0.fetch_sub(1, Ordering::SeqCst);
        }
    }
    let _guard = LiveGuard(&shared.live_workers);
    loop {
        match shared.conns.pop_timeout(Duration::from_millis(50)) {
            Pop::Item(stream) => {
                // one poisoned connection must not shrink the pool:
                // swallow handler panics, keep serving the next one
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    handle_connection(shared, stream);
                }));
            }
            Pop::TimedOut => continue,
            Pop::Closed => break,
        }
    }
}

/// Serve one keep-alive connection to completion: read → route →
/// respond, until the peer closes, a wire limit trips, or shutdown
/// drains us. Every request read gets exactly one response.
fn handle_connection(shared: &Shared, mut stream: TcpStream) {
    let conn = shared.conn_seq.fetch_add(1, Ordering::Relaxed);
    let tid = shared.tele.alloc_tid(&format!("http-conn-{conn}"));
    let mut carry = Vec::new();
    loop {
        let req = match read_request(&mut stream, &mut carry, &shared.wire, &shared.stop) {
            Ok(req) => req,
            Err(ReadError::Eof) | Err(ReadError::Disconnect(_)) => return,
            Err(ReadError::Bad { status, msg }) => {
                // answerable protocol violation: one response, then
                // close (framing is not trustworthy afterwards)
                let _ = write_response(&mut stream, &error_json(status, &msg), false);
                return;
            }
        };
        let t0 = shared.tele.now_us();
        let resp = route(shared, &req);
        // a drain that began mid-request still answers it — but on a
        // closing connection, so the client re-resolves
        let keep = req.wants_keep_alive() && !shared.stop.load(Ordering::SeqCst);
        let wrote = write_response(&mut stream, &resp, keep);
        let dur = shared.tele.now_us().saturating_sub(t0);
        shared.tele.record(|b| {
            let pid = b.pid();
            b.push(
                TraceEvent::span("http", "http", pid, tid, t0, dur)
                    .with_note(format!("{} {} -> {}", req.method, req.path, resp.status)),
            );
        });
        if !keep || wrote.is_err() {
            return;
        }
    }
}

fn route(shared: &Shared, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/metrics") => Response::new(
            200,
            PROMETHEUS_CONTENT_TYPE,
            shared.router.prometheus_text().into_bytes(),
        ),
        (_, "/metrics") | (_, "/healthz") if req.method != "GET" => {
            error_json(405, "method not allowed").with_header("Allow", "GET")
        }
        ("GET", "/healthz") => healthz(shared),
        (method, path) if path.starts_with("/v1/models/") && path.ends_with("/infer") => {
            let name = &path["/v1/models/".len()..path.len() - "/infer".len()];
            if name.is_empty() || name.contains('/') {
                return error_json(404, &format!("no route for {path}"));
            }
            if method != "POST" {
                return error_json(405, "inference requires POST").with_header("Allow", "POST");
            }
            infer(shared, name, req)
        }
        (_, path) => error_json(404, &format!("no route for {path}")),
    }
}

/// `POST /v1/models/{name}/infer`: decode the image, submit through
/// the router (per-source admission accounting + typed rejections),
/// block for the single reply, serialize it.
fn infer(shared: &Shared, model: &str, req: &Request) -> Response {
    let deadline = match req.header("deadline-ms") {
        None => None,
        Some(v) => match v.parse::<u64>() {
            Ok(ms) => Some(Duration::from_millis(ms)),
            Err(_) => return error_json(400, &format!("unparseable Deadline-Ms {v:?}")),
        },
    };
    let tokens = match decode_tokens(req) {
        Ok(t) => t,
        Err(msg) => return error_json(400, &msg),
    };
    let rx = match shared.router.submit_from(AdmitSource::Http, model, tokens, deadline) {
        Ok(rx) => rx,
        Err(e) => return admission_error(&e),
    };
    match rx.recv() {
        Ok(Ok(resp)) => Response::json(200, infer_body(model, &resp)),
        Ok(Err(e)) => {
            if e.downcast_ref::<DeadlineExceeded>().is_some() {
                error_json(504, &format!("{e}"))
            } else {
                // dispatch failed or the fleet shut down mid-flight:
                // the explicit one-reply error crosses the socket too
                error_json(500, &format!("{e}"))
            }
        }
        Err(_) => error_json(500, "reply channel lost"),
    }
}

/// Map a submit-time rejection onto the wire via typed downcasts.
fn admission_error(e: &anyhow::Error) -> Response {
    if let Some(o) = e.downcast_ref::<Overloaded>() {
        // tell the client when to come back; 1s is the shortest
        // integral Retry-After and the queue drains far faster
        return error_json(429, &format!("{o}")).with_header("Retry-After", "1");
    }
    if e.downcast_ref::<UnknownModel>().is_some() {
        return error_json(404, &format!("{e}"));
    }
    let msg = format!("{e:#}");
    if msg.contains("server stopped") {
        return error_json(503, &msg);
    }
    // everything else submit rejects is a malformed request (e.g.
    // wrong token count for the model's input shape)
    error_json(400, &msg)
}

/// The image body: raw little-endian f32 by default, or a JSON array
/// of numbers when the content type (or the payload itself) says so.
fn decode_tokens(req: &Request) -> Result<Vec<f32>, String> {
    let content_type = req.header("content-type").unwrap_or("");
    let first = req.body.iter().find(|b| !b.is_ascii_whitespace());
    if content_type.contains("json") || first == Some(&b'[') {
        let text = std::str::from_utf8(&req.body).map_err(|_| "JSON body is not UTF-8")?;
        let parsed = Json::parse(text).map_err(|e| format!("malformed JSON body: {e}"))?;
        let arr = parsed.as_arr().ok_or("JSON body must be an array of numbers")?;
        return arr
            .iter()
            .map(|v| match v.as_f64() {
                Some(f) => Ok(f as f32),
                None => Err("JSON body must contain only numbers".to_string()),
            })
            .collect();
    }
    if req.body.len() % 4 != 0 {
        return Err(format!(
            "binary body length {} is not a multiple of 4 (little-endian f32s)",
            req.body.len()
        ));
    }
    Ok(req
        .body
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn infer_body(model: &str, r: &crate::coordinator::Response) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(64 + r.logits.len() * 12);
    let _ = write!(
        s,
        "{{\"id\":{},\"model\":{},\"argmax\":{},\"latency_us\":{},\"logits\":[",
        r.id,
        json_str(model),
        r.argmax,
        r.latency.as_micros()
    );
    for (i, l) in r.logits.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        // f32 Display is the shortest decimal that round-trips, so
        // clients parsing with `str::parse::<f32>` recover the exact
        // bits — the smoke gate's bit-exactness rides on this
        let _ = write!(s, "{l}");
    }
    s.push_str("]}");
    s
}

/// `GET /healthz`: 200 while every routed model has at least one live
/// replica, 503 (with the same body shape) once any fleet degraded to
/// zero — load balancers eject the instance, scrapes keep working.
fn healthz(shared: &Shared) -> Response {
    let models = shared.router.models();
    let mut all_live = !models.is_empty();
    let mut items = Vec::new();
    for name in &models {
        if let Some(s) = shared.router.server(name) {
            let live = s.live_replicas();
            if live == 0 {
                all_live = false;
            }
            items.push(format!(
                "{{\"name\":{},\"live_replicas\":{live},\"replicas\":{},\"queue_depth\":{}}}",
                json_str(name),
                s.replicas(),
                s.queue_len()
            ));
        }
    }
    let status = if all_live { 200 } else { 503 };
    let body = format!(
        "{{\"status\":{},\"models\":[{}]}}",
        json_str(if all_live { "ok" } else { "degraded" }),
        items.join(",")
    );
    Response::json(status, body)
}

fn error_json(status: u16, msg: &str) -> Response {
    Response::json(status, format!("{{\"error\":{},\"status\":{status}}}", json_str(msg)))
}

/// Serialize one JSON string literal (quotes, backslashes, control
/// bytes) — error messages quote client input, so this is load-bearing.
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_strings_escape_quotes_and_control_bytes() {
        assert_eq!(json_str("plain"), "\"plain\"");
        assert_eq!(json_str("a\"b\\c"), "\"a\\\"b\\\\c\"");
        assert_eq!(json_str("line\nbreak\x01"), "\"line\\nbreak\\u0001\"");
    }

    #[test]
    fn binary_and_json_bodies_decode_identically() {
        let vals = [0.5f32, -1.25, 3.0];
        let bin: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let req = |body: Vec<u8>, ct: Option<&'static str>| Request {
            method: "POST".into(),
            path: "/v1/models/m/infer".into(),
            version: "HTTP/1.1".into(),
            headers: ct.map(|c| ("content-type".to_string(), c.to_string())).into_iter().collect(),
            body,
        };
        assert_eq!(decode_tokens(&req(bin, None)).unwrap(), vals);
        assert_eq!(
            decode_tokens(&req(b"[0.5, -1.25, 3]".to_vec(), Some("application/json"))).unwrap(),
            vals
        );
        assert!(decode_tokens(&req(vec![0u8; 5], None)).is_err());
        assert!(decode_tokens(&req(b"[1, \"x\"]".to_vec(), None)).is_err());
    }

    #[test]
    fn infer_body_round_trips_f32_logits() {
        let r = crate::coordinator::Response {
            id: 7,
            logits: vec![0.1f32, -2.7182817, 1.0],
            argmax: 2,
            latency: Duration::from_micros(1234),
        };
        let body = infer_body("tiny-synth", &r);
        assert!(body.contains("\"id\":7"));
        assert!(body.contains("\"argmax\":2"));
        let logits: Vec<f32> = body
            .split("\"logits\":[")
            .nth(1)
            .unwrap()
            .trim_end_matches("]}")
            .split(',')
            .map(|t| t.parse().unwrap())
            .collect();
        for (got, want) in logits.iter().zip(&r.logits) {
            assert_eq!(got.to_bits(), want.to_bits());
        }
    }
}
