//! Minimal HTTP/1.1 wire codec for the front door: request framing
//! (head + `Content-Length` body) and response serialization over a
//! blocking [`TcpStream`], with the abuse limits the edge needs —
//! a head-size cap, a body-size cap, and a per-request read budget so
//! a slow or stalled client cannot pin a worker forever.
//!
//! Deliberately not a general HTTP implementation: no chunked
//! transfer encoding (rejected with `501`), no continuation lines, no
//! multi-valued header folding. The serving API only needs `POST`
//! with a sized body and bodyless `GET`s, and every limit violation
//! maps to a precise status code so misbehaving clients get an
//! answer, not a hang (see [`ReadError`]).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Wire-level limits and budgets, fixed per server.
#[derive(Debug, Clone, Copy)]
pub struct Wire {
    /// Max bytes of request line + headers (`431` beyond this).
    pub max_head_bytes: usize,
    /// Max declared `Content-Length` (`413` beyond this, before any
    /// body byte is read).
    pub max_body_bytes: usize,
    /// Budget for receiving one complete head and, separately, one
    /// complete body. A client that trickles bytes slower than this
    /// is disconnected, not waited on.
    pub read_timeout: Duration,
}

/// One parsed request. Header names are lowercased at parse time;
/// values keep their case with surrounding whitespace trimmed.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// `HTTP/1.0` or `HTTP/1.1` (anything else is rejected with 505).
    pub version: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First value of `name` (case-insensitive lookup).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// Whether the client asked to keep the connection open:
    /// HTTP/1.1 defaults to keep-alive unless `Connection: close`;
    /// HTTP/1.0 defaults to close unless `Connection: keep-alive`.
    pub fn wants_keep_alive(&self) -> bool {
        let conn = self.header("connection").map(|v| v.to_ascii_lowercase());
        match self.version.as_str() {
            "HTTP/1.0" => conn.as_deref() == Some("keep-alive"),
            _ => conn.as_deref() != Some("close"),
        }
    }
}

/// Why a request could not be read off the connection.
#[derive(Debug)]
pub enum ReadError {
    /// Clean end of stream between requests — the peer closed an idle
    /// keep-alive connection. Not an error; just stop serving it.
    Eof,
    /// The connection is unusable (closed mid-request, read failure,
    /// or the read budget expired with an incomplete request). No
    /// response can be framed; drop the connection.
    Disconnect(String),
    /// The request violated the protocol or a limit in a way that can
    /// still be answered: respond with `status`, then close (framing
    /// is not trustworthy after a malformed request).
    Bad { status: u16, msg: String },
}

fn bad(status: u16, msg: impl Into<String>) -> ReadError {
    ReadError::Bad { status, msg: msg.into() }
}

/// Read one request from `stream`. `carry` holds bytes already read
/// past the previous request's body (pipelined or coalesced reads)
/// and is maintained across calls on the same connection.
///
/// `shutting_down` lets a draining server close *idle* keep-alive
/// connections promptly: if the flag is set and not a single byte of
/// the next request has arrived, the read stops with
/// [`ReadError::Eof`]. A half-received request keeps its full read
/// budget — in-flight work is drained, not dropped.
pub fn read_request(
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
    wire: &Wire,
    shutting_down: &AtomicBool,
) -> Result<Request, ReadError> {
    // -------- head: read until the blank line --------
    let head_deadline = Instant::now() + wire.read_timeout;
    let head_end = loop {
        match find_head_end(carry) {
            Some(pos) if pos <= wire.max_head_bytes => break pos,
            // over the cap — whether the terminator arrived or not
            Some(_) => {
                return Err(bad(
                    431,
                    format!("request head exceeds {} bytes", wire.max_head_bytes),
                ));
            }
            None if carry.len() > wire.max_head_bytes + 4 => {
                return Err(bad(
                    431,
                    format!("request head exceeds {} bytes", wire.max_head_bytes),
                ));
            }
            None => {}
        }
        if shutting_down.load(Ordering::SeqCst) && carry.is_empty() {
            return Err(ReadError::Eof);
        }
        let now = Instant::now();
        if now >= head_deadline {
            return Err(ReadError::Disconnect(if carry.is_empty() {
                "idle past the read budget".into()
            } else {
                "request head incomplete past the read budget".into()
            }));
        }
        // short read slices so both the shutdown flag and the budget
        // are re-checked at least every 100ms
        match read_chunk(stream, carry, (head_deadline - now).min(Duration::from_millis(100))) {
            ReadChunk::Data | ReadChunk::TimedOut => {}
            ReadChunk::Eof => {
                return Err(if carry.is_empty() {
                    ReadError::Eof
                } else {
                    ReadError::Disconnect("peer closed mid-head".into())
                });
            }
            ReadChunk::Failed(e) => return Err(ReadError::Disconnect(e)),
        }
    };
    let head: Vec<u8> = carry.drain(..head_end + 4).take(head_end).collect();
    let (method, path, version, headers) = parse_head(&head)?;

    // -------- body: exactly Content-Length bytes --------
    if header_value(&headers, "transfer-encoding").is_some() {
        return Err(bad(501, "chunked transfer encoding is not supported"));
    }
    let content_length = match header_value(&headers, "content-length") {
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| bad(400, format!("unparseable Content-Length {v:?}")))?,
        None if method == "POST" || method == "PUT" => {
            return Err(bad(411, "request body requires a Content-Length header"));
        }
        None => 0,
    };
    if content_length > wire.max_body_bytes {
        // answered before reading a single body byte — the connection
        // closes after the 413, so the unread body is never drained
        return Err(bad(
            413,
            format!(
                "body of {content_length} bytes exceeds the {} byte limit",
                wire.max_body_bytes
            ),
        ));
    }
    let body_deadline = Instant::now() + wire.read_timeout;
    while carry.len() < content_length {
        let now = Instant::now();
        if now >= body_deadline {
            return Err(ReadError::Disconnect(format!(
                "body incomplete past the read budget ({} of {content_length} bytes)",
                carry.len()
            )));
        }
        match read_chunk(stream, carry, (body_deadline - now).min(Duration::from_millis(100))) {
            ReadChunk::Data | ReadChunk::TimedOut => {}
            ReadChunk::Eof => return Err(ReadError::Disconnect("peer closed mid-body".into())),
            ReadChunk::Failed(e) => return Err(ReadError::Disconnect(e)),
        }
    }
    let body: Vec<u8> = carry.drain(..content_length).collect();
    Ok(Request { method, path, version, headers, body })
}

/// Byte offset of the `\r\n\r\n` head terminator, if present.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

enum ReadChunk {
    Data,
    TimedOut,
    Eof,
    Failed(String),
}

/// One bounded read into `into`. The socket's read timeout is set to
/// `timeout` for this read only (clamped to ≥1ms — a zero timeout is
/// an error on std sockets).
fn read_chunk(stream: &mut TcpStream, into: &mut Vec<u8>, timeout: Duration) -> ReadChunk {
    if stream.set_read_timeout(Some(timeout.max(Duration::from_millis(1)))).is_err() {
        return ReadChunk::Failed("set_read_timeout failed".into());
    }
    let mut buf = [0u8; 4096];
    match stream.read(&mut buf) {
        Ok(0) => ReadChunk::Eof,
        Ok(n) => {
            into.extend_from_slice(&buf[..n]);
            ReadChunk::Data
        }
        Err(e) => match e.kind() {
            std::io::ErrorKind::WouldBlock
            | std::io::ErrorKind::TimedOut
            | std::io::ErrorKind::Interrupted => ReadChunk::TimedOut,
            _ => ReadChunk::Failed(e.to_string()),
        },
    }
}

#[allow(clippy::type_complexity)]
fn parse_head(head: &[u8]) -> Result<(String, String, String, Vec<(String, String)>), ReadError> {
    let text = std::str::from_utf8(head).map_err(|_| bad(400, "request head is not UTF-8"))?;
    let mut lines = text.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    // exactly `METHOD SP PATH SP VERSION` — split on single spaces so
    // a truncated or over-spaced line is rejected, not reinterpreted
    let mut parts = request_line.split(' ');
    let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) if !m.is_empty() && !p.is_empty() && !v.is_empty() => {
            (m.to_string(), p.to_string(), v.to_string())
        }
        _ => return Err(bad(400, format!("malformed request line {request_line:?}"))),
    };
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(bad(505, format!("unsupported protocol version {version:?}")));
    }
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(bad(400, format!("malformed header line {line:?}")));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    Ok((method, path, version, headers))
}

fn header_value<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
}

/// One response to serialize. `Connection:` is decided by the caller
/// at write time (keep-alive vs close/drain), not stored here.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra headers (e.g. `Retry-After` on 429, `Allow` on 405).
    pub headers: Vec<(&'static str, String)>,
}

impl Response {
    pub fn new(status: u16, content_type: &'static str, body: Vec<u8>) -> Self {
        Response { status, content_type, body, headers: Vec::new() }
    }

    /// A JSON reply (the serving API's default content type).
    pub fn json(status: u16, body: String) -> Self {
        Self::new(status, "application/json", body.into_bytes())
    }

    pub fn with_header(mut self, name: &'static str, value: impl Into<String>) -> Self {
        self.headers.push((name, value.into()));
        self
    }
}

/// Serialize `resp`. `keep_alive` picks the `Connection:` header; the
/// status line is always HTTP/1.1 (valid to send to 1.0 clients).
pub fn write_response(
    stream: &mut TcpStream,
    resp: &Response,
    keep_alive: bool,
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        resp.status,
        reason(resp.status),
        resp.content_type,
        resp.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    for (name, value) in &resp.headers {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()
}

/// Reason phrases for the statuses the front door emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_terminator_found() {
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n\r\nrest"), Some(16));
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n"), None);
    }

    #[test]
    fn request_line_parses() {
        let (m, p, v, h) = parse_head(b"POST /v1/x HTTP/1.1\r\nContent-Length: 4").unwrap();
        assert_eq!((m.as_str(), p.as_str(), v.as_str()), ("POST", "/v1/x", "HTTP/1.1"));
        assert_eq!(h, vec![("content-length".to_string(), "4".to_string())]);
    }

    #[test]
    fn header_names_lowercase_values_trimmed() {
        let (_, _, _, h) = parse_head(b"GET / HTTP/1.1\r\nDeadline-Ms:  25 ").unwrap();
        assert_eq!(h, vec![("deadline-ms".to_string(), "25".to_string())]);
    }

    #[test]
    fn truncated_request_line_is_400() {
        for line in ["GET", "GET /", "", "GET  / HTTP/1.1", "GET / HTTP/1.1 extra"] {
            match parse_head(line.as_bytes()) {
                Err(ReadError::Bad { status: 400, .. }) => {}
                other => panic!("{line:?}: expected 400, got {other:?}"),
            }
        }
    }

    #[test]
    fn unsupported_version_is_505() {
        match parse_head(b"GET / HTTP/2.0") {
            Err(ReadError::Bad { status: 505, .. }) => {}
            other => panic!("expected 505, got {other:?}"),
        }
    }

    #[test]
    fn keep_alive_defaults_by_version() {
        let req = |version: &str, conn: Option<&str>| Request {
            method: "GET".into(),
            path: "/".into(),
            version: version.into(),
            headers: conn.map(|c| ("connection".to_string(), c.to_string())).into_iter().collect(),
            body: Vec::new(),
        };
        assert!(req("HTTP/1.1", None).wants_keep_alive());
        assert!(!req("HTTP/1.1", Some("close")).wants_keep_alive());
        assert!(!req("HTTP/1.0", None).wants_keep_alive());
        assert!(req("HTTP/1.0", Some("keep-alive")).wants_keep_alive());
    }

    #[test]
    fn reason_phrases_cover_the_emitted_statuses() {
        for s in [200, 400, 404, 405, 411, 413, 429, 431, 500, 501, 503, 504, 505] {
            assert_ne!(reason(s), "Unknown", "missing reason for {s}");
        }
    }
}
