//! Deep-FIFO depth search (paper Sec. 4.2: "We carried out simulation
//! experiments to identify the shallowest depth that avoids deadlocks,
//! and the typical depth of deep FIFOs is 512").

use super::builder::{build_vit, Paradigm, SimConfig};
use super::engine::{run_fast, StopReason};
#[cfg(test)]
use super::engine::run;
use crate::arch::parallelism::Design;
use crate::model::ViTConfig;

/// Binary-search the minimal deep-FIFO capacity (in token groups) that
/// completes `images` images without deadlock.
pub fn min_deep_fifo_depth(design: &Design, cfg: &ViTConfig, images: u64) -> u64 {
    let base = SimConfig::matched(design, cfg);
    let ok = |cap: u64| -> bool {
        let sim = SimConfig { deep_fifo_cap: cap, ..base };
        let p = build_vit(design, cfg, Paradigm::Hybrid, sim);
        matches!(run_fast(&p, images, 500_000_000).stop, StopReason::Completed)
    };
    let tt = (cfg.tokens() as u64).div_ceil(2);
    let mut hi = 2 * tt; // one image's groups + margin always suffices
    while !ok(hi) {
        hi *= 2;
        assert!(hi < 1 << 20, "no feasible deep-FIFO depth found");
    }
    let mut lo = 0u64; // known-bad (a 0-cap FIFO cannot exist)
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if mid == 0 || !ok(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    hi
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::parallelism::design_network;
    use crate::model::Precision;

    #[test]
    fn min_depth_is_about_one_image() {
        // the residual/Q streams must hold roughly a whole image's tokens
        // while the K/V dependency blocks the attention path
        let cfg = ViTConfig::tiny_synth();
        let d = design_network(&cfg, Precision::A4W4, 2);
        let tt = (cfg.tokens() as u64).div_ceil(2);
        let depth = min_deep_fifo_depth(&d, &cfg, 2);
        assert!(depth >= tt / 2, "depth {depth} suspiciously small (tt={tt})");
        assert!(depth <= 2 * tt, "depth {depth} suspiciously large (tt={tt})");
        // and the found depth indeed completes while depth-1 deadlocks
        let base = SimConfig::matched(&d, &cfg);
        let bad = build_vit(
            &d,
            &cfg,
            Paradigm::Hybrid,
            SimConfig { deep_fifo_cap: depth - 1, ..base },
        );
        assert!(matches!(run(&bad, 2, 500_000_000).stop, StopReason::Deadlock { .. }));
    }
}
