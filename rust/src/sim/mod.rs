//! Cycle-accurate simulator of the hybrid-grained pipeline (Sec. 4.1/4.2):
//! decentralized per-stage FSMs, AXI-Stream handshakes, FIFO / deep-buffer
//! / PIPO channels, deadlock detection and the Fig. 12 timing evidence.

pub mod builder;
pub mod channel;
pub mod deadlock;
pub mod engine;
pub mod stage;
pub mod trace;

pub use builder::{build_vit, Paradigm, SimConfig};
pub use engine::{run, run_fast, Pipeline, SimReport, StopReason};
