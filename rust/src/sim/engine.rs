//! The cycle-accurate engine: advances every stage FSM each cycle,
//! respecting channel handshakes; detects deadlock; records the timing
//! evidence the paper reports in Fig. 12 (stable II, first-image latency).

use super::channel::{Channel, ChannelKind};
use super::stage::{StageSpec, StageState};

/// A complete pipeline to simulate.
#[derive(Debug, Clone, Default)]
pub struct Pipeline {
    pub stages: Vec<StageSpec>,
    pub channels: Vec<Channel>,
    /// Index of the sink stage whose completions mark image completion.
    pub sink: usize,
}

impl Pipeline {
    pub fn add_channel(&mut self, name: impl Into<String>, kind: ChannelKind) -> usize {
        self.channels.push(Channel::new(name, kind));
        self.channels.len() - 1
    }

    pub fn add_stage(&mut self, spec: StageSpec) -> usize {
        self.stages.push(spec);
        self.stages.len() - 1
    }
}

/// Why a run stopped.
#[derive(Debug, Clone, PartialEq)]
pub enum StopReason {
    /// All images drained through the sink.
    Completed,
    /// No stage busy and none can start — circular wait.
    Deadlock { cycle: u64, waiting: Vec<String> },
    /// Cycle budget exhausted.
    Budget,
}

/// Result of a simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub stop: StopReason,
    pub cycles: u64,
    /// Sink completion cycle per image.
    pub image_done: Vec<u64>,
    pub stage_specs: Vec<StageSpec>,
    pub stage_states: Vec<StageState>,
    pub channel_names: Vec<String>,
    pub channel_max_occupancy: Vec<u64>,
}

impl SimReport {
    /// Stable II: cycles between the last two image completions.
    pub fn stable_ii(&self) -> Option<u64> {
        let n = self.image_done.len();
        if n >= 2 {
            Some(self.image_done[n - 1] - self.image_done[n - 2])
        } else {
            None
        }
    }

    /// First-image latency: source start (cycle 0) to first completion.
    pub fn first_image_latency(&self) -> Option<u64> {
        self.image_done.first().copied()
    }

    pub fn utilization(&self, stage: usize) -> f64 {
        self.stage_states[stage].busy_cycles as f64 / self.cycles.max(1) as f64
    }
}

/// Run the pipeline for `images` images or until `max_cycles`.
pub fn run(pipeline: &Pipeline, images: u64, max_cycles: u64) -> SimReport {
    let mut channels = pipeline.channels.clone();
    let mut states: Vec<StageState> = vec![StageState::default(); pipeline.stages.len()];
    let mut image_done: Vec<u64> = Vec::with_capacity(images as usize);
    let mut cycle: u64 = 0;
    let stop;

    'outer: loop {
        if image_done.len() as u64 >= images {
            stop = StopReason::Completed;
            break;
        }
        if cycle >= max_cycles {
            stop = StopReason::Budget;
            break;
        }

        let mut any_busy = false;
        let mut any_start = false;

        for (idx, spec) in pipeline.stages.iter().enumerate() {
            let st = &mut states[idx];

            // stages past their image quota are done
            if st.image >= images {
                continue;
            }

            if st.busy > 0 {
                st.busy -= 1;
                st.busy_cycles += 1;
                any_busy = true;
                if st.busy == 0 {
                    // firing completes: emit one group to every output
                    for &o in &spec.outputs {
                        channels[o].push();
                    }
                    st.record_end(cycle);
                    st.fired += 1;
                    st.total_firings += 1;
                    if st.fired == spec.firings_per_image {
                        // image finished: release deep/pipo inputs
                        for &i in &spec.inputs {
                            if !matches!(channels[i].kind, ChannelKind::Fifo { .. }) {
                                channels[i].release(st.image);
                            }
                        }
                        if idx == pipeline.sink {
                            image_done.push(cycle);
                            if image_done.len() as u64 >= images {
                                stop = StopReason::Completed;
                                break 'outer;
                            }
                        }
                        st.fired = 0;
                        st.image += 1;
                    }
                    // fall through: a fully-pipelined stage may initiate
                    // its next firing back-to-back (II = cost, not cost+1)
                } else {
                    continue;
                }
                if st.image >= images {
                    continue;
                }
            }

            // idle (or just finished): try to start a firing
            let img = st.image;
            let inputs_ready =
                spec.is_source || spec.inputs.iter().all(|&i| channels[i].can_consume(img));
            let outputs_ready = spec.outputs.iter().all(|&o| channels[o].can_push());
            if inputs_ready && outputs_ready {
                if !spec.is_source {
                    for &i in &spec.inputs {
                        channels[i].consume(img);
                    }
                }
                st.busy = spec.cost;
                st.record_start(cycle);
                any_start = true;
            } else if !inputs_ready {
                st.stall_in += 1;
            } else {
                st.stall_out += 1;
            }
        }

        if !any_busy && !any_start {
            // nothing running, nothing startable: permanent stall
            let waiting = pipeline
                .stages
                .iter()
                .zip(&states)
                .filter(|(_, st)| st.image < images)
                .map(|(sp, st)| format!("{} (img {}, fired {})", sp.name, st.image, st.fired))
                .collect();
            stop = StopReason::Deadlock { cycle, waiting };
            break;
        }
        cycle += 1;
    }

    SimReport {
        stop,
        cycles: cycle,
        image_done,
        stage_specs: pipeline.stages.clone(),
        stage_states: states,
        channel_names: channels.iter().map(|c| c.name.clone()).collect(),
        channel_max_occupancy: channels.iter().map(|c| c.max_occupancy).collect(),
    }
}

/// Event-driven fast path: identical semantics to [`run`] but advances
/// time directly to the next firing completion instead of stepping every
/// cycle (state only changes at completions). ~2-3 orders of magnitude
/// faster on the full DeiT-tiny pipeline; see EXPERIMENTS.md §Perf.
///
/// One deliberate idealization vs the cycle-stepped reference: start
/// cascades within a single instant resolve to a fixpoint (combinational
/// handshakes), where the reference resolves one stage-order pass per
/// cycle. This can shift fill-phase starts by a few cycles; steady-state
/// II and deadlock verdicts are identical (asserted by tests).
pub fn run_fast(pipeline: &Pipeline, images: u64, max_cycles: u64) -> SimReport {
    let mut channels = pipeline.channels.clone();
    let mut states: Vec<StageState> = vec![StageState::default(); pipeline.stages.len()];
    let mut busy_until: Vec<u64> = vec![u64::MAX; pipeline.stages.len()];
    let mut image_done: Vec<u64> = Vec::with_capacity(images as usize);
    let mut now: u64 = 0;
    let stop;

    'outer: loop {
        // start every firing that can begin at `now` (fixpoint cascade)
        loop {
            let mut any = false;
            for (idx, spec) in pipeline.stages.iter().enumerate() {
                let st = &mut states[idx];
                if busy_until[idx] != u64::MAX || st.image >= images {
                    continue;
                }
                let img = st.image;
                let inputs_ready =
                    spec.is_source || spec.inputs.iter().all(|&i| channels[i].can_consume(img));
                if !inputs_ready || !spec.outputs.iter().all(|&o| channels[o].can_push()) {
                    continue;
                }
                if !spec.is_source {
                    for &i in &spec.inputs {
                        channels[i].consume(img);
                    }
                }
                busy_until[idx] = now + spec.cost;
                st.record_start(now);
                any = true;
            }
            if !any {
                break;
            }
        }

        // next completion time
        let Some(&t) = busy_until.iter().filter(|&&t| t != u64::MAX).min() else {
            let waiting = pipeline
                .stages
                .iter()
                .zip(&states)
                .filter(|(_, st)| st.image < images)
                .map(|(sp, st)| format!("{} (img {}, fired {})", sp.name, st.image, st.fired))
                .collect::<Vec<_>>();
            stop = if waiting.is_empty() {
                StopReason::Completed
            } else {
                StopReason::Deadlock { cycle: now, waiting }
            };
            break;
        };
        if t > max_cycles {
            now = max_cycles;
            stop = StopReason::Budget;
            break;
        }
        now = t;

        // complete every firing ending at `now` (stage order)
        for (idx, spec) in pipeline.stages.iter().enumerate() {
            if busy_until[idx] != now {
                continue;
            }
            busy_until[idx] = u64::MAX;
            let st = &mut states[idx];
            st.busy_cycles += spec.cost;
            for &o in &spec.outputs {
                channels[o].push();
            }
            st.record_end(now);
            st.fired += 1;
            st.total_firings += 1;
            if st.fired == spec.firings_per_image {
                for &i in &spec.inputs {
                    if !matches!(channels[i].kind, ChannelKind::Fifo { .. }) {
                        channels[i].release(st.image);
                    }
                }
                if idx == pipeline.sink {
                    image_done.push(now);
                    if image_done.len() as u64 >= images {
                        stop = StopReason::Completed;
                        break 'outer;
                    }
                }
                st.fired = 0;
                st.image += 1;
            }
        }
    }

    SimReport {
        stop,
        cycles: now,
        image_done,
        stage_specs: pipeline.stages.clone(),
        stage_states: states,
        channel_names: channels.iter().map(|c| c.name.clone()).collect(),
        channel_max_occupancy: channels.iter().map(|c| c.max_occupancy).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// source -> A -> B -> sink, all FIFOs: a textbook linear pipeline.
    fn linear(cost_a: u64, cost_b: u64, cap: u64) -> Pipeline {
        let mut p = Pipeline::default();
        let c0 = p.add_channel("s->a", ChannelKind::Fifo { cap });
        let c1 = p.add_channel("a->b", ChannelKind::Fifo { cap });
        p.add_stage(StageSpec {
            name: "src".into(),
            block: "src".into(),
            cost: 1,
            firings_per_image: 4,
            inputs: vec![],
            outputs: vec![c0],
            is_source: true,
        });
        p.add_stage(StageSpec {
            name: "A".into(),
            block: "A".into(),
            cost: cost_a,
            firings_per_image: 4,
            inputs: vec![c0],
            outputs: vec![c1],
            is_source: false,
        });
        let sink = p.add_stage(StageSpec {
            name: "B".into(),
            block: "B".into(),
            cost: cost_b,
            firings_per_image: 4,
            inputs: vec![c1],
            outputs: vec![],
            is_source: false,
        });
        p.sink = sink;
        p
    }

    #[test]
    fn linear_pipeline_completes() {
        let r = run(&linear(3, 2, 4), 3, 1_000_000);
        assert_eq!(r.stop, StopReason::Completed);
        assert_eq!(r.image_done.len(), 3);
    }

    #[test]
    fn stable_ii_equals_bottleneck() {
        // bottleneck stage: cost 5 x 4 firings = II 20
        let r = run(&linear(5, 2, 8), 4, 1_000_000);
        assert_eq!(r.stable_ii(), Some(20));
    }

    #[test]
    fn imbalance_creates_bubbles_fig9a() {
        // Fig 9a: unbalanced stages leave the fast stage idle; balancing
        // via parallelism (lower cost) removes the bubbles.
        let slow = run(&linear(8, 2, 4), 6, 1_000_000);
        let util_b_slow = slow.utilization(2);
        let balanced = run(&linear(2, 2, 4), 6, 1_000_000);
        let util_b_bal = balanced.utilization(2);
        assert!(util_b_bal > util_b_slow + 0.2, "{util_b_bal} vs {util_b_slow}");
    }

    #[test]
    fn deep_buffer_dependency_delays_consumer() {
        // src -> fill deep buffer; consumer needs the whole image first
        let mut p = Pipeline::default();
        let c0 = p.add_channel("s->buf", ChannelKind::DeepBuffer { groups_per_image: 4 });
        p.add_stage(StageSpec {
            name: "src".into(),
            block: "s".into(),
            cost: 2,
            firings_per_image: 4,
            inputs: vec![],
            outputs: vec![c0],
            is_source: true,
        });
        let sink = p.add_stage(StageSpec {
            name: "dymm".into(),
            block: "d".into(),
            cost: 1,
            firings_per_image: 4,
            inputs: vec![c0],
            outputs: vec![],
            is_source: false,
        });
        p.sink = sink;
        let r = run(&p, 2, 100_000);
        assert_eq!(r.stop, StopReason::Completed);
        // consumer's first start must be after the 4th producer emission
        // (4 firings x 2 cycles)
        let first = r.stage_states[1].image_spans[0].0;
        assert!(first >= 7, "consumer started at {first}");
    }

    #[test]
    fn undersized_fifo_with_circular_wait_deadlocks() {
        // fork: src feeds residual fifo (cap 1) and a deep buffer; the
        // join needs both the buffer-gated path and the residual -> with a
        // tiny residual fifo the source blocks before the buffer fills
        let mut p = Pipeline::default();
        let res = p.add_channel("res", ChannelKind::Fifo { cap: 1 });
        let buf = p.add_channel("buf", ChannelKind::DeepBuffer { groups_per_image: 4 });
        let gated = p.add_channel("gated", ChannelKind::Fifo { cap: 2 });
        p.add_stage(StageSpec {
            name: "src".into(),
            block: "s".into(),
            cost: 1,
            firings_per_image: 4,
            inputs: vec![],
            outputs: vec![res, buf],
            is_source: true,
        });
        p.add_stage(StageSpec {
            name: "dymm".into(),
            block: "d".into(),
            cost: 1,
            firings_per_image: 4,
            inputs: vec![buf],
            outputs: vec![gated],
            is_source: false,
        });
        let sink = p.add_stage(StageSpec {
            name: "join".into(),
            block: "j".into(),
            cost: 1,
            firings_per_image: 4,
            inputs: vec![res, gated],
            outputs: vec![],
            is_source: false,
        });
        p.sink = sink;
        let r = run(&p, 1, 100_000);
        assert!(matches!(r.stop, StopReason::Deadlock { .. }), "{:?}", r.stop);
    }

    #[test]
    fn deadlock_fixed_by_deep_fifo() {
        let mut p = Pipeline::default();
        let res = p.add_channel("res", ChannelKind::Fifo { cap: 4 }); // deep enough
        let buf = p.add_channel("buf", ChannelKind::DeepBuffer { groups_per_image: 4 });
        let gated = p.add_channel("gated", ChannelKind::Fifo { cap: 2 });
        p.add_stage(StageSpec {
            name: "src".into(),
            block: "s".into(),
            cost: 1,
            firings_per_image: 4,
            inputs: vec![],
            outputs: vec![res, buf],
            is_source: true,
        });
        p.add_stage(StageSpec {
            name: "dymm".into(),
            block: "d".into(),
            cost: 1,
            firings_per_image: 4,
            inputs: vec![buf],
            outputs: vec![gated],
            is_source: false,
        });
        let sink = p.add_stage(StageSpec {
            name: "join".into(),
            block: "j".into(),
            cost: 1,
            firings_per_image: 4,
            inputs: vec![res, gated],
            outputs: vec![],
            is_source: false,
        });
        p.sink = sink;
        let r = run(&p, 2, 100_000);
        assert_eq!(r.stop, StopReason::Completed);
    }
}

#[cfg(test)]
mod fast_tests {
    use super::*;
    use crate::arch::parallelism::design_network;
    use crate::model::{Precision, ViTConfig};
    use crate::sim::builder::{build_vit, Paradigm, SimConfig};

    #[test]
    fn fast_matches_reference_on_deit() {
        let cfg = ViTConfig::deit_tiny();
        let d = design_network(&cfg, Precision::A4W3, 2);
        let p = build_vit(&d, &cfg, Paradigm::Hybrid, SimConfig::matched(&d, &cfg));
        let slow = run(&p, 3, 5_000_000);
        let fast = run_fast(&p, 3, 5_000_000);
        assert_eq!(fast.stop, StopReason::Completed);
        assert_eq!(fast.stable_ii(), slow.stable_ii(), "steady state must agree exactly");
        let (a, b) = (
            fast.first_image_latency().unwrap() as i64,
            slow.first_image_latency().unwrap() as i64,
        );
        // fill-phase cascade idealization: within a handful of cycles
        assert!((a - b).abs() < 200, "first image fast {a} vs slow {b}");
    }

    #[test]
    fn fast_matches_reference_deadlock_verdict() {
        let cfg = ViTConfig::deit_tiny();
        let d = design_network(&cfg, Precision::A4W3, 2);
        let p = build_vit(&d, &cfg, Paradigm::FineGrained, SimConfig::matched(&d, &cfg));
        assert!(matches!(run_fast(&p, 1, 100_000_000).stop, StopReason::Deadlock { .. }));
    }

    #[test]
    fn fast_matches_reference_on_coarse() {
        let cfg = ViTConfig::tiny_synth();
        let d = design_network(&cfg, Precision::A4W4, 2);
        let p = build_vit(&d, &cfg, Paradigm::CoarseGrained, SimConfig::matched(&d, &cfg));
        let slow = run(&p, 3, 100_000_000);
        let fast = run_fast(&p, 3, 100_000_000);
        // coarse mode puts whole-image handoff cascades on the critical
        // path, where the fixpoint idealization may differ by a cycle or
        // two per handoff (hybrid steady state is exact — see above)
        let (a, b) = (fast.stable_ii().unwrap() as i64, slow.stable_ii().unwrap() as i64);
        assert!((a - b).abs() <= 4, "fast {a} vs slow {b}");
    }

    #[test]
    fn fast_total_firings_conserved() {
        let cfg = ViTConfig::tiny_synth();
        let d = design_network(&cfg, Precision::A4W4, 2);
        let p = build_vit(&d, &cfg, Paradigm::Hybrid, SimConfig::matched(&d, &cfg));
        let slow = run(&p, 2, 100_000_000);
        let fast = run_fast(&p, 2, 100_000_000);
        for (a, b) in slow.stage_states.iter().zip(&fast.stage_states) {
            assert_eq!(a.total_firings, b.total_firings);
            assert_eq!(a.busy_cycles, b.busy_cycles);
        }
    }
}
