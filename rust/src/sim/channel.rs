//! Inter-stage channels of the hybrid-grained pipeline (Sec. 4.2):
//! FIFOs (fine-grained, tile/token-group granularity), deep buffers
//! (coarse-grained whole-tensor stores for K/V), and PIPO buffers (the
//! coarse-grained baseline paradigm).
//!
//! The simulator tracks *token groups* (TP tokens each) as its flow unit;
//! data values are irrelevant to the cycle behaviour.

/// Channel semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChannelKind {
    /// First-in first-out, `cap` groups. Fine-grained streaming.
    Fifo { cap: u64 },
    /// Whole-tensor store: the reader may only start once all
    /// `groups_per_image` groups of the current image are present; reads
    /// are non-destructive (the DyMM re-reads the tensor COT times); the
    /// writer may not write the *next* image until the reader releases.
    DeepBuffer { groups_per_image: u64 },
    /// Ping-pong pair of whole-tensor buffers (coarse-grained baseline):
    /// writer fills one bank while the reader drains the other.
    Pipo { groups_per_image: u64 },
}

/// Runtime state of a channel.
#[derive(Debug, Clone)]
pub struct Channel {
    pub name: String,
    pub kind: ChannelKind,
    /// Groups currently enqueued (FIFO) or written of the filling image.
    pub occupancy: u64,
    /// DeepBuffer/Pipo: image id currently readable (None until first fill).
    pub readable_image: Option<u64>,
    /// DeepBuffer/Pipo: image id currently being written.
    pub writing_image: u64,
    /// Pipo: banks filled and not yet released (0..=2).
    pub full_banks: u64,
    /// High-water mark of FIFO occupancy (buffer sizing evidence).
    pub max_occupancy: u64,
    /// Total groups pushed through (throughput accounting).
    pub pushed: u64,
}

impl Channel {
    pub fn new(name: impl Into<String>, kind: ChannelKind) -> Self {
        Self {
            name: name.into(),
            kind,
            occupancy: 0,
            readable_image: None,
            writing_image: 0,
            full_banks: 0,
            max_occupancy: 0,
            pushed: 0,
        }
    }

    /// Can the producer push one group (of its current image)?
    pub fn can_push(&self) -> bool {
        match self.kind {
            ChannelKind::Fifo { cap } => self.occupancy < cap,
            ChannelKind::DeepBuffer { groups_per_image } => {
                // single physical buffer: writable while filling; once the
                // image is complete the writer must wait for release
                self.readable_image.is_none() && self.occupancy < groups_per_image
            }
            ChannelKind::Pipo { groups_per_image } => {
                self.full_banks < 2 && self.occupancy < groups_per_image
            }
        }
    }

    pub fn push(&mut self) {
        debug_assert!(self.can_push(), "{}: push on full channel", self.name);
        self.occupancy += 1;
        self.pushed += 1;
        self.max_occupancy = self.max_occupancy.max(self.occupancy);
        match self.kind {
            ChannelKind::DeepBuffer { groups_per_image } => {
                if self.occupancy == groups_per_image {
                    self.readable_image = Some(self.writing_image);
                    self.writing_image += 1;
                }
            }
            ChannelKind::Pipo { groups_per_image } => {
                if self.occupancy == groups_per_image {
                    self.full_banks += 1;
                    if self.readable_image.is_none() {
                        self.readable_image = Some(self.writing_image);
                    }
                    self.writing_image += 1;
                    if self.full_banks < 2 {
                        self.occupancy = 0; // start filling the other bank
                    }
                }
            }
            ChannelKind::Fifo { .. } => {}
        }
    }

    /// Can the consumer take its next unit? For FIFOs: one group queued.
    /// For DeepBuffer/Pipo: the image `img` is fully resident.
    pub fn can_consume(&self, img: u64) -> bool {
        match self.kind {
            ChannelKind::Fifo { .. } => self.occupancy > 0,
            ChannelKind::DeepBuffer { .. } | ChannelKind::Pipo { .. } => {
                self.readable_image == Some(img)
            }
        }
    }

    /// Consume for one firing: pops a group from a FIFO; no-op for buffers
    /// (non-destructive reads).
    pub fn consume(&mut self, img: u64) {
        match self.kind {
            ChannelKind::Fifo { .. } => {
                debug_assert!(self.occupancy > 0, "{}: pop on empty fifo", self.name);
                self.occupancy -= 1;
            }
            _ => debug_assert!(self.readable_image == Some(img)),
        }
    }

    /// Reader finished the image held in a DeepBuffer / one Pipo bank.
    pub fn release(&mut self, img: u64) {
        match self.kind {
            ChannelKind::DeepBuffer { .. } => {
                debug_assert_eq!(self.readable_image, Some(img), "{}", self.name);
                self.readable_image = None;
                self.occupancy = 0;
            }
            ChannelKind::Pipo { groups_per_image } => {
                debug_assert_eq!(self.readable_image, Some(img), "{}", self.name);
                self.full_banks -= 1;
                self.readable_image = if self.full_banks > 0 { Some(img + 1) } else { None };
                if self.full_banks == 1 && self.occupancy == groups_per_image {
                    // the bank just released becomes writable
                    self.occupancy = 0;
                }
            }
            ChannelKind::Fifo { .. } => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_push_pop_capacity() {
        let mut c = Channel::new("f", ChannelKind::Fifo { cap: 2 });
        assert!(c.can_push());
        c.push();
        c.push();
        assert!(!c.can_push());
        assert!(c.can_consume(0));
        c.consume(0);
        assert!(c.can_push());
        assert_eq!(c.max_occupancy, 2);
    }

    #[test]
    fn deep_buffer_requires_full_image() {
        let mut c = Channel::new("k", ChannelKind::DeepBuffer { groups_per_image: 3 });
        c.push();
        c.push();
        assert!(!c.can_consume(0), "not full yet");
        c.push();
        assert!(c.can_consume(0));
        assert!(!c.can_push(), "single-buffered: next image blocked");
        c.release(0);
        assert!(c.can_push());
        assert!(!c.can_consume(1));
    }

    #[test]
    fn deep_buffer_reads_are_non_destructive() {
        let mut c = Channel::new("k", ChannelKind::DeepBuffer { groups_per_image: 2 });
        c.push();
        c.push();
        for _ in 0..10 {
            assert!(c.can_consume(0));
            c.consume(0);
        }
    }

    #[test]
    fn pipo_double_buffers() {
        let mut c = Channel::new("p", ChannelKind::Pipo { groups_per_image: 2 });
        c.push();
        c.push(); // bank 0 full -> readable img 0
        assert!(c.can_consume(0));
        assert!(c.can_push(), "second bank writable");
        c.push();
        c.push(); // bank 1 full
        assert!(!c.can_push(), "both banks full");
        c.release(0);
        assert!(c.can_consume(1), "bank 1 readable after release");
        assert!(c.can_push(), "released bank writable again");
    }
}
