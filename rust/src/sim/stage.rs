//! Pipeline stages: decentralized FSMs with AXI-Stream-like handshakes
//! (paper Sec. 4.1 — "each stage is controlled by its own FSM ...
//! modules are completely decoupled").
//!
//! The simulation unit is one *firing* = processing TP tokens (one token
//! group). A module's Table-1 initiation interval decomposes as
//! `II = firings_per_image * cost_per_firing`.

/// Static description of a stage.
#[derive(Debug, Clone)]
pub struct StageSpec {
    pub name: String,
    /// Network block this stage belongs to (timing-diagram grouping),
    /// e.g. "PatchEmbed", "MHA3", "MLP7", "Head".
    pub block: String,
    /// Cycles per firing (II / TT).
    pub cost: u64,
    /// Firings per image (TT; 1 for whole-image stages like Head).
    pub firings_per_image: u64,
    /// Input channel ids (all must be ready to fire).
    pub inputs: Vec<usize>,
    /// Output channel ids (all must have space to fire; one group pushed
    /// to each on completion).
    pub outputs: Vec<usize>,
    /// Source stages generate groups with no inputs (the DMA loader).
    pub is_source: bool,
}

impl StageSpec {
    pub fn ii(&self) -> u64 {
        self.cost * self.firings_per_image
    }
}

/// Mutable FSM state.
#[derive(Debug, Clone, Default)]
pub struct StageState {
    /// Image currently being processed.
    pub image: u64,
    /// Firings completed within the current image.
    pub fired: u64,
    /// Remaining busy cycles of the current firing (0 = idle).
    pub busy: u64,
    /// Total busy cycles (utilization accounting).
    pub busy_cycles: u64,
    /// Total firings across all images.
    pub total_firings: u64,
    /// Per-image (first_start_cycle, last_end_cycle).
    pub image_spans: Vec<(u64, u64)>,
    /// Stall cycles attributed to inputs-not-ready vs outputs-full.
    pub stall_in: u64,
    pub stall_out: u64,
}

impl StageState {
    pub fn record_start(&mut self, cycle: u64) {
        let img = self.image as usize;
        while self.image_spans.len() <= img {
            self.image_spans.push((u64::MAX, 0));
        }
        let e = &mut self.image_spans[img];
        e.0 = e.0.min(cycle);
    }

    pub fn record_end(&mut self, cycle: u64) {
        let img = self.image as usize;
        while self.image_spans.len() <= img {
            self.image_spans.push((u64::MAX, 0));
        }
        self.image_spans[img].1 = cycle;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ii_decomposition() {
        let s = StageSpec {
            name: "Softmax".into(),
            block: "MHA0".into(),
            cost: 588,
            firings_per_image: 98,
            inputs: vec![],
            outputs: vec![],
            is_source: false,
        };
        assert_eq!(s.ii(), 57_624); // Table 1 / Fig 12 stable II
    }

    #[test]
    fn spans_track_min_start_max_end() {
        let mut st = StageState::default();
        st.record_start(100);
        st.record_end(150);
        st.record_start(90);
        st.record_end(200);
        assert_eq!(st.image_spans[0], (90, 200));
    }
}
