//! Timing-diagram extraction (Fig. 12): per network block and per image,
//! the first-start / last-end cycles, plus the headline numbers the paper
//! reports (stable II, first-image total cycles, latency, ideal FPS).

use super::engine::SimReport;

/// One block x image span.
#[derive(Debug, Clone)]
pub struct BlockSpan {
    pub block: String,
    pub image: u64,
    pub start: u64,
    pub end: u64,
}

/// Aggregate stage spans into block spans (min start / max end).
pub fn block_spans(report: &SimReport) -> Vec<BlockSpan> {
    use std::collections::BTreeMap;
    let mut agg: BTreeMap<(String, u64), (u64, u64)> = BTreeMap::new();
    for (spec, st) in report.stage_specs.iter().zip(&report.stage_states) {
        for (img, &(s, e)) in st.image_spans.iter().enumerate() {
            if s == u64::MAX {
                continue;
            }
            let key = (spec.block.clone(), img as u64);
            let entry = agg.entry(key).or_insert((u64::MAX, 0));
            entry.0 = entry.0.min(s);
            entry.1 = entry.1.max(e);
        }
    }
    agg.into_iter()
        .map(|((block, image), (start, end))| BlockSpan { block, image, start, end })
        .collect()
}

/// The Fig. 12 headline numbers.
#[derive(Debug, Clone)]
pub struct TimingSummary {
    pub stable_ii: u64,
    pub first_image_cycles: u64,
    pub freq_hz: f64,
    pub latency_ms: f64,
    pub ideal_fps: f64,
}

pub fn summarize(report: &SimReport, freq_hz: f64) -> Option<TimingSummary> {
    let stable_ii = report.stable_ii()?;
    let first = report.first_image_latency()?;
    Some(TimingSummary {
        stable_ii,
        first_image_cycles: first,
        freq_hz,
        latency_ms: stable_ii as f64 / freq_hz * 1e3,
        ideal_fps: freq_hz / stable_ii as f64,
    })
}

/// Render an ASCII Gantt chart of the block spans (one row per block,
/// one column per `cycles_per_col` cycles; images as distinct glyphs).
pub fn render_gantt(report: &SimReport, width: usize) -> String {
    let spans = block_spans(report);
    if spans.is_empty() {
        return "(no spans)".into();
    }
    let max_cycle = spans.iter().map(|s| s.end).max().unwrap().max(1);
    let per_col = max_cycle.div_ceil(width as u64).max(1);
    // preserve first-appearance block order
    let mut blocks: Vec<String> = Vec::new();
    for s in &spans {
        if !blocks.contains(&s.block) {
            blocks.push(s.block.clone());
        }
    }
    let glyphs = ['1', '2', '3', '4', '5', '6', '7', '8', '9'];
    let mut out = String::new();
    out.push_str(&format!("cycles 0..{max_cycle} ({per_col}/col)\n"));
    for b in &blocks {
        let mut row = vec![' '; width];
        for s in spans.iter().filter(|s| &s.block == b) {
            let g = glyphs[(s.image as usize) % glyphs.len()];
            let c0 = (s.start / per_col) as usize;
            let c1 = ((s.end / per_col) as usize).min(width - 1);
            for c in row.iter_mut().take(c1 + 1).skip(c0) {
                *c = g;
            }
        }
        out.push_str(&format!("{:>12} |{}|\n", b, row.iter().collect::<String>()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::parallelism::design_network;
    use crate::model::{Precision, ViTConfig};
    use crate::sim::builder::{build_vit, Paradigm, SimConfig};
    use crate::sim::engine::run;

    #[test]
    fn spans_cover_all_blocks() {
        let cfg = ViTConfig::tiny_synth();
        let d = design_network(&cfg, Precision::A4W4, 2);
        let p = build_vit(&d, &cfg, Paradigm::Hybrid, SimConfig::matched(&d, &cfg));
        let r = run(&p, 2, 50_000_000);
        let spans = block_spans(&r);
        let blocks: std::collections::BTreeSet<_> = spans.iter().map(|s| s.block.clone()).collect();
        // DMA + PatchEmbed + 4x(MHA, MLP) + Head
        assert_eq!(blocks.len(), 2 + 2 * cfg.depth + 1);
    }

    #[test]
    fn gantt_renders() {
        let cfg = ViTConfig::tiny_synth();
        let d = design_network(&cfg, Precision::A4W4, 2);
        let p = build_vit(&d, &cfg, Paradigm::Hybrid, SimConfig::matched(&d, &cfg));
        let r = run(&p, 2, 50_000_000);
        let g = render_gantt(&r, 80);
        assert!(g.contains("MHA0"));
        assert!(g.contains('1') && g.contains('2'));
    }
}
