//! Build simulatable pipelines from a network design, in any of the
//! paper's paradigms (Fig. 2): the hybrid-grained pipeline (ours), the
//! coarse-grained baseline (all-PIPO), and the fine-grained attempt
//! (small FIFOs only — deadlocks on ViT, reproducing "ViT Compatibility
//! ✗" of Fig. 2c).

use super::channel::ChannelKind;
use super::engine::Pipeline;
use super::stage::StageSpec;
use crate::arch::parallelism::Design;
use crate::model::ViTConfig;

/// Pipeline paradigm to construct (Fig. 2a).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Paradigm {
    /// Deep buffers on K/V + deep FIFOs on residual/Q (the paper).
    Hybrid,
    /// Whole-tensor PIPO buffers everywhere.
    CoarseGrained,
    /// Streaming FIFOs only, sized for CNN-style locality.
    FineGrained,
}

/// Simulator construction parameters.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Token-group capacity of the deep FIFOs (residual + Q branches).
    /// The paper's "typical depth of deep FIFOs is 512" (tokens) = 256
    /// groups at TP=2.
    pub deep_fifo_cap: u64,
    /// Capacity of ordinary inter-stage FIFOs (HLS stream depth).
    pub small_fifo_cap: u64,
    /// Cycles between DMA input group arrivals (match the pipeline II).
    pub source_interval: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self { deep_fifo_cap: 256, small_fifo_cap: 4, source_interval: 588 }
    }
}

impl SimConfig {
    /// Match the DMA input rate to the design's balance target so the
    /// source is never the bottleneck nor idle (the paper streams input
    /// tiles at the pipeline's pace).
    ///
    /// Clamped to >= 1: when `target_ii < tt` (small networks / deep
    /// token tiling) the integer division would truncate to a zero-cost
    /// source stage, which the engine treats as never-firing — the sim
    /// would spin until the cycle budget instead of completing.
    pub fn matched(design: &Design, cfg: &ViTConfig) -> Self {
        let tt = (cfg.tokens() as u64).div_ceil(2);
        Self { source_interval: (design.target_ii / tt).max(1), ..Self::default() }
    }
}

/// Build the full-network pipeline for a design.
pub fn build_vit(
    design: &Design,
    cfg: &ViTConfig,
    paradigm: Paradigm,
    sim: SimConfig,
) -> Pipeline {
    let mut p = Pipeline::default();
    let tt = (cfg.tokens() as u64).div_ceil(2); // TP = 2 throughout

    let cost = |name: &str| -> u64 {
        let m = design
            .find(name)
            .unwrap_or_else(|| panic!("module '{name}' missing from design"));
        m.ii / m.tt.max(1)
    };

    // channel constructors per paradigm
    let stream = |p: &mut Pipeline, name: String| -> usize {
        match paradigm {
            Paradigm::CoarseGrained => {
                p.add_channel(name, ChannelKind::Pipo { groups_per_image: tt })
            }
            _ => p.add_channel(name, ChannelKind::Fifo { cap: sim.small_fifo_cap }),
        }
    };
    let deep_fifo = |p: &mut Pipeline, name: String| -> usize {
        match paradigm {
            Paradigm::CoarseGrained => {
                p.add_channel(name, ChannelKind::Pipo { groups_per_image: tt })
            }
            Paradigm::Hybrid => p.add_channel(name, ChannelKind::Fifo { cap: sim.deep_fifo_cap }),
            Paradigm::FineGrained => {
                p.add_channel(name, ChannelKind::Fifo { cap: sim.small_fifo_cap })
            }
        }
    };
    // K/V deep buffers are double-banked (Fig. 6: Image2's K/V tokens load
    // while Image1's are being consumed; the buffers "refresh" with no gap)
    // — single-banked buffers would serialize fill and drain and double
    // the stable II.
    let tensor_buf = |p: &mut Pipeline, name: String| -> usize {
        p.add_channel(name, ChannelKind::Pipo { groups_per_image: tt })
    };

    // ---- DMA source + PatchEmbed -----------------------------------------
    let pe_in = stream(&mut p, "pe_in".into());
    p.add_stage(StageSpec {
        name: "DMA-in".into(),
        block: "DMA".into(),
        cost: sim.source_interval,
        firings_per_image: tt,
        inputs: vec![],
        outputs: vec![pe_in],
        is_source: true,
    });

    // every block boundary carries (main stream, residual stream)
    let mut ln_in = stream(&mut p, "b0.x".into());
    let mut res_in = deep_fifo(&mut p, "b0.res".into());
    p.add_stage(StageSpec {
        name: "PatchEmbed".into(),
        block: "PatchEmbed".into(),
        cost: cost("PatchEmbed"),
        firings_per_image: tt,
        inputs: vec![pe_in],
        outputs: vec![ln_in, res_in],
        is_source: false,
    });

    for blk in 0..cfg.depth {
        let b = |n: &str| format!("b{blk}.{n}");
        let mha = format!("MHA{blk}");
        let mlp = format!("MLP{blk}");

        // ---- MHA ----------------------------------------------------------
        let qkv_in = stream(&mut p, b("qkv_in"));
        p.add_stage(StageSpec {
            name: b("LayerNorm1"),
            block: mha.clone(),
            cost: cost(&b("LayerNorm1")),
            firings_per_image: tt,
            inputs: vec![ln_in],
            outputs: vec![qkv_in],
            is_source: false,
        });

        let q = deep_fifo(&mut p, b("q"));
        let k_buf = tensor_buf(&mut p, b("k_buf"));
        let v_tr = stream(&mut p, b("v_tr"));
        p.add_stage(StageSpec {
            name: b("QKVGen"),
            block: mha.clone(),
            cost: cost(&b("QKVGen0")),
            firings_per_image: tt,
            inputs: vec![qkv_in],
            outputs: vec![q, k_buf, v_tr],
            is_source: false,
        });

        // Transpose Module (Sec. 4.2): re-orders V into row-wise access
        let v_buf = tensor_buf(&mut p, b("v_buf"));
        p.add_stage(StageSpec {
            name: b("Transpose"),
            block: mha.clone(),
            cost: 1,
            firings_per_image: tt,
            inputs: vec![v_tr],
            outputs: vec![v_buf],
            is_source: false,
        });

        let scores = stream(&mut p, b("scores"));
        p.add_stage(StageSpec {
            name: b("QKMatMul"),
            block: mha.clone(),
            cost: cost(&b("QKMatMul0")),
            firings_per_image: tt,
            inputs: vec![q, k_buf],
            outputs: vec![scores],
            is_source: false,
        });

        let probs = stream(&mut p, b("probs"));
        p.add_stage(StageSpec {
            name: b("Softmax"),
            block: mha.clone(),
            cost: cost(&b("Softmax")),
            firings_per_image: tt,
            inputs: vec![scores],
            outputs: vec![probs],
            is_source: false,
        });

        let attn = stream(&mut p, b("attn"));
        p.add_stage(StageSpec {
            name: b("RVMatMul"),
            block: mha.clone(),
            cost: cost(&b("RVMatMul0")),
            firings_per_image: tt,
            inputs: vec![probs, v_buf],
            outputs: vec![attn],
            is_source: false,
        });

        let proj_out = stream(&mut p, b("proj_out"));
        p.add_stage(StageSpec {
            name: b("OutputProj"),
            block: mha.clone(),
            cost: cost(&b("OutputProj")),
            firings_per_image: tt,
            inputs: vec![attn],
            outputs: vec![proj_out],
            is_source: false,
        });

        let ln2_in = stream(&mut p, b("ln2_in"));
        let res2 = deep_fifo(&mut p, b("res2"));
        p.add_stage(StageSpec {
            name: b("ResidualAdd1"),
            block: mha.clone(),
            cost: cost(&b("ResidualAdd1")),
            firings_per_image: tt,
            inputs: vec![res_in, proj_out],
            outputs: vec![ln2_in, res2],
            is_source: false,
        });

        // ---- MLP ----------------------------------------------------------
        let mm1_in = stream(&mut p, b("mm1_in"));
        p.add_stage(StageSpec {
            name: b("LayerNorm2"),
            block: mlp.clone(),
            cost: cost(&b("LayerNorm2")),
            firings_per_image: tt,
            inputs: vec![ln2_in],
            outputs: vec![mm1_in],
            is_source: false,
        });

        let gelu_in = stream(&mut p, b("gelu_in"));
        p.add_stage(StageSpec {
            name: b("MatMul1"),
            block: mlp.clone(),
            cost: cost(&b("MatMul1")),
            firings_per_image: tt,
            inputs: vec![mm1_in],
            outputs: vec![gelu_in],
            is_source: false,
        });

        let mm2_in = stream(&mut p, b("mm2_in"));
        p.add_stage(StageSpec {
            name: b("GeLU"),
            block: mlp.clone(),
            cost: cost(&b("GeLU")),
            firings_per_image: tt,
            inputs: vec![gelu_in],
            outputs: vec![mm2_in],
            is_source: false,
        });

        let mlp_out = stream(&mut p, b("mlp_out"));
        p.add_stage(StageSpec {
            name: b("MatMul2"),
            block: mlp.clone(),
            cost: cost(&b("MatMul2")),
            firings_per_image: tt,
            inputs: vec![mm2_in],
            outputs: vec![mlp_out],
            is_source: false,
        });

        let next_ln = stream(&mut p, format!("b{}.x", blk + 1));
        let next_res = deep_fifo(&mut p, format!("b{}.res", blk + 1));
        p.add_stage(StageSpec {
            name: b("ResidualAdd2"),
            block: mlp.clone(),
            cost: cost(&b("ResidualAdd2")),
            firings_per_image: tt,
            inputs: vec![res2, mlp_out],
            outputs: vec![next_ln, next_res],
            is_source: false,
        });

        ln_in = next_ln;
        res_in = next_res;
    }

    // ---- final LN + pooled head -------------------------------------------
    // the residual stream of the would-be next block is unused: absorb it
    // with a zero-cost drain so the last ResidualAdd2 is never blocked.
    let head_buf = tensor_buf(&mut p, "head_buf".into());
    p.add_stage(StageSpec {
        name: "LayerNormF".into(),
        block: "Head".into(),
        cost: cost("LayerNormF"),
        firings_per_image: tt,
        inputs: vec![ln_in],
        outputs: vec![head_buf],
        is_source: false,
    });
    p.add_stage(StageSpec {
        name: "ResDrain".into(),
        block: "Head".into(),
        cost: 1,
        firings_per_image: tt,
        inputs: vec![res_in],
        outputs: vec![],
        is_source: false,
    });

    // head emits ONE group per image — always a plain FIFO, never PIPO
    let head_out = p.add_channel("head_out", ChannelKind::Fifo { cap: sim.small_fifo_cap });
    p.add_stage(StageSpec {
        name: "Head".into(),
        block: "Head".into(),
        cost: cost("Head"),
        firings_per_image: 1,
        inputs: vec![head_buf],
        outputs: vec![head_out],
        is_source: false,
    });

    let sink = p.add_stage(StageSpec {
        name: "DMA-out".into(),
        block: "DMA".into(),
        cost: 1,
        firings_per_image: 1,
        inputs: vec![head_out],
        outputs: vec![],
        is_source: false,
    });
    p.sink = sink;
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::parallelism::design_network;
    use crate::model::Precision;
    use crate::sim::engine::{run, StopReason};

    fn tiny() -> (Design, ViTConfig) {
        let cfg = ViTConfig::tiny_synth();
        (design_network(&cfg, Precision::A4W4, 2), cfg)
    }

    #[test]
    fn matched_clamps_source_interval_to_one() {
        // regression: target_ii < tt used to truncate to a zero-cost DMA
        // source, which starts a firing every cycle but never completes
        // one — sim::run spun until the cycle budget
        let (mut d, cfg) = tiny();
        let tt = (cfg.tokens() as u64).div_ceil(2);
        d.target_ii = tt - 1; // forces target_ii / tt == 0
        let sim = SimConfig::matched(&d, &cfg);
        assert_eq!(sim.source_interval, 1);
        let r = run(&build_vit(&d, &cfg, Paradigm::Hybrid, sim), 1, 50_000_000);
        assert_eq!(r.stop, StopReason::Completed, "{:?}", r.stop);
    }

    #[test]
    fn hybrid_tiny_completes() {
        let (d, cfg) = tiny();
        let p = build_vit(&d, &cfg, Paradigm::Hybrid, SimConfig::default());
        let r = run(&p, 3, 50_000_000);
        assert_eq!(r.stop, StopReason::Completed, "{:?}", r.stop);
        assert!(r.stable_ii().is_some());
    }

    #[test]
    fn coarse_tiny_completes_with_higher_latency() {
        let (d, cfg) = tiny();
        let sim = SimConfig::default();
        let h = run(&build_vit(&d, &cfg, Paradigm::Hybrid, sim), 3, 50_000_000);
        let c = run(&build_vit(&d, &cfg, Paradigm::CoarseGrained, sim), 3, 100_000_000);
        assert_eq!(c.stop, StopReason::Completed, "{:?}", c.stop);
        assert!(
            c.first_image_latency().unwrap() > h.first_image_latency().unwrap(),
            "coarse {} !> hybrid {}",
            c.first_image_latency().unwrap(),
            h.first_image_latency().unwrap()
        );
    }

    #[test]
    fn fine_grained_deadlocks_on_vit() {
        // Fig 2c: "ViT Compatibility: X" — without deep FIFOs the global
        // attention dependency wedges the pipeline
        let (d, cfg) = tiny();
        let p = build_vit(&d, &cfg, Paradigm::FineGrained, SimConfig::default());
        let r = run(&p, 1, 50_000_000);
        assert!(matches!(r.stop, StopReason::Deadlock { .. }), "{:?}", r.stop);
    }
}
