//! Architecture-paradigm models (Fig. 2): temporal (GeMM), coarse-grained
//! pipeline, fine-grained pipeline, hybrid-grained pipeline — buffer
//! cost, off-chip traffic, throughput and latency characteristics.

use crate::arch::parallelism::Design;
use crate::model::{Precision, ViTConfig};
use crate::platform::{BRAM_DEPTH, BRAM_WIDTH};

/// Paradigm identifiers (superset of `sim::Paradigm`: includes temporal,
/// which has no pipeline to simulate).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParadigmKind {
    Temporal,
    CoarseGrained,
    FineGrained,
    HybridGrained,
}

impl ParadigmKind {
    pub fn label(&self) -> &'static str {
        match self {
            ParadigmKind::Temporal => "Temporal (GeMM)",
            ParadigmKind::CoarseGrained => "Coarse-grained pipeline",
            ParadigmKind::FineGrained => "Fine-grained pipeline",
            ParadigmKind::HybridGrained => "Hybrid-grained pipeline",
        }
    }
}

// ---------------------------------------------------------------------------
// activation buffer accounting (Fig. 3 challenge 1b / Fig. 7b)
// ---------------------------------------------------------------------------

/// BRAMs for a buffer holding `tokens` tokens of `channels` values at
/// `bits` each, banked for one-token-wide access.
pub fn tensor_buffer_brams(tokens: u64, channels: u64, bits: u64) -> u64 {
    let width_banks = (channels * bits).div_ceil(BRAM_WIDTH);
    let depth_banks = tokens.div_ceil(BRAM_DEPTH);
    width_banks * depth_banks
}

/// Residual-path buffer accounting for one attention block (the paper's
/// Fig. 7b: "the residual buffer cost is significantly reduced by 83.3%
/// compared to traditional PIPO implementation").
#[derive(Debug, Clone)]
pub struct ResidualBufferReport {
    /// Pipeline stages the residual must cross in the MHA block.
    pub pipo_stages: u64,
    /// Tensor-buffers (1 image of residual each) in the coarse PIPO
    /// scheme: stages x 2 (ping + pong).
    pub coarse_tensor_buffers: u64,
    /// Tensor-buffers in the hybrid deep-FIFO scheme.
    pub hybrid_tensor_buffers: u64,
    pub brams_per_tensor: u64,
    pub coarse_brams: u64,
    pub hybrid_brams: u64,
    pub saving: f64,
}

pub fn residual_buffer_report(cfg: &ViTConfig, prec: Precision) -> ResidualBufferReport {
    // the residual skips LN, QKV Gen, QK MatMul, Softmax, RV MatMul and
    // Output Proj: 6 stages (paper: "6 PIPO stages (168 BRAMs)")
    let pipo_stages = 6;
    let coarse_tensor_buffers = pipo_stages * 2;
    // hybrid: one deep FIFO sized ~1 image on the MHA residual plus the
    // equally-sized Q-branch FIFO
    let hybrid_tensor_buffers = 2;
    let brams_per_tensor =
        tensor_buffer_brams(cfg.tokens() as u64, cfg.dim as u64, prec.act_bits as u64);
    let coarse_brams = coarse_tensor_buffers * brams_per_tensor;
    let hybrid_brams = hybrid_tensor_buffers * brams_per_tensor;
    ResidualBufferReport {
        pipo_stages,
        coarse_tensor_buffers,
        hybrid_tensor_buffers,
        brams_per_tensor,
        coarse_brams,
        hybrid_brams,
        saving: 1.0 - hybrid_brams as f64 / coarse_brams as f64,
    }
}

/// Whole-network activation-buffer BRAMs per paradigm.
pub fn activation_buffer_brams(design: &Design, cfg: &ViTConfig, kind: ParadigmKind) -> u64 {
    let t = cfg.tokens() as u64;
    let a = design.precision.act_bits as u64;
    let mut total = 0u64;
    for m in &design.modules {
        let out_ch = if m.spec.is_mm() { m.spec.co as u64 } else { m.spec.ci as u64 };
        match kind {
            ParadigmKind::CoarseGrained => {
                // every inter-stage tensor double-buffered
                total += 2 * tensor_buffer_brams(t, out_ch, a);
            }
            ParadigmKind::Temporal => {}
            ParadigmKind::FineGrained | ParadigmKind::HybridGrained => {
                // small FIFOs: a few groups — count 1 BRAM each
                total += 1;
            }
        }
    }
    match kind {
        ParadigmKind::Temporal => {
            // one global double-buffered scratch the size of the largest tensor
            let max_ch = design
                .modules
                .iter()
                .map(|m| if m.spec.is_mm() { m.spec.co as u64 } else { m.spec.ci as u64 })
                .max()
                .unwrap_or(0);
            2 * tensor_buffer_brams(t, max_ch, a)
        }
        ParadigmKind::HybridGrained => {
            // plus per-layer: 2 deep FIFOs + double-banked K/V buffers
            let dh = cfg.head_dim() as u64;
            let per_layer = 2 * tensor_buffer_brams(512, cfg.dim as u64, a)
                + 2 * 2 * cfg.heads as u64 * tensor_buffer_brams(t, dh, a);
            total + cfg.depth as u64 * per_layer
        }
        _ => total,
    }
}

// ---------------------------------------------------------------------------
// off-chip traffic models (roofline inputs, Fig. 1)
// ---------------------------------------------------------------------------

/// Temporal traffic when every tensor streams exactly once (a perfectly
/// fused temporal engine — the optimistic end of the GeMM spectrum, used
/// for the "GeMM + LUT MACs" roofline point).
pub fn temporal_traffic_once(design: &Design, cfg: &ViTConfig) -> u64 {
    let a_bits = design.precision.act_bits as u64;
    let w_bits = design.precision.weight_bits as u64;
    let t = cfg.tokens() as u64;
    let io = (t * cfg.patch_dim() as u64 * 8 + cfg.num_classes as u64 * 32) / 8;
    let mut bytes = io;
    for m in &design.modules {
        let (tm, ci, co) = (m.spec.t as u64, m.spec.ci as u64, m.spec.co as u64);
        if m.spec.is_mm() {
            bytes += (tm * ci * a_bits + ci * co * w_bits + tm * co * a_bits) / 8;
        } else {
            bytes += 2 * tm * ci * a_bits / 8;
        }
    }
    bytes
}

/// Bytes moved to/from DRAM per inference.
pub fn offchip_traffic_bytes(design: &Design, cfg: &ViTConfig, kind: ParadigmKind) -> u64 {
    let a_bits = design.precision.act_bits as u64;
    let w_bits = design.precision.weight_bits as u64;
    let t = cfg.tokens() as u64;
    let io = (t * cfg.patch_dim() as u64 * 8 + cfg.num_classes as u64 * 32) / 8;
    match kind {
        ParadigmKind::Temporal => {
            // every operator's inputs and outputs round-trip; tiled GeMM
            // re-reads the stationary operand T/TILE times
            const TILE: u64 = 64;
            let mut bytes = io;
            for m in &design.modules {
                let (tm, ci, co) = (m.spec.t as u64, m.spec.ci as u64, m.spec.co as u64);
                if m.spec.is_mm() {
                    let reread = tm.div_ceil(TILE).max(1);
                    bytes += (tm * ci * a_bits + ci * co * w_bits * reread + tm * co * a_bits) / 8;
                } else {
                    bytes += 2 * tm * ci * a_bits / 8;
                }
            }
            bytes
        }
        ParadigmKind::CoarseGrained | ParadigmKind::FineGrained => {
            // activations stay on chip; weights stream from DRAM each
            // inference (they do not all fit next to double-buffered tensors)
            let weights: u64 = design.modules.iter().map(|m| m.spec.weight_count()).sum();
            io + weights * w_bits / 8
        }
        ParadigmKind::HybridGrained => io, // weights frozen on chip
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::parallelism::design_network;

    fn setup() -> (Design, ViTConfig) {
        let cfg = ViTConfig::deit_tiny();
        (design_network(&cfg, Precision::A4W4, 2), cfg)
    }

    #[test]
    fn residual_saving_is_83_percent() {
        let cfg = ViTConfig::deit_tiny();
        let r = residual_buffer_report(&cfg, Precision::A4W4);
        assert!((r.saving - 0.8333).abs() < 0.001, "saving {}", r.saving);
        assert_eq!(r.coarse_tensor_buffers, 12);
        assert_eq!(r.hybrid_tensor_buffers, 2);
    }

    #[test]
    fn residual_tensor_brams_near_paper_14() {
        // paper: "buffering one residual tensor consumes 14 BRAMs"
        let cfg = ViTConfig::deit_tiny();
        let r = residual_buffer_report(&cfg, Precision::A4W4);
        assert!((8..=16).contains(&r.brams_per_tensor), "brams/tensor {}", r.brams_per_tensor);
    }

    #[test]
    fn coarse_buffers_dwarf_hybrid_and_temporal() {
        let (d, cfg) = setup();
        let coarse = activation_buffer_brams(&d, &cfg, ParadigmKind::CoarseGrained);
        let hybrid = activation_buffer_brams(&d, &cfg, ParadigmKind::HybridGrained);
        let temporal = activation_buffer_brams(&d, &cfg, ParadigmKind::Temporal);
        assert!(coarse > hybrid, "coarse {coarse} !> hybrid {hybrid}");
        assert!(temporal < coarse, "temporal {temporal} !< coarse {coarse}");
    }

    #[test]
    fn traffic_ordering_matches_fig1() {
        // temporal >> coarse/fine (weights only) >> hybrid (I/O only)
        let (d, cfg) = setup();
        let t = offchip_traffic_bytes(&d, &cfg, ParadigmKind::Temporal);
        let c = offchip_traffic_bytes(&d, &cfg, ParadigmKind::CoarseGrained);
        let h = offchip_traffic_bytes(&d, &cfg, ParadigmKind::HybridGrained);
        assert!(t > 4 * c, "temporal {t} vs coarse {c}");
        assert!(c > 2 * h, "coarse {c} vs hybrid {h}");
        assert!(h < 1_000_000, "{h}");
    }
}
