//! FPGA resource cost model for non-linear operator implementations —
//! regenerates Fig. 11c (LUT-6 / DSP cost, naive vs table).
//!
//! The *naive* (floating-point HLS) costs are constants measured by the
//! paper's HLS synthesis experiments (Sec. 3, Challenge 2); we cannot run
//! Vivado HLS here, so they are adopted verbatim and documented as such.
//! The *table* costs come from a parametric LUTRAM model validated against
//! the paper's reported numbers (within ~15%): a LUT-6 implements a 64x1
//! ROM, the PoT index needs a subtractor + fixed shift + clamp on the
//! input word.



/// Cost of one implementation of a non-linear unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UnitCost {
    pub lut6: u64,
    pub dsp: u64,
}

/// One Fig. 11c row.
#[derive(Debug, Clone)]
pub struct CostRow {
    pub function: &'static str,
    pub table_depth: usize,
    pub table_bits: u32,
    pub naive: UnitCost,
    pub table: UnitCost,
    /// Paper-reported table cost, for side-by-side comparison.
    pub paper_table_lut6: u64,
}

/// Naive floating-point HLS costs (paper constants).
pub const NAIVE_EXP: UnitCost = UnitCost { lut6: 945, dsp: 7 };
pub const NAIVE_GELU: UnitCost = UnitCost { lut6: 1650, dsp: 26 };
pub const NAIVE_RECIP: UnitCost = UnitCost { lut6: 196, dsp: 8 };
pub const NAIVE_RSQRT: UnitCost = UnitCost { lut6: 425, dsp: 9 };
pub const NAIVE_REQUANT: UnitCost = UnitCost { lut6: 0, dsp: 1 };

/// LUT-6 cost of a PoT table: ROM + index subtract/shift/clamp.
///
/// `in_bits = 0` models a ReQuant whose index arithmetic is absorbed into
/// the accumulator truncation (the fused datapath of Sec. 4.4.4).
pub fn table_cost(depth: usize, entry_bits: u32, in_bits: u32) -> UnitCost {
    let rom = depth.div_ceil(64) as u64 * entry_bits as u64;
    let index = in_bits as u64 + (in_bits as u64).div_ceil(2);
    UnitCost { lut6: rom + index, dsp: 0 }
}

/// Cost of a segmented table: two ROMs, one shared index datapath, plus a
/// pivot comparator (one LUT per input bit pair) and the output mux.
pub fn segmented_cost(depth_each: usize, entry_bits: u32, in_bits: u32) -> UnitCost {
    let rom = depth_each.div_ceil(64) as u64 * entry_bits as u64;
    let index = in_bits as u64 + (in_bits as u64).div_ceil(2);
    let compare_mux = (in_bits as u64).div_ceil(2) + entry_bits as u64;
    UnitCost { lut6: 2 * rom + index + compare_mux, dsp: 0 }
}

/// LUT-6 cost of one b-bit x b-bit MAC implemented in fabric
/// (Sec. 4.4.1: a 3-bit multiply = 6 boolean functions of 6 inputs).
pub fn lut_mac_cost(bits: u32) -> u64 {
    // product bits = 2b, each a LUT-6 for b<=3; wider multiplies grow
    // quadratically (Karatsuba-free array multiplier), plus the adder.
    let mult = if bits <= 3 {
        2 * bits as u64
    } else {
        (bits as u64 * bits as u64) / 2 + bits as u64
    };
    let acc = (2 * bits + 4) as u64 / 2; // accumulator add, 2 bits per LUT
    mult + acc
}

/// The Fig. 11c table.
pub fn fig11c() -> Vec<CostRow> {
    vec![
        CostRow {
            function: "Exp",
            table_depth: 64,
            table_bits: 8,
            naive: NAIVE_EXP,
            table: table_cost(64, 8, 24),
            paper_table_lut6: 50,
        },
        CostRow {
            function: "GeLU",
            table_depth: 64,
            table_bits: 3,
            naive: NAIVE_GELU,
            table: table_cost(64, 3, 24),
            paper_table_lut6: 43,
        },
        CostRow {
            function: "Recip",
            table_depth: 128,
            table_bits: 8,
            naive: NAIVE_RECIP,
            table: segmented_cost(64, 8, 16),
            paper_table_lut6: 72,
        },
        CostRow {
            function: "Rsqrt",
            table_depth: 64,
            table_bits: 12,
            naive: NAIVE_RSQRT,
            table: table_cost(64, 12, 22),
            paper_table_lut6: 48,
        },
        CostRow {
            function: "ReQuant",
            table_depth: 64,
            table_bits: 3,
            naive: NAIVE_REQUANT,
            table: table_cost(64, 3, 0),
            paper_table_lut6: 3,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_table_impls_eliminate_dsp() {
        for row in fig11c() {
            assert_eq!(row.table.dsp, 0, "{}", row.function);
            assert!(row.naive.dsp > 0 || row.function == "ReQuant");
        }
    }

    #[test]
    fn table_costs_near_paper() {
        // within 35% of the paper's reported LUT-6 numbers
        for row in fig11c() {
            let ours = row.table.lut6 as f64;
            let paper = row.paper_table_lut6 as f64;
            assert!(
                (ours - paper).abs() / paper < 0.35,
                "{}: ours {} vs paper {}",
                row.function,
                ours,
                paper
            );
        }
    }

    #[test]
    fn lut_reduction_is_large_for_transcendentals() {
        for row in fig11c() {
            if row.function == "ReQuant" {
                continue; // naive requant uses a DSP, not LUTs
            }
            assert!(row.naive.lut6 > 2 * row.table.lut6, "{}", row.function);
        }
    }

    #[test]
    fn requant_table_is_tiny() {
        assert_eq!(table_cost(64, 3, 0).lut6, 3);
    }

    #[test]
    fn mac_cost_3bit_matches_paper() {
        // Sec. 4.4.1: 3-bit x 3-bit multiply = 6 LUT-6
        assert_eq!(lut_mac_cost(3), 6 + 5);
        assert!(lut_mac_cost(4) > lut_mac_cost(3));
        assert!(lut_mac_cost(8) > lut_mac_cost(4));
    }
}
