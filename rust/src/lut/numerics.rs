//! Deterministic scalar numerics — verbatim mirror of
//! `python/compile/numerics.py`. The golden cross-check test
//! (`tests/golden_tables.rs`) pins both generators to the same JSON
//! fixture, so any change here must be made in the python twin too.

/// Round half away from zero (matches `f64::round`, and the python twin).
#[inline]
pub fn round_half_away(x: f64) -> f64 {
    x.round()
}

/// Clamp an integer into `[lo, hi]`.
#[inline]
pub fn clamp_i64(x: i64, lo: i64, hi: i64) -> i64 {
    x.max(lo).min(hi)
}

/// Abramowitz & Stegun 7.1.26 erf approximation (max abs err 1.5e-7).
///
/// Fixed constants, identical to the python twin — rust std has no `erf`
/// and we refuse to depend on platform libm parity for table contents.
pub fn erf_approx(x: f64) -> f64 {
    let sign = if x >= 0.0 { 1.0 } else { -1.0 };
    let ax = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * ax);
    let poly =
        ((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t + 0.254829592;
    sign * (1.0 - poly * t * (-ax * ax).exp())
}

/// GeLU via erf (paper Eq. 1).
pub fn gelu(x: f64) -> f64 {
    0.5 * x * (1.0 + erf_approx(x / std::f64::consts::SQRT_2))
}

/// `s_PoT`: smallest shift with `(beta - alpha) >> s <= 2^n - 1`
/// (integer-domain equivalent of `ceil(log2(span / (2^n - 1)))`, clamped
/// to >= 0; ceiling so the max datum never overflows — paper Sec. 4.4.2).
pub fn pot_shift(alpha: i64, beta: i64, n_bits: u32) -> u32 {
    let span = beta - alpha;
    if span <= 0 {
        return 0;
    }
    let limit = (1i64 << n_bits) - 1;
    let mut s = 0u32;
    while (span >> s) > limit {
        s += 1;
    }
    s
}

/// Eq. 6: `index = (x - alpha) >> s`, clamped into the table.
#[inline]
pub fn pot_index(x: i64, alpha: i64, s: u32, n_bits: u32) -> i64 {
    clamp_i64((x - alpha) >> s, 0, (1i64 << n_bits) - 1)
}

/// Eq. 7 (inverted table): `index = (beta - x) >> s` — anchors the zero
/// point at `beta` so the softmax max element is exact (Sec. 4.4.7).
#[inline]
pub fn pot_index_inverted(x: i64, beta: i64, s: u32, n_bits: u32) -> i64 {
    clamp_i64((beta - x) >> s, 0, (1i64 << n_bits) - 1)
}

/// Representative input of bucket `i` (arithmetic midpoint of the bucket).
pub fn index_midpoint(alpha: i64, i: i64, s: u32) -> f64 {
    let lo = alpha + (i << s);
    let hi = alpha + ((i + 1) << s) - 1;
    0.5 * (lo + hi) as f64
}

/// Representative input of bucket `i` of an inverted table: the
/// anchor-side endpoint, so bucket 0 represents exactly `beta`.
pub fn index_midpoint_inverted(beta: i64, i: i64, s: u32) -> f64 {
    (beta - (i << s)) as f64
}

/// Quantize a real table output to an integer entry (half-away rounding).
pub fn quantize_entry(y: f64, scale: f64, zero_point: i64, qmin: i64, qmax: i64) -> i64 {
    let q = round_half_away(y / scale) as i64 + zero_point;
    clamp_i64(q, qmin, qmax)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_half_away_matches_python() {
        assert_eq!(round_half_away(0.5), 1.0);
        assert_eq!(round_half_away(-0.5), -1.0);
        assert_eq!(round_half_away(2.4), 2.0);
        assert_eq!(round_half_away(-2.4), -2.0);
    }

    #[test]
    fn erf_endpoints() {
        assert!(erf_approx(0.0).abs() < 1e-8);
        assert!((erf_approx(3.0) - 0.99997791).abs() < 1e-5);
        assert_eq!(erf_approx(-2.0), -erf_approx(2.0));
    }

    #[test]
    fn gelu_known_values() {
        assert!(gelu(0.0).abs() < 1e-12);
        assert!((gelu(1.0) - 0.8413447).abs() < 1e-5);
        assert!((gelu(-10.0)).abs() < 1e-6);
        assert!((gelu(10.0) - 10.0).abs() < 1e-6);
    }

    #[test]
    fn pot_shift_minimal_and_safe() {
        assert_eq!(pot_shift(0, 63, 6), 0);
        assert_eq!(pot_shift(0, 64, 6), 1);
        assert_eq!(pot_shift(0, 127, 6), 1);
        assert_eq!(pot_shift(0, 128, 6), 2);
        for beta in [63i64, 64, 100, 1000, 12345, 1 << 30] {
            let s = pot_shift(0, beta, 6);
            assert!(beta >> s <= 63);
            if s > 0 {
                assert!(beta >> (s - 1) > 63);
            }
        }
    }

    #[test]
    fn inverted_index_anchors_beta() {
        let s = pot_shift(-5000, 0, 6);
        assert_eq!(pot_index_inverted(0, 0, s, 6), 0);
        assert_eq!(pot_index_inverted(-(1 << s), 0, s, 6), 1);
    }

    #[test]
    fn indices_always_in_range() {
        let s = pot_shift(-1000, 4000, 6);
        for x in [-1_000_000i64, -1000, 0, 4000, 1_000_000] {
            let i = pot_index(x, -1000, s, 6);
            assert!((0..64).contains(&i));
        }
    }

    #[test]
    fn quantize_entry_clamps_and_rounds() {
        assert_eq!(quantize_entry(100.0, 1.0, 0, -8, 7), 7);
        assert_eq!(quantize_entry(-100.0, 1.0, 0, -8, 7), -8);
        assert_eq!(quantize_entry(0.5, 1.0, 0, -8, 7), 1);
        assert_eq!(quantize_entry(-0.5, 1.0, 0, -8, 7), -1);
    }
}
