//! The PoT-indexed lookup table — rust twin of `tables.LutTable` /
//! `tables.SegmentedTable`, sharing the JSON wire format with python.

use super::numerics;
use crate::util::json::Json;

/// Affine output quantizer of a table entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OutQuant {
    pub scale: f64,
    pub zero_point: i64,
    pub bits: u32,
    pub signed: bool,
}

impl OutQuant {
    pub fn symmetric(scale: f64, bits: u32) -> Self {
        Self { scale, zero_point: 0, bits, signed: true }
    }

    pub fn unsigned(scale: f64, bits: u32) -> Self {
        Self { scale, zero_point: 0, bits, signed: false }
    }

    pub fn qmin(&self) -> i64 {
        if self.signed { -(1i64 << (self.bits - 1)) } else { 0 }
    }

    pub fn qmax(&self) -> i64 {
        if self.signed { (1i64 << (self.bits - 1)) - 1 } else { (1i64 << self.bits) - 1 }
    }
}

/// A PoT-indexed lookup table (paper Sec. 4.4.2 / 4.4.7).
///
/// `real_out = (entries[index] - out_zp) * out_scale` with
/// `index = (x - alpha) >> shift` (normal) or `(alpha - x) >> shift`
/// (inverted; `alpha` stores beta).
#[derive(Debug, Clone, PartialEq)]
pub struct LutTable {
    pub name: String,
    pub alpha: i64,
    pub shift: u32,
    pub n_bits: u32,
    pub inverted: bool,
    pub out_scale: f64,
    pub out_zp: i64,
    pub entries: Vec<i64>,
}

impl LutTable {
    pub fn depth(&self) -> usize {
        1usize << self.n_bits
    }

    /// Resident bytes of the table's entry storage (the artifact-memory
    /// accounting behind `ModelArtifact::footprint_bytes`).
    pub fn footprint_bytes(&self) -> usize {
        self.entries.len() * std::mem::size_of::<i64>()
    }

    /// Integer-in integer-out table application.
    #[inline]
    pub fn lookup(&self, x: i64) -> i64 {
        let raw = if self.inverted {
            (self.alpha - x) >> self.shift
        } else {
            (x - self.alpha) >> self.shift
        };
        let idx = numerics::clamp_i64(raw, 0, (1i64 << self.n_bits) - 1);
        self.entries[idx as usize]
    }

    pub fn lookup_real(&self, x: i64) -> f64 {
        (self.lookup(x) - self.out_zp) as f64 * self.out_scale
    }

    /// Mean squared error against `f(x * in_scale)` over integer samples.
    pub fn mse<F: Fn(f64) -> f64>(&self, xs: &[i64], f: F, in_scale: f64) -> f64 {
        let mut acc = 0.0;
        for &x in xs {
            let d = self.lookup_real(x) - f(x as f64 * in_scale);
            acc += d * d;
        }
        acc / xs.len() as f64
    }
}

/// Two PoT tables over `[alpha, pivot)` / `[pivot, beta]` with independent
/// PoT output scales — the segmented Recip of Sec. 4.4.6.
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentedTable {
    pub name: String,
    pub pivot: i64,
    pub steep: LutTable,
    pub flat: LutTable,
}

impl SegmentedTable {
    pub fn lookup_real(&self, x: i64) -> f64 {
        if x < self.pivot { self.steep.lookup_real(x) } else { self.flat.lookup_real(x) }
    }

    /// log2(steep_scale / flat_scale) — the left-shift applied to steep
    /// entries to express them in the common (finer) flat scale.
    pub fn ratio_log2(&self) -> u32 {
        let r = self.steep.out_scale / self.flat.out_scale;
        let l = r.log2().round();
        debug_assert!((r - 2f64.powf(l)).abs() < 1e-12);
        l as u32
    }

    /// Integer lookup in the common (flat) output scale.
    pub fn lookup_common(&self, x: i64) -> i64 {
        if x < self.pivot {
            self.steep.lookup(x) << self.ratio_log2()
        } else {
            self.flat.lookup(x)
        }
    }

    pub fn mse<F: Fn(f64) -> f64>(&self, xs: &[i64], f: F, in_scale: f64) -> f64 {
        let mut acc = 0.0;
        for &x in xs {
            let d = self.lookup_real(x) - f(x as f64 * in_scale);
            acc += d * d;
        }
        acc / xs.len() as f64
    }
}

/// Either table kind, as serialized by `tables.dump_tables`
/// (`{"kind": "lut"|"segmented", "data": {...}}`).
#[derive(Debug, Clone, PartialEq)]
pub enum AnyTable {
    Lut(LutTable),
    Segmented(SegmentedTable),
}

// ---------------------------------------------------------------------------
// JSON wire format (shared with python/compile/tables.py)
// ---------------------------------------------------------------------------

impl LutTable {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("alpha", self.alpha.into()),
            ("shift", (self.shift as i64).into()),
            ("n_bits", (self.n_bits as i64).into()),
            ("inverted", self.inverted.into()),
            ("out_scale", self.out_scale.into()),
            ("out_zp", self.out_zp.into()),
            ("entries", Json::Arr(self.entries.iter().map(|&e| e.into()).collect())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self, String> {
        Ok(LutTable {
            name: v.req("name")?.as_str().ok_or("name not str")?.to_string(),
            alpha: v.req("alpha")?.as_i64().ok_or("alpha")?,
            shift: v.req("shift")?.as_i64().ok_or("shift")? as u32,
            n_bits: v.req("n_bits")?.as_i64().ok_or("n_bits")? as u32,
            inverted: v.req("inverted")?.as_bool().ok_or("inverted")?,
            out_scale: v.req("out_scale")?.as_f64().ok_or("out_scale")?,
            out_zp: v.req("out_zp")?.as_i64().ok_or("out_zp")?,
            entries: v
                .req("entries")?
                .as_arr()
                .ok_or("entries")?
                .iter()
                .map(|e| e.as_i64().ok_or_else(|| "entry".to_string()))
                .collect::<Result<_, _>>()?,
        })
    }
}

impl SegmentedTable {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("pivot", self.pivot.into()),
            ("steep", self.steep.to_json()),
            ("flat", self.flat.to_json()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self, String> {
        Ok(SegmentedTable {
            name: v.req("name")?.as_str().ok_or("name")?.to_string(),
            pivot: v.req("pivot")?.as_i64().ok_or("pivot")?,
            steep: LutTable::from_json(v.req("steep")?)?,
            flat: LutTable::from_json(v.req("flat")?)?,
        })
    }
}

impl AnyTable {
    pub fn to_json(&self) -> Json {
        match self {
            AnyTable::Lut(t) => Json::obj(vec![("kind", "lut".into()), ("data", t.to_json())]),
            AnyTable::Segmented(s) => {
                Json::obj(vec![("kind", "segmented".into()), ("data", s.to_json())])
            }
        }
    }

    pub fn from_json(v: &Json) -> Result<Self, String> {
        let data = v.req("data")?;
        match v.req("kind")?.as_str() {
            Some("lut") => Ok(AnyTable::Lut(LutTable::from_json(data)?)),
            Some("segmented") => Ok(AnyTable::Segmented(SegmentedTable::from_json(data)?)),
            other => Err(format!("unknown table kind {other:?}")),
        }
    }
}

impl SegmentedTable {
    /// Resident bytes across both segments' entry storage.
    pub fn footprint_bytes(&self) -> usize {
        self.steep.footprint_bytes() + self.flat.footprint_bytes()
    }
}

impl AnyTable {
    /// Resident bytes of the table's entry storage.
    pub fn footprint_bytes(&self) -> usize {
        match self {
            AnyTable::Lut(t) => t.footprint_bytes(),
            AnyTable::Segmented(s) => s.footprint_bytes(),
        }
    }

    pub fn entry_count(&self) -> usize {
        match self {
            AnyTable::Lut(t) => t.depth(),
            AnyTable::Segmented(s) => s.steep.depth() + s.flat.depth(),
        }
    }

    pub fn entry_bits(&self) -> u32 {
        match self {
            AnyTable::Lut(t) => bits_needed(&t.entries),
            AnyTable::Segmented(s) => {
                bits_needed(&s.steep.entries).max(bits_needed(&s.flat.entries))
            }
        }
    }
}

fn bits_needed(entries: &[i64]) -> u32 {
    let lo = entries.iter().copied().min().unwrap_or(0);
    let hi = entries.iter().copied().max().unwrap_or(0);
    let unsigned = lo >= 0;
    let mag = hi.max(-lo).max(1) as u64;
    let b = 64 - mag.leading_zeros();
    if unsigned { b } else { b + 1 }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(entries: Vec<i64>, inverted: bool) -> LutTable {
        LutTable {
            name: "t".into(),
            alpha: 0,
            shift: 2,
            n_bits: 2,
            inverted,
            out_scale: 0.5,
            out_zp: 0,
            entries,
        }
    }

    #[test]
    fn lookup_normal_and_clamped() {
        let t = mk(vec![10, 20, 30, 40], false);
        assert_eq!(t.lookup(0), 10);
        assert_eq!(t.lookup(4), 20);
        assert_eq!(t.lookup(15), 40);
        assert_eq!(t.lookup(-100), 10);
        assert_eq!(t.lookup(100), 40);
    }

    #[test]
    fn lookup_inverted() {
        let mut t = mk(vec![10, 20, 30, 40], true);
        t.alpha = 0; // beta anchor
        assert_eq!(t.lookup(0), 10); // x == beta -> index 0
        assert_eq!(t.lookup(-4), 20);
        assert_eq!(t.lookup(-100), 40);
    }

    #[test]
    fn lookup_real_applies_out_scale() {
        let t = mk(vec![1, 2, 3, 4], false);
        assert_eq!(t.lookup_real(0), 0.5);
    }

    #[test]
    fn segmented_selects_by_pivot() {
        let steep = LutTable { out_scale: 1.0, ..mk(vec![100, 90, 80, 70], false) };
        let mut flat = mk(vec![5, 4, 3, 2], false);
        flat.alpha = 16;
        flat.out_scale = 0.25;
        let s = SegmentedTable { name: "s".into(), pivot: 16, steep, flat };
        assert_eq!(s.lookup_real(0), 100.0);
        assert_eq!(s.lookup_real(16), 1.25);
        assert_eq!(s.ratio_log2(), 2);
        assert_eq!(s.lookup_common(0), 400);
    }

    #[test]
    fn bits_needed_counts_sign() {
        assert_eq!(bits_needed(&[0, 255]), 8);
        assert_eq!(bits_needed(&[-8, 7]), 5); // mag 8 -> 4 bits + sign
        assert_eq!(bits_needed(&[0, 4095]), 12);
    }

    #[test]
    fn json_roundtrip_matches_python_format() {
        let t = mk(vec![1, 2, 3, 4], false);
        let js = AnyTable::Lut(t.clone()).to_json().to_string_compact();
        assert!(js.contains("\"kind\":\"lut\""));
        let back = AnyTable::from_json(&Json::parse(&js).unwrap()).unwrap();
        assert_eq!(back, AnyTable::Lut(t));
    }
}
