//! Table generators — byte-for-byte mirror of `python/compile/tables.py`.
//!
//! The golden cross-check (`tests/golden_tables.rs`) regenerates the
//! fixture cases emitted by `python -m compile.aot` and compares: alpha /
//! shift / pivot / scales must match exactly, entries within ±1 LSB
//! (libm `exp`/`sqrt` may differ by an ulp across languages).

use super::numerics;
use super::table::{LutTable, OutQuant, SegmentedTable};

/// Default table geometry (paper Fig. 11c).
pub const EXP_BITS: u32 = 6;
pub const EXP_OUT_BITS: u32 = 8;
pub const GELU_BITS: u32 = 6;
pub const RECIP_BITS: u32 = 6;
pub const RECIP_OUT_BITS: u32 = 8;
pub const RSQRT_BITS: u32 = 6;
pub const RSQRT_OUT_BITS: u32 = 12;
pub const REQUANT_BITS: u32 = 6;

/// Power-of-two output scale so `max_abs` maps inside the entry range.
pub fn pot_out_scale(max_abs: f64, bits: u32, signed: bool) -> f64 {
    let qmax = if signed { (1i64 << (bits - 1)) - 1 } else { (1i64 << bits) - 1 } as f64;
    if max_abs <= 0.0 {
        return 1.0;
    }
    2f64.powi((max_abs / qmax).log2().ceil() as i32)
}

/// Sample `f` (real-valued over the dequantized input) into a PoT table.
pub fn build_table<F: Fn(f64) -> f64>(
    name: &str,
    f: F,
    alpha: i64,
    beta: i64,
    in_scale: f64,
    n_bits: u32,
    out: OutQuant,
    inverted: bool,
) -> LutTable {
    let shift = numerics::pot_shift(alpha, beta, n_bits);
    let depth = 1i64 << n_bits;
    let mut entries = Vec::with_capacity(depth as usize);
    for i in 0..depth {
        let mid = if inverted {
            numerics::index_midpoint_inverted(beta, i, shift)
        } else {
            numerics::index_midpoint(alpha, i, shift)
        };
        let y = f(mid * in_scale);
        entries.push(numerics::quantize_entry(
            y,
            out.scale,
            out.zero_point,
            out.qmin(),
            out.qmax(),
        ));
    }
    LutTable {
        name: name.to_string(),
        alpha: if inverted { beta } else { alpha },
        shift,
        n_bits,
        inverted,
        out_scale: out.scale,
        out_zp: out.zero_point,
        entries,
    }
}

/// Sec. 4.4.4 — ReQuant as a table.
pub fn requant_table(name: &str, alpha: i64, beta: i64, in_scale: f64, out: OutQuant) -> LutTable {
    build_table(name, |x| x, alpha, beta, in_scale, REQUANT_BITS, out, false)
}

/// Sec. 4.4.3 — fused GeLU-ReQuant table.
pub fn gelu_requant_table(
    name: &str,
    alpha: i64,
    beta: i64,
    in_scale: f64,
    out: OutQuant,
) -> LutTable {
    build_table(name, numerics::gelu, alpha, beta, in_scale, GELU_BITS, out, false)
}

/// Sec. 4.4.7 — Inversed Exponential table (beta anchored at 0).
pub fn exp_table_inverted(name: &str, alpha: i64, beta: i64, in_scale: f64) -> LutTable {
    let out = OutQuant::unsigned(1.0 / ((1i64 << EXP_OUT_BITS) - 1) as f64, EXP_OUT_BITS);
    build_table(name, f64::exp, alpha, beta, in_scale, EXP_BITS, out, true)
}

/// The non-inverted exp table — the Fig. 11b ablation baseline.
pub fn exp_table_normal(name: &str, alpha: i64, beta: i64, in_scale: f64) -> LutTable {
    let out = OutQuant::unsigned(1.0 / ((1i64 << EXP_OUT_BITS) - 1) as f64, EXP_OUT_BITS);
    build_table(name, f64::exp, alpha, beta, in_scale, EXP_BITS, out, false)
}

/// Sec. 4.4.5 — Joint Table Range Calibration: iteratively shrink
/// `[alpha, beta]` past the clamp-saturated runs at both ends.
pub fn joint_calibrate<F: Fn(f64) -> f64 + Copy>(
    name: &str,
    f: F,
    mut alpha: i64,
    mut beta: i64,
    in_scale: f64,
    n_bits: u32,
    out: OutQuant,
) -> LutTable {
    for _ in 0..16 {
        let table = build_table(name, f, alpha, beta, in_scale, n_bits, out, false);
        let ent = &table.entries;
        let depth = ent.len();
        let mut lsi = 0usize;
        while lsi + 1 < depth && ent[lsi + 1] == ent[0] {
            lsi += 1;
        }
        let mut msi = depth - 1;
        while msi > 1 && ent[msi - 1] == ent[depth - 1] {
            msi -= 1;
        }
        if lsi == 0 && msi == depth - 1 {
            return table;
        }
        let new_alpha = alpha + ((lsi as i64) << table.shift);
        let new_beta = alpha + (((msi + 1) as i64) << table.shift) - 1;
        if new_alpha >= new_beta || (new_alpha == alpha && new_beta == beta) {
            return table;
        }
        alpha = new_alpha;
        beta = new_beta;
    }
    build_table(name, f, alpha, beta, in_scale, n_bits, out, false)
}

/// Sec. 4.4.6 — segmented Recip: pivot at the first 1/8 of the span,
/// independent PoT output scale per segment.
pub fn recip_table_segmented(name: &str, alpha: i64, beta: i64, in_scale: f64) -> SegmentedTable {
    let alpha = alpha.max(1);
    let span = beta - alpha;
    let pivot = alpha + (span >> 3).max(1);
    let steep_out = OutQuant::unsigned(
        pot_out_scale(1.0 / (alpha as f64 * in_scale), RECIP_OUT_BITS, false),
        RECIP_OUT_BITS,
    );
    let flat_out = OutQuant::unsigned(
        pot_out_scale(1.0 / (pivot as f64 * in_scale), RECIP_OUT_BITS, false),
        RECIP_OUT_BITS,
    );
    let steep = build_table(
        &format!("{name}.steep"),
        |x| 1.0 / x,
        alpha,
        pivot - 1,
        in_scale,
        RECIP_BITS,
        steep_out,
        false,
    );
    let flat = build_table(
        &format!("{name}.flat"),
        |x| 1.0 / x,
        pivot,
        beta,
        in_scale,
        RECIP_BITS,
        flat_out,
        false,
    );
    SegmentedTable { name: name.to_string(), pivot, steep, flat }
}

/// Unsegmented Recip baseline (same total depth: 128 entries).
pub fn recip_table_flat(name: &str, alpha: i64, beta: i64, in_scale: f64) -> LutTable {
    let alpha = alpha.max(1);
    let out = OutQuant::unsigned(
        pot_out_scale(1.0 / (alpha as f64 * in_scale), RECIP_OUT_BITS, false),
        RECIP_OUT_BITS,
    );
    build_table(name, |x| 1.0 / x, alpha, beta, in_scale, RECIP_BITS + 1, out, false)
}

/// Rsqrt table (LayerNorm).
pub fn rsqrt_table(name: &str, alpha: i64, beta: i64, in_scale: f64) -> LutTable {
    let alpha = alpha.max(1);
    let out = OutQuant::unsigned(
        pot_out_scale(1.0 / (alpha as f64 * in_scale).sqrt(), RSQRT_OUT_BITS, false),
        RSQRT_OUT_BITS,
    );
    build_table(
        name,
        |x| if x > 0.0 { 1.0 / x.sqrt() } else { 0.0 },
        alpha,
        beta,
        in_scale,
        RSQRT_BITS,
        out,
        false,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn out4() -> OutQuant {
        OutQuant::symmetric(0.125, 4)
    }

    #[test]
    fn requant_is_monotone_64_deep() {
        let t = requant_table("rq", -1000, 1000, 0.01, out4());
        assert_eq!(t.depth(), 64);
        assert!(t.entries.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn inverted_exp_anchor_exact() {
        let t = exp_table_inverted("e", -5000, 0, 0.001);
        assert!((t.lookup_real(0) - 1.0).abs() < 2.0 / 255.0);
    }

    #[test]
    fn exp_monotone_toward_anchor() {
        let t = exp_table_inverted("e", -3000, 0, 0.002);
        let mut prev = -1.0;
        for x in (-3000..=0).step_by(50) {
            let v = t.lookup_real(x);
            assert!(v >= prev - 1e-12);
            prev = v;
        }
    }

    #[test]
    fn joint_calibration_removes_saturation() {
        let raw = requant_table("r", -100_000, 100_000, 0.001, out4());
        let sat = |e: &Vec<i64>| {
            e.iter().filter(|&&v| v == e[0]).count()
                + e.iter().filter(|&&v| v == e[e.len() - 1]).count()
        };
        let cal = joint_calibrate("r", |x| x, -100_000, 100_000, 0.001, 6, out4());
        assert!(sat(&cal.entries) < sat(&raw.entries));
    }

    #[test]
    fn segmented_recip_beats_flat_on_skewed_inputs() {
        // Fig 10d: MSE drops by ~10x with the 2-segment table
        let (a, b, s) = (200i64, 40_000i64, 1.0 / 255.0);
        let seg = recip_table_segmented("r", a, b, s);
        let flat = recip_table_flat("r", a, b, s);
        // log-normal-ish skew toward the steep region
        let xs: Vec<i64> = (0..5000)
            .map(|i| {
                let u = (i as f64 + 0.5) / 5000.0;
                (200.0 * (1.0 / u).powf(1.4)).min(40_000.0) as i64
            })
            .collect();
        let f = |x: f64| 1.0 / x;
        let m_seg = seg.mse(&xs, f, s);
        let m_flat = flat.mse(&xs, f, s);
        assert!(m_seg < m_flat, "seg {m_seg} !< flat {m_flat}");
        assert!(m_flat / m_seg.max(1e-15) > 3.0);
    }

    #[test]
    fn segmented_pivot_at_first_eighth() {
        let seg = recip_table_segmented("r", 1000, 9000, 0.01);
        assert_eq!(seg.pivot, 1000 + (8000 >> 3));
    }

    #[test]
    fn rsqrt_tracks_function() {
        let t = rsqrt_table("rs", 50, 100_000, 0.0625);
        let mut rels: Vec<f64> = (50..100_000)
            .step_by(97)
            .map(|x| {
                let exact = 1.0 / ((x as f64) * 0.0625).sqrt();
                (t.lookup_real(x) - exact).abs() / exact
            })
            .collect();
        rels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(rels[rels.len() / 2] < 0.15);
    }

    #[test]
    fn pot_out_scale_is_power_of_two() {
        for m in [0.3, 1.0, 77.7, 4000.0] {
            let s = pot_out_scale(m, 8, false);
            assert_eq!(s.log2().fract(), 0.0);
            assert!(m / s <= 255.0);
        }
    }
}
