//! LUT-based non-linear operator processing (paper Sec. 4.4).
//!
//! * [`numerics`] — deterministic scalar math (python twin: `numerics.py`),
//! * [`table`] — the PoT-indexed table types + shared JSON wire format,
//! * [`generate`] — table generators (python twin: `tables.py`),
//! * [`cost`] — the Fig. 11c FPGA resource cost model.

pub mod cost;
pub mod generate;
pub mod numerics;
pub mod table;

pub use table::{AnyTable, LutTable, OutQuant, SegmentedTable};

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;

/// Load a table set serialized by `python/compile/tables.dump_tables`.
pub fn load_tables(path: &Path) -> crate::Result<BTreeMap<String, AnyTable>> {
    let data = std::fs::read_to_string(path)?;
    let v = Json::parse(&data).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    let obj = v.as_obj().ok_or_else(|| anyhow::anyhow!("table file is not an object"))?;
    let mut out = BTreeMap::new();
    for (k, t) in obj {
        out.insert(
            k.clone(),
            AnyTable::from_json(t).map_err(|e| anyhow::anyhow!("table '{k}': {e}"))?,
        );
    }
    Ok(out)
}

/// Serialize a table set in the shared wire format.
pub fn dump_tables(tables: &BTreeMap<String, AnyTable>, path: &Path) -> crate::Result<()> {
    let obj = Json::Obj(tables.iter().map(|(k, v)| (k.clone(), v.to_json())).collect());
    std::fs::write(path, obj.to_string_pretty())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dump_load_roundtrip() {
        let t = generate::requant_table("rq", -100, 100, 0.5, OutQuant::symmetric(0.125, 4));
        let s = generate::recip_table_segmented("rc", 10, 1000, 0.01);
        let mut map = BTreeMap::new();
        map.insert("rq".to_string(), AnyTable::Lut(t));
        map.insert("rc".to_string(), AnyTable::Segmented(s));
        let dir = std::env::temp_dir().join("hgpipe_lut_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.json");
        dump_tables(&map, &p).unwrap();
        let back = load_tables(&p).unwrap();
        assert_eq!(back, map);
    }
}
