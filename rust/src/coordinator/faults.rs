//! Deterministic fault injection for the serving layer.
//!
//! A [`FaultPlan`] describes *which* faults to inject and *how often*;
//! a per-replica [`FaultInjector`] (seeded from the plan seed XOR'd
//! with the replica index) decides *when*. All randomness flows through
//! the in-repo xoshiro256** [`crate::util::prng::Prng`], so a given
//! (plan, replica, dispatch-sequence) triple always produces the same
//! fault schedule — chaos tests are reproducible, not flaky.
//!
//! The harness is off by default and zero-cost when off: the serving
//! path carries an `Option<FaultInjector>` that is `None` unless a plan
//! was supplied via [`crate::runtime::RuntimeConfig::faults`] or the
//! `HGPIPE_FAULTS` environment variable (explicit config wins, the
//! repo-wide precedence rule).
//!
//! Spec grammar (comma-separated, any order, all parts optional):
//!
//! ```text
//! panic:RATE            probability a dispatch panics the replica thread
//! stall:RATE[:MS]       probability a dispatch stalls MS ms first (default 10)
//! load:RATE             probability an artifact load / replica (re)build fails
//! seed:N                PRNG seed (default 0x4847_5049, "HGPI")
//! ```
//!
//! Example: `HGPIPE_FAULTS=panic:0.05,stall:0.01:20,seed:42`.

use crate::util::prng::Prng;
use std::time::Duration;

/// Default seed: ASCII "HGPI".
pub const DEFAULT_SEED: u64 = 0x4847_5049;
/// Default stall duration when `stall:RATE` omits the millisecond part.
pub const DEFAULT_STALL_MS: u64 = 10;

/// A fault to act on at a replica dispatch point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Panic the replica thread (simulates a crashed executor).
    Panic,
    /// Sleep before executing (simulates a wedged/slow stage).
    Stall(Duration),
}

/// Declarative description of the faults to inject. `Copy` so it can
/// ride inside [`crate::runtime::RuntimeConfig`] without breaking its
/// `Copy` derive.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Per-dispatch probability of a replica panic, in `[0, 1]`.
    pub panic_rate: f64,
    /// Per-dispatch probability of a stall, in `[0, 1]`.
    pub stall_rate: f64,
    /// How long an injected stall sleeps.
    pub stall_ms: u64,
    /// Per-load probability that building a replica runtime fails, in
    /// `[0, 1]` (exercises both fleet-startup and restart paths).
    pub load_fail_rate: f64,
    /// Base PRNG seed; each replica derives its own stream from it.
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            panic_rate: 0.0,
            stall_rate: 0.0,
            stall_ms: DEFAULT_STALL_MS,
            load_fail_rate: 0.0,
            seed: DEFAULT_SEED,
        }
    }
}

impl FaultPlan {
    /// Parse a spec string (see module docs for the grammar).
    pub fn parse(spec: &str) -> crate::Result<Self> {
        let mut plan = FaultPlan::default();
        for item in spec.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            let mut parts = item.split(':');
            let key = parts.next().unwrap_or("");
            let rate = |s: Option<&str>| -> crate::Result<f64> {
                let raw = s.ok_or_else(|| {
                    anyhow::anyhow!("fault spec item '{item}' is missing a rate")
                })?;
                let v: f64 = raw
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad rate '{raw}' in fault spec item '{item}'"))?;
                anyhow::ensure!(
                    (0.0..=1.0).contains(&v),
                    "rate {v} in fault spec item '{item}' is outside [0, 1]"
                );
                Ok(v)
            };
            match key {
                "panic" => plan.panic_rate = rate(parts.next())?,
                "stall" => {
                    plan.stall_rate = rate(parts.next())?;
                    if let Some(ms) = parts.next() {
                        plan.stall_ms = ms.parse().map_err(|_| {
                            anyhow::anyhow!("bad stall ms '{ms}' in fault spec item '{item}'")
                        })?;
                    }
                }
                "load" => plan.load_fail_rate = rate(parts.next())?,
                "seed" => {
                    let raw = parts.next().ok_or_else(|| {
                        anyhow::anyhow!("fault spec item '{item}' is missing a seed value")
                    })?;
                    plan.seed = raw.parse().map_err(|_| {
                        anyhow::anyhow!("bad seed '{raw}' in fault spec item '{item}'")
                    })?;
                }
                other => anyhow::bail!(
                    "unknown fault spec key '{other}' (expected panic/stall/load/seed)"
                ),
            }
            anyhow::ensure!(
                parts.next().is_none(),
                "trailing garbage in fault spec item '{item}'"
            );
        }
        Ok(plan)
    }

    /// Read `HGPIPE_FAULTS`. Mirrors the other env fallbacks: unset or
    /// unparsable (with a warning) means no injection.
    pub fn from_env() -> Option<Self> {
        let raw = std::env::var("HGPIPE_FAULTS").ok()?;
        match FaultPlan::parse(&raw) {
            Ok(plan) if plan.is_off() => None,
            Ok(plan) => Some(plan),
            Err(e) => {
                eprintln!("warning: ignoring HGPIPE_FAULTS={raw:?}: {e}");
                None
            }
        }
    }

    /// True when no fault can ever fire — callers treat an off plan the
    /// same as no plan so the hot path stays untouched.
    pub fn is_off(&self) -> bool {
        self.panic_rate <= 0.0 && self.stall_rate <= 0.0 && self.load_fail_rate <= 0.0
    }

    /// Per-replica injector with its own deterministic PRNG stream.
    pub fn injector(&self, replica: usize) -> FaultInjector {
        // golden-ratio multiply decorrelates adjacent replica indices
        let stream = (replica as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        FaultInjector { plan: *self, rng: Prng::new(self.seed ^ stream) }
    }
}

/// Stateful per-replica fault source. One PRNG draw per configured
/// fault class per decision point keeps the stream aligned regardless
/// of which faults actually fire.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    rng: Prng,
}

impl FaultInjector {
    /// Called once per dispatch, right before the forward pass.
    pub fn dispatch_fault(&mut self) -> Option<Fault> {
        if self.plan.panic_rate > 0.0 && self.rng.f64() < self.plan.panic_rate {
            return Some(Fault::Panic);
        }
        if self.plan.stall_rate > 0.0 && self.rng.f64() < self.plan.stall_rate {
            return Some(Fault::Stall(Duration::from_millis(self.plan.stall_ms)));
        }
        None
    }

    /// Called once per replica-runtime build (initial load and every
    /// supervised restart).
    pub fn load_fails(&mut self) -> bool {
        self.plan.load_fail_rate > 0.0 && self.rng.f64() < self.plan.load_fail_rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let p = FaultPlan::parse("panic:0.05,stall:0.1:25,load:0.2,seed:42").unwrap();
        assert_eq!(p.panic_rate, 0.05);
        assert_eq!(p.stall_rate, 0.1);
        assert_eq!(p.stall_ms, 25);
        assert_eq!(p.load_fail_rate, 0.2);
        assert_eq!(p.seed, 42);
    }

    #[test]
    fn parse_defaults_and_partial_specs() {
        let p = FaultPlan::parse("panic:0.5").unwrap();
        assert_eq!(p.stall_rate, 0.0);
        assert_eq!(p.stall_ms, DEFAULT_STALL_MS);
        assert_eq!(p.seed, DEFAULT_SEED);
        let p = FaultPlan::parse("stall:0.3").unwrap();
        assert_eq!(p.stall_ms, DEFAULT_STALL_MS);
        assert!(FaultPlan::parse("").unwrap().is_off());
    }

    #[test]
    fn parse_rejects_bad_specs() {
        for bad in [
            "panic",            // missing rate
            "panic:two",        // non-numeric rate
            "panic:1.5",        // rate out of range
            "stall:0.1:fast",   // non-numeric ms
            "jitter:0.1",       // unknown key
            "seed:0x2a",        // non-decimal seed
            "panic:0.1:extra",  // trailing part
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "spec {bad:?} should fail");
        }
    }

    #[test]
    fn injector_streams_are_deterministic_and_per_replica() {
        let plan = FaultPlan::parse("panic:0.3,stall:0.3,seed:7").unwrap();
        let seq = |replica| {
            let mut inj = plan.injector(replica);
            (0..64).map(|_| inj.dispatch_fault()).collect::<Vec<_>>()
        };
        assert_eq!(seq(0), seq(0), "same replica, same stream");
        assert_ne!(seq(0), seq(1), "replicas draw decorrelated streams");
        assert!(
            seq(0).iter().any(|f| f.is_some()),
            "a 30%+30% plan must fire within 64 draws"
        );
    }

    #[test]
    fn off_plan_never_fires() {
        let mut inj = FaultPlan::default().injector(3);
        for _ in 0..256 {
            assert_eq!(inj.dispatch_fault(), None);
            assert!(!inj.load_fails());
        }
        assert!(FaultPlan::default().is_off());
    }

    #[test]
    fn certain_rates_always_fire() {
        let mut inj = FaultPlan::parse("panic:1.0").unwrap().injector(0);
        for _ in 0..16 {
            assert_eq!(inj.dispatch_fault(), Some(Fault::Panic));
        }
        let mut inj = FaultPlan::parse("stall:1.0:5,load:1.0").unwrap().injector(0);
        assert_eq!(inj.dispatch_fault(), Some(Fault::Stall(Duration::from_millis(5))));
        assert!(inj.load_fails());
    }
}
