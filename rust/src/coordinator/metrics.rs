//! Serving metrics: latency percentiles, throughput, batch histogram.

use std::time::Duration;

#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub latencies_us: Vec<u64>,
    pub batch_hist: std::collections::BTreeMap<usize, u64>,
    pub exec_ms_total: f64,
    pub queue_ms_total: f64,
    /// Requests answered with an error: dispatch failures plus requests
    /// still queued/pending when the server shut down.
    pub failed: u64,
    /// Submits rejected at the bounded front door (`Overloaded`). Shed
    /// requests never reach a replica, so this counter lives only in
    /// the rollup — per-replica copies stay 0.
    pub shed: u64,
    /// Requests answered with `DeadlineExceeded` at pop time, without
    /// ever executing a forward pass.
    pub expired: u64,
    /// Requests returned to the front queue after their replica died
    /// mid-dispatch (each such request is counted once per retry).
    pub retried: u64,
    /// Replica deaths survived via supervised restart (each
    /// `executor_loop` panic increments this once).
    pub restarts: u64,
    /// Replicas retired permanently after flapping (consecutive deaths
    /// without a completed dispatch in between).
    pub retired: u64,
    pub started: Option<std::time::Instant>,
    pub finished: Option<std::time::Instant>,
}

impl ServeMetrics {
    pub fn record(&mut self, latency: Duration, batch: usize, exec_ms: f64, queue_ms: f64) {
        self.latencies_us.push(latency.as_micros() as u64);
        *self.batch_hist.entry(batch).or_default() += 1;
        self.exec_ms_total += exec_ms;
        self.queue_ms_total += queue_ms;
    }

    pub fn count(&self) -> usize {
        self.latencies_us.len()
    }

    pub fn percentile(&self, p: f64) -> Option<Duration> {
        if self.latencies_us.is_empty() {
            return None;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let idx = ((v.len() as f64 - 1.0) * p).round() as usize;
        Some(Duration::from_micros(v[idx]))
    }

    pub fn throughput(&self) -> Option<f64> {
        let (s, f) = (self.started?, self.finished?);
        let secs = f.duration_since(s).as_secs_f64();
        if secs > 0.0 {
            Some(self.count() as f64 / secs)
        } else {
            None
        }
    }

    pub fn mean_batch(&self) -> f64 {
        let total: u64 = self.batch_hist.iter().map(|(b, n)| *b as u64 * n).sum();
        let dispatches: u64 = self.batch_hist.values().sum();
        if dispatches == 0 {
            0.0
        } else {
            total as f64 / dispatches as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} failed={} shed={} expired={} retried={} restarts={} throughput={:.1}/s p50={:?} p95={:?} p99={:?} p999={:?} mean_batch={:.2} exec={:.0}ms queue={:.0}ms",
            self.count(),
            self.failed,
            self.shed,
            self.expired,
            self.retried,
            self.restarts,
            self.throughput().unwrap_or(0.0),
            self.percentile(0.50).unwrap_or_default(),
            self.percentile(0.95).unwrap_or_default(),
            self.percentile(0.99).unwrap_or_default(),
            self.percentile(0.999).unwrap_or_default(),
            self.mean_batch(),
            self.exec_ms_total,
            self.queue_ms_total,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut m = ServeMetrics::default();
        for i in 1..=100u64 {
            m.record(Duration::from_micros(i * 10), 1, 0.1, 0.0);
        }
        assert!(m.percentile(0.5).unwrap() <= m.percentile(0.95).unwrap());
        assert!(m.percentile(0.95).unwrap() <= m.percentile(0.99).unwrap());
    }

    #[test]
    fn p999_tracks_the_tail() {
        let mut m = ServeMetrics::default();
        for _ in 0..999 {
            m.record(Duration::from_micros(100), 1, 0.0, 0.0);
        }
        m.record(Duration::from_millis(50), 1, 0.0, 0.0);
        assert!(m.percentile(0.99).unwrap() <= m.percentile(0.999).unwrap());
        assert_eq!(m.percentile(0.999).unwrap(), Duration::from_millis(50));
    }

    #[test]
    fn summary_surfaces_fault_counters() {
        let mut m = ServeMetrics::default();
        m.shed = 3;
        m.expired = 2;
        m.retried = 5;
        m.restarts = 1;
        let s = m.summary();
        for token in ["shed=3", "expired=2", "retried=5", "restarts=1", "p999="] {
            assert!(s.contains(token), "summary {s:?} missing {token}");
        }
    }

    #[test]
    fn mean_batch_weighted() {
        let mut m = ServeMetrics::default();
        m.record(Duration::ZERO, 8, 0.0, 0.0);
        m.record(Duration::ZERO, 8, 0.0, 0.0);
        m.record(Duration::ZERO, 1, 0.0, 0.0);
        assert!((m.mean_batch() - 17.0 / 3.0).abs() < 1e-9);
    }
}
