//! Serving metrics: latency percentiles (log-bucketed histogram),
//! throughput, batch histogram, fault counters, and — in pipeline mode
//! — per-stage occupancy and channel stall counters promoted from the
//! bench into the serving path.

use std::time::Duration;

/// A fixed-size HDR-style latency histogram over microseconds.
///
/// Values 0..64µs land in 64 exact 1µs buckets; above that each
/// power-of-two octave is split into 32 sub-buckets, so the relative
/// quantization error is bounded by 1/32 (~3.1%) at any magnitude up
/// to u64::MAX. Memory is a fixed ~15KiB however long the server
/// runs, and two histograms merge by adding counts — which is how the
/// per-replica metrics roll up.
#[derive(Debug, Clone)]
pub struct LatencyHist {
    counts: Vec<u64>,
    total: u64,
    sum_us: u64,
}

/// Exact 1µs-wide buckets below this value.
const LINEAR: u64 = 64;
/// Sub-buckets per octave above the linear range.
const SUB: usize = 32;
/// Octaves cover top bits 6..=63.
const NBUCKETS: usize = LINEAR as usize + 58 * SUB;

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist { counts: vec![0; NBUCKETS], total: 0, sum_us: 0 }
    }
}

impl LatencyHist {
    fn index(v: u64) -> usize {
        if v < LINEAR {
            v as usize
        } else {
            let top = 63 - v.leading_zeros() as u64; // >= 6
            let sub = ((v >> (top - 5)) & 31) as usize;
            LINEAR as usize + (top as usize - 6) * SUB + sub
        }
    }

    /// `[lo, lo+width)` bounds of the bucket holding `v` — the
    /// guaranteed precision of any percentile near `v`.
    pub fn bucket_bounds(v: u64) -> (u64, u64) {
        if v < LINEAR {
            return (v, 1);
        }
        let top = 63 - v.leading_zeros() as u64;
        let width = 1u64 << (top - 5);
        ((v >> (top - 5)) << (top - 5), width)
    }

    /// The representative value reported for a bucket: its midpoint.
    fn value_at(idx: usize) -> u64 {
        if idx < LINEAR as usize {
            return idx as u64;
        }
        let k = idx - LINEAR as usize;
        let top = 6 + (k / SUB) as u64;
        let sub = (k % SUB) as u64;
        let width = 1u64 << (top - 5);
        ((32 + sub) << (top - 5)) + width / 2
    }

    pub fn record_us(&mut self, us: u64) {
        self.counts[Self::index(us)] += 1;
        self.total += 1;
        self.sum_us = self.sum_us.saturating_add(us);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Sum of recorded values, for Prometheus `_sum` exposition.
    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    /// The nearest-rank percentile (same rank rule the exact sorted-vec
    /// implementation used), accurate to within one bucket width.
    pub fn percentile_us(&self, p: f64) -> Option<u64> {
        if self.total == 0 {
            return None;
        }
        let rank = ((self.total - 1) as f64 * p).round() as u64;
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if c > 0 && seen > rank {
                return Some(Self::value_at(idx));
            }
        }
        // rank == total-1 falls in the last non-empty bucket
        self.counts.iter().rposition(|&c| c > 0).map(Self::value_at)
    }

    /// Add every count of `other` into `self` (replica rollup).
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
    }
}

/// One resident pipeline stage's occupancy snapshot as seen from the
/// serving path: compute time vs wall, plus the stage's channel stall
/// counters (`stalls_full` = blocked sends / backpressure,
/// `stalls_empty` = blocked recvs / bubbles).
#[derive(Debug, Clone)]
pub struct StageOcc {
    pub name: String,
    pub images: u64,
    pub busy_ms: f64,
    /// Wall-clock of the window the counters cover (replica uptime).
    pub wall_ms: f64,
    pub stalls_empty: u64,
    pub stalls_full: u64,
}

impl StageOcc {
    /// Fraction of the wall the stage spent computing.
    pub fn occupancy(&self) -> f64 {
        if self.wall_ms > 0.0 {
            self.busy_ms / self.wall_ms
        } else {
            0.0
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    /// Request latencies, log-bucketed (bounded memory under sustained
    /// load; the old unbounded `Vec<u64>` grew forever and was
    /// clone+sorted on every percentile call).
    pub latency: LatencyHist,
    pub batch_hist: std::collections::BTreeMap<usize, u64>,
    pub exec_ms_total: f64,
    pub queue_ms_total: f64,
    /// Requests answered with an error: dispatch failures plus requests
    /// still queued/pending when the server shut down.
    pub failed: u64,
    /// Submits rejected at the bounded front door (`Overloaded`). Shed
    /// requests never reach a replica, so this counter lives only in
    /// the rollup — per-replica copies stay 0.
    pub shed: u64,
    /// `shed` broken down by admission source label
    /// (`AdmitSource::label`: `"inprocess"` / `"http"`); values sum
    /// to `shed`. Rollup-only, like `shed` itself.
    pub shed_by_source: std::collections::BTreeMap<&'static str, u64>,
    /// Requests answered with `DeadlineExceeded` without ever
    /// executing a forward pass — at admission when the deadline was
    /// already dead on arrival (rollup-only, like `shed`), otherwise
    /// at pop time.
    pub expired: u64,
    /// Requests returned to the front queue after their replica died
    /// mid-dispatch (each such request is counted once per retry).
    pub retried: u64,
    /// Replica deaths survived via supervised restart (each
    /// `executor_loop` panic increments this once).
    pub restarts: u64,
    /// Replicas retired permanently after flapping (consecutive deaths
    /// without a completed dispatch in between).
    pub retired: u64,
    /// Per-replica pipeline stage occupancy, keyed by replica index and
    /// replaced wholesale on update (the counters are cumulative on the
    /// pipeline side). Empty outside pipeline mode.
    pub stages: std::collections::BTreeMap<usize, Vec<StageOcc>>,
    pub started: Option<std::time::Instant>,
    pub finished: Option<std::time::Instant>,
}

impl ServeMetrics {
    pub fn record(&mut self, latency: Duration, batch: usize, exec_ms: f64, queue_ms: f64) {
        self.latency.record_us(latency.as_micros() as u64);
        *self.batch_hist.entry(batch).or_default() += 1;
        self.exec_ms_total += exec_ms;
        self.queue_ms_total += queue_ms;
    }

    pub fn count(&self) -> usize {
        self.latency.count() as usize
    }

    pub fn percentile(&self, p: f64) -> Option<Duration> {
        self.latency.percentile_us(p).map(Duration::from_micros)
    }

    /// Requests per second over the serving window. A live server (no
    /// `finished` mark yet — e.g. a mid-run `/metrics` scrape before
    /// the first dispatch lands, or right after a restart) reports
    /// elapsed-to-now throughput instead of `None`.
    pub fn throughput(&self) -> Option<f64> {
        let s = self.started?;
        let end = self.finished.unwrap_or_else(std::time::Instant::now);
        let secs = end.saturating_duration_since(s).as_secs_f64();
        if secs > 0.0 {
            Some(self.count() as f64 / secs)
        } else {
            None
        }
    }

    /// Replace replica `ri`'s stage occupancy snapshot (cumulative
    /// counters, so replacement — not accumulation — is correct).
    pub fn update_stage_occupancy(&mut self, ri: usize, stages: Vec<StageOcc>) {
        self.stages.insert(ri, stages);
    }

    /// Total backpressure stalls (blocked sends) across all stages of
    /// all replicas this metrics object has seen.
    pub fn pipeline_stalls_full(&self) -> u64 {
        self.stages.values().flatten().map(|s| s.stalls_full).sum()
    }

    /// Total bubble stalls (blocked recvs) across all stages.
    pub fn pipeline_stalls_empty(&self) -> u64 {
        self.stages.values().flatten().map(|s| s.stalls_empty).sum()
    }

    pub fn mean_batch(&self) -> f64 {
        let total: u64 = self.batch_hist.iter().map(|(b, n)| *b as u64 * n).sum();
        let dispatches: u64 = self.batch_hist.values().sum();
        if dispatches == 0 {
            0.0
        } else {
            total as f64 / dispatches as f64
        }
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} failed={} shed={} expired={} retried={} restarts={} throughput={:.1}/s p50={:?} p95={:?} p99={:?} p999={:?} mean_batch={:.2} exec={:.0}ms queue={:.0}ms",
            self.count(),
            self.failed,
            self.shed,
            self.expired,
            self.retried,
            self.restarts,
            self.throughput().unwrap_or(0.0),
            self.percentile(0.50).unwrap_or_default(),
            self.percentile(0.95).unwrap_or_default(),
            self.percentile(0.99).unwrap_or_default(),
            self.percentile(0.999).unwrap_or_default(),
            self.mean_batch(),
            self.exec_ms_total,
            self.queue_ms_total,
        );
        if !self.stages.is_empty() {
            // bubble visibility in serving, not just the bench: stall
            // totals plus per-replica per-stage occupancy fractions
            s.push_str(&format!(
                " stalls_full={} stalls_empty={} occ=",
                self.pipeline_stalls_full(),
                self.pipeline_stalls_empty()
            ));
            for (i, (ri, stages)) in self.stages.iter().enumerate() {
                if i > 0 {
                    s.push('|');
                }
                s.push_str(&format!("r{ri}:"));
                for (j, st) in stages.iter().enumerate() {
                    if j > 0 {
                        s.push(',');
                    }
                    s.push_str(&format!("{:.2}", st.occupancy()));
                }
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn percentiles_ordered() {
        let mut m = ServeMetrics::default();
        for i in 1..=100u64 {
            m.record(Duration::from_micros(i * 10), 1, 0.1, 0.0);
        }
        assert!(m.percentile(0.5).unwrap() <= m.percentile(0.95).unwrap());
        assert!(m.percentile(0.95).unwrap() <= m.percentile(0.99).unwrap());
    }

    #[test]
    fn p999_tracks_the_tail() {
        let mut m = ServeMetrics::default();
        for _ in 0..999 {
            m.record(Duration::from_micros(100), 1, 0.0, 0.0);
        }
        m.record(Duration::from_millis(50), 1, 0.0, 0.0);
        assert!(m.percentile(0.99).unwrap() <= m.percentile(0.999).unwrap());
        // the histogram pins the tail to within one bucket width
        let p999 = m.percentile(0.999).unwrap().as_micros() as i64;
        let (_, width) = LatencyHist::bucket_bounds(50_000);
        assert!(
            (p999 - 50_000).unsigned_abs() <= width,
            "p999 {p999}µs strayed more than a bucket ({width}µs) from 50ms"
        );
    }

    #[test]
    fn histogram_matches_exact_quantiles_on_known_sample() {
        // regression vs the exact sorted-vec percentile the histogram
        // replaced: nearest-rank on a pseudorandom sample, error must
        // stay within one bucket width at every probed quantile
        let mut rng = Prng::new(0xA11CE);
        let samples: Vec<u64> = (0..2000).map(|_| rng.range_i64(1, 2_000_000) as u64).collect();
        let mut exact = samples.clone();
        exact.sort_unstable();
        let mut h = LatencyHist::default();
        for &s in &samples {
            h.record_us(s);
        }
        assert_eq!(h.count(), samples.len() as u64);
        for p in [0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 0.999, 1.0] {
            let rank = ((exact.len() - 1) as f64 * p).round() as usize;
            let want = exact[rank];
            let got = h.percentile_us(p).unwrap();
            let (_, width) = LatencyHist::bucket_bounds(want);
            assert!(
                got.abs_diff(want) <= width,
                "p{p}: hist {got}µs vs exact {want}µs exceeds bucket width {width}µs"
            );
        }
    }

    #[test]
    fn histograms_merge_across_replicas() {
        let mut rng = Prng::new(7);
        let samples: Vec<u64> = (0..500).map(|_| rng.range_i64(0, 100_000) as u64).collect();
        let mut whole = LatencyHist::default();
        let (mut a, mut b) = (LatencyHist::default(), LatencyHist::default());
        for (i, &s) in samples.iter().enumerate() {
            whole.record_us(s);
            if i % 2 == 0 {
                a.record_us(s);
            } else {
                b.record_us(s);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.sum_us(), whole.sum_us());
        for p in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.percentile_us(p), whole.percentile_us(p), "merge changed p{p}");
        }
    }

    #[test]
    fn throughput_is_live_before_finish() {
        // a scraped-mid-run server has started but never finished: it
        // must report elapsed-to-now throughput, not None/0.0
        let mut m = ServeMetrics::default();
        m.record(Duration::from_micros(10), 1, 0.0, 0.0);
        m.started = Some(std::time::Instant::now() - Duration::from_millis(100));
        assert!(m.finished.is_none());
        let tp = m.throughput().expect("live server reports throughput");
        assert!(tp > 0.0, "live throughput must be positive, got {tp}");
    }

    #[test]
    fn summary_surfaces_fault_counters() {
        let mut m = ServeMetrics::default();
        m.shed = 3;
        m.expired = 2;
        m.retried = 5;
        m.restarts = 1;
        let s = m.summary();
        for token in ["shed=3", "expired=2", "retried=5", "restarts=1", "p999="] {
            assert!(s.contains(token), "summary {s:?} missing {token}");
        }
        // no pipeline data -> no occupancy tokens (line layout unchanged)
        assert!(!s.contains("stalls_full="));
    }

    #[test]
    fn summary_surfaces_stage_occupancy() {
        let mut m = ServeMetrics::default();
        m.update_stage_occupancy(
            0,
            vec![
                StageOcc {
                    name: "stage0".into(),
                    images: 10,
                    busy_ms: 50.0,
                    wall_ms: 100.0,
                    stalls_empty: 4,
                    stalls_full: 7,
                },
                StageOcc {
                    name: "stage1".into(),
                    images: 10,
                    busy_ms: 25.0,
                    wall_ms: 100.0,
                    stalls_empty: 1,
                    stalls_full: 0,
                },
            ],
        );
        assert_eq!(m.pipeline_stalls_full(), 7);
        assert_eq!(m.pipeline_stalls_empty(), 5);
        let s = m.summary();
        for token in ["stalls_full=7", "stalls_empty=5", "r0:0.50,0.25"] {
            assert!(s.contains(token), "summary {s:?} missing {token}");
        }
    }

    #[test]
    fn mean_batch_weighted() {
        let mut m = ServeMetrics::default();
        m.record(Duration::ZERO, 8, 0.0, 0.0);
        m.record(Duration::ZERO, 8, 0.0, 0.0);
        m.record(Duration::ZERO, 1, 0.0, 0.0);
        assert!((m.mean_batch() - 17.0 / 3.0).abs() < 1e-9);
    }
}
