//! The multi-consumer front queue replicated executors pull from.
//!
//! `std::sync::mpsc` is single-consumer (`Receiver` is `!Sync`), so once
//! a model runs **N executor replicas** the request stream needs a real
//! MPMC queue: one producer side fed by [`super::ModelServer::submit`],
//! any number of replica threads competing to pop. A `Mutex<VecDeque>` +
//! `Condvar` is exactly enough — requests are popped one at a time under
//! the lock, so every request is owned by **exactly one** replica (the
//! delivery guarantee and the no-double-counting metrics invariant both
//! rest on this).
//!
//! Close semantics mirror the mpsc disconnect contract the single-
//! executor loop relied on: after [`FrontQueue::close`], pushes fail
//! (handing the item back), but queued items keep draining — a popper
//! observes [`Pop::Closed`] only once the queue is *empty*, so shutdown
//! never strands an accepted request inside the queue.
//!
//! The queue can optionally be **bounded** ([`FrontQueue::bounded`]):
//! a push against a full queue is rejected with
//! [`Rejected::Overloaded`], handing the item back so the front door
//! can shed load explicitly instead of queueing doomed work without
//! limit. [`FrontQueue::requeue`] exists for the supervision path: it
//! returns a request a dead replica had already *accepted* to the front
//! of the line, and is therefore exempt from the capacity bound (the
//! item was admitted once; shedding it on retry would turn a replica
//! fault into spurious client-visible overload).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Outcome of a bounded wait on the queue.
#[derive(Debug, PartialEq, Eq)]
pub enum Pop<T> {
    /// An item was dequeued (this caller now exclusively owns it).
    Item(T),
    /// The queue stayed empty for the whole timeout (still open).
    TimedOut,
    /// The queue is closed *and* fully drained — end of stream.
    Closed,
}

/// Why a push was refused. The item is always handed back so the caller
/// can answer the request explicitly instead of dropping it silently.
#[derive(Debug, PartialEq, Eq)]
pub enum Rejected<T> {
    /// The queue is closed (the server is shutting down).
    Closed(T),
    /// The queue is at its capacity bound (the server is overloaded).
    Overloaded(T),
}

impl<T> Rejected<T> {
    /// Recover the rejected item regardless of the reason.
    pub fn into_inner(self) -> T {
        match self {
            Rejected::Closed(t) | Rejected::Overloaded(t) => t,
        }
    }
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// An MPMC FIFO shared between one front door and N executor replicas
/// (share it via `Arc`), unbounded by default.
pub struct FrontQueue<T> {
    state: Mutex<State<T>>,
    available: Condvar,
    capacity: Option<usize>,
}

impl<T> Default for FrontQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> FrontQueue<T> {
    pub fn new() -> Self {
        Self::with_capacity(None)
    }

    /// A queue that rejects pushes beyond `capacity` queued items.
    pub fn bounded(capacity: usize) -> Self {
        Self::with_capacity(Some(capacity))
    }

    /// `None` = unbounded.
    pub fn with_capacity(capacity: Option<usize>) -> Self {
        Self {
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            available: Condvar::new(),
            capacity,
        }
    }

    /// The capacity bound, if any.
    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    /// Enqueue `t`, waking one parked popper. Rejected (item handed
    /// back) once the queue is closed or, for a bounded queue, full.
    pub fn push(&self, t: T) -> Result<(), Rejected<T>> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(Rejected::Closed(t));
        }
        if let Some(cap) = self.capacity {
            if st.items.len() >= cap {
                return Err(Rejected::Overloaded(t));
            }
        }
        st.items.push_back(t);
        drop(st);
        self.available.notify_one();
        Ok(())
    }

    /// Return an already-accepted item to the **front** of the queue
    /// (it was admitted before its replica died, so it keeps its place
    /// in line and is exempt from the capacity bound). `Err(t)` only if
    /// the queue is closed.
    pub fn requeue(&self, t: T) -> Result<(), T> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(t);
        }
        st.items.push_front(t);
        drop(st);
        self.available.notify_one();
        Ok(())
    }

    /// Dequeue without blocking. Items keep draining after close; `None`
    /// means only "empty right now", not end-of-stream.
    pub fn try_pop(&self) -> Option<T> {
        self.state.lock().unwrap().items.pop_front()
    }

    /// Dequeue, parking up to `timeout` while the queue is empty and
    /// open. Returns [`Pop::Closed`] only when closed *and* drained.
    pub fn pop_timeout(&self, timeout: Duration) -> Pop<T> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(t) = st.items.pop_front() {
                return Pop::Item(t);
            }
            if st.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            // wait_timeout can wake spuriously or at the boundary with an
            // item just pushed — the loop re-checks items before closed
            // before deadline, in that order
            let (guard, _) = self.available.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Close the queue: subsequent pushes fail, queued items keep
    /// draining, and every parked popper wakes (observing `Closed` once
    /// the backlog is gone). Idempotent.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.available.notify_all();
    }

    /// Whether [`FrontQueue::close`] has been called (items may still
    /// be draining).
    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// Items currently queued (snapshot; racy by nature).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = FrontQueue::new();
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(q.try_pop(), Some(i));
        }
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn pop_timeout_times_out_on_open_empty_queue() {
        let q: FrontQueue<u8> = FrontQueue::new();
        let t0 = Instant::now();
        assert_eq!(q.pop_timeout(Duration::from_millis(20)), Pop::TimedOut);
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn close_drains_before_reporting_closed() {
        let q = FrontQueue::new();
        q.push(1u8).unwrap();
        q.push(2).unwrap();
        q.close();
        assert!(q.is_closed());
        assert_eq!(q.push(3), Err(Rejected::Closed(3)), "push after close hands the item back");
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Pop::Item(1));
        assert_eq!(q.try_pop(), Some(2), "queued items drain after close");
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Pop::Closed);
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Pop::Closed, "Closed is sticky");
    }

    #[test]
    fn bounded_queue_sheds_at_capacity_and_frees_on_pop() {
        let q = FrontQueue::bounded(2);
        assert_eq!(q.capacity(), Some(2));
        q.push(1u8).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.push(3), Err(Rejected::Overloaded(3)), "full queue sheds, hands item back");
        assert_eq!(q.try_pop(), Some(1));
        q.push(3).unwrap();
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), Some(3));
        // closed beats overloaded: shutdown is reported as such even when full
        q.push(4).unwrap();
        q.push(5).unwrap();
        q.close();
        assert_eq!(q.push(6), Err(Rejected::Closed(6)));
    }

    #[test]
    fn requeue_goes_to_the_front_and_ignores_capacity() {
        let q = FrontQueue::bounded(2);
        q.push(1u8).unwrap();
        q.push(2).unwrap();
        // an accepted item coming back from a dead replica is never shed
        q.requeue(0).unwrap();
        assert_eq!(q.len(), 3, "requeue may exceed the bound");
        assert_eq!(q.try_pop(), Some(0), "requeued item keeps its place at the head");
        assert_eq!(q.try_pop(), Some(1));
        q.close();
        assert_eq!(q.requeue(9), Err(9), "requeue after close hands the item back");
    }

    #[test]
    fn close_wakes_parked_poppers() {
        let q: Arc<FrontQueue<u8>> = Arc::new(FrontQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_timeout(Duration::from_secs(30)));
        // no deterministic "is parked" signal — close is required to wake
        // a popper whether it parked already or is about to
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(h.join().unwrap(), Pop::Closed);
    }

    #[test]
    fn push_wakes_a_parked_popper() {
        let q: Arc<FrontQueue<u32>> = Arc::new(FrontQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_timeout(Duration::from_secs(30)));
        std::thread::sleep(Duration::from_millis(5));
        q.push(42).unwrap();
        assert_eq!(h.join().unwrap(), Pop::Item(42));
    }

    #[test]
    fn every_item_is_popped_exactly_once_across_consumers() {
        let q: Arc<FrontQueue<usize>> = Arc::new(FrontQueue::new());
        let n = 200usize;
        let consumers = 4usize;
        let mut handles = Vec::new();
        for _ in 0..consumers {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                loop {
                    match q.pop_timeout(Duration::from_secs(10)) {
                        Pop::Item(v) => got.push(v),
                        Pop::Closed => return got,
                        Pop::TimedOut => panic!("test queue should close, not time out"),
                    }
                }
            }));
        }
        for i in 0..n {
            q.push(i).unwrap();
        }
        q.close();
        let mut all: Vec<usize> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>(), "each item exactly once, none lost");
    }

    #[test]
    fn close_while_popping_never_loses_or_duplicates() {
        // Race close() against concurrent pushers and poppers: every item
        // must end up either (a) rejected at push (handed back to its
        // pusher) or (b) delivered to exactly one popper — never both,
        // never neither. Repeated so the close lands at different phases.
        for round in 0..16u64 {
            let q: Arc<FrontQueue<u64>> = Arc::new(FrontQueue::new());
            let pushers = 3u64;
            let poppers = 3usize;
            let per_pusher = 400u64;
            let mut push_handles = Vec::new();
            for p in 0..pushers {
                let q = q.clone();
                push_handles.push(std::thread::spawn(move || {
                    let mut accepted = Vec::new();
                    for i in 0..per_pusher {
                        let tag = p * 10_000 + i;
                        match q.push(tag) {
                            Ok(()) => accepted.push(tag),
                            // closed: the item came back to us, stop pushing
                            Err(Rejected::Closed(t)) => {
                                assert_eq!(t, tag);
                                break;
                            }
                            Err(Rejected::Overloaded(_)) => unreachable!("unbounded queue"),
                        }
                    }
                    accepted
                }));
            }
            let mut pop_handles = Vec::new();
            for _ in 0..poppers {
                let q = q.clone();
                pop_handles.push(std::thread::spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        match q.pop_timeout(Duration::from_secs(10)) {
                            Pop::Item(v) => got.push(v),
                            Pop::Closed => return got,
                            Pop::TimedOut => panic!("queue closes, never times out here"),
                        }
                    }
                }));
            }
            // close mid-stream at a round-dependent instant
            std::thread::sleep(Duration::from_micros(50 * round));
            q.close();
            let mut accepted: Vec<u64> =
                push_handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
            let mut delivered: Vec<u64> =
                pop_handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
            accepted.sort_unstable();
            delivered.sort_unstable();
            assert_eq!(
                delivered, accepted,
                "round {round}: accepted and delivered sets must match exactly"
            );
            assert!(q.is_empty(), "round {round}: nothing may remain queued after Closed");
        }
    }
}
