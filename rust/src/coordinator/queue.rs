//! The multi-consumer front queue replicated executors pull from.
//!
//! `std::sync::mpsc` is single-consumer (`Receiver` is `!Sync`), so once
//! a model runs **N executor replicas** the request stream needs a real
//! MPMC queue: one producer side fed by [`super::ModelServer::submit`],
//! any number of replica threads competing to pop. A `Mutex<VecDeque>` +
//! `Condvar` is exactly enough — requests are popped one at a time under
//! the lock, so every request is owned by **exactly one** replica (the
//! delivery guarantee and the no-double-counting metrics invariant both
//! rest on this).
//!
//! Close semantics mirror the mpsc disconnect contract the single-
//! executor loop relied on: after [`FrontQueue::close`], pushes fail
//! (handing the item back), but queued items keep draining — a popper
//! observes [`Pop::Closed`] only once the queue is *empty*, so shutdown
//! never strands an accepted request inside the queue.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Outcome of a bounded wait on the queue.
#[derive(Debug, PartialEq, Eq)]
pub enum Pop<T> {
    /// An item was dequeued (this caller now exclusively owns it).
    Item(T),
    /// The queue stayed empty for the whole timeout (still open).
    TimedOut,
    /// The queue is closed *and* fully drained — end of stream.
    Closed,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// An unbounded MPMC FIFO shared between one front door and N executor
/// replicas (share it via `Arc`).
pub struct FrontQueue<T> {
    state: Mutex<State<T>>,
    available: Condvar,
}

impl<T> Default for FrontQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> FrontQueue<T> {
    pub fn new() -> Self {
        Self {
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            available: Condvar::new(),
        }
    }

    /// Enqueue `t`, waking one parked popper. `Err(t)` once the queue is
    /// closed (the server is shutting down) — the item is handed back so
    /// the caller can reply with an explicit error instead of dropping
    /// the request silently.
    pub fn push(&self, t: T) -> Result<(), T> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(t);
        }
        st.items.push_back(t);
        drop(st);
        self.available.notify_one();
        Ok(())
    }

    /// Dequeue without blocking. Items keep draining after close; `None`
    /// means only "empty right now", not end-of-stream.
    pub fn try_pop(&self) -> Option<T> {
        self.state.lock().unwrap().items.pop_front()
    }

    /// Dequeue, parking up to `timeout` while the queue is empty and
    /// open. Returns [`Pop::Closed`] only when closed *and* drained.
    pub fn pop_timeout(&self, timeout: Duration) -> Pop<T> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(t) = st.items.pop_front() {
                return Pop::Item(t);
            }
            if st.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            // wait_timeout can wake spuriously or at the boundary with an
            // item just pushed — the loop re-checks items before closed
            // before deadline, in that order
            let (guard, _) = self.available.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Close the queue: subsequent pushes fail, queued items keep
    /// draining, and every parked popper wakes (observing `Closed` once
    /// the backlog is gone). Idempotent.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.available.notify_all();
    }

    /// Items currently queued (snapshot; racy by nature).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = FrontQueue::new();
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(q.try_pop(), Some(i));
        }
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn pop_timeout_times_out_on_open_empty_queue() {
        let q: FrontQueue<u8> = FrontQueue::new();
        let t0 = Instant::now();
        assert_eq!(q.pop_timeout(Duration::from_millis(20)), Pop::TimedOut);
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn close_drains_before_reporting_closed() {
        let q = FrontQueue::new();
        q.push(1u8).unwrap();
        q.push(2).unwrap();
        q.close();
        assert_eq!(q.push(3), Err(3), "push after close hands the item back");
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Pop::Item(1));
        assert_eq!(q.try_pop(), Some(2), "queued items drain after close");
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Pop::Closed);
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Pop::Closed, "Closed is sticky");
    }

    #[test]
    fn close_wakes_parked_poppers() {
        let q: Arc<FrontQueue<u8>> = Arc::new(FrontQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_timeout(Duration::from_secs(30)));
        // no deterministic "is parked" signal — close is required to wake
        // a popper whether it parked already or is about to
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(h.join().unwrap(), Pop::Closed);
    }

    #[test]
    fn push_wakes_a_parked_popper() {
        let q: Arc<FrontQueue<u32>> = Arc::new(FrontQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_timeout(Duration::from_secs(30)));
        std::thread::sleep(Duration::from_millis(5));
        q.push(42).unwrap();
        assert_eq!(h.join().unwrap(), Pop::Item(42));
    }

    #[test]
    fn every_item_is_popped_exactly_once_across_consumers() {
        let q: Arc<FrontQueue<usize>> = Arc::new(FrontQueue::new());
        let n = 200usize;
        let consumers = 4usize;
        let mut handles = Vec::new();
        for _ in 0..consumers {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                loop {
                    match q.pop_timeout(Duration::from_secs(10)) {
                        Pop::Item(v) => got.push(v),
                        Pop::Closed => return got,
                        Pop::TimedOut => panic!("test queue should close, not time out"),
                    }
                }
            }));
        }
        for i in 0..n {
            q.push(i).unwrap();
        }
        q.close();
        let mut all: Vec<usize> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>(), "each item exactly once, none lost");
    }
}
