//! Dynamic batching policy — pure logic, unit-testable without PJRT.
//!
//! The AOT pipeline emits one executable per batch size (e.g. {1, 8});
//! the batcher picks which variant to dispatch given the queue depth and
//! how long the head request has waited. Mirrors the paper's serving
//! setup where the accelerator pipeline is fed back-to-back images and
//! the host aggregates them (Sec. 5.1's PYNQ measurement loop).

use std::time::Duration;

/// Batching policy parameters.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Available executable batch sizes, ascending (e.g. [1, 8]).
    pub variants: Vec<usize>,
    /// Max time the head-of-line request may wait for peers.
    pub max_wait: Duration,
}

impl BatchPolicy {
    /// Construct-time validation instead of latent panics downstream:
    /// an empty or zero-containing variant set would make `largest()` /
    /// the executor's lane padding blow up mid-serve.
    pub fn new(mut variants: Vec<usize>, max_wait: Duration) -> crate::Result<Self> {
        variants.sort_unstable();
        variants.dedup();
        anyhow::ensure!(!variants.is_empty(), "batch policy needs at least one batch variant");
        anyhow::ensure!(
            variants[0] >= 1,
            "batch variants must be >= 1, got {:?}",
            variants
        );
        Ok(Self { variants, max_wait })
    }

    pub fn largest(&self) -> usize {
        *self.variants.last().unwrap()
    }

    /// Time remaining before the head-of-line request exhausts
    /// `max_wait` (zero once the deadline has passed). The executor
    /// blocks in `recv_timeout` for exactly this long when
    /// [`Self::decide`] returns `None` on a non-empty queue, instead of
    /// spinning in short sleeps.
    pub fn residual_wait(&self, head_waited: Duration) -> Duration {
        self.max_wait.saturating_sub(head_waited)
    }

    /// Decide the batch size to dispatch now, or None to keep waiting.
    ///
    /// * a full largest-variant batch dispatches immediately;
    /// * once the head request has waited `max_wait`, dispatch the largest
    ///   variant the queue can fill — or, if the queue is smaller than
    ///   every variant, the smallest variant (the executor pads the
    ///   missing lanes; better than starving the head request).
    pub fn decide(&self, queued: usize, head_waited: Duration) -> Option<usize> {
        if queued == 0 {
            return None;
        }
        let largest = self.largest();
        if queued >= largest {
            return Some(largest);
        }
        if head_waited >= self.max_wait {
            let fit = self
                .variants
                .iter()
                .rev()
                .find(|&&v| v <= queued)
                .copied()
                .unwrap_or(self.variants[0]);
            return Some(fit);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(vec![8, 1], Duration::from_millis(2)).unwrap()
    }

    #[test]
    fn empty_or_zero_variants_are_construction_errors() {
        assert!(BatchPolicy::new(vec![], Duration::ZERO).is_err());
        assert!(BatchPolicy::new(vec![0, 4], Duration::ZERO).is_err());
    }

    #[test]
    fn variants_sorted_deduped() {
        let p = BatchPolicy::new(vec![8, 1, 8], Duration::ZERO).unwrap();
        assert_eq!(p.variants, vec![1, 8]);
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        assert_eq!(policy().decide(8, Duration::ZERO), Some(8));
        assert_eq!(policy().decide(20, Duration::ZERO), Some(8));
    }

    #[test]
    fn partial_batch_waits_until_deadline() {
        let p = policy();
        assert_eq!(p.decide(3, Duration::from_micros(100)), None);
        assert_eq!(p.decide(3, Duration::from_millis(3)), Some(1));
    }

    #[test]
    fn empty_queue_never_dispatches() {
        assert_eq!(policy().decide(0, Duration::from_secs(1)), None);
    }

    #[test]
    fn picks_largest_variant_fitting_queue() {
        let p = BatchPolicy::new(vec![1, 4, 8], Duration::from_millis(1)).unwrap();
        assert_eq!(p.decide(5, Duration::from_millis(2)), Some(4));
        assert_eq!(p.decide(2, Duration::from_millis(2)), Some(1));
    }

    #[test]
    fn deadline_boundary_is_inclusive() {
        // exactly max_wait dispatches; one nanosecond under keeps waiting
        let p = policy();
        let deadline = p.max_wait;
        assert_eq!(p.decide(3, deadline), Some(1));
        assert_eq!(p.decide(3, deadline - Duration::from_nanos(1)), None);
    }

    #[test]
    fn residual_wait_complements_head_wait() {
        let p = policy(); // max_wait = 2ms
        assert_eq!(p.residual_wait(Duration::ZERO), p.max_wait);
        let waited = Duration::from_micros(700);
        assert_eq!(p.residual_wait(waited) + waited, p.max_wait);
        // at or past the deadline the residual saturates to zero, so the
        // executor's recv_timeout returns immediately and decide() fires
        assert_eq!(p.residual_wait(p.max_wait), Duration::ZERO);
        assert_eq!(p.residual_wait(p.max_wait + Duration::from_secs(1)), Duration::ZERO);
    }

    #[test]
    fn whenever_decide_waits_residual_is_positive() {
        // invariant the executor loop relies on: a None decision on a
        // non-empty queue always leaves a positive residual to block on
        let p = BatchPolicy::new(vec![1, 4, 8], Duration::from_millis(3)).unwrap();
        for q in 1..20usize {
            for us in [0u64, 1, 500, 2999, 3000, 3001, 10_000] {
                let waited = Duration::from_micros(us);
                if p.decide(q, waited).is_none() {
                    assert!(p.residual_wait(waited) > Duration::ZERO, "q={q} us={us}");
                }
            }
        }
    }
}
