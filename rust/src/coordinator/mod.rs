//! L3 serving coordinator: request queue → dynamic batcher → backend
//! executor, with per-request latency accounting. Thread-based (this
//! offline environment has no tokio); the executor thread plays the role
//! of the accelerator's DMA feeder, the backend (interpreter or PJRT)
//! plays the fully-pipelined fabric.
//!
//! The coordinator is generic over the execution backend via
//! [`crate::runtime::BackendKind`]: `ModelServer::start` uses the default
//! (pure-rust interpreter); `start_with_backend` selects explicitly, and
//! `start_with_config` also carries the lane count and the temporal-vs-
//! spatial [`crate::runtime::ExecMode`] (lane-parallel or pipeline) per
//! model. [`Router`] fronts several `ModelServer`s, routing requests by
//! model name with per-model metrics export.
//!
//! Delivery guarantee: every accepted request receives exactly one reply
//! — `Ok(Response)` on success, an explicit `Err` if its dispatch failed
//! or the server shut down first (counted in [`ServeMetrics::failed`]).
//! While a partial batch waits out the batching deadline the executor
//! blocks in `recv_timeout` for the residual head-of-line wait rather
//! than spinning.

pub mod batcher;
pub mod metrics;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::artifacts::Manifest;
use crate::runtime::{self, BackendKind, Executor, RuntimeConfig};
use batcher::BatchPolicy;
use metrics::ServeMetrics;

/// One inference request: a patchified image (flat T*P f32 tokens).
///
/// The reply channel carries a `Result`: the executor answers *every*
/// drained request, with logits on success or an explicit error when the
/// dispatch failed or the server shut down first — a client blocked on
/// `recv` never waits on a silently-dropped sender.
pub struct Request {
    pub id: u64,
    pub tokens: Vec<f32>,
    pub enqueued: Instant,
    pub reply: Sender<crate::Result<Response>>,
}

/// The reply: logits + timing.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub argmax: usize,
    pub latency: std::time::Duration,
}

/// A serving endpoint for one model (all its batch variants).
///
/// Each server owns its fabric: the executor thread loads the model,
/// which creates the persistent worker pool; dropping the server joins
/// the executor thread, which drops the loaded model and in turn joins
/// the fabric workers — unload never leaks threads.
pub struct ModelServer {
    name: String,
    config: RuntimeConfig,
    queue_tx: Sender<Request>,
    next_id: AtomicU64,
    pub metrics: Arc<Mutex<ServeMetrics>>,
    stop: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<()>>,
    tokens_per_image: usize,
    num_classes: usize,
    compile_ms: f64,
}

impl ModelServer {
    /// Spin up the executor thread on the default backend (the pure-rust
    /// interpreter).
    pub fn start(manifest: &Manifest, model: &str, policy_wait_ms: u64) -> crate::Result<Self> {
        Self::start_with_backend(manifest, model, policy_wait_ms, BackendKind::default())
    }

    /// [`Self::start_with_config`] with the default lane policy for the
    /// chosen backend (`HGPIPE_LANES`, then available parallelism).
    pub fn start_with_backend(
        manifest: &Manifest,
        model: &str,
        policy_wait_ms: u64,
        backend: BackendKind,
    ) -> crate::Result<Self> {
        Self::start_with_config(manifest, model, policy_wait_ms, RuntimeConfig::new(backend))
    }

    /// Spin up the executor thread for a model's batch variants on the
    /// configured backend (engine + explicit fabric lane count).
    ///
    /// The backend's executors are created *inside* the executor thread:
    /// the PJRT `xla` handles are not `Send` (Rc-based), so the thread
    /// owns the whole runtime — which also mirrors the hardware: one
    /// fabric, one feeder.
    pub fn start_with_config(
        manifest: &Manifest,
        model: &str,
        policy_wait_ms: u64,
        config: RuntimeConfig,
    ) -> crate::Result<Self> {
        let manifest = manifest.clone();
        let model_name = model.to_string();
        let (tx, rx) = channel::<Request>();
        let (init_tx, init_rx) = channel::<Result<(usize, usize, f64), String>>();
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let stop = Arc::new(AtomicBool::new(false));
        let m2 = metrics.clone();
        let s2 = stop.clone();
        let wait = std::time::Duration::from_millis(policy_wait_ms);
        let worker = std::thread::spawn(move || {
            // load/compile all variants up front (the paper's bitstream load)
            match runtime::load_model(config, &manifest, &model_name) {
                Err(e) => {
                    let _ = init_tx.send(Err(format!("{e:#}")));
                }
                Ok(loaded) => {
                    let _ = init_tx.send(Ok((
                        loaded.tokens_per_image,
                        loaded.num_classes,
                        loaded.compile_ms,
                    )));
                    let policy =
                        BatchPolicy::new(loaded.executors.iter().map(|e| e.batch()).collect(), wait);
                    executor_loop(
                        rx,
                        loaded.executors,
                        policy,
                        loaded.tokens_per_image,
                        loaded.num_classes,
                        m2,
                        s2,
                    );
                }
            }
        });
        let (tokens_per_image, num_classes, compile_ms) = match init_rx.recv() {
            Ok(Ok(shape)) => shape,
            Ok(Err(e)) => return Err(anyhow::anyhow!("model '{model}' failed to load: {e}")),
            Err(_) => return Err(anyhow::anyhow!("executor thread died during init")),
        };

        Ok(Self {
            name: model.to_string(),
            config,
            queue_tx: tx,
            next_id: AtomicU64::new(0),
            metrics,
            stop,
            worker: Some(worker),
            tokens_per_image,
            num_classes,
            compile_ms,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// The execution backend this server was started on.
    pub fn backend(&self) -> BackendKind {
        self.config.backend
    }

    /// The full runtime configuration (backend + explicit lane count).
    pub fn config(&self) -> RuntimeConfig {
        self.config
    }

    pub fn tokens_per_image(&self) -> usize {
        self.tokens_per_image
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// Backend load/compile time for all batch variants (the "bitstream
    /// load" the paper amortizes once per deployment).
    pub fn compile_ms(&self) -> f64 {
        self.compile_ms
    }

    /// Submit one image; returns the reply channel. The reply is always
    /// delivered: `Ok(Response)` with the logits, or `Err` if the
    /// dispatch failed or the server shut down before the request ran.
    pub fn submit(&self, tokens: Vec<f32>) -> crate::Result<Receiver<crate::Result<Response>>> {
        anyhow::ensure!(
            tokens.len() == self.tokens_per_image,
            "expected {} token values, got {}",
            self.tokens_per_image,
            tokens.len()
        );
        let (tx, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            tokens,
            enqueued: Instant::now(),
            reply: tx,
        };
        self.queue_tx.send(req).map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rx)
    }

    /// Submit a set of images and wait for all replies (offline driver).
    pub fn infer_all(&self, images: Vec<Vec<f32>>) -> crate::Result<Vec<Response>> {
        let rxs: Vec<_> = images.into_iter().map(|i| self.submit(i)).collect::<Result<_, _>>()?;
        rxs.into_iter()
            .map(|rx| rx.recv().map_err(|e| anyhow::anyhow!("reply lost: {e}"))?)
            .collect()
    }
}

impl Drop for ModelServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the executor by closing the queue; the loop's shutdown
        // drain then fails every queued + pending request explicitly
        // (clients blocked on `recv` get an error, not a dropped sender)
        let (tx, _rx) = channel();
        let _ = std::mem::replace(&mut self.queue_tx, tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn executor_loop(
    rx: Receiver<Request>,
    executables: Vec<Box<dyn Executor>>,
    policy: BatchPolicy,
    tokens_per_image: usize,
    num_classes: usize,
    metrics: Arc<Mutex<ServeMetrics>>,
    stop: Arc<AtomicBool>,
) {
    let mut pending: Vec<Request> = Vec::new();
    'serve: loop {
        if stop.load(Ordering::SeqCst) {
            break 'serve;
        }
        // top up the pending queue (non-blocking drain, short block if empty)
        if pending.is_empty() {
            match rx.recv_timeout(std::time::Duration::from_millis(5)) {
                Ok(r) => pending.push(r),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break 'serve,
            }
        }
        while let Ok(r) = rx.try_recv() {
            pending.push(r);
        }

        let head_waited = pending[0].enqueued.elapsed();
        let Some(batch) = policy.decide(pending.len(), head_waited) else {
            // a partial batch is waiting out `max_wait`: block for exactly
            // the residual head-of-line deadline instead of burning a core
            // in a sleep/poll spin — a new arrival wakes us early (it may
            // complete a batch), the timeout lands us past the deadline
            match rx.recv_timeout(policy.residual_wait(head_waited)) {
                Ok(r) => pending.push(r),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break 'serve,
            }
            continue;
        };
        let exe = executables
            .iter()
            .find(|e| e.batch() == batch)
            .expect("policy only returns available variants");

        // the queue may be smaller than the chosen variant (head-of-line
        // timeout with a sparse queue): pad the missing lanes with zeros
        // and discard their outputs
        let take = batch.min(pending.len());
        let reqs: Vec<Request> = pending.drain(..take).collect();
        let mut input = vec![0.0f32; batch * tokens_per_image];
        for (i, r) in reqs.iter().enumerate() {
            input[i * tokens_per_image..(i + 1) * tokens_per_image].copy_from_slice(&r.tokens);
        }
        // per-image attribution divides by the number of REAL images in
        // the dispatch, not the variant width: zero-padded lanes are
        // serving overhead, and dividing by `batch` understated both the
        // queue wait and the execution cost whenever lanes were padded
        let queue_ms = reqs.iter().map(|r| r.enqueued.elapsed().as_secs_f64() * 1e3).sum::<f64>()
            / reqs.len() as f64;
        let t0 = Instant::now();
        let out = match exe.run_f32(&input) {
            Ok(o) => o,
            Err(e) => {
                // answer every drained request with the error instead of
                // dropping their senders (which left clients hanging on
                // `recv` until an opaque "reply lost")
                let msg = format!("{e:#}");
                metrics.lock().unwrap().failed += reqs.len() as u64;
                for r in reqs {
                    let _ = r.reply.send(Err(anyhow::anyhow!(
                        "executor error running request {}: {msg}",
                        r.id
                    )));
                }
                continue;
            }
        };
        let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
        let per_image_exec_ms = exec_ms / reqs.len() as f64;

        {
            let mut m = metrics.lock().unwrap();
            if m.started.is_none() {
                m.started = Some(t0);
            }
            m.finished = Some(Instant::now());
            for r in &reqs {
                m.record(r.enqueued.elapsed(), batch, per_image_exec_ms, queue_ms);
            }
        }
        for (i, r) in reqs.into_iter().enumerate() {
            let logits = out[i * num_classes..(i + 1) * num_classes].to_vec();
            let argmax = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap_or(0);
            let _ = r.reply.send(Ok(Response {
                id: r.id,
                logits,
                argmax,
                latency: r.enqueued.elapsed(),
            }));
        }
    }

    // shutdown drain: whatever is still queued or pending will never run;
    // fail each request deterministically so no client hangs on `recv`
    while let Ok(r) = rx.try_recv() {
        pending.push(r);
    }
    if !pending.is_empty() {
        metrics.lock().unwrap().failed += pending.len() as u64;
        for r in pending {
            let _ = r.reply.send(Err(anyhow::anyhow!(
                "server shut down before request {} was executed",
                r.id
            )));
        }
    }
}

/// Route requests across several models (the vLLM-style front door):
/// one [`ModelServer`] per model name — each with its own executor
/// thread and its own fabric or pipeline — with submission routed by
/// model name and per-model metrics export. `hgpipe serve --models a,b`
/// drives one of these.
pub struct Router {
    servers: Vec<ModelServer>,
}

impl Router {
    pub fn new(servers: Vec<ModelServer>) -> Self {
        Self { servers }
    }

    /// Start one server per model name, all on the same runtime config.
    /// Duplicate names are rejected (routing would silently shadow one).
    pub fn start(
        manifest: &Manifest,
        models: &[String],
        policy_wait_ms: u64,
        config: RuntimeConfig,
    ) -> crate::Result<Self> {
        anyhow::ensure!(!models.is_empty(), "router needs at least one model");
        let mut servers: Vec<ModelServer> = Vec::with_capacity(models.len());
        for m in models {
            anyhow::ensure!(
                servers.iter().all(|s| s.name() != m),
                "duplicate model '{m}' in --models"
            );
            servers.push(ModelServer::start_with_config(manifest, m, policy_wait_ms, config)?);
        }
        Ok(Self { servers })
    }

    pub fn server(&self, model: &str) -> Option<&ModelServer> {
        self.servers.iter().find(|s| s.name() == model)
    }

    /// The server for `model`, or an actionable routing error naming
    /// what *is* being served.
    fn routed(&self, model: &str) -> crate::Result<&ModelServer> {
        self.server(model).ok_or_else(|| {
            anyhow::anyhow!(
                "no server for model '{model}' (serving: {})",
                self.models().join(", ")
            )
        })
    }

    /// Route one request to `model`'s server.
    pub fn submit(
        &self,
        model: &str,
        tokens: Vec<f32>,
    ) -> crate::Result<Receiver<crate::Result<Response>>> {
        self.routed(model)?.submit(tokens)
    }

    /// Route a whole image set to `model`'s server and wait for replies.
    pub fn infer_all(&self, model: &str, images: Vec<Vec<f32>>) -> crate::Result<Vec<Response>> {
        self.routed(model)?.infer_all(images)
    }

    pub fn models(&self) -> Vec<&str> {
        self.servers.iter().map(|s| s.name()).collect()
    }

    /// Per-model metrics export: a `(model, metrics)` snapshot per
    /// served model (the front door's observability surface).
    pub fn metrics(&self) -> Vec<(String, ServeMetrics)> {
        self.servers
            .iter()
            .map(|s| (s.name().to_string(), s.metrics.lock().unwrap().clone()))
            .collect()
    }
}
