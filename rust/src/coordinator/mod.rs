//! L3 serving coordinator: request queue → dynamic batcher → PJRT
//! executor, with per-request latency accounting. Thread-based (this
//! offline environment has no tokio); the executor thread plays the role
//! of the accelerator's DMA feeder, the AOT executable plays the
//! fully-pipelined fabric.

pub mod batcher;
pub mod metrics;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::artifacts::Manifest;
use crate::runtime::{Engine, Executable};
use batcher::BatchPolicy;
use metrics::ServeMetrics;

/// One inference request: a patchified image (flat T*P f32 tokens).
pub struct Request {
    pub id: u64,
    pub tokens: Vec<f32>,
    pub enqueued: Instant,
    pub reply: Sender<Response>,
}

/// The reply: logits + timing.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub argmax: usize,
    pub latency: std::time::Duration,
}

/// A serving endpoint for one model (all its batch variants).
pub struct ModelServer {
    name: String,
    queue_tx: Sender<Request>,
    next_id: AtomicU64,
    pub metrics: Arc<Mutex<ServeMetrics>>,
    stop: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<()>>,
    tokens_per_image: usize,
    num_classes: usize,
}

impl ModelServer {
    /// Spin up the executor thread for a model's batch variants.
    ///
    /// The PJRT client and executables are created *inside* the executor
    /// thread: the `xla` crate's handles are not `Send` (Rc-based), so the
    /// thread owns the whole runtime — which also mirrors the hardware:
    /// one fabric, one feeder.
    pub fn start(manifest: &Manifest, model: &str, policy_wait_ms: u64) -> crate::Result<Self> {
        let variants: Vec<crate::artifacts::ArtifactInfo> =
            manifest.variants(model).into_iter().cloned().collect();
        anyhow::ensure!(!variants.is_empty(), "no artifacts for model '{model}'");
        let tokens_per_image: usize = variants[0].input_shape[1..].iter().product();
        let num_classes = *variants[0].output_shape.last().unwrap();

        let (tx, rx) = channel::<Request>();
        let (init_tx, init_rx) = channel::<Result<f64, String>>();
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let stop = Arc::new(AtomicBool::new(false));
        let m2 = metrics.clone();
        let s2 = stop.clone();
        let wait = std::time::Duration::from_millis(policy_wait_ms);
        let worker = std::thread::spawn(move || {
            // compile all variants up front (the paper's bitstream load)
            let init = (|| -> crate::Result<(Vec<(usize, Arc<Executable>)>, f64)> {
                let engine = Engine::cpu()?;
                let mut executables = Vec::new();
                let mut compile_ms = 0.0;
                for v in &variants {
                    let e = engine.load(v)?;
                    compile_ms += e.compile_ms;
                    executables.push((v.batch(), e));
                }
                Ok((executables, compile_ms))
            })();
            match init {
                Err(e) => {
                    let _ = init_tx.send(Err(format!("{e:#}")));
                }
                Ok((executables, compile_ms)) => {
                    let _ = init_tx.send(Ok(compile_ms));
                    let policy =
                        BatchPolicy::new(executables.iter().map(|(b, _)| *b).collect(), wait);
                    executor_loop(rx, executables, policy, tokens_per_image, num_classes, m2, s2);
                }
            }
        });
        match init_rx.recv() {
            Ok(Ok(_compile_ms)) => {}
            Ok(Err(e)) => return Err(anyhow::anyhow!("model '{model}' failed to load: {e}")),
            Err(_) => return Err(anyhow::anyhow!("executor thread died during init")),
        }

        Ok(Self {
            name: model.to_string(),
            queue_tx: tx,
            next_id: AtomicU64::new(0),
            metrics,
            stop,
            worker: Some(worker),
            tokens_per_image,
            num_classes,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn tokens_per_image(&self) -> usize {
        self.tokens_per_image
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// Submit one image; returns the reply channel.
    pub fn submit(&self, tokens: Vec<f32>) -> crate::Result<Receiver<Response>> {
        anyhow::ensure!(
            tokens.len() == self.tokens_per_image,
            "expected {} token values, got {}",
            self.tokens_per_image,
            tokens.len()
        );
        let (tx, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            tokens,
            enqueued: Instant::now(),
            reply: tx,
        };
        self.queue_tx.send(req).map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rx)
    }

    /// Submit a set of images and wait for all replies (offline driver).
    pub fn infer_all(&self, images: Vec<Vec<f32>>) -> crate::Result<Vec<Response>> {
        let rxs: Vec<_> = images.into_iter().map(|i| self.submit(i)).collect::<Result<_, _>>()?;
        rxs.into_iter().map(|rx| rx.recv().map_err(|e| anyhow::anyhow!("reply lost: {e}"))).collect()
    }
}

impl Drop for ModelServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the executor by closing the queue
        let (tx, _rx) = channel();
        let _ = std::mem::replace(&mut self.queue_tx, tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn executor_loop(
    rx: Receiver<Request>,
    executables: Vec<(usize, Arc<Executable>)>,
    policy: BatchPolicy,
    tokens_per_image: usize,
    num_classes: usize,
    metrics: Arc<Mutex<ServeMetrics>>,
    stop: Arc<AtomicBool>,
) {
    let mut pending: Vec<Request> = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        // top up the pending queue (non-blocking drain, short block if empty)
        if pending.is_empty() {
            match rx.recv_timeout(std::time::Duration::from_millis(5)) {
                Ok(r) => pending.push(r),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
            }
        }
        while let Ok(r) = rx.try_recv() {
            pending.push(r);
        }

        let head_waited = pending[0].enqueued.elapsed();
        let Some(batch) = policy.decide(pending.len(), head_waited) else {
            std::thread::sleep(std::time::Duration::from_micros(100));
            continue;
        };
        let (_, exe) = executables
            .iter()
            .find(|(b, _)| *b == batch)
            .expect("policy only returns available variants");

        // the queue may be smaller than the chosen variant (head-of-line
        // timeout with a sparse queue): pad the missing lanes with zeros
        // and discard their outputs
        let take = batch.min(pending.len());
        let reqs: Vec<Request> = pending.drain(..take).collect();
        let mut input = vec![0.0f32; batch * tokens_per_image];
        for (i, r) in reqs.iter().enumerate() {
            input[i * tokens_per_image..(i + 1) * tokens_per_image].copy_from_slice(&r.tokens);
        }
        let queue_ms =
            reqs.iter().map(|r| r.enqueued.elapsed().as_secs_f64() * 1e3).sum::<f64>() / batch as f64;
        let t0 = Instant::now();
        let out = match exe.run_f32(&input) {
            Ok(o) => o,
            Err(e) => {
                eprintln!("executor error: {e}");
                continue;
            }
        };
        let exec_ms = t0.elapsed().as_secs_f64() * 1e3;

        {
            let mut m = metrics.lock().unwrap();
            if m.started.is_none() {
                m.started = Some(t0);
            }
            m.finished = Some(Instant::now());
            for r in &reqs {
                m.record(r.enqueued.elapsed(), batch, exec_ms / batch as f64, queue_ms);
            }
        }
        for (i, r) in reqs.into_iter().enumerate() {
            let logits = out[i * num_classes..(i + 1) * num_classes].to_vec();
            let argmax = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap_or(0);
            let _ = r.reply.send(Response {
                id: r.id,
                logits,
                argmax,
                latency: r.enqueued.elapsed(),
            });
        }
    }
}

/// Route requests across several models (the vLLM-style front door).
pub struct Router {
    servers: Vec<ModelServer>,
}

impl Router {
    pub fn new(servers: Vec<ModelServer>) -> Self {
        Self { servers }
    }

    pub fn server(&self, model: &str) -> Option<&ModelServer> {
        self.servers.iter().find(|s| s.name() == model)
    }

    pub fn models(&self) -> Vec<&str> {
        self.servers.iter().map(|s| s.name()).collect()
    }
}
