//! L3 serving coordinator: request queue → dynamic batcher → backend
//! executor, with per-request latency accounting. Thread-based (this
//! offline environment has no tokio); the executor threads play the role
//! of the accelerator's DMA feeders, the backend (interpreter or PJRT)
//! plays the fully-pipelined fabric.
//!
//! The coordinator is generic over the execution backend via
//! [`crate::runtime::BackendKind`]: `ModelServer::start` uses the default
//! (pure-rust interpreter); `start_with_backend` selects explicitly, and
//! `start_with_config` also carries the lane count, the temporal-vs-
//! spatial [`crate::runtime::ExecMode`] (lane-parallel or pipeline), and
//! the **executor replica count** per model. [`Router`] fronts several
//! `ModelServer`s, routing requests by model name with per-model (and
//! per-replica) metrics export — and is a **hot model zoo**:
//! [`Router::load`] / [`Router::unload`] / [`Router::swap`] change what
//! one long-lived process serves, with versioned drain-then-swap
//! semantics and per-version metrics.
//!
//! Scale-out: one model may run `RuntimeConfig::replicas` executor
//! threads (the `--replicas` flag / `HGPIPE_REPLICAS` env fallback), all
//! pulling from **one shared MPMC front [`queue`]**. Each replica owns a
//! complete runtime of its own — its persistent fabric in lane-parallel
//! mode, its resident stage pipeline in pipeline mode (the pipeline
//! feeder is SPSC, so replication happens at the pipeline boundary) —
//! the software analogue of replicating whole accelerator engines behind
//! one request stream. Every request is popped by exactly one replica,
//! so metrics roll up without double counting.
//!
//! Delivery guarantee: every accepted request receives exactly one reply
//! — `Ok(Response)` on success, an explicit `Err` if its dispatch failed
//! or the server shut down first (counted in [`ServeMetrics::failed`]).
//! While a partial batch waits out the batching deadline the executor
//! blocks in a timed pop for the residual head-of-line wait rather than
//! spinning.

pub mod batcher;
pub mod metrics;
pub mod queue;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use crate::artifacts::Manifest;
use crate::runtime::{self, BackendKind, Executor, ModelArtifact, RuntimeConfig};
use batcher::BatchPolicy;
use metrics::ServeMetrics;
use queue::{FrontQueue, Pop};

/// One inference request: a patchified image (flat T*P f32 tokens).
///
/// The reply channel carries a `Result`: the executor answers *every*
/// drained request, with logits on success or an explicit error when the
/// dispatch failed or the server shut down first — a client blocked on
/// `recv` never waits on a silently-dropped sender.
pub struct Request {
    pub id: u64,
    pub tokens: Vec<f32>,
    pub enqueued: Instant,
    pub reply: Sender<crate::Result<Response>>,
}

/// The reply: logits + timing.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub argmax: usize,
    pub latency: std::time::Duration,
}

/// A serving endpoint for one model (all its batch variants), executed
/// by one or more replica threads behind a shared front queue.
///
/// Each replica owns its runtime: the executor thread loads the model,
/// which creates its persistent worker pool (or resident pipeline);
/// dropping the server closes the queue and joins every executor
/// thread, which drops the loaded models and in turn joins the fabric
/// workers and stage threads — unload never leaks threads.
pub struct ModelServer {
    name: String,
    config: RuntimeConfig,
    front: Arc<FrontQueue<Request>>,
    next_id: AtomicU64,
    /// Rolled-up serving metrics across all executor replicas. Every
    /// request is popped by exactly one replica and recorded here once,
    /// so sums never double count; [`Self::replica_metrics`] has the
    /// per-replica breakdown.
    pub metrics: Arc<Mutex<ServeMetrics>>,
    replica_metrics: Vec<Arc<Mutex<ServeMetrics>>>,
    stop: Arc<AtomicBool>,
    workers: Vec<std::thread::JoinHandle<()>>,
    tokens_per_image: usize,
    num_classes: usize,
    compile_ms: f64,
    /// The immutable model (weights + packed panels + LUTs), loaded
    /// once and shared by every replica behind an `Arc` (interpreter
    /// backend; `None` on backends whose handles cannot cross threads).
    artifact: Option<ModelArtifact>,
}

impl ModelServer {
    /// Spin up the executor thread on the default backend (the pure-rust
    /// interpreter).
    pub fn start(manifest: &Manifest, model: &str, policy_wait_ms: u64) -> crate::Result<Self> {
        Self::start_with_backend(manifest, model, policy_wait_ms, BackendKind::default())
    }

    /// [`Self::start_with_config`] with the default lane policy for the
    /// chosen backend (`HGPIPE_LANES`, then available parallelism).
    pub fn start_with_backend(
        manifest: &Manifest,
        model: &str,
        policy_wait_ms: u64,
        backend: BackendKind,
    ) -> crate::Result<Self> {
        Self::start_with_config(manifest, model, policy_wait_ms, RuntimeConfig::new(backend))
    }

    /// Spin up the executor replica threads for a model's batch variants
    /// on the configured backend (engine + explicit fabric lane count +
    /// replica count).
    ///
    /// Each replica's executors are created *inside* its own thread: the
    /// PJRT `xla` handles are not `Send` (Rc-based), so every thread
    /// owns a whole runtime — which also mirrors the hardware: one
    /// fabric (or pipeline) per feeder, N feeders behind one queue.
    /// If any replica fails to load, startup fails as a unit (the
    /// replicas that did load are shut down and joined first).
    pub fn start_with_config(
        manifest: &Manifest,
        model: &str,
        policy_wait_ms: u64,
        config: RuntimeConfig,
    ) -> crate::Result<Self> {
        let replicas = config.resolve_replicas();
        // the immutable half loads ONCE, on the starter thread: every
        // interpreter replica shares the same `Arc`'d artifact, so N
        // replicas hold one copy of the weight panels, not N. (A failed
        // artifact load fails startup before any thread spawns — the
        // same atomic-fleet guarantee as a failed replica.) PJRT's
        // handles are `Rc`-based and not `Send`, so that backend keeps
        // its per-thread load path.
        let artifact = match config.backend {
            BackendKind::Interpreter => Some(ModelArtifact::load(manifest, model)?),
            _ => None,
        };
        let front = Arc::new(FrontQueue::<Request>::new());
        let (init_tx, init_rx) = channel::<(usize, Result<(usize, usize, f64), String>)>();
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let stop = Arc::new(AtomicBool::new(false));
        let wait = std::time::Duration::from_millis(policy_wait_ms);
        let mut workers = Vec::with_capacity(replicas);
        let mut replica_metrics = Vec::with_capacity(replicas);
        for ri in 0..replicas {
            let manifest = manifest.clone();
            let model_name = model.to_string();
            let art = artifact.clone();
            let own = Arc::new(Mutex::new(ServeMetrics::default()));
            replica_metrics.push(own.clone());
            let sinks = MetricSinks { rollup: metrics.clone(), own };
            let q = front.clone();
            let s2 = stop.clone();
            let itx = init_tx.clone();
            workers.push(std::thread::spawn(move || {
                // build this replica's mutable runtime (fabric lanes or
                // resident pipeline + scratch) — from the shared
                // artifact when there is one, else a full per-thread
                // load (the paper's bitstream load, once per engine)
                let loaded = match &art {
                    Some(a) => runtime::load_model_from_artifact(config, a),
                    None => runtime::load_model(config, &manifest, &model_name),
                };
                // the executors hold their own handles now; dropping
                // the spawn-time clone keeps artifact accounting tied
                // to live executors, not parked threads
                drop(art);
                match loaded {
                    Err(e) => {
                        let _ = itx.send((ri, Err(format!("{e:#}"))));
                    }
                    Ok(loaded) => {
                        let _ = itx.send((
                            ri,
                            Ok((loaded.tokens_per_image, loaded.num_classes, loaded.compile_ms)),
                        ));
                        // release the init sender BEFORE serving: if a
                        // sibling replica panics inside load_model (no
                        // message sent), the starter's recv must observe
                        // disconnection rather than block behind this
                        // replica's still-alive sender for the whole
                        // serve lifetime
                        drop(itx);
                        let policy = BatchPolicy::new(
                            loaded.executors.iter().map(|e| e.batch()).collect(),
                            wait,
                        );
                        executor_loop(
                            q,
                            loaded.executors,
                            policy,
                            loaded.tokens_per_image,
                            loaded.num_classes,
                            sinks,
                            s2,
                        );
                    }
                }
            }));
        }
        drop(init_tx);

        // collect every replica's init result before deciding: a partial
        // fleet must not serve (replicas are interchangeable consumers,
        // so a silently-missing one would just skew throughput)
        let mut shape: Option<(usize, usize)> = None;
        let mut compile_ms = 0.0f64;
        let mut failures: Vec<String> = Vec::new();
        for _ in 0..replicas {
            match init_rx.recv() {
                Ok((_, Ok((tpi, nc, cms)))) => {
                    // replicas load the same bundle; a shape mismatch
                    // means the artifact changed mid-start
                    match shape {
                        None => shape = Some((tpi, nc)),
                        Some(s) if s != (tpi, nc) => {
                            failures.push(format!(
                                "replica shape mismatch: {s:?} vs {:?}",
                                (tpi, nc)
                            ));
                        }
                        Some(_) => {}
                    }
                    // loads run concurrently: the deployment pays the max
                    compile_ms = compile_ms.max(cms);
                }
                Ok((ri, Err(e))) => failures.push(format!("replica {ri}: {e}")),
                Err(_) => failures.push("executor thread died during init".to_string()),
            }
        }
        if !failures.is_empty() || shape.is_none() {
            stop.store(true, Ordering::SeqCst);
            front.close();
            for w in workers {
                let _ = w.join();
            }
            return Err(anyhow::anyhow!(
                "model '{model}' failed to load: {}",
                failures.join("; ")
            ));
        }
        let (tokens_per_image, num_classes) = shape.expect("checked above");

        Ok(Self {
            name: model.to_string(),
            config,
            front,
            next_id: AtomicU64::new(0),
            metrics,
            replica_metrics,
            stop,
            workers,
            tokens_per_image,
            num_classes,
            compile_ms,
            artifact,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// The execution backend this server was started on.
    pub fn backend(&self) -> BackendKind {
        self.config.backend
    }

    /// The full runtime configuration (backend + explicit lane count).
    pub fn config(&self) -> RuntimeConfig {
        self.config
    }

    /// Number of executor replicas serving this model's queue.
    pub fn replicas(&self) -> usize {
        self.workers.len()
    }

    /// The shared immutable model artifact every replica borrows
    /// (interpreter backend; `None` on per-thread-load backends).
    /// Clone it to observe sharing from outside: `strong_count` grows
    /// with the fleet and falls back to the callers' handles on drop,
    /// and `footprint_bytes` is the whole fleet's weight memory — once,
    /// not per replica.
    pub fn artifact(&self) -> Option<&ModelArtifact> {
        self.artifact.as_ref()
    }

    /// Per-replica metrics snapshot (same order as replica indices).
    /// Each request is recorded by exactly one replica, so these sum to
    /// the rolled-up [`Self::metrics`] — including `failed`.
    pub fn replica_metrics(&self) -> Vec<ServeMetrics> {
        self.replica_metrics.iter().map(|m| m.lock().unwrap().clone()).collect()
    }

    pub fn tokens_per_image(&self) -> usize {
        self.tokens_per_image
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// Backend load/compile time for all batch variants (the "bitstream
    /// load" the paper amortizes once per deployment).
    pub fn compile_ms(&self) -> f64 {
        self.compile_ms
    }

    /// Submit one image; returns the reply channel. The reply is always
    /// delivered: `Ok(Response)` with the logits, or `Err` if the
    /// dispatch failed or the server shut down before the request ran.
    pub fn submit(&self, tokens: Vec<f32>) -> crate::Result<Receiver<crate::Result<Response>>> {
        anyhow::ensure!(
            tokens.len() == self.tokens_per_image,
            "expected {} token values, got {}",
            self.tokens_per_image,
            tokens.len()
        );
        let (tx, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            tokens,
            enqueued: Instant::now(),
            reply: tx,
        };
        self.front.push(req).map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rx)
    }

    /// Submit a set of images and wait for all replies (offline driver).
    pub fn infer_all(&self, images: Vec<Vec<f32>>) -> crate::Result<Vec<Response>> {
        let rxs: Vec<_> = images.into_iter().map(|i| self.submit(i)).collect::<Result<_, _>>()?;
        rxs.into_iter()
            .map(|rx| rx.recv().map_err(|e| anyhow::anyhow!("reply lost: {e}"))?)
            .collect()
    }
}

impl Drop for ModelServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock every replica by closing the queue; each loop's
        // shutdown drain then fails its share of the queued + pending
        // requests explicitly (clients blocked on `recv` get an error,
        // not a dropped sender) — one replica per request, no double
        // counting
        self.front.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// The metric destinations one executor replica records into: the
/// server-wide rollup (what [`ModelServer::metrics`] exposes) and the
/// replica's own breakdown. Each request is drained by exactly one
/// replica, so recording into both sinks keeps `rollup == Σ replicas`
/// for every counter, including `failed`.
///
/// The rollup is deliberately **materialized** rather than derived from
/// the replica sinks at read time: `ModelServer::metrics` is a shared
/// `Arc` that callers clone and may read *after* the server (and its
/// replica sinks) is gone — the shutdown-accounting tests rely on that.
/// The cost is one extra mutex lock per *batch* (not per request) and a
/// duplicate latency sample; both are noise next to a dispatch.
struct MetricSinks {
    rollup: Arc<Mutex<ServeMetrics>>,
    own: Arc<Mutex<ServeMetrics>>,
}

impl MetricSinks {
    fn each(&self, f: impl Fn(&mut ServeMetrics)) {
        f(&mut self.rollup.lock().unwrap());
        f(&mut self.own.lock().unwrap());
    }
}

fn executor_loop(
    front: Arc<FrontQueue<Request>>,
    executables: Vec<Box<dyn Executor>>,
    policy: BatchPolicy,
    tokens_per_image: usize,
    num_classes: usize,
    sinks: MetricSinks,
    stop: Arc<AtomicBool>,
) {
    let mut pending: Vec<Request> = Vec::new();
    'serve: loop {
        if stop.load(Ordering::SeqCst) {
            break 'serve;
        }
        // top up the pending queue (non-blocking drain, bounded block if
        // empty); other replicas compete on the same front queue, and
        // each pop transfers exclusive ownership of that request. The
        // timeout is only a safety poll — pushes and close() both wake
        // parked poppers immediately, so idle replicas mostly sleep
        if pending.is_empty() {
            match front.pop_timeout(std::time::Duration::from_millis(100)) {
                Pop::Item(r) => pending.push(r),
                Pop::TimedOut => continue,
                Pop::Closed => break 'serve,
            }
        }
        // top up to at most one full largest-variant batch: draining the
        // whole backlog would hoard requests in this replica's private
        // `pending` where idle sibling replicas cannot steal them,
        // collapsing a bursty submission back to single-replica speed
        while pending.len() < policy.largest() {
            match front.try_pop() {
                Some(r) => pending.push(r),
                None => break,
            }
        }

        let head_waited = pending[0].enqueued.elapsed();
        let Some(batch) = policy.decide(pending.len(), head_waited) else {
            // a partial batch is waiting out `max_wait`: block for exactly
            // the residual head-of-line deadline instead of burning a core
            // in a sleep/poll spin — a new arrival wakes us early (it may
            // complete a batch), the timeout lands us past the deadline
            match front.pop_timeout(policy.residual_wait(head_waited)) {
                Pop::Item(r) => pending.push(r),
                Pop::TimedOut => {}
                Pop::Closed => break 'serve,
            }
            continue;
        };
        let exe = executables
            .iter()
            .find(|e| e.batch() == batch)
            .expect("policy only returns available variants");

        // the queue may be smaller than the chosen variant (head-of-line
        // timeout with a sparse queue): pad the missing lanes with zeros
        // and discard their outputs
        let take = batch.min(pending.len());
        let reqs: Vec<Request> = pending.drain(..take).collect();
        let mut input = vec![0.0f32; batch * tokens_per_image];
        for (i, r) in reqs.iter().enumerate() {
            input[i * tokens_per_image..(i + 1) * tokens_per_image].copy_from_slice(&r.tokens);
        }
        // per-image attribution divides by the number of REAL images in
        // the dispatch, not the variant width: zero-padded lanes are
        // serving overhead, and dividing by `batch` understated both the
        // queue wait and the execution cost whenever lanes were padded
        let queue_ms = reqs.iter().map(|r| r.enqueued.elapsed().as_secs_f64() * 1e3).sum::<f64>()
            / reqs.len() as f64;
        let t0 = Instant::now();
        let out = match exe.run_f32(&input) {
            Ok(o) => o,
            Err(e) => {
                // answer every drained request with the error instead of
                // dropping their senders (which left clients hanging on
                // `recv` until an opaque "reply lost")
                let msg = format!("{e:#}");
                let n = reqs.len() as u64;
                sinks.each(|m| m.failed += n);
                for r in reqs {
                    let _ = r.reply.send(Err(anyhow::anyhow!(
                        "executor error running request {}: {msg}",
                        r.id
                    )));
                }
                continue;
            }
        };
        let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
        let per_image_exec_ms = exec_ms / reqs.len() as f64;

        {
            // snapshot the latencies once so rollup and replica sinks
            // record identical values
            let finished = Instant::now();
            let lats: Vec<std::time::Duration> =
                reqs.iter().map(|r| r.enqueued.elapsed()).collect();
            sinks.each(|m| {
                // replicas race on the rollup: keep the EARLIEST start
                // and the LATEST finish, not first/last-writer-wins —
                // otherwise a replica recording out of order shrinks
                // (or inverts) the throughput window
                m.started = Some(match m.started {
                    Some(s) if s <= t0 => s,
                    _ => t0,
                });
                m.finished = Some(match m.finished {
                    Some(f) if f >= finished => f,
                    _ => finished,
                });
                for &lat in &lats {
                    m.record(lat, batch, per_image_exec_ms, queue_ms);
                }
            });
        }
        for (i, r) in reqs.into_iter().enumerate() {
            let logits = out[i * num_classes..(i + 1) * num_classes].to_vec();
            let argmax = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap_or(0);
            let _ = r.reply.send(Ok(Response {
                id: r.id,
                logits,
                argmax,
                latency: r.enqueued.elapsed(),
            }));
        }
    }

    // shutdown drain: whatever this replica still holds — plus whatever
    // it can win from the shared queue — will never run; fail each
    // request deterministically so no client hangs on `recv`. Pops are
    // exclusive, so concurrent replica drains never fail one request
    // twice.
    while let Some(r) = front.try_pop() {
        pending.push(r);
    }
    if !pending.is_empty() {
        let n = pending.len() as u64;
        sinks.each(|m| m.failed += n);
        for r in pending {
            let _ = r.reply.send(Err(anyhow::anyhow!(
                "server shut down before request {} was executed",
                r.id
            )));
        }
    }
}

/// One model's slot in the [`Router`]'s zoo: the live server fleet,
/// its monotonically increasing version, and the final metrics of
/// every version that has been swapped out.
struct ModelEntry {
    name: String,
    /// Starts at 1 on load; bumped by every successful swap.
    version: u64,
    server: Arc<ModelServer>,
    /// `(version, final metrics)` of drained versions, oldest first.
    /// A `ServeMetrics` Arc outlives its server by design (see
    /// [`MetricSinks`]), so a retired version's counters — including
    /// the requests its drain-then-swap failed — stay readable after
    /// the fleet is joined, and stay *out* of the replacement's
    /// counters: per-version lines decompose the total, never double
    /// count it.
    retired: Vec<(u64, Arc<Mutex<ServeMetrics>>)>,
}

fn serving_list(entries: &[ModelEntry]) -> String {
    entries.iter().map(|e| e.name.as_str()).collect::<Vec<_>>().join(", ")
}

/// Route requests across several models (the vLLM-style front door):
/// one [`ModelServer`] per model name — each with its own executor
/// replica fleet, every replica borrowing one shared immutable
/// [`ModelArtifact`] — with submission routed by model name and
/// per-model + per-replica + per-version metrics export. `hgpipe serve
/// --models a,b` drives one of these.
///
/// The zoo is **hot**: [`Router::load`] / [`Router::unload`] /
/// [`Router::swap`] change what one long-lived process serves, with
/// drain-then-swap semantics — a swapped-out version finishes its
/// in-flight dispatches and fails whatever is still queued explicitly
/// (the [`ModelServer`] delivery guarantee: every accepted request gets
/// exactly one reply), and its weight memory is freed when the last
/// `Arc` handle drops. Routing state lives behind a lock so swaps can
/// happen while other threads submit; a submit that races a swap and
/// lands on the closing queue gets an explicit "server stopped" error
/// (never a silent drop) and can simply be resubmitted — it will route
/// to the new version.
pub struct Router {
    entries: RwLock<Vec<ModelEntry>>,
}

impl Router {
    pub fn new(servers: Vec<ModelServer>) -> Self {
        Self {
            entries: RwLock::new(
                servers
                    .into_iter()
                    .map(|s| ModelEntry {
                        name: s.name().to_string(),
                        version: 1,
                        server: Arc::new(s),
                        retired: Vec::new(),
                    })
                    .collect(),
            ),
        }
    }

    /// Start one server per model name, all on the same runtime config.
    /// Duplicate names are rejected (routing would silently shadow one).
    pub fn start(
        manifest: &Manifest,
        models: &[String],
        policy_wait_ms: u64,
        config: RuntimeConfig,
    ) -> crate::Result<Self> {
        anyhow::ensure!(!models.is_empty(), "router needs at least one model");
        let mut servers: Vec<ModelServer> = Vec::with_capacity(models.len());
        for m in models {
            anyhow::ensure!(
                servers.iter().all(|s| s.name() != m),
                "duplicate model '{m}' in --models"
            );
            servers.push(ModelServer::start_with_config(manifest, m, policy_wait_ms, config)?);
        }
        Ok(Self::new(servers))
    }

    /// The live server fleet for `model` (its current version). The
    /// returned handle pins that version: a concurrent swap retires it
    /// from routing, but drain + join wait for the last handle.
    pub fn server(&self, model: &str) -> Option<Arc<ModelServer>> {
        self.entries
            .read()
            .unwrap()
            .iter()
            .find(|e| e.name == model)
            .map(|e| e.server.clone())
    }

    /// The current version of `model` (1 until the first swap).
    pub fn version(&self, model: &str) -> Option<u64> {
        self.entries.read().unwrap().iter().find(|e| e.name == model).map(|e| e.version)
    }

    /// The server for `model`, or an actionable routing error naming
    /// what *is* being served.
    fn routed(&self, model: &str) -> crate::Result<Arc<ModelServer>> {
        self.server(model).ok_or_else(|| {
            anyhow::anyhow!(
                "no server for model '{model}' (serving: {})",
                self.models().join(", ")
            )
        })
    }

    /// Route one request to `model`'s current server. The request is
    /// pinned to the version that accepted it; a swap racing this call
    /// either queues it on the old version (which drains it — reply or
    /// explicit failure) or surfaces an explicit "server stopped"
    /// error, in which case resubmitting routes to the new version.
    pub fn submit(
        &self,
        model: &str,
        tokens: Vec<f32>,
    ) -> crate::Result<Receiver<crate::Result<Response>>> {
        self.routed(model)?.submit(tokens)
    }

    /// Route a whole image set to `model`'s server and wait for replies.
    pub fn infer_all(&self, model: &str, images: Vec<Vec<f32>>) -> crate::Result<Vec<Response>> {
        self.routed(model)?.infer_all(images)
    }

    /// Add a model to the zoo at version 1. The fleet starts (and may
    /// fail, atomically) *before* the routing table changes: a failed
    /// load leaves the zoo serving exactly what it served before.
    pub fn load(
        &self,
        manifest: &Manifest,
        model: &str,
        policy_wait_ms: u64,
        config: RuntimeConfig,
    ) -> crate::Result<()> {
        anyhow::ensure!(
            self.server(model).is_none(),
            "model '{model}' is already served (swap it instead)"
        );
        let server = ModelServer::start_with_config(manifest, model, policy_wait_ms, config)?;
        let mut entries = self.entries.write().unwrap();
        // re-check under the write lock: a concurrent load may have won
        anyhow::ensure!(
            entries.iter().all(|e| e.name != model),
            "model '{model}' is already served (swap it instead)"
        );
        entries.push(ModelEntry {
            name: model.to_string(),
            version: 1,
            server: Arc::new(server),
            retired: Vec::new(),
        });
        Ok(())
    }

    /// Remove a model from the zoo: unroute it, then drain — queued and
    /// in-flight requests complete or are failed explicitly (exactly
    /// one reply each) — and join its fleet. The weight artifact is
    /// freed when the last outside handle (if any) drops.
    pub fn unload(&self, model: &str) -> crate::Result<()> {
        let entry = {
            let mut entries = self.entries.write().unwrap();
            let Some(i) = entries.iter().position(|e| e.name == model) else {
                let serving = serving_list(&entries);
                anyhow::bail!("no server for model '{model}' to unload (serving: {serving})");
            };
            entries.remove(i)
        };
        // drain + join OUTSIDE the lock: unloading one model must not
        // stall routing for the others
        drop(entry);
        Ok(())
    }

    /// Hot-swap `model` to a freshly loaded fleet (drain-then-swap);
    /// returns the new version number.
    ///
    /// Order of operations is the whole guarantee:
    /// 1. the replacement fleet starts first, atomically — a failed
    ///    start returns the error and leaves the old version serving;
    /// 2. the routing table flips to the new fleet and the old
    ///    version's metrics are retired (they keep its counters, so
    ///    per-version lines always sum to the total);
    /// 3. the old fleet drains outside the lock: in-flight dispatches
    ///    finish, still-queued requests are failed explicitly — every
    ///    accepted request still gets exactly one reply, none are
    ///    silently dropped — and the fleet joins. Its share of the old
    ///    artifact drops with it.
    pub fn swap(
        &self,
        manifest: &Manifest,
        model: &str,
        policy_wait_ms: u64,
        config: RuntimeConfig,
    ) -> crate::Result<u64> {
        let fresh = Arc::new(ModelServer::start_with_config(
            manifest,
            model,
            policy_wait_ms,
            config,
        )?);
        let mut entries = self.entries.write().unwrap();
        let Some(i) = entries.iter().position(|e| e.name == model) else {
            let serving = serving_list(&entries);
            drop(entries);
            // `fresh` drops (and drains, trivially — it never served)
            anyhow::bail!("no server for model '{model}' to swap (serving: {serving})");
        };
        let e = &mut entries[i];
        e.retired.push((e.version, e.server.metrics.clone()));
        e.version += 1;
        let version = e.version;
        let old = std::mem::replace(&mut e.server, fresh);
        drop(entries); // new version routes before the old one drains
        drop(old);
        Ok(version)
    }

    pub fn models(&self) -> Vec<String> {
        self.entries.read().unwrap().iter().map(|e| e.name.clone()).collect()
    }

    /// Per-model metrics export: a `(model, metrics)` snapshot per
    /// served model (the front door's observability surface). The
    /// snapshot is the **current version's** cross-replica rollup; see
    /// [`Self::version_metrics`] for retired versions and
    /// [`Self::metrics_lines`] / [`ModelServer::replica_metrics`] for
    /// the per-replica breakdown.
    pub fn metrics(&self) -> Vec<(String, ServeMetrics)> {
        self.entries
            .read()
            .unwrap()
            .iter()
            .map(|e| (e.name.clone(), e.server.metrics.lock().unwrap().clone()))
            .collect()
    }

    /// Every version's metrics for `model`, oldest first, current last:
    /// `(version, snapshot)`. Each request was recorded by exactly one
    /// version (drain-then-swap failures land in the version that owned
    /// the queue), so counts and failures sum to the model's lifetime
    /// totals without double counting.
    pub fn version_metrics(&self, model: &str) -> crate::Result<Vec<(u64, ServeMetrics)>> {
        let entries = self.entries.read().unwrap();
        let Some(e) = entries.iter().find(|e| e.name == model) else {
            let serving = serving_list(&entries);
            anyhow::bail!("no server for model '{model}' (serving: {serving})");
        };
        let mut out: Vec<(u64, ServeMetrics)> =
            e.retired.iter().map(|(v, m)| (*v, m.lock().unwrap().clone())).collect();
        out.push((e.version, e.server.metrics.lock().unwrap().clone()));
        Ok(out)
    }

    /// Human-readable metric report: one rollup line per model version
    /// plus — when the current fleet runs more than one executor
    /// replica — one line per replica with its queue/exec breakdown.
    /// The rollup line *is* that version's total (each request is
    /// popped and recorded by exactly one replica of exactly one
    /// version), so replica lines decompose their version line and
    /// version lines decompose the model's lifetime — failed dispatches
    /// and drain-then-swap failures included, each counted once.
    ///
    /// A never-swapped model keeps the unversioned `[model]` /
    /// `[model/replicaN]` labels; after the first swap the lines are
    /// versioned: `[model@v1]` (retired), `[model@v2]`,
    /// `[model@v2/replica0]`, ...
    pub fn metrics_lines(&self) -> Vec<String> {
        let mut lines = Vec::new();
        for e in self.entries.read().unwrap().iter() {
            let tag = if e.version == 1 && e.retired.is_empty() {
                e.name.clone()
            } else {
                format!("{}@v{}", e.name, e.version)
            };
            for (v, m) in &e.retired {
                lines.push(format!("[{}@v{}] {}", e.name, v, m.lock().unwrap().summary()));
            }
            lines.push(format!("[{tag}] {}", e.server.metrics.lock().unwrap().summary()));
            if e.server.replicas() > 1 {
                for (ri, m) in e.server.replica_metrics().into_iter().enumerate() {
                    lines.push(format!("[{tag}/replica{ri}] {}", m.summary()));
                }
            }
        }
        lines
    }
}
