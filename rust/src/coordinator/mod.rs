//! L3 serving coordinator: request queue → dynamic batcher → backend
//! executor, with per-request latency accounting. Thread-based (this
//! offline environment has no tokio); the executor threads play the role
//! of the accelerator's DMA feeders, the backend (interpreter or PJRT)
//! plays the fully-pipelined fabric.
//!
//! The coordinator is generic over the execution backend via
//! [`crate::runtime::BackendKind`]: `ModelServer::start` uses the default
//! (pure-rust interpreter); `start_with_backend` selects explicitly, and
//! `start_with_config` also carries the lane count, the temporal-vs-
//! spatial [`crate::runtime::ExecMode`] (lane-parallel or pipeline), and
//! the **executor replica count** per model. [`Router`] fronts several
//! `ModelServer`s, routing requests by model name with per-model (and
//! per-replica) metrics export — and is a **hot model zoo**:
//! [`Router::load`] / [`Router::unload`] / [`Router::swap`] change what
//! one long-lived process serves, with versioned drain-then-swap
//! semantics and per-version metrics.
//!
//! Scale-out: one model may run `RuntimeConfig::replicas` executor
//! threads (the `--replicas` flag / `HGPIPE_REPLICAS` env fallback), all
//! pulling from **one shared MPMC front [`queue`]**. Each replica owns a
//! complete runtime of its own — its persistent fabric in lane-parallel
//! mode, its resident stage pipeline in pipeline mode (the pipeline
//! feeder is SPSC, so replication happens at the pipeline boundary) —
//! the software analogue of replicating whole accelerator engines behind
//! one request stream. Every request is popped by exactly one replica,
//! so metrics roll up without double counting.
//!
//! Delivery guarantee: every accepted request receives exactly one reply
//! — `Ok(Response)` on success, an explicit `Err` if its dispatch failed
//! or the server shut down first (counted in [`ServeMetrics::failed`]).
//! While a partial batch waits out the batching deadline the executor
//! blocks in a timed pop for the residual head-of-line wait rather than
//! spinning.
//!
//! Fault tolerance: each replica's [`executor_loop`] runs under a
//! supervisor (`catch_unwind`) — a panicking replica returns its
//! accepted requests to the front of the shared queue (counted as
//! `retried`; the forward pass is pure and no reply has been sent, so
//! re-execution preserves exactly-once replies), rebuilds its runtime
//! from the shared [`ModelArtifact`] with capped exponential backoff,
//! and resumes. A replica that keeps dying without completing a single
//! dispatch is retired permanently — the fleet degrades to fewer
//! replicas, and when the *last* replica retires the queue closes so
//! every remaining request is failed explicitly instead of hanging.
//! The front door can be **bounded** ([`RuntimeConfig::queue_capacity`]
//! / `HGPIPE_QUEUE_CAP`): at capacity, [`ModelServer::submit`] rejects
//! with a typed [`Overloaded`] error (counted as `shed`, attributed
//! to its [`AdmitSource`]) instead of queueing doomed work without
//! limit. Requests may carry a deadline
//! ([`ModelServer::submit_with_deadline`]): an expired request is
//! answered with a typed [`DeadlineExceeded`] without computing its
//! forward pass (counted as `expired`) — dead-on-arrival deadlines
//! short-circuit at admission, never enqueueing; the rest expire at
//! pop time. The [`faults`]
//! harness injects replica panics / stalls / load failures
//! deterministically so all of the above is pinned by reproducible
//! chaos tests (`tests/fault_tolerance.rs`).

pub mod batcher;
pub mod faults;
pub mod metrics;
pub mod queue;

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::artifacts::Manifest;
use crate::runtime::{self, BackendKind, Executor, LoadedModel, ModelArtifact, RuntimeConfig};
use crate::telemetry::{Telemetry, TraceBuf, TraceEvent};
use batcher::BatchPolicy;
use faults::{Fault, FaultInjector};
use metrics::{ServeMetrics, StageOcc};
use queue::{FrontQueue, Pop, Rejected};

/// One inference request: a patchified image (flat T*P f32 tokens).
///
/// The reply channel carries a `Result`: the executor answers *every*
/// drained request, with logits on success or an explicit error when the
/// dispatch failed or the server shut down first — a client blocked on
/// `recv` never waits on a silently-dropped sender.
pub struct Request {
    pub id: u64,
    pub tokens: Vec<f32>,
    pub enqueued: Instant,
    /// Answer-by time. A request found expired at pop time is answered
    /// with [`DeadlineExceeded`] without computing its forward pass.
    pub deadline: Option<Instant>,
    pub reply: Sender<crate::Result<Response>>,
}

impl Request {
    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Typed admission-rejection error: the bounded front queue is at
/// capacity. Downcast from the anyhow error returned by
/// [`ModelServer::submit`] to distinguish overload (retry later /
/// back off) from shutdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Overloaded {
    /// The queue bound that was hit.
    pub capacity: usize,
}

impl std::fmt::Display for Overloaded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "overloaded: front queue at capacity {} — request shed", self.capacity)
    }
}

impl std::error::Error for Overloaded {}

/// Typed deadline-expiry error: the request's deadline passed before an
/// executor picked it up, so it was answered without running (no
/// compute wasted on a doomed reply).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeadlineExceeded {
    /// Id of the expired request.
    pub id: u64,
}

impl std::fmt::Display for DeadlineExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "deadline exceeded before request {} was executed", self.id)
    }
}

impl std::error::Error for DeadlineExceeded {}

/// Typed routing error: the request named a model the [`Router`] is
/// not serving. Downcast at the serving edge (the HTTP front door
/// maps it to `404`) to distinguish a client-side routing miss from
/// an internal failure; `Display` names what *is* being served.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UnknownModel {
    /// The model the request asked for.
    pub model: String,
    /// Names currently routed, in routing-table order.
    pub serving: Vec<String>,
}

impl std::fmt::Display for UnknownModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "no server for model '{}' (serving: {})", self.model, self.serving.join(", "))
    }
}

impl std::error::Error for UnknownModel {}

/// Where a request entered the system. Admission-control accounting
/// (`shed`) is broken down by source — an overloaded fleet shows
/// *who* it is shedding (`ServeMetrics::shed_by_source`, exported as
/// the `hgpipe_requests_shed_by_source_total{source=...}` family).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitSource {
    /// In-process callers: `submit`/`infer_all`, benches, tests, the
    /// synthetic `hgpipe serve` traffic loop.
    InProcess,
    /// The network front door ([`crate::server`]).
    Http,
}

impl AdmitSource {
    /// Stable label used as the metrics-map key and the Prometheus
    /// `source="..."` label value.
    pub fn label(self) -> &'static str {
        match self {
            AdmitSource::InProcess => "inprocess",
            AdmitSource::Http => "http",
        }
    }
}

/// The reply: logits + timing.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub argmax: usize,
    pub latency: std::time::Duration,
}

/// A serving endpoint for one model (all its batch variants), executed
/// by one or more replica threads behind a shared front queue.
///
/// Each replica owns its runtime: the executor thread loads the model,
/// which creates its persistent worker pool (or resident pipeline);
/// dropping the server closes the queue and joins every executor
/// thread, which drops the loaded models and in turn joins the fabric
/// workers and stage threads — unload never leaks threads.
pub struct ModelServer {
    name: String,
    config: RuntimeConfig,
    front: Arc<FrontQueue<Request>>,
    next_id: AtomicU64,
    /// Rolled-up serving metrics across all executor replicas. Every
    /// request is popped by exactly one replica and recorded here once,
    /// so sums never double count; [`Self::replica_metrics`] has the
    /// per-replica breakdown.
    pub metrics: Arc<Mutex<ServeMetrics>>,
    replica_metrics: Vec<Arc<Mutex<ServeMetrics>>>,
    stop: Arc<AtomicBool>,
    /// Replicas currently serving (started minus permanently retired).
    live: Arc<AtomicUsize>,
    workers: Vec<std::thread::JoinHandle<()>>,
    tokens_per_image: usize,
    num_classes: usize,
    compile_ms: f64,
    /// The immutable model (weights + packed panels + LUTs), loaded
    /// once and shared by every replica behind an `Arc` (interpreter
    /// backend; `None` on backends whose handles cannot cross threads).
    artifact: Option<ModelArtifact>,
    /// This fleet's trace process (one pid per model), threaded into
    /// every replica and resident stage. Off unless the config resolves
    /// a trace path — then every recording site is a branch + nothing.
    telemetry: Telemetry,
}

impl ModelServer {
    /// Spin up the executor thread on the default backend (the pure-rust
    /// interpreter).
    pub fn start(manifest: &Manifest, model: &str, policy_wait_ms: u64) -> crate::Result<Self> {
        Self::start_with_backend(manifest, model, policy_wait_ms, BackendKind::default())
    }

    /// [`Self::start_with_config`] with the default lane policy for the
    /// chosen backend (`HGPIPE_LANES`, then available parallelism).
    pub fn start_with_backend(
        manifest: &Manifest,
        model: &str,
        policy_wait_ms: u64,
        backend: BackendKind,
    ) -> crate::Result<Self> {
        Self::start_with_config(manifest, model, policy_wait_ms, RuntimeConfig::new(backend))
    }

    /// Spin up the executor replica threads for a model's batch variants
    /// on the configured backend (engine + explicit fabric lane count +
    /// replica count).
    ///
    /// Each replica's executors are created *inside* its own thread: the
    /// PJRT `xla` handles are not `Send` (Rc-based), so every thread
    /// owns a whole runtime — which also mirrors the hardware: one
    /// fabric (or pipeline) per feeder, N feeders behind one queue.
    /// If any replica fails to load, startup fails as a unit (the
    /// replicas that did load are shut down and joined first).
    pub fn start_with_config(
        manifest: &Manifest,
        model: &str,
        policy_wait_ms: u64,
        config: RuntimeConfig,
    ) -> crate::Result<Self> {
        let replicas = config.resolve_replicas();
        let queue_capacity = config.resolve_queue_capacity();
        // resolved ONCE on the starter thread (explicit config beats
        // HGPIPE_FAULTS, the repo-wide precedence); each replica derives
        // its own deterministic injector stream from the shared plan
        let fault_plan = config.resolve_faults();
        // the immutable half loads ONCE, on the starter thread: every
        // interpreter replica shares the same `Arc`'d artifact, so N
        // replicas hold one copy of the weight panels, not N. (A failed
        // artifact load fails startup before any thread spawns — the
        // same atomic-fleet guarantee as a failed replica.) PJRT's
        // handles are `Rc`-based and not `Send`, so that backend keeps
        // its per-thread load path.
        let artifact = match config.backend {
            BackendKind::Interpreter => Some(ModelArtifact::load(manifest, model)?),
            _ => None,
        };
        // one trace process per fleet: pid + "client" tid registered
        // here; replica supervisors and pipeline stages allocate their
        // own named tids from the same handle. An explicit but
        // unopenable `--trace` path fails startup (the caller asked for
        // it); an unusable HGPIPE_TRACE only warns (see
        // `Telemetry::from_config`).
        let telemetry = Telemetry::from_config(&config)?.for_model(model);
        let front = Arc::new(FrontQueue::<Request>::with_capacity(queue_capacity));
        let (init_tx, init_rx) = channel::<InitResult>();
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let stop = Arc::new(AtomicBool::new(false));
        let live = Arc::new(AtomicUsize::new(replicas));
        let wait = std::time::Duration::from_millis(policy_wait_ms);
        let mut workers = Vec::with_capacity(replicas);
        let mut replica_metrics = Vec::with_capacity(replicas);
        for ri in 0..replicas {
            let own = Arc::new(Mutex::new(ServeMetrics::default()));
            replica_metrics.push(own.clone());
            let harness = ReplicaHarness {
                ri,
                config,
                manifest: manifest.clone(),
                model: model.to_string(),
                artifact: artifact.clone(),
                front: front.clone(),
                sinks: MetricSinks { rollup: metrics.clone(), own },
                stop: stop.clone(),
                live: live.clone(),
                wait,
                plan: fault_plan,
                tele: telemetry.clone(),
            };
            let itx = init_tx.clone();
            workers.push(std::thread::spawn(move || replica_supervisor(harness, itx)));
        }
        drop(init_tx);

        // collect every replica's init result before deciding: a partial
        // fleet must not serve (replicas are interchangeable consumers,
        // so a silently-missing one would just skew throughput)
        let mut shape: Option<(usize, usize)> = None;
        let mut compile_ms = 0.0f64;
        let mut failures: Vec<String> = Vec::new();
        for _ in 0..replicas {
            match init_rx.recv() {
                Ok((_, Ok((tpi, nc, cms)))) => {
                    // replicas load the same bundle; a shape mismatch
                    // means the artifact changed mid-start
                    match shape {
                        None => shape = Some((tpi, nc)),
                        Some(s) if s != (tpi, nc) => {
                            failures.push(format!(
                                "replica shape mismatch: {s:?} vs {:?}",
                                (tpi, nc)
                            ));
                        }
                        Some(_) => {}
                    }
                    // loads run concurrently: the deployment pays the max
                    compile_ms = compile_ms.max(cms);
                }
                Ok((ri, Err(e))) => failures.push(format!("replica {ri}: {e}")),
                Err(_) => failures.push("executor thread died during init".to_string()),
            }
        }
        if !failures.is_empty() || shape.is_none() {
            stop.store(true, Ordering::SeqCst);
            front.close();
            for w in workers {
                let _ = w.join();
            }
            return Err(anyhow::anyhow!(
                "model '{model}' failed to load: {}",
                failures.join("; ")
            ));
        }
        let (tokens_per_image, num_classes) = shape.expect("checked above");

        Ok(Self {
            name: model.to_string(),
            config,
            front,
            next_id: AtomicU64::new(0),
            metrics,
            replica_metrics,
            stop,
            live,
            workers,
            tokens_per_image,
            num_classes,
            compile_ms,
            artifact,
            telemetry,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// The execution backend this server was started on.
    pub fn backend(&self) -> BackendKind {
        self.config.backend
    }

    /// The full runtime configuration (backend + explicit lane count).
    pub fn config(&self) -> RuntimeConfig {
        self.config
    }

    /// Number of executor replicas serving this model's queue.
    pub fn replicas(&self) -> usize {
        self.workers.len()
    }

    /// Replicas currently serving: started minus permanently retired.
    /// Equals [`Self::replicas`] unless supervision gave up on a
    /// flapping replica and degraded the fleet.
    pub fn live_replicas(&self) -> usize {
        self.live.load(Ordering::SeqCst)
    }

    /// The front queue's admission bound (`None` = unbounded).
    pub fn queue_capacity(&self) -> Option<usize> {
        self.front.capacity()
    }

    /// Requests currently queued at the front door (snapshot).
    pub fn queue_len(&self) -> usize {
        self.front.len()
    }

    /// The shared immutable model artifact every replica borrows
    /// (interpreter backend; `None` on per-thread-load backends).
    /// Clone it to observe sharing from outside: `strong_count` grows
    /// with the fleet and falls back to the callers' handles on drop,
    /// and `footprint_bytes` is the whole fleet's weight memory — once,
    /// not per replica.
    pub fn artifact(&self) -> Option<&ModelArtifact> {
        self.artifact.as_ref()
    }

    /// This fleet's telemetry handle (off unless the config resolved a
    /// trace path). Useful for asserting trace state in tests.
    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    /// Per-replica metrics snapshot (same order as replica indices).
    /// Each request is recorded by exactly one replica, so these sum to
    /// the rolled-up [`Self::metrics`] — including `failed`.
    pub fn replica_metrics(&self) -> Vec<ServeMetrics> {
        self.replica_metrics.iter().map(|m| m.lock().unwrap().clone()).collect()
    }

    pub fn tokens_per_image(&self) -> usize {
        self.tokens_per_image
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// Backend load/compile time for all batch variants (the "bitstream
    /// load" the paper amortizes once per deployment).
    pub fn compile_ms(&self) -> f64 {
        self.compile_ms
    }

    /// Submit one image; returns the reply channel. The reply is always
    /// delivered: `Ok(Response)` with the logits, or `Err` if the
    /// dispatch failed or the server shut down before the request ran.
    pub fn submit(&self, tokens: Vec<f32>) -> crate::Result<Receiver<crate::Result<Response>>> {
        self.submit_with_deadline(tokens, None)
    }

    /// [`Self::submit`] with an answer-by budget. If no executor picks
    /// the request up before `deadline` elapses, it is answered with a
    /// downcastable [`DeadlineExceeded`] error *without* computing its
    /// forward pass. On a bounded queue at capacity, admission itself
    /// fails with a downcastable [`Overloaded`] error (counted as
    /// `shed` in the rollup metrics) — the request was never accepted,
    /// so there is no reply channel to wait on.
    pub fn submit_with_deadline(
        &self,
        tokens: Vec<f32>,
        deadline: Option<Duration>,
    ) -> crate::Result<Receiver<crate::Result<Response>>> {
        self.submit_from(AdmitSource::InProcess, tokens, deadline)
    }

    /// [`Self::submit_with_deadline`] with an explicit admission
    /// source, so overload accounting attributes shed requests to the
    /// entry point that produced them (the HTTP front door submits
    /// with [`AdmitSource::Http`]).
    ///
    /// A deadline that has *already* expired at admission — including
    /// `Some(Duration::ZERO)` — never enqueues: the reply channel is
    /// answered with [`DeadlineExceeded`] immediately and the request
    /// is counted as `expired` (not `shed`), exactly as if it had
    /// died waiting at the front of the queue.
    pub fn submit_from(
        &self,
        source: AdmitSource,
        tokens: Vec<f32>,
        deadline: Option<Duration>,
    ) -> crate::Result<Receiver<crate::Result<Response>>> {
        anyhow::ensure!(
            tokens.len() == self.tokens_per_image,
            "expected {} token values, got {}",
            self.tokens_per_image,
            tokens.len()
        );
        let (tx, rx) = channel();
        let now = Instant::now();
        let rid = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id: rid,
            tokens,
            enqueued: now,
            deadline: deadline.map(|d| now + d),
            reply: tx,
        };
        // admission instants land on tid 0 ("client"): exactly one
        // non-shed "admit" per accepted request — a supervisor requeue
        // after a replica death emits "retry" events, never a second
        // admission root
        let t_admit = self.telemetry.ts_us(now);
        if req.expired(now) {
            // dead on arrival: short-circuit at admission instead of
            // queueing work every executor would only throw away. The
            // reply still flows through the channel, so callers see
            // the same one-reply shape as a pop-time expiry. Like
            // `shed`, this never reaches a replica — rollup only.
            self.metrics.lock().unwrap().expired += 1;
            self.telemetry.record(|b| {
                let pid = b.pid();
                b.push(
                    TraceEvent::instant("admit", "request", pid, 0, t_admit)
                        .with_id(rid)
                        .with_note("expired"),
                );
            });
            let _ = req.reply.send(Err(anyhow::Error::new(DeadlineExceeded { id: rid })));
            return Ok(rx);
        }
        match self.front.push(req) {
            Ok(()) => {
                self.telemetry.record(|b| {
                    let pid = b.pid();
                    b.push(TraceEvent::instant("admit", "request", pid, 0, t_admit).with_id(rid));
                });
                Ok(rx)
            }
            Err(Rejected::Closed(_)) => Err(anyhow::anyhow!("server stopped")),
            Err(Rejected::Overloaded(_)) => {
                // shed requests never reach a replica: the rollup is the
                // only sink that sees them (replica sums exclude shed by
                // design — documented on `ServeMetrics::shed`)
                {
                    let mut m = self.metrics.lock().unwrap();
                    m.shed += 1;
                    *m.shed_by_source.entry(source.label()).or_default() += 1;
                }
                self.telemetry.record(|b| {
                    let pid = b.pid();
                    b.push(
                        TraceEvent::instant("admit", "request", pid, 0, t_admit)
                            .with_id(rid)
                            .with_note("shed"),
                    );
                });
                let capacity = self.front.capacity().expect("overload implies a bound");
                Err(anyhow::Error::new(Overloaded { capacity }))
            }
        }
    }

    /// Submit a set of images and wait for all replies (offline driver).
    pub fn infer_all(&self, images: Vec<Vec<f32>>) -> crate::Result<Vec<Response>> {
        let rxs: Vec<_> = images.into_iter().map(|i| self.submit(i)).collect::<Result<_, _>>()?;
        rxs.into_iter()
            .map(|rx| rx.recv().map_err(|e| anyhow::anyhow!("reply lost: {e}"))?)
            .collect()
    }
}

impl Drop for ModelServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock every replica by closing the queue; each loop's
        // shutdown drain then fails its share of the queued + pending
        // requests explicitly (clients blocked on `recv` get an error,
        // not a dropped sender) — one replica per request, no double
        // counting
        self.front.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// The metric destinations one executor replica records into: the
/// server-wide rollup (what [`ModelServer::metrics`] exposes) and the
/// replica's own breakdown. Each request is drained by exactly one
/// replica, so recording into both sinks keeps `rollup == Σ replicas`
/// for every counter, including `failed`.
///
/// The rollup is deliberately **materialized** rather than derived from
/// the replica sinks at read time: `ModelServer::metrics` is a shared
/// `Arc` that callers clone and may read *after* the server (and its
/// replica sinks) is gone — the shutdown-accounting tests rely on that.
/// The cost is one extra mutex lock per *batch* (not per request) and a
/// duplicate latency sample; both are noise next to a dispatch.
struct MetricSinks {
    rollup: Arc<Mutex<ServeMetrics>>,
    own: Arc<Mutex<ServeMetrics>>,
}

impl MetricSinks {
    fn each(&self, f: impl Fn(&mut ServeMetrics)) {
        f(&mut self.rollup.lock().unwrap());
        f(&mut self.own.lock().unwrap());
    }
}

/// What a replica reports back to the fleet starter: its index plus
/// either `(tokens_per_image, num_classes, compile_ms)` or the build
/// error.
type InitResult = (usize, Result<(usize, usize, f64), String>);

/// Everything one replica's supervisor needs to build, run, and rebuild
/// its executor runtime.
struct ReplicaHarness {
    ri: usize,
    config: RuntimeConfig,
    manifest: Manifest,
    model: String,
    artifact: Option<ModelArtifact>,
    front: Arc<FrontQueue<Request>>,
    sinks: MetricSinks,
    stop: Arc<AtomicBool>,
    live: Arc<AtomicUsize>,
    wait: Duration,
    plan: Option<faults::FaultPlan>,
    /// The fleet's trace handle; this replica allocates its own tid and
    /// ring buffer from it, and resident pipeline stages theirs.
    tele: Telemetry,
}

/// A flapping replica — this many consecutive deaths without a single
/// completed dispatch in between — is retired permanently: restarting a
/// deterministically-crashing replica forever would burn a core
/// reloading weights.
const MAX_CONSECUTIVE_DEATHS: u32 = 6;
/// Exponential restart backoff: `BASE << (deaths - 1)`, capped.
const RESTART_BACKOFF_BASE: Duration = Duration::from_millis(1);
const RESTART_BACKOFF_CAP: Duration = Duration::from_millis(64);

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run one replica under supervision: build the runtime (reporting the
/// result over `init_tx`), serve, and on a panic inside the serve loop
/// requeue the replica's accepted requests, rebuild the runtime from
/// the shared artifact with capped exponential backoff, and resume.
/// The `pending`/`inflight` vectors live HERE, outside the unwind
/// boundary, so a panic can never drop a reply sender silently.
fn replica_supervisor(h: ReplicaHarness, init_tx: Sender<InitResult>) {
    let mut injector = h.plan.map(|p| p.injector(h.ri));
    // the replica's trace identity and ring buffer, allocated once and
    // reused across supervised rebuilds (tid 0 when tracing is off)
    let trace_tid = h.tele.alloc_tid(&format!("replica{}", h.ri));
    let mut tracebuf = h.tele.buffer();
    // build this replica's mutable runtime (fabric lanes or resident
    // pipeline + scratch) — from the shared artifact when there is one,
    // else a full per-thread load (the paper's bitstream load, once per
    // engine). Used for the initial build and every supervised rebuild.
    let build = |inj: &mut Option<FaultInjector>| -> Result<(LoadedModel, BatchPolicy), String> {
        if let Some(i) = inj.as_mut() {
            if i.load_fails() {
                return Err("injected artifact-load failure (faults harness)".to_string());
            }
        }
        let loaded = match &h.artifact {
            Some(a) => runtime::load_model_from_artifact_traced(h.config, a, &h.tele),
            None => runtime::load_model(h.config, &h.manifest, &h.model),
        }
        .map_err(|e| format!("{e:#}"))?;
        let policy =
            BatchPolicy::new(loaded.executors.iter().map(|e| e.batch()).collect(), h.wait)
                .map_err(|e| format!("{e:#}"))?;
        Ok((loaded, policy))
    };
    let mut runtime_slot: Option<(LoadedModel, BatchPolicy)> = match build(&mut injector) {
        Err(e) => {
            let _ = init_tx.send((h.ri, Err(e)));
            return;
        }
        Ok(built) => {
            let _ = init_tx.send((
                h.ri,
                Ok((built.0.tokens_per_image, built.0.num_classes, built.0.compile_ms)),
            ));
            Some(built)
        }
    };
    // release the init sender BEFORE serving: if a sibling replica
    // panics inside load_model (no message sent), the starter's recv
    // must observe disconnection rather than block behind this
    // replica's still-alive sender for the whole serve lifetime
    drop(init_tx);
    let tokens_per_image = runtime_slot.as_ref().expect("just built").0.tokens_per_image;
    let num_classes = runtime_slot.as_ref().expect("just built").0.num_classes;
    // wall-clock base for stage-occupancy fractions: this runtime's
    // (re)build time — occupancy is busy/wall since the stages spawned
    let mut built_at = Instant::now();

    let mut pending: Vec<Request> = Vec::new();
    let mut inflight: Vec<Request> = Vec::new();
    let mut deaths: u32 = 0;
    let mut retired = false;
    'supervise: loop {
        let current = runtime_slot.as_ref().expect("runtime present while supervising");
        let mut dispatched = false;
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            executor_loop(
                &h.front,
                &current.0.executors,
                &current.1,
                tokens_per_image,
                num_classes,
                &h.sinks,
                &h.stop,
                &mut pending,
                &mut inflight,
                &mut injector,
                &mut dispatched,
                h.ri,
                built_at,
                &mut tracebuf,
                trace_tid,
            )
        }));
        let payload = match run {
            // normal return: queue closed or stop requested
            Ok(()) => break 'supervise,
            Err(payload) => payload,
        };
        let msg = panic_message(payload.as_ref());
        drop(payload);
        h.sinks.each(|m| m.restarts += 1);
        // hand the replica's accepted requests back: the batch that was
        // executing when the panic hit plus everything staged behind it
        // returns to the FRONT of the shared queue (oldest first) for a
        // sibling — or this replica, once restarted — to run. The
        // forward pass is pure and no reply has been sent for any of
        // these, so re-execution preserves exactly-once replies. Only
        // when the queue is already closed (shutdown racing the panic)
        // are they failed explicitly instead.
        let orphans: Vec<Request> = inflight.drain(..).chain(pending.drain(..)).collect();
        let mut retried = 0u64;
        let mut lost: Vec<Request> = Vec::new();
        for r in orphans.into_iter().rev() {
            let rid = r.id;
            match h.front.requeue(r) {
                Ok(()) => {
                    retried += 1;
                    // a requeue is a retry event, NOT a second admission:
                    // the request keeps its one "admit" root
                    if let Some(b) = &mut tracebuf {
                        let pid = b.pid();
                        let now = b.now();
                        b.push(
                            TraceEvent::instant("retry", "retry", pid, trace_tid, now)
                                .with_id(rid),
                        );
                    }
                }
                Err(r) => lost.push(r),
            }
        }
        if retried > 0 {
            h.sinks.each(|m| m.retried += retried);
        }
        if !lost.is_empty() {
            let n = lost.len() as u64;
            h.sinks.each(|m| m.failed += n);
            for r in lost {
                let _ = r.reply.send(Err(anyhow::anyhow!(
                    "replica died while request {} was queued on it ({msg}) and the server is shutting down",
                    r.id
                )));
            }
        }
        if h.stop.load(Ordering::SeqCst) {
            break 'supervise;
        }
        // tear the (possibly wedged) runtime down before rebuilding —
        // its drop joins the fabric workers / stage threads. Teardown
        // of a panicked runtime may itself panic; that must not kill
        // the supervisor (the exact silent-death mode it exists to fix)
        let old = runtime_slot.take();
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || drop(old)));
        deaths = if dispatched { 1 } else { deaths + 1 };
        // capped exponential backoff, then rebuild from the shared
        // artifact. A rebuild failure (including injected load
        // failures) counts as another death and extends the backoff.
        loop {
            if deaths > MAX_CONSECUTIVE_DEATHS {
                eprintln!(
                    "warning: replica {} of '{}' retired after {} consecutive deaths (last: {msg})",
                    h.ri, h.model, deaths
                );
                h.sinks.each(|m| m.retired += 1);
                retired = true;
                if h.live.fetch_sub(1, Ordering::SeqCst) == 1 {
                    // last live replica: close the front door so new
                    // submits fail fast and the drain below answers
                    // whatever is still queued — graceful total
                    // degradation instead of a silently hung fleet
                    h.front.close();
                }
                break 'supervise;
            }
            let exp = deaths.saturating_sub(1).min(16);
            let backoff = RESTART_BACKOFF_BASE
                .saturating_mul(1u32 << exp)
                .min(RESTART_BACKOFF_CAP);
            std::thread::sleep(backoff);
            if h.stop.load(Ordering::SeqCst) {
                break 'supervise;
            }
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| build(&mut injector))) {
                Ok(Ok(built))
                    if built.0.tokens_per_image == tokens_per_image
                        && built.0.num_classes == num_classes =>
                {
                    runtime_slot = Some(built);
                    built_at = Instant::now();
                    continue 'supervise;
                }
                // a rebuild that comes back with different shapes means
                // the artifact changed underneath us — flap to retirement
                Ok(Ok(_)) => deaths = MAX_CONSECUTIVE_DEATHS + 1,
                Ok(Err(_)) | Err(_) => deaths += 1,
            }
        }
    }

    // shutdown drain: runs when the stream actually ended (queue closed
    // or stop requested) — whatever this replica still holds, plus
    // whatever it can win from the shared queue, will never run; fail
    // each request deterministically so no client hangs on `recv`. Pops
    // are exclusive, so concurrent replica drains never fail one
    // request twice. A retired replica with live siblings skips the
    // queue drain (its own requests were already requeued): the queue
    // still belongs to the survivors.
    if h.stop.load(Ordering::SeqCst) || h.front.is_closed() {
        while let Some(r) = h.front.try_pop() {
            pending.push(r);
        }
    }
    let leftovers: Vec<Request> = inflight.drain(..).chain(pending.drain(..)).collect();
    if !leftovers.is_empty() {
        let n = leftovers.len() as u64;
        h.sinks.each(|m| m.failed += n);
        for r in leftovers {
            let _ = r.reply.send(Err(anyhow::anyhow!(
                "server shut down before request {} was executed",
                r.id
            )));
        }
    }
    // a normally-stopping replica is still "live" right up to shutdown;
    // decrement only so the gauge reads 0 after the fleet is joined.
    // (Retired replicas already decremented.)
    if !retired {
        h.live.fetch_sub(1, Ordering::SeqCst);
    }
}

#[allow(clippy::too_many_arguments)]
fn executor_loop(
    front: &FrontQueue<Request>,
    executables: &[Box<dyn Executor>],
    policy: &BatchPolicy,
    tokens_per_image: usize,
    num_classes: usize,
    sinks: &MetricSinks,
    stop: &AtomicBool,
    pending: &mut Vec<Request>,
    inflight: &mut Vec<Request>,
    injector: &mut Option<FaultInjector>,
    dispatched: &mut bool,
    ri: usize,
    // when this replica's runtime was (re)built — the wall-clock base
    // its stage-occupancy fractions are measured against
    runtime_built: Instant,
    tele: &mut Option<TraceBuf>,
    trace_tid: u64,
) {
    'serve: loop {
        if stop.load(Ordering::SeqCst) {
            break 'serve;
        }
        // top up the pending queue (non-blocking drain, bounded block if
        // empty); other replicas compete on the same front queue, and
        // each pop transfers exclusive ownership of that request. The
        // timeout is only a safety poll — pushes and close() both wake
        // parked poppers immediately, so idle replicas mostly sleep
        if pending.is_empty() {
            match front.pop_timeout(std::time::Duration::from_millis(100)) {
                Pop::Item(r) => pending.push(r),
                Pop::TimedOut => continue,
                Pop::Closed => break 'serve,
            }
        }
        // top up to at most one full largest-variant batch: draining the
        // whole backlog would hoard requests in this replica's private
        // `pending` where idle sibling replicas cannot steal them,
        // collapsing a bursty submission back to single-replica speed
        while pending.len() < policy.largest() {
            match front.try_pop() {
                Some(r) => pending.push(r),
                None => break,
            }
        }

        // deadline sweep before spending any compute: a request whose
        // answer-by time has passed gets an explicit DeadlineExceeded
        // reply now, and never occupies a batch lane
        let now = Instant::now();
        if pending.iter().any(|r| r.expired(now)) {
            let mut keep = Vec::with_capacity(pending.len());
            let mut doomed = Vec::new();
            for r in pending.drain(..) {
                if r.expired(now) {
                    doomed.push(r);
                } else {
                    keep.push(r);
                }
            }
            *pending = keep;
            let n = doomed.len() as u64;
            sinks.each(|m| m.expired += n);
            if let Some(b) = tele.as_mut() {
                let pid = b.pid();
                let ts = b.now();
                for r in &doomed {
                    let ev = TraceEvent::instant("expired", "request", pid, trace_tid, ts);
                    b.push(ev.with_id(r.id));
                }
            }
            for r in doomed {
                let _ = r.reply.send(Err(anyhow::Error::new(DeadlineExceeded { id: r.id })));
            }
            if pending.is_empty() {
                continue 'serve;
            }
        }

        let head_waited = pending[0].enqueued.elapsed();
        let Some(batch) = policy.decide(pending.len(), head_waited) else {
            // a partial batch is waiting out `max_wait`: block for exactly
            // the residual head-of-line deadline instead of burning a core
            // in a sleep/poll spin — a new arrival wakes us early (it may
            // complete a batch), the timeout lands us past the deadline
            match front.pop_timeout(policy.residual_wait(head_waited)) {
                Pop::Item(r) => pending.push(r),
                Pop::TimedOut => {}
                Pop::Closed => break 'serve,
            }
            continue;
        };
        let exe = executables
            .iter()
            .find(|e| e.batch() == batch)
            .expect("policy only returns available variants");

        // the queue may be smaller than the chosen variant (head-of-line
        // timeout with a sparse queue): pad the missing lanes with zeros
        // and discard their outputs. The dispatch batch moves to the
        // supervisor-owned `inflight` so a panic below can requeue it.
        let take = batch.min(pending.len());
        inflight.extend(pending.drain(..take));
        let mut input = vec![0.0f32; batch * tokens_per_image];
        for (i, r) in inflight.iter().enumerate() {
            input[i * tokens_per_image..(i + 1) * tokens_per_image].copy_from_slice(&r.tokens);
        }
        // per-image attribution divides by the number of REAL images in
        // the dispatch, not the variant width: zero-padded lanes are
        // serving overhead, and dividing by `batch` understated both the
        // queue wait and the execution cost whenever lanes were padded
        let queue_ms = inflight
            .iter()
            .map(|r| r.enqueued.elapsed().as_secs_f64() * 1e3)
            .sum::<f64>()
            / inflight.len() as f64;
        // fault injection point (off ⇒ `injector` is None ⇒ zero cost):
        // a Panic here simulates the replica dying mid-dispatch with the
        // batch in flight; a Stall simulates a wedged/slow stage
        if let Some(inj) = injector.as_mut() {
            match inj.dispatch_fault() {
                Some(Fault::Panic) => panic!("injected replica panic (faults harness)"),
                Some(Fault::Stall(d)) => std::thread::sleep(d),
                None => {}
            }
        }
        let t0 = Instant::now();
        // one queue-wait span per request in the dispatch, closed at
        // dispatch start — all ending at the same tick, so they nest
        // cleanly on this replica's tid
        if let Some(b) = tele.as_mut() {
            let pid = b.pid();
            let t_dispatch = b.ts(t0);
            for r in inflight.iter() {
                let ts = b.ts(r.enqueued);
                b.push(
                    TraceEvent::span(
                        "queue_wait",
                        "request",
                        pid,
                        trace_tid,
                        ts,
                        t_dispatch.saturating_sub(ts),
                    )
                    .with_id(r.id),
                );
            }
        }
        let out = match exe.run_f32(&input) {
            Ok(o) => o,
            Err(e) => {
                // answer every drained request with the error instead of
                // dropping their senders (which left clients hanging on
                // `recv` until an opaque "reply lost")
                let msg = format!("{e:#}");
                let n = inflight.len() as u64;
                sinks.each(|m| m.failed += n);
                for r in inflight.drain(..) {
                    let _ = r.reply.send(Err(anyhow::anyhow!(
                        "executor error running request {}: {msg}",
                        r.id
                    )));
                }
                // an error reply still *completed* a dispatch: the
                // runtime made progress, so it counts against flapping
                // exactly like a success does
                *dispatched = true;
                continue;
            }
        };
        let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
        let per_image_exec_ms = exec_ms / inflight.len() as f64;
        if let Some(b) = tele.as_mut() {
            // the dispatch span, with the interpreter's per-op kernel
            // spans (when profiling is on) clamped inside it
            let pid = b.pid();
            let ts = b.ts(t0);
            let end = b.now().max(ts);
            b.push(
                TraceEvent::span("exec", "dispatch", pid, trace_tid, ts, end - ts)
                    .with_batch(inflight.len() as u64),
            );
            if let Some(p) = exe.take_op_profile() {
                b.push_op_spans(trace_tid, ts, end, &p.named_ms());
            }
            b.maybe_flush(256);
        }
        // stage occupancy rides every dispatch (always on, not only
        // when tracing): pipeline executors snapshot their cumulative
        // stage counters into the serve metrics; other executors
        // report nothing and skip this entirely
        if let Some(ps) = exe.pipeline_stats() {
            let wall_ms = runtime_built.elapsed().as_secs_f64() * 1e3;
            let occ: Vec<StageOcc> = ps
                .stages
                .iter()
                .map(|s| StageOcc {
                    name: s.name.clone(),
                    images: s.images,
                    busy_ms: s.busy_ms,
                    wall_ms,
                    stalls_empty: s.stalls_empty,
                    stalls_full: s.stalls_full,
                })
                .collect();
            sinks.each(|m| m.update_stage_occupancy(ri, occ.clone()));
        }

        {
            // snapshot the latencies once so rollup and replica sinks
            // record identical values
            let finished = Instant::now();
            let lats: Vec<std::time::Duration> =
                inflight.iter().map(|r| r.enqueued.elapsed()).collect();
            sinks.each(|m| {
                // replicas race on the rollup: keep the EARLIEST start
                // and the LATEST finish, not first/last-writer-wins —
                // otherwise a replica recording out of order shrinks
                // (or inverts) the throughput window
                m.started = Some(match m.started {
                    Some(s) if s <= t0 => s,
                    _ => t0,
                });
                m.finished = Some(match m.finished {
                    Some(f) if f >= finished => f,
                    _ => finished,
                });
                for &lat in &lats {
                    m.record(lat, batch, per_image_exec_ms, queue_ms);
                }
            });
        }
        for (i, r) in inflight.drain(..).enumerate() {
            let logits = out[i * num_classes..(i + 1) * num_classes].to_vec();
            // total_cmp, not partial_cmp().unwrap(): a NaN logit (e.g. a
            // backend numerics bug) must misclassify one image, not
            // panic the replica thread
            let argmax = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(j, _)| j)
                .unwrap_or(0);
            let _ = r.reply.send(Ok(Response {
                id: r.id,
                logits,
                argmax,
                latency: r.enqueued.elapsed(),
            }));
        }
        // a completed dispatch proves the rebuilt runtime works: the
        // supervisor resets its consecutive-death count on this
        *dispatched = true;
    }
    // the shutdown drain lives in `replica_supervisor`, which owns
    // `pending`/`inflight` across panics
}

/// One model's slot in the [`Router`]'s zoo: the live server fleet,
/// its monotonically increasing version, and the final metrics of
/// every version that has been swapped out.
struct ModelEntry {
    name: String,
    /// Starts at 1 on load; bumped by every successful swap.
    version: u64,
    server: Arc<ModelServer>,
    /// `(version, final metrics)` of drained versions, oldest first.
    /// A `ServeMetrics` Arc outlives its server by design (see
    /// [`MetricSinks`]), so a retired version's counters — including
    /// the requests its drain-then-swap failed — stay readable after
    /// the fleet is joined, and stay *out* of the replacement's
    /// counters: per-version lines decompose the total, never double
    /// count it.
    retired: Vec<(u64, Arc<Mutex<ServeMetrics>>)>,
}

fn serving_list(entries: &[ModelEntry]) -> String {
    entries.iter().map(|e| e.name.as_str()).collect::<Vec<_>>().join(", ")
}

/// Route requests across several models (the vLLM-style front door):
/// one [`ModelServer`] per model name — each with its own executor
/// replica fleet, every replica borrowing one shared immutable
/// [`ModelArtifact`] — with submission routed by model name and
/// per-model + per-replica + per-version metrics export. `hgpipe serve
/// --models a,b` drives one of these.
///
/// The zoo is **hot**: [`Router::load`] / [`Router::unload`] /
/// [`Router::swap`] change what one long-lived process serves, with
/// drain-then-swap semantics — a swapped-out version finishes its
/// in-flight dispatches and fails whatever is still queued explicitly
/// (the [`ModelServer`] delivery guarantee: every accepted request gets
/// exactly one reply), and its weight memory is freed when the last
/// `Arc` handle drops. Routing state lives behind a lock so swaps can
/// happen while other threads submit; a submit that races a swap and
/// lands on the closing queue gets an explicit "server stopped" error
/// (never a silent drop) and can simply be resubmitted — it will route
/// to the new version.
pub struct Router {
    entries: RwLock<Vec<ModelEntry>>,
}

impl Router {
    pub fn new(servers: Vec<ModelServer>) -> Self {
        Self {
            entries: RwLock::new(
                servers
                    .into_iter()
                    .map(|s| ModelEntry {
                        name: s.name().to_string(),
                        version: 1,
                        server: Arc::new(s),
                        retired: Vec::new(),
                    })
                    .collect(),
            ),
        }
    }

    /// Start one server per model name, all on the same runtime config.
    /// Duplicate names are rejected (routing would silently shadow one).
    pub fn start(
        manifest: &Manifest,
        models: &[String],
        policy_wait_ms: u64,
        config: RuntimeConfig,
    ) -> crate::Result<Self> {
        anyhow::ensure!(!models.is_empty(), "router needs at least one model");
        let mut servers: Vec<ModelServer> = Vec::with_capacity(models.len());
        for m in models {
            anyhow::ensure!(
                servers.iter().all(|s| s.name() != m),
                "duplicate model '{m}' in --models"
            );
            servers.push(ModelServer::start_with_config(manifest, m, policy_wait_ms, config)?);
        }
        Ok(Self::new(servers))
    }

    /// The live server fleet for `model` (its current version). The
    /// returned handle pins that version: a concurrent swap retires it
    /// from routing, but drain + join wait for the last handle.
    pub fn server(&self, model: &str) -> Option<Arc<ModelServer>> {
        self.entries
            .read()
            .unwrap()
            .iter()
            .find(|e| e.name == model)
            .map(|e| e.server.clone())
    }

    /// The current version of `model` (1 until the first swap).
    pub fn version(&self, model: &str) -> Option<u64> {
        self.entries.read().unwrap().iter().find(|e| e.name == model).map(|e| e.version)
    }

    /// The server for `model`, or a downcastable [`UnknownModel`]
    /// naming what *is* being served (the front door maps it to 404).
    fn routed(&self, model: &str) -> crate::Result<Arc<ModelServer>> {
        self.server(model).ok_or_else(|| {
            anyhow::Error::new(UnknownModel { model: model.to_string(), serving: self.models() })
        })
    }

    /// Route one request to `model`'s current server. The request is
    /// pinned to the version that accepted it; a swap racing this call
    /// either queues it on the old version (which drains it — reply or
    /// explicit failure) or surfaces an explicit "server stopped"
    /// error, in which case resubmitting routes to the new version.
    pub fn submit(
        &self,
        model: &str,
        tokens: Vec<f32>,
    ) -> crate::Result<Receiver<crate::Result<Response>>> {
        self.routed(model)?.submit(tokens)
    }

    /// [`Self::submit`] with an answer-by budget (see
    /// [`ModelServer::submit_with_deadline`] for the `Overloaded` /
    /// `DeadlineExceeded` semantics).
    pub fn submit_with_deadline(
        &self,
        model: &str,
        tokens: Vec<f32>,
        deadline: Option<Duration>,
    ) -> crate::Result<Receiver<crate::Result<Response>>> {
        self.routed(model)?.submit_with_deadline(tokens, deadline)
    }

    /// [`Self::submit_with_deadline`] with an explicit
    /// [`AdmitSource`], so the edge's shed accounting is attributed
    /// (see [`ModelServer::submit_from`]).
    pub fn submit_from(
        &self,
        source: AdmitSource,
        model: &str,
        tokens: Vec<f32>,
        deadline: Option<Duration>,
    ) -> crate::Result<Receiver<crate::Result<Response>>> {
        self.routed(model)?.submit_from(source, tokens, deadline)
    }

    /// Route a whole image set to `model`'s server and wait for replies.
    pub fn infer_all(&self, model: &str, images: Vec<Vec<f32>>) -> crate::Result<Vec<Response>> {
        self.routed(model)?.infer_all(images)
    }

    /// Add a model to the zoo at version 1. The fleet starts (and may
    /// fail, atomically) *before* the routing table changes: a failed
    /// load leaves the zoo serving exactly what it served before.
    pub fn load(
        &self,
        manifest: &Manifest,
        model: &str,
        policy_wait_ms: u64,
        config: RuntimeConfig,
    ) -> crate::Result<()> {
        anyhow::ensure!(
            self.server(model).is_none(),
            "model '{model}' is already served (swap it instead)"
        );
        let server = ModelServer::start_with_config(manifest, model, policy_wait_ms, config)?;
        let mut entries = self.entries.write().unwrap();
        // re-check under the write lock: a concurrent load may have won
        anyhow::ensure!(
            entries.iter().all(|e| e.name != model),
            "model '{model}' is already served (swap it instead)"
        );
        entries.push(ModelEntry {
            name: model.to_string(),
            version: 1,
            server: Arc::new(server),
            retired: Vec::new(),
        });
        Ok(())
    }

    /// Remove a model from the zoo: unroute it, then drain — queued and
    /// in-flight requests complete or are failed explicitly (exactly
    /// one reply each) — and join its fleet. The weight artifact is
    /// freed when the last outside handle (if any) drops.
    pub fn unload(&self, model: &str) -> crate::Result<()> {
        let entry = {
            let mut entries = self.entries.write().unwrap();
            let Some(i) = entries.iter().position(|e| e.name == model) else {
                let serving = serving_list(&entries);
                anyhow::bail!("no server for model '{model}' to unload (serving: {serving})");
            };
            entries.remove(i)
        };
        // drain + join OUTSIDE the lock: unloading one model must not
        // stall routing for the others
        drop(entry);
        Ok(())
    }

    /// Hot-swap `model` to a freshly loaded fleet (drain-then-swap);
    /// returns the new version number.
    ///
    /// Order of operations is the whole guarantee:
    /// 1. the replacement fleet starts first, atomically — a failed
    ///    start returns the error and leaves the old version serving;
    /// 2. the routing table flips to the new fleet and the old
    ///    version's metrics are retired (they keep its counters, so
    ///    per-version lines always sum to the total);
    /// 3. the old fleet drains outside the lock: in-flight dispatches
    ///    finish, still-queued requests are failed explicitly — every
    ///    accepted request still gets exactly one reply, none are
    ///    silently dropped — and the fleet joins. Its share of the old
    ///    artifact drops with it.
    pub fn swap(
        &self,
        manifest: &Manifest,
        model: &str,
        policy_wait_ms: u64,
        config: RuntimeConfig,
    ) -> crate::Result<u64> {
        let fresh = Arc::new(ModelServer::start_with_config(
            manifest,
            model,
            policy_wait_ms,
            config,
        )?);
        let mut entries = self.entries.write().unwrap();
        let Some(i) = entries.iter().position(|e| e.name == model) else {
            let serving = serving_list(&entries);
            drop(entries);
            // `fresh` drops (and drains, trivially — it never served)
            anyhow::bail!("no server for model '{model}' to swap (serving: {serving})");
        };
        let e = &mut entries[i];
        e.retired.push((e.version, e.server.metrics.clone()));
        e.version += 1;
        let version = e.version;
        let old = std::mem::replace(&mut e.server, fresh);
        drop(entries); // new version routes before the old one drains
        drop(old);
        Ok(version)
    }

    pub fn models(&self) -> Vec<String> {
        self.entries.read().unwrap().iter().map(|e| e.name.clone()).collect()
    }

    /// Per-model metrics export: a `(model, metrics)` snapshot per
    /// served model (the front door's observability surface). The
    /// snapshot is the **current version's** cross-replica rollup; see
    /// [`Self::version_metrics`] for retired versions and
    /// [`Self::metrics_lines`] / [`ModelServer::replica_metrics`] for
    /// the per-replica breakdown.
    pub fn metrics(&self) -> Vec<(String, ServeMetrics)> {
        self.entries
            .read()
            .unwrap()
            .iter()
            .map(|e| (e.name.clone(), e.server.metrics.lock().unwrap().clone()))
            .collect()
    }

    /// Every version's metrics for `model`, oldest first, current last:
    /// `(version, snapshot)`. Each request was recorded by exactly one
    /// version (drain-then-swap failures land in the version that owned
    /// the queue), so counts and failures sum to the model's lifetime
    /// totals without double counting.
    pub fn version_metrics(&self, model: &str) -> crate::Result<Vec<(u64, ServeMetrics)>> {
        let entries = self.entries.read().unwrap();
        let Some(e) = entries.iter().find(|e| e.name == model) else {
            let serving = serving_list(&entries);
            anyhow::bail!("no server for model '{model}' (serving: {serving})");
        };
        let mut out: Vec<(u64, ServeMetrics)> =
            e.retired.iter().map(|(v, m)| (*v, m.lock().unwrap().clone())).collect();
        out.push((e.version, e.server.metrics.lock().unwrap().clone()));
        Ok(out)
    }

    /// Human-readable metric report: one rollup line per model version
    /// plus — when the current fleet runs more than one executor
    /// replica — one line per replica with its queue/exec breakdown.
    /// The rollup line *is* that version's total (each request is
    /// popped and recorded by exactly one replica of exactly one
    /// version), so replica lines decompose their version line and
    /// version lines decompose the model's lifetime — failed dispatches
    /// and drain-then-swap failures included, each counted once.
    ///
    /// A never-swapped model keeps the unversioned `[model]` /
    /// `[model/replicaN]` labels; after the first swap the lines are
    /// versioned: `[model@v1]` (retired), `[model@v2]`,
    /// `[model@v2/replica0]`, ...
    pub fn metrics_lines(&self) -> Vec<String> {
        let mut lines = Vec::new();
        for e in self.entries.read().unwrap().iter() {
            let tag = if e.version == 1 && e.retired.is_empty() {
                e.name.clone()
            } else {
                format!("{}@v{}", e.name, e.version)
            };
            for (v, m) in &e.retired {
                lines.push(format!("[{}@v{}] {}", e.name, v, m.lock().unwrap().summary()));
            }
            lines.push(format!("[{tag}] {}", e.server.metrics.lock().unwrap().summary()));
            if e.server.replicas() > 1 {
                for (ri, m) in e.server.replica_metrics().into_iter().enumerate() {
                    lines.push(format!("[{tag}/replica{ri}] {}", m.summary()));
                }
            }
        }
        lines
    }

    /// Prometheus text exposition (version 0.0.4) of every serving
    /// metric: request/fault counters, live-replica and queue-depth
    /// gauges, the latency summary (p50/p95/p99/p999 + sum + count) and
    /// per-replica per-stage pipeline occupancy, labelled
    /// `model="name",version="vN"` — retired versions keep reporting
    /// their final counters, so per-version series always sum to the
    /// model's lifetime totals. Always on: this renders counters the
    /// serving path maintains anyway, independent of `--trace`.
    pub fn prometheus_text(&self) -> String {
        use std::fmt::Write as _;
        struct Row {
            labels: String,
            m: ServeMetrics,
            /// Live gauges exist only for the currently-routed version.
            live: Option<(usize, usize)>, // (live_replicas, queue_len)
        }
        let mut rows: Vec<Row> = Vec::new();
        for e in self.entries.read().unwrap().iter() {
            for (v, m) in &e.retired {
                rows.push(Row {
                    labels: format!("model=\"{}\",version=\"v{}\"", e.name, v),
                    m: m.lock().unwrap().clone(),
                    live: None,
                });
            }
            rows.push(Row {
                labels: format!("model=\"{}\",version=\"v{}\"", e.name, e.version),
                m: e.server.metrics.lock().unwrap().clone(),
                live: Some((e.server.live_replicas(), e.server.queue_len())),
            });
        }

        let mut out = String::new();
        let mut family = |name: &str, kind: &str, help: &str, values: Vec<(String, String)>| {
            if values.is_empty() {
                return;
            }
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} {kind}");
            for (labels, v) in values {
                let _ = writeln!(out, "{name}{{{labels}}} {v}");
            }
        };
        let counters: [(&str, &str, fn(&ServeMetrics) -> u64); 7] = [
            ("hgpipe_requests_total", "Requests completed successfully.", |m| m.count() as u64),
            ("hgpipe_requests_failed_total", "Requests answered with an error.", |m| m.failed),
            (
                "hgpipe_requests_shed_total",
                "Requests rejected at admission (bounded queue full).",
                |m| m.shed,
            ),
            (
                "hgpipe_requests_expired_total",
                "Requests expired before execution (deadline).",
                |m| m.expired,
            ),
            (
                "hgpipe_requests_retried_total",
                "Requests requeued after a replica death.",
                |m| m.retried,
            ),
            ("hgpipe_replica_restarts_total", "Replica supervisor restarts.", |m| m.restarts),
            (
                "hgpipe_replicas_retired_total",
                "Replicas permanently retired after flapping.",
                |m| m.retired,
            ),
        ];
        for (name, help, pick) in counters {
            family(
                name,
                "counter",
                help,
                rows.iter().map(|r| (r.labels.clone(), pick(&r.m).to_string())).collect(),
            );
        }
        // shed, broken down by admission source (in-process callers vs
        // the HTTP front door); versions that never shed emit nothing
        let mut shed_by_source: Vec<(String, String)> = Vec::new();
        for r in &rows {
            for (src, n) in &r.m.shed_by_source {
                shed_by_source.push((format!("{},source=\"{src}\"", r.labels), n.to_string()));
            }
        }
        family(
            "hgpipe_requests_shed_by_source_total",
            "counter",
            "Requests rejected at admission, by entry point.",
            shed_by_source,
        );
        family(
            "hgpipe_live_replicas",
            "gauge",
            "Replicas currently serving (started minus retired).",
            rows.iter()
                .filter_map(|r| r.live.map(|(l, _)| (r.labels.clone(), l.to_string())))
                .collect(),
        );
        family(
            "hgpipe_queue_depth",
            "gauge",
            "Requests waiting in the front queue right now.",
            rows.iter()
                .filter_map(|r| r.live.map(|(_, q)| (r.labels.clone(), q.to_string())))
                .collect(),
        );
        family(
            "hgpipe_throughput_images_per_second",
            "gauge",
            "Completed requests per second over the serving window.",
            rows.iter()
                .filter_map(|r| {
                    r.m.throughput().map(|t| (r.labels.clone(), format!("{t:.3}")))
                })
                .collect(),
        );
        // the latency summary: quantile series plus _sum/_count, all in
        // seconds (Prometheus base units)
        let mut latency: Vec<(String, String)> = Vec::new();
        for r in &rows {
            for q in [0.5, 0.95, 0.99, 0.999] {
                if let Some(d) = r.m.percentile(q) {
                    latency.push((
                        format!("{},quantile=\"{q}\"", r.labels),
                        format!("{:.6}", d.as_secs_f64()),
                    ));
                }
            }
        }
        family(
            "hgpipe_request_latency_seconds",
            "summary",
            "End-to-end request latency (admission to reply).",
            latency,
        );
        family(
            "hgpipe_request_latency_seconds_sum",
            "counter",
            "Sum of request latencies.",
            rows.iter()
                .map(|r| {
                    (r.labels.clone(), format!("{:.6}", r.m.latency.sum_us() as f64 / 1e6))
                })
                .collect(),
        );
        family(
            "hgpipe_request_latency_seconds_count",
            "counter",
            "Count of latency observations.",
            rows.iter().map(|r| (r.labels.clone(), r.m.count().to_string())).collect(),
        );
        // per-replica per-stage pipeline occupancy (pipeline mode only —
        // empty otherwise, and the whole family is omitted)
        let stage_rows = |pick: fn(&StageOcc) -> String| -> Vec<(String, String)> {
            let mut v = Vec::new();
            for r in &rows {
                for (ri, stages) in &r.m.stages {
                    for s in stages {
                        v.push((
                            format!("{},replica=\"{ri}\",stage=\"{}\"", r.labels, s.name),
                            pick(s),
                        ));
                    }
                }
            }
            v
        };
        family(
            "hgpipe_stage_images_total",
            "counter",
            "Images processed by each resident pipeline stage.",
            stage_rows(|s| s.images.to_string()),
        );
        family(
            "hgpipe_stage_busy_seconds_total",
            "counter",
            "Compute time per resident stage (excludes channel waits).",
            stage_rows(|s| format!("{:.6}", s.busy_ms / 1e3)),
        );
        family(
            "hgpipe_stage_occupancy_ratio",
            "gauge",
            "Busy/wall fraction per resident stage since its runtime was built.",
            stage_rows(|s| format!("{:.4}", s.occupancy())),
        );
        family(
            "hgpipe_stage_stalls_empty_total",
            "counter",
            "Input-FIFO stalls (stage sat empty) per resident stage.",
            stage_rows(|s| s.stalls_empty.to_string()),
        );
        family(
            "hgpipe_stage_stalls_full_total",
            "counter",
            "Output-FIFO backpressure stalls per resident stage.",
            stage_rows(|s| s.stalls_full.to_string()),
        );
        out
    }
}
