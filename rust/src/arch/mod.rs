//! The parallelism designer (paper Sec. 4.3): choose TP/CIP/COP per
//! module so the pipeline is balanced (every II <= the non-linear
//! bottleneck's II) and BRAM layout is efficient (Sec. 4.3.2), then
//! account resources (MAC units, DSPs, BRAMs, LUTs).

pub mod bram;
pub mod dsp;
pub mod parallelism;

pub use parallelism::{design_network, design_table1, Design, ModuleDesign};
