//! DSP usage accounting — the Fig. 11a ladder (14304 -> 3024 -> 312 in
//! the paper) derived from an explicit unit inventory of the design.
//!
//! Unit model per module design:
//! * MM modules: P MAC units; TP*COP ReQuant lanes on the output side
//!   (except MatMul1, whose ReQuant fuses into the GeLU table).
//! * LayerNorm: P lanes, each holding one Rsqrt unit, one normalize
//!   multiplier and one ReQuant.
//! * Softmax: P lanes, each holding one Exp, one Recip, one probability
//!   multiplier and one ReQuant.
//! * GeLU: P fused GeLU-ReQuant units.
//!
//! Naive per-unit DSP costs are the paper's HLS measurements (Sec. 3):
//! Exp 7, Rsqrt 8, Recip 9, GeLU 26, ReQuant 1.



use crate::lut::cost;
use crate::model::ModuleKind;

use super::parallelism::Design;

/// Inventory of non-linear / auxiliary units in a design.
#[derive(Debug, Clone, Copy, Default)]
pub struct UnitInventory {
    pub mac_units: u64,
    pub exp: u64,
    pub recip: u64,
    pub rsqrt: u64,
    pub gelu: u64,
    pub requant: u64,
    /// True integer multipliers that survive LUT conversion
    /// (LayerNorm c*r normalize, Softmax e*r probability product).
    pub residual_mults: u64,
}

pub fn inventory(design: &Design) -> UnitInventory {
    let mut inv = UnitInventory::default();
    for m in &design.modules {
        match m.spec.kind {
            ModuleKind::StMM | ModuleKind::DyMM => {
                inv.mac_units += m.p;
                // output-side requant lanes; MatMul1's fuses into the GeLU
                // table and QK MatMul feeds Softmax raw accumulators
                let fused_or_raw =
                    m.spec.name.contains("MatMul1") || m.spec.name.contains("QK");
                if !fused_or_raw {
                    inv.requant += m.tp * m.cop;
                }
            }
            ModuleKind::Elementwise => {
                inv.rsqrt += m.p;
                inv.requant += m.p;
                inv.residual_mults += m.p;
            }
            ModuleKind::Softmax => {
                inv.exp += m.p;
                inv.recip += m.p;
                inv.requant += m.p;
                inv.residual_mults += m.p;
            }
            ModuleKind::Gelu => inv.gelu += m.p,
            ModuleKind::Residual => {}
        }
    }
    inv
}

/// One Fig. 11a ladder step.
#[derive(Debug, Clone)]
pub struct LadderStep {
    pub name: &'static str,
    pub dsps: u64,
    /// The paper's reported value at the matching step (DeiT-tiny).
    pub paper_dsps: Option<u64>,
}

/// Naive (pre-optimization) DSP usage of the non-linear units alone.
pub fn naive_nonlinear_dsps(inv: &UnitInventory) -> u64 {
    inv.exp * cost::NAIVE_EXP.dsp
        + inv.recip * cost::NAIVE_RECIP.dsp
        + inv.rsqrt * cost::NAIVE_RSQRT.dsp
        + inv.gelu * cost::NAIVE_GELU.dsp
        + inv.requant * cost::NAIVE_REQUANT.dsp
}

/// The Fig. 11a DSP ladder for a design.
///
/// Step semantics follow the paper:
/// 1. float MACs + float non-linears (MACs packed 2-per-DSP),
/// 2. quantization moves MACs to LUTs; non-linears still DSP,
/// 3. PoT tables eliminate non-linear DSPs; only true multipliers remain.
pub fn dsp_ladder(design: &Design) -> Vec<LadderStep> {
    let inv = inventory(design);
    let nl = naive_nonlinear_dsps(&inv);
    vec![
        LadderStep {
            name: "float (DSP MACs + DSP non-linear)",
            dsps: inv.mac_units / 2 + nl,
            paper_dsps: Some(14_304),
        },
        LadderStep { name: "w/ LUT-based MACs", dsps: nl, paper_dsps: Some(3_024) },
        LadderStep {
            name: "w/ PoT LUT non-linear",
            dsps: inv.residual_mults,
            paper_dsps: Some(312),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::parallelism::design_network;
    use crate::model::{Precision, ViTConfig};

    fn design() -> Design {
        design_network(&ViTConfig::deit_tiny(), Precision::A4W3, 2)
    }

    #[test]
    fn ladder_is_monotone_decreasing() {
        let steps = dsp_ladder(&design());
        assert!(steps[0].dsps > steps[1].dsps);
        assert!(steps[1].dsps > steps[2].dsps);
    }

    #[test]
    fn ladder_matches_paper_magnitudes() {
        // shape check: step1 O(10^4), step2 O(10^3), step3 O(10^2)
        let steps = dsp_ladder(&design());
        assert!((8_000..25_000).contains(&steps[0].dsps), "{}", steps[0].dsps);
        assert!((1_500..6_000).contains(&steps[1].dsps), "{}", steps[1].dsps);
        assert!(steps[2].dsps < 600, "{}", steps[2].dsps);
    }

    #[test]
    fn reduction_ratio_matches_paper_89_percent() {
        // paper: "reduce DSP usage by 89.6%" (3024 -> 312); ours must show
        // a comparable ratio from step 2 to step 3
        let steps = dsp_ladder(&design());
        let ratio = 1.0 - steps[2].dsps as f64 / steps[1].dsps as f64;
        assert!(ratio > 0.85, "ratio {ratio}");
    }

    #[test]
    fn inventory_counts_macs() {
        let inv = inventory(&design());
        assert!(inv.mac_units > 20_000);
        assert!(inv.exp > 0 && inv.rsqrt > 0 && inv.gelu > 0);
    }
}
