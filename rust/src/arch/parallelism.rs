//! Parallelism design (paper Sec. 4.3, Table 1).
//!
//! The paper hand-crafts TP/CIP/COP per module (footnote 1: the design
//! space is small because every transformer layer has the same shape).
//! We provide both:
//!
//! * [`design_table1`] — the paper's hand choices for DeiT-tiny, with all
//!   derived quantities (II, P, MOPs, #BRAM, eta) *computed* from the
//!   formulas, reproducing Table 1 exactly, and
//! * [`design_network`] — an automatic designer (an extension over the
//!   paper): smallest CIP*COP meeting the balance target, tie-broken by
//!   BRAM efficiency then aspect ratio. Used for deit-small / arbitrary
//!   configs.



use super::bram;
use crate::model::{ModuleKind, ModuleSpec, Precision, ViTConfig};

/// A fully-specified module design (one Table 1 row).
#[derive(Debug, Clone)]
pub struct ModuleDesign {
    pub spec: ModuleSpec,
    pub tp: u64,
    pub cip: u64,
    pub cop: u64,
    pub tt: u64,
    pub cit: u64,
    pub cot: u64,
    /// Parallel MAC / elementwise units: TP * CIP * COP.
    pub p: u64,
    /// Initiation interval in cycles: passes * TT * CIT * COT.
    pub ii: u64,
    /// Weight/dynamic-buffer BRAM count (MMs only).
    pub brams: u64,
    /// BRAM utilization efficiency (MMs only).
    pub eta: f64,
}

impl ModuleDesign {
    pub fn new(spec: &ModuleSpec, prec: Precision, tp: u64, cip: u64, cop: u64) -> Self {
        let t = spec.t as u64;
        let ci = spec.ci as u64;
        let co = spec.co as u64;
        let tt = t.div_ceil(tp);
        let cit = ci.div_ceil(cip);
        let (cot, p, ii, brams, eta) = if spec.is_mm() {
            let cot = co.div_ceil(cop);
            let p = tp * cip * cop;
            let ii = spec.passes as u64 * tt * cit * cot;
            // static weights at weight_bits; dynamic (K/V) at act_bits
            let dw = match spec.kind {
                ModuleKind::StMM => prec.weight_bits as u64,
                _ => prec.act_bits as u64,
            };
            let b = bram::bram_count(dw, ci, co, cip, cop);
            let e = bram::bram_efficiency(dw, ci, co, cip, cop);
            (cot, p, ii, b, e)
        } else {
            let p = tp * cip;
            let ii = spec.passes as u64 * tt * cit;
            (0, p, ii, 0, 0.0)
        };
        Self { spec: spec.clone(), tp, cip, cop, tt, cit, cot, p, ii, brams, eta }
    }

    /// MOPs as Table 1 reports them (MACs, in millions).
    pub fn mops(&self) -> f64 {
        self.spec.ops() as f64 / 1e6
    }
}

/// A full-network design.
#[derive(Debug, Clone)]
pub struct Design {
    pub network: String,
    pub precision: Precision,
    /// Balance target: the non-linear bottleneck's II (paper: Softmax).
    pub target_ii: u64,
    pub modules: Vec<ModuleDesign>,
}

impl Design {
    /// Whole-accelerator II = max over stages (Table 1 footnote 3).
    pub fn accelerator_ii(&self) -> u64 {
        self.modules.iter().map(|m| m.ii).max().unwrap_or(0)
    }

    /// Total parallel MAC units over all MM modules.
    pub fn total_macs(&self) -> u64 {
        self.modules.iter().filter(|m| m.spec.is_mm()).map(|m| m.p).sum()
    }

    /// Total weight/dynamic-buffer BRAMs.
    pub fn total_brams(&self) -> u64 {
        self.modules.iter().map(|m| m.brams).sum()
    }

    pub fn find(&self, name: &str) -> Option<&ModuleDesign> {
        self.modules.iter().find(|m| m.spec.name == name)
    }
}

/// Balance target for a network: the Softmax module at minimal P=2
/// (paper Sec. 4.3.3: "we choose the non-linear operators to be the II
/// bottleneck" to save DSPs).
pub fn balance_target(cfg: &ViTConfig, tp: u64) -> u64 {
    let t = cfg.tokens() as u64;
    3 * t.div_ceil(tp) * t
}

fn divisors(n: u64) -> Vec<u64> {
    (1..=n).filter(|d| n % d == 0).collect()
}

/// Automatic designer for one MM module.
///
/// Objective (lexicographic, matching the paper's hand-design priorities
/// in Sec. 4.3.2): feasibility (II <= target), then fewest BRAMs — the
/// paper trades extra MACs for 100%-efficient layouts (Output Proj uses
/// P=144 where P=128 would meet the II target at half the efficiency) —
/// then fewest MAC units, then aspect ratio closest to the ideal.
fn design_mm(spec: &ModuleSpec, prec: Precision, tp: u64, target: u64) -> ModuleDesign {
    let ci = spec.ci as u64;
    let co = spec.co as u64;
    let tt = (spec.t as u64).div_ceil(tp);
    let need = (tt * ci * co).div_ceil(target).max(1); // min CIP*COP product
    let mut best: Option<((u64, u64, u64, u64), u64, u64)> = None;
    for &cip in &divisors(ci) {
        for &cop in &divisors(co) {
            let prod = cip * cop;
            if prod < need {
                continue;
            }
            let d = ModuleDesign::new(spec, prec, tp, cip, cop);
            debug_assert!(d.ii <= target);
            // ideal aspect: cip/cop ~ sqrt(prod * ci/co) per side
            let ideal_cip = ((prod as f64) * ci as f64 / co as f64).sqrt();
            let aspect = (cip as f64 / ideal_cip).ln().abs();
            let key = (d.brams, prod, (aspect * 1e6) as u64, u64::MAX - cip);
            if best.as_ref().map(|(k, _, _)| key < *k).unwrap_or(true) {
                best = Some((key, cip, cop));
            }
        }
    }
    let (_, cip, cop) = best.expect("at least (ci, co) is feasible");
    ModuleDesign::new(spec, prec, tp, cip, cop)
}

/// Automatic designer for an elementwise module: smallest CIP meeting the
/// target.
fn design_elementwise(spec: &ModuleSpec, prec: Precision, tp: u64, target: u64) -> ModuleDesign {
    let ci = spec.ci as u64;
    let tt = (spec.t as u64).div_ceil(tp);
    for &cip in &divisors(ci) {
        let ii = spec.passes as u64 * tt * ci.div_ceil(cip);
        if ii <= target {
            return ModuleDesign::new(spec, prec, tp, cip, 1);
        }
    }
    ModuleDesign::new(spec, prec, tp, ci, 1)
}

/// Design every module of a network automatically.
pub fn design_network(cfg: &ViTConfig, prec: Precision, tp: u64) -> Design {
    let target = balance_target(cfg, tp);
    let modules = cfg
        .modules()
        .iter()
        .map(|spec| {
            if spec.is_mm() {
                design_mm(spec, prec, tp, target)
            } else {
                design_elementwise(spec, prec, tp, target)
            }
        })
        .collect();
    Design { network: cfg.name.clone(), precision: prec, target_ii: target, modules }
}

/// The paper's hand-crafted Table 1 design for DeiT-tiny (one MHA + one
/// MLP block; representative of all 12 layers). All derived columns are
/// computed, not transcribed.
pub fn design_table1() -> Design {
    let cfg = ViTConfig::deit_tiny();
    let prec = Precision::A4W3; // Table 1's DW: 3-bit static, 4-bit dynamic
    let t = cfg.tokens();
    let d = cfg.dim;
    let dh = cfg.head_dim();
    let hid = cfg.hidden();
    let rows: Vec<(ModuleSpec, u64, u64)> = vec![
        (ModuleSpec::elementwise("LayerNorm", t, d, 3), 1, 1),
        (ModuleSpec::st_mm("QKV Gen", t, d, dh, 1), 6, 4),
        (ModuleSpec::dy_mm("QK MatMul", t, dh, t), 4, 7),
        (ModuleSpec::softmax("Softmax", t, t), 1, 1),
        (ModuleSpec::dy_mm("RV MatMul", t, t, dh), 7, 4),
        (ModuleSpec::st_mm("Output Proj", t, d, d, 1), 12, 6),
        (ModuleSpec::residual("Residual Add", t, d), 1, 1),
        (ModuleSpec::elementwise("LayerNorm (MLP)", t, d, 3), 1, 1),
        (ModuleSpec::st_mm("MatMul1", t, d, hid, 1), 12, 24),
        (ModuleSpec::gelu("GeLU", t, hid), 2, 1),
        (ModuleSpec::st_mm("MatMul2", t, hid, d, 1), 24, 12),
    ];
    let modules =
        rows.iter().map(|(spec, cip, cop)| ModuleDesign::new(spec, prec, 2, *cip, *cop)).collect();
    Design {
        network: "deit-tiny (Table 1)".into(),
        precision: prec,
        target_ii: balance_target(&cfg, 2),
        modules,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_iis_match_paper() {
        let d = design_table1();
        let ii = |n: &str| d.find(n).unwrap().ii;
        assert_eq!(ii("LayerNorm"), 56_448);
        assert_eq!(ii("QKV Gen"), 50_176);
        assert_eq!(ii("QK MatMul"), 43_904);
        assert_eq!(ii("Softmax"), 57_624);
        assert_eq!(ii("RV MatMul"), 43_904);
        assert_eq!(ii("Output Proj"), 50_176);
        assert_eq!(ii("Residual Add"), 18_816);
        assert_eq!(ii("MatMul1"), 50_176);
        assert_eq!(ii("GeLU"), 37_632);
        assert_eq!(ii("MatMul2"), 50_176);
    }

    #[test]
    fn table1_parallelism_matches_paper() {
        let d = design_table1();
        let p = |n: &str| d.find(n).unwrap().p;
        assert_eq!(p("LayerNorm"), 2);
        assert_eq!(p("QKV Gen"), 48);
        assert_eq!(p("QK MatMul"), 56);
        assert_eq!(p("Softmax"), 2);
        assert_eq!(p("Output Proj"), 144);
        assert_eq!(p("MatMul1"), 576);
        assert_eq!(p("GeLU"), 4);
        assert_eq!(p("MatMul2"), 576);
    }

    #[test]
    fn table1_bram_efficiency_matches_paper() {
        let d = design_table1();
        let eta = |n: &str| d.find(n).unwrap().eta;
        assert!((eta("QKV Gen") - 1.0).abs() < 1e-9);
        assert!((eta("Output Proj") - 1.0).abs() < 1e-9);
        assert!((eta("MatMul1") - 1.0).abs() < 1e-9);
        assert!((eta("MatMul2") - 1.0).abs() < 1e-9);
        assert!((eta("QK MatMul") - 0.681).abs() < 0.005);
        assert!((eta("RV MatMul") - 0.681).abs() < 0.005);
    }

    #[test]
    fn table1_accelerator_ii_is_softmax() {
        let d = design_table1();
        assert_eq!(d.accelerator_ii(), 57_624); // Fig 12's stable II
        assert_eq!(d.accelerator_ii(), d.target_ii);
    }

    #[test]
    fn table1_mops_match_paper() {
        let d = design_table1();
        let m = |n: &str| d.find(n).unwrap().mops();
        assert!((m("QKV Gen") - 2.41).abs() < 0.01);
        assert!((m("QK MatMul") - 2.46).abs() < 0.01);
        assert!((m("Output Proj") - 7.23).abs() < 0.01);
        assert!((m("MatMul1") - 28.9).abs() < 0.1);
        assert!((m("Residual Add") - 0.038).abs() < 0.002);
    }

    #[test]
    fn auto_designer_meets_balance_target() {
        for cfg in [ViTConfig::deit_tiny(), ViTConfig::deit_small()] {
            let d = design_network(&cfg, Precision::A4W3, 2);
            assert!(d.accelerator_ii() <= d.target_ii, "{}", cfg.name);
            for m in &d.modules {
                assert!(m.ii <= d.target_ii, "{} ii {} > {}", m.spec.name, m.ii, d.target_ii);
            }
        }
    }

    #[test]
    fn auto_designer_eta_at_least_paper_quality() {
        // the auto search must find 100%-efficient layouts for the static
        // MMs of deit-tiny, like the paper's hand design
        let d = design_network(&ViTConfig::deit_tiny(), Precision::A4W3, 2);
        for m in d.modules.iter().filter(|m| m.spec.kind == ModuleKind::StMM) {
            if m.spec.name == "PatchEmbed" || m.spec.name == "Head" {
                continue; // odd shapes; not in Table 1
            }
            assert!(m.eta > 0.999, "{}: eta {}", m.spec.name, m.eta);
        }
    }

    #[test]
    fn total_mac_units_above_20k() {
        // paper Sec. 4.1: "over 20,000 MAC units"
        let d = design_network(&ViTConfig::deit_tiny(), Precision::A4W3, 2);
        let total = d.total_macs();
        assert!(total > 20_000, "{total}");
    }

    #[test]
    fn deit_small_design_is_feasible() {
        let d = design_network(&ViTConfig::deit_small(), Precision::A3W3, 2);
        assert!(d.total_macs() > 20_000);
        assert!(d.total_brams() > 0);
    }
}
