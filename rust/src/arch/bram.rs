//! BRAM layout model — Table 1 footnote 4 / Fig. 9b.
//!
//! A module's weight array (CI x CO at DW bits) is banked for parallel
//! access: each cycle the PE reads a (CIP x COP) slab, so the memory is
//! `#BRAM = ceil(DW*CIP*COP / B_width) * ceil(CIT*COT / B_depth)` BRAMs in
//! the 512x72 SDP geometry, and the utilization efficiency is
//! `eta = DW*CI*CO / (#BRAM * B_width * B_depth)`.

use crate::platform::{BRAM_DEPTH, BRAM_WIDTH};

/// BRAM count for a (CI, CO) weight array tiled as (CIP, COP).
pub fn bram_count(dw: u64, ci: u64, co: u64, cip: u64, cop: u64) -> u64 {
    let cit = ci.div_ceil(cip);
    let cot = co.div_ceil(cop);
    (dw * cip * cop).div_ceil(BRAM_WIDTH) * (cit * cot).div_ceil(BRAM_DEPTH)
}

/// Utilization efficiency eta (1.0 = every stored bit is a weight bit).
pub fn bram_efficiency(dw: u64, ci: u64, co: u64, cip: u64, cop: u64) -> f64 {
    let n = bram_count(dw, ci, co, cip, cop);
    (dw * ci * co) as f64 / (n * BRAM_WIDTH * BRAM_DEPTH) as f64
}

/// Fig. 9b: sweep CIP (at fixed COP) to show layout-induced BRAM waste.
pub fn fig9b_sweep(dw: u64, ci: u64, co: u64, cop: u64) -> Vec<(u64, u64, f64)> {
    let mut rows = Vec::new();
    let mut cip = 1;
    while cip <= ci {
        if ci % cip == 0 {
            let count = bram_count(dw, ci, co, cip, cop);
            rows.push((cip, count, bram_efficiency(dw, ci, co, cip, cop)));
        }
        cip += 1;
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_qkv_gen_is_100_percent() {
        // QKV Gen: DW=3(static), CI=192, CO=64, CIP=6, COP=4 -> 1 BRAM, 100%
        assert_eq!(bram_count(3, 192, 64, 6, 4), 1);
        assert!((bram_efficiency(3, 192, 64, 6, 4) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn table1_output_proj_is_100_percent() {
        // Output Proj: CIP=12, COP=6 -> 3 BRAMs, 100%
        assert_eq!(bram_count(3, 192, 192, 12, 6), 3);
        assert!((bram_efficiency(3, 192, 192, 12, 6) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn table1_matmul1_is_100_percent() {
        assert_eq!(bram_count(3, 192, 768, 12, 24), 12);
        assert!((bram_efficiency(3, 192, 768, 12, 24) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn table1_qk_matmul_is_68_percent() {
        // QK MatMul: dynamic weights are 4-bit activations, CIP=4, COP=7
        let eta = bram_efficiency(4, 64, 196, 4, 7);
        assert!((eta - 0.681).abs() < 0.005, "eta = {eta}");
    }

    #[test]
    fn fig9b_halving_cip_can_halve_brams() {
        // Fig 9b's point: a layout needing 2 BRAMs by width overflow drops
        // to 1 when CIP is halved
        let wide = bram_count(4, 64, 64, 10, 2); // 80 bits wide -> 2 BRAM
        let narrow = bram_count(4, 64, 64, 5, 2); // 40 bits -> 1 BRAM (hmm depth)
        assert!(wide >= 2);
        assert!(narrow < wide);
    }

    #[test]
    fn efficiency_never_exceeds_one() {
        for cip in [1u64, 2, 4, 8, 16] {
            for cop in [1u64, 2, 4, 8] {
                let e = bram_efficiency(4, 128, 128, cip, cop);
                assert!(e <= 1.0 + 1e-9);
            }
        }
    }
}
