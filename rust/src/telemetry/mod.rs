//! Zero-cost-when-off telemetry: per-request trace spans recorded into
//! per-thread ring buffers and drained by a writer thread into
//! Chrome-trace-compatible JSONL (opens directly in Perfetto or
//! `chrome://tracing`).
//!
//! Design contract:
//! - One [`Telemetry`] handle per serving stack (cheap `Arc` clone).
//!   When tracing is off the handle holds `None` and every recording
//!   call is a single branch — no locks, no clock reads, no allocation.
//! - Recording threads own a [`TraceBuf`]: a fixed-capacity ring that
//!   drops the OLDEST events on overflow (counted, surfaced as
//!   `trace_dropped`) and flushes in batches over an mpsc channel to a
//!   dedicated writer thread, so the hot path never touches a lock or
//!   a file descriptor.
//! - Events follow the Chrome trace event format: `X` complete spans
//!   (`ts`/`dur` in microseconds), `M` metadata events naming pids and
//!   tids, `i` instants, and one final `C` counter carrying the drop
//!   total. pid = model, tid = replica / pipeline stage / client — the
//!   HTTP front door ([`crate::server`]) allocates one `http-conn-N`
//!   tid lane per accepted connection and records an `http` span per
//!   request served on it, alongside the router's admission instants.
//! - The file's first line is `[` and every event line ends with a
//!   comma; Chrome's trace importer explicitly tolerates the missing
//!   `]`, and each line stays individually parseable after stripping
//!   the trailing comma (the `util::tracecheck` contract).
//!
//! Handles for the same output path share one writer (a process-global
//! registry keyed on the path), so a `Router` fleet of several
//! `ModelServer`s — or several tests in one process — interleave into
//! a single well-formed trace.

use std::borrow::Cow;
use std::collections::{HashMap, VecDeque};
use std::fmt::Write as _;
use std::io::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock, Weak};
use std::time::{Duration, Instant};

use crate::runtime::RuntimeConfig;

/// Default per-thread ring capacity, in events. A thread that outruns
/// its flushes overwrites its oldest events (counted, never blocking).
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// One Chrome-trace event. `ph`: `X` complete span, `M` metadata,
/// `i` instant, `C` counter.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub ph: char,
    pub name: Cow<'static, str>,
    pub cat: &'static str,
    pub pid: u32,
    pub tid: u64,
    /// Microseconds since the trace epoch.
    pub ts_us: u64,
    /// Span duration in microseconds (`X` events only).
    pub dur_us: u64,
    /// Request id, rendered as `args.id`.
    pub id: Option<u64>,
    /// Batch size (or counter value for `C`), rendered as `args.batch`.
    pub batch: Option<u64>,
    /// Free-form annotation, rendered as `args.note` (`args.name` for
    /// `M` metadata events).
    pub note: Option<String>,
}

impl TraceEvent {
    pub fn span(
        name: impl Into<Cow<'static, str>>,
        cat: &'static str,
        pid: u32,
        tid: u64,
        ts_us: u64,
        dur_us: u64,
    ) -> Self {
        TraceEvent {
            ph: 'X',
            name: name.into(),
            cat,
            pid,
            tid,
            ts_us,
            dur_us,
            id: None,
            batch: None,
            note: None,
        }
    }

    pub fn instant(
        name: impl Into<Cow<'static, str>>,
        cat: &'static str,
        pid: u32,
        tid: u64,
        ts_us: u64,
    ) -> Self {
        TraceEvent { ph: 'i', ..TraceEvent::span(name, cat, pid, tid, ts_us, 0) }
    }

    fn meta(kind: &'static str, pid: u32, tid: u64, label: String) -> Self {
        TraceEvent { ph: 'M', note: Some(label), ..TraceEvent::span(kind, "meta", pid, tid, 0, 0) }
    }

    pub fn with_id(mut self, id: u64) -> Self {
        self.id = Some(id);
        self
    }

    pub fn with_batch(mut self, n: u64) -> Self {
        self.batch = Some(n);
        self
    }

    pub fn with_note(mut self, note: impl Into<String>) -> Self {
        self.note = Some(note.into());
        self
    }
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Render one event as a JSONL line (trailing comma + newline: the
/// Chrome array form whose closing bracket is optional).
fn render(ev: &TraceEvent, out: &mut String) {
    let _ = write!(
        out,
        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"{}\",\"pid\":{},\"tid\":{}",
        esc(&ev.name),
        ev.cat,
        ev.ph,
        ev.pid,
        ev.tid
    );
    if ev.ph != 'M' {
        let _ = write!(out, ",\"ts\":{}", ev.ts_us);
    }
    if ev.ph == 'X' {
        let _ = write!(out, ",\"dur\":{}", ev.dur_us);
    }
    let mut args = String::new();
    match ev.ph {
        'M' => {
            if let Some(n) = &ev.note {
                let _ = write!(args, "\"name\":\"{}\"", esc(n));
            }
        }
        'C' => {
            let _ = write!(args, "\"dropped\":{}", ev.batch.unwrap_or(0));
        }
        _ => {
            if let Some(id) = ev.id {
                let _ = write!(args, "\"id\":{id}");
            }
            if let Some(b) = ev.batch {
                let _ = write!(args, "{}\"batch\":{b}", if args.is_empty() { "" } else { "," });
            }
            if let Some(n) = &ev.note {
                let _ = write!(
                    args,
                    "{}\"note\":\"{}\"",
                    if args.is_empty() { "" } else { "," },
                    esc(n)
                );
            }
        }
    }
    if !args.is_empty() {
        let _ = write!(out, ",\"args\":{{{args}}}");
    }
    out.push_str("},\n");
}

struct Batch {
    events: Vec<TraceEvent>,
    dropped: u64,
}

struct TraceInner {
    id: u64,
    path: String,
    epoch: Instant,
    ring_cap: usize,
    tx: Mutex<Option<mpsc::Sender<Batch>>>,
    writer: Mutex<Option<std::thread::JoinHandle<()>>>,
    closing: Arc<AtomicBool>,
    dropped: Arc<AtomicU64>,
    written: Arc<AtomicU64>,
    next_pid: AtomicU32,
    next_tid: AtomicU64,
}

impl TraceInner {
    fn spawn(path: &str, ring_cap: usize) -> crate::Result<Arc<TraceInner>> {
        static NEXT_ID: AtomicU64 = AtomicU64::new(1);
        let file = std::fs::File::create(path)
            .map_err(|e| anyhow::anyhow!("cannot open trace file {path:?}: {e}"))?;
        let mut w = std::io::BufWriter::new(file);
        writeln!(w, "[").map_err(|e| anyhow::anyhow!("cannot write trace file {path:?}: {e}"))?;
        let (tx, rx) = mpsc::channel::<Batch>();
        let epoch = Instant::now();
        let closing = Arc::new(AtomicBool::new(false));
        let dropped = Arc::new(AtomicU64::new(0));
        let written = Arc::new(AtomicU64::new(0));
        let handle = {
            let (closing, dropped, written) = (closing.clone(), dropped.clone(), written.clone());
            std::thread::Builder::new()
                .name("hgpipe-trace-writer".into())
                .spawn(move || {
                    let mut line = String::new();
                    let mut take = |w: &mut std::io::BufWriter<std::fs::File>, b: Batch| {
                        for ev in &b.events {
                            line.clear();
                            render(ev, &mut line);
                            let _ = w.write_all(line.as_bytes());
                        }
                        written.fetch_add(b.events.len() as u64, Ordering::Relaxed);
                        if b.dropped > 0 {
                            dropped.fetch_add(b.dropped, Ordering::Relaxed);
                        }
                    };
                    loop {
                        match rx.recv_timeout(Duration::from_millis(50)) {
                            Ok(b) => {
                                take(&mut w, b);
                                let _ = w.flush();
                            }
                            Err(mpsc::RecvTimeoutError::Timeout) => {
                                if closing.load(Ordering::Relaxed) {
                                    break;
                                }
                            }
                            Err(mpsc::RecvTimeoutError::Disconnected) => break,
                        }
                    }
                    // drain anything that raced with the close
                    while let Ok(b) = rx.try_recv() {
                        take(&mut w, b);
                    }
                    let d = dropped.load(Ordering::Relaxed);
                    if d > 0 {
                        // droppage is visible in the trace itself
                        let ev = TraceEvent {
                            ph: 'C',
                            batch: Some(d),
                            ..TraceEvent::span(
                                "trace_dropped",
                                "telemetry",
                                0,
                                0,
                                epoch.elapsed().as_micros() as u64,
                                0,
                            )
                        };
                        let mut line = String::new();
                        render(&ev, &mut line);
                        let _ = w.write_all(line.as_bytes());
                    }
                    let _ = w.flush();
                })
                .map_err(|e| anyhow::anyhow!("cannot spawn trace writer: {e}"))?
        };
        Ok(Arc::new(TraceInner {
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            path: path.to_string(),
            epoch,
            ring_cap: ring_cap.max(1),
            tx: Mutex::new(Some(tx)),
            writer: Mutex::new(Some(handle)),
            closing,
            dropped,
            written,
            next_pid: AtomicU32::new(1),
            next_tid: AtomicU64::new(1),
        }))
    }

    fn emit_now(&self, ev: TraceEvent) {
        if let Some(tx) = self.tx.lock().unwrap().as_ref() {
            let _ = tx.send(Batch { events: vec![ev], dropped: 0 });
        }
    }

    fn close(&self) {
        self.closing.store(true, Ordering::SeqCst);
        *self.tx.lock().unwrap() = None;
        if let Some(h) = self.writer.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for TraceInner {
    fn drop(&mut self) {
        self.close();
    }
}

fn registry() -> &'static Mutex<HashMap<String, Weak<TraceInner>>> {
    static REGISTRY: OnceLock<Mutex<HashMap<String, Weak<TraceInner>>>> = OnceLock::new();
    REGISTRY.get_or_init(Default::default)
}

thread_local! {
    static TLS_BUFS: std::cell::RefCell<Vec<(u64, TraceBuf)>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// The telemetry handle. Off by default; every recording entry point
/// is a no-op branch when off.
#[derive(Clone, Default)]
pub struct Telemetry {
    inner: Option<Arc<TraceInner>>,
    pid: u32,
}

impl Telemetry {
    /// The disabled handle: every call is a branch + nothing.
    pub fn off() -> Telemetry {
        Telemetry::default()
    }

    /// Open (or join) the trace sink at `path`. Handles for the same
    /// path share one writer thread and one epoch.
    pub fn to_file(path: &str) -> crate::Result<Telemetry> {
        Telemetry::to_file_with_ring(path, DEFAULT_RING_CAPACITY)
    }

    /// As [`to_file`](Telemetry::to_file) with an explicit per-thread
    /// ring capacity (only honored when this call creates the sink).
    pub fn to_file_with_ring(path: &str, ring_cap: usize) -> crate::Result<Telemetry> {
        let mut reg = registry().lock().unwrap();
        if let Some(inner) = reg.get(path).and_then(Weak::upgrade) {
            if !inner.closing.load(Ordering::Relaxed) {
                return Ok(Telemetry { inner: Some(inner), pid: 0 });
            }
        }
        let inner = TraceInner::spawn(path, ring_cap)?;
        reg.insert(path.to_string(), Arc::downgrade(&inner));
        Ok(Telemetry { inner: Some(inner), pid: 0 })
    }

    /// Resolve tracing from the config: an explicit
    /// `RuntimeConfig::trace` path wins (and an unopenable one is an
    /// error — the caller asked for it); the `HGPIPE_TRACE` env
    /// fallback warns and disables instead, matching the other
    /// `HGPIPE_*` read-only fallbacks.
    pub fn from_config(cfg: &RuntimeConfig) -> crate::Result<Telemetry> {
        if let Some(p) = cfg.trace {
            if p.is_empty() {
                return Ok(Telemetry::off());
            }
            return Telemetry::to_file(p);
        }
        match RuntimeConfig::trace_from_env() {
            Some(p) => match Telemetry::to_file(&p) {
                Ok(t) => Ok(t),
                Err(e) => {
                    eprintln!("warning: HGPIPE_TRACE={p:?} is unusable ({e}); tracing disabled");
                    Ok(Telemetry::off())
                }
            },
            None => Ok(Telemetry::off()),
        }
    }

    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// The output path, when tracing is on.
    pub fn path(&self) -> Option<&str> {
        self.inner.as_deref().map(|i| i.path.as_str())
    }

    pub fn pid(&self) -> u32 {
        self.pid
    }

    /// A handle scoped to one model: allocates a fresh pid and names it
    /// (Chrome `process_name` metadata). Each `for_model` call gets its
    /// own pid, so hot-swapped versions stay distinguishable.
    pub fn for_model(&self, name: &str) -> Telemetry {
        let Some(inner) = &self.inner else { return Telemetry::off() };
        let pid = inner.next_pid.fetch_add(1, Ordering::Relaxed);
        inner.emit_now(TraceEvent::meta("process_name", pid, 0, name.to_string()));
        inner.emit_now(TraceEvent::meta("thread_name", pid, 0, "client".to_string()));
        Telemetry { inner: Some(inner.clone()), pid }
    }

    /// Allocate a named tid (replica or stage lane). Returns 0 when off.
    pub fn alloc_tid(&self, label: &str) -> u64 {
        let Some(inner) = &self.inner else { return 0 };
        let tid = inner.next_tid.fetch_add(1, Ordering::Relaxed);
        inner.emit_now(TraceEvent::meta("thread_name", self.pid, tid, label.to_string()));
        tid
    }

    /// An owned per-thread ring buffer for a long-running loop (replica
    /// executor, pipeline stage). `None` when tracing is off or closed.
    pub fn buffer(&self) -> Option<TraceBuf> {
        let cap = self.inner.as_ref()?.ring_cap;
        self.buffer_with_capacity(cap)
    }

    /// As [`buffer`](Telemetry::buffer) with an explicit ring capacity.
    pub fn buffer_with_capacity(&self, cap: usize) -> Option<TraceBuf> {
        let inner = self.inner.as_ref()?;
        let tx = inner.tx.lock().unwrap().clone()?;
        Some(TraceBuf {
            ring: VecDeque::with_capacity(cap.min(1024)),
            cap: cap.max(1),
            dropped: 0,
            tx,
            epoch: inner.epoch,
            pid: self.pid,
        })
    }

    /// Record through this thread's cached buffer (lazily created, one
    /// per sink per thread, flushed at a watermark and on thread exit).
    /// For call sites that don't own a loop — e.g. request admission.
    pub fn record(&self, f: impl FnOnce(&mut TraceBuf)) {
        let Some(inner) = &self.inner else { return };
        TLS_BUFS.with(|cell| {
            let mut bufs = cell.borrow_mut();
            if let Some((_, b)) = bufs.iter_mut().find(|(id, _)| *id == inner.id) {
                f(b);
                b.maybe_flush(64);
                return;
            }
            if let Some(mut b) = self.buffer() {
                f(&mut b);
                b.maybe_flush(64);
                bufs.push((inner.id, b));
            }
        });
    }

    /// Microseconds since the trace epoch (0 when off).
    pub fn ts_us(&self, t: Instant) -> u64 {
        match &self.inner {
            Some(i) => t.checked_duration_since(i.epoch).unwrap_or_default().as_micros() as u64,
            None => 0,
        }
    }

    pub fn now_us(&self) -> u64 {
        match &self.inner {
            Some(i) => i.epoch.elapsed().as_micros() as u64,
            None => 0,
        }
    }

    /// Total events dropped to ring overflow (as of the last flushes).
    pub fn dropped(&self) -> u64 {
        self.inner.as_ref().map_or(0, |i| i.dropped.load(Ordering::Relaxed))
    }

    /// Total events written to the sink.
    pub fn written(&self) -> u64 {
        self.inner.as_ref().map_or(0, |i| i.written.load(Ordering::Relaxed))
    }

    /// Flush this thread's cached buffer and shut the writer down
    /// (joins it). Buffers still held by other threads keep counting
    /// drops but stop reaching the file. Idempotent.
    pub fn finish(&self) {
        let Some(inner) = &self.inner else { return };
        TLS_BUFS.with(|cell| {
            cell.borrow_mut().retain_mut(|(id, b)| {
                if *id == inner.id {
                    b.flush();
                    false
                } else {
                    true
                }
            })
        });
        inner.close();
    }
}

/// A thread-owned event ring: plain local writes on push, drop-oldest
/// on overflow (counted), batch-flushed to the writer thread.
pub struct TraceBuf {
    ring: VecDeque<TraceEvent>,
    cap: usize,
    dropped: u64,
    tx: mpsc::Sender<Batch>,
    epoch: Instant,
    pid: u32,
}

impl TraceBuf {
    pub fn pid(&self) -> u32 {
        self.pid
    }

    /// Microseconds since the trace epoch.
    pub fn ts(&self, t: Instant) -> u64 {
        t.checked_duration_since(self.epoch).unwrap_or_default().as_micros() as u64
    }

    pub fn now(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    pub fn push(&mut self, ev: TraceEvent) {
        if self.ring.len() == self.cap {
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(ev);
    }

    /// Lay per-op kernel spans back-to-back from `start_us` on `tid`,
    /// clamped to end no later than `end_us` so they always nest inside
    /// the parent span that measured them (µs rounding can otherwise
    /// overhang it). Ops with sub-microsecond totals are elided.
    pub fn push_op_spans(
        &mut self,
        tid: u64,
        start_us: u64,
        end_us: u64,
        ops: &[(&'static str, f64)],
    ) {
        let pid = self.pid;
        let mut t = start_us;
        for &(name, ms) in ops {
            if t >= end_us {
                break;
            }
            let dur = ((ms * 1e3) as u64).min(end_us - t);
            if dur == 0 {
                continue;
            }
            self.push(TraceEvent::span(name, "op", pid, tid, t, dur));
            t += dur;
        }
    }

    pub fn maybe_flush(&mut self, watermark: usize) {
        if self.ring.len() >= watermark {
            self.flush();
        }
    }

    pub fn flush(&mut self) {
        if self.ring.is_empty() && self.dropped == 0 {
            return;
        }
        let b = Batch {
            events: self.ring.drain(..).collect(),
            dropped: std::mem::take(&mut self.dropped),
        };
        let _ = self.tx.send(b);
    }
}

impl Drop for TraceBuf {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{BackendKind, RuntimeConfig};

    fn tmp(name: &str) -> String {
        std::env::temp_dir()
            .join(format!("hgpipe_tele_{}_{name}.jsonl", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn off_handle_is_inert() {
        let t = Telemetry::off();
        assert!(!t.enabled());
        assert!(t.buffer().is_none());
        assert_eq!(t.ts_us(Instant::now()), 0);
        assert_eq!(t.alloc_tid("x"), 0);
        assert!(!t.for_model("m").enabled());
        let mut called = false;
        t.record(|_| called = true);
        assert!(!called, "record must not run the closure when tracing is off");
        t.finish();
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let path = tmp("overflow");
        let t = Telemetry::to_file_with_ring(&path, 4).expect("open trace");
        let tm = t.for_model("m");
        let mut buf = tm.buffer_with_capacity(4).expect("buffer");
        for i in 0..10u64 {
            let ev = TraceEvent::span("ev", "op", buf.pid(), 1, i, 1).with_id(i);
            buf.push(ev);
        }
        buf.flush();
        drop(buf);
        t.finish();
        assert_eq!(t.dropped(), 6);
        let text = std::fs::read_to_string(&path).expect("trace file");
        let survivors: Vec<&str> =
            text.lines().filter(|l| l.contains("\"name\":\"ev\"")).collect();
        assert_eq!(survivors.len(), 4, "ring of 4 keeps the 4 newest events");
        for want in 6..10 {
            assert!(
                text.contains(&format!("\"id\":{want}")),
                "newest event {want} must survive"
            );
        }
        assert!(!text.contains("\"id\":0,") && !text.contains("\"id\":0}"));
        assert!(
            text.contains("\"dropped\":6"),
            "the drop total is a counter event in the trace: {text}"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn same_path_shares_one_sink() {
        let path = tmp("shared");
        let t1 = Telemetry::to_file(&path).expect("open").for_model("a");
        let t2 = Telemetry::to_file(&path).expect("join").for_model("b");
        t1.record(|b| {
            let ev = TraceEvent::span("from_a", "op", b.pid(), 1, 0, 1);
            b.push(ev);
        });
        t2.record(|b| {
            let ev = TraceEvent::span("from_b", "op", b.pid(), 1, 0, 1);
            b.push(ev);
        });
        t1.finish();
        let text = std::fs::read_to_string(&path).expect("trace file");
        assert!(text.contains("from_a") && text.contains("from_b"));
        assert_ne!(t1.pid(), t2.pid(), "each for_model gets its own pid");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn every_line_is_parseable_json() {
        let path = tmp("parse");
        let t = Telemetry::to_file(&path).expect("open").for_model("quoted \"model\"");
        let tid = t.alloc_tid("replica0");
        t.record(|b| {
            let pid = b.pid();
            let ev = TraceEvent::span("exec", "request", pid, tid, 10, 50)
                .with_id(7)
                .with_batch(2)
                .with_note("line\nbreak");
            b.push(ev);
            b.push(TraceEvent::instant("expired", "request", pid, tid, 99).with_id(8));
        });
        t.finish();
        let text = std::fs::read_to_string(&path).expect("trace file");
        let mut events = 0;
        for line in text.lines() {
            let line = line.trim().trim_end_matches(',');
            if line.is_empty() || line == "[" {
                continue;
            }
            let v = crate::util::json::Json::parse(line)
                .unwrap_or_else(|e| panic!("unparseable trace line {line:?}: {e}"));
            assert!(v.get("name").is_some() && v.get("ph").is_some());
            events += 1;
        }
        assert!(events >= 5, "metadata + recorded events expected, got {events}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn explicit_config_path_beats_env() {
        let path = tmp("explicit");
        let leaked: &'static str = Box::leak(path.clone().into_boxed_str());
        let cfg = RuntimeConfig::new(BackendKind::Interpreter).with_trace(Some(leaked));
        let t = Telemetry::from_config(&cfg).expect("explicit trace path opens");
        assert!(t.enabled());
        assert_eq!(t.path(), Some(path.as_str()));
        t.finish();
        // explicit empty string disables even when HGPIPE_TRACE is set
        let off = Telemetry::from_config(
            &RuntimeConfig::new(BackendKind::Interpreter).with_trace(Some("")),
        )
        .expect("empty trace path is off");
        assert!(!off.enabled());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn explicit_unopenable_path_is_an_error() {
        let cfg = RuntimeConfig::new(BackendKind::Interpreter)
            .with_trace(Some("/nonexistent-dir/definitely/not/here.jsonl"));
        assert!(Telemetry::from_config(&cfg).is_err());
    }
}
