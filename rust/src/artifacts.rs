//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime (`artifacts/manifest.json`).

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub path: PathBuf,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub model: String,
    pub precision: String,
}

impl ArtifactInfo {
    pub fn batch(&self) -> usize {
        self.input_shape.first().copied().unwrap_or(1)
    }
}

/// One interpreter-backend model bundle (weights + LUTs as JSON,
/// exported by `python -m compile.export`).
#[derive(Debug, Clone)]
pub struct BundleInfo {
    pub name: String,
    pub path: PathBuf,
    pub model: String,
    pub precision: String,
    /// Per-image token shape `[tokens, patch_dim]`.
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    /// Batch variants the dynamic batcher may dispatch.
    pub batches: Vec<usize>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactInfo>,
    pub bundles: Vec<BundleInfo>,
}

/// Extract a usize array field (`"input": [16, 192]`), empty if absent.
fn usize_arr(info: &Json, key: &str) -> Vec<usize> {
    info.get(key)
        .and_then(|s| s.as_arr())
        .map(|a| a.iter().filter_map(|x| x.as_i64()).map(|x| x as usize).collect())
        .unwrap_or_default()
}

fn str_field(info: &Json, key: &str) -> String {
    info.get(key).and_then(|m| m.as_str()).unwrap_or("?").to_string()
}

impl Manifest {
    /// Search the conventional artifact locations relative to the cwd: a
    /// full `make artifacts` output first, then the committed golden
    /// fixture — from either the workspace root or the rust/ package dir.
    pub fn discover() -> Option<PathBuf> {
        ["artifacts", "rust/artifacts", "artifacts/golden", "rust/artifacts/golden"]
            .iter()
            .map(PathBuf::from)
            .find(|d| d.join("manifest.json").exists())
    }

    pub fn load(dir: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let mut artifacts = Vec::new();
        if let Some(arts) = v.get("artifacts").and_then(|a| a.as_obj()) {
            for (name, info) in arts {
                artifacts.push(ArtifactInfo {
                    name: name.clone(),
                    path: dir.join(info.get("path").and_then(|p| p.as_str()).unwrap_or(name)),
                    input_shape: usize_arr(info, "input"),
                    output_shape: usize_arr(info, "output"),
                    model: str_field(info, "model"),
                    precision: str_field(info, "precision"),
                });
            }
        }
        artifacts.sort_by(|a, b| a.name.cmp(&b.name));
        let mut bundles = Vec::new();
        if let Some(bs) = v.get("bundles").and_then(|b| b.as_obj()) {
            for (name, info) in bs {
                bundles.push(BundleInfo {
                    name: name.clone(),
                    path: dir.join(info.get("path").and_then(|p| p.as_str()).unwrap_or(name)),
                    model: str_field(info, "model"),
                    precision: str_field(info, "precision"),
                    input_shape: usize_arr(info, "input"),
                    num_classes: usize_arr(info, "output").first().copied().unwrap_or(0),
                    batches: usize_arr(info, "batches"),
                });
            }
        }
        bundles.sort_by(|a, b| a.name.cmp(&b.name));
        anyhow::ensure!(
            !artifacts.is_empty() || !bundles.is_empty(),
            "manifest has neither 'artifacts' nor 'bundles'"
        );
        Ok(Self { dir: dir.to_path_buf(), artifacts, bundles })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// The interpreter bundle serving `model`, if any.
    pub fn bundle_for(&self, model: &str) -> Option<&BundleInfo> {
        self.bundles.iter().find(|b| b.model == model)
    }

    /// All batch variants of a model, smallest batch first.
    pub fn variants(&self, model: &str) -> Vec<&ArtifactInfo> {
        let mut v: Vec<_> = self
            .artifacts
            .iter()
            .filter(|a| a.model == model && !a.name.contains("block"))
            .collect();
        v.sort_by_key(|a| a.batch());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": {
                "m_b1": {"path": "m1.hlo.txt", "input": [1, 4, 8], "output": [1, 2], "model": "m", "precision": "a4w4"},
                "m_b8": {"path": "m8.hlo.txt", "input": [8, 4, 8], "output": [8, 2], "model": "m", "precision": "a4w4"}
            }, "models": {}}"#,
        )
        .unwrap();
    }

    #[test]
    fn bundles_only_manifest_loads() {
        let dir = std::env::temp_dir().join("hgpipe_manifest_bundle_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"bundles": {"tv": {"path": "tv.json", "model": "tiny-synth",
                "precision": "a4w4", "input": [16, 192], "output": [10],
                "batches": [1, 8]}}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifacts.is_empty());
        let b = m.bundle_for("tiny-synth").unwrap();
        assert_eq!(b.input_shape, vec![16, 192]);
        assert_eq!(b.num_classes, 10);
        assert_eq!(b.batches, vec![1, 8]);
        assert!(m.bundle_for("no-such").is_none());
    }

    #[test]
    fn empty_manifest_rejected() {
        let dir = std::env::temp_dir().join("hgpipe_manifest_empty_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"models": {}}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn loads_and_finds() {
        let dir = std::env::temp_dir().join("hgpipe_manifest_test");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.find("m_b1").unwrap().batch(), 1);
        let v = m.variants("m");
        assert_eq!(v.len(), 2);
        assert!(v[0].batch() < v[1].batch());
    }
}
