//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime (`artifacts/manifest.json`).

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub path: PathBuf,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub model: String,
    pub precision: String,
}

impl ArtifactInfo {
    pub fn batch(&self) -> usize {
        self.input_shape.first().copied().unwrap_or(1)
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let arts = v
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'artifacts'"))?;
        let mut artifacts = Vec::new();
        for (name, info) in arts {
            let shape = |key: &str| -> Vec<usize> {
                info.get(key)
                    .and_then(|s| s.as_arr())
                    .map(|a| a.iter().filter_map(|x| x.as_i64()).map(|x| x as usize).collect())
                    .unwrap_or_default()
            };
            artifacts.push(ArtifactInfo {
                name: name.clone(),
                path: dir.join(info.get("path").and_then(|p| p.as_str()).unwrap_or(name)),
                input_shape: shape("input"),
                output_shape: shape("output"),
                model: info.get("model").and_then(|m| m.as_str()).unwrap_or("?").to_string(),
                precision: info.get("precision").and_then(|m| m.as_str()).unwrap_or("?").to_string(),
            });
        }
        artifacts.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(Self { dir: dir.to_path_buf(), artifacts })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All batch variants of a model, smallest batch first.
    pub fn variants(&self, model: &str) -> Vec<&ArtifactInfo> {
        let mut v: Vec<_> = self
            .artifacts
            .iter()
            .filter(|a| a.model == model && !a.name.contains("block"))
            .collect();
        v.sort_by_key(|a| a.batch());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": {
                "m_b1": {"path": "m1.hlo.txt", "input": [1, 4, 8], "output": [1, 2], "model": "m", "precision": "a4w4"},
                "m_b8": {"path": "m8.hlo.txt", "input": [8, 4, 8], "output": [8, 2], "model": "m", "precision": "a4w4"}
            }, "models": {}}"#,
        )
        .unwrap();
    }

    #[test]
    fn loads_and_finds() {
        let dir = std::env::temp_dir().join("hgpipe_manifest_test");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.find("m_b1").unwrap().batch(), 1);
        let v = m.variants("m");
        assert_eq!(v.len(), 2);
        assert!(v[0].batch() < v[1].batch());
    }
}
