//! Roofline model for FPGA-based ViT acceleration — Figure 1.
//!
//! Four design points on the VCK190 / DeiT-tiny roofline:
//!   * temporal GeMM (DSP MACs, bandwidth-starved)        paper: 1.1 TOP/s
//!   * coarse-grained pipeline (DSP-roof-limited)         paper: 3.2 TOP/s
//!   * LUT-MAC GeMM (higher compute roof, bandwidth wall) paper: 7.8 TOP/s
//!   * HG-PIPE (weights on chip, breaks both walls)       paper: 17.8 TOP/s

use crate::arch::parallelism::Design;
use crate::lut::cost::lut_mac_cost;
use crate::model::ViTConfig;
use crate::paradigms::{offchip_traffic_bytes, ParadigmKind};
use crate::platform::Fpga;

/// One point on the roofline plot.
#[derive(Debug, Clone)]
pub struct RooflinePoint {
    pub label: &'static str,
    /// Arithmetic intensity, ops per DRAM byte.
    pub intensity: f64,
    /// Compute roof (ops/s) for this design style.
    pub compute_roof: f64,
    /// Achievable throughput = min(roof, intensity * bandwidth), ops/s.
    pub achievable: f64,
    /// The paper's reported value for this point (TOP/s), for comparison.
    pub paper_tops: f64,
}

/// Fraction of the LUT budget spendable on MAC units (the rest is
/// control, interconnect, non-linear PEs) — calibrated against the
/// paper's 669k-LUT VCK190 deployment carrying ~25k MACs at 11 LUTs each.
pub const MAC_LUT_BUDGET_FRAC: f64 = 0.45;

/// Build the Fig. 1 roofline for a design on a platform.
///
/// Traffic assumptions per point follow the paper's framing:
/// * "GeMM": a conventional temporal A8W8 engine with tiled re-reads —
///   deeply bandwidth-starved (paper: 1.1 TOP/s);
/// * "coarse-grained pipeline": activations on chip, DSP-roof-bound
///   (paper: 3.2);
/// * "GeMM + LUT MACs": low-bit, perfectly-fused streaming (each tensor
///   once) — the raised compute roof re-exposes the bandwidth wall
///   (paper: 7.8);
/// * HG-PIPE: weights frozen on chip; only image I/O crosses DRAM
///   (paper: 17.8).
pub fn fig1(design: &Design, cfg: &ViTConfig, fpga: &Fpga) -> Vec<RooflinePoint> {
    use crate::arch::parallelism::design_network;
    use crate::model::Precision;
    use crate::paradigms::temporal_traffic_once;

    let ops = cfg.ops_per_inference() as f64;
    let bw = fpga.dram_bw;
    let dsp_roof = 2.0 * fpga.dsp_peak_macs(); // 2 ops per MAC
    let lut_roof =
        2.0 * fpga.lut_peak_macs(lut_mac_cost(design.precision.act_bits), MAC_LUT_BUDGET_FRAC);
    let design8 = design_network(cfg, Precision::A8W8, 2);

    let mk = |label, traffic: f64, roof: f64, paper| RooflinePoint {
        label,
        intensity: ops / traffic,
        compute_roof: roof,
        achievable: roof.min(ops / traffic * bw),
        paper_tops: paper,
    };

    vec![
        mk(
            "GeMM (temporal, DSP)",
            offchip_traffic_bytes(&design8, cfg, ParadigmKind::Temporal) as f64,
            dsp_roof,
            1.1,
        ),
        mk(
            "Coarse-grained pipeline (DSP)",
            offchip_traffic_bytes(design, cfg, ParadigmKind::CoarseGrained) as f64,
            dsp_roof,
            3.2,
        ),
        mk("GeMM + LUT MACs", temporal_traffic_once(design, cfg) as f64, lut_roof, 7.8),
        mk(
            "HG-PIPE (hybrid, LUT)",
            offchip_traffic_bytes(design, cfg, ParadigmKind::HybridGrained) as f64,
            lut_roof,
            17.8,
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::parallelism::design_network;
    use crate::model::Precision;

    fn points() -> Vec<RooflinePoint> {
        let cfg = ViTConfig::deit_tiny();
        let d = design_network(&cfg, Precision::A4W4, 2);
        fig1(&d, &cfg, &Fpga::vck190())
    }

    #[test]
    fn ordering_matches_paper() {
        let p = points();
        assert!(p[0].achievable < p[1].achievable, "GeMM < coarse");
        assert!(p[1].achievable < p[2].achievable, "coarse < LUT GeMM");
        assert!(p[2].achievable < p[3].achievable, "LUT GeMM < HG-PIPE");
    }

    #[test]
    fn magnitudes_within_2x_of_paper() {
        for p in points() {
            let ours_tops = p.achievable / 1e12;
            let ratio = ours_tops / p.paper_tops;
            assert!(
                (0.5..2.5).contains(&ratio),
                "{}: ours {ours_tops:.2} TOP/s vs paper {} (ratio {ratio:.2})",
                p.label,
                p.paper_tops
            );
        }
    }

    #[test]
    fn bandwidth_binds_temporal_but_not_hybrid() {
        let p = points();
        assert!(p[0].achievable < p[0].compute_roof, "temporal is BW-bound");
        assert!(
            (p[3].achievable - p[3].compute_roof).abs() < 1e-6,
            "hybrid reaches its compute roof"
        );
    }

    #[test]
    fn coarse_pipeline_hits_dsp_roof() {
        let p = points();
        // paper: 3.2 TOP/s from the DSP limit; our DSP roof model:
        // 2 ops x 2 MACs/DSP x 1968 DSPs x 425 MHz = 3.34 TOP/s
        assert!((p[1].compute_roof / 1e12 - 3.34).abs() < 0.1);
        assert_eq!(p[1].achievable, p[1].compute_roof);
    }
}
