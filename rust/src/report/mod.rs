//! Report renderers: regenerate every table and figure of the paper's
//! evaluation section as text (the `hgpipe report <id>` subcommand and
//! the benches call these).

use crate::arch::dsp::dsp_ladder;
use crate::arch::parallelism::{design_network, design_table1};
use crate::lut::cost::fig11c;
use crate::lut::generate;
use crate::model::{Precision, ViTConfig};
use crate::paradigms::{self, ParadigmKind};
use crate::platform::Fpga;
use crate::roofline;
use crate::sim::{self, builder::Paradigm, SimConfig};
use crate::util::ascii_table;
use crate::util::json::Json;

/// All report ids, in paper order.
pub const ALL: &[&str] = &[
    "fig1", "fig2c", "tab1", "fig9a", "fig9b", "fig10a", "fig10b", "fig10c", "fig10d",
    "fig11a", "fig11b", "fig11c", "fig12", "tab2",
];

/// Render a report by id (None = unknown id).
pub fn render(id: &str, artifacts_dir: &std::path::Path) -> Option<String> {
    Some(match id {
        "fig1" => fig1(),
        "fig2c" => fig2c(),
        "tab1" => tab1(),
        "fig9a" => fig9a(),
        "fig9b" => fig9b(),
        "fig10a" => fig10a(),
        "fig10b" => fig10b(),
        "fig10c" => fig10c(),
        "fig10d" => fig10d(),
        "fig11a" => fig11a(),
        "fig11b" => fig11b(artifacts_dir),
        "fig11c" => fig11c_report(),
        "fig12" => fig12(),
        "tab2" => tab2(),
        _ => return None,
    })
}

fn deit_design() -> (crate::arch::parallelism::Design, ViTConfig) {
    let cfg = ViTConfig::deit_tiny();
    let d = design_network(&cfg, Precision::A4W4, 2);
    (d, cfg)
}

// ---------------------------------------------------------------------------

pub fn fig1() -> String {
    let (d, cfg) = deit_design();
    let points = roofline::fig1(&d, &cfg, &Fpga::vck190());
    let rows = points
        .iter()
        .map(|p| {
            vec![
                p.label.to_string(),
                format!("{:.1}", p.intensity),
                format!("{:.2}", p.compute_roof / 1e12),
                format!("{:.2}", p.achievable / 1e12),
                format!("{:.1}", p.paper_tops),
            ]
        })
        .collect::<Vec<_>>();
    format!(
        "Figure 1 — Roofline model, VCK190 / DeiT-tiny\n{}",
        ascii_table(
            &["design point", "ops/byte", "roof TOP/s", "achievable TOP/s", "paper TOP/s"],
            &rows
        )
    )
}

pub fn fig2c() -> String {
    let (d, cfg) = deit_design();
    let sim_cfg = SimConfig::matched(&d, &cfg);
    let mut rows = Vec::new();
    for (kind, sim_par) in [
        (ParadigmKind::Temporal, None),
        (ParadigmKind::CoarseGrained, Some(Paradigm::CoarseGrained)),
        (ParadigmKind::FineGrained, Some(Paradigm::FineGrained)),
        (ParadigmKind::HybridGrained, Some(Paradigm::Hybrid)),
    ] {
        let bufs = paradigms::activation_buffer_brams(&d, &cfg, kind);
        let traffic = paradigms::offchip_traffic_bytes(&d, &cfg, kind) as f64 / 1e6;
        let (compat, latency, ii) = match sim_par {
            None => ("yes (low util)".to_string(), "high".into(), "-".into()),
            Some(p) => {
                let r = sim::run_fast(&sim::build_vit(&d, &cfg, p, sim_cfg), 3, 20_000_000);
                match r.stop {
                    sim::StopReason::Completed => (
                        "yes".to_string(),
                        format!("{}", r.first_image_latency().unwrap()),
                        format!("{}", r.stable_ii().unwrap()),
                    ),
                    sim::StopReason::Deadlock { cycle, .. } => {
                        (format!("NO (deadlock @{cycle})"), "-".into(), "-".into())
                    }
                    sim::StopReason::Budget => ("timeout".into(), "-".into(), "-".into()),
                }
            }
        };
        rows.push(vec![
            kind.label().to_string(),
            format!("{bufs}"),
            format!("{traffic:.2}"),
            compat,
            latency,
            ii,
        ]);
    }
    format!(
        "Figure 2c — paradigm comparison (simulated, DeiT-tiny)\n{}",
        ascii_table(
            &[
                "paradigm",
                "act-buffer BRAMs",
                "DRAM MB/inf",
                "ViT compat",
                "latency (cyc)",
                "stable II",
            ],
            &rows
        )
    )
}

pub fn tab1() -> String {
    let d = design_table1();
    let rows = d
        .modules
        .iter()
        .map(|m| {
            vec![
                m.spec.name.clone(),
                format!("{}/{}={}", m.spec.t, m.tp, m.tt),
                format!("{}/{}={}", m.spec.ci, m.cip, m.cit),
                if m.spec.is_mm() {
                    format!("{}/{}={}", m.spec.co, m.cop, m.cot)
                } else {
                    "-".into()
                },
                format!("{:.2}", m.mops()),
                format!("{}", m.p),
                format!("{}", m.ii),
                if m.spec.is_mm() { format!("{:.1}%", m.eta * 100.0) } else { "-".into() },
            ]
        })
        .collect::<Vec<_>>();
    format!(
        "Table 1 — parallelism design on DeiT-tiny (computed; paper hand-crafted)\n{}accelerator II = {} (paper: 57624)\n",
        ascii_table(
            &["module", "T/TP=TT", "CI/CIP=CIT", "CO/COP=COT", "MOPs", "P", "II", "eta"],
            &rows
        ),
        d.accelerator_ii()
    )
}

pub fn fig9a() -> String {
    // two-stage toy pipeline: unbalanced vs balanced
    use crate::sim::engine::{run, Pipeline};
    use crate::sim::channel::ChannelKind;
    use crate::sim::stage::StageSpec;
    let build = |cost_a: u64, cost_b: u64| -> Pipeline {
        let mut p = Pipeline::default();
        let c0 = p.add_channel("s->a", ChannelKind::Fifo { cap: 4 });
        let c1 = p.add_channel("a->b", ChannelKind::Fifo { cap: 4 });
        p.add_stage(StageSpec {
            name: "src".into(),
            block: "s".into(),
            cost: 2,
            firings_per_image: 8,
            inputs: vec![],
            outputs: vec![c0],
            is_source: true,
        });
        p.add_stage(StageSpec {
            name: "Matmul1".into(),
            block: "m1".into(),
            cost: cost_a,
            firings_per_image: 8,
            inputs: vec![c0],
            outputs: vec![c1],
            is_source: false,
        });
        let sink = p.add_stage(StageSpec {
            name: "Matmul2".into(),
            block: "m2".into(),
            cost: cost_b,
            firings_per_image: 8,
            inputs: vec![c1],
            outputs: vec![],
            is_source: false,
        });
        p.sink = sink;
        p
    };
    let unbal = run(&build(6, 2), 6, 1_000_000);
    let bal = run(&build(2, 2), 6, 1_000_000);
    format!(
        "Figure 9a — imbalance-induced bubbles\n\
         unbalanced (II 48 vs 16): stable II {}  Matmul2 utilization {:.0}%\n\
         balanced   (II 16 vs 16): stable II {}  Matmul2 utilization {:.0}%\n\
         allocating more parallelism to Matmul1 removes the bubbles.\n",
        unbal.stable_ii().unwrap(),
        unbal.utilization(2) * 100.0,
        bal.stable_ii().unwrap(),
        bal.utilization(2) * 100.0,
    )
}

pub fn fig9b() -> String {
    use crate::arch::bram;
    let rows = bram::fig9b_sweep(4, 64, 64, 2)
        .into_iter()
        .map(|(cip, n, eta)| vec![format!("{cip}"), format!("{n}"), format!("{:.0}%", eta * 100.0)])
        .collect::<Vec<_>>();
    format!(
        "Figure 9b — BRAM layout vs CIP (DW=4, CI=CO=64, COP=2)\n{}",
        ascii_table(&["CIP", "#BRAM", "eta"], &rows)
    )
}

pub fn fig10a() -> String {
    let t = generate::requant_table(
        "demo",
        -1000,
        1000,
        0.01,
        crate::lut::OutQuant::symmetric(0.125, 4),
    );
    format!(
        "Figure 10a — PoT index approximation\n\
         range [-1000, 1000], 64 entries: exact scale = {:.4}, PoT shift = {} (/{}), \n\
         boundary maps to index {} (<= 63 by the ceiling rule; no overflow)\n",
        2000.0 / 63.0,
        t.shift,
        1u64 << t.shift,
        (2000i64) >> t.shift,
    )
}

pub fn fig10b() -> String {
    let out = crate::lut::OutQuant::symmetric(0.125, 4);
    let t = generate::gelu_requant_table("gelu", -800, 800, 0.0078125, out);
    let mut curve = String::new();
    for i in (0..64).step_by(8) {
        curve.push_str(&format!("  idx {i:2}: entry {:+}\n", t.entries[i]));
    }
    format!(
        "Figure 10b — fused GeLU-ReQuant transfer curve (64 entries, 4-bit out)\n{curve}\
         (left end saturates at gelu~0, right end tracks identity)\n"
    )
}

pub fn fig10c() -> String {
    let out = crate::lut::OutQuant::symmetric(0.125, 4);
    let raw = generate::requant_table("rq", -100_000, 100_000, 0.001, out);
    let cal = generate::joint_calibrate("rq", |x| x, -100_000, 100_000, 0.001, 6, out);
    let sat = |e: &Vec<i64>| -> usize {
        e.iter().filter(|&&v| v == e[0]).count()
            + e.iter().filter(|&&v| v == *e.last().unwrap()).count()
    };
    format!(
        "Figure 10c — joint table range calibration\n\
         before: range [-100000, 100000], shift {}, saturated entries {}\n\
         after : range [{}, ~{}], shift {}, saturated entries {}\n",
        raw.shift,
        sat(&raw.entries),
        cal.alpha,
        cal.alpha + (64i64 << cal.shift),
        cal.shift,
        sat(&cal.entries),
    )
}

pub fn fig10d() -> String {
    let (a, b, s) = (200i64, 40_000i64, 1.0 / 255.0);
    let seg = generate::recip_table_segmented("r", a, b, s);
    let flat = generate::recip_table_flat("r", a, b, s);
    let xs: Vec<i64> = (0..20_000)
        .map(|i| {
            let u = (i as f64 + 0.5) / 20_000.0;
            ((a as f64) * (1.0 / u).powf(1.4)).min(b as f64) as i64
        })
        .collect();
    let f = |x: f64| 1.0 / x;
    let m_seg = seg.mse(&xs, f, s);
    let m_flat = flat.mse(&xs, f, s);
    format!(
        "Figure 10d — segmented Recip table (pivot at first 1/8 = {})\n\
         flat 128-entry table MSE      : {m_flat:.6}\n\
         segmented 64x2 table MSE      : {m_seg:.6}\n\
         improvement                   : {:.1}x   (paper: 0.032 -> 0.0034, 9.4x)\n",
        seg.pivot,
        m_flat / m_seg,
    )
}

pub fn fig11a() -> String {
    let (d, _) = deit_design();
    let rows = dsp_ladder(&d)
        .into_iter()
        .map(|s| {
            vec![
                s.name.to_string(),
                format!("{}", s.dsps),
                s.paper_dsps.map(|p| p.to_string()).unwrap_or_default(),
            ]
        })
        .collect::<Vec<_>>();
    format!(
        "Figure 11a — DSP usage ladder (DeiT-tiny; accuracy trajectory in accuracy_ladder.json)\n{}",
        ascii_table(&["step", "DSPs (ours)", "DSPs (paper)"], &rows)
    )
}

pub fn fig11b(artifacts_dir: &std::path::Path) -> String {
    let path = artifacts_dir.join("accuracy_ladder.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        return format!("Figure 11b — run `make artifacts` first ({} missing)\n", path.display());
    };
    let Ok(v) = Json::parse(&text) else {
        return "Figure 11b — could not parse accuracy_ladder.json\n".into();
    };
    let mut out = String::from(
        "Figure 11b — LUT ablations on the tiny-ViT synthetic task\n\
         (paper evaluates DeiT-tiny on ImageNet with QAT; we substitute a\n\
          trained tiny-ViT on a procedural 10-class set — shapes, not levels)\n",
    );
    for prec in ["a4w4", "a3w3"] {
        let Some(p) = v.get(prec) else { continue };
        out.push_str(&format!("\n[{prec}]\n"));
        if let Some(full) =
            p.get("ladder").and_then(|l| l.get("+segmented_recip")).and_then(|x| x.as_f64())
        {
            out.push_str(&format!("  full pipeline accuracy: {:.3}\n", full));
            if let Some(abl) = p.get("ablation").and_then(|a| a.as_obj()) {
                for (name, acc) in abl {
                    let a = acc.as_f64().unwrap_or(f64::NAN);
                    out.push_str(&format!("  {name:<22} {a:.3}  ({:+.3})\n", a - full));
                }
            }
        }
    }
    out
}

pub fn fig11c_report() -> String {
    let rows = fig11c()
        .into_iter()
        .map(|r| {
            vec![
                r.function.to_string(),
                format!("{}", r.table_depth),
                format!("{}", r.table_bits),
                format!("{} -> {}", r.naive.lut6, r.table.lut6),
                format!("{} (paper)", r.paper_table_lut6),
                format!("{} -> {}", r.naive.dsp, r.table.dsp),
            ]
        })
        .collect::<Vec<_>>();
    format!(
        "Figure 11c — non-linear function resource reduction\n{}",
        ascii_table(
            &[
                "function",
                "depth",
                "bits",
                "LUT-6 naive->table",
                "table (paper)",
                "DSP naive->table",
            ],
            &rows
        )
    )
}

pub fn fig12() -> String {
    let cfg = ViTConfig::deit_tiny();
    let d = design_network(&cfg, Precision::A4W3, 2);
    let sim_cfg = SimConfig::matched(&d, &cfg);
    let r = sim::run_fast(&sim::build_vit(&d, &cfg, Paradigm::Hybrid, sim_cfg), 3, 5_000_000);
    let gantt = sim::trace::render_gantt(&r, 100);
    let s = sim::trace::summarize(&r, 425e6).expect("sim must complete");
    format!(
        "Figure 12 — timing diagram (cycle-accurate simulation, 3 images)\n{gantt}\n\
         stable II            : {} cycles   (paper: 57,624)\n\
         Image1 total         : {} cycles   (paper: 824,843)\n\
         latency              : {:.3} ms     (paper: 0.136 ms)\n\
         ideal frame rate     : {:.0} img/s  (paper: 7,353)\n",
        s.stable_ii, s.first_image_cycles, s.latency_ms, s.ideal_fps
    )
}

pub fn tab2() -> String {
    let rows = crate::metrics::table2()
        .into_iter()
        .map(|r| {
            vec![
                r.name.clone(),
                r.platform.clone(),
                format!("{:.0}", r.freq_mhz),
                r.network.clone(),
                r.precision.clone(),
                format!("{:.0}", r.fps),
                format!("{:.0}", r.gops),
                if r.luts_k.is_nan() { "-".into() } else { format!("{:.1}", r.luts_k) },
                format!("{}", r.dsps),
                if r.brams.is_nan() { "-".into() } else { format!("{:.0}", r.brams) },
                format!("{:.1}", r.power_w),
                if r.luts_k.is_nan() { "-".into() } else { format!("{:.2}", r.gops_per_klut()) },
                format!("{:.1}", r.gops_per_w()),
            ]
        })
        .collect::<Vec<_>>();
    format!(
        "Table 2 — comparison with prior art (ours computed, prior art as reported)\n{}",
        ascii_table(
            &[
                "accelerator",
                "device",
                "MHz",
                "network",
                "prec",
                "FPS",
                "GOPs",
                "kLUT",
                "DSP",
                "BRAM",
                "W",
                "GOPs/kLUT",
                "GOPs/W",
            ],
            &rows
        )
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_reports_render() {
        let dir = std::path::Path::new("artifacts");
        for id in ALL {
            let r = render(id, dir);
            assert!(r.is_some(), "{id} missing");
            assert!(!r.unwrap().is_empty(), "{id} empty");
        }
    }

    #[test]
    fn unknown_report_is_none() {
        assert!(render("fig99", std::path::Path::new(".")).is_none());
    }

    #[test]
    fn fig12_reproduces_stable_ii() {
        let text = fig12();
        assert!(text.contains("stable II            : 57624"), "{text}");
    }
}
