//! End-to-end smoke for the network front door (`make http-smoke`).
//!
//! Unlike `tests/http_edge.rs` (which binds `HttpServer` in-process),
//! this harness exercises the *binary*: it spawns the sibling `hgpipe`
//! executable with `serve --http 127.0.0.1:0` on the committed golden
//! fixture, parses the bound port off the child's stdout, and then
//! talks to it over real sockets:
//!
//! 1. POSTs every golden image (binary bodies, plus one JSON body) and
//!    asserts the replies are bit-exact against `golden_logits.bin`,
//! 2. scrapes `/metrics` and line-parses the whole exposition against
//!    the pinned Prometheus families (exact request count included),
//! 3. checks `/healthz` reports a healthy fleet,
//! 4. restarts the server with `--queue-cap 1` + a stall fault and
//!    fires concurrent posts to force at least one `429`, verifying the
//!    shed is attributed to `source="http"` in the scrape.
//!
//! Exits non-zero on the first violation; prints `http-smoke OK` on
//! success. The child is killed on drop, so a panicking assertion never
//! leaks a listener.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::Duration;

use hgpipe::util::json::Json;

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join("golden")
}

/// The serving binary, resolved next to this harness (both live in
/// `target/<profile>/`; `make http-smoke` builds `hgpipe` first).
fn hgpipe_bin() -> PathBuf {
    let mut p = std::env::current_exe().expect("own path");
    p.set_file_name("hgpipe");
    assert!(p.exists(), "{} not built — run via `make http-smoke`", p.display());
    p
}

/// Golden images and their expected (argmax, f32 logits), sized off the
/// manifest's eval_set shape — no model load needed on the harness side.
fn golden() -> (Vec<Vec<f32>>, Vec<(usize, Vec<f32>)>) {
    let dir = fixture_dir();
    let manifest = std::fs::read_to_string(dir.join("manifest.json")).expect("golden manifest");
    let v = Json::parse(&manifest).expect("manifest parses");
    let shape: Vec<usize> = v
        .get("eval_set")
        .and_then(|e| e.get("shape"))
        .and_then(Json::as_arr)
        .expect("eval_set.shape")
        .iter()
        .map(|x| x.as_i64().unwrap() as usize)
        .collect();
    let (n, per) = (shape[0], shape[1] * shape[2]);
    let tokens: Vec<f32> = std::fs::read(dir.join("golden_tokens.bin"))
        .unwrap()
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let logits: Vec<f64> = std::fs::read(dir.join("golden_logits.bin"))
        .unwrap()
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect();
    assert_eq!(tokens.len(), n * per, "golden token size vs eval_set shape");
    let nc = logits.len() / n;
    let images: Vec<Vec<f32>> = tokens.chunks_exact(per).map(<[f32]>::to_vec).collect();
    let expected = logits
        .chunks_exact(nc)
        .map(|row| {
            let row: Vec<f32> = row.iter().map(|&v| v as f32).collect();
            // same reduction as the coordinator: total_cmp, last max wins
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(j, _)| j)
                .unwrap_or(0);
            (argmax, row)
        })
        .collect();
    (images, expected)
}

/// A spawned `hgpipe serve --http` child, killed on drop.
struct Server {
    child: Child,
    addr: String,
}

impl Server {
    fn start(extra_flags: &[&str]) -> Server {
        let mut cmd = Command::new(hgpipe_bin());
        cmd.arg("serve")
            .arg("--http")
            .arg("127.0.0.1:0")
            .arg("--artifacts")
            .arg(fixture_dir())
            .arg("--lanes")
            .arg("2")
            .args(extra_flags)
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit());
        let mut child = cmd.spawn().expect("spawn hgpipe serve --http");
        let stdout = child.stdout.take().expect("piped stdout");
        let mut lines = BufReader::new(stdout);
        let addr = loop {
            let mut line = String::new();
            let n = lines.read_line(&mut line).expect("child stdout");
            assert!(n > 0, "server exited before announcing its listen address");
            print!("  [server] {line}");
            if let Some(rest) = line.split("listening on http://").nth(1) {
                break rest.split_whitespace().next().expect("addr token").to_string();
            }
        };
        // keep draining so the child never blocks on a full pipe
        std::thread::spawn(move || {
            let mut sink = String::new();
            while matches!(lines.read_line(&mut sink), Ok(n) if n > 0) {
                sink.clear();
            }
        });
        Server { child, addr }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

// ---------------- tiny blocking HTTP/1.1 client ----------------

struct Reply {
    status: u16,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl Reply {
    fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    fn text(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

fn read_reply(stream: &mut TcpStream) -> Reply {
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = Vec::new();
    let head_end = loop {
        if let Some(p) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break p;
        }
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk).expect("response head");
        assert!(n > 0, "connection closed before a full response head");
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8(buf[..head_end].to_vec()).unwrap();
    let mut lines = head.split("\r\n");
    let status: u16 =
        lines.next().unwrap().split(' ').nth(1).expect("status code").parse().unwrap();
    let headers: Vec<(String, String)> = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
        .collect();
    let len: usize = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| v.parse().unwrap())
        .unwrap_or(0);
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < len {
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk).expect("response body");
        assert!(n > 0, "connection closed mid-body");
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(len);
    Reply { status, headers, body }
}

fn request(addr: &str, method: &str, path: &str, hs: &[(&str, &str)], body: &[u8]) -> Reply {
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut head = format!("{method} {path} HTTP/1.1\r\nHost: t\r\n");
    for (k, v) in hs {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str(&format!("Content-Length: {}\r\n\r\n", body.len()));
    stream.write_all(head.as_bytes()).unwrap();
    stream.write_all(body).unwrap();
    read_reply(&mut stream)
}

fn infer_path() -> &'static str {
    "/v1/models/tiny-synth/infer"
}

fn image_bytes(image: &[f32]) -> Vec<u8> {
    image.iter().flat_map(|v| v.to_le_bytes()).collect()
}

fn reply_argmax(body: &str) -> usize {
    body.split("\"argmax\":")
        .nth(1)
        .expect("argmax in reply")
        .split(|c: char| !c.is_ascii_digit())
        .next()
        .unwrap()
        .parse()
        .unwrap()
}

fn reply_logits(body: &str) -> Vec<f32> {
    body.split("\"logits\":[")
        .nth(1)
        .expect("logits array in reply")
        .split(']')
        .next()
        .unwrap()
        .split(',')
        .map(|t| t.parse().unwrap())
        .collect()
}

// ---------------- the checks ----------------

/// Every line of the exposition must be `# HELP`, `# TYPE` (with a
/// known kind) or a `name{labels} value` sample whose value parses.
fn check_prometheus_shape(text: &str) {
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# ") {
            let mut toks = rest.splitn(3, ' ');
            let keyword = toks.next().unwrap_or("");
            let name = toks.next().unwrap_or("");
            let tail = toks.next().unwrap_or("");
            assert!(
                (keyword == "HELP" || keyword == "TYPE")
                    && name.starts_with("hgpipe_")
                    && !tail.is_empty(),
                "bad comment line: {line:?}"
            );
            if keyword == "TYPE" {
                assert!(
                    ["counter", "gauge", "summary"].contains(&tail),
                    "unknown metric kind in {line:?}"
                );
            }
            continue;
        }
        let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| {
            panic!("sample line has no value: {line:?}");
        });
        assert!(value.parse::<f64>().is_ok(), "unparseable value in {line:?}");
        let name = series.split('{').next().unwrap();
        assert!(name.starts_with("hgpipe_"), "foreign family in {line:?}");
        if series.contains('{') {
            assert!(series.ends_with('}'), "unbalanced labels in {line:?}");
        }
    }
}

/// Grab the (single) sample value of `family`, if the family is present.
fn sample_value(text: &str, family: &str) -> Option<f64> {
    text.lines()
        .find(|l| l.starts_with(&format!("{family}{{")))
        .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
}

fn check_bit_exact_inference(addr: &str) -> usize {
    let (images, expected) = golden();
    for (i, (image, (want_argmax, want_logits))) in images.iter().zip(&expected).enumerate() {
        let reply = request(addr, "POST", infer_path(), &[], &image_bytes(image));
        assert_eq!(reply.status, 200, "image {i}: {}", reply.text());
        let body = reply.text();
        assert_eq!(reply_argmax(&body), *want_argmax, "image {i} argmax");
        let logits = reply_logits(&body);
        assert_eq!(logits.len(), want_logits.len(), "image {i} logit count");
        for (j, (got, want)) in logits.iter().zip(want_logits).enumerate() {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "image {i} logit {j} must cross the socket bit-exact"
            );
        }
    }
    // one JSON-array body must decode to the same tokens as binary
    let json = format!(
        "[{}]",
        images[0].iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",")
    );
    let reply = request(
        addr,
        "POST",
        infer_path(),
        &[("Content-Type", "application/json")],
        json.as_bytes(),
    );
    assert_eq!(reply.status, 200, "{}", reply.text());
    assert_eq!(reply_argmax(&reply.text()), expected[0].0, "json body argmax");
    images.len() + 1
}

fn check_metrics(addr: &str, want_requests: usize) {
    let reply = request(addr, "GET", "/metrics", &[], b"");
    assert_eq!(reply.status, 200);
    assert_eq!(
        reply.header("content-type"),
        Some("text/plain; version=0.0.4"),
        "prometheus content type"
    );
    let text = reply.text();
    check_prometheus_shape(&text);
    for family in [
        "hgpipe_requests_total",
        "hgpipe_requests_failed_total",
        "hgpipe_requests_shed_total",
        "hgpipe_requests_expired_total",
        "hgpipe_requests_retried_total",
        "hgpipe_replica_restarts_total",
        "hgpipe_replicas_retired_total",
        "hgpipe_live_replicas",
        "hgpipe_queue_depth",
        "hgpipe_request_latency_seconds",
        "hgpipe_request_latency_seconds_sum",
        "hgpipe_request_latency_seconds_count",
    ] {
        assert!(text.contains(&format!("# TYPE {family} ")), "missing family {family}");
    }
    let line =
        format!("hgpipe_requests_total{{model=\"tiny-synth\",version=\"v1\"}} {want_requests}");
    assert!(text.contains(&line), "expected {line:?} in:\n{text}");
}

fn check_healthz(addr: &str) {
    let reply = request(addr, "GET", "/healthz", &[], b"");
    assert_eq!(reply.status, 200, "{}", reply.text());
    let body = reply.text();
    assert!(body.contains("\"status\":\"ok\""), "{body}");
    assert!(body.contains("tiny-synth"), "{body}");
}

/// Capacity-1 queue behind one stalled replica: concurrent posts must
/// produce at least one `429`, visible in the scrape as an http shed.
fn check_overload_sheds_429(addr: &str) {
    let (images, _) = golden();
    let body = Arc::new(image_bytes(&images[0]));
    let statuses: Vec<u16> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let body = body.clone();
                s.spawn(move || {
                    let reply = request(addr, "POST", infer_path(), &[], &body);
                    if reply.status == 429 {
                        assert_eq!(reply.header("retry-after"), Some("1"), "429 advises a retry");
                    }
                    reply.status
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert!(statuses.iter().all(|s| *s == 200 || *s == 429), "{statuses:?}");
    let sheds = statuses.iter().filter(|s| **s == 429).count();
    assert!(sheds >= 1, "capacity-1 queue under 8 posts must shed: {statuses:?}");

    let text = request(addr, "GET", "/metrics", &[], b"").text();
    check_prometheus_shape(&text);
    let scraped = sample_value(&text, "hgpipe_requests_shed_total").expect("shed family");
    assert!(scraped as usize >= sheds, "scraped shed {scraped} < observed 429s {sheds}");
    let by_http = text
        .lines()
        .find(|l| {
            l.starts_with("hgpipe_requests_shed_by_source_total{") && l.contains("source=\"http\"")
        })
        .expect("per-source shed family");
    let by_http: usize = by_http.rsplit(' ').next().unwrap().parse().unwrap();
    assert_eq!(by_http as f64, scraped, "every shed came over http");
}

fn main() {
    println!("http-smoke: golden-fixture inference over the wire");
    let server = Server::start(&[]);
    let answered = check_bit_exact_inference(&server.addr);
    println!("  {answered} bit-exact replies from http://{}", server.addr);
    check_metrics(&server.addr, answered);
    println!("  /metrics line-parses, request count exact");
    check_healthz(&server.addr);
    println!("  /healthz ok");
    drop(server);

    println!("http-smoke: overload shedding behind --queue-cap 1");
    let server = Server::start(&[
        "--queue-cap",
        "1",
        "--replicas",
        "1",
        "--faults",
        "stall:1.0:400,seed:7",
    ]);
    check_overload_sheds_429(&server.addr);
    println!("  429 + Retry-After observed, shed attributed to source=\"http\"");
    drop(server);

    println!("http-smoke OK");
}
