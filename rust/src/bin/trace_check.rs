//! `trace_check` — the Chrome-trace smoke gate (`make trace-smoke`).
//!
//! Reads a JSONL trace emitted by `hgpipe serve --trace FILE` and fails
//! (exit 1) when any line is malformed, spans on one thread lane
//! partially overlap, a request id was admitted twice, or the trace is
//! trivially empty (no admits or no dispatches — a trace that recorded
//! nothing would pass a pure well-formedness check).
//!
//! The logic lives in `hgpipe::util::tracecheck` (unit-tested there);
//! this binary is the argument parsing and the process exit code.
//!
//! Usage: trace_check [--trace PATH]

use hgpipe::util::tracecheck::check;

fn main() {
    let mut trace_path = "TRACE_smoke.jsonl".to_string();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--trace" if i + 1 < argv.len() => {
                trace_path = argv[i + 1].clone();
                i += 1;
            }
            other => {
                eprintln!("trace-check: unknown argument '{other}'");
                eprintln!("usage: trace_check [--trace PATH]");
                std::process::exit(2);
            }
        }
        i += 1;
    }

    let text = std::fs::read_to_string(&trace_path).unwrap_or_else(|e| {
        eprintln!("trace-check: cannot read trace '{trace_path}': {e}");
        std::process::exit(2);
    });

    let (sum, mut errors) = check(&text);
    if sum.admits == 0 {
        errors.push("trace has no accepted 'admit' instants — nothing was served".into());
    }
    if sum.execs == 0 {
        errors.push("trace has no 'exec' dispatch spans — nothing was executed".into());
    }

    if errors.is_empty() {
        println!(
            "trace-check: OK — {} events: {} admits (+{} shed), {} queue waits, \
             {} dispatches, {} stage tiles, {} op spans, {} stalls, {} retries, \
             {} dropped to ring overflow",
            sum.events,
            sum.admits,
            sum.sheds,
            sum.queue_waits,
            sum.execs,
            sum.tiles,
            sum.op_spans,
            sum.stalls,
            sum.retries,
            sum.dropped
        );
    } else {
        eprintln!("trace-check: FAILED ({} problem(s))", errors.len());
        for e in errors.iter().take(20) {
            eprintln!("  - {e}");
        }
        if errors.len() > 20 {
            eprintln!("  ... and {} more", errors.len() - 20);
        }
        std::process::exit(1);
    }
}
