//! `bench_check` — the CI perf-regression gate (`make bench-check`).
//!
//! Reads the freshly-generated `BENCH_interpreter.json` and the
//! committed `BENCH_baseline.json`, then fails (exit 1) when:
//!
//! * the bench artifact is missing any field of its documented schema
//!   (including the `scale_out` and shared-artifact `memory` sections)
//!   — schema drift vs README, or
//! * a gated throughput (pooled fabric, pipeline) fell below its
//!   committed floor by more than the baseline's `tolerance`.
//!
//! The logic lives in `hgpipe::util::benchcheck` (unit-tested there);
//! this binary is the argument parsing and the process exit code.
//!
//! Usage: bench_check [--bench PATH] [--baseline PATH]

use hgpipe::util::benchcheck::{regression_errors, schema_errors};
use hgpipe::util::json::Json;

fn load(path: &str, what: &str) -> Json {
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
        eprintln!("bench-check: cannot read {what} '{path}': {e}");
        std::process::exit(2);
    });
    Json::parse(&text).unwrap_or_else(|e| {
        eprintln!("bench-check: {what} '{path}' is not valid JSON: {e}");
        std::process::exit(2);
    })
}

fn main() {
    let mut bench_path = "BENCH_interpreter.json".to_string();
    let mut baseline_path = "BENCH_baseline.json".to_string();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--bench" if i + 1 < argv.len() => {
                bench_path = argv[i + 1].clone();
                i += 1;
            }
            "--baseline" if i + 1 < argv.len() => {
                baseline_path = argv[i + 1].clone();
                i += 1;
            }
            other => {
                eprintln!("bench-check: unknown argument '{other}'");
                eprintln!("usage: bench_check [--bench PATH] [--baseline PATH]");
                std::process::exit(2);
            }
        }
        i += 1;
    }

    let bench = load(&bench_path, "bench json");
    let baseline = load(&baseline_path, "baseline");

    let mut errors = schema_errors(&bench);
    errors.extend(regression_errors(&bench, &baseline));

    if errors.is_empty() {
        let pooled = bench.get("fabric_pooled_img_s").and_then(Json::as_f64).unwrap_or(0.0);
        let pipe = bench
            .get("pipeline")
            .and_then(|p| p.get("img_s"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        println!(
            "bench-check: OK — schema valid, pooled {pooled:.1} img/s and pipeline \
             {pipe:.1} img/s within tolerance of the committed baseline"
        );
    } else {
        eprintln!("bench-check: FAILED ({} problem(s))", errors.len());
        for e in &errors {
            eprintln!("  - {e}");
        }
        std::process::exit(1);
    }
}
