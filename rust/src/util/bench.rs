//! Minimal benchmark harness (criterion is not vendored in this offline
//! environment): warmup + timed iterations + robust summary statistics.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<42} {:>10} iters  mean {:>12?}  median {:>12?}  p95 {:>12?}",
            self.name, self.iters, self.mean, self.median, self.p95
        )
    }
}

/// Run `f` repeatedly for ~`budget` after a warmup, returning stats.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let target_iters = (budget.as_secs_f64() / once.as_secs_f64()).clamp(5.0, 10_000.0) as u64;

    let mut samples = Vec::with_capacity(target_iters as usize);
    for _ in 0..target_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    BenchResult {
        name: name.to_string(),
        iters: target_iters,
        mean,
        median: samples[samples.len() / 2],
        p95: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
        min: samples[0],
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-ish", Duration::from_millis(20), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.min <= r.median && r.median <= r.p95);
    }
}
