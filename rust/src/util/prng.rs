//! Deterministic PRNG (xoshiro256**) — no external `rand` in this
//! environment. Used by tests (property-test driver), the workload
//! generators and the coordinator benches.

/// xoshiro256** with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 to fill the state
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine for
        // test workloads; bias < 2^-32 for n << 2^32
        ((self.next_u64() >> 32) * n) >> 32
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exponentially-distributed f64 with the given mean.
    pub fn exp(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Tiny property-test driver: run `f` on `n` seeded cases, reporting the
/// failing seed (our stand-in for proptest, which is not vendored here).
pub fn for_all_seeds<F: Fn(&mut Prng)>(n: u64, f: F) {
    for seed in 0..n {
        let mut rng = Prng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property failed at seed {seed}: {e:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(7);
        let mut b = Prng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Prng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval_and_spread() {
        let mut r = Prng::new(2);
        let xs: Vec<f64> = (0..10_000).map(|_| r.f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.02);
    }

    #[test]
    fn range_inclusive() {
        let mut r = Prng::new(3);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let v = r.range_i64(-2, 2);
            assert!((-2..=2).contains(&v));
            saw_lo |= v == -2;
            saw_hi |= v == 2;
        }
        assert!(saw_lo && saw_hi);
    }
}
