//! Schema + perf-regression checks for `BENCH_interpreter.json` — the
//! library behind the `bench_check` binary (`make bench-check`, the CI
//! gate that runs right after the smoke bench).
//!
//! Two independent checks:
//!
//! * [`schema_errors`] — the bench artifact must contain every field the
//!   README documents (including the `scale_out`, `kernels`, `faults`,
//!   `telemetry`, `http` and `memory` sections), so the schema
//!   cannot silently drift away from the docs: the bench emits its JSON
//!   by hand (no serde offline), and a renamed or dropped key would
//!   otherwise only be noticed by whoever next reads the artifact.
//! * [`regression_errors`] — headline throughputs (`fabric_pooled_img_s`
//!   and `pipeline.img_s`) must not fall below the committed floors in
//!   `BENCH_baseline.json` by more than the baseline's own `tolerance`.
//!   The floors are deliberately generous (CI runners are noisy and
//!   heterogeneous): the gate exists to catch *catastrophic* regressions
//!   — an accidentally-serial fabric, a deadlocked pipeline limping on
//!   timeouts — not 10% jitter.
//!
//! Bit-exactness needs no checking here: the bench binary self-checks
//! fabric-, pipeline- and replica-vs-naive logits before timing and
//! exits non-zero on divergence, which already fails the CI step.

use crate::util::json::Json;

/// Walk a dotted path through nested objects.
pub fn lookup<'a>(doc: &'a Json, path: &str) -> Option<&'a Json> {
    path.split('.').try_fold(doc, |d, k| d.get(k))
}

/// Every dotted path the README documents for `BENCH_interpreter.json`.
/// Arrays are validated element-wise by [`schema_errors`] with the
/// per-element keys below.
const REQUIRED_PATHS: &[&str] = &[
    "model",
    "smoke",
    "images",
    "lanes",
    "scalar_naive_img_s",
    "fabric_serial_img_s",
    "spawn_pooled_img_s",
    "fabric_pooled_img_s",
    "speedup_pooled_vs_naive",
    "speedup_pooled_vs_serial",
    "speedup_persistent_vs_spawn",
    "gemm_microkernel.shape",
    "gemm_microkernel.dense_speedup_vs_naive",
    "gemm_microkernel.sparse_speedup_vs_naive",
    "lane_sweep",
    "pipeline.stages",
    "pipeline.queue_depth",
    "pipeline.lanes_per_stage",
    "pipeline.img_s",
    "pipeline.speedup_vs_lane_parallel",
    "pipeline.window.rounds",
    "pipeline.window.images_per_round",
    "pipeline.window.wall_ms",
    "pipeline.fill_drain_bubbles",
    "pipeline.backpressure_stalls",
    "pipeline.stage_sweep",
    "pipeline.per_stage",
    "scale_out.replica_sweep",
    "scale_out.partition.stages",
    "scale_out.partition.near_even.stages",
    "scale_out.partition.near_even.img_s",
    "scale_out.partition.near_even.per_stage_busy_ms",
    "scale_out.partition.near_even.max_min_busy_ratio",
    "scale_out.partition.near_even_pr4.stages",
    "scale_out.partition.near_even_pr4.img_s",
    "scale_out.partition.near_even_pr4.per_stage_busy_ms",
    "scale_out.partition.near_even_pr4.max_min_busy_ratio",
    "scale_out.partition.work_proportional.stages",
    "scale_out.partition.work_proportional.img_s",
    "scale_out.partition.work_proportional.per_stage_busy_ms",
    "scale_out.partition.work_proportional.max_min_busy_ratio",
    "kernels.detected",
    "kernels.scalar_img_s",
    "kernels.simd_img_s",
    "kernels.speedup",
    "kernels.per_op_scalar_ms_per_image.gemm",
    "kernels.per_op_scalar_ms_per_image.attention",
    "kernels.per_op_scalar_ms_per_image.layernorm",
    "kernels.per_op_scalar_ms_per_image.requant",
    "kernels.per_op_simd_ms_per_image.gemm",
    "kernels.per_op_simd_ms_per_image.attention",
    "kernels.per_op_simd_ms_per_image.layernorm",
    "kernels.per_op_simd_ms_per_image.requant",
    "faults.enabled",
    "faults.restarts",
    "faults.retried",
    "faults.shed",
    "faults.expired",
    "telemetry.tracing_off_img_s",
    "telemetry.tracing_on_img_s",
    "telemetry.overhead_ratio",
    "http.inproc_img_s",
    "http.loopback_img_s",
    "http.overhead_ratio",
    "http.connections",
    "http.requests",
    "memory.artifact_footprint_bytes",
    "memory.replicas",
    "memory.unshared_bytes",
    "memory.shared_bytes",
    "memory.savings_ratio",
    "memory.artifact_refs",
    "per_op_ms_per_image.gemm",
    "per_op_ms_per_image.attention",
    "per_op_ms_per_image.layernorm",
    "per_op_ms_per_image.requant",
    "per_op_pooled_ms_per_image.gemm",
    "per_op_pooled_ms_per_image.attention",
    "per_op_pooled_ms_per_image.layernorm",
    "per_op_pooled_ms_per_image.requant",
];

/// `(array path, required keys of each element)`.
const REQUIRED_ARRAY_ELEMENTS: &[(&str, &[&str])] = &[
    ("lane_sweep", &["lanes", "persistent_img_s", "spawn_img_s"]),
    ("pipeline.stage_sweep", &["stages", "img_s"]),
    (
        "pipeline.per_stage",
        &[
            "name",
            "blocks",
            "lanes",
            "images",
            "busy_ms",
            "occupancy",
            "stalls_empty",
            "stalls_full",
        ],
    ),
    ("scale_out.replica_sweep", &["replicas", "img_s", "speedup_vs_1", "per_replica"]),
];

/// Validate `doc` against the documented `BENCH_interpreter.json`
/// schema; returns one message per missing/ill-typed piece (empty =
/// valid).
pub fn schema_errors(doc: &Json) -> Vec<String> {
    let mut errs = Vec::new();
    for path in REQUIRED_PATHS {
        if lookup(doc, path).is_none() {
            errs.push(format!("missing key: {path}"));
        }
    }
    for (path, keys) in REQUIRED_ARRAY_ELEMENTS {
        let Some(arr) = lookup(doc, path) else {
            continue; // already reported as missing above (or by REQUIRED_PATHS)
        };
        let Some(items) = arr.as_arr() else {
            errs.push(format!("{path} is not an array"));
            continue;
        };
        if items.is_empty() {
            errs.push(format!("{path} is empty"));
        }
        for (i, item) in items.iter().enumerate() {
            for k in *keys {
                if item.get(k).is_none() {
                    errs.push(format!("{path}[{i}] missing key: {k}"));
                }
            }
        }
    }
    // the replica sweep nests one more documented array: each replica's
    // window breakdown ({images, exec_ms, occupancy})
    if let Some(items) = lookup(doc, "scale_out.replica_sweep").and_then(Json::as_arr) {
        for (i, item) in items.iter().enumerate() {
            let Some(prs) = item.get("per_replica").and_then(Json::as_arr) else {
                continue; // absence already reported by the element loop
            };
            if prs.is_empty() {
                errs.push(format!("scale_out.replica_sweep[{i}].per_replica is empty"));
            }
            for (j, pr) in prs.iter().enumerate() {
                for k in ["images", "exec_ms", "occupancy"] {
                    if pr.get(k).is_none() {
                        errs.push(format!(
                            "scale_out.replica_sweep[{i}].per_replica[{j}] missing key: {k}"
                        ));
                    }
                }
            }
        }
    }
    errs
}

/// Throughput keys gated against the baseline:
/// `(baseline key, bench path, human label)`.
const GATED: &[(&str, &str, &str)] = &[
    ("fabric_pooled_img_s", "fabric_pooled_img_s", "lane-parallel pooled throughput"),
    ("pipeline_img_s", "pipeline.img_s", "pipeline throughput"),
];

/// Compare the bench artifact against the committed baseline floors.
/// A gated value may fall below its floor by at most the baseline's
/// `tolerance` fraction (default 0.4). Missing baseline keys are errors
/// — a silently-ungated baseline is how regressions slip through.
pub fn regression_errors(current: &Json, baseline: &Json) -> Vec<String> {
    let mut errs = Vec::new();
    let tolerance = match baseline.get("tolerance").and_then(Json::as_f64) {
        Some(t) if (0.0..1.0).contains(&t) => t,
        Some(t) => {
            errs.push(format!("baseline tolerance {t} outside [0, 1)"));
            return errs;
        }
        None => 0.4,
    };
    for (base_key, cur_path, label) in GATED {
        let Some(floor) = baseline.get(base_key).and_then(Json::as_f64) else {
            errs.push(format!("baseline missing gate key: {base_key}"));
            continue;
        };
        let Some(cur) = lookup(current, cur_path).and_then(Json::as_f64) else {
            errs.push(format!("bench json missing gated value: {cur_path}"));
            continue;
        };
        let allowed = floor * (1.0 - tolerance);
        if cur < allowed {
            errs.push(format!(
                "{label} regressed: {cur_path} = {cur:.1} img/s < {allowed:.1} \
                 (baseline {floor:.1} - {tolerance:.0}% tolerance)",
                tolerance = tolerance * 100.0
            ));
        }
    }
    errs
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal bench artifact satisfying the documented schema — kept
    /// in lockstep with what `benches/interpreter.rs` emits (this test
    /// failing after a bench edit means the schema, README and checker
    /// need the same update).
    pub(super) fn sample() -> Json {
        Json::parse(
            r#"{
  "model": "tiny-synth", "smoke": true, "images": 16, "lanes": 4,
  "scalar_naive_img_s": 100.0, "fabric_serial_img_s": 150.0,
  "spawn_pooled_img_s": 300.0, "fabric_pooled_img_s": 400.0,
  "speedup_pooled_vs_naive": 4.0, "speedup_pooled_vs_serial": 2.67,
  "speedup_persistent_vs_spawn": 1.33,
  "gemm_microkernel": {"shape": [16, 64, 192], "dense_speedup_vs_naive": 2.0,
                       "sparse_speedup_vs_naive": 1.5},
  "lane_sweep": [{"lanes": 1, "persistent_img_s": 150.0, "spawn_img_s": 140.0}],
  "pipeline": {
    "stages": 5, "queue_depth": 2, "lanes_per_stage": 1,
    "img_s": 350.0, "speedup_vs_lane_parallel": 0.9,
    "window": {"rounds": 3, "images_per_round": 16, "wall_ms": 120.0},
    "fill_drain_bubbles": 12, "backpressure_stalls": 3,
    "stage_sweep": [{"stages": 1, "img_s": 160.0}],
    "per_stage": [{"name": "stage0", "blocks": [0, 0], "lanes": 1, "images": 48,
                   "busy_ms": 20.0, "occupancy": 0.4, "stalls_empty": 4, "stalls_full": 1}]
  },
  "scale_out": {
    "replica_sweep": [{"replicas": 1, "img_s": 400.0, "speedup_vs_1": 1.0,
                       "per_replica": [{"images": 64, "exec_ms": 100.0, "occupancy": 0.8}]}],
    "partition": {
      "stages": 5,
      "near_even": {"stages": 5, "img_s": 300.0,
                    "per_stage_busy_ms": [30.0, 20.0], "max_min_busy_ratio": 12.0},
      "near_even_pr4": {"stages": 4, "img_s": 310.0,
                        "per_stage_busy_ms": [30.0, 24.0], "max_min_busy_ratio": 1.3},
      "work_proportional": {"stages": 5, "img_s": 350.0,
                            "per_stage_busy_ms": [22.0, 21.0], "max_min_busy_ratio": 3.0}
    }
  },
  "kernels": {
    "detected": "avx2", "scalar_img_s": 150.0, "simd_img_s": 450.0, "speedup": 3.0,
    "per_op_scalar_ms_per_image": {"quantize": 0.1, "gemm": 3.0, "layernorm": 0.4,
                                   "attention": 1.2, "requant": 0.1, "head": 0.1},
    "per_op_simd_ms_per_image": {"quantize": 0.1, "gemm": 1.0, "layernorm": 0.2,
                                 "attention": 0.4, "requant": 0.0, "head": 0.1}
  },
  "faults": {"enabled": false, "restarts": 0, "retried": 0, "shed": 0, "expired": 0},
  "telemetry": {"tracing_off_img_s": 400.0, "tracing_on_img_s": 390.0,
                "overhead_ratio": 1.026},
  "http": {"inproc_img_s": 400.0, "loopback_img_s": 380.0,
           "overhead_ratio": 1.053, "connections": 8, "requests": 64},
  "memory": {"artifact_footprint_bytes": 1048576, "replicas": 4,
             "unshared_bytes": 4194304, "shared_bytes": 1048576,
             "savings_ratio": 4.0, "artifact_refs": 9},
  "per_op_ms_per_image": {"quantize": 0.1, "gemm": 2.0, "layernorm": 0.3,
                          "attention": 0.8, "requant": 0.0, "head": 0.1},
  "per_op_pooled_ms_per_image": {"quantize": 0.1, "gemm": 1.0, "layernorm": 0.2,
                                 "attention": 0.5, "requant": 0.0, "head": 0.1}
}"#,
        )
        .expect("sample parses")
    }

    fn baseline() -> Json {
        Json::parse(
            r#"{"tolerance": 0.4, "fabric_pooled_img_s": 400.0, "pipeline_img_s": 350.0}"#,
        )
        .unwrap()
    }

    #[test]
    fn sample_matches_schema() {
        assert_eq!(schema_errors(&sample()), Vec::<String>::new());
    }

    #[test]
    fn missing_scale_out_is_reported() {
        let mut doc = sample();
        if let Json::Obj(m) = &mut doc {
            m.remove("scale_out");
        }
        let errs = schema_errors(&doc);
        assert!(
            errs.iter().any(|e| e.contains("scale_out")),
            "scale_out omission must be caught: {errs:?}"
        );
    }

    #[test]
    fn missing_kernels_section_is_reported() {
        let mut doc = sample();
        if let Json::Obj(m) = &mut doc {
            m.remove("kernels");
        }
        let errs = schema_errors(&doc);
        assert!(
            errs.iter().any(|e| e.contains("kernels.detected")),
            "kernels omission must be caught: {errs:?}"
        );
    }

    #[test]
    fn missing_faults_section_is_reported() {
        let mut doc = sample();
        if let Json::Obj(m) = &mut doc {
            m.remove("faults");
        }
        let errs = schema_errors(&doc);
        assert!(
            errs.iter().any(|e| e.contains("faults.restarts")),
            "faults omission must be caught: {errs:?}"
        );
    }

    #[test]
    fn missing_telemetry_section_is_reported() {
        let mut doc = sample();
        if let Json::Obj(m) = &mut doc {
            m.remove("telemetry");
        }
        let errs = schema_errors(&doc);
        assert!(
            errs.iter().any(|e| e.contains("telemetry.overhead_ratio")),
            "telemetry omission must be caught: {errs:?}"
        );
    }

    #[test]
    fn missing_http_section_is_reported() {
        let mut doc = sample();
        if let Json::Obj(m) = &mut doc {
            m.remove("http");
        }
        let errs = schema_errors(&doc);
        assert!(
            errs.iter().any(|e| e.contains("http.overhead_ratio")),
            "http omission must be caught: {errs:?}"
        );
    }

    #[test]
    fn missing_memory_section_is_reported() {
        let mut doc = sample();
        if let Json::Obj(m) = &mut doc {
            m.remove("memory");
        }
        let errs = schema_errors(&doc);
        assert!(
            errs.iter().any(|e| e.contains("memory.artifact_footprint_bytes")),
            "memory omission must be caught: {errs:?}"
        );
    }

    #[test]
    fn missing_array_element_key_is_reported() {
        let mut doc = sample();
        // drop "spawn_img_s" from the first lane_sweep element
        if let Json::Obj(m) = &mut doc {
            if let Some(Json::Arr(a)) = m.get_mut("lane_sweep") {
                if let Some(Json::Obj(e)) = a.first_mut() {
                    e.remove("spawn_img_s");
                }
            }
        }
        let errs = schema_errors(&doc);
        assert!(
            errs.iter().any(|e| e.contains("lane_sweep[0]") && e.contains("spawn_img_s")),
            "{errs:?}"
        );
    }

    #[test]
    fn missing_nested_per_replica_key_is_reported() {
        let mut doc = sample();
        // drop "occupancy" from replica_sweep[0].per_replica[0]
        if let Json::Obj(m) = &mut doc {
            if let Some(Json::Obj(so)) = m.get_mut("scale_out") {
                if let Some(Json::Arr(sweep)) = so.get_mut("replica_sweep") {
                    if let Some(Json::Obj(e)) = sweep.first_mut() {
                        if let Some(Json::Arr(prs)) = e.get_mut("per_replica") {
                            if let Some(Json::Obj(pr)) = prs.first_mut() {
                                pr.remove("occupancy");
                            }
                        }
                    }
                }
            }
        }
        let errs = schema_errors(&doc);
        assert!(
            errs.iter().any(|e| e.contains("per_replica[0]") && e.contains("occupancy")),
            "nested per_replica drift must be caught: {errs:?}"
        );
    }

    #[test]
    fn within_tolerance_passes() {
        // 40% below 400 is 240: a current of 250 squeaks by
        let mut doc = sample();
        if let Json::Obj(m) = &mut doc {
            m.insert("fabric_pooled_img_s".into(), Json::Num(250.0));
        }
        assert_eq!(regression_errors(&doc, &baseline()), Vec::<String>::new());
    }

    #[test]
    fn beyond_tolerance_fails_with_a_named_gate() {
        let mut doc = sample();
        if let Json::Obj(m) = &mut doc {
            m.insert("fabric_pooled_img_s".into(), Json::Num(100.0));
        }
        let errs = regression_errors(&doc, &baseline());
        assert_eq!(errs.len(), 1, "{errs:?}");
        assert!(errs[0].contains("fabric_pooled_img_s"), "{errs:?}");
    }

    #[test]
    fn pipeline_gate_reads_the_nested_path() {
        let mut doc = sample();
        if let Json::Obj(m) = &mut doc {
            if let Some(Json::Obj(p)) = m.get_mut("pipeline") {
                p.insert("img_s".into(), Json::Num(10.0));
            }
        }
        let errs = regression_errors(&doc, &baseline());
        assert!(errs.iter().any(|e| e.contains("pipeline.img_s")), "{errs:?}");
    }

    #[test]
    fn baseline_missing_gate_key_is_an_error() {
        let b = Json::parse(r#"{"tolerance": 0.4, "fabric_pooled_img_s": 400.0}"#).unwrap();
        let errs = regression_errors(&sample(), &b);
        assert!(errs.iter().any(|e| e.contains("pipeline_img_s")), "{errs:?}");
    }

    #[test]
    fn bogus_tolerance_is_rejected() {
        let b = Json::parse(r#"{"tolerance": 1.5}"#).unwrap();
        let errs = regression_errors(&sample(), &b);
        assert!(errs.iter().any(|e| e.contains("tolerance")), "{errs:?}");
    }
}
