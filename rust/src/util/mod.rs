//! Dependency-free utilities (this environment builds offline without
//! serde / clap / criterion / rand / proptest): JSON codec, deterministic
//! PRNG, bench harness, table formatting.

pub mod bench;
pub mod benchcheck;
pub mod json;
pub mod prng;
pub mod tracecheck;

/// Render an ASCII table (used by the report generators).
pub fn ascii_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        out.push('+');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('+');
        }
        out.push('\n');
    };
    sep(&mut out);
    out.push('|');
    for (h, w) in headers.iter().zip(&widths) {
        out.push_str(&format!(" {h:<w$} |"));
    }
    out.push('\n');
    sep(&mut out);
    for row in rows {
        out.push('|');
        for (c, w) in row.iter().zip(&widths) {
            out.push_str(&format!(" {c:<w$} |"));
        }
        out.push('\n');
    }
    sep(&mut out);
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn ascii_table_aligns() {
        let t = super::ascii_table(
            &["a", "bbbb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
        assert!(t.contains("| 333 | 4    |"));
    }
}
