//! Chrome-trace JSONL validation (`make trace-smoke` / `trace_check`).
//!
//! A trace produced by [`crate::telemetry`] must be loadable by
//! Perfetto and internally consistent. This module checks, line by
//! line:
//!
//! * every event line parses as a JSON object with the Chrome trace
//!   required fields (`name`, `ph`, `pid`, `tid`, `ts` except for `M`
//!   metadata, `dur` for `X` complete spans);
//! * `ph` is one of the phases the exporter emits (`X M i C B E` —
//!   `B`/`E` begin/end pairs are accepted and balance-checked even
//!   though the current exporter only writes complete spans);
//! * `X` spans on one `(pid, tid)` lane nest properly — two spans may
//!   be disjoint or contained, never strictly partially overlapping.
//!   Spans of category `request` are exempt: a `queue_wait` interval
//!   for dispatch N+1 legitimately straddles the `exec` span of
//!   dispatch N (requests arrive while a prior batch is running);
//! * every request id is admitted exactly once per process — duplicate
//!   non-shed `admit` instants for one `(pid, id)` mean the admission
//!   seam double-fired.
//!
//! The checker is pure text-in / errors-out so the integration tests
//! can drive it without touching the filesystem; the `trace_check`
//! binary owns the exit codes.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// What a valid trace contained — printed by `trace_check` so the
/// smoke test's log shows coverage, not just "ok".
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct TraceSummary {
    /// Total event lines (excluding the opening `[`).
    pub events: usize,
    /// Accepted `admit` instants.
    pub admits: usize,
    /// Shed `admit` instants (`args.note == "shed"`).
    pub sheds: usize,
    /// `queue_wait` spans.
    pub queue_waits: usize,
    /// `exec` dispatch spans.
    pub execs: usize,
    /// Per-tile stage residency spans.
    pub tiles: usize,
    /// Per-op kernel spans (cat `op`).
    pub op_spans: usize,
    /// Channel stall spans (cat `stall`).
    pub stalls: usize,
    /// Retry instants (supervised-restart requeues).
    pub retries: usize,
    /// Events dropped to ring overflow (the closing `C` counter).
    pub dropped: u64,
}

/// Validate a whole trace file's text. Returns the summary and every
/// problem found (empty = valid).
pub fn check(text: &str) -> (TraceSummary, Vec<String>) {
    let mut sum = TraceSummary::default();
    let mut errors = Vec::new();
    // per-(pid,tid) open B count, X spans (ts, end, name, cat)
    let mut open: BTreeMap<(u64, u64), i64> = BTreeMap::new();
    let mut spans: BTreeMap<(u64, u64), Vec<(u64, u64, String)>> = BTreeMap::new();
    // per-(pid,id) accepted-admit count
    let mut admits: BTreeMap<(u64, i64), usize> = BTreeMap::new();

    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim().trim_end_matches(',');
        if line.is_empty() || line == "[" || line == "]" {
            continue;
        }
        let lineno = ln + 1;
        let v = match Json::parse(line) {
            Ok(v) => v,
            Err(e) => {
                errors.push(format!("line {lineno}: not valid JSON: {e}"));
                continue;
            }
        };
        if v.as_obj().is_none() {
            errors.push(format!("line {lineno}: event is not a JSON object"));
            continue;
        }
        sum.events += 1;
        let name = match v.get("name").and_then(Json::as_str) {
            Some(n) => n.to_string(),
            None => {
                errors.push(format!("line {lineno}: missing string field 'name'"));
                continue;
            }
        };
        let ph = match v.get("ph").and_then(Json::as_str) {
            Some(p) if ["X", "M", "i", "C", "B", "E"].contains(&p) => p.to_string(),
            Some(p) => {
                errors.push(format!("line {lineno}: unknown phase '{p}'"));
                continue;
            }
            None => {
                errors.push(format!("line {lineno}: missing string field 'ph'"));
                continue;
            }
        };
        let (Some(pid), Some(tid)) = (
            v.get("pid").and_then(Json::as_i64).map(|n| n as u64),
            v.get("tid").and_then(Json::as_i64).map(|n| n as u64),
        ) else {
            errors.push(format!("line {lineno}: missing numeric 'pid'/'tid'"));
            continue;
        };
        let ts = v.get("ts").and_then(Json::as_i64);
        if ph != "M" && ts.is_none() {
            errors.push(format!("line {lineno}: '{name}' ({ph}) has no numeric 'ts'"));
            continue;
        }
        let cat = v.get("cat").and_then(Json::as_str).unwrap_or("").to_string();
        match ph.as_str() {
            "X" => {
                let Some(dur) = v.get("dur").and_then(Json::as_i64) else {
                    errors.push(format!("line {lineno}: X span '{name}' has no 'dur'"));
                    continue;
                };
                if dur < 0 {
                    errors.push(format!("line {lineno}: X span '{name}' has negative dur"));
                    continue;
                }
                let t = ts.unwrap_or(0).max(0) as u64;
                // `request` spans are logical waiting intervals, not
                // thread occupancy — exempt from lane nesting
                if cat != "request" {
                    spans
                        .entry((pid, tid))
                        .or_default()
                        .push((t, t + dur as u64, name.clone()));
                }
            }
            "B" => *open.entry((pid, tid)).or_default() += 1,
            "E" => {
                let c = open.entry((pid, tid)).or_default();
                *c -= 1;
                if *c < 0 {
                    errors.push(format!(
                        "line {lineno}: 'E' without matching 'B' on pid {pid} tid {tid}"
                    ));
                    *c = 0;
                }
            }
            "C" if name == "trace_dropped" => {
                sum.dropped = v
                    .get("args")
                    .and_then(|a| a.get("dropped"))
                    .and_then(Json::as_i64)
                    .unwrap_or(0)
                    .max(0) as u64;
            }
            _ => {}
        }
        match name.as_str() {
            "admit" if ph == "i" => {
                let shed = v
                    .get("args")
                    .and_then(|a| a.get("note"))
                    .and_then(Json::as_str)
                    .is_some_and(|n| n == "shed");
                if shed {
                    sum.sheds += 1;
                } else {
                    sum.admits += 1;
                    match v.get("args").and_then(|a| a.get("id")).and_then(Json::as_i64) {
                        Some(id) => *admits.entry((pid, id)).or_default() += 1,
                        None => errors
                            .push(format!("line {lineno}: 'admit' instant has no args.id")),
                    }
                }
            }
            "queue_wait" => sum.queue_waits += 1,
            "exec" => sum.execs += 1,
            "tile" => sum.tiles += 1,
            "retry" => sum.retries += 1,
            _ => {}
        }
        if cat == "op" {
            sum.op_spans += 1;
        } else if cat == "stall" {
            sum.stalls += 1;
        }
    }

    for ((pid, tid), c) in &open {
        if *c != 0 {
            errors.push(format!("{c} unclosed 'B' event(s) on pid {pid} tid {tid}"));
        }
    }
    for ((pid, id), c) in &admits {
        if *c > 1 {
            errors.push(format!("request id {id} admitted {c} times on pid {pid}"));
        }
    }
    for ((pid, tid), lane) in &mut spans {
        errors.extend(nesting_errors(lane).into_iter().map(|e| format!(
            "pid {pid} tid {tid}: {e}"
        )));
    }
    (sum, errors)
}

/// Errors only — the shape most tests want.
pub fn trace_errors(text: &str) -> Vec<String> {
    check(text).1
}

/// Strict-partial-overlap detection on one lane's complete spans. Two
/// spans may be disjoint or contained (shared endpoints allowed); a
/// span that starts inside another and ends outside it is a broken
/// parent/child relationship.
fn nesting_errors(lane: &mut [(u64, u64, String)]) -> Vec<String> {
    // parents first: by start ascending, then by end descending so a
    // containing span sorts before the spans it contains
    lane.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.cmp(&a.1)));
    let mut errors = Vec::new();
    let mut stack: Vec<(u64, u64, &str)> = Vec::new();
    for (ts, end, name) in lane.iter() {
        while let Some(top) = stack.last() {
            if top.1 <= *ts {
                stack.pop();
            } else {
                break;
            }
        }
        if let Some(top) = stack.last() {
            if *end > top.1 {
                errors.push(format!(
                    "span '{name}' [{ts}, {end}] partially overlaps '{}' [{}, {}]",
                    top.2, top.0, top.1
                ));
                continue; // don't push the malformed span as a parent
            }
        }
        stack.push((*ts, *end, name));
    }
    errors
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(s: &str) -> String {
        format!("{s},\n")
    }

    fn valid_trace() -> String {
        let mut t = String::from("[\n");
        t += &ev(r#"{"name":"process_name","cat":"meta","ph":"M","pid":1,"tid":0,"args":{"name":"tiny-synth"}}"#);
        t += &ev(r#"{"name":"thread_name","cat":"meta","ph":"M","pid":1,"tid":1,"args":{"name":"replica0"}}"#);
        t += &ev(r#"{"name":"admit","cat":"request","ph":"i","pid":1,"tid":0,"ts":10,"args":{"id":0}}"#);
        t += &ev(r#"{"name":"admit","cat":"request","ph":"i","pid":1,"tid":0,"ts":12,"args":{"id":1}}"#);
        t += &ev(r#"{"name":"admit","cat":"request","ph":"i","pid":1,"tid":0,"ts":14,"args":{"id":2,"note":"shed"}}"#);
        // queue_wait for id 1 straddles the first exec span — legal
        t += &ev(r#"{"name":"queue_wait","cat":"request","ph":"X","pid":1,"tid":1,"ts":10,"dur":10,"args":{"id":0}}"#);
        t += &ev(r#"{"name":"exec","cat":"dispatch","ph":"X","pid":1,"tid":1,"ts":20,"dur":30,"args":{"batch":1}}"#);
        t += &ev(r#"{"name":"queue_wait","cat":"request","ph":"X","pid":1,"tid":1,"ts":12,"dur":48,"args":{"id":1}}"#);
        t += &ev(r#"{"name":"gemm","cat":"op","ph":"X","pid":1,"tid":1,"ts":22,"dur":20}"#);
        t += &ev(r#"{"name":"exec","cat":"dispatch","ph":"X","pid":1,"tid":1,"ts":60,"dur":5,"args":{"batch":1}}"#);
        t += &ev(r#"{"name":"tile","cat":"stage","ph":"X","pid":1,"tid":2,"ts":21,"dur":8,"args":{"id":0}}"#);
        t += &ev(r#"{"name":"blocked_recv","cat":"stall","ph":"X","pid":1,"tid":2,"ts":30,"dur":3}"#);
        t += &ev(r#"{"name":"trace_dropped","cat":"meta","ph":"C","pid":0,"tid":0,"ts":99,"args":{"dropped":4}}"#);
        t
    }

    #[test]
    fn valid_trace_passes_with_summary() {
        let (sum, errors) = check(&valid_trace());
        assert!(errors.is_empty(), "unexpected errors: {errors:?}");
        assert_eq!(sum.admits, 2);
        assert_eq!(sum.sheds, 1);
        assert_eq!(sum.queue_waits, 2);
        assert_eq!(sum.execs, 2);
        assert_eq!(sum.tiles, 1);
        assert_eq!(sum.op_spans, 1);
        assert_eq!(sum.stalls, 1);
        assert_eq!(sum.dropped, 4);
    }

    #[test]
    fn bad_json_line_is_an_error() {
        let t = format!("{}{{not json\n", valid_trace());
        assert!(trace_errors(&t).iter().any(|e| e.contains("not valid JSON")));
    }

    #[test]
    fn missing_required_fields_are_errors() {
        let no_ts = ev(r#"{"name":"exec","cat":"dispatch","ph":"X","pid":1,"tid":1,"dur":5}"#);
        assert!(trace_errors(&no_ts).iter().any(|e| e.contains("no numeric 'ts'")));
        let no_dur = ev(r#"{"name":"exec","cat":"dispatch","ph":"X","pid":1,"tid":1,"ts":5}"#);
        assert!(trace_errors(&no_dur).iter().any(|e| e.contains("no 'dur'")));
        let bad_ph = ev(r#"{"name":"x","cat":"y","ph":"Z","pid":1,"tid":1,"ts":5}"#);
        assert!(trace_errors(&bad_ph).iter().any(|e| e.contains("unknown phase")));
    }

    #[test]
    fn duplicate_admit_is_an_error() {
        let mut t = valid_trace();
        t += &ev(r#"{"name":"admit","cat":"request","ph":"i","pid":1,"tid":0,"ts":40,"args":{"id":0}}"#);
        assert!(trace_errors(&t).iter().any(|e| e.contains("admitted 2 times")));
        // ...but the same id on another pid (another model) is fine
        let mut t2 = valid_trace();
        t2 += &ev(r#"{"name":"admit","cat":"request","ph":"i","pid":2,"tid":0,"ts":40,"args":{"id":0}}"#);
        assert!(trace_errors(&t2).is_empty());
    }

    #[test]
    fn unbalanced_begin_end_is_an_error() {
        let e_only = ev(r#"{"name":"x","cat":"y","ph":"E","pid":1,"tid":1,"ts":5}"#);
        assert!(trace_errors(&e_only).iter().any(|e| e.contains("without matching 'B'")));
        let b_only = ev(r#"{"name":"x","cat":"y","ph":"B","pid":1,"tid":1,"ts":5}"#);
        assert!(trace_errors(&b_only).iter().any(|e| e.contains("unclosed 'B'")));
    }

    #[test]
    fn partial_overlap_on_a_checked_cat_is_an_error() {
        let mut t = String::from("[\n");
        t += &ev(r#"{"name":"tile","cat":"stage","ph":"X","pid":1,"tid":2,"ts":10,"dur":20,"args":{"id":0}}"#);
        t += &ev(r#"{"name":"tile","cat":"stage","ph":"X","pid":1,"tid":2,"ts":20,"dur":20,"args":{"id":1}}"#);
        assert!(trace_errors(&t).iter().any(|e| e.contains("partially overlaps")));
        // contained and back-to-back spans are fine
        let mut ok = String::from("[\n");
        ok += &ev(r#"{"name":"tile","cat":"stage","ph":"X","pid":1,"tid":2,"ts":10,"dur":20,"args":{"id":0}}"#);
        ok += &ev(r#"{"name":"gemm","cat":"op","ph":"X","pid":1,"tid":2,"ts":12,"dur":18}"#);
        ok += &ev(r#"{"name":"tile","cat":"stage","ph":"X","pid":1,"tid":2,"ts":30,"dur":5,"args":{"id":1}}"#);
        assert!(trace_errors(&ok).is_empty());
    }

    #[test]
    fn request_cat_spans_are_exempt_from_nesting() {
        // queue_wait straddling exec on the same tid must NOT error
        let mut t = String::from("[\n");
        t += &ev(r#"{"name":"exec","cat":"dispatch","ph":"X","pid":1,"tid":1,"ts":20,"dur":30}"#);
        t += &ev(r#"{"name":"queue_wait","cat":"request","ph":"X","pid":1,"tid":1,"ts":25,"dur":40,"args":{"id":7}}"#);
        assert!(trace_errors(&t).is_empty());
    }
}
