//! Minimal JSON codec (this environment has no serde): a recursive
//! descent parser + writer covering exactly the machine-generated JSON
//! exchanged with the python build pipeline (tables, manifests, reports).
//!
//! f64 round-tripping: python emits shortest-round-trip reprs and
//! `str::parse::<f64>` is correctly rounded, so scales survive exactly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the missing path (for required fields).
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    v.write(out, indent, pretty);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        for _ in 0..indent + 1 {
                            out.push(' ');
                        }
                    }
                    write_str(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    for _ in 0..indent {
                        out.push(' ');
                    }
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        // shortest round-trip repr (rust's {} for f64 guarantees this)
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{s}': {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|e| e.to_string())?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("bad array sep {other:?} at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("bad object sep {other:?} at {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"alpha":-1000,"entries":[-4,0,3],"scale":0.0078125,"name":"t"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn f64_shortest_roundtrip() {
        for x in [0.1, 1.0 / 3.0, 0.0078125, 2.5e-8, 1e300] {
            let s = Json::Num(x).to_string_compact();
            assert_eq!(Json::parse(&s).unwrap().as_f64().unwrap(), x, "{s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }
}
