//! Fixed-point / Power-of-Two quantization primitives (paper Sec. 2.1,
//! Eq. 4) — the rust-side mirror of `python/compile/quantize.py`, used by
//! the resource models and the report generators.



/// Affine quantizer: `real = (q - zero_point) * scale`, `q in [qmin, qmax]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    pub scale: f64,
    pub zero_point: i64,
    pub bits: u32,
    pub signed: bool,
}

impl QuantParams {
    pub fn symmetric(amax: f64, bits: u32) -> Self {
        let qmax = ((1i64 << (bits - 1)) - 1) as f64;
        Self { scale: amax.max(1e-8) / qmax, zero_point: 0, bits, signed: true }
    }

    pub fn qmin(&self) -> i64 {
        if self.signed { -(1i64 << (self.bits - 1)) } else { 0 }
    }

    pub fn qmax(&self) -> i64 {
        if self.signed { (1i64 << (self.bits - 1)) - 1 } else { (1i64 << self.bits) - 1 }
    }

    /// ReQuant (Eq. 4): round-half-away, clamp.
    pub fn quantize(&self, x: f64) -> i64 {
        let q = (x / self.scale).round() as i64 + self.zero_point;
        q.max(self.qmin()).min(self.qmax())
    }

    pub fn dequantize(&self, q: i64) -> f64 {
        (q - self.zero_point) as f64 * self.scale
    }
}

/// Nearest power-of-two estimate of a scaling factor (PoT quantization,
/// Sec. 4.4.2 — ceiling variant so indices never overflow).
pub fn pot_ceil(x: f64) -> f64 {
    2f64.powi(x.log2().ceil() as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric_covers_range() {
        let q = QuantParams::symmetric(1.0, 4);
        assert_eq!(q.quantize(1.0), 7);
        assert_eq!(q.quantize(-1.0), -7);
        assert_eq!(q.quantize(100.0), 7); // clamp
        assert_eq!(q.qmin(), -8);
    }

    #[test]
    fn quantize_dequantize_within_half_lsb() {
        let q = QuantParams::symmetric(2.0, 8);
        for x in [-1.9, -0.3, 0.0, 0.7, 1.99] {
            let r = q.dequantize(q.quantize(x));
            assert!((r - x).abs() <= q.scale / 2.0 + 1e-12);
        }
    }

    #[test]
    fn pot_ceil_is_upper_power_of_two() {
        assert_eq!(pot_ceil(3.0), 4.0);
        assert_eq!(pot_ceil(4.0), 4.0);
        assert_eq!(pot_ceil(0.3), 0.5);
    }
}
