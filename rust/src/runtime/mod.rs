//! Pluggable execution backends for the AOT-quantized ViT.
//!
//! The serving stack ([`crate::coordinator`]) is generic over *how* a
//! model executes; this module defines the contract and the two engines:
//!
//! * [`interpreter`] — the default: a pure-rust integer interpreter that
//!   runs the quantized dataflow directly from a weight/LUT *bundle*
//!   (`python -m compile.export`), bit-exact with the python reference
//!   (`python/compile/kernels/ref.py` semantics). No native deps, no
//!   `make artifacts` prerequisite beyond the bundle JSON.
//! * [`fabric`] — the interpreter's compute layer: a persistent pool of
//!   parked worker threads (batch-lane and token-row grains, created
//!   once per loaded model), a per-lane scratch arena, and the
//!   panel-packed integer GEMM with its register-blocked microkernel.
//!   Bit-exactness-preserving.
//! * [`kernels`] — the runtime-dispatched SIMD kernel layer: every hot
//!   inner loop (GEMM axpy, requant LUT application, softmax, LayerNorm)
//!   behind one [`kernels::Kernels`] fn-pointer vtable with `scalar`,
//!   `avx2` and `neon` backends, selected **once at model load** and
//!   threaded through both execution modes. All backends are bit-exact;
//!   the scalar table is the differential-testing oracle.
//! * [`pipeline`] — the hybrid-grained **spatial** executor
//!   ([`ExecMode::Pipeline`]): the model unrolled into resident stages,
//!   each pinned to its own persistent worker with stage-resident
//!   scratch, connected by bounded SPSC queues carrying activation
//!   tiles. Coarse grain across stages, fine token-row grain inside
//!   them; bit-identical logits at every stage count.
//! * [`pjrt`] (feature `pjrt`) — the XLA path: load `artifacts/*.hlo.txt`
//!   emitted by `python/compile/aot.py` onto a PJRT CPU client. Interchange
//!   is HLO **text** — jax >= 0.5 emits protos with 64-bit instruction ids
//!   that xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!   Default builds never see the `xla` crate.
//!
//! Both backends expose batch-variant [`Executor`]s behind one trait, so
//! the dynamic batcher and the metrics pipeline are backend-agnostic.

pub mod fabric;
pub mod interpreter;
pub mod kernels;
pub mod pipeline;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::artifacts::Manifest;

/// Cumulative execution statistics for one executor.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub executions: u64,
    pub total_ms: f64,
}

/// Which execution engine runs the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Pure-rust integer interpreter over a weight/LUT bundle.
    #[default]
    Interpreter,
    /// PJRT CPU client executing AOT-compiled HLO text.
    #[cfg(feature = "pjrt")]
    Pjrt,
    /// Test-only: loads instantly, every execution fails. Drives the
    /// coordinator's error-reply path in integration tests; not
    /// reachable from [`BackendKind::parse`].
    #[doc(hidden)]
    Faulty,
}

/// How the interpreter backend executes a model: temporally (the
/// lane-parallel fabric, every lane on the same layer) or spatially
/// (the hybrid-grained [`pipeline`] of resident stages connected by
/// bounded queues). Both are bit-exact against the golden fixture.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Resolve from the `HGPIPE_MODE` env var (read-only fallback:
    /// `pipeline` or `lane-parallel`/`lanes`), defaulting to
    /// [`ExecMode::LaneParallel`] when unset. Mirrors the
    /// `lanes: None` → `HGPIPE_LANES` precedence.
    #[default]
    Auto,
    /// Temporal execution on the persistent worker fabric (batch-lane
    /// or token-row grains).
    LaneParallel,
    /// Spatial execution: resident transformer stages with bounded
    /// inter-stage queues ([`pipeline`]).
    Pipeline {
        /// Resident stage count; `0` = auto (fully unrolled: a
        /// dedicated patch-embed stage plus one stage per block,
        /// clamped to `depth + 1`).
        stages: usize,
        /// Bounded inter-stage FIFO depth in tiles (min 1).
        queue_depth: usize,
    },
}

impl ExecMode {
    /// The mode `Auto` resolves to: `HGPIPE_MODE` (read-only — the CLI's
    /// `--pipeline` is threaded through [`RuntimeConfig`] instead of
    /// mutating the environment), defaulting to lane-parallel. An
    /// unrecognized value warns on stderr rather than silently changing
    /// the execution architecture.
    pub fn from_env() -> Self {
        match std::env::var("HGPIPE_MODE") {
            Ok(v) => match v.trim() {
                "pipeline" => {
                    Self::Pipeline { stages: 0, queue_depth: pipeline::DEFAULT_QUEUE_DEPTH }
                }
                "lanes" | "lane-parallel" => Self::LaneParallel,
                other => {
                    eprintln!(
                        "warning: HGPIPE_MODE='{other}' is not a mode \
                         (pipeline | lane-parallel); using lane-parallel"
                    );
                    Self::LaneParallel
                }
            },
            Err(_) => Self::LaneParallel,
        }
    }

    /// Resolve `Auto` through the environment; explicit modes pass
    /// through unchanged.
    pub fn resolve(self) -> Self {
        match self {
            Self::Auto => Self::from_env(),
            other => other,
        }
    }
}

/// How to run a model: which engine, how wide its fabric is, and
/// whether execution is temporal (lane-parallel) or spatial (pipeline).
///
/// The `--lanes` CLI flag travels here explicitly — mutating
/// `HGPIPE_LANES` from the binary was unsound once threads existed
/// (`set_var` races every concurrent `getenv`), so the env var is now a
/// read-only *fallback* consulted only when `lanes` is `None`
/// (see [`fabric::LanePool::from_env`]). `--pipeline` travels the same
/// way via [`ExecMode`], with `HGPIPE_MODE` as its read-only fallback.
#[derive(Debug, Clone, Copy, Default)]
pub struct RuntimeConfig {
    pub backend: BackendKind,
    /// Explicit fabric lane count. `None` defers to `HGPIPE_LANES`,
    /// then to the machine's available parallelism. In pipeline mode
    /// this is the total fine-grained budget split across stages.
    pub lanes: Option<usize>,
    /// Temporal vs spatial execution (interpreter backend only).
    pub mode: ExecMode,
    /// Executor replicas per model: how many executor threads the
    /// coordinator runs for one model, each owning its **own** fabric
    /// (lane-parallel mode) or its own resident pipeline (pipeline
    /// mode — the pipeline feeder is SPSC, so replication happens at
    /// the pipeline boundary, not inside it), all pulling from one
    /// shared front queue. `None` defers to `HGPIPE_REPLICAS`, then 1.
    pub replicas: Option<usize>,
    /// Explicit kernel-backend preference (`--kernels`). `None` defers
    /// to the `HGPIPE_KERNELS` read-only env fallback, then to CPU
    /// feature auto-detection (see [`kernels::from_env`]). An explicit
    /// preference that names a backend this host cannot run is a load
    /// **error**, never a silent downgrade.
    pub kernels: Option<kernels::KernelPref>,
    /// Bounded admission for the serving front queue (`--queue-cap`):
    /// at this many queued requests, submits are rejected with a typed
    /// `Overloaded` error instead of queueing without limit. `None`
    /// defers to the `HGPIPE_QUEUE_CAP` read-only env fallback, then
    /// unbounded (the pre-fault-tolerance behavior).
    pub queue_capacity: Option<usize>,
    /// Deterministic fault-injection plan (`--faults`). `None` defers
    /// to the `HGPIPE_FAULTS` read-only env fallback, then no
    /// injection — the serving hot path carries no injector at all.
    pub faults: Option<crate::coordinator::faults::FaultPlan>,
    /// Trace output path (`--trace out.jsonl`): when set, the serving
    /// stack records a Chrome-trace span tree per request (see
    /// [`crate::telemetry`]). `None` defers to the `HGPIPE_TRACE`
    /// read-only env fallback, then tracing stays off (the hot path
    /// pays one branch). `Some("")` explicitly disables. A `&'static`
    /// so the config stays `Copy`; the CLI leaks its one flag string.
    pub trace: Option<&'static str>,
}

impl RuntimeConfig {
    pub fn new(backend: BackendKind) -> Self {
        Self {
            backend,
            lanes: None,
            mode: ExecMode::Auto,
            replicas: None,
            kernels: None,
            queue_capacity: None,
            faults: None,
            trace: None,
        }
    }

    /// Set (or clear) the explicit lane count.
    pub fn with_lanes(mut self, lanes: Option<usize>) -> Self {
        self.lanes = lanes;
        self
    }

    /// Set the execution mode explicitly (beats `HGPIPE_MODE`).
    pub fn with_mode(mut self, mode: ExecMode) -> Self {
        self.mode = mode;
        self
    }

    /// Set (or clear) the explicit executor replica count (beats
    /// `HGPIPE_REPLICAS`). A value of 0 clamps to 1 at resolution.
    pub fn with_replicas(mut self, replicas: Option<usize>) -> Self {
        self.replicas = replicas;
        self
    }

    /// The executor replica count this config resolves to: the explicit
    /// value wins, else the `HGPIPE_REPLICAS` env fallback, else 1.
    /// Always at least 1.
    pub fn resolve_replicas(&self) -> usize {
        self.replicas.unwrap_or_else(Self::replicas_from_env).max(1)
    }

    /// Set (or clear) the explicit kernel-backend preference (beats
    /// `HGPIPE_KERNELS`).
    pub fn with_kernels(mut self, kernels: Option<kernels::KernelPref>) -> Self {
        self.kernels = kernels;
        self
    }

    /// The kernel backend this config resolves to: an explicit
    /// preference must be satisfiable (an unavailable backend is an
    /// error), else the `HGPIPE_KERNELS` env fallback / auto-detection
    /// via [`kernels::from_env`].
    pub fn resolve_kernels(&self) -> crate::Result<&'static kernels::Kernels> {
        match self.kernels {
            Some(pref) => kernels::select(pref),
            None => Ok(kernels::from_env()),
        }
    }

    /// Set (or clear) the explicit front-queue admission bound (beats
    /// `HGPIPE_QUEUE_CAP`). A value of 0 means unbounded.
    pub fn with_queue_capacity(mut self, capacity: Option<usize>) -> Self {
        self.queue_capacity = capacity;
        self
    }

    /// The front-queue bound this config resolves to: the explicit
    /// value wins, else the `HGPIPE_QUEUE_CAP` env fallback, else
    /// unbounded. A 0 from either source means unbounded.
    pub fn resolve_queue_capacity(&self) -> Option<usize> {
        self.queue_capacity
            .or_else(Self::queue_capacity_from_env)
            .filter(|&cap| cap > 0)
    }

    /// Set (or clear) the explicit fault-injection plan (beats
    /// `HGPIPE_FAULTS`).
    pub fn with_faults(mut self, faults: Option<crate::coordinator::faults::FaultPlan>) -> Self {
        self.faults = faults;
        self
    }

    /// The fault plan this config resolves to: the explicit plan wins,
    /// else the `HGPIPE_FAULTS` env fallback, else none. A plan whose
    /// rates are all zero resolves to none, keeping the serving hot
    /// path injector-free.
    pub fn resolve_faults(&self) -> Option<crate::coordinator::faults::FaultPlan> {
        self.faults
            .or_else(crate::coordinator::faults::FaultPlan::from_env)
            .filter(|p| !p.is_off())
    }

    /// Set (or clear) the explicit trace output path (beats
    /// `HGPIPE_TRACE`). `Some("")` disables tracing outright.
    pub fn with_trace(mut self, trace: Option<&'static str>) -> Self {
        self.trace = trace;
        self
    }

    /// The trace path this config resolves to: the explicit path wins
    /// (empty = explicitly off), else the `HGPIPE_TRACE` env fallback,
    /// else none (tracing off).
    pub fn resolve_trace(&self) -> Option<String> {
        match self.trace {
            Some(p) if !p.is_empty() => Some(p.to_string()),
            Some(_) => None,
            None => Self::trace_from_env(),
        }
    }

    /// The `HGPIPE_TRACE` read-only env fallback (mirrors the other
    /// `HGPIPE_*` vars: nothing in this crate mutates it). Unset or
    /// empty means tracing stays off.
    pub fn trace_from_env() -> Option<String> {
        std::env::var("HGPIPE_TRACE").ok().filter(|v| !v.trim().is_empty())
    }

    /// The `HGPIPE_QUEUE_CAP` read-only env fallback (mirrors the other
    /// `HGPIPE_*` vars: nothing in this crate mutates it). Unset means
    /// unbounded admission; an unparseable value warns rather than
    /// silently shedding load.
    pub fn queue_capacity_from_env() -> Option<usize> {
        match std::env::var("HGPIPE_QUEUE_CAP") {
            Ok(v) => match v.trim().parse::<usize>() {
                Ok(n) => Some(n),
                Err(_) => {
                    eprintln!(
                        "warning: HGPIPE_QUEUE_CAP='{v}' is not a queue capacity; \
                         leaving the queue unbounded"
                    );
                    None
                }
            },
            Err(_) => None,
        }
    }

    /// The `HGPIPE_REPLICAS` read-only env fallback (mirrors
    /// `HGPIPE_LANES` / `HGPIPE_MODE`: nothing in this crate mutates
    /// it). Unset means 1 executor per model — the pre-scale-out
    /// layout; an unparseable value warns rather than silently changing
    /// the serving topology.
    pub fn replicas_from_env() -> usize {
        match std::env::var("HGPIPE_REPLICAS") {
            Ok(v) => match v.trim().parse::<usize>() {
                Ok(n) => n.max(1),
                Err(_) => {
                    eprintln!(
                        "warning: HGPIPE_REPLICAS='{v}' is not a replica count; using 1"
                    );
                    1
                }
            },
            Err(_) => 1,
        }
    }
}

impl From<BackendKind> for RuntimeConfig {
    fn from(backend: BackendKind) -> Self {
        Self::new(backend)
    }
}

impl BackendKind {
    /// Parse a CLI flag value. Naming `pjrt` without the feature is a
    /// distinct, actionable error.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "interpreter" | "int" => Ok(Self::Interpreter),
            #[cfg(feature = "pjrt")]
            "pjrt" => Ok(Self::Pjrt),
            #[cfg(not(feature = "pjrt"))]
            "pjrt" => anyhow::bail!(
                "backend 'pjrt' is not compiled in — rebuild with `--features pjrt`"
            ),
            other => anyhow::bail!("unknown backend '{other}' (interpreter | pjrt)"),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Self::Interpreter => "interpreter",
            #[cfg(feature = "pjrt")]
            Self::Pjrt => "pjrt",
            Self::Faulty => "faulty",
        }
    }
}

/// A ready-to-run batch variant of a model: float tokens in, float
/// logits out, shapes fixed at load time.
///
/// Deliberately NOT `Send`: the PJRT client's handles are `Rc`-based, so
/// the owning thread (the coordinator's executor thread) constructs and
/// drives its executors locally — which also mirrors the hardware: one
/// fabric, one feeder.
pub trait Executor {
    /// Batch size this variant was compiled/configured for.
    fn batch(&self) -> usize;
    /// Run on a flat f32 input of `batch * tokens_per_image` values;
    /// returns `batch * num_classes` logits.
    fn run_f32(&self, input: &[f32]) -> crate::Result<Vec<f32>>;
    /// One-time load/compile cost attributed to this variant.
    fn compile_ms(&self) -> f64;
    fn stats(&self) -> ExecStats;
    /// Pipeline-mode executors expose their resident stages' cumulative
    /// occupancy and stall counters so the coordinator can fold them
    /// into `ServeMetrics`; every other executor reports `None`.
    fn pipeline_stats(&self) -> Option<pipeline::PipelineStats> {
        None
    }
    /// Drain the per-op kernel profile accumulated since the last call
    /// — `Some` only for executors built with telemetry profiling on.
    fn take_op_profile(&self) -> Option<interpreter::OpProfile> {
        None
    }
}

/// A loaded model: all batch-variant executors plus shape metadata.
///
/// This is the **mutable half** of a model's lifetime: executors own the
/// per-replica runtime (the persistent fabric or resident pipeline and
/// its scratch arenas). The **immutable half** — weights, packed GEMM
/// panels, LUT tables — lives in a [`ModelArtifact`] that any number of
/// `LoadedModel`s can share.
pub struct LoadedModel {
    pub executors: Vec<Box<dyn Executor>>,
    pub tokens_per_image: usize,
    pub num_classes: usize,
    /// Total load/compile time across variants (the "bitstream load").
    pub compile_ms: f64,
}

/// The immutable half of a loaded model: the quantized network bundle
/// ([`interpreter::QuantViT`] — weights re-packed into blocked GEMM
/// panels plus every requant/non-linear LUT) behind one `Arc`, with the
/// manifest's batch variants and the one-time load cost.
///
/// Loading is the expensive, read-only part of a model's lifetime
/// (parse the bundle JSON, pack the panels) — so it happens **once per
/// model**, and every executor replica built from the artifact borrows
/// the same allocation: N replicas hold N scratch arenas but exactly
/// one copy of the weight panels (ME-ViT's single-load-weights argument
/// in software). `Clone` is an `Arc` bump; the weights are freed when
/// the last holder — replica or caller — drops its handle.
///
/// Interpreter-backend only: PJRT handles are `Rc`-based and not
/// `Send`, so that backend keeps its per-thread load path.
#[derive(Clone)]
pub struct ModelArtifact {
    net: std::sync::Arc<interpreter::QuantViT>,
    batches: Vec<usize>,
    load_ms: f64,
}

impl ModelArtifact {
    /// Load and validate `model`'s bundle once. The returned artifact is
    /// the only copy of the weights however many replicas it later
    /// feeds.
    pub fn load(manifest: &Manifest, model: &str) -> crate::Result<Self> {
        let (net, batches, load_ms) = interpreter::load_bundle(manifest, model)?;
        Ok(Self { net, batches, load_ms })
    }

    /// The shared network. Cloning the `Arc` (not the network) is how
    /// executors join the sharing.
    pub fn net(&self) -> &std::sync::Arc<interpreter::QuantViT> {
        &self.net
    }

    /// Batch variants the dynamic batcher may dispatch.
    pub fn batches(&self) -> &[usize] {
        &self.batches
    }

    /// One-time bundle parse + panel-pack cost.
    pub fn load_ms(&self) -> f64 {
        self.load_ms
    }

    pub fn tokens_per_image(&self) -> usize {
        self.net.tokens_per_image()
    }

    pub fn num_classes(&self) -> usize {
        self.net.num_classes
    }

    /// Resident bytes of the immutable model (panels + LUTs + head).
    /// Fleet memory for N sharing replicas is this value once, not N
    /// times — the bench `memory` section and the scale-out tests pin
    /// that.
    pub fn footprint_bytes(&self) -> usize {
        self.net.footprint_bytes()
    }

    /// How many handles currently share the weights (1 = this one).
    /// Tests use this to prove replicas share (count grows with the
    /// fleet) and that unload frees (count returns to 1).
    pub fn strong_count(&self) -> usize {
        std::sync::Arc::strong_count(&self.net)
    }

    /// Whether two artifacts are views of the same weight allocation.
    pub fn shares_weights_with(&self, other: &ModelArtifact) -> bool {
        std::sync::Arc::ptr_eq(&self.net, &other.net)
    }
}

/// Build a model's batch-variant executors from an already-loaded
/// shared [`ModelArtifact`] — the replica-side half of
/// [`load_model`]: only the mutable runtime (fabric lanes or resident
/// pipeline stages, scratch arenas) is created here; the weights are
/// borrowed from the artifact. Interpreter-backend configs only.
pub fn load_model_from_artifact(
    cfg: RuntimeConfig,
    artifact: &ModelArtifact,
) -> crate::Result<LoadedModel> {
    load_model_from_artifact_traced(cfg, artifact, &crate::telemetry::Telemetry::off())
}

/// [`load_model_from_artifact`] with a telemetry handle: pipeline
/// stages get their own trace buffers/tids, lane-parallel executors
/// get per-op profiling. An off handle builds exactly what
/// [`load_model_from_artifact`] builds.
pub fn load_model_from_artifact_traced(
    cfg: RuntimeConfig,
    artifact: &ModelArtifact,
    tele: &crate::telemetry::Telemetry,
) -> crate::Result<LoadedModel> {
    anyhow::ensure!(
        matches!(cfg.backend, BackendKind::Interpreter),
        "shared model artifacts require the interpreter backend (got '{}')",
        cfg.backend.label()
    );
    let lanes = cfg.lanes.unwrap_or_else(fabric::LanePool::lanes_from_env);
    // resolve the kernel backend ONCE per load; every replica fabric and
    // every resident pipeline stage built below inherits this vtable
    let kern = cfg.resolve_kernels()?;
    match cfg.mode.resolve() {
        ExecMode::Pipeline { stages, queue_depth } => Ok(pipeline::executors_from_artifact_traced(
            artifact,
            lanes,
            stages,
            queue_depth,
            kern,
            tele,
        )),
        _ => Ok(interpreter::executors_from_artifact_profiled(
            artifact,
            lanes,
            kern,
            tele.enabled(),
        )),
    }
}

/// Load a model's batch variants on the configured backend. An explicit
/// `cfg.lanes` wins; otherwise the interpreter falls back to
/// `HGPIPE_LANES` / available parallelism. Likewise `cfg.mode`:
/// explicit beats the `HGPIPE_MODE` env fallback.
pub fn load_model(
    cfg: RuntimeConfig,
    manifest: &Manifest,
    model: &str,
) -> crate::Result<LoadedModel> {
    // an EXPLICITLY requested pipeline mode on a backend that cannot
    // execute it must be a load error, not a silent downgrade to the
    // temporal path (the read-only HGPIPE_MODE fallback, by contrast,
    // only ever applies to the interpreter backend)
    if !matches!(cfg.backend, BackendKind::Interpreter) {
        anyhow::ensure!(
            !matches!(cfg.mode, ExecMode::Pipeline { .. }),
            "pipeline mode requires the interpreter backend (got '{}')",
            cfg.backend.label()
        );
    }
    match cfg.backend {
        BackendKind::Interpreter => {
            // the standalone path is the shared path with a fleet of
            // one: load the immutable artifact, build executors from it
            let artifact = ModelArtifact::load(manifest, model)?;
            load_model_from_artifact(cfg, &artifact)
        }
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => pjrt::load_model(manifest, model),
        BackendKind::Faulty => Ok(faulty::load_model()),
    }
}

/// Test-only backend whose executors always fail at run time — the only
/// way to exercise the coordinator's dispatch-error reply path from an
/// integration test (the interpreter cannot fail on length-validated
/// input).
#[doc(hidden)]
pub mod faulty {
    use super::{ExecStats, Executor, LoadedModel};

    pub const TOKENS_PER_IMAGE: usize = 4;
    pub const NUM_CLASSES: usize = 2;

    struct FaultyExecutor {
        batch: usize,
    }

    impl Executor for FaultyExecutor {
        fn batch(&self) -> usize {
            self.batch
        }

        fn run_f32(&self, _input: &[f32]) -> crate::Result<Vec<f32>> {
            anyhow::bail!("injected fabric fault")
        }

        fn compile_ms(&self) -> f64 {
            0.0
        }

        fn stats(&self) -> ExecStats {
            ExecStats::default()
        }
    }

    pub fn load_model() -> LoadedModel {
        LoadedModel {
            executors: vec![Box::new(FaultyExecutor { batch: 1 })],
            tokens_per_image: TOKENS_PER_IMAGE,
            num_classes: NUM_CLASSES,
            compile_ms: 0.0,
        }
    }
}
