//! Pluggable execution backends for the AOT-quantized ViT.
//!
//! The serving stack ([`crate::coordinator`]) is generic over *how* a
//! model executes; this module defines the contract and the two engines:
//!
//! * [`interpreter`] — the default: a pure-rust integer interpreter that
//!   runs the quantized dataflow directly from a weight/LUT *bundle*
//!   (`python -m compile.export`), bit-exact with the python reference
//!   (`python/compile/kernels/ref.py` semantics). No native deps, no
//!   `make artifacts` prerequisite beyond the bundle JSON.
//! * [`fabric`] — the interpreter's compute layer: a persistent pool of
//!   parked worker threads (batch-lane and token-row grains, created
//!   once per loaded model), a per-lane scratch arena, and the
//!   panel-packed integer GEMM with its register-blocked microkernel.
//!   Bit-exactness-preserving.
//! * [`pjrt`] (feature `pjrt`) — the XLA path: load `artifacts/*.hlo.txt`
//!   emitted by `python/compile/aot.py` onto a PJRT CPU client. Interchange
//!   is HLO **text** — jax >= 0.5 emits protos with 64-bit instruction ids
//!   that xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!   Default builds never see the `xla` crate.
//!
//! Both backends expose batch-variant [`Executor`]s behind one trait, so
//! the dynamic batcher and the metrics pipeline are backend-agnostic.

pub mod fabric;
pub mod interpreter;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::artifacts::Manifest;

/// Cumulative execution statistics for one executor.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub executions: u64,
    pub total_ms: f64,
}

/// Which execution engine runs the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Pure-rust integer interpreter over a weight/LUT bundle.
    #[default]
    Interpreter,
    /// PJRT CPU client executing AOT-compiled HLO text.
    #[cfg(feature = "pjrt")]
    Pjrt,
    /// Test-only: loads instantly, every execution fails. Drives the
    /// coordinator's error-reply path in integration tests; not
    /// reachable from [`BackendKind::parse`].
    #[doc(hidden)]
    Faulty,
}

/// How to run a model: which engine, and how wide its fabric is.
///
/// The `--lanes` CLI flag travels here explicitly — mutating
/// `HGPIPE_LANES` from the binary was unsound once threads existed
/// (`set_var` races every concurrent `getenv`), so the env var is now a
/// read-only *fallback* consulted only when `lanes` is `None`
/// (see [`fabric::LanePool::from_env`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct RuntimeConfig {
    pub backend: BackendKind,
    /// Explicit fabric lane count. `None` defers to `HGPIPE_LANES`,
    /// then to the machine's available parallelism.
    pub lanes: Option<usize>,
}

impl RuntimeConfig {
    pub fn new(backend: BackendKind) -> Self {
        Self { backend, lanes: None }
    }

    /// Set (or clear) the explicit lane count.
    pub fn with_lanes(mut self, lanes: Option<usize>) -> Self {
        self.lanes = lanes;
        self
    }
}

impl From<BackendKind> for RuntimeConfig {
    fn from(backend: BackendKind) -> Self {
        Self::new(backend)
    }
}

impl BackendKind {
    /// Parse a CLI flag value. Naming `pjrt` without the feature is a
    /// distinct, actionable error.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "interpreter" | "int" => Ok(Self::Interpreter),
            #[cfg(feature = "pjrt")]
            "pjrt" => Ok(Self::Pjrt),
            #[cfg(not(feature = "pjrt"))]
            "pjrt" => anyhow::bail!(
                "backend 'pjrt' is not compiled in — rebuild with `--features pjrt`"
            ),
            other => anyhow::bail!("unknown backend '{other}' (interpreter | pjrt)"),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Self::Interpreter => "interpreter",
            #[cfg(feature = "pjrt")]
            Self::Pjrt => "pjrt",
            Self::Faulty => "faulty",
        }
    }
}

/// A ready-to-run batch variant of a model: float tokens in, float
/// logits out, shapes fixed at load time.
///
/// Deliberately NOT `Send`: the PJRT client's handles are `Rc`-based, so
/// the owning thread (the coordinator's executor thread) constructs and
/// drives its executors locally — which also mirrors the hardware: one
/// fabric, one feeder.
pub trait Executor {
    /// Batch size this variant was compiled/configured for.
    fn batch(&self) -> usize;
    /// Run on a flat f32 input of `batch * tokens_per_image` values;
    /// returns `batch * num_classes` logits.
    fn run_f32(&self, input: &[f32]) -> crate::Result<Vec<f32>>;
    /// One-time load/compile cost attributed to this variant.
    fn compile_ms(&self) -> f64;
    fn stats(&self) -> ExecStats;
}

/// A loaded model: all batch-variant executors plus shape metadata.
pub struct LoadedModel {
    pub executors: Vec<Box<dyn Executor>>,
    pub tokens_per_image: usize,
    pub num_classes: usize,
    /// Total load/compile time across variants (the "bitstream load").
    pub compile_ms: f64,
}

/// Load a model's batch variants on the configured backend. An explicit
/// `cfg.lanes` wins; otherwise the interpreter falls back to
/// `HGPIPE_LANES` / available parallelism.
pub fn load_model(
    cfg: RuntimeConfig,
    manifest: &Manifest,
    model: &str,
) -> crate::Result<LoadedModel> {
    match cfg.backend {
        BackendKind::Interpreter => match cfg.lanes {
            Some(n) => interpreter::load_model_with_lanes(manifest, model, n),
            None => interpreter::load_model(manifest, model),
        },
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => pjrt::load_model(manifest, model),
        BackendKind::Faulty => Ok(faulty::load_model()),
    }
}

/// Test-only backend whose executors always fail at run time — the only
/// way to exercise the coordinator's dispatch-error reply path from an
/// integration test (the interpreter cannot fail on length-validated
/// input).
#[doc(hidden)]
pub mod faulty {
    use super::{ExecStats, Executor, LoadedModel};

    pub const TOKENS_PER_IMAGE: usize = 4;
    pub const NUM_CLASSES: usize = 2;

    struct FaultyExecutor {
        batch: usize,
    }

    impl Executor for FaultyExecutor {
        fn batch(&self) -> usize {
            self.batch
        }

        fn run_f32(&self, _input: &[f32]) -> crate::Result<Vec<f32>> {
            anyhow::bail!("injected fabric fault")
        }

        fn compile_ms(&self) -> f64 {
            0.0
        }

        fn stats(&self) -> ExecStats {
            ExecStats::default()
        }
    }

    pub fn load_model() -> LoadedModel {
        LoadedModel {
            executors: vec![Box::new(FaultyExecutor { batch: 1 })],
            tokens_per_image: TOKENS_PER_IMAGE,
            num_classes: NUM_CLASSES,
            compile_ms: 0.0,
        }
    }
}
