//! Pure-rust interpreter backend: execute the quantized ViT directly
//! from its weight/LUT *bundle* (`python -m compile.export`).
//!
//! This is the default execution engine — no XLA, no HLO, no native
//! libraries. It mirrors, **bit-exactly**, the integer semantics of
//! `python/compile/kernels/ref.py` / `model.LutExec` (the accelerator's
//! canonical dataflow): i64 output-stationary matmul accumulation,
//! PoT-indexed LUT non-linears, three-pass integer LayerNorm, inverted-Exp
//! + segmented-Recip Softmax. Where the numpy reference narrows to int32
//! (`LutExec._i32`: every LUT input, attention scores, the residual
//! stream), this interpreter performs the same wrapping cast, so even
//! out-of-range corner cases agree with the python oracle; the golden
//! fixture in `rust/artifacts/` pins that equality logit-for-logit.
//!
//! Throughput is modest (a few images/s on the tiny-synth model in debug
//! builds) — the point is a dependency-free, provably-correct serving
//! path; the PJRT backend and future native kernels are the fast paths.

use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::artifacts::{BundleInfo, Manifest};
use crate::lut::{AnyTable, LutTable, SegmentedTable};
use crate::runtime::{ExecStats, Executor, LoadedModel};
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// integer LUT application — the rust twin of model.LutExec._lut / _seg
// ---------------------------------------------------------------------------

/// `LutExec._lut`: int32-domain PoT-indexed lookup.
#[inline]
fn lut_i32(t: &LutTable, x: i32) -> i32 {
    let alpha = t.alpha as i32;
    let diff = if t.inverted { alpha.wrapping_sub(x) } else { x.wrapping_sub(alpha) };
    let raw = diff >> t.shift;
    let hi = (1i32 << t.n_bits) - 1;
    t.entries[raw.clamp(0, hi) as usize] as i32
}

/// `LutExec._seg`: segmented lookup in the common (flat) output scale.
#[inline]
fn seg_i32(s: &SegmentedTable, x: i32) -> i32 {
    if x < s.pivot as i32 {
        lut_i32(&s.steep, x).wrapping_shl(s.ratio_log2())
    } else {
        lut_i32(&s.flat, x)
    }
}

#[inline]
fn any_i32(t: &AnyTable, x: i32) -> i32 {
    match t {
        AnyTable::Lut(l) => lut_i32(l, x),
        AnyTable::Segmented(s) => seg_i32(s, x),
    }
}

// ---------------------------------------------------------------------------
// the model bundle
// ---------------------------------------------------------------------------

/// One encoder block's integer parameters + tables.
struct BlockParams {
    qkv_w: Vec<i32>,
    qkv_b: Vec<i64>,
    proj_w: Vec<i32>,
    proj_b: Vec<i64>,
    mm1_w: Vec<i32>,
    mm1_b: Vec<i64>,
    mm2_w: Vec<i32>,
    mm2_b: Vec<i64>,
    ln1_guard: u32,
    ln2_guard: u32,
    ln1_rsqrt: LutTable,
    ln1_rq: LutTable,
    qkv_rq: LutTable,
    exp: LutTable,
    recip: AnyTable,
    prob: LutTable,
    rv_rq: LutTable,
    proj_rq: LutTable,
    ln2_rsqrt: LutTable,
    ln2_rq: LutTable,
    gelu: LutTable,
    mm2_rq: LutTable,
}

/// A fully-loaded quantized ViT, ready to execute.
pub struct QuantViT {
    pub model: String,
    pub precision: String,
    pub tokens: usize,
    pub patch_dim: usize,
    pub dim: usize,
    pub depth: usize,
    pub heads: usize,
    pub hidden: usize,
    pub num_classes: usize,
    in_scale: f64,
    in_qmin: i64,
    in_qmax: i64,
    logit_scale: f64,
    /// Head bias: float32 values widened to f64 (numpy adds them in f64).
    head_bias: Vec<f64>,
    pe_w: Vec<i32>,
    pe_b: Vec<i64>,
    pe_rq: LutTable,
    blocks: Vec<BlockParams>,
    ln_f_guard: u32,
    ln_f_rsqrt: LutTable,
    ln_f_rq: LutTable,
    head_w: Vec<i32>,
}

fn ints_i32(v: &Json, key: &str, expect: usize) -> crate::Result<Vec<i32>> {
    let arr = v
        .req(key)
        .map_err(|e| anyhow::anyhow!(e))?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("bundle '{key}' is not an array"))?;
    anyhow::ensure!(arr.len() == expect, "bundle '{key}': {} values, expected {expect}", arr.len());
    arr.iter()
        .map(|x| x.as_i64().map(|v| v as i32).ok_or_else(|| anyhow::anyhow!("bad int in '{key}'")))
        .collect()
}

fn ints_i64(v: &Json, key: &str, expect: usize) -> crate::Result<Vec<i64>> {
    let arr = v
        .req(key)
        .map_err(|e| anyhow::anyhow!(e))?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("bundle '{key}' is not an array"))?;
    anyhow::ensure!(arr.len() == expect, "bundle '{key}': {} values, expected {expect}", arr.len());
    arr.iter()
        .map(|x| x.as_i64().ok_or_else(|| anyhow::anyhow!("bad int in '{key}'")))
        .collect()
}

fn usize_field(v: &Json, key: &str) -> crate::Result<usize> {
    v.req(key)
        .map_err(|e| anyhow::anyhow!(e))?
        .as_i64()
        .map(|x| x as usize)
        .ok_or_else(|| anyhow::anyhow!("bundle '{key}' is not an integer"))
}

impl QuantViT {
    /// Parse a bundle JSON written by `compile/export.py`.
    pub fn load(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("bundle {}: {e}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("bundle parse: {e}"))?;
        let format = v.get("format").and_then(|f| f.as_str()).unwrap_or("?");
        anyhow::ensure!(format == "hgpipe-bundle-v1", "unsupported bundle format '{format}'");

        let cfg = v.req("cfg").map_err(|e| anyhow::anyhow!(e))?;
        let tokens = usize_field(cfg, "tokens")?;
        let patch_dim = usize_field(cfg, "patch_dim")?;
        let dim = usize_field(cfg, "dim")?;
        let depth = usize_field(cfg, "depth")?;
        let heads = usize_field(cfg, "heads")?;
        let hidden = usize_field(cfg, "hidden")?;
        let num_classes = usize_field(cfg, "num_classes")?;
        anyhow::ensure!(heads > 0 && dim % heads == 0, "dim {dim} not divisible by heads {heads}");

        let input = v.req("input").map_err(|e| anyhow::anyhow!(e))?;
        let head = v.req("head").map_err(|e| anyhow::anyhow!(e))?;
        let weights = v.req("weights").map_err(|e| anyhow::anyhow!(e))?;
        let guards = v.req("guards").map_err(|e| anyhow::anyhow!(e))?;
        let luts = v
            .req("luts")
            .map_err(|e| anyhow::anyhow!(e))?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("bundle 'luts' is not an object"))?;

        // validate at load time what lut_i32 will index at run time, so a
        // malformed bundle is a load error, not an executor-thread panic
        fn check(t: &LutTable) -> crate::Result<()> {
            let depth = 1usize << t.n_bits;
            anyhow::ensure!(
                t.entries.len() == depth,
                "lut '{}': {} entries, expected {depth}",
                t.name,
                t.entries.len()
            );
            anyhow::ensure!(t.shift < 32, "lut '{}': shift {} out of i32 range", t.name, t.shift);
            Ok(())
        }
        let table = |name: &str| -> crate::Result<AnyTable> {
            let t = luts.get(name).ok_or_else(|| anyhow::anyhow!("bundle missing lut '{name}'"))?;
            let t = AnyTable::from_json(t).map_err(|e| anyhow::anyhow!("lut '{name}': {e}"))?;
            match &t {
                AnyTable::Lut(l) => check(l)?,
                AnyTable::Segmented(s) => {
                    check(&s.steep)?;
                    check(&s.flat)?;
                }
            }
            Ok(t)
        };
        let plain = |name: &str| -> crate::Result<LutTable> {
            match table(name)? {
                AnyTable::Lut(t) => Ok(t),
                AnyTable::Segmented(_) => anyhow::bail!("lut '{name}': expected plain table"),
            }
        };
        let guard = |name: &str| -> crate::Result<u32> {
            guards
                .get(name)
                .and_then(|g| g.as_i64())
                .map(|g| g as u32)
                .ok_or_else(|| anyhow::anyhow!("bundle missing guard '{name}'"))
        };

        let mut blocks = Vec::with_capacity(depth);
        for i in 0..depth {
            let p = |n: &str| format!("b{i}.{n}");
            blocks.push(BlockParams {
                qkv_w: ints_i32(weights, &p("qkv_w"), dim * 3 * dim)?,
                qkv_b: ints_i64(weights, &p("qkv_b"), 3 * dim)?,
                proj_w: ints_i32(weights, &p("proj_w"), dim * dim)?,
                proj_b: ints_i64(weights, &p("proj_b"), dim)?,
                mm1_w: ints_i32(weights, &p("mm1_w"), dim * hidden)?,
                mm1_b: ints_i64(weights, &p("mm1_b"), hidden)?,
                mm2_w: ints_i32(weights, &p("mm2_w"), hidden * dim)?,
                mm2_b: ints_i64(weights, &p("mm2_b"), dim)?,
                ln1_guard: guard(&p("ln1"))?,
                ln2_guard: guard(&p("ln2"))?,
                ln1_rsqrt: plain(&p("ln1.rsqrt"))?,
                ln1_rq: plain(&p("ln1.rq"))?,
                qkv_rq: plain(&p("qkv"))?,
                exp: plain(&p("attn.exp"))?,
                recip: table(&p("attn.recip"))?,
                prob: plain(&p("attn.prob"))?,
                rv_rq: plain(&p("rv"))?,
                proj_rq: plain(&p("proj"))?,
                ln2_rsqrt: plain(&p("ln2.rsqrt"))?,
                ln2_rq: plain(&p("ln2.rq"))?,
                gelu: plain(&p("gelu"))?,
                mm2_rq: plain(&p("mm2"))?,
            });
        }

        let bias_f64 = head
            .req("bias")
            .map_err(|e| anyhow::anyhow!(e))?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("head bias not an array"))?
            .iter()
            .map(|x| x.as_f64().ok_or_else(|| anyhow::anyhow!("bad head bias")))
            .collect::<crate::Result<Vec<f64>>>()?;
        anyhow::ensure!(bias_f64.len() == num_classes, "head bias length mismatch");

        Ok(Self {
            model: v.get("model").and_then(|m| m.as_str()).unwrap_or("?").to_string(),
            precision: v.get("precision").and_then(|m| m.as_str()).unwrap_or("?").to_string(),
            tokens,
            patch_dim,
            dim,
            depth,
            heads,
            hidden,
            num_classes,
            in_scale: input
                .req("scale")
                .map_err(|e| anyhow::anyhow!(e))?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("input scale"))?,
            in_qmin: input
                .req("qmin")
                .map_err(|e| anyhow::anyhow!(e))?
                .as_i64()
                .ok_or_else(|| anyhow::anyhow!("input qmin"))?,
            in_qmax: input
                .req("qmax")
                .map_err(|e| anyhow::anyhow!(e))?
                .as_i64()
                .ok_or_else(|| anyhow::anyhow!("input qmax"))?,
            logit_scale: head
                .req("logit_scale")
                .map_err(|e| anyhow::anyhow!(e))?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("logit scale"))?,
            head_bias: bias_f64,
            pe_w: ints_i32(weights, "pe_w", patch_dim * dim)?,
            pe_b: ints_i64(weights, "pe_b", dim)?,
            pe_rq: plain("pe")?,
            blocks,
            ln_f_guard: guard("ln_f")?,
            ln_f_rsqrt: plain("ln_f.rsqrt")?,
            ln_f_rq: plain("ln_f.rq")?,
            head_w: ints_i32(weights, "head_w", dim * num_classes)?,
        })
    }

    pub fn tokens_per_image(&self) -> usize {
        self.tokens * self.patch_dim
    }

    /// Input quantization — `QuantParams.quantize` (round half away from
    /// zero, computed in f64 exactly as numpy does over the f32 tokens).
    #[inline]
    fn quantize_in(&self, x: f32) -> i32 {
        let xf = x as f64;
        let q = if xf < 0.0 {
            -((-xf / self.in_scale + 0.5).floor())
        } else {
            (xf / self.in_scale + 0.5).floor()
        };
        (q as i64).clamp(self.in_qmin, self.in_qmax) as i32
    }

    /// Exact i64 output-stationary matmul + bias: `acc = x @ w + b`,
    /// x (t, ci) i32 row-major, w (ci, co) i32 row-major.
    fn matmul_bias(x: &[i32], t: usize, ci: usize, w: &[i32], co: usize, bias: &[i64]) -> Vec<i64> {
        let mut out = vec![0i64; t * co];
        for r in 0..t {
            let orow = &mut out[r * co..(r + 1) * co];
            orow.copy_from_slice(bias);
            for k in 0..ci {
                let xv = x[r * ci + k] as i64;
                if xv != 0 {
                    let wrow = &w[k * co..(k + 1) * co];
                    for (o, &wv) in orow.iter_mut().zip(wrow) {
                        *o += xv * wv as i64;
                    }
                }
            }
        }
        out
    }

    /// Integer LayerNorm (`LutExec.layernorm`): three passes per token.
    fn layernorm(&self, x: &[i32], guard: u32, rsqrt: &LutTable, rq: &LutTable) -> Vec<i32> {
        let d = self.dim;
        let mut out = Vec::with_capacity(x.len());
        let mut c = vec![0i64; d];
        for row in x.chunks_exact(d) {
            let s: i64 = row.iter().map(|&v| v as i64).sum();
            let mut v: i64 = 0;
            for (cj, &xv) in c.iter_mut().zip(row) {
                // numpy: `ci * x` runs in int32 (wrapping) before the
                // int64 subtraction widens it
                *cj = (d as i32).wrapping_mul(xv) as i64 - s;
                let cg = *cj >> guard;
                v += cg * cg;
            }
            let r = lut_i32(rsqrt, v as i32) as i64;
            for &cj in &c {
                out.push(lut_i32(rq, (cj * r) as i32));
            }
        }
        out
    }

    /// Integer Softmax over one score row (`LutExec.softmax`): max-
    /// subtract, inverted Exp LUT, (segmented) Recip, prob ReQuant.
    fn softmax_row(&self, blk: &BlockParams, scores: &[i64], probs: &mut [i32]) {
        let sc: Vec<i32> = scores.iter().map(|&a| a as i32).collect();
        let m = *sc.iter().max().unwrap();
        let mut tot: i64 = 0;
        let mut e = vec![0i32; sc.len()];
        for (ev, &s) in e.iter_mut().zip(&sc) {
            *ev = lut_i32(&blk.exp, s.wrapping_sub(m));
            tot += *ev as i64;
        }
        let r = any_i32(&blk.recip, tot as i32);
        for (p, &ev) in probs.iter_mut().zip(&e) {
            *p = lut_i32(&blk.prob, ev.wrapping_mul(r));
        }
    }

    /// Full integer forward for one image: f32 tokens (T*P) -> f64 logits.
    ///
    /// Bit-exact with `model.forward_int_np` over the same f32 tokens.
    pub fn forward_image(&self, tokens: &[f32]) -> crate::Result<Vec<f64>> {
        anyhow::ensure!(
            tokens.len() == self.tokens_per_image(),
            "expected {} token values, got {}",
            self.tokens_per_image(),
            tokens.len()
        );
        let (t, d, h) = (self.tokens, self.dim, self.heads);
        let dh = d / h;

        let xq: Vec<i32> = tokens.iter().map(|&x| self.quantize_in(x)).collect();
        let acc = Self::matmul_bias(&xq, t, self.patch_dim, &self.pe_w, d, &self.pe_b);
        // residual stream: int32, common scale s0 (+2 guard bits)
        let mut x: Vec<i32> = acc.iter().map(|&a| lut_i32(&self.pe_rq, a as i32)).collect();

        for blk in &self.blocks {
            // ---- MHA ----
            let n = self.layernorm(&x, blk.ln1_guard, &blk.ln1_rsqrt, &blk.ln1_rq);
            let acc = Self::matmul_bias(&n, t, d, &blk.qkv_w, 3 * d, &blk.qkv_b);
            let qkv: Vec<i32> = acc.iter().map(|&a| lut_i32(&blk.qkv_rq, a as i32)).collect();

            let mut a_q = vec![0i32; t * d];
            let mut scores = vec![0i64; t];
            let mut probs = vec![0i32; t * t];
            for hh in 0..h {
                let (qof, kof, vof) = (hh * dh, d + hh * dh, 2 * d + hh * dh);
                // DyMM 1: scores = Q @ K^T, then row-wise softmax
                for t1 in 0..t {
                    let q = &qkv[t1 * 3 * d + qof..t1 * 3 * d + qof + dh];
                    for t2 in 0..t {
                        let k = &qkv[t2 * 3 * d + kof..t2 * 3 * d + kof + dh];
                        scores[t2] = q.iter().zip(k).map(|(&a, &b)| a as i64 * b as i64).sum();
                    }
                    self.softmax_row(blk, &scores, &mut probs[t1 * t..(t1 + 1) * t]);
                }
                // DyMM 2: R @ V, requantized into the head's output slice
                for t1 in 0..t {
                    for c in 0..dh {
                        let mut s: i64 = 0;
                        for t2 in 0..t {
                            s += probs[t1 * t + t2] as i64
                                * qkv[t2 * 3 * d + vof + c] as i64;
                        }
                        a_q[t1 * d + hh * dh + c] = lut_i32(&blk.rv_rq, s as i32);
                    }
                }
            }
            let acc = Self::matmul_bias(&a_q, t, d, &blk.proj_w, d, &blk.proj_b);
            for (xv, &a) in x.iter_mut().zip(&acc) {
                *xv = xv.wrapping_add(lut_i32(&blk.proj_rq, a as i32));
            }

            // ---- MLP ----
            let n2 = self.layernorm(&x, blk.ln2_guard, &blk.ln2_rsqrt, &blk.ln2_rq);
            let acc = Self::matmul_bias(&n2, t, d, &blk.mm1_w, self.hidden, &blk.mm1_b);
            let hdn: Vec<i32> = acc.iter().map(|&a| lut_i32(&blk.gelu, a as i32)).collect();
            let acc = Self::matmul_bias(&hdn, t, self.hidden, &blk.mm2_w, d, &blk.mm2_b);
            for (xv, &a) in x.iter_mut().zip(&acc) {
                *xv = xv.wrapping_add(lut_i32(&blk.mm2_rq, a as i32));
            }
        }

        // ---- final LN + mean-pool head (the /T fold lives in logit_scale)
        let n = self.layernorm(&x, self.ln_f_guard, &self.ln_f_rsqrt, &self.ln_f_rq);
        let mut pooled = vec![0i64; d];
        for row in n.chunks_exact(d) {
            for (p, &v) in pooled.iter_mut().zip(row) {
                *p += v as i64;
            }
        }
        let mut logits = Vec::with_capacity(self.num_classes);
        for k in 0..self.num_classes {
            let mut s: i64 = 0;
            for (c, &p) in pooled.iter().enumerate() {
                s += p * self.head_w[c * self.num_classes + k] as i64;
            }
            logits.push(s as f64 * self.logit_scale + self.head_bias[k]);
        }
        Ok(logits)
    }
}

// ---------------------------------------------------------------------------
// Executor adapter (one per batch variant, sharing the loaded model)
// ---------------------------------------------------------------------------

/// A batch-size view over a shared [`QuantViT`].
pub struct InterpreterExecutor {
    net: Arc<QuantViT>,
    batch: usize,
    load_ms: f64,
    stats: Mutex<ExecStats>,
}

impl Executor for InterpreterExecutor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn run_f32(&self, input: &[f32]) -> crate::Result<Vec<f32>> {
        let per = self.net.tokens_per_image();
        anyhow::ensure!(
            input.len() == self.batch * per,
            "input length {} != batch {} x {}",
            input.len(),
            self.batch,
            per
        );
        let t0 = Instant::now();
        let mut out = Vec::with_capacity(self.batch * self.net.num_classes);
        for lane in input.chunks_exact(per) {
            out.extend(self.net.forward_image(lane)?.iter().map(|&l| l as f32));
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut s = self.stats.lock().unwrap();
        s.executions += 1;
        s.total_ms += ms;
        Ok(out)
    }

    fn compile_ms(&self) -> f64 {
        self.load_ms
    }

    fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }
}

/// Load a model's bundle and wrap it in one executor per batch variant.
pub fn load_model(manifest: &Manifest, model: &str) -> crate::Result<LoadedModel> {
    let info: &BundleInfo = manifest
        .bundle_for(model)
        .ok_or_else(|| anyhow::anyhow!("no interpreter bundle for model '{model}' in manifest"))?;
    let t0 = Instant::now();
    let net = Arc::new(QuantViT::load(&info.path)?);
    let load_ms = t0.elapsed().as_secs_f64() * 1e3;
    anyhow::ensure!(
        net.model == model,
        "bundle model '{}' != requested '{model}'",
        net.model
    );
    let batches = if info.batches.is_empty() { vec![1] } else { info.batches.clone() };
    let executors: Vec<Box<dyn Executor>> = batches
        .iter()
        .map(|&b| {
            Box::new(InterpreterExecutor {
                net: net.clone(),
                batch: b,
                load_ms,
                stats: Mutex::new(ExecStats::default()),
            }) as Box<dyn Executor>
        })
        .collect();
    Ok(LoadedModel {
        executors,
        tokens_per_image: net.tokens_per_image(),
        num_classes: net.num_classes,
        compile_ms: load_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_lut(alpha: i64, shift: u32, n_bits: u32, inverted: bool, entries: Vec<i64>) -> LutTable {
        LutTable {
            name: "t".into(),
            alpha,
            shift,
            n_bits,
            inverted,
            out_scale: 1.0,
            out_zp: 0,
            entries,
        }
    }

    #[test]
    fn lut_i32_matches_table_lookup_in_range() {
        let t = mk_lut(-8, 2, 2, false, vec![10, 20, 30, 40]);
        for x in -20i64..20 {
            assert_eq!(lut_i32(&t, x as i32) as i64, t.lookup(x), "x={x}");
        }
    }

    #[test]
    fn lut_i32_inverted_matches() {
        let t = mk_lut(0, 1, 2, true, vec![1, 2, 3, 4]);
        for x in -20i64..5 {
            assert_eq!(lut_i32(&t, x as i32) as i64, t.lookup(x), "x={x}");
        }
    }

    #[test]
    fn lut_i32_wraps_like_numpy_int32() {
        // an accumulator past i32::MAX wraps negative before indexing,
        // exactly as numpy's astype(int32) does in LutExec._lut
        let t = mk_lut(0, 4, 2, false, vec![7, 8, 9, 10]);
        let big: i64 = (1i64 << 31) + 5; // wraps to i32::MIN + 5
        let wrapped = big as i32;
        assert!(wrapped < 0);
        assert_eq!(lut_i32(&t, wrapped), 7); // clamps to index 0
    }

    #[test]
    fn seg_i32_selects_by_pivot_and_shifts() {
        let steep = LutTable { out_scale: 1.0, ..mk_lut(0, 2, 2, false, vec![100, 90, 80, 70]) };
        let flat = LutTable { out_scale: 0.25, alpha: 16, ..mk_lut(0, 2, 2, false, vec![5, 4, 3, 2]) };
        let s = SegmentedTable { name: "s".into(), pivot: 16, steep, flat };
        assert_eq!(seg_i32(&s, 0), 400); // 100 << 2
        assert_eq!(seg_i32(&s, 16), 5);
    }
}
