//! Runtime-dispatched SIMD kernel layer: every scalar hot loop of the
//! interpreter, behind one [`Kernels`] vtable.
//!
//! HG-PIPE's resource argument is that linear *and* non-linear operators
//! should run on the cheap, abundant compute substrate (LUTs on the
//! FPGA); on a CPU that substrate is the vector unit. This module lifts
//! the hot inner loops that used to live in `fabric/gemm.rs` (the GEMM
//! microkernel), `interpreter/ops.rs` (softmax, LayerNorm, the attention
//! score loop) and the requant LUT application into a table of plain
//! `fn` pointers with three backends:
//!
//! * [`scalar`] — bit-for-bit the pre-refactor code, kept as the
//!   **oracle**: every other backend is differentially tested against it
//!   (`tests/kernel_dispatch.rs`), and `HGPIPE_KERNELS=scalar` forces it
//!   everywhere (the CI matrix runs the whole suite that way).
//! * `avx2` — x86_64, selected when `is_x86_feature_detected!("avx2")`
//!   holds: widening 32×32→64 multiplies for the GEMM/attention
//!   accumulators, vectorized LUT index computation (wrapping subtract,
//!   arithmetic shift, clamp) with scalar table gathers.
//! * `neon` — aarch64 (`vmull_s32` widening multiply-accumulate and
//!   vectorized LUT indexing); the i64-squaring LayerNorm reduction
//!   delegates to the scalar oracle.
//!
//! Selection happens **once at model load** ([`detect`] / [`select`] /
//! [`from_env`]) and the chosen table threads through
//! [`Exec`](crate::runtime::fabric::Exec), the
//! [`LanePool`](crate::runtime::fabric::LanePool) band workers and the
//! resident pipeline stages, so lane-parallel and pipeline modes hit the
//! same vectorized code. Precedence mirrors the lane/mode/replica flags:
//! an explicit `RuntimeConfig::kernels` / `--kernels` wins, then the
//! read-only `HGPIPE_KERNELS` env fallback, then auto-detection.
//!
//! ## Bit-exactness contract
//!
//! Every op is defined over integer arithmetic that vectorizes
//! *exactly*: i64 accumulator addition is associative mod 2^64, the
//! `as i32` narrowings keep only the low 32 bits (so a vector that
//! multiplies low-32×low-32 reproduces `(a * b) as i32` verbatim), and
//! the LUT index path (wrapping subtract, arithmetic shift by a table
//! constant `< 32`, clamp to `[0, 2^n_bits - 1]`) maps lane-for-lane
//! onto vector min/max/shift instructions. The golden fixture and the
//! randomized differential tests pin equality on every backend, in both
//! exec modes. `unsafe` lives only in this directory — the backend
//! tables are plain safe `fn`s whose bodies prove the single
//! feature-detection precondition.

mod scalar;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "aarch64")]
mod neon;

use crate::lut::LutTable;

/// `LutExec._lut`: int32-domain PoT-indexed lookup — the one table
/// application every requant/exp/prob op is built from. Lives here so
/// the backends and the scalar oracle share a single definition;
/// `interpreter::ops` re-exports it.
#[inline]
pub(crate) fn lut_i32(t: &LutTable, x: i32) -> i32 {
    let alpha = t.alpha as i32;
    let diff = if t.inverted { alpha.wrapping_sub(x) } else { x.wrapping_sub(alpha) };
    let raw = diff >> t.shift;
    let hi = (1i32 << t.n_bits) - 1;
    t.entries[raw.clamp(0, hi) as usize] as i32
}

/// The kernel vtable: one `fn` pointer per hot loop. A backend is a
/// `static` instance of this struct; dispatch is one indirect call per
/// *band-level* loop (never per element), selected once at model load.
///
/// All ops share the oracle's semantics exactly — wrapping `as i32`
/// narrowings, arithmetic shifts, ascending-index i64 accumulation —
/// so any two backends produce identical bytes on identical inputs.
pub struct Kernels {
    /// Backend name, as printed by `hgpipe serve` and the bench report.
    pub name: &'static str,
    /// `o[j] += a * w[j]` (i64 accumulate over one packed panel row) —
    /// the GEMM microkernel's inner loop and the attention `R @ V`
    /// accumulate. `w.len() == o.len()`.
    pub axpy: fn(a: i32, w: &[i32], o: &mut [i64]),
    /// Four [`Kernels::axpy`]s sharing one weight row: the 4-row
    /// register-blocked GEMM microkernel body. Each output tile has
    /// `w.len()` elements.
    pub axpy4:
        fn(a: [i32; 4], w: &[i32], o0: &mut [i64], o1: &mut [i64], o2: &mut [i64], o3: &mut [i64]),
    /// `out[j] = lut(rq, acc[j] as i32)` — the fused requant epilogue
    /// applied to a GEMM/attention accumulator band. Lengths equal.
    pub requant: fn(rq: &LutTable, acc: &[i64], out: &mut [i32]),
    /// `out[j] = out[j].wrapping_add(lut(rq, acc[j] as i32))` — the
    /// requant epilogue fused with the residual add. Lengths equal.
    pub requant_add: fn(rq: &LutTable, acc: &[i64], out: &mut [i32]),
    /// `Σ a[i] * b[i]` with exact i64 accumulation — one attention
    /// score. `a.len() == b.len()`.
    pub dot_i32: fn(a: &[i32], b: &[i32]) -> i64,
    /// Max over a **non-empty** slice — the softmax max-subtract.
    pub max_i32: fn(x: &[i32]) -> i32,
    /// `e[i] = lut(exp, sc[i].wrapping_sub(m))`, returning `Σ e[i]` as
    /// i64 — the softmax exp pass. Lengths equal.
    pub exp_lut_sum: fn(exp: &LutTable, m: i32, sc: &[i32], e: &mut [i32]) -> i64,
    /// `p[i] = lut(prob, e[i].wrapping_mul(r))` — the softmax
    /// probability requant. Lengths equal.
    pub prob_lut: fn(prob: &LutTable, r: i32, e: &[i32], p: &mut [i32]),
    /// `Σ row[i]` as i64 — the LayerNorm row sum.
    pub sum_i32: fn(row: &[i32]) -> i64,
    /// LayerNorm center pass: `c[j] = d.wrapping_mul(row[j]) as i64 -
    /// sum`, returning `Σ (c[j] >> guard)²` (the variance accumulator).
    /// `row.len() == c.len()`.
    pub ln_center: fn(d: i32, sum: i64, guard: u32, row: &[i32], c: &mut [i64]) -> i64,
    /// LayerNorm output pass: `out[j] = lut(rq, (c[j] * r) as i32)`.
    /// Only the low 32 bits of the product survive the narrowing, so
    /// backends may multiply low-32×low-32. Lengths equal.
    pub ln_finish: fn(rq: &LutTable, r: i64, c: &[i64], out: &mut [i32]),
}

impl std::fmt::Debug for Kernels {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Kernels").field("name", &self.name).finish_non_exhaustive()
    }
}

impl PartialEq for Kernels {
    /// Two kernel tables are the same backend iff they have the same
    /// name (backends are singleton statics).
    fn eq(&self, other: &Self) -> bool {
        std::ptr::eq(self, other) || self.name == other.name
    }
}

/// Which kernel backend a config asks for — the CLI's `--kernels` and
/// `RuntimeConfig::kernels` speak this; [`select`] turns it into a
/// table or an error when the host can't run it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelPref {
    /// Auto-detect the best backend for this host ([`detect`]).
    #[default]
    Auto,
    /// Force the scalar oracle.
    Scalar,
    /// Require AVX2 (x86_64 hosts with the feature only).
    Avx2,
    /// Require NEON (aarch64 hosts only).
    Neon,
}

impl KernelPref {
    /// Parse a CLI flag / env value. Unknown names are an error — a
    /// typo'd backend must never silently change the compute substrate.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "auto" => Ok(Self::Auto),
            "scalar" => Ok(Self::Scalar),
            "avx2" => Ok(Self::Avx2),
            "neon" => Ok(Self::Neon),
            other => anyhow::bail!(
                "unknown kernel backend '{other}' (scalar | avx2 | neon | auto)"
            ),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Self::Auto => "auto",
            Self::Scalar => "scalar",
            Self::Avx2 => "avx2",
            Self::Neon => "neon",
        }
    }
}

/// The scalar oracle backend — always available, bit-for-bit the
/// pre-refactor code.
pub fn scalar() -> &'static Kernels {
    &scalar::KERNELS
}

/// Auto-detect the best backend for this host: AVX2 on x86_64 CPUs that
/// report the feature, NEON on aarch64, the scalar oracle otherwise.
/// Pure detection — no env consultation (that is [`from_env`]'s job).
pub fn detect() -> &'static Kernels {
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") {
        return &avx2::KERNELS;
    }
    #[cfg(target_arch = "aarch64")]
    if std::arch::is_aarch64_feature_detected!("neon") {
        return &neon::KERNELS;
    }
    &scalar::KERNELS
}

/// Resolve an explicit preference to a kernel table. Asking for a
/// backend the host cannot execute is an **error**, not a silent
/// fallback — like requesting the pjrt backend without the feature.
pub fn select(pref: KernelPref) -> crate::Result<&'static Kernels> {
    match pref {
        KernelPref::Auto => return Ok(detect()),
        KernelPref::Scalar => return Ok(&scalar::KERNELS),
        KernelPref::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            if std::arch::is_x86_feature_detected!("avx2") {
                return Ok(&avx2::KERNELS);
            }
        }
        KernelPref::Neon => {
            #[cfg(target_arch = "aarch64")]
            if std::arch::is_aarch64_feature_detected!("neon") {
                return Ok(&neon::KERNELS);
            }
        }
    }
    anyhow::bail!(
        "kernel backend '{}' is unavailable on this host (arch {}); \
         use `--kernels scalar` or `--kernels auto`",
        pref.label(),
        std::env::consts::ARCH
    )
}

/// The backend [`detect`] would be overridden to by the read-only
/// `HGPIPE_KERNELS` env var (mirrors `HGPIPE_LANES` / `HGPIPE_MODE` /
/// `HGPIPE_REPLICAS`: nothing in this crate mutates it; the CLI's
/// `--kernels` is threaded through `RuntimeConfig` instead). A value
/// naming an unavailable or unknown backend warns on stderr and falls
/// back to auto-detection — an env typo must never silently change (or
/// crash) a serving process that never asked for a specific backend.
pub fn from_env() -> &'static Kernels {
    match std::env::var("HGPIPE_KERNELS") {
        Ok(v) => match KernelPref::parse(v.trim()) {
            Ok(pref) => match select(pref) {
                Ok(k) => k,
                Err(e) => {
                    eprintln!("warning: HGPIPE_KERNELS='{v}': {e:#}; using auto-detection");
                    detect()
                }
            },
            Err(_) => {
                eprintln!(
                    "warning: HGPIPE_KERNELS='{v}' is not a kernel backend \
                     (scalar | avx2 | neon | auto); using auto-detection"
                );
                detect()
            }
        },
        Err(_) => detect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn mk_lut(alpha: i64, shift: u32, n_bits: u32, inverted: bool, entries: Vec<i64>) -> LutTable {
        LutTable {
            name: "t".into(),
            alpha,
            shift,
            n_bits,
            inverted,
            out_scale: 1.0,
            out_zp: 0,
            entries,
        }
    }

    #[test]
    fn scalar_is_always_selectable_and_named() {
        assert_eq!(scalar().name, "scalar");
        assert_eq!(select(KernelPref::Scalar).unwrap().name, "scalar");
        assert_eq!(select(KernelPref::Auto).unwrap().name, detect().name);
    }

    #[test]
    fn pref_parse_round_trips_and_rejects_unknown() {
        for p in [KernelPref::Auto, KernelPref::Scalar, KernelPref::Avx2, KernelPref::Neon] {
            assert_eq!(KernelPref::parse(p.label()).unwrap(), p);
        }
        assert!(KernelPref::parse("sse9").is_err());
        assert!(KernelPref::parse("").is_err());
    }

    #[test]
    fn selecting_a_foreign_arch_backend_is_an_error() {
        // at most one of avx2/neon can be available on any one host
        let avx2 = select(KernelPref::Avx2);
        let neon = select(KernelPref::Neon);
        assert!(avx2.is_err() || neon.is_err());
    }

    #[test]
    fn detected_backend_agrees_with_scalar_on_random_ops() {
        // a compact in-module differential check (the exhaustive sweeps
        // live in tests/kernel_dispatch.rs): every vtable op, detected
        // backend vs the scalar oracle, across awkward lengths
        let s = scalar();
        let d = detect();
        let mut rng = Prng::new(0x5EED);
        let rq = mk_lut(-300, 3, 6, false, (0..64).map(|i| i * 7 - 200).collect());
        let exp = mk_lut(0, 2, 5, true, (0..32).map(|i| 1000 - i * 31).collect());
        for n in [1usize, 2, 3, 4, 7, 8, 9, 15, 16, 17, 31, 33, 64, 100] {
            let w: Vec<i32> = (0..n).map(|_| rng.range_i64(-1000, 1000) as i32).collect();
            let x: Vec<i32> = (0..n).map(|_| rng.range_i64(-1000, 1000) as i32).collect();
            let acc: Vec<i64> = (0..n).map(|_| rng.range_i64(-1 << 40, 1 << 40)).collect();

            let (mut o1, mut o2) = (acc.clone(), acc.clone());
            (s.axpy)(-37, &w, &mut o1);
            (d.axpy)(-37, &w, &mut o2);
            assert_eq!(o1, o2, "axpy n={n}");

            assert_eq!((s.dot_i32)(&x, &w), (d.dot_i32)(&x, &w), "dot n={n}");
            assert_eq!((s.max_i32)(&x), (d.max_i32)(&x), "max n={n}");
            assert_eq!((s.sum_i32)(&x), (d.sum_i32)(&x), "sum n={n}");

            let (mut r1, mut r2) = (vec![0i32; n], vec![0i32; n]);
            (s.requant)(&rq, &acc, &mut r1);
            (d.requant)(&rq, &acc, &mut r2);
            assert_eq!(r1, r2, "requant n={n}");
            (s.requant_add)(&rq, &acc, &mut r1);
            (d.requant_add)(&rq, &acc, &mut r2);
            assert_eq!(r1, r2, "requant_add n={n}");

            let (mut e1, mut e2) = (vec![0i32; n], vec![0i32; n]);
            let m = (s.max_i32)(&x);
            let t1 = (s.exp_lut_sum)(&exp, m, &x, &mut e1);
            let t2 = (d.exp_lut_sum)(&exp, m, &x, &mut e2);
            assert_eq!((t1, &e1), (t2, &e2), "exp_lut_sum n={n}");

            let (mut p1, mut p2) = (vec![0i32; n], vec![0i32; n]);
            (s.prob_lut)(&rq, 77, &e1, &mut p1);
            (d.prob_lut)(&rq, 77, &e2, &mut p2);
            assert_eq!(p1, p2, "prob_lut n={n}");

            let (mut c1, mut c2) = (vec![0i64; n], vec![0i64; n]);
            let v1 = (s.ln_center)(n as i32, (s.sum_i32)(&x), 2, &x, &mut c1);
            let v2 = (d.ln_center)(n as i32, (s.sum_i32)(&x), 2, &x, &mut c2);
            assert_eq!((v1, &c1), (v2, &c2), "ln_center n={n}");

            let (mut f1, mut f2) = (vec![0i32; n], vec![0i32; n]);
            (s.ln_finish)(&rq, 123, &c1, &mut f1);
            (d.ln_finish)(&rq, 123, &c2, &mut f2);
            assert_eq!(f1, f2, "ln_finish n={n}");
        }
    }
}
