//! NEON kernel backend (aarch64). Reached only through
//! `super::detect()` / `super::select()`, which gate this table behind
//! `is_aarch64_feature_detected!("neon")` — the one precondition every
//! `unsafe` block here relies on.
//!
//! Same exact-integer-arithmetic contract as the AVX2 backend:
//! `vmull_s32` produces full 64-bit products of 32-bit lanes, i64
//! accumulator addition is associative mod 2^64, and the LUT index path
//! (wrapping subtract, arithmetic shift, clamp) maps lane-for-lane onto
//! `vsub/vshl(-n)/vmax/vmin` with scalar table gathers. The LayerNorm
//! variance pass needs a 64×64 low multiply NEON doesn't have, so
//! [`Kernels::ln_center`] delegates to the scalar oracle — bit-exact by
//! construction.

use std::arch::aarch64::*;

use crate::lut::LutTable;

use super::{lut_i32, Kernels};

pub(super) static KERNELS: Kernels = Kernels {
    name: "neon",
    axpy,
    axpy4,
    requant,
    requant_add,
    dot_i32,
    max_i32,
    exp_lut_sum,
    prob_lut,
    sum_i32,
    // no 64-bit low multiply on NEON: the scalar oracle IS the impl
    ln_center: super::scalar::ln_center,
    ln_finish,
};

// SAFETY (every wrapper below): this vtable is only handed out by
// detect()/select() after is_aarch64_feature_detected!("neon")
// confirmed the CPU executes NEON, which is the sole precondition of
// the #[target_feature(enable = "neon")] implementations.

fn axpy(a: i32, w: &[i32], o: &mut [i64]) {
    unsafe { axpy_impl(a, w, o) }
}

fn axpy4(a: [i32; 4], w: &[i32], o0: &mut [i64], o1: &mut [i64], o2: &mut [i64], o3: &mut [i64]) {
    unsafe {
        axpy_impl(a[0], w, o0);
        axpy_impl(a[1], w, o1);
        axpy_impl(a[2], w, o2);
        axpy_impl(a[3], w, o3);
    }
}

fn requant(rq: &LutTable, acc: &[i64], out: &mut [i32]) {
    unsafe { requant_impl(rq, acc, out, false) }
}

fn requant_add(rq: &LutTable, acc: &[i64], out: &mut [i32]) {
    unsafe { requant_impl(rq, acc, out, true) }
}

fn dot_i32(a: &[i32], b: &[i32]) -> i64 {
    unsafe { dot_impl(a, b) }
}

fn max_i32(x: &[i32]) -> i32 {
    unsafe { max_impl(x) }
}

fn exp_lut_sum(exp: &LutTable, m: i32, sc: &[i32], e: &mut [i32]) -> i64 {
    unsafe { exp_lut_sum_impl(exp, m, sc, e) }
}

fn prob_lut(prob: &LutTable, r: i32, e: &[i32], p: &mut [i32]) {
    unsafe { prob_lut_impl(prob, r, e, p) }
}

fn sum_i32(row: &[i32]) -> i64 {
    unsafe { sum_impl(row) }
}

fn ln_finish(rq: &LutTable, r: i64, c: &[i64], out: &mut [i32]) {
    unsafe { ln_finish_impl(rq, r, c, out) }
}

/// Vectorized LUT index computation, four lanes at a time.
struct LutIdx {
    alpha: int32x4_t,
    hi: int32x4_t,
    lo: int32x4_t,
    /// Negative shift count: signed `vshl` by a negative amount is a
    /// truncating arithmetic right shift, matching `>>`.
    nshift: int32x4_t,
    inverted: bool,
}

impl LutIdx {
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn new(t: &LutTable) -> Self {
        Self {
            alpha: vdupq_n_s32(t.alpha as i32),
            hi: vdupq_n_s32((1i32 << t.n_bits) - 1),
            lo: vdupq_n_s32(0),
            nshift: vdupq_n_s32(-(t.shift as i32)),
            inverted: t.inverted,
        }
    }

    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn idx(&self, x: int32x4_t) -> int32x4_t {
        let diff = if self.inverted { vsubq_s32(self.alpha, x) } else { vsubq_s32(x, self.alpha) };
        let raw = vshlq_s32(diff, self.nshift);
        vminq_s32(vmaxq_s32(raw, self.lo), self.hi)
    }
}

#[target_feature(enable = "neon")]
unsafe fn axpy_impl(a: i32, w: &[i32], o: &mut [i64]) {
    debug_assert_eq!(w.len(), o.len());
    let n4 = w.len() & !3;
    let mut j = 0usize;
    while j < n4 {
        let w4 = vld1q_s32(w.as_ptr().add(j));
        let plo = vmull_n_s32(vget_low_s32(w4), a);
        let phi = vmull_n_s32(vget_high_s32(w4), a);
        let olo = vld1q_s64(o.as_ptr().add(j));
        vst1q_s64(o.as_mut_ptr().add(j), vaddq_s64(olo, plo));
        let ohi = vld1q_s64(o.as_ptr().add(j + 2));
        vst1q_s64(o.as_mut_ptr().add(j + 2), vaddq_s64(ohi, phi));
        j += 4;
    }
    let a = a as i64;
    for jj in n4..w.len() {
        o[jj] += a * w[jj] as i64;
    }
}

#[target_feature(enable = "neon")]
unsafe fn requant_impl(rq: &LutTable, acc: &[i64], out: &mut [i32], add: bool) {
    debug_assert_eq!(acc.len(), out.len());
    let li = LutIdx::new(rq);
    let mut idx = [0i32; 4];
    let n4 = acc.len() & !3;
    let mut j = 0usize;
    while j < n4 {
        // `acc as i32` is the low 32 bits of each lane: narrow + combine
        let lo = vmovn_s64(vld1q_s64(acc.as_ptr().add(j)));
        let hi = vmovn_s64(vld1q_s64(acc.as_ptr().add(j + 2)));
        let id = li.idx(vcombine_s32(lo, hi));
        vst1q_s32(idx.as_mut_ptr(), id);
        for t in 0..4 {
            let v = rq.entries[idx[t] as usize] as i32;
            out[j + t] = if add { out[j + t].wrapping_add(v) } else { v };
        }
        j += 4;
    }
    for t in n4..acc.len() {
        let v = lut_i32(rq, acc[t] as i32);
        out[t] = if add { out[t].wrapping_add(v) } else { v };
    }
}

#[target_feature(enable = "neon")]
unsafe fn dot_impl(a: &[i32], b: &[i32]) -> i64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = vdupq_n_s64(0);
    let n4 = a.len() & !3;
    let mut j = 0usize;
    while j < n4 {
        let a4 = vld1q_s32(a.as_ptr().add(j));
        let b4 = vld1q_s32(b.as_ptr().add(j));
        acc = vmlal_s32(acc, vget_low_s32(a4), vget_low_s32(b4));
        acc = vmlal_s32(acc, vget_high_s32(a4), vget_high_s32(b4));
        j += 4;
    }
    let mut tot = vaddvq_s64(acc);
    for t in n4..a.len() {
        tot += a[t] as i64 * b[t] as i64;
    }
    tot
}

#[target_feature(enable = "neon")]
unsafe fn max_impl(x: &[i32]) -> i32 {
    assert!(!x.is_empty(), "max_i32 over an empty row");
    let mut best = i32::MIN;
    let n4 = x.len() & !3;
    if n4 != 0 {
        let mut m = vld1q_s32(x.as_ptr());
        let mut j = 4usize;
        while j < n4 {
            m = vmaxq_s32(m, vld1q_s32(x.as_ptr().add(j)));
            j += 4;
        }
        best = vmaxvq_s32(m);
    }
    for &v in &x[n4..] {
        best = best.max(v);
    }
    best
}

#[target_feature(enable = "neon")]
unsafe fn exp_lut_sum_impl(exp: &LutTable, m: i32, sc: &[i32], e: &mut [i32]) -> i64 {
    debug_assert_eq!(sc.len(), e.len());
    let li = LutIdx::new(exp);
    let mv = vdupq_n_s32(m);
    let mut idx = [0i32; 4];
    let mut tot: i64 = 0;
    let n4 = sc.len() & !3;
    let mut j = 0usize;
    while j < n4 {
        let x = vld1q_s32(sc.as_ptr().add(j));
        let id = li.idx(vsubq_s32(x, mv));
        vst1q_s32(idx.as_mut_ptr(), id);
        for t in 0..4 {
            let v = exp.entries[idx[t] as usize] as i32;
            e[j + t] = v;
            tot += v as i64;
        }
        j += 4;
    }
    for t in n4..sc.len() {
        let v = lut_i32(exp, sc[t].wrapping_sub(m));
        e[t] = v;
        tot += v as i64;
    }
    tot
}

#[target_feature(enable = "neon")]
unsafe fn prob_lut_impl(prob: &LutTable, r: i32, e: &[i32], p: &mut [i32]) {
    debug_assert_eq!(e.len(), p.len());
    let li = LutIdx::new(prob);
    let rv = vdupq_n_s32(r);
    let mut idx = [0i32; 4];
    let n4 = e.len() & !3;
    let mut j = 0usize;
    while j < n4 {
        let x = vld1q_s32(e.as_ptr().add(j));
        let id = li.idx(vmulq_s32(x, rv));
        vst1q_s32(idx.as_mut_ptr(), id);
        for t in 0..4 {
            p[j + t] = prob.entries[idx[t] as usize] as i32;
        }
        j += 4;
    }
    for t in n4..e.len() {
        p[t] = lut_i32(prob, e[t].wrapping_mul(r));
    }
}

#[target_feature(enable = "neon")]
unsafe fn sum_impl(row: &[i32]) -> i64 {
    let mut tot: i64 = 0;
    let n4 = row.len() & !3;
    let mut j = 0usize;
    while j < n4 {
        tot += vaddlvq_s32(vld1q_s32(row.as_ptr().add(j)));
        j += 4;
    }
    for &v in &row[n4..] {
        tot += v as i64;
    }
    tot
}

#[target_feature(enable = "neon")]
unsafe fn ln_finish_impl(rq: &LutTable, r: i64, c: &[i64], out: &mut [i32]) {
    debug_assert_eq!(c.len(), out.len());
    let li = LutIdx::new(rq);
    // only the low 32 bits of c[j] * r survive the `as i32` narrowing
    let rv = vdupq_n_s32(r as i32);
    let mut idx = [0i32; 4];
    let n4 = c.len() & !3;
    let mut j = 0usize;
    while j < n4 {
        let lo = vmovn_s64(vld1q_s64(c.as_ptr().add(j)));
        let hi = vmovn_s64(vld1q_s64(c.as_ptr().add(j + 2)));
        let prod = vmulq_s32(vcombine_s32(lo, hi), rv);
        let id = li.idx(prod);
        vst1q_s32(idx.as_mut_ptr(), id);
        for t in 0..4 {
            out[j + t] = rq.entries[idx[t] as usize] as i32;
        }
        j += 4;
    }
    for t in n4..c.len() {
        out[t] = lut_i32(rq, (c[t] as i32).wrapping_mul(r as i32));
    }
}
