//! AVX2 kernel backend (x86_64). Reached only through
//! `super::detect()` / `super::select()`, which gate this table behind
//! `is_x86_feature_detected!("avx2")` — that runtime check is the one
//! safety precondition every `unsafe` block in this file relies on.
//!
//! Bit-exactness with the scalar oracle comes from staying in exact
//! integer arithmetic end to end:
//!
//! * `o += a * w` accumulators use `_mm256_mul_epi32` (signed low-32 ×
//!   low-32 → full 64-bit product) on sign-extended lanes, then
//!   `_mm256_add_epi64` — i64 addition is associative mod 2^64, and
//!   each output element receives exactly one product per `k`, so lane
//!   order never changes the result.
//! * The LUT index path (wrapping subtract, arithmetic shift by the
//!   table's PoT constant, clamp to `[0, 2^n_bits - 1]`) maps
//!   lane-for-lane onto `sub/sra/max/min`; the table gather itself
//!   stays scalar through a spilled index block (entries are i64 and
//!   tables are tiny — the index math is the vectorizable part).
//! * Narrowings like `acc as i32` and `(c * r) as i32` keep only the
//!   low 32 bits, so packing the low halves of i64 lanes and using
//!   `_mm256_mullo_epi32` (wrapping) reproduces them verbatim.
//! * AVX2 has no 64-bit arithmetic right shift or 64×64 multiply; the
//!   LayerNorm variance pass uses the sign-bias trick
//!   `((c + 2^63) >>logical g) - (2^63 >>logical g)` and the squaring
//!   identity `x² mod 2^64 = lo² + ((hi·lo) << 33)`.

use std::arch::x86_64::*;

use crate::lut::LutTable;

use super::{lut_i32, Kernels};

pub(super) static KERNELS: Kernels = Kernels {
    name: "avx2",
    axpy,
    axpy4,
    requant,
    requant_add,
    dot_i32,
    max_i32,
    exp_lut_sum,
    prob_lut,
    sum_i32,
    ln_center,
    ln_finish,
};

// SAFETY (every wrapper below): this vtable is only handed out by
// detect()/select() after is_x86_feature_detected!("avx2") confirmed
// the CPU executes AVX2, which is the sole precondition of the
// #[target_feature(enable = "avx2")] implementations.

fn axpy(a: i32, w: &[i32], o: &mut [i64]) {
    unsafe { axpy_impl(a, w, o) }
}

fn axpy4(a: [i32; 4], w: &[i32], o0: &mut [i64], o1: &mut [i64], o2: &mut [i64], o3: &mut [i64]) {
    unsafe { axpy4_impl(a, w, o0, o1, o2, o3) }
}

fn requant(rq: &LutTable, acc: &[i64], out: &mut [i32]) {
    unsafe { requant_impl(rq, acc, out) }
}

fn requant_add(rq: &LutTable, acc: &[i64], out: &mut [i32]) {
    unsafe { requant_add_impl(rq, acc, out) }
}

fn dot_i32(a: &[i32], b: &[i32]) -> i64 {
    unsafe { dot_impl(a, b) }
}

fn max_i32(x: &[i32]) -> i32 {
    unsafe { max_impl(x) }
}

fn exp_lut_sum(exp: &LutTable, m: i32, sc: &[i32], e: &mut [i32]) -> i64 {
    unsafe { exp_lut_sum_impl(exp, m, sc, e) }
}

fn prob_lut(prob: &LutTable, r: i32, e: &[i32], p: &mut [i32]) {
    unsafe { prob_lut_impl(prob, r, e, p) }
}

fn sum_i32(row: &[i32]) -> i64 {
    unsafe { sum_impl(row) }
}

fn ln_center(d: i32, sum: i64, guard: u32, row: &[i32], c: &mut [i64]) -> i64 {
    unsafe { ln_center_impl(d, sum, guard, row, c) }
}

fn ln_finish(rq: &LutTable, r: i64, c: &[i64], out: &mut [i32]) {
    unsafe { ln_finish_impl(rq, r, c, out) }
}

/// Vectorized LUT index computation: the `(x -/~ alpha) >> shift`
/// clamp-to-range half of [`lut_i32`], eight lanes at a time.
struct LutIdx {
    alpha: __m256i,
    hi: __m256i,
    lo: __m256i,
    shift: __m128i,
    inverted: bool,
}

impl LutIdx {
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn new(t: &LutTable) -> Self {
        Self {
            alpha: _mm256_set1_epi32(t.alpha as i32),
            hi: _mm256_set1_epi32((1i32 << t.n_bits) - 1),
            lo: _mm256_setzero_si256(),
            shift: _mm_cvtsi32_si128(t.shift as i32),
            inverted: t.inverted,
        }
    }

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn idx(&self, x: __m256i) -> __m256i {
        let diff = if self.inverted {
            _mm256_sub_epi32(self.alpha, x)
        } else {
            _mm256_sub_epi32(x, self.alpha)
        };
        let raw = _mm256_sra_epi32(diff, self.shift);
        _mm256_min_epi32(_mm256_max_epi32(raw, self.lo), self.hi)
    }
}

/// Pack the low 32 bits of eight i64 lanes (`a` then `b`) into one
/// ordered 8×i32 vector — the vector form of `acc as i32`.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn pack_lo32(a: __m256i, b: __m256i) -> __m256i {
    // per 128-bit half: [q0_lo, q1_lo, q0_lo, q1_lo]
    let a32 = _mm256_shuffle_epi32::<0b10_00_10_00>(a);
    let b32 = _mm256_shuffle_epi32::<0b10_00_10_00>(b);
    // qwords: [a0a1, b0b1 | a2a3, b2b3]
    let packed = _mm256_unpacklo_epi64(a32, b32);
    // reorder qwords [0,2,1,3] -> [a0a1, a2a3, b0b1, b2b3]
    _mm256_permute4x64_epi64::<0b11_01_10_00>(packed)
}

/// `x² mod 2^64` per i64 lane: `lo² + ((hi·lo) << 33)` with `lo` the
/// unsigned low 32 bits and `hi` the logical high 32 bits.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn sq64(x: __m256i) -> __m256i {
    let lo_sq = _mm256_mul_epu32(x, x);
    let cross = _mm256_mul_epu32(_mm256_srli_epi64::<32>(x), x);
    _mm256_add_epi64(lo_sq, _mm256_slli_epi64::<33>(cross))
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum_epi64(v: __m256i) -> i64 {
    let mut lanes = [0i64; 4];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, v);
    lanes[0]
        .wrapping_add(lanes[1])
        .wrapping_add(lanes[2])
        .wrapping_add(lanes[3])
}

#[target_feature(enable = "avx2")]
unsafe fn axpy_impl(a: i32, w: &[i32], o: &mut [i64]) {
    debug_assert_eq!(w.len(), o.len());
    let av = _mm256_set1_epi64x(a as i64);
    let n8 = w.len() & !7;
    let mut j = 0usize;
    while j < n8 {
        let w8 = _mm256_loadu_si256(w.as_ptr().add(j) as *const __m256i);
        let wlo = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(w8));
        let whi = _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(w8));
        let olo = _mm256_loadu_si256(o.as_ptr().add(j) as *const __m256i);
        let ohi = _mm256_loadu_si256(o.as_ptr().add(j + 4) as *const __m256i);
        _mm256_storeu_si256(
            o.as_mut_ptr().add(j) as *mut __m256i,
            _mm256_add_epi64(olo, _mm256_mul_epi32(wlo, av)),
        );
        _mm256_storeu_si256(
            o.as_mut_ptr().add(j + 4) as *mut __m256i,
            _mm256_add_epi64(ohi, _mm256_mul_epi32(whi, av)),
        );
        j += 8;
    }
    let a = a as i64;
    for jj in n8..w.len() {
        o[jj] += a * w[jj] as i64;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn axpy4_impl(
    a: [i32; 4],
    w: &[i32],
    o0: &mut [i64],
    o1: &mut [i64],
    o2: &mut [i64],
    o3: &mut [i64],
) {
    debug_assert!(w.len() == o0.len() && w.len() == o1.len());
    debug_assert!(w.len() == o2.len() && w.len() == o3.len());
    let a0 = _mm256_set1_epi64x(a[0] as i64);
    let a1 = _mm256_set1_epi64x(a[1] as i64);
    let a2 = _mm256_set1_epi64x(a[2] as i64);
    let a3 = _mm256_set1_epi64x(a[3] as i64);
    let n4 = w.len() & !3;
    let mut j = 0usize;
    while j < n4 {
        // one widened weight load shared by all four output rows — the
        // register-blocked microkernel body
        let wv = _mm256_cvtepi32_epi64(_mm_loadu_si128(w.as_ptr().add(j) as *const __m128i));
        let t0 = _mm256_loadu_si256(o0.as_ptr().add(j) as *const __m256i);
        _mm256_storeu_si256(
            o0.as_mut_ptr().add(j) as *mut __m256i,
            _mm256_add_epi64(t0, _mm256_mul_epi32(wv, a0)),
        );
        let t1 = _mm256_loadu_si256(o1.as_ptr().add(j) as *const __m256i);
        _mm256_storeu_si256(
            o1.as_mut_ptr().add(j) as *mut __m256i,
            _mm256_add_epi64(t1, _mm256_mul_epi32(wv, a1)),
        );
        let t2 = _mm256_loadu_si256(o2.as_ptr().add(j) as *const __m256i);
        _mm256_storeu_si256(
            o2.as_mut_ptr().add(j) as *mut __m256i,
            _mm256_add_epi64(t2, _mm256_mul_epi32(wv, a2)),
        );
        let t3 = _mm256_loadu_si256(o3.as_ptr().add(j) as *const __m256i);
        _mm256_storeu_si256(
            o3.as_mut_ptr().add(j) as *mut __m256i,
            _mm256_add_epi64(t3, _mm256_mul_epi32(wv, a3)),
        );
        j += 4;
    }
    for jj in n4..w.len() {
        let wv = w[jj] as i64;
        o0[jj] += a[0] as i64 * wv;
        o1[jj] += a[1] as i64 * wv;
        o2[jj] += a[2] as i64 * wv;
        o3[jj] += a[3] as i64 * wv;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn requant_impl(rq: &LutTable, acc: &[i64], out: &mut [i32]) {
    debug_assert_eq!(acc.len(), out.len());
    let li = LutIdx::new(rq);
    let mut idx = [0i32; 8];
    let n8 = acc.len() & !7;
    let mut j = 0usize;
    while j < n8 {
        let lo = _mm256_loadu_si256(acc.as_ptr().add(j) as *const __m256i);
        let hi = _mm256_loadu_si256(acc.as_ptr().add(j + 4) as *const __m256i);
        let id = li.idx(pack_lo32(lo, hi));
        _mm256_storeu_si256(idx.as_mut_ptr() as *mut __m256i, id);
        for t in 0..8 {
            out[j + t] = rq.entries[idx[t] as usize] as i32;
        }
        j += 8;
    }
    for t in n8..acc.len() {
        out[t] = lut_i32(rq, acc[t] as i32);
    }
}

#[target_feature(enable = "avx2")]
unsafe fn requant_add_impl(rq: &LutTable, acc: &[i64], out: &mut [i32]) {
    debug_assert_eq!(acc.len(), out.len());
    let li = LutIdx::new(rq);
    let mut idx = [0i32; 8];
    let n8 = acc.len() & !7;
    let mut j = 0usize;
    while j < n8 {
        let lo = _mm256_loadu_si256(acc.as_ptr().add(j) as *const __m256i);
        let hi = _mm256_loadu_si256(acc.as_ptr().add(j + 4) as *const __m256i);
        let id = li.idx(pack_lo32(lo, hi));
        _mm256_storeu_si256(idx.as_mut_ptr() as *mut __m256i, id);
        for t in 0..8 {
            out[j + t] = out[j + t].wrapping_add(rq.entries[idx[t] as usize] as i32);
        }
        j += 8;
    }
    for t in n8..acc.len() {
        out[t] = out[t].wrapping_add(lut_i32(rq, acc[t] as i32));
    }
}

#[target_feature(enable = "avx2")]
unsafe fn dot_impl(a: &[i32], b: &[i32]) -> i64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = _mm256_setzero_si256();
    let n8 = a.len() & !7;
    let mut j = 0usize;
    while j < n8 {
        let av = _mm256_loadu_si256(a.as_ptr().add(j) as *const __m256i);
        let bv = _mm256_loadu_si256(b.as_ptr().add(j) as *const __m256i);
        // even i32 lanes sit in the low halves of the i64 lanes
        let even = _mm256_mul_epi32(av, bv);
        // odd lanes shifted down (mul_epi32 reads only the low 32 bits)
        let odd = _mm256_mul_epi32(_mm256_srli_epi64::<32>(av), _mm256_srli_epi64::<32>(bv));
        acc = _mm256_add_epi64(acc, _mm256_add_epi64(even, odd));
        j += 8;
    }
    let mut tot = hsum_epi64(acc);
    for t in n8..a.len() {
        tot += a[t] as i64 * b[t] as i64;
    }
    tot
}

#[target_feature(enable = "avx2")]
unsafe fn max_impl(x: &[i32]) -> i32 {
    assert!(!x.is_empty(), "max_i32 over an empty row");
    let mut best = i32::MIN;
    let n8 = x.len() & !7;
    if n8 != 0 {
        let mut m = _mm256_loadu_si256(x.as_ptr() as *const __m256i);
        let mut j = 8usize;
        while j < n8 {
            m = _mm256_max_epi32(m, _mm256_loadu_si256(x.as_ptr().add(j) as *const __m256i));
            j += 8;
        }
        let mut lanes = [0i32; 8];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, m);
        for &l in &lanes {
            best = best.max(l);
        }
    }
    for &v in &x[n8..] {
        best = best.max(v);
    }
    best
}

#[target_feature(enable = "avx2")]
unsafe fn exp_lut_sum_impl(exp: &LutTable, m: i32, sc: &[i32], e: &mut [i32]) -> i64 {
    debug_assert_eq!(sc.len(), e.len());
    let li = LutIdx::new(exp);
    let mv = _mm256_set1_epi32(m);
    let mut idx = [0i32; 8];
    let mut tot: i64 = 0;
    let n8 = sc.len() & !7;
    let mut j = 0usize;
    while j < n8 {
        let x = _mm256_loadu_si256(sc.as_ptr().add(j) as *const __m256i);
        let id = li.idx(_mm256_sub_epi32(x, mv));
        _mm256_storeu_si256(idx.as_mut_ptr() as *mut __m256i, id);
        for t in 0..8 {
            let v = exp.entries[idx[t] as usize] as i32;
            e[j + t] = v;
            tot += v as i64;
        }
        j += 8;
    }
    for t in n8..sc.len() {
        let v = lut_i32(exp, sc[t].wrapping_sub(m));
        e[t] = v;
        tot += v as i64;
    }
    tot
}

#[target_feature(enable = "avx2")]
unsafe fn prob_lut_impl(prob: &LutTable, r: i32, e: &[i32], p: &mut [i32]) {
    debug_assert_eq!(e.len(), p.len());
    let li = LutIdx::new(prob);
    let rv = _mm256_set1_epi32(r);
    let mut idx = [0i32; 8];
    let n8 = e.len() & !7;
    let mut j = 0usize;
    while j < n8 {
        let x = _mm256_loadu_si256(e.as_ptr().add(j) as *const __m256i);
        let id = li.idx(_mm256_mullo_epi32(x, rv));
        _mm256_storeu_si256(idx.as_mut_ptr() as *mut __m256i, id);
        for t in 0..8 {
            p[j + t] = prob.entries[idx[t] as usize] as i32;
        }
        j += 8;
    }
    for t in n8..e.len() {
        p[t] = lut_i32(prob, e[t].wrapping_mul(r));
    }
}

#[target_feature(enable = "avx2")]
unsafe fn sum_impl(row: &[i32]) -> i64 {
    let mut acc = _mm256_setzero_si256();
    let n8 = row.len() & !7;
    let mut j = 0usize;
    while j < n8 {
        let x8 = _mm256_loadu_si256(row.as_ptr().add(j) as *const __m256i);
        let lo = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(x8));
        let hi = _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(x8));
        acc = _mm256_add_epi64(acc, _mm256_add_epi64(lo, hi));
        j += 8;
    }
    let mut tot = hsum_epi64(acc);
    for &v in &row[n8..] {
        tot += v as i64;
    }
    tot
}

#[target_feature(enable = "avx2")]
unsafe fn ln_center_impl(d: i32, sum: i64, guard: u32, row: &[i32], c: &mut [i64]) -> i64 {
    debug_assert_eq!(row.len(), c.len());
    let dv = _mm256_set1_epi32(d);
    let sv = _mm256_set1_epi64x(sum);
    // AVX2 has no 64-bit arithmetic shift: bias into the unsigned range,
    // shift logically, subtract the shifted bias
    let bias = _mm256_set1_epi64x(i64::MIN);
    let cnt = _mm_cvtsi32_si128(guard as i32);
    let bias_s = _mm256_srl_epi64(bias, cnt);
    let mut vacc = _mm256_setzero_si256();
    let n8 = row.len() & !7;
    let mut j = 0usize;
    while j < n8 {
        let x8 = _mm256_loadu_si256(row.as_ptr().add(j) as *const __m256i);
        let prod = _mm256_mullo_epi32(x8, dv);
        let plo = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(prod));
        let phi = _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(prod));
        let clo = _mm256_sub_epi64(plo, sv);
        let chi = _mm256_sub_epi64(phi, sv);
        _mm256_storeu_si256(c.as_mut_ptr().add(j) as *mut __m256i, clo);
        _mm256_storeu_si256(c.as_mut_ptr().add(j + 4) as *mut __m256i, chi);
        let glo = _mm256_sub_epi64(_mm256_srl_epi64(_mm256_add_epi64(clo, bias), cnt), bias_s);
        let ghi = _mm256_sub_epi64(_mm256_srl_epi64(_mm256_add_epi64(chi, bias), cnt), bias_s);
        vacc = _mm256_add_epi64(vacc, sq64(glo));
        vacc = _mm256_add_epi64(vacc, sq64(ghi));
        j += 8;
    }
    let mut v = hsum_epi64(vacc);
    for jj in n8..row.len() {
        let cj = d.wrapping_mul(row[jj]) as i64 - sum;
        c[jj] = cj;
        let cg = cj >> guard;
        v += cg * cg;
    }
    v
}

#[target_feature(enable = "avx2")]
unsafe fn ln_finish_impl(rq: &LutTable, r: i64, c: &[i64], out: &mut [i32]) {
    debug_assert_eq!(c.len(), out.len());
    let li = LutIdx::new(rq);
    // only the low 32 bits of c[j] * r survive the `as i32` narrowing
    let rv = _mm256_set1_epi32(r as i32);
    let mut idx = [0i32; 8];
    let n8 = c.len() & !7;
    let mut j = 0usize;
    while j < n8 {
        let lo = _mm256_loadu_si256(c.as_ptr().add(j) as *const __m256i);
        let hi = _mm256_loadu_si256(c.as_ptr().add(j + 4) as *const __m256i);
        let prod = _mm256_mullo_epi32(pack_lo32(lo, hi), rv);
        let id = li.idx(prod);
        _mm256_storeu_si256(idx.as_mut_ptr() as *mut __m256i, id);
        for t in 0..8 {
            out[j + t] = rq.entries[idx[t] as usize] as i32;
        }
        j += 8;
    }
    for t in n8..c.len() {
        out[t] = lut_i32(rq, (c[t] as i32).wrapping_mul(r as i32));
    }
}
