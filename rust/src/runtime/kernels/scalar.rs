//! The scalar kernel backend — bit-for-bit the pre-refactor inner
//! loops, kept forever as the **oracle** every SIMD backend is
//! differentially tested against (and the forced backend under
//! `HGPIPE_KERNELS=scalar`). No `unsafe`, no intrinsics; the fixed
//! 8-wide unroll in [`axpy`] is the only concession to the optimizer.

use crate::lut::LutTable;

use super::{lut_i32, Kernels};

pub(super) static KERNELS: Kernels = Kernels {
    name: "scalar",
    axpy,
    axpy4,
    requant,
    requant_add,
    dot_i32,
    max_i32,
    exp_lut_sum,
    prob_lut,
    sum_i32,
    ln_center,
    ln_finish,
};

/// `o[j] += a * w[j]` over one packed panel row, fixed 8-wide unroll —
/// the GEMM microkernel's inner loop (formerly `gemm::axpy8`).
#[inline(always)]
pub(super) fn axpy(a: i32, w: &[i32], o: &mut [i64]) {
    debug_assert_eq!(w.len(), o.len());
    let a = a as i64;
    let n8 = w.len() & !7;
    let (w8, w_tail) = w.split_at(n8);
    let (o8, o_tail) = o.split_at_mut(n8);
    for (oc, wc) in o8.chunks_exact_mut(8).zip(w8.chunks_exact(8)) {
        oc[0] += a * wc[0] as i64;
        oc[1] += a * wc[1] as i64;
        oc[2] += a * wc[2] as i64;
        oc[3] += a * wc[3] as i64;
        oc[4] += a * wc[4] as i64;
        oc[5] += a * wc[5] as i64;
        oc[6] += a * wc[6] as i64;
        oc[7] += a * wc[7] as i64;
    }
    for (ov, &wv) in o_tail.iter_mut().zip(w_tail) {
        *ov += a * wv as i64;
    }
}

/// Four [`axpy`]s sharing one weight row — the 4-row register-blocked
/// microkernel body (formerly the inner loop of `gemm::rows4_into`).
#[inline(always)]
pub(super) fn axpy4(
    a: [i32; 4],
    w: &[i32],
    o0: &mut [i64],
    o1: &mut [i64],
    o2: &mut [i64],
    o3: &mut [i64],
) {
    axpy(a[0], w, o0);
    axpy(a[1], w, o1);
    axpy(a[2], w, o2);
    axpy(a[3], w, o3);
}

/// Fused requant epilogue over one accumulator band (formerly the tail
/// loop of `ops::gemm_rq_into`).
#[inline(always)]
pub(super) fn requant(rq: &LutTable, acc: &[i64], out: &mut [i32]) {
    debug_assert_eq!(acc.len(), out.len());
    for (o, &a) in out.iter_mut().zip(acc) {
        *o = lut_i32(rq, a as i32);
    }
}

/// Requant epilogue fused with the residual add (formerly the tail loop
/// of `ops::gemm_rq_add_into`).
#[inline(always)]
pub(super) fn requant_add(rq: &LutTable, acc: &[i64], out: &mut [i32]) {
    debug_assert_eq!(acc.len(), out.len());
    for (o, &a) in out.iter_mut().zip(acc) {
        *o = o.wrapping_add(lut_i32(rq, a as i32));
    }
}

/// One attention score: `Σ q[i] * k[i]` with exact i64 accumulation.
#[inline(always)]
pub(super) fn dot_i32(a: &[i32], b: &[i32]) -> i64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as i64 * y as i64).sum()
}

/// Max over a non-empty slice — the softmax max-subtract.
#[inline(always)]
pub(super) fn max_i32(x: &[i32]) -> i32 {
    *x.iter().max().expect("max_i32 over an empty row")
}

/// Softmax exp pass: `e[i] = lut(exp, sc[i] - m)`, returns `Σ e[i]`.
#[inline(always)]
pub(super) fn exp_lut_sum(exp: &LutTable, m: i32, sc: &[i32], e: &mut [i32]) -> i64 {
    debug_assert_eq!(sc.len(), e.len());
    let mut tot: i64 = 0;
    for (ev, &s) in e.iter_mut().zip(sc) {
        *ev = lut_i32(exp, s.wrapping_sub(m));
        tot += *ev as i64;
    }
    tot
}

/// Softmax probability requant: `p[i] = lut(prob, e[i] * r)`.
#[inline(always)]
pub(super) fn prob_lut(prob: &LutTable, r: i32, e: &[i32], p: &mut [i32]) {
    debug_assert_eq!(e.len(), p.len());
    for (pv, &ev) in p.iter_mut().zip(e) {
        *pv = lut_i32(prob, ev.wrapping_mul(r));
    }
}

/// LayerNorm row sum.
#[inline(always)]
pub(super) fn sum_i32(row: &[i32]) -> i64 {
    row.iter().map(|&v| v as i64).sum()
}

/// LayerNorm center pass: fills `c[j] = d*row[j] - sum` and returns the
/// guarded variance accumulator `Σ (c[j] >> guard)²`.
#[inline(always)]
pub(super) fn ln_center(d: i32, sum: i64, guard: u32, row: &[i32], c: &mut [i64]) -> i64 {
    debug_assert_eq!(row.len(), c.len());
    let mut v: i64 = 0;
    for (cj, &xv) in c.iter_mut().zip(row) {
        *cj = d.wrapping_mul(xv) as i64 - sum;
        let cg = *cj >> guard;
        v += cg * cg;
    }
    v
}

/// LayerNorm output pass: `out[j] = lut(rq, (c[j] * r) as i32)`.
#[inline(always)]
pub(super) fn ln_finish(rq: &LutTable, r: i64, c: &[i64], out: &mut [i32]) {
    debug_assert_eq!(c.len(), out.len());
    for (o, &cj) in out.iter_mut().zip(c) {
        *o = lut_i32(rq, (cj * r) as i32);
    }
}
