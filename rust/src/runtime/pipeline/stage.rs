//! A resident pipeline stage: one persistent worker thread pinned to a
//! contiguous slice of the model (optionally the patch-embed front and
//! the classifier head), with its own scratch box and — when the lane
//! budget allows — its own private [`LanePool`] for fine-grained
//! token-row banding inside the stage.
//!
//! The stage loop is the paper's decentralized FSM in software: recv an
//! activation tile, run the stage's slice over it in place, send it on.
//! No stage knows the global schedule; the bounded channels alone
//! provide ordering and backpressure.

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Instant;

use crate::runtime::fabric::{Exec, LanePool, LaneScratch};
use crate::runtime::interpreter::{OpClock, OpProfile, QuantViT};
use crate::runtime::kernels::Kernels;
use crate::telemetry::{TraceBuf, TraceEvent};

use super::channel;

/// The unit flowing through the pipeline: one image's buffers. The
/// residual stream `x` is updated **in place** by every stage (the
/// dataflow is residual, so the same tile flows end to end), and the
/// whole struct returns to the feeder's recycle bag after the head
/// stage — steady-state pipelining allocates no activation buffers.
#[derive(Default)]
pub(crate) struct Work {
    pub(crate) idx: usize,
    /// f32 input tokens — consumed by the embed stage, dead weight (a
    /// vec header riding along for recycling) afterwards.
    pub(crate) tokens: Vec<f32>,
    /// The int32 residual stream, `tokens x dim`.
    pub(crate) x: Vec<i32>,
}

/// What one stage executes: which encoder blocks (possibly an empty
/// range — the work-proportional partition dedicates a block-less stage
/// to patch-embed when that evens out occupancy), and whether the
/// patch-embed front and/or the classifier head are fused in.
pub(crate) struct StageSpec {
    pub(crate) embed: bool,
    pub(crate) head: bool,
    pub(crate) blocks: Range<usize>,
}

/// Occupancy counters one stage publishes (channel stall counters live
/// on the channels themselves).
#[derive(Default)]
pub(crate) struct StageShared {
    pub(crate) images: AtomicU64,
    /// Nanoseconds spent computing (excludes time parked on channels).
    pub(crate) busy_ns: AtomicU64,
    /// Panic message of a kernel that died in this stage — surfaced by
    /// `run_batch` so a stage death reports its original cause, not
    /// just a generic channel-termination error (the pipeline twin of
    /// the fabric's re-raise-original-panic contract).
    pub(crate) panic_msg: Mutex<Option<String>>,
}

/// Where a stage's finished tile goes: the next stage's bounded FIFO,
/// or (for the head stage) the feeder's unbounded logits channel plus
/// the buffer recycle bag.
pub(crate) enum StageOut {
    Next(channel::Sender<Work>),
    Done {
        logits: std::sync::mpsc::Sender<(usize, Vec<f64>)>,
        recycle: Arc<Mutex<Vec<Work>>>,
    },
}

/// The stage worker body. Runs until its input channel reports
/// end-of-stream (pipeline shutdown) or its output side disappears (a
/// downstream stage died) — either way it returns, dropping its
/// endpoints, which cascades the shutdown both directions.
pub(crate) fn stage_loop(
    net: Arc<QuantViT>,
    spec: StageSpec,
    rx: channel::Receiver<Work>,
    tx: StageOut,
    shared: Arc<StageShared>,
    // the stage's private fabric share, created by `Pipeline::new` on
    // the loading thread so a worker-spawn failure is a *load* error,
    // not a silent post-load stage death
    pool: Option<LanePool>,
    // the kernel backend resolved once at model load; serial stages
    // drive it directly, pooled stages carry it inside their pool
    kernels: &'static Kernels,
    // trace buffer + named tid when telemetry is on; `None` keeps the
    // loop on the original clock-free path (plain send/recv, detached
    // op clock, zero Instant reads beyond the busy_ns accounting)
    mut tele: Option<(TraceBuf, u64)>,
) {
    // stage-resident state: the scratch box (the op clock is per tile —
    // detached unless this stage traces, so the segments' lap calls
    // cost zero clock reads on the untraced path)
    let mut scratch = Box::<LaneScratch>::default();

    loop {
        // a recv that parks on an empty input FIFO is a fill/drain
        // bubble — traced stages record the parked interval as a span
        let (got, stall_in) = match &tele {
            Some(_) => rx.recv_timed(),
            None => (rx.recv(), None),
        };
        let Some(mut w) = got else { break };
        if let Some((buf, tid)) = &mut tele {
            if let Some((s, e)) = stall_in {
                let ts = buf.ts(s);
                let dur = buf.ts(e).saturating_sub(ts);
                let pid = buf.pid();
                buf.push(TraceEvent::span("blocked_recv", "stall", pid, *tid, ts, dur));
            }
        }
        let t0 = Instant::now();
        let traced = tele.is_some();
        let mut prof = OpProfile::default();
        // contain a panicking kernel: park its message where run_batch
        // can attach it to the error, then exit (dropping the endpoints
        // cascades the shutdown; the stage is not reusable after this)
        let computed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let LaneScratch { band, pass } = &mut *scratch;
            let mut exec = match &pool {
                Some(p) => Exec::pool(p),
                None => Exec::serial(band, kernels),
            };
            let mut clk = match traced {
                true => OpClock::attached(&mut prof),
                false => OpClock::detached(),
            };
            if spec.embed {
                net.embed_into(&w.tokens, &mut w.x, pass, &mut exec, &mut clk);
            }
            for bi in spec.blocks.clone() {
                net.block_into(bi, &mut w.x, pass, &mut exec, &mut clk);
            }
            if spec.head {
                Some(net.head_into(&w.x, pass, &mut exec, &mut clk))
            } else {
                None
            }
        }));
        let logits = match computed {
            Ok(l) => l,
            Err(p) => {
                let msg = if let Some(s) = p.downcast_ref::<&str>() {
                    (*s).to_string()
                } else if let Some(s) = p.downcast_ref::<String>() {
                    s.clone()
                } else {
                    "non-string panic payload".to_string()
                };
                *shared.panic_msg.lock().unwrap_or_else(PoisonError::into_inner) = Some(msg);
                break;
            }
        };
        shared.busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        shared.images.fetch_add(1, Ordering::Relaxed);
        if let Some((buf, tid)) = &mut tele {
            // one residency span per tile, with the per-op kernel spans
            // nested inside it
            let ts = buf.ts(t0);
            let end = buf.now().max(ts);
            let pid = buf.pid();
            buf.push(
                TraceEvent::span("tile", "stage", pid, *tid, ts, end - ts)
                    .with_id(w.idx as u64),
            );
            buf.push_op_spans(*tid, ts, end, &prof.named_ms());
        }

        match &tx {
            StageOut::Next(next) => {
                // a send parked on a full output FIFO is backpressure —
                // traced stages record the parked interval
                let sent = match &tele {
                    Some(_) => next.send_timed(w),
                    None => next.send(w).map(|()| None),
                };
                match sent {
                    Ok(stall_out) => {
                        if let Some((buf, tid)) = &mut tele {
                            if let Some((s, e)) = stall_out {
                                let ts = buf.ts(s);
                                let dur = buf.ts(e).saturating_sub(ts);
                                let pid = buf.pid();
                                buf.push(TraceEvent::span(
                                    "blocked_send",
                                    "stall",
                                    pid,
                                    *tid,
                                    ts,
                                    dur,
                                ));
                            }
                        }
                    }
                    Err(_) => {
                        // downstream stage is gone; stop consuming so the
                        // shutdown cascades upstream through our rx drop
                        break;
                    }
                }
            }
            StageOut::Done { logits: out, recycle } => {
                let l = logits.expect("head stage produced no logits");
                // a failed send means the feeder is gone (drop-mid-stream):
                // keep draining so upstream stages empty out cleanly
                let _ = out.send((w.idx, l));
                recycle.lock().unwrap_or_else(PoisonError::into_inner).push(w);
            }
        }
        if let Some((buf, _)) = &mut tele {
            buf.maybe_flush(256);
        }
    }
    // TraceBuf's Drop flushes whatever the ring still holds
}
