//! Bounded SPSC channels between resident pipeline stages — the
//! software twin of the paper's inter-stage FIFOs.
//!
//! A thin wrapper over `std::sync::mpsc::sync_channel` that adds the
//! **stall counters** the pipeline's occupancy accounting needs; the
//! blocking, bounding and disconnect semantics are std's, not bespoke
//! concurrency code:
//!
//! * **Bounded**: `send` blocks while the queue holds `cap` items — the
//!   paper's backpressure. No global barrier exists anywhere in the
//!   pipeline; a fast stage simply fills its output FIFO and parks.
//! * **Close-on-drop, both sides**: dropping the [`Sender`] lets the
//!   receiver drain the queue and then observe end-of-stream (`recv`
//!   returns `None`); dropping the [`Receiver`] fails every subsequent
//!   or parked `send` with the rejected item. Stage shutdown therefore
//!   cascades downstream (sender drops) *and* unblocks upstream
//!   (receiver drops) — no stage can wedge on a peer that is gone.
//! * **Counted stalls**: a `send` that found the queue full increments
//!   `blocked_sends` (backpressure), a `recv` that found it empty
//!   increments `blocked_recvs` (the stage sat *empty* — these are the
//!   pipeline's fill/drain bubbles plus any steady-state imbalance).
//!   `benches/interpreter.rs` diffs these counters around its timed
//!   window.
//!
//! The channel is used single-producer single-consumer by construction
//! (each endpoint moves into exactly one stage thread); `SyncSender`
//! being clonable is simply never exercised.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, SyncSender, TryRecvError, TrySendError};
use std::sync::Arc;
use std::time::Instant;

/// Stall counters for one channel, shared with the pipeline's stats
/// snapshot (the channel endpoints move into stage threads; the
/// counters stay reachable).
#[derive(Default)]
pub(crate) struct ChannelStats {
    /// Items ever enqueued.
    pub(crate) sends: AtomicU64,
    /// `send` calls that found the queue full (backpressure stalls).
    pub(crate) blocked_sends: AtomicU64,
    /// `recv` calls that found the queue empty (bubble stalls).
    pub(crate) blocked_recvs: AtomicU64,
}

/// Create a bounded SPSC channel of depth `cap` (clamped to at least 1 —
/// depth 0 would be a rendezvous channel, i.e. no decoupling at all).
/// Returns the two endpoints plus the shared stall counters.
pub(crate) fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>, Arc<ChannelStats>) {
    let stats = Arc::new(ChannelStats::default());
    let (tx, rx) = mpsc::sync_channel(cap.max(1));
    (
        Sender { tx, stats: stats.clone() },
        Receiver { rx, stats: stats.clone() },
        stats,
    )
}

/// Producing endpoint.
pub(crate) struct Sender<T> {
    tx: SyncSender<T>,
    stats: Arc<ChannelStats>,
}

impl<T> Sender<T> {
    /// Enqueue `t`, blocking while the queue is full. Returns `Err(t)`
    /// if the receiver is gone (pipeline shutting down or a downstream
    /// stage died) — the item is handed back so its buffers can be
    /// recycled or dropped deliberately.
    pub(crate) fn send(&self, t: T) -> Result<(), T> {
        let t = match self.tx.try_send(t) {
            Ok(()) => {
                self.stats.sends.fetch_add(1, Ordering::Relaxed);
                return Ok(());
            }
            Err(TrySendError::Disconnected(t)) => return Err(t),
            Err(TrySendError::Full(t)) => {
                self.stats.blocked_sends.fetch_add(1, Ordering::Relaxed);
                t
            }
        };
        match self.tx.send(t) {
            Ok(()) => {
                self.stats.sends.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(mpsc::SendError(t)) => Err(t),
        }
    }

    /// As [`send`](Sender::send), additionally reporting the wall-clock
    /// interval the call spent parked on a full queue (`None` when it
    /// did not block). The clock is read only on the blocked path, so
    /// the unblocked fast path stays identical to `send` — this is the
    /// tracing variant the stage loop switches to when telemetry is on.
    pub(crate) fn send_timed(&self, t: T) -> Result<Option<(Instant, Instant)>, T> {
        let t = match self.tx.try_send(t) {
            Ok(()) => {
                self.stats.sends.fetch_add(1, Ordering::Relaxed);
                return Ok(None);
            }
            Err(TrySendError::Disconnected(t)) => return Err(t),
            Err(TrySendError::Full(t)) => {
                self.stats.blocked_sends.fetch_add(1, Ordering::Relaxed);
                t
            }
        };
        let t0 = Instant::now();
        match self.tx.send(t) {
            Ok(()) => {
                self.stats.sends.fetch_add(1, Ordering::Relaxed);
                Ok(Some((t0, Instant::now())))
            }
            Err(mpsc::SendError(t)) => Err(t),
        }
    }
}

/// Consuming endpoint.
pub(crate) struct Receiver<T> {
    rx: mpsc::Receiver<T>,
    stats: Arc<ChannelStats>,
}

impl<T> Receiver<T> {
    /// Dequeue the next item, blocking while the queue is empty. `None`
    /// once the sender is gone *and* the queue is drained — in-flight
    /// items are always delivered before end-of-stream.
    pub(crate) fn recv(&self) -> Option<T> {
        match self.rx.try_recv() {
            Ok(t) => Some(t),
            Err(TryRecvError::Disconnected) => None,
            Err(TryRecvError::Empty) => {
                self.stats.blocked_recvs.fetch_add(1, Ordering::Relaxed);
                self.rx.recv().ok()
            }
        }
    }

    /// As [`recv`](Receiver::recv), additionally reporting the
    /// wall-clock interval spent parked on an empty queue (`None` when
    /// an item was ready). Clock reads only happen on the blocked path.
    pub(crate) fn recv_timed(&self) -> (Option<T>, Option<(Instant, Instant)>) {
        match self.rx.try_recv() {
            Ok(t) => (Some(t), None),
            Err(TryRecvError::Disconnected) => (None, None),
            Err(TryRecvError::Empty) => {
                self.stats.blocked_recvs.fetch_add(1, Ordering::Relaxed);
                let t0 = Instant::now();
                let got = self.rx.recv().ok();
                (got, Some((t0, Instant::now())))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_within_capacity() {
        let (tx, rx, stats) = bounded(4);
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        for i in 0..4 {
            assert_eq!(rx.recv(), Some(i));
        }
        assert_eq!(stats.sends.load(Ordering::Relaxed), 4);
        assert_eq!(stats.blocked_sends.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn send_blocks_at_capacity_until_a_recv() {
        let (tx, rx, stats) = bounded(1);
        tx.send(1u32).unwrap();
        let h = std::thread::spawn(move || {
            tx.send(2).unwrap(); // must park until the main thread recvs
            tx // keep the sender alive until joined
        });
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        let _tx = h.join().unwrap();
        assert_eq!(stats.sends.load(Ordering::Relaxed), 2);
        assert_eq!(stats.blocked_sends.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn receiver_sees_eos_after_sender_drop_and_drain() {
        let (tx, rx, _) = bounded(2);
        tx.send("a").unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Some("a"));
        assert_eq!(rx.recv(), None);
        assert_eq!(rx.recv(), None, "EOS is sticky");
    }

    #[test]
    fn send_fails_with_item_after_receiver_drop() {
        let (tx, rx, _) = bounded(2);
        drop(rx);
        assert_eq!(tx.send(7), Err(7));
    }

    #[test]
    fn blocked_sender_unblocks_on_receiver_drop() {
        let (tx, rx, stats) = bounded(1);
        tx.send(1u8).unwrap();
        let h = std::thread::spawn(move || tx.send(2));
        // wait until the sender has actually hit the full queue (the
        // stall is counted before parking), then kill the receiver: the
        // parked send must wake and hand back its item
        while stats.blocked_sends.load(Ordering::Relaxed) == 0 {
            std::thread::yield_now();
        }
        drop(rx);
        assert_eq!(h.join().unwrap(), Err(2));
    }

    #[test]
    fn blocked_receiver_counts_a_bubble() {
        let (tx, rx, stats) = bounded(2);
        let h = std::thread::spawn(move || rx.recv());
        // wait until the receiver has actually found the queue empty
        // (counted before parking), then feed it — deterministic, no
        // sleep race
        while stats.blocked_recvs.load(Ordering::Relaxed) == 0 {
            std::thread::yield_now();
        }
        tx.send(9u8).unwrap();
        assert_eq!(h.join().unwrap(), Some(9));
        assert_eq!(stats.blocked_recvs.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn timed_send_reports_the_blocked_interval() {
        let (tx, rx, stats) = bounded(1);
        assert_eq!(tx.send_timed(1u8).unwrap(), None, "uncontended send does not block");
        let h = std::thread::spawn(move || tx.send_timed(2));
        while stats.blocked_sends.load(Ordering::Relaxed) == 0 {
            std::thread::yield_now();
        }
        assert_eq!(rx.recv(), Some(1));
        let stall = h.join().unwrap().unwrap().expect("blocked send reports an interval");
        assert!(stall.1 >= stall.0);
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(stats.sends.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn timed_recv_reports_the_blocked_interval() {
        let (tx, rx, stats) = bounded(2);
        tx.send(5u8).unwrap();
        assert_eq!(rx.recv_timed(), (Some(5), None), "ready item does not block");
        let h = std::thread::spawn(move || rx.recv_timed());
        while stats.blocked_recvs.load(Ordering::Relaxed) == 0 {
            std::thread::yield_now();
        }
        tx.send(6u8).unwrap();
        let (got, stall) = h.join().unwrap();
        assert_eq!(got, Some(6));
        let (s, e) = stall.expect("blocked recv reports an interval");
        assert!(e >= s);
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let (tx, rx, _) = bounded(0);
        tx.send(1).unwrap(); // would rendezvous-block at true depth 0
        assert_eq!(rx.recv(), Some(1));
    }
}
