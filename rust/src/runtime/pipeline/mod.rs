//! The hybrid-grained pipeline executor: the paper's architecture as a
//! software execution mode.
//!
//! Where the lane-parallel interpreter runs the ViT *temporally* — one
//! kernel at a time over the whole model, all lanes on the same layer —
//! this module **spatially unrolls** the model into resident stages:
//!
//! * **Coarse grain**: the encoder blocks are partitioned into
//!   contiguous slices, each pinned to its own persistent worker thread
//!   ([`stage`]) with stage-resident scratch. The patch-embed front
//!   rides with the first stage, the classifier head with the last.
//!   Different images occupy different stages simultaneously, so
//!   steady-state throughput is set by the **slowest stage**, not the
//!   sum of all layers. Each stage only ever touches its own slice's
//!   packed GEMM panels — the software analogue of weights resident per
//!   processing element (ME-ViT's single-load discipline).
//! * **Fine grain**: inside a stage, token-row bands stream through the
//!   GEMM/LayerNorm/attention kernels with the requant LUT epilogue
//!   fused into the producing band, either serially in the stage's own
//!   scratch or across the stage's private [`LanePool`] share of the
//!   lane budget.
//! * **Bounded queues, no barriers**: stages are connected by bounded
//!   SPSC [`channel`]s carrying whole activation tiles (the int32
//!   residual stream, updated in place). Backpressure from a full queue
//!   is the only synchronization; fill/drain bubbles and backpressure
//!   stalls are counted per channel and reported in
//!   [`PipelineStats`].
//!
//! Bit-exactness: stages execute the *same* forward-pass segments
//! ([`QuantViT::embed_into`] / `block_into` / `head_into`) the
//! monolithic forward chains, so pipeline logits are bit-identical to
//! the lane-parallel and scalar paths at every stage count, queue depth
//! and lane split — `tests/pipeline_golden.rs` pins stage counts 1, 2,
//! 4 and max against the golden fixture.
//!
//! Select the mode with `RuntimeConfig::with_mode(ExecMode::Pipeline
//! { .. })`, the `--pipeline [--stages N] [--queue-depth N]` CLI flags,
//! or `HGPIPE_MODE=pipeline` (read-only env fallback, used by the CI
//! matrix).

pub(crate) mod channel;
mod stage;

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Instant;

use crate::artifacts::Manifest;
use crate::runtime::fabric::LanePool;
use crate::runtime::interpreter::{self, QuantViT};
use crate::runtime::{ExecStats, Executor, LoadedModel};
use channel::ChannelStats;
use stage::{StageOut, StageShared, StageSpec, Work};

/// Default inter-stage FIFO depth (in activation tiles). Depth 1 is the
/// minimum for rate decoupling; 2 absorbs one tile of jitter per hop —
/// the paper's deep-FIFO sizing question, at tile granularity.
pub const DEFAULT_QUEUE_DEPTH: usize = 2;

/// Count of live resident stage threads across the process (the
/// pipeline twin of `LanePool::live_workers`); dropping a [`Pipeline`]
/// joins its stages, so the liveness tests pin "no leaked threads" on
/// this going back to baseline.
static LIVE_STAGES: AtomicUsize = AtomicUsize::new(0);

/// Process-wide count of live pipeline stage threads.
pub fn live_stages() -> usize {
    LIVE_STAGES.load(Ordering::SeqCst)
}

/// How to spatially unroll a model.
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    /// Requested resident stage count. `0` means auto: one stage per
    /// encoder block (the paper's fully-unrolled layout). Clamped to
    /// `[1, depth]` — more stages than blocks would sit empty.
    pub stages: usize,
    /// Bounded inter-stage FIFO depth, in tiles (min 1).
    pub queue_depth: usize,
    /// Total fine-grained lane budget, split evenly across stages
    /// (each stage gets at least its own thread).
    pub lanes: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self { stages: 0, queue_depth: DEFAULT_QUEUE_DEPTH, lanes: 1 }
    }
}

fn resolve_stage_count(depth: usize, requested: usize) -> usize {
    let max = depth.max(1);
    if requested == 0 {
        max
    } else {
        requested.clamp(1, max)
    }
}

/// Near-even contiguous partition of `depth` blocks into `stages`
/// slices (the first `depth % stages` slices take one extra block).
fn partition(depth: usize, stages: usize) -> Vec<Range<usize>> {
    let base = depth / stages;
    let extra = depth % stages;
    let mut parts = Vec::with_capacity(stages);
    let mut b0 = 0usize;
    for si in 0..stages {
        let take = base + usize::from(si < extra);
        parts.push(b0..b0 + take);
        b0 += take;
    }
    parts
}

/// One stage's cumulative counters, snapshotted.
#[derive(Debug, Clone)]
pub struct StageSnapshot {
    pub name: String,
    /// Encoder blocks resident in this stage, `[start, end)`.
    pub blocks: (usize, usize),
    /// Fine-grained lanes inside the stage (1 = the stage thread alone).
    pub lanes: usize,
    pub images: u64,
    /// Time spent computing (excludes time parked on channels).
    pub busy_ms: f64,
    /// Input-FIFO stalls: the stage sat empty (fill/drain bubbles plus
    /// steady-state starvation).
    pub stalls_empty: u64,
    /// Output-FIFO stalls: the stage was backpressured by a full queue.
    pub stalls_full: u64,
}

/// Cumulative pipeline counters. Diff two snapshots
/// ([`PipelineStats::delta`]) to attribute occupancy and bubbles to a
/// measurement window, as `benches/interpreter.rs` does.
#[derive(Debug, Clone)]
pub struct PipelineStats {
    pub stages: Vec<StageSnapshot>,
    /// Total input-FIFO stalls across stages — the pipeline's fill and
    /// drain bubbles (plus any steady-state starvation of a fast stage).
    pub fill_drain_bubbles: u64,
    /// Total output-FIFO backpressure stalls across stages.
    pub backpressure_stalls: u64,
}

impl PipelineStats {
    /// Counters accumulated since `earlier` (same pipeline, same shape).
    pub fn delta(&self, earlier: &PipelineStats) -> PipelineStats {
        let stages = self
            .stages
            .iter()
            .zip(&earlier.stages)
            .map(|(now, was)| StageSnapshot {
                name: now.name.clone(),
                blocks: now.blocks,
                lanes: now.lanes,
                images: now.images - was.images,
                busy_ms: now.busy_ms - was.busy_ms,
                stalls_empty: now.stalls_empty - was.stalls_empty,
                stalls_full: now.stalls_full - was.stalls_full,
            })
            .collect::<Vec<_>>();
        let fill_drain_bubbles = stages.iter().map(|s| s.stalls_empty).sum();
        let backpressure_stalls = stages.iter().map(|s| s.stalls_full).sum();
        PipelineStats { stages, fill_drain_bubbles, backpressure_stalls }
    }
}

/// Per-stage bookkeeping the owning [`Pipeline`] keeps after the
/// endpoints moved into the stage threads.
struct StageMeta {
    name: String,
    blocks: Range<usize>,
    lanes: usize,
    shared: Arc<StageShared>,
    /// Stats of the stage's *input* channel (stalls_empty).
    in_stats: Arc<ChannelStats>,
    /// Stats of the stage's *output* channel; `None` for the head stage
    /// (its output is the unbounded logits channel).
    out_stats: Option<Arc<ChannelStats>>,
}

/// Feeder-side state, serialized under one mutex: batches are fed and
/// drained by exactly one caller at a time (the pipeline is SPSC end to
/// end).
struct Feeder {
    /// `None` once the pipeline began shutting down.
    input: Option<channel::Sender<Work>>,
    output: std::sync::mpsc::Receiver<(usize, Vec<f64>)>,
    recycle: Arc<Mutex<Vec<Work>>>,
}

/// A spatially-unrolled, queue-connected instance of one model: the
/// resident stage threads, their channels, and the feeder endpoints.
///
/// All batch-variant executors of a loaded model share one `Pipeline`
/// via `Arc`; dropping the last handle closes the input channel, lets
/// every stage drain, and joins the stage threads deterministically.
pub struct Pipeline {
    net: Arc<QuantViT>,
    feeder: Mutex<Feeder>,
    meta: Vec<StageMeta>,
    workers: Vec<std::thread::JoinHandle<()>>,
    queue_depth: usize,
}

impl Pipeline {
    /// Spatially unroll `net` into resident stages. Threads spawn here
    /// and park on their input FIFOs until images arrive.
    pub fn new(net: Arc<QuantViT>, cfg: PipelineConfig) -> Self {
        let depth = net.depth;
        let stages = resolve_stage_count(depth, cfg.stages);
        let queue_depth = cfg.queue_depth.max(1);
        let per_stage_lanes = (cfg.lanes / stages).max(1);
        let parts = partition(depth, stages);

        let (in_tx, in_rx, in_stats) = channel::bounded::<Work>(queue_depth);
        let (out_tx, out_rx) = std::sync::mpsc::channel::<(usize, Vec<f64>)>();
        let recycle = Arc::new(Mutex::new(Vec::<Work>::new()));

        let mut meta = Vec::with_capacity(stages);
        let mut workers = Vec::with_capacity(stages);
        let mut cur_rx = Some(in_rx);
        let mut cur_in_stats = in_stats;
        for (si, blocks) in parts.into_iter().enumerate() {
            // the stage's private fabric share is created HERE, on the
            // loading thread: a worker-spawn failure must be a load
            // error (like lane-parallel mode), never a silent stage
            // death after the load reported success. On panic, close
            // the feed and join the stages spawned so far first.
            let stage_pool = match std::panic::catch_unwind(|| {
                (per_stage_lanes > 1).then(|| LanePool::new(per_stage_lanes))
            }) {
                Ok(p) => p,
                Err(payload) => {
                    drop(cur_rx.take());
                    drop(in_tx);
                    for h in workers.drain(..) {
                        let _ = h.join();
                    }
                    std::panic::resume_unwind(payload);
                }
            };
            let spec = StageSpec {
                embed: si == 0,
                head: si + 1 == stages,
                blocks: blocks.clone(),
            };
            let (out, next_rx, out_stats) = if si + 1 < stages {
                let (tx, rxn, cs) = channel::bounded::<Work>(queue_depth);
                (StageOut::Next(tx), Some(rxn), Some(cs))
            } else {
                (
                    StageOut::Done { logits: out_tx.clone(), recycle: recycle.clone() },
                    None,
                    None,
                )
            };
            let shared = Arc::new(StageShared::default());
            let rx_stage = cur_rx.take().expect("one receiver per stage");
            let net2 = net.clone();
            let shared2 = shared.clone();
            LIVE_STAGES.fetch_add(1, Ordering::SeqCst);
            let handle = std::thread::Builder::new()
                .name(format!("hgpipe-stage-{si}"))
                .spawn(move || {
                    // decrement on every exit path, including unwinding
                    struct Live;
                    impl Drop for Live {
                        fn drop(&mut self) {
                            LIVE_STAGES.fetch_sub(1, Ordering::SeqCst);
                        }
                    }
                    let _live = Live;
                    stage::stage_loop(net2, spec, rx_stage, out, shared2, stage_pool);
                });
            let handle = match handle {
                Ok(h) => h,
                Err(e) => {
                    LIVE_STAGES.fetch_sub(1, Ordering::SeqCst);
                    // mirror LanePool::new's hardening: the failed
                    // closure (with this stage's endpoints) was already
                    // dropped by `spawn`, so closing the feed lets the
                    // EOS cascade reach every stage spawned so far —
                    // JOIN them before propagating, so a failed spawn
                    // neither leaks resident threads nor leaves
                    // live_stages() settling asynchronously under a
                    // caught panic
                    drop(in_tx);
                    for h in workers.drain(..) {
                        let _ = h.join();
                    }
                    panic!("failed to spawn pipeline stage {si}: {e}");
                }
            };
            workers.push(handle);
            meta.push(StageMeta {
                name: format!("stage{si}"),
                blocks,
                lanes: per_stage_lanes,
                shared,
                in_stats: cur_in_stats.clone(),
                out_stats: out_stats.clone(),
            });
            if let Some(cs) = out_stats {
                cur_in_stats = cs;
            }
            cur_rx = next_rx;
        }
        // only the head stage may hold a logits sender: the feeder's
        // recv must observe disconnection if the stages die
        drop(out_tx);

        Self {
            net,
            feeder: Mutex::new(Feeder { input: Some(in_tx), output: out_rx, recycle }),
            meta,
            workers,
            queue_depth,
        }
    }

    pub fn stage_count(&self) -> usize {
        self.meta.len()
    }

    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// Fine-grained lanes inside each stage.
    pub fn lanes_per_stage(&self) -> usize {
        self.meta.first().map_or(1, |m| m.lanes)
    }

    pub fn tokens_per_image(&self) -> usize {
        self.net.tokens_per_image()
    }

    pub fn num_classes(&self) -> usize {
        self.net.num_classes
    }

    /// Stream a batch through the pipeline: feed every image (the
    /// bounded input FIFO backpressures the feed), then drain exactly
    /// `batch` logit rows, placed by image index. Flat f64 logits,
    /// bit-identical to the monolithic forward.
    ///
    /// Streaming, not a barrier: image `i+1` enters stage 0 while image
    /// `i` is deeper in the pipe; the only waits are bounded-queue
    /// backpressure and the final drain.
    pub fn run_batch(&self, input: &[f32], batch: usize) -> crate::Result<Vec<f64>> {
        let per = self.net.tokens_per_image();
        let nc = self.net.num_classes;
        anyhow::ensure!(
            input.len() == batch * per,
            "input length {} != batch {batch} x {per}",
            input.len()
        );
        let mut feeder = self.feeder.lock().unwrap_or_else(PoisonError::into_inner);
        let mut result = feed_and_drain(&feeder, input, batch, per, nc);
        if result.is_err() {
            // a stage died mid-batch: poison the pipeline (no later call
            // may run against a partially-dead stage chain) and discard
            // any logits the head already emitted for this batch — stale
            // outputs must never be attributed to a future batch
            feeder.input = None;
            while feeder.output.try_recv().is_ok() {}
            // surface the original cause when a kernel panicked (the
            // panicking stage parks its message before dropping the
            // channels whose disconnect produced this error)
            if let Some((name, msg)) = self.meta.iter().find_map(|m| {
                m.shared
                    .panic_msg
                    .lock()
                    .unwrap_or_else(PoisonError::into_inner)
                    .clone()
                    .map(|msg| (m.name.clone(), msg))
            }) {
                result = result.map_err(|e| e.context(format!("{name} panicked: {msg}")));
            }
        }
        result
    }

    /// Snapshot every stage's cumulative occupancy and stall counters.
    pub fn stats(&self) -> PipelineStats {
        let stages: Vec<StageSnapshot> = self
            .meta
            .iter()
            .map(|m| StageSnapshot {
                name: m.name.clone(),
                blocks: (m.blocks.start, m.blocks.end),
                lanes: m.lanes,
                images: m.shared.images.load(Ordering::Relaxed),
                busy_ms: m.shared.busy_ns.load(Ordering::Relaxed) as f64 / 1e6,
                stalls_empty: m.in_stats.blocked_recvs.load(Ordering::Relaxed),
                stalls_full: m
                    .out_stats
                    .as_ref()
                    .map_or(0, |s| s.blocked_sends.load(Ordering::Relaxed)),
            })
            .collect();
        let fill_drain_bubbles = stages.iter().map(|s| s.stalls_empty).sum();
        let backpressure_stalls = stages.iter().map(|s| s.stalls_full).sum();
        PipelineStats { stages, fill_drain_bubbles, backpressure_stalls }
    }
}

/// The body of [`Pipeline::run_batch`], separated so the caller can
/// poison the feeder state on any error without fighting the borrow of
/// the in-flight feed.
fn feed_and_drain(
    feeder: &Feeder,
    input: &[f32],
    batch: usize,
    per: usize,
    nc: usize,
) -> crate::Result<Vec<f64>> {
    let tx = feeder
        .input
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("pipeline is shut down"))?;
    let mut out = vec![0f64; batch * nc];
    for (i, img) in input.chunks_exact(per).enumerate() {
        let mut w = feeder
            .recycle
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .pop()
            .unwrap_or_default();
        w.idx = i;
        w.tokens.clear();
        w.tokens.extend_from_slice(img);
        tx.send(w)
            .map_err(|_| anyhow::anyhow!("pipeline stage terminated while feeding"))?;
    }
    for _ in 0..batch {
        let (idx, logits) = feeder
            .output
            .recv()
            .map_err(|_| anyhow::anyhow!("pipeline stages terminated before the batch drained"))?;
        anyhow::ensure!(idx < batch && logits.len() == nc, "corrupt pipeline output");
        out[idx * nc..(idx + 1) * nc].copy_from_slice(&logits);
    }
    Ok(out)
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        // close the input FIFO: stage 0 drains its queue, observes EOS
        // and exits, dropping its output sender — the shutdown cascades
        // stage by stage with every in-flight image completing
        self.feeder.lock().unwrap_or_else(PoisonError::into_inner).input.take();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Executor adapter + model loading (the coordinator-facing surface)
// ---------------------------------------------------------------------------

/// A batch-size view over a shared [`Pipeline`] (all batch variants of
/// one model stream through the same resident stages).
pub struct PipelineExecutor {
    pipe: Arc<Pipeline>,
    batch: usize,
    load_ms: f64,
    stats: Mutex<ExecStats>,
}

impl Executor for PipelineExecutor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn run_f32(&self, input: &[f32]) -> crate::Result<Vec<f32>> {
        let t0 = Instant::now();
        let out = self.pipe.run_batch(input, self.batch)?;
        let out32: Vec<f32> = out.iter().map(|&v| v as f32).collect();
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut s = self.stats.lock().unwrap();
        s.executions += 1;
        s.total_ms += ms;
        Ok(out32)
    }

    fn compile_ms(&self) -> f64 {
        self.load_ms
    }

    fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }
}

/// Load a model's bundle and spatially unroll it into a resident-stage
/// pipeline; one [`PipelineExecutor`] per batch variant, all sharing the
/// same stages. Dropping the returned [`LoadedModel`] drains and joins
/// the stage threads.
pub fn load_model(
    manifest: &Manifest,
    model: &str,
    lanes: usize,
    stages: usize,
    queue_depth: usize,
) -> crate::Result<LoadedModel> {
    let (net, batches, bundle_ms) = interpreter::load_bundle(manifest, model)?;
    let t0 = Instant::now();
    let pipe = Arc::new(Pipeline::new(net.clone(), PipelineConfig { stages, queue_depth, lanes }));
    let load_ms = bundle_ms + t0.elapsed().as_secs_f64() * 1e3;
    let executors: Vec<Box<dyn Executor>> = batches
        .iter()
        .map(|&b| {
            Box::new(PipelineExecutor {
                pipe: pipe.clone(),
                batch: b,
                load_ms,
                stats: Mutex::new(ExecStats::default()),
            }) as Box<dyn Executor>
        })
        .collect();
    Ok(LoadedModel {
        executors,
        tokens_per_image: net.tokens_per_image(),
        num_classes: net.num_classes,
        compile_ms: load_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_all_blocks_exactly_once() {
        for depth in 1..=12usize {
            for stages in 1..=depth {
                let parts = partition(depth, stages);
                assert_eq!(parts.len(), stages);
                let mut next = 0usize;
                for p in &parts {
                    assert_eq!(p.start, next, "contiguous ({depth},{stages})");
                    assert!(p.end >= p.start);
                    next = p.end;
                }
                assert_eq!(next, depth, "all blocks covered ({depth},{stages})");
                // near-even: sizes differ by at most one
                let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
                let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(mx - mn <= 1, "uneven split ({depth},{stages}): {sizes:?}");
            }
        }
    }

    #[test]
    fn stage_count_resolution() {
        assert_eq!(resolve_stage_count(4, 0), 4, "auto = one stage per block");
        assert_eq!(resolve_stage_count(4, 1), 1);
        assert_eq!(resolve_stage_count(4, 3), 3);
        assert_eq!(resolve_stage_count(4, 99), 4, "clamped to depth");
        assert_eq!(resolve_stage_count(0, 0), 1, "blockless model still has a stage");
    }
}
