//! The hybrid-grained pipeline executor: the paper's architecture as a
//! software execution mode.
//!
//! Where the lane-parallel interpreter runs the ViT *temporally* — one
//! kernel at a time over the whole model, all lanes on the same layer —
//! this module **spatially unrolls** the model into resident stages:
//!
//! * **Coarse grain**: the encoder blocks are partitioned into
//!   contiguous slices, each pinned to its own persistent worker thread
//!   ([`stage`]) with stage-resident scratch. The slicing is
//!   **work-proportional** by default ([`PartitionStrategy`]): a
//!   per-segment cost model (GEMM MACs of the patch-embed, block and
//!   head segments) picks the contiguous partition with the smallest
//!   bottleneck stage, dedicating a stage to the patch-embed front
//!   whenever that evens out occupancy — otherwise embed rides the
//!   first stage; the classifier head always rides the last.
//!   Different images occupy different stages simultaneously, so
//!   steady-state throughput is set by the **slowest stage**, not the
//!   sum of all layers. Each stage only ever touches its own slice's
//!   packed GEMM panels — the software analogue of weights resident per
//!   processing element (ME-ViT's single-load discipline).
//! * **Fine grain**: inside a stage, token-row bands stream through the
//!   GEMM/LayerNorm/attention kernels with the requant LUT epilogue
//!   fused into the producing band, either serially in the stage's own
//!   scratch or across the stage's private [`LanePool`] share of the
//!   lane budget.
//! * **Bounded queues, no barriers**: stages are connected by bounded
//!   SPSC [`channel`]s carrying whole activation tiles (the int32
//!   residual stream, updated in place). Backpressure from a full queue
//!   is the only synchronization; fill/drain bubbles and backpressure
//!   stalls are counted per channel and reported in
//!   [`PipelineStats`].
//!
//! Bit-exactness: stages execute the *same* forward-pass segments
//! ([`QuantViT::embed_into`] / `block_into` / `head_into`) the
//! monolithic forward chains, so pipeline logits are bit-identical to
//! the lane-parallel and scalar paths at every stage count, queue depth
//! and lane split — `tests/pipeline_golden.rs` pins stage counts 1, 2,
//! 4 and max against the golden fixture.
//!
//! Select the mode with `RuntimeConfig::with_mode(ExecMode::Pipeline
//! { .. })`, the `--pipeline [--stages N] [--queue-depth N]` CLI flags,
//! or `HGPIPE_MODE=pipeline` (read-only env fallback, used by the CI
//! matrix).

pub(crate) mod channel;
mod stage;

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Instant;

use crate::artifacts::Manifest;
use crate::runtime::fabric::LanePool;
use crate::runtime::interpreter::QuantViT;
use crate::runtime::kernels::{self, Kernels};
use crate::runtime::{ExecStats, Executor, LoadedModel, ModelArtifact};
use channel::ChannelStats;
use stage::{StageOut, StageShared, StageSpec, Work};

/// Default inter-stage FIFO depth (in activation tiles). Depth 1 is the
/// minimum for rate decoupling; 2 absorbs one tile of jitter per hop —
/// the paper's deep-FIFO sizing question, at tile granularity.
pub const DEFAULT_QUEUE_DEPTH: usize = 2;

/// Count of live resident stage threads across the process (the
/// pipeline twin of `LanePool::live_workers`); dropping a [`Pipeline`]
/// joins its stages, so the liveness tests pin "no leaked threads" on
/// this going back to baseline.
static LIVE_STAGES: AtomicUsize = AtomicUsize::new(0);

/// Process-wide count of live pipeline stage threads.
pub fn live_stages() -> usize {
    LIVE_STAGES.load(Ordering::SeqCst)
}

/// How the encoder blocks are sliced across resident stages.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PartitionStrategy {
    /// Slice by a per-segment **cost model** (GEMM MACs of the
    /// patch-embed, per-block, and head segments): minimize the
    /// bottleneck stage over all contiguous partitions, which
    /// dedicates a stage to patch-embed whenever that evens out
    /// fully-unrolled occupancy. The default.
    #[default]
    WorkProportional,
    /// PR-4's near-even block-count split (patch-embed always rides
    /// stage 0). Kept as the baseline the cost model is measured
    /// against in `benches/interpreter.rs`.
    NearEven,
}

/// How to spatially unroll a model.
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    /// Requested resident stage count. `0` means auto: fully unrolled —
    /// one stage per encoder block **plus** the dedicated patch-embed
    /// stage. Clamped to `[1, depth + 1]` — more stages than segments
    /// would sit empty.
    pub stages: usize,
    /// Bounded inter-stage FIFO depth, in tiles (min 1).
    pub queue_depth: usize,
    /// Total fine-grained lane budget, split evenly across stages
    /// (each stage gets at least its own thread).
    pub lanes: usize,
    /// Near-even block slicing vs the work-proportional cost model.
    pub partition: PartitionStrategy,
    /// The kernel backend every resident stage (and each stage's
    /// private lane-pool share) drives its inner loops through.
    /// Resolved once at model load; the default defers to
    /// `HGPIPE_KERNELS` / auto-detection.
    pub kernels: &'static Kernels,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            stages: 0,
            queue_depth: DEFAULT_QUEUE_DEPTH,
            lanes: 1,
            partition: PartitionStrategy::default(),
            kernels: kernels::from_env(),
        }
    }
}

fn resolve_stage_count(depth: usize, requested: usize) -> usize {
    // depth + 1 partitionable segments: patch-embed plus each block
    // (the head always rides the last stage — it is orders of magnitude
    // lighter than any GEMM segment)
    let max = depth + 1;
    if requested == 0 {
        max
    } else {
        requested.clamp(1, max)
    }
}

/// Near-even contiguous partition of `depth` blocks into `stages`
/// slices (the first `depth % stages` slices take one extra block;
/// with more stages than blocks the tail slices are empty).
fn partition_near_even(depth: usize, stages: usize) -> Vec<Range<usize>> {
    let base = depth / stages;
    let extra = depth % stages;
    let mut parts = Vec::with_capacity(stages);
    let mut b0 = 0usize;
    for si in 0..stages {
        let take = base + usize::from(si < extra);
        parts.push(b0..b0 + take);
        b0 += take;
    }
    parts
}

/// GEMM MAC counts for the three segment kinds of the forward pass —
/// the cost model driving [`PartitionStrategy::WorkProportional`].
/// Attention's two token×token matmuls count as GEMM work too; the LUT
/// and LayerNorm passes ride the same bands and scale with the same
/// terms, so MACs are a faithful relative weight.
fn segment_costs(net: &QuantViT) -> (f64, f64, f64) {
    let t = net.tokens as f64;
    let d = net.dim as f64;
    let h = net.hidden as f64;
    let pd = net.patch_dim as f64;
    let embed = t * pd * d;
    // qkv (d -> 3d) + proj (d -> d) + mlp up (d -> h) + mlp down (h -> d)
    // per token, plus the score and probability-x-V matmuls (t*t*d each)
    let block = t * (d * 3.0 * d + d * d + d * h + h * d) + 2.0 * t * t * d;
    let head = t * d + d * net.num_classes as f64;
    (embed, block, head)
}

/// Contiguous partition of `items` into exactly `stages` non-empty
/// groups minimizing the maximum group sum (the classic linear
/// partition DP — `items.len()` is at most depth+1, so O(n²·s) is
/// trivially cheap at load time). Deterministic: ties keep the earliest
/// cut found.
fn min_bottleneck_groups(items: &[f64], stages: usize) -> Vec<Range<usize>> {
    let n = items.len();
    debug_assert!(stages >= 1 && stages <= n, "stages {stages} for {n} items");
    let mut pre = vec![0.0f64; n + 1];
    for (i, &c) in items.iter().enumerate() {
        pre[i + 1] = pre[i] + c;
    }
    // dp[k][i]: min achievable bottleneck splitting items[0..i] into k
    // groups; cut[k][i]: the j that starts the k-th group
    let mut dp = vec![vec![f64::INFINITY; n + 1]; stages + 1];
    let mut cut = vec![vec![0usize; n + 1]; stages + 1];
    dp[0][0] = 0.0;
    for k in 1..=stages {
        // leave at least one item per remaining group
        for i in k..=(n - (stages - k)) {
            for j in (k - 1)..i {
                let cand = dp[k - 1][j].max(pre[i] - pre[j]);
                if cand < dp[k][i] {
                    dp[k][i] = cand;
                    cut[k][i] = j;
                }
            }
        }
    }
    let mut bounds = vec![n];
    let mut i = n;
    for k in (1..=stages).rev() {
        i = cut[k][i];
        bounds.push(i);
    }
    bounds.reverse();
    bounds.windows(2).map(|w| w[0]..w[1]).collect()
}

/// Work-proportional block slices for `stages` resident stages. The
/// partitionable sequence is `[embed, block 0, …, block depth-1]` with
/// the (tiny) head cost folded into the final item; the returned ranges
/// are encoder-block ranges per stage — stage 0's may be **empty**,
/// which is the dedicated patch-embed stage.
fn partition_work(embed: f64, block_costs: &[f64], head: f64, stages: usize) -> Vec<Range<usize>> {
    let mut items = Vec::with_capacity(block_costs.len() + 1);
    items.push(embed);
    items.extend_from_slice(block_costs);
    if let Some(last) = items.last_mut() {
        *last += head;
    }
    let groups = min_bottleneck_groups(&items, stages.min(items.len()));
    // item 0 is embed; item i >= 1 is block i-1. Group [a, b) therefore
    // covers blocks [max(a,1)-1, b-1).
    groups.into_iter().map(|g| g.start.max(1) - 1..g.end - 1).collect()
}

/// One stage's cumulative counters, snapshotted.
#[derive(Debug, Clone)]
pub struct StageSnapshot {
    pub name: String,
    /// Encoder blocks resident in this stage, `[start, end)`.
    pub blocks: (usize, usize),
    /// Fine-grained lanes inside the stage (1 = the stage thread alone).
    pub lanes: usize,
    pub images: u64,
    /// Time spent computing (excludes time parked on channels).
    pub busy_ms: f64,
    /// Input-FIFO stalls: the stage sat empty (fill/drain bubbles plus
    /// steady-state starvation).
    pub stalls_empty: u64,
    /// Output-FIFO stalls: the stage was backpressured by a full queue.
    pub stalls_full: u64,
}

/// Cumulative pipeline counters. Diff two snapshots
/// ([`PipelineStats::delta`]) to attribute occupancy and bubbles to a
/// measurement window, as `benches/interpreter.rs` does.
#[derive(Debug, Clone)]
pub struct PipelineStats {
    pub stages: Vec<StageSnapshot>,
    /// Total input-FIFO stalls across stages — the pipeline's fill and
    /// drain bubbles (plus any steady-state starvation of a fast stage).
    pub fill_drain_bubbles: u64,
    /// Total output-FIFO backpressure stalls across stages.
    pub backpressure_stalls: u64,
}

impl PipelineStats {
    /// Counters accumulated since `earlier` (same pipeline, same shape).
    pub fn delta(&self, earlier: &PipelineStats) -> PipelineStats {
        let stages = self
            .stages
            .iter()
            .zip(&earlier.stages)
            .map(|(now, was)| StageSnapshot {
                name: now.name.clone(),
                blocks: now.blocks,
                lanes: now.lanes,
                images: now.images - was.images,
                busy_ms: now.busy_ms - was.busy_ms,
                stalls_empty: now.stalls_empty - was.stalls_empty,
                stalls_full: now.stalls_full - was.stalls_full,
            })
            .collect::<Vec<_>>();
        let fill_drain_bubbles = stages.iter().map(|s| s.stalls_empty).sum();
        let backpressure_stalls = stages.iter().map(|s| s.stalls_full).sum();
        PipelineStats { stages, fill_drain_bubbles, backpressure_stalls }
    }
}

/// Per-stage bookkeeping the owning [`Pipeline`] keeps after the
/// endpoints moved into the stage threads.
struct StageMeta {
    name: String,
    blocks: Range<usize>,
    lanes: usize,
    shared: Arc<StageShared>,
    /// Stats of the stage's *input* channel (stalls_empty).
    in_stats: Arc<ChannelStats>,
    /// Stats of the stage's *output* channel; `None` for the head stage
    /// (its output is the unbounded logits channel).
    out_stats: Option<Arc<ChannelStats>>,
}

/// Feeder-side state, serialized under one mutex: batches are fed and
/// drained by exactly one caller at a time (the pipeline is SPSC end to
/// end).
struct Feeder {
    /// `None` once the pipeline began shutting down.
    input: Option<channel::Sender<Work>>,
    output: std::sync::mpsc::Receiver<(usize, Vec<f64>)>,
    recycle: Arc<Mutex<Vec<Work>>>,
}

/// A spatially-unrolled, queue-connected instance of one model: the
/// resident stage threads, their channels, and the feeder endpoints.
///
/// All batch-variant executors of a loaded model share one `Pipeline`
/// via `Arc`; dropping the last handle closes the input channel, lets
/// every stage drain, and joins the stage threads deterministically.
pub struct Pipeline {
    net: Arc<QuantViT>,
    feeder: Mutex<Feeder>,
    meta: Vec<StageMeta>,
    workers: Vec<std::thread::JoinHandle<()>>,
    queue_depth: usize,
    partition: PartitionStrategy,
}

impl Pipeline {
    /// Spatially unroll `net` into resident stages. Threads spawn here
    /// and park on their input FIFOs until images arrive.
    pub fn new(net: Arc<QuantViT>, cfg: PipelineConfig) -> Self {
        Self::new_traced(net, cfg, &crate::telemetry::Telemetry::off())
    }

    /// As [`new`](Pipeline::new), additionally wiring each resident
    /// stage to `tele`: every stage gets its own named trace tid and
    /// ring buffer, and records per-tile residency, stall intervals and
    /// per-op kernel spans. An off handle builds the exact untraced
    /// pipeline — stages receive no buffer and skip every clock read.
    pub fn new_traced(
        net: Arc<QuantViT>,
        cfg: PipelineConfig,
        tele: &crate::telemetry::Telemetry,
    ) -> Self {
        let depth = net.depth;
        let stages = resolve_stage_count(depth, cfg.stages);
        let queue_depth = cfg.queue_depth.max(1);
        let per_stage_lanes = (cfg.lanes / stages).max(1);
        let kern = cfg.kernels;
        let parts = match cfg.partition {
            PartitionStrategy::NearEven => partition_near_even(depth, stages),
            PartitionStrategy::WorkProportional => {
                let (embed, block, head) = segment_costs(&net);
                partition_work(embed, &vec![block; depth], head, stages)
            }
        };

        let (in_tx, in_rx, in_stats) = channel::bounded::<Work>(queue_depth);
        let (out_tx, out_rx) = std::sync::mpsc::channel::<(usize, Vec<f64>)>();
        let recycle = Arc::new(Mutex::new(Vec::<Work>::new()));

        let mut meta = Vec::with_capacity(stages);
        let mut workers = Vec::with_capacity(stages);
        let mut cur_rx = Some(in_rx);
        let mut cur_in_stats = in_stats;
        for (si, blocks) in parts.into_iter().enumerate() {
            // the stage's private fabric share is created HERE, on the
            // loading thread: a worker-spawn failure must be a load
            // error (like lane-parallel mode), never a silent stage
            // death after the load reported success. On panic, close
            // the feed and join the stages spawned so far first.
            let stage_pool = match std::panic::catch_unwind(|| {
                (per_stage_lanes > 1).then(|| LanePool::with_kernels(per_stage_lanes, kern))
            }) {
                Ok(p) => p,
                Err(payload) => {
                    drop(cur_rx.take());
                    drop(in_tx);
                    for h in workers.drain(..) {
                        let _ = h.join();
                    }
                    std::panic::resume_unwind(payload);
                }
            };
            let spec = StageSpec {
                embed: si == 0,
                head: si + 1 == stages,
                blocks: blocks.clone(),
            };
            let (out, next_rx, out_stats) = if si + 1 < stages {
                let (tx, rxn, cs) = channel::bounded::<Work>(queue_depth);
                (StageOut::Next(tx), Some(rxn), Some(cs))
            } else {
                (
                    StageOut::Done { logits: out_tx.clone(), recycle: recycle.clone() },
                    None,
                    None,
                )
            };
            let shared = Arc::new(StageShared::default());
            let rx_stage = cur_rx.take().expect("one receiver per stage");
            let net2 = net.clone();
            let shared2 = shared.clone();
            // each stage owns its trace buffer + named tid; None keeps
            // the loop on the untraced (clock-free) path
            let stage_tele = tele
                .buffer()
                .map(|buf| (buf, tele.alloc_tid(&format!("stage{si}"))));
            LIVE_STAGES.fetch_add(1, Ordering::SeqCst);
            let handle = std::thread::Builder::new()
                .name(format!("hgpipe-stage-{si}"))
                .spawn(move || {
                    // decrement on every exit path, including unwinding
                    struct Live;
                    impl Drop for Live {
                        fn drop(&mut self) {
                            LIVE_STAGES.fetch_sub(1, Ordering::SeqCst);
                        }
                    }
                    let _live = Live;
                    stage::stage_loop(
                        net2, spec, rx_stage, out, shared2, stage_pool, kern, stage_tele,
                    );
                });
            let handle = match handle {
                Ok(h) => h,
                Err(e) => {
                    LIVE_STAGES.fetch_sub(1, Ordering::SeqCst);
                    // mirror LanePool::new's hardening: the failed
                    // closure (with this stage's endpoints) was already
                    // dropped by `spawn`, so closing the feed lets the
                    // EOS cascade reach every stage spawned so far —
                    // JOIN them before propagating, so a failed spawn
                    // neither leaks resident threads nor leaves
                    // live_stages() settling asynchronously under a
                    // caught panic
                    drop(in_tx);
                    for h in workers.drain(..) {
                        let _ = h.join();
                    }
                    panic!("failed to spawn pipeline stage {si}: {e}");
                }
            };
            workers.push(handle);
            meta.push(StageMeta {
                name: format!("stage{si}"),
                blocks,
                lanes: per_stage_lanes,
                shared,
                in_stats: cur_in_stats.clone(),
                out_stats: out_stats.clone(),
            });
            if let Some(cs) = out_stats {
                cur_in_stats = cs;
            }
            cur_rx = next_rx;
        }
        // only the head stage may hold a logits sender: the feeder's
        // recv must observe disconnection if the stages die
        drop(out_tx);

        Self {
            net,
            feeder: Mutex::new(Feeder { input: Some(in_tx), output: out_rx, recycle }),
            meta,
            workers,
            queue_depth,
            partition: cfg.partition,
        }
    }

    pub fn stage_count(&self) -> usize {
        self.meta.len()
    }

    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// The block-slicing strategy this pipeline was built with.
    pub fn partition_strategy(&self) -> PartitionStrategy {
        self.partition
    }

    /// Fine-grained lanes inside each stage.
    pub fn lanes_per_stage(&self) -> usize {
        self.meta.first().map_or(1, |m| m.lanes)
    }

    pub fn tokens_per_image(&self) -> usize {
        self.net.tokens_per_image()
    }

    pub fn num_classes(&self) -> usize {
        self.net.num_classes
    }

    /// Stream a batch through the pipeline: feed every image (the
    /// bounded input FIFO backpressures the feed), then drain exactly
    /// `batch` logit rows, placed by image index. Flat f64 logits,
    /// bit-identical to the monolithic forward.
    ///
    /// Streaming, not a barrier: image `i+1` enters stage 0 while image
    /// `i` is deeper in the pipe; the only waits are bounded-queue
    /// backpressure and the final drain.
    pub fn run_batch(&self, input: &[f32], batch: usize) -> crate::Result<Vec<f64>> {
        let per = self.net.tokens_per_image();
        let nc = self.net.num_classes;
        anyhow::ensure!(
            input.len() == batch * per,
            "input length {} != batch {batch} x {per}",
            input.len()
        );
        let mut feeder = self.feeder.lock().unwrap_or_else(PoisonError::into_inner);
        let mut result = feed_and_drain(&feeder, input, batch, per, nc);
        if result.is_err() {
            // a stage died mid-batch: poison the pipeline (no later call
            // may run against a partially-dead stage chain) and discard
            // any logits the head already emitted for this batch — stale
            // outputs must never be attributed to a future batch
            feeder.input = None;
            while feeder.output.try_recv().is_ok() {}
            // surface the original cause when a kernel panicked (the
            // panicking stage parks its message before dropping the
            // channels whose disconnect produced this error)
            if let Some((name, msg)) = self.meta.iter().find_map(|m| {
                m.shared
                    .panic_msg
                    .lock()
                    .unwrap_or_else(PoisonError::into_inner)
                    .clone()
                    .map(|msg| (m.name.clone(), msg))
            }) {
                result = result.map_err(|e| e.context(format!("{name} panicked: {msg}")));
            }
        }
        result
    }

    /// Snapshot every stage's cumulative occupancy and stall counters.
    pub fn stats(&self) -> PipelineStats {
        let stages: Vec<StageSnapshot> = self
            .meta
            .iter()
            .map(|m| StageSnapshot {
                name: m.name.clone(),
                blocks: (m.blocks.start, m.blocks.end),
                lanes: m.lanes,
                images: m.shared.images.load(Ordering::Relaxed),
                busy_ms: m.shared.busy_ns.load(Ordering::Relaxed) as f64 / 1e6,
                stalls_empty: m.in_stats.blocked_recvs.load(Ordering::Relaxed),
                stalls_full: m
                    .out_stats
                    .as_ref()
                    .map_or(0, |s| s.blocked_sends.load(Ordering::Relaxed)),
            })
            .collect();
        let fill_drain_bubbles = stages.iter().map(|s| s.stalls_empty).sum();
        let backpressure_stalls = stages.iter().map(|s| s.stalls_full).sum();
        PipelineStats { stages, fill_drain_bubbles, backpressure_stalls }
    }
}

/// The body of [`Pipeline::run_batch`], separated so the caller can
/// poison the feeder state on any error without fighting the borrow of
/// the in-flight feed.
fn feed_and_drain(
    feeder: &Feeder,
    input: &[f32],
    batch: usize,
    per: usize,
    nc: usize,
) -> crate::Result<Vec<f64>> {
    let tx = feeder
        .input
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("pipeline is shut down"))?;
    let mut out = vec![0f64; batch * nc];
    for (i, img) in input.chunks_exact(per).enumerate() {
        let mut w = feeder
            .recycle
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .pop()
            .unwrap_or_default();
        w.idx = i;
        w.tokens.clear();
        w.tokens.extend_from_slice(img);
        tx.send(w)
            .map_err(|_| anyhow::anyhow!("pipeline stage terminated while feeding"))?;
    }
    for _ in 0..batch {
        let (idx, logits) = feeder
            .output
            .recv()
            .map_err(|_| anyhow::anyhow!("pipeline stages terminated before the batch drained"))?;
        anyhow::ensure!(idx < batch && logits.len() == nc, "corrupt pipeline output");
        out[idx * nc..(idx + 1) * nc].copy_from_slice(&logits);
    }
    Ok(out)
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        // close the input FIFO: stage 0 drains its queue, observes EOS
        // and exits, dropping its output sender — the shutdown cascades
        // stage by stage with every in-flight image completing
        self.feeder.lock().unwrap_or_else(PoisonError::into_inner).input.take();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Executor adapter + model loading (the coordinator-facing surface)
// ---------------------------------------------------------------------------

/// A batch-size view over a shared [`Pipeline`] (all batch variants of
/// one model stream through the same resident stages).
pub struct PipelineExecutor {
    pipe: Arc<Pipeline>,
    batch: usize,
    load_ms: f64,
    stats: Mutex<ExecStats>,
}

impl Executor for PipelineExecutor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn run_f32(&self, input: &[f32]) -> crate::Result<Vec<f32>> {
        let t0 = Instant::now();
        let out = self.pipe.run_batch(input, self.batch)?;
        let out32: Vec<f32> = out.iter().map(|&v| v as f32).collect();
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut s = self.stats.lock().unwrap();
        s.executions += 1;
        s.total_ms += ms;
        Ok(out32)
    }

    fn compile_ms(&self) -> f64 {
        self.load_ms
    }

    fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }

    fn pipeline_stats(&self) -> Option<PipelineStats> {
        Some(self.pipe.stats())
    }
}

/// Load a model's bundle and spatially unroll it into a resident-stage
/// pipeline; one [`PipelineExecutor`] per batch variant, all sharing the
/// same stages. Dropping the returned [`LoadedModel`] drains and joins
/// the stage threads.
pub fn load_model(
    manifest: &Manifest,
    model: &str,
    lanes: usize,
    stages: usize,
    queue_depth: usize,
) -> crate::Result<LoadedModel> {
    let artifact = ModelArtifact::load(manifest, model)?;
    Ok(executors_from_artifact(&artifact, lanes, stages, queue_depth, kernels::from_env()))
}

/// Spatially unroll an already-loaded shared [`ModelArtifact`] into a
/// resident-stage pipeline. Only the mutable per-replica half is built
/// here — stage threads, bounded queues, stage-resident scratch; every
/// stage borrows the artifact's weight allocation through the shared
/// `Arc` (the N-replica fleet holds one copy of the panels).
pub fn executors_from_artifact(
    artifact: &ModelArtifact,
    lanes: usize,
    stages: usize,
    queue_depth: usize,
    kern: &'static Kernels,
) -> LoadedModel {
    executors_from_artifact_traced(
        artifact,
        lanes,
        stages,
        queue_depth,
        kern,
        &crate::telemetry::Telemetry::off(),
    )
}

/// [`executors_from_artifact`] with a telemetry handle: the resident
/// stages record residency/stall/op spans onto per-stage tids of the
/// handle's trace process.
pub fn executors_from_artifact_traced(
    artifact: &ModelArtifact,
    lanes: usize,
    stages: usize,
    queue_depth: usize,
    kern: &'static Kernels,
    tele: &crate::telemetry::Telemetry,
) -> LoadedModel {
    let net = artifact.net().clone();
    let t0 = Instant::now();
    let pipe = Arc::new(Pipeline::new_traced(
        net.clone(),
        PipelineConfig { stages, queue_depth, lanes, kernels: kern, ..Default::default() },
        tele,
    ));
    let load_ms = artifact.load_ms() + t0.elapsed().as_secs_f64() * 1e3;
    let executors: Vec<Box<dyn Executor>> = artifact
        .batches()
        .iter()
        .map(|&b| {
            Box::new(PipelineExecutor {
                pipe: pipe.clone(),
                batch: b,
                load_ms,
                stats: Mutex::new(ExecStats::default()),
            }) as Box<dyn Executor>
        })
        .collect();
    LoadedModel {
        executors,
        tokens_per_image: net.tokens_per_image(),
        num_classes: net.num_classes,
        compile_ms: load_ms,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Contiguity + exactly-once coverage shared by both strategies.
    fn assert_covers(parts: &[Range<usize>], depth: usize, stages: usize, ctx: &str) {
        assert_eq!(parts.len(), stages, "{ctx}");
        let mut next = 0usize;
        for p in parts {
            assert_eq!(p.start, next, "contiguous ({ctx})");
            assert!(p.end >= p.start);
            next = p.end;
        }
        assert_eq!(next, depth, "all blocks covered ({ctx})");
    }

    #[test]
    fn near_even_partition_covers_all_blocks_exactly_once() {
        for depth in 1..=12usize {
            for stages in 1..=depth + 1 {
                let parts = partition_near_even(depth, stages);
                assert_covers(&parts, depth, stages, &format!("near-even {depth},{stages}"));
                // near-even: sizes differ by at most one
                let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
                let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(mx - mn <= 1, "uneven split ({depth},{stages}): {sizes:?}");
            }
        }
    }

    #[test]
    fn work_partition_covers_all_blocks_exactly_once() {
        for depth in 1..=12usize {
            let blocks = vec![8.0f64; depth];
            for stages in 1..=depth + 1 {
                for embed in [0.5f64, 4.0, 30.0] {
                    let parts = partition_work(embed, &blocks, 0.1, stages);
                    assert_covers(
                        &parts,
                        depth,
                        stages,
                        &format!("work {depth},{stages},embed {embed}"),
                    );
                }
            }
        }
    }

    #[test]
    fn min_bottleneck_beats_or_matches_any_even_split() {
        let items = [3.0f64, 8.0, 8.0, 8.0, 8.0];
        let groups = min_bottleneck_groups(&items, 2);
        // optimal 2-way cut: [3,8,8] | [8,8] -> bottleneck 19 (vs 24/27)
        let sums: Vec<f64> =
            groups.iter().map(|g| items[g.clone()].iter().sum()).collect();
        let bottleneck = sums.iter().cloned().fold(0.0f64, f64::max);
        assert!((bottleneck - 19.0).abs() < 1e-9, "got {sums:?}");
    }

    #[test]
    fn fully_unrolled_work_partition_dedicates_the_embed_stage() {
        // 4 blocks, 5 stages: every segment gets its own stage, so
        // stage 0 carries embed alone (an empty block range)
        let parts = partition_work(3.0, &[8.0; 4], 0.1, 5);
        assert_eq!(parts[0], 0..0, "stage 0 is the dedicated embed stage");
        for (si, p) in parts.iter().enumerate().skip(1) {
            assert_eq!(p.len(), 1, "stage {si} holds exactly one block");
        }
    }

    #[test]
    fn heavy_embed_offloads_blocks_from_stage_zero() {
        // embed outweighs two blocks (the deit-tiny ci=192 situation):
        // at 3 stages over 4 blocks the cost model must NOT put a block
        // next to embed when [E | 2B | 2B] has the smaller bottleneck
        let parts = partition_work(20.0, &[8.0; 4], 0.1, 3);
        assert_eq!(parts[0], 0..0, "heavy embed stands alone");
        assert_eq!(parts[1], 0..2);
        assert_eq!(parts[2], 2..4);
    }

    #[test]
    fn work_partition_bottleneck_never_exceeds_near_even() {
        for depth in 1..=12usize {
            for stages in 1..=depth + 1 {
                for embed in [0.5f64, 8.0, 40.0] {
                    let (block, head) = (8.0f64, 0.1f64);
                    let cost = |parts: &[Range<usize>]| -> f64 {
                        parts
                            .iter()
                            .enumerate()
                            .map(|(si, p)| {
                                let mut c = p.len() as f64 * block;
                                if si == 0 {
                                    c += embed;
                                }
                                if si + 1 == parts.len() {
                                    c += head;
                                }
                                c
                            })
                            .fold(0.0f64, f64::max)
                    };
                    let work = cost(&partition_work(embed, &vec![block; depth], head, stages));
                    let even = cost(&partition_near_even(depth, stages));
                    assert!(
                        work <= even + 1e-9,
                        "({depth},{stages},embed {embed}): work {work} > near-even {even}"
                    );
                }
            }
        }
    }

    #[test]
    fn stage_count_resolution() {
        assert_eq!(resolve_stage_count(4, 0), 5, "auto = embed stage + one per block");
        assert_eq!(resolve_stage_count(4, 1), 1);
        assert_eq!(resolve_stage_count(4, 3), 3);
        assert_eq!(resolve_stage_count(4, 99), 5, "clamped to depth + 1");
        assert_eq!(resolve_stage_count(0, 0), 1, "blockless model still has a stage");
    }
}
