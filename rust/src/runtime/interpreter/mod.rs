//! Pure-rust interpreter backend: execute the quantized ViT directly
//! from its weight/LUT *bundle* (`python -m compile.export`).
//!
//! This is the default execution engine — no XLA, no HLO, no native
//! libraries. It mirrors, **bit-exactly**, the integer semantics of
//! `python/compile/kernels/ref.py` / `model.LutExec` (the accelerator's
//! canonical dataflow): i64 output-stationary matmul accumulation,
//! PoT-indexed LUT non-linears, three-pass integer LayerNorm, inverted-Exp
//! + segmented-Recip Softmax. Where the numpy reference narrows to int32
//! (`LutExec._i32`: every LUT input, attention scores, the residual
//! stream), this interpreter performs the same wrapping cast, so even
//! out-of-range corner cases agree with the python oracle; the golden
//! fixture in `rust/artifacts/` pins that equality logit-for-logit.
//!
//! The module is split by concern so the kernels are independently
//! testable:
//!
//! * [`bundle`](self) — load/validate the JSON bundle ([`QuantViT`]);
//!   weights are re-packed into blocked GEMM panels here, once.
//! * `ops` — the integer kernels (LUT application, LayerNorm, Softmax,
//!   fused attention, GEMM with the requant LUT fused into the
//!   producing band) in scratch-backed banded and pre-fabric (naive)
//!   variants.
//! * this file — the forward pass as **stage-sliceable segments**
//!   ([`QuantViT::embed_into`] / [`QuantViT::block_into`] /
//!   [`QuantViT::head_into`]), per-op profiling, and the [`Executor`]
//!   adapter the coordinator drives. The pipeline executor
//!   ([`crate::runtime::pipeline`]) runs the *same* segments, one
//!   contiguous slice per resident stage, so pipeline logits are
//!   bit-identical by construction.
//!
//! Execution runs on the [`fabric`](crate::runtime::fabric) behind an
//! [`Exec`] dispatch: token-row bands either stream serially through a
//! caller-provided [`BandScratch`] (zero locking — the batch-grain
//! worker bands and the pipeline's resident stages run this way), or
//! spread across a [`LanePool`] of **persistent parked workers**
//! (created once per loaded model). The elementwise requant LUT passes
//! are fused into the GEMM band that produces them, so no kernel leaves
//! a serial epilogue on the caller thread. Every intermediate buffer
//! comes from a scratch box, so steady-state serving performs no
//! per-image heap allocation in GEMM/attention scratch. Lane count
//! comes from [`crate::runtime::RuntimeConfig`] (the `--lanes` CLI
//! flag) or the `HGPIPE_LANES` env var; every lane count produces
//! bit-identical logits (`cargo test` pins lanes 1, 2, 7 and 16 against
//! the golden fixture).

mod bundle;
pub(crate) mod ops;

pub use bundle::QuantViT;

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::artifacts::{BundleInfo, Manifest};
use crate::runtime::fabric::{Exec, LanePool, LaneScratch, PassScratch};
use crate::runtime::kernels::{self, Kernels};
use crate::runtime::{ExecStats, Executor, LoadedModel, ModelArtifact};
use ops::lut_i32;

/// Wall-clock milliseconds spent per kernel family during a forward
/// pass — the per-op breakdown `benches/interpreter.rs` reports.
///
/// Since the requant LUT maps are fused into the GEMM bands that
/// produce them, their time lands in `gemm_ms`; `requant_ms` remains in
/// the schema (it tracked the pre-fusion serial caller-thread passes)
/// and now stays at zero — the field is the record that the cost moved.
#[derive(Debug, Clone, Copy, Default)]
pub struct OpProfile {
    pub quantize_ms: f64,
    pub gemm_ms: f64,
    pub layernorm_ms: f64,
    pub attention_ms: f64,
    /// Standalone elementwise requant passes between kernels — zero
    /// since the fusion into the producing GEMM band.
    pub requant_ms: f64,
    pub head_ms: f64,
}

impl OpProfile {
    pub fn merge(&mut self, o: &OpProfile) {
        self.quantize_ms += o.quantize_ms;
        self.gemm_ms += o.gemm_ms;
        self.layernorm_ms += o.layernorm_ms;
        self.attention_ms += o.attention_ms;
        self.requant_ms += o.requant_ms;
        self.head_ms += o.head_ms;
    }

    pub fn total_ms(&self) -> f64 {
        self.quantize_ms
            + self.gemm_ms
            + self.layernorm_ms
            + self.attention_ms
            + self.requant_ms
            + self.head_ms
    }

    /// The profile as `(kernel family, milliseconds)` pairs, in schema
    /// order — the shape the telemetry layer renders op spans from.
    pub fn named_ms(&self) -> [(&'static str, f64); 6] {
        [
            ("quantize", self.quantize_ms),
            ("gemm", self.gemm_ms),
            ("layernorm", self.layernorm_ms),
            ("attention", self.attention_ms),
            ("requant", self.requant_ms),
            ("head", self.head_ms),
        ]
    }
}

/// Lap timer feeding an [`OpProfile`] — or a no-op when detached, so
/// hot paths that never read the profile (the pipeline's per-image
/// stage loop) pay **zero** clock reads for it.
pub(crate) struct OpClock<'a> {
    prof: Option<(&'a mut OpProfile, Instant)>,
}

impl<'a> OpClock<'a> {
    pub(crate) fn attached(prof: &'a mut OpProfile) -> Self {
        Self { prof: Some((prof, Instant::now())) }
    }

    pub(crate) fn detached() -> OpClock<'static> {
        OpClock { prof: None }
    }

    /// Attribute the time since the previous lap to the [`OpProfile`]
    /// field `pick` selects. Detached clocks do nothing.
    #[inline]
    pub(crate) fn lap(&mut self, pick: impl FnOnce(&mut OpProfile) -> &mut f64) {
        if let Some((prof, last)) = &mut self.prof {
            let now = Instant::now();
            *pick(&mut **prof) += now.duration_since(*last).as_secs_f64() * 1e3;
            *last = now;
        }
    }
}

impl QuantViT {
    /// Full integer forward for one image: f32 tokens (T*P) -> f64 logits.
    ///
    /// Bit-exact with `model.forward_int_np` over the same f32 tokens.
    /// Runs fully serial on a throwaway pool; hot paths should hold a
    /// [`LanePool`] and call [`Self::forward_image_pooled`] so scratch
    /// buffers are recycled across calls (identical results either way).
    pub fn forward_image(&self, tokens: &[f32]) -> crate::Result<Vec<f64>> {
        self.forward_image_pooled(tokens, &LanePool::serial())
    }

    /// [`Self::forward_image`] with token-row bands spread across the
    /// pool's lanes and every intermediate buffer drawn from the pool's
    /// scratch arena. Bit-identical at every lane count.
    pub fn forward_image_pooled(&self, tokens: &[f32], pool: &LanePool) -> crate::Result<Vec<f64>> {
        Ok(self.forward_profiled(tokens, pool)?.0)
    }

    /// [`Self::forward_image_pooled`] plus the per-op time breakdown.
    ///
    /// A single-lane pool takes the fully-serial path: the whole pass
    /// runs in one scratch box with the kernels' band buffers threaded
    /// in explicitly, so beyond the one checkout/restore of that box it
    /// touches no lock at all.
    pub fn forward_profiled(
        &self,
        tokens: &[f32],
        pool: &LanePool,
    ) -> crate::Result<(Vec<f64>, OpProfile)> {
        anyhow::ensure!(
            tokens.len() == self.tokens_per_image(),
            "expected {} token values, got {}",
            self.tokens_per_image(),
            tokens.len()
        );
        let mut fs = pool.checkout_scratch();
        let mut prof = OpProfile::default();
        let mut clk = OpClock::attached(&mut prof);
        let logits = if pool.lanes() <= 1 {
            let LaneScratch { band, pass } = &mut *fs;
            self.forward_core(tokens, pass, &mut Exec::serial(band, pool.kernels()), &mut clk)
        } else {
            self.forward_core(tokens, &mut fs.pass, &mut Exec::pool(pool), &mut clk)
        };
        drop(clk);
        pool.restore_scratch(fs);
        Ok((logits, prof))
    }

    /// Fully-serial forward in a caller-provided scratch box — **zero
    /// locking**: the pass buffers and the kernels' band buffers are the
    /// two disjoint halves of `fs`. This is how a batch-grain worker
    /// band runs its nested per-image forwards (its own worker box,
    /// retiring the old `inline_pool` arena mutex) and how a pipeline
    /// stage runs its block slice. Nobody here reads a per-op profile,
    /// so the clock stays detached (zero clock reads). Input length must
    /// already be validated (`tokens_per_image` values). The caller
    /// names the kernel backend explicitly (its pool's or stage's), so
    /// serial nested forwards drive the same vectorized inner loops as
    /// lane-parallel ones.
    pub(crate) fn forward_in_scratch(
        &self,
        tokens: &[f32],
        fs: &mut LaneScratch,
        kernels: &'static Kernels,
    ) -> Vec<f64> {
        debug_assert_eq!(tokens.len(), self.tokens_per_image());
        let LaneScratch { band, pass } = fs;
        self.forward_core(tokens, pass, &mut Exec::serial(band, kernels), &mut OpClock::detached())
    }

    /// The one forward-pass implementation both dispatches share:
    /// embed → blocks → head, each segment a reusable stage slice.
    fn forward_core(
        &self,
        tokens: &[f32],
        ps: &mut PassScratch,
        exec: &mut Exec<'_>,
        clk: &mut OpClock<'_>,
    ) -> Vec<f64> {
        // the residual stream leaves the scratch for the pass so the
        // block segments can borrow it alongside the other pass buffers
        // (pipeline stages carry it through channels the same way)
        let mut x = std::mem::take(&mut ps.x);
        self.embed_into(tokens, &mut x, ps, exec, clk);
        for bi in 0..self.blocks.len() {
            self.block_into(bi, &mut x, ps, exec, clk);
        }
        let logits = self.head_into(&x, ps, exec, clk);
        ps.x = x;
        logits
    }

    // -----------------------------------------------------------------
    // Stage segments: the spatial slices the pipeline executor pins to
    // resident stages. `forward_core` chains all of them, so monolithic
    // and stage-sliced execution are the same arithmetic by construction.
    // -----------------------------------------------------------------

    /// Patch-embed segment: quantize the f32 tokens and produce the
    /// int32 residual stream `x` (GEMM with the pe requant LUT fused
    /// into the producing band).
    pub(crate) fn embed_into(
        &self,
        tokens: &[f32],
        x: &mut Vec<i32>,
        ps: &mut PassScratch,
        exec: &mut Exec<'_>,
        clk: &mut OpClock<'_>,
    ) {
        debug_assert_eq!(tokens.len(), self.tokens_per_image());
        ps.xq.clear();
        ps.xq.extend(tokens.iter().map(|&v| self.quantize_in(v)));
        clk.lap(|p| &mut p.quantize_ms);
        ops::gemm_rq_into(&self.pe, &ps.xq, self.tokens, &self.pe_rq, x, exec);
        clk.lap(|p| &mut p.gemm_ms);
    }

    /// One encoder block (MHA + MLP) over the residual stream, in place.
    /// Every requant LUT pass is fused into the GEMM band producing it;
    /// the residual adds ride the same bands.
    pub(crate) fn block_into(
        &self,
        bi: usize,
        x: &mut [i32],
        ps: &mut PassScratch,
        exec: &mut Exec<'_>,
        clk: &mut OpClock<'_>,
    ) {
        let (t, d, h) = (self.tokens, self.dim, self.heads);
        let blk = &self.blocks[bi];

        // ---- MHA ----
        ops::layernorm_into(x, d, blk.ln1_guard, &blk.ln1_rsqrt, &blk.ln1_rq, &mut ps.n, exec);
        clk.lap(|p| &mut p.layernorm_ms);
        ops::gemm_rq_into(&blk.qkv, &ps.n, t, &blk.qkv_rq, &mut ps.qkv, exec);
        clk.lap(|p| &mut p.gemm_ms);
        ops::attention_into(blk, &ps.qkv, t, d, h, &mut ps.a_q, exec);
        clk.lap(|p| &mut p.attention_ms);
        ops::gemm_rq_add_into(&blk.proj, &ps.a_q, t, &blk.proj_rq, x, exec);
        clk.lap(|p| &mut p.gemm_ms);

        // ---- MLP ----
        ops::layernorm_into(x, d, blk.ln2_guard, &blk.ln2_rsqrt, &blk.ln2_rq, &mut ps.n, exec);
        clk.lap(|p| &mut p.layernorm_ms);
        ops::gemm_rq_into(&blk.mm1, &ps.n, t, &blk.gelu, &mut ps.hdn, exec);
        clk.lap(|p| &mut p.gemm_ms);
        ops::gemm_rq_add_into(&blk.mm2, &ps.hdn, t, &blk.mm2_rq, x, exec);
        clk.lap(|p| &mut p.gemm_ms);
    }

    /// Head segment: final LayerNorm + mean-pool classifier over the
    /// residual stream.
    pub(crate) fn head_into(
        &self,
        x: &[i32],
        ps: &mut PassScratch,
        exec: &mut Exec<'_>,
        clk: &mut OpClock<'_>,
    ) -> Vec<f64> {
        ops::layernorm_into(
            x,
            self.dim,
            self.ln_f_guard,
            &self.ln_f_rsqrt,
            &self.ln_f_rq,
            &mut ps.n,
            exec,
        );
        clk.lap(|p| &mut p.layernorm_ms);
        let logits = self.head_with(&ps.n, &mut ps.pooled);
        clk.lap(|p| &mut p.head_ms);
        logits
    }

    /// The pre-fabric forward — naive row-major GEMM, per-head
    /// probability matrix, per-row softmax allocations, unfused serial
    /// requant passes, fully serial. Kept as the differential-testing
    /// oracle and the scalar baseline `benches/interpreter.rs` measures
    /// the fabric against; must stay bit-identical to
    /// [`Self::forward_image`].
    pub fn forward_image_naive(&self, tokens: &[f32]) -> crate::Result<Vec<f64>> {
        anyhow::ensure!(
            tokens.len() == self.tokens_per_image(),
            "expected {} token values, got {}",
            self.tokens_per_image(),
            tokens.len()
        );
        let (t, d, h) = (self.tokens, self.dim, self.heads);

        let xq: Vec<i32> = tokens.iter().map(|&x| self.quantize_in(x)).collect();
        let acc = self.pe.matmul_naive(&xq, t);
        let mut x: Vec<i32> = acc.iter().map(|&a| lut_i32(&self.pe_rq, a as i32)).collect();

        for blk in &self.blocks {
            let n = layernorm_naive(&x, d, blk.ln1_guard, &blk.ln1_rsqrt, &blk.ln1_rq);
            let acc = blk.qkv.matmul_naive(&n, t);
            let qkv: Vec<i32> = acc.iter().map(|&a| lut_i32(&blk.qkv_rq, a as i32)).collect();
            let a_q = ops::attention_naive(blk, &qkv, t, d, h);
            let acc = blk.proj.matmul_naive(&a_q, t);
            for (xv, &a) in x.iter_mut().zip(&acc) {
                *xv = xv.wrapping_add(lut_i32(&blk.proj_rq, a as i32));
            }

            let n2 = layernorm_naive(&x, d, blk.ln2_guard, &blk.ln2_rsqrt, &blk.ln2_rq);
            let acc = blk.mm1.matmul_naive(&n2, t);
            let hdn: Vec<i32> = acc.iter().map(|&a| lut_i32(&blk.gelu, a as i32)).collect();
            let acc = blk.mm2.matmul_naive(&hdn, t);
            for (xv, &a) in x.iter_mut().zip(&acc) {
                *xv = xv.wrapping_add(lut_i32(&blk.mm2_rq, a as i32));
            }
        }

        let n = layernorm_naive(&x, d, self.ln_f_guard, &self.ln_f_rsqrt, &self.ln_f_rq);
        let mut pooled = Vec::new();
        Ok(self.head_with(&n, &mut pooled))
    }

    /// Mean-pool + classifier head over the final-LN output rows; the
    /// pooling accumulator comes from the caller (scratch on the hot
    /// path), only the returned logits allocate.
    fn head_with(&self, n: &[i32], pooled: &mut Vec<i64>) -> Vec<f64> {
        let d = self.dim;
        pooled.clear();
        pooled.resize(d, 0);
        for row in n.chunks_exact(d) {
            for (p, &v) in pooled.iter_mut().zip(row) {
                *p += v as i64;
            }
        }
        let mut logits = Vec::with_capacity(self.num_classes);
        for k in 0..self.num_classes {
            let mut acc: i64 = 0;
            for (c, &p) in pooled.iter().enumerate() {
                acc += p * self.head_w[c * self.num_classes + k] as i64;
            }
            logits.push(acc as f64 * self.logit_scale + self.head_bias[k]);
        }
        logits
    }
}

/// Serial allocate-per-call LayerNorm for the naive oracle path (the
/// exact pre-fabric structure, preserved as a baseline).
fn layernorm_naive(
    x: &[i32],
    d: usize,
    guard: u32,
    rsqrt: &crate::lut::LutTable,
    rq: &crate::lut::LutTable,
) -> Vec<i32> {
    let mut out = vec![0i32; x.len()];
    let mut c = vec![0i64; d];
    for (orow, row) in out.chunks_exact_mut(d).zip(x.chunks_exact(d)) {
        let sum: i64 = row.iter().map(|&v| v as i64).sum();
        let mut v: i64 = 0;
        for (cj, &xv) in c.iter_mut().zip(row) {
            *cj = (d as i32).wrapping_mul(xv) as i64 - sum;
            let cg = *cj >> guard;
            v += cg * cg;
        }
        let r = lut_i32(rsqrt, v as i32) as i64;
        for (o, &cj) in orow.iter_mut().zip(c.iter()) {
            *o = lut_i32(rq, (cj * r) as i32);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Executor adapter (one per batch variant, sharing the loaded model)
// ---------------------------------------------------------------------------

/// A batch-size view over a shared [`QuantViT`], executing on the
/// model's persistent [`LanePool`] fabric.
///
/// Work is partitioned at two grains: when the dispatch carries at least
/// as many images as the pool has lanes, each worker runs whole images
/// (batch-lane grain, one parallel region per dispatch) with its nested
/// forward running entirely in the worker's own scratch box — no arena
/// or pool locking inside the band; otherwise the pool drops inside each
/// image and parallelizes token-row bands (row grain). Both grains are
/// bit-exact with serial execution. All batch variants of one model
/// clone the same pool handle, so workers are created once per loaded
/// model and shut down when it unloads.
pub struct InterpreterExecutor {
    net: Arc<QuantViT>,
    batch: usize,
    /// The model's persistent worker fabric.
    pool: LanePool,
    load_ms: f64,
    stats: Mutex<ExecStats>,
    /// Per-op accumulation for telemetry, present only when tracing is
    /// on (the off path never attaches a clock). Drained by the
    /// coordinator through [`Executor::take_op_profile`]. Row-grain
    /// dispatches attribute fully; batch-lane-grain dispatches keep the
    /// workers' lock-free nested forwards and skip attribution.
    profile: Option<Mutex<OpProfile>>,
}

impl Executor for InterpreterExecutor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn run_f32(&self, input: &[f32]) -> crate::Result<Vec<f32>> {
        let per = self.net.tokens_per_image();
        anyhow::ensure!(
            input.len() == self.batch * per,
            "input length {} != batch {} x {}",
            input.len(),
            self.batch,
            per
        );
        let t0 = Instant::now();
        let nc = self.net.num_classes;
        let mut out = vec![0.0f32; self.batch * nc];
        if self.pool.lanes() > 1 && self.batch >= self.pool.lanes() {
            // batch-lane grain: a band of whole images per worker, each
            // image's forward running serially in the band's own scratch
            let kern = self.pool.kernels();
            self.pool.par_chunks_mut(&mut out, nc, |s, i0, band| {
                for (j, orow) in band.chunks_exact_mut(nc).enumerate() {
                    let i = i0 + j;
                    let logits =
                        self.net.forward_in_scratch(&input[i * per..(i + 1) * per], s, kern);
                    for (o, &v) in orow.iter_mut().zip(&logits) {
                        *o = v as f32;
                    }
                }
            });
        } else {
            // row grain: images serial, token rows banded inside each
            for (i, lane) in input.chunks_exact(per).enumerate() {
                let logits = match &self.profile {
                    // tracing on: same forward (pooled is profiled with
                    // the profile discarded), but keep the laps
                    Some(acc) => {
                        let (logits, p) = self.net.forward_profiled(lane, &self.pool)?;
                        acc.lock().unwrap().merge(&p);
                        logits
                    }
                    None => self.net.forward_image_pooled(lane, &self.pool)?,
                };
                for (o, &v) in out[i * nc..(i + 1) * nc].iter_mut().zip(&logits) {
                    *o = v as f32;
                }
            }
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut s = self.stats.lock().unwrap();
        s.executions += 1;
        s.total_ms += ms;
        Ok(out)
    }

    fn compile_ms(&self) -> f64 {
        self.load_ms
    }

    fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }

    fn take_op_profile(&self) -> Option<OpProfile> {
        self.profile.as_ref().map(|m| std::mem::take(&mut *m.lock().unwrap()))
    }
}

/// Load a model's bundle and wrap it in one executor per batch variant,
/// with the lane count taken from `HGPIPE_LANES` (or the machine's
/// available parallelism).
pub fn load_model(manifest: &Manifest, model: &str) -> crate::Result<LoadedModel> {
    load_model_with_lanes(manifest, model, LanePool::lanes_from_env())
}

/// Load and validate a model's bundle for `model`, shared between the
/// lane-parallel and pipeline executors.
pub(crate) fn load_bundle(
    manifest: &Manifest,
    model: &str,
) -> crate::Result<(Arc<QuantViT>, Vec<usize>, f64)> {
    let info: &BundleInfo = manifest
        .bundle_for(model)
        .ok_or_else(|| anyhow::anyhow!("no interpreter bundle for model '{model}' in manifest"))?;
    let t0 = Instant::now();
    let net = Arc::new(QuantViT::load(&info.path)?);
    let load_ms = t0.elapsed().as_secs_f64() * 1e3;
    anyhow::ensure!(
        net.model == model,
        "bundle model '{}' != requested '{model}'",
        net.model
    );
    let batches = if info.batches.is_empty() { vec![1] } else { info.batches.clone() };
    Ok((net, batches, load_ms))
}

/// [`load_model`] with an explicit lane count (the `--lanes` flag
/// arrives here via [`crate::runtime::RuntimeConfig`]; tests and benches
/// pass it directly so they never depend on the process environment).
///
/// The persistent worker fabric is created here, once: every batch
/// variant clones the same pool handle, and dropping the returned
/// [`LoadedModel`] joins the workers.
pub fn load_model_with_lanes(
    manifest: &Manifest,
    model: &str,
    lanes: usize,
) -> crate::Result<LoadedModel> {
    let artifact = ModelArtifact::load(manifest, model)?;
    Ok(executors_from_artifact(&artifact, lanes, kernels::from_env()))
}

/// Build the lane-parallel executors for an already-loaded shared
/// [`ModelArtifact`]: only the **mutable** per-replica half is created
/// here (the persistent worker fabric and, lazily, its scratch arena) —
/// the weights stay in the artifact's allocation, however many replicas
/// call this. The kernel backend was resolved once by the caller
/// ([`crate::runtime::RuntimeConfig::resolve_kernels`]) and is pinned
/// into the replica's fabric here.
pub fn executors_from_artifact(
    artifact: &ModelArtifact,
    lanes: usize,
    kern: &'static Kernels,
) -> LoadedModel {
    executors_from_artifact_profiled(artifact, lanes, kern, false)
}

/// [`executors_from_artifact`] with per-op profiling switched on for
/// telemetry: each executor accumulates an [`OpProfile`] the
/// coordinator drains into per-op trace spans after every dispatch.
pub fn executors_from_artifact_profiled(
    artifact: &ModelArtifact,
    lanes: usize,
    kern: &'static Kernels,
    profiled: bool,
) -> LoadedModel {
    let net = artifact.net().clone();
    let load_ms = artifact.load_ms();
    let pool = LanePool::with_kernels(lanes, kern);
    let executors: Vec<Box<dyn Executor>> = artifact
        .batches()
        .iter()
        .map(|&b| {
            Box::new(InterpreterExecutor {
                net: net.clone(),
                batch: b,
                pool: pool.clone(),
                load_ms,
                stats: Mutex::new(ExecStats::default()),
                profile: profiled.then(|| Mutex::new(OpProfile::default())),
            }) as Box<dyn Executor>
        })
        .collect();
    LoadedModel {
        executors,
        tokens_per_image: net.tokens_per_image(),
        num_classes: net.num_classes,
        compile_ms: load_ms,
    }
}
