//! Pure-rust interpreter backend: execute the quantized ViT directly
//! from its weight/LUT *bundle* (`python -m compile.export`).
//!
//! This is the default execution engine — no XLA, no HLO, no native
//! libraries. It mirrors, **bit-exactly**, the integer semantics of
//! `python/compile/kernels/ref.py` / `model.LutExec` (the accelerator's
//! canonical dataflow): i64 output-stationary matmul accumulation,
//! PoT-indexed LUT non-linears, three-pass integer LayerNorm, inverted-Exp
//! + segmented-Recip Softmax. Where the numpy reference narrows to int32
//! (`LutExec._i32`: every LUT input, attention scores, the residual
//! stream), this interpreter performs the same wrapping cast, so even
//! out-of-range corner cases agree with the python oracle; the golden
//! fixture in `rust/artifacts/` pins that equality logit-for-logit.
//!
//! The module is split by concern so the kernels are independently
//! testable:
//!
//! * [`bundle`](self) — load/validate the JSON bundle ([`QuantViT`]);
//!   weights are re-packed into blocked GEMM panels here, once.
//! * `ops` — the integer kernels (LUT application, LayerNorm, Softmax,
//!   fused attention) in pooled and pre-fabric (naive) variants.
//! * this file — the forward pass, per-op profiling, and the
//!   [`Executor`] adapter the coordinator drives.
//!
//! Execution runs on the [`fabric`](crate::runtime::fabric): a
//! [`LanePool`] parallelizes whole batch lanes across workers (one image
//! per lane) or, when the dispatch is smaller than the pool, token-row
//! bands inside each image. Lane count comes from `HGPIPE_LANES` / the
//! `--lanes` CLI flag; every lane count produces bit-identical logits
//! (`cargo test` pins lanes 1, 2 and 7 against the golden fixture).

mod bundle;
mod ops;

pub use bundle::QuantViT;

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::artifacts::{BundleInfo, Manifest};
use crate::runtime::fabric::LanePool;
use crate::runtime::{ExecStats, Executor, LoadedModel};
use ops::lut_i32;

/// Wall-clock milliseconds spent per kernel family during a forward
/// pass — the per-op breakdown `benches/interpreter.rs` reports.
#[derive(Debug, Clone, Copy, Default)]
pub struct OpProfile {
    pub quantize_ms: f64,
    pub gemm_ms: f64,
    pub layernorm_ms: f64,
    pub attention_ms: f64,
    /// Elementwise requant LUT maps + residual adds between kernels.
    pub requant_ms: f64,
    pub head_ms: f64,
}

impl OpProfile {
    pub fn merge(&mut self, o: &OpProfile) {
        self.quantize_ms += o.quantize_ms;
        self.gemm_ms += o.gemm_ms;
        self.layernorm_ms += o.layernorm_ms;
        self.attention_ms += o.attention_ms;
        self.requant_ms += o.requant_ms;
        self.head_ms += o.head_ms;
    }

    pub fn total_ms(&self) -> f64 {
        self.quantize_ms
            + self.gemm_ms
            + self.layernorm_ms
            + self.attention_ms
            + self.requant_ms
            + self.head_ms
    }
}

fn lap(last: &mut Instant) -> f64 {
    let now = Instant::now();
    let ms = now.duration_since(*last).as_secs_f64() * 1e3;
    *last = now;
    ms
}

impl QuantViT {
    /// Full integer forward for one image: f32 tokens (T*P) -> f64 logits.
    ///
    /// Bit-exact with `model.forward_int_np` over the same f32 tokens.
    /// Runs fully serial; see [`Self::forward_image_pooled`] for the
    /// lane-parallel variant (identical results).
    pub fn forward_image(&self, tokens: &[f32]) -> crate::Result<Vec<f64>> {
        self.forward_image_pooled(tokens, &LanePool::serial())
    }

    /// [`Self::forward_image`] with token-row bands spread across the
    /// pool's lanes. Bit-identical at every lane count.
    pub fn forward_image_pooled(&self, tokens: &[f32], pool: &LanePool) -> crate::Result<Vec<f64>> {
        Ok(self.forward_profiled(tokens, pool)?.0)
    }

    /// [`Self::forward_image_pooled`] plus the per-op time breakdown.
    pub fn forward_profiled(
        &self,
        tokens: &[f32],
        pool: &LanePool,
    ) -> crate::Result<(Vec<f64>, OpProfile)> {
        anyhow::ensure!(
            tokens.len() == self.tokens_per_image(),
            "expected {} token values, got {}",
            self.tokens_per_image(),
            tokens.len()
        );
        let (t, d, h) = (self.tokens, self.dim, self.heads);
        let mut prof = OpProfile::default();
        let mut last = Instant::now();

        let xq: Vec<i32> = tokens.iter().map(|&x| self.quantize_in(x)).collect();
        prof.quantize_ms += lap(&mut last);
        let acc = self.pe.matmul(&xq, t, pool);
        prof.gemm_ms += lap(&mut last);
        // residual stream: int32, common scale s0 (+2 guard bits)
        let mut x: Vec<i32> = acc.iter().map(|&a| lut_i32(&self.pe_rq, a as i32)).collect();
        prof.requant_ms += lap(&mut last);

        for blk in &self.blocks {
            // ---- MHA ----
            let n = ops::layernorm(&x, d, blk.ln1_guard, &blk.ln1_rsqrt, &blk.ln1_rq, pool);
            prof.layernorm_ms += lap(&mut last);
            let acc = blk.qkv.matmul(&n, t, pool);
            prof.gemm_ms += lap(&mut last);
            let qkv: Vec<i32> = acc.iter().map(|&a| lut_i32(&blk.qkv_rq, a as i32)).collect();
            prof.requant_ms += lap(&mut last);
            let a_q = ops::attention(blk, &qkv, t, d, h, pool);
            prof.attention_ms += lap(&mut last);
            let acc = blk.proj.matmul(&a_q, t, pool);
            prof.gemm_ms += lap(&mut last);
            for (xv, &a) in x.iter_mut().zip(&acc) {
                *xv = xv.wrapping_add(lut_i32(&blk.proj_rq, a as i32));
            }
            prof.requant_ms += lap(&mut last);

            // ---- MLP ----
            let n2 = ops::layernorm(&x, d, blk.ln2_guard, &blk.ln2_rsqrt, &blk.ln2_rq, pool);
            prof.layernorm_ms += lap(&mut last);
            let acc = blk.mm1.matmul(&n2, t, pool);
            prof.gemm_ms += lap(&mut last);
            let hdn: Vec<i32> = acc.iter().map(|&a| lut_i32(&blk.gelu, a as i32)).collect();
            prof.requant_ms += lap(&mut last);
            let acc = blk.mm2.matmul(&hdn, t, pool);
            prof.gemm_ms += lap(&mut last);
            for (xv, &a) in x.iter_mut().zip(&acc) {
                *xv = xv.wrapping_add(lut_i32(&blk.mm2_rq, a as i32));
            }
            prof.requant_ms += lap(&mut last);
        }

        // ---- final LN + mean-pool head (the /T fold lives in logit_scale)
        let n = ops::layernorm(&x, d, self.ln_f_guard, &self.ln_f_rsqrt, &self.ln_f_rq, pool);
        prof.layernorm_ms += lap(&mut last);
        let logits = self.head(&n);
        prof.head_ms += lap(&mut last);
        Ok((logits, prof))
    }

    /// The pre-fabric forward — naive row-major GEMM, per-head
    /// probability matrix, per-row softmax allocations, fully serial.
    /// Kept as the differential-testing oracle and the scalar baseline
    /// `benches/interpreter.rs` measures the fabric against; must stay
    /// bit-identical to [`Self::forward_image`].
    pub fn forward_image_naive(&self, tokens: &[f32]) -> crate::Result<Vec<f64>> {
        anyhow::ensure!(
            tokens.len() == self.tokens_per_image(),
            "expected {} token values, got {}",
            self.tokens_per_image(),
            tokens.len()
        );
        let (t, d, h) = (self.tokens, self.dim, self.heads);
        let serial = LanePool::serial();

        let xq: Vec<i32> = tokens.iter().map(|&x| self.quantize_in(x)).collect();
        let acc = self.pe.matmul_naive(&xq, t);
        let mut x: Vec<i32> = acc.iter().map(|&a| lut_i32(&self.pe_rq, a as i32)).collect();

        for blk in &self.blocks {
            let n = ops::layernorm(&x, d, blk.ln1_guard, &blk.ln1_rsqrt, &blk.ln1_rq, &serial);
            let acc = blk.qkv.matmul_naive(&n, t);
            let qkv: Vec<i32> = acc.iter().map(|&a| lut_i32(&blk.qkv_rq, a as i32)).collect();
            let a_q = ops::attention_naive(blk, &qkv, t, d, h);
            let acc = blk.proj.matmul_naive(&a_q, t);
            for (xv, &a) in x.iter_mut().zip(&acc) {
                *xv = xv.wrapping_add(lut_i32(&blk.proj_rq, a as i32));
            }

            let n2 = ops::layernorm(&x, d, blk.ln2_guard, &blk.ln2_rsqrt, &blk.ln2_rq, &serial);
            let acc = blk.mm1.matmul_naive(&n2, t);
            let hdn: Vec<i32> = acc.iter().map(|&a| lut_i32(&blk.gelu, a as i32)).collect();
            let acc = blk.mm2.matmul_naive(&hdn, t);
            for (xv, &a) in x.iter_mut().zip(&acc) {
                *xv = xv.wrapping_add(lut_i32(&blk.mm2_rq, a as i32));
            }
        }

        let n = ops::layernorm(&x, d, self.ln_f_guard, &self.ln_f_rsqrt, &self.ln_f_rq, &serial);
        Ok(self.head(&n))
    }

    /// Mean-pool + classifier head over the final-LN output rows.
    fn head(&self, n: &[i32]) -> Vec<f64> {
        let d = self.dim;
        let mut pooled = vec![0i64; d];
        for row in n.chunks_exact(d) {
            for (p, &v) in pooled.iter_mut().zip(row) {
                *p += v as i64;
            }
        }
        let mut logits = Vec::with_capacity(self.num_classes);
        for k in 0..self.num_classes {
            let mut s: i64 = 0;
            for (c, &p) in pooled.iter().enumerate() {
                s += p * self.head_w[c * self.num_classes + k] as i64;
            }
            logits.push(s as f64 * self.logit_scale + self.head_bias[k]);
        }
        logits
    }
}

// ---------------------------------------------------------------------------
// Executor adapter (one per batch variant, sharing the loaded model)
// ---------------------------------------------------------------------------

/// A batch-size view over a shared [`QuantViT`], executing on a
/// [`LanePool`].
///
/// Work is partitioned at two grains: when the dispatch carries at least
/// as many images as the pool has lanes, each worker runs whole images
/// (batch-lane grain, one parallel region per dispatch); otherwise the
/// pool drops inside each image and parallelizes token-row bands (row
/// grain). Both grains are bit-exact with serial execution.
pub struct InterpreterExecutor {
    net: Arc<QuantViT>,
    batch: usize,
    pool: LanePool,
    load_ms: f64,
    stats: Mutex<ExecStats>,
}

impl Executor for InterpreterExecutor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn run_f32(&self, input: &[f32]) -> crate::Result<Vec<f32>> {
        let per = self.net.tokens_per_image();
        anyhow::ensure!(
            input.len() == self.batch * per,
            "input length {} != batch {} x {}",
            input.len(),
            self.batch,
            per
        );
        let t0 = Instant::now();
        let nc = self.net.num_classes;
        let mut out = vec![0.0f32; self.batch * nc];
        if self.pool.lanes() > 1 && self.batch >= self.pool.lanes() {
            // batch-lane grain: a band of whole images per worker
            let err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
            let serial = LanePool::serial();
            self.pool.par_chunks_mut(&mut out, nc, |i0, band| {
                for (j, orow) in band.chunks_exact_mut(nc).enumerate() {
                    let i = i0 + j;
                    match self.net.forward_image_pooled(&input[i * per..(i + 1) * per], &serial) {
                        Ok(logits) => {
                            for (o, &v) in orow.iter_mut().zip(&logits) {
                                *o = v as f32;
                            }
                        }
                        Err(e) => {
                            *err.lock().unwrap() = Some(e);
                            return;
                        }
                    }
                }
            });
            if let Some(e) = err.into_inner().unwrap() {
                return Err(e);
            }
        } else {
            // row grain: images serial, token rows banded inside each
            for (i, lane) in input.chunks_exact(per).enumerate() {
                let logits = self.net.forward_image_pooled(lane, &self.pool)?;
                for (o, &v) in out[i * nc..(i + 1) * nc].iter_mut().zip(&logits) {
                    *o = v as f32;
                }
            }
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut s = self.stats.lock().unwrap();
        s.executions += 1;
        s.total_ms += ms;
        Ok(out)
    }

    fn compile_ms(&self) -> f64 {
        self.load_ms
    }

    fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }
}

/// Load a model's bundle and wrap it in one executor per batch variant,
/// with the lane count taken from `HGPIPE_LANES` (or the machine's
/// available parallelism).
pub fn load_model(manifest: &Manifest, model: &str) -> crate::Result<LoadedModel> {
    load_model_with_lanes(manifest, model, LanePool::from_env().lanes())
}

/// [`load_model`] with an explicit lane count (tests and benches pass
/// this directly so they never race on the process environment).
pub fn load_model_with_lanes(
    manifest: &Manifest,
    model: &str,
    lanes: usize,
) -> crate::Result<LoadedModel> {
    let info: &BundleInfo = manifest
        .bundle_for(model)
        .ok_or_else(|| anyhow::anyhow!("no interpreter bundle for model '{model}' in manifest"))?;
    let t0 = Instant::now();
    let net = Arc::new(QuantViT::load(&info.path)?);
    let load_ms = t0.elapsed().as_secs_f64() * 1e3;
    anyhow::ensure!(
        net.model == model,
        "bundle model '{}' != requested '{model}'",
        net.model
    );
    let batches = if info.batches.is_empty() { vec![1] } else { info.batches.clone() };
    let executors: Vec<Box<dyn Executor>> = batches
        .iter()
        .map(|&b| {
            Box::new(InterpreterExecutor {
                net: net.clone(),
                batch: b,
                pool: LanePool::new(lanes),
                load_ms,
                stats: Mutex::new(ExecStats::default()),
            }) as Box<dyn Executor>
        })
        .collect();
    Ok(LoadedModel {
        executors,
        tokens_per_image: net.tokens_per_image(),
        num_classes: net.num_classes,
        compile_ms: load_ms,
    })
}
