//! Integer kernels of the interpreter — the rust twin of
//! `python/compile/kernels/ref.py` / `model.LutExec`.
//!
//! Every kernel here is bit-exact with the numpy oracle: i64
//! accumulation in ascending index order, `as i32` wrapping narrowings
//! exactly where `LutExec._i32` narrows, PoT-indexed LUT lookups for the
//! non-linears. The `*_into` variants band output rows through an
//! [`Exec`] dispatch — serial with explicit band scratch (zero locks),
//! or spread across [`LanePool`](crate::runtime::fabric::LanePool)
//! lanes — and draw every working buffer from the band's
//! [`BandScratch`] (no per-call allocation); each row's arithmetic is
//! unchanged, so the dispatch never changes a single bit of the result.
//!
//! The elementwise requant LUT passes are **fused into the GEMM band
//! that produces them** ([`gemm_rq_into`] / [`gemm_rq_add_into`]): a
//! band computes its i64 accumulator rows and immediately maps them
//! through the requant LUT (plus the residual add where the dataflow
//! has one) before the region completes. Pre-fusion these passes ran
//! serially on the caller thread after every matmul — the per-op
//! profile's top non-GEMM cost. Per output element the arithmetic is
//! `lut(acc as i32)` either way, in the same order, so fusion is
//! bit-exactness-preserving.
//!
//! The `*_naive` variants preserve the pre-fabric scalar structure
//! (per-row scratch allocations, per-head probability matrix,
//! column-outer `R @ V`). They are the differential-testing oracle and
//! the baseline `benches/interpreter.rs` measures the fabric against —
//! they never touch the dispatched vtable ([`attention_naive`] pins the
//! scalar table explicitly), so the oracle stays oracle even when the
//! process auto-detected a SIMD backend.
//!
//! The inner loops themselves live in
//! [`kernels`](crate::runtime::kernels): each `*_into` kernel reads the
//! [`Kernels`] vtable off its [`Exec`] dispatch (which carries the
//! backend selected at model load) and drives the band-level ops
//! through it.

use crate::lut::{AnyTable, LutTable, SegmentedTable};
use crate::runtime::fabric::gemm::PackedGemm;
use crate::runtime::fabric::scratch::SoftmaxScratch;
use crate::runtime::fabric::Exec;
use crate::runtime::kernels::{self, Kernels};

use super::bundle::BlockParams;

// ---------------------------------------------------------------------------
// integer LUT application — the rust twin of model.LutExec._lut / _seg
// ---------------------------------------------------------------------------

// `LutExec._lut` itself (`lut_i32`) moved into the kernels layer, where
// the SIMD backends share its definition; re-exported here because it
// is this module's vocabulary (every op above is built from it).
pub(crate) use crate::runtime::kernels::lut_i32;

/// `LutExec._seg`: segmented lookup in the common (flat) output scale.
#[inline]
pub(crate) fn seg_i32(s: &SegmentedTable, x: i32) -> i32 {
    if x < s.pivot as i32 {
        lut_i32(&s.steep, x).wrapping_shl(s.ratio_log2())
    } else {
        lut_i32(&s.flat, x)
    }
}

#[inline]
pub(crate) fn any_i32(t: &AnyTable, x: i32) -> i32 {
    match t {
        AnyTable::Lut(l) => lut_i32(l, x),
        AnyTable::Segmented(s) => seg_i32(s, x),
    }
}

// ---------------------------------------------------------------------------
// GEMM with the requant LUT fused into the producing band
// ---------------------------------------------------------------------------

/// `out = rq_lut(x @ W + b)`, the requant map applied by the same band
/// that computed the accumulator rows (no serial epilogue on the caller
/// thread). Bit-exact with `matmul` + a serial `lut_i32` map: per
/// element, the identical `lut(acc as i32)` in the identical order.
pub(crate) fn gemm_rq_into(
    g: &PackedGemm,
    x: &[i32],
    t: usize,
    rq: &LutTable,
    out: &mut Vec<i32>,
    exec: &mut Exec<'_>,
) {
    assert_eq!(x.len(), t * g.ci(), "input shape mismatch");
    let co = g.co();
    // no clear(): every element is written by the band epilogue below
    out.resize(t * co, 0);
    let kern = exec.kernels();
    exec.run(out.as_mut_slice(), co, |s, r0, band| {
        s.acc.resize(band.len(), 0); // fully overwritten by band_into
        g.band_into(x, r0, &mut s.acc[..band.len()], kern);
        (kern.requant)(rq, &s.acc[..band.len()], band);
    });
}

/// `xio += rq_lut(xin @ W + b)` (wrapping add into the residual
/// stream), fused exactly like [`gemm_rq_into`]. The residual rows are
/// banded, so the add also stops being a serial caller-thread pass.
pub(crate) fn gemm_rq_add_into(
    g: &PackedGemm,
    xin: &[i32],
    t: usize,
    rq: &LutTable,
    xio: &mut [i32],
    exec: &mut Exec<'_>,
) {
    assert_eq!(xin.len(), t * g.ci(), "input shape mismatch");
    let co = g.co();
    assert_eq!(xio.len(), t * co, "residual shape mismatch");
    let kern = exec.kernels();
    exec.run(xio, co, |s, r0, band| {
        s.acc.resize(band.len(), 0);
        g.band_into(xin, r0, &mut s.acc[..band.len()], kern);
        (kern.requant_add)(rq, &s.acc[..band.len()], band);
    });
}

// ---------------------------------------------------------------------------
// LayerNorm
// ---------------------------------------------------------------------------

/// Integer LayerNorm (`LutExec.layernorm`): three passes per token row,
/// rows banded through the dispatch, centered-sum buffer from the band
/// scratch, output into a caller-owned reusable buffer.
pub(crate) fn layernorm_into(
    x: &[i32],
    d: usize,
    guard: u32,
    rsqrt: &LutTable,
    rq: &LutTable,
    out: &mut Vec<i32>,
    exec: &mut Exec<'_>,
) {
    debug_assert_eq!(x.len() % d, 0);
    // no clear(): every element of every row is written below, so
    // resize only pays for newly grown capacity
    out.resize(x.len(), 0);
    let kern = exec.kernels();
    exec.run(out.as_mut_slice(), d, |s, r0, band| {
        s.ln_c.resize(d, 0); // fully overwritten per row

        for (i, orow) in band.chunks_exact_mut(d).enumerate() {
            let row = &x[(r0 + i) * d..(r0 + i + 1) * d];
            let sum = (kern.sum_i32)(row);
            // numpy: `ci * x` runs in int32 (wrapping) before the int64
            // subtraction widens it — ln_center keeps that narrowing
            let v = (kern.ln_center)(d as i32, sum, guard, row, &mut s.ln_c);
            let r = lut_i32(rsqrt, v as i32) as i64;
            (kern.ln_finish)(rq, r, &s.ln_c, orow);
        }
    });
}

// ---------------------------------------------------------------------------
// Softmax
// ---------------------------------------------------------------------------

/// Integer Softmax over one score row (`LutExec.softmax`): max-subtract,
/// inverted Exp LUT, (segmented) Recip, prob ReQuant — the three row
/// passes driven through the given kernel backend (the recip is a
/// single scalar lookup, not a loop, so it stays here).
pub(crate) fn softmax_row(
    kern: &Kernels,
    exp: &LutTable,
    recip: &AnyTable,
    prob: &LutTable,
    scores: &[i64],
    probs: &mut [i32],
    scratch: &mut SoftmaxScratch,
) {
    debug_assert_eq!(scores.len(), scratch.sc.len());
    for (s, &a) in scratch.sc.iter_mut().zip(scores) {
        *s = a as i32;
    }
    let m = (kern.max_i32)(&scratch.sc);
    let tot = (kern.exp_lut_sum)(exp, m, &scratch.sc, &mut scratch.e);
    let r = any_i32(recip, tot as i32);
    (kern.prob_lut)(prob, r, &scratch.e, probs);
}

// ---------------------------------------------------------------------------
// Attention
// ---------------------------------------------------------------------------

/// Fused multi-head attention over requantized `qkv` rows: per output
/// token `t1` (banded through the dispatch) and head, compute the score
/// row, softmax it, and accumulate `R @ V` with the zero-probability
/// skip. All per-row buffers come from the band scratch; the output
/// goes into a caller-owned reusable buffer.
///
/// Bit-exact with [`attention_naive`]: per output element the same i64
/// terms are summed in the same ascending-`t2` order (skipping a zero
/// probability adds nothing), and the `as i32` narrowing into the
/// `rv` requant LUT is unchanged.
pub(crate) fn attention_into(
    blk: &BlockParams,
    qkv: &[i32],
    t: usize,
    d: usize,
    h: usize,
    out: &mut Vec<i32>,
    exec: &mut Exec<'_>,
) {
    let dh = d / h;
    // no clear(): `d % h == 0` (validated at bundle load), so the head
    // slices cover every element of every row — stale values never leak
    out.resize(t * d, 0);
    let kern = exec.kernels();
    exec.run(out.as_mut_slice(), d, |s, t1_0, band| {
        s.scores.resize(t, 0); // fully overwritten per (t1, head)
        s.prob.resize(t, 0); // ditto (softmax writes all t entries)
        s.rv.resize(dh, 0); // zeroed per head by fill(0) below
        s.softmax.reset(t);
        for (i, orow) in band.chunks_exact_mut(d).enumerate() {
            let t1 = t1_0 + i;
            let qrow = t1 * 3 * d;
            for hh in 0..h {
                let (qof, kof, vof) = (hh * dh, d + hh * dh, 2 * d + hh * dh);
                // DyMM 1: scores = Q @ K^T for this (t1, head)
                let q = &qkv[qrow + qof..qrow + qof + dh];
                for (t2, sc) in s.scores.iter_mut().enumerate() {
                    let k = &qkv[t2 * 3 * d + kof..t2 * 3 * d + kof + dh];
                    *sc = (kern.dot_i32)(q, k);
                }
                softmax_row(
                    kern,
                    &blk.exp,
                    &blk.recip,
                    &blk.prob,
                    &s.scores,
                    &mut s.prob,
                    &mut s.softmax,
                );
                // DyMM 2: R @ V, t2-outer so V rows stream contiguously
                s.rv.fill(0);
                for (t2, &p) in s.prob.iter().enumerate() {
                    if p != 0 {
                        let v = &qkv[t2 * 3 * d + vof..t2 * 3 * d + vof + dh];
                        (kern.axpy)(p, v, &mut s.rv);
                    }
                }
                (kern.requant)(&blk.rv_rq, &s.rv, &mut orow[hh * dh..(hh + 1) * dh]);
            }
        }
    });
}

/// The pre-fabric attention: head-outer, full `t x t` probability
/// matrix, column-outer `R @ V`, per-row softmax allocations. Kept as
/// the differential oracle / scalar baseline.
pub(crate) fn attention_naive(
    blk: &BlockParams,
    qkv: &[i32],
    t: usize,
    d: usize,
    h: usize,
) -> Vec<i32> {
    let dh = d / h;
    let mut a_q = vec![0i32; t * d];
    let mut scores = vec![0i64; t];
    let mut probs = vec![0i32; t * t];
    for hh in 0..h {
        let (qof, kof, vof) = (hh * dh, d + hh * dh, 2 * d + hh * dh);
        for t1 in 0..t {
            let q = &qkv[t1 * 3 * d + qof..t1 * 3 * d + qof + dh];
            for t2 in 0..t {
                let k = &qkv[t2 * 3 * d + kof..t2 * 3 * d + kof + dh];
                scores[t2] = q.iter().zip(k).map(|(&a, &b)| a as i64 * b as i64).sum();
            }
            let mut scratch = SoftmaxScratch::new(t); // per-row, like the old code
            softmax_row(
                kernels::scalar(), // the oracle stays pure scalar
                &blk.exp,
                &blk.recip,
                &blk.prob,
                &scores,
                &mut probs[t1 * t..(t1 + 1) * t],
                &mut scratch,
            );
        }
        for t1 in 0..t {
            for c in 0..dh {
                let mut s: i64 = 0;
                for t2 in 0..t {
                    s += probs[t1 * t + t2] as i64 * qkv[t2 * 3 * d + vof + c] as i64;
                }
                a_q[t1 * d + hh * dh + c] = lut_i32(&blk.rv_rq, s as i32);
            }
        }
    }
    a_q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::fabric::{BandScratch, LanePool};
    use crate::util::prng::Prng;

    fn mk_lut(alpha: i64, shift: u32, n_bits: u32, inverted: bool, entries: Vec<i64>) -> LutTable {
        LutTable {
            name: "t".into(),
            alpha,
            shift,
            n_bits,
            inverted,
            out_scale: 1.0,
            out_zp: 0,
            entries,
        }
    }

    #[test]
    fn lut_i32_matches_table_lookup_in_range() {
        let t = mk_lut(-8, 2, 2, false, vec![10, 20, 30, 40]);
        for x in -20i64..20 {
            assert_eq!(lut_i32(&t, x as i32) as i64, t.lookup(x), "x={x}");
        }
    }

    #[test]
    fn lut_i32_inverted_matches() {
        let t = mk_lut(0, 1, 2, true, vec![1, 2, 3, 4]);
        for x in -20i64..5 {
            assert_eq!(lut_i32(&t, x as i32) as i64, t.lookup(x), "x={x}");
        }
    }

    #[test]
    fn lut_i32_wraps_like_numpy_int32() {
        // an accumulator past i32::MAX wraps negative before indexing,
        // exactly as numpy's astype(int32) does in LutExec._lut
        let t = mk_lut(0, 4, 2, false, vec![7, 8, 9, 10]);
        let big: i64 = (1i64 << 31) + 5; // wraps to i32::MIN + 5
        let wrapped = big as i32;
        assert!(wrapped < 0);
        assert_eq!(lut_i32(&t, wrapped), 7); // clamps to index 0
    }

    #[test]
    fn seg_i32_selects_by_pivot_and_shifts() {
        let steep = LutTable { out_scale: 1.0, ..mk_lut(0, 2, 2, false, vec![100, 90, 80, 70]) };
        let flat =
            LutTable { out_scale: 0.25, alpha: 16, ..mk_lut(0, 2, 2, false, vec![5, 4, 3, 2]) };
        let s = SegmentedTable { name: "s".into(), pivot: 16, steep, flat };
        assert_eq!(seg_i32(&s, 0), 400); // 100 << 2
        assert_eq!(seg_i32(&s, 16), 5);
    }

    #[test]
    fn layernorm_rows_independent_of_dispatch() {
        let rsqrt = mk_lut(-(1 << 20), 10, 6, false, (0..64i64).map(|i| 64 - i).collect());
        let rq = mk_lut(-(1 << 20), 12, 6, false, (0..64i64).map(|i| i - 32).collect());
        let d = 16;
        let x: Vec<i32> = (0..5 * d as i32).map(|i| (i * 37 % 113) - 56).collect();
        let mut serial = Vec::new();
        let mut band = BandScratch::default();
        let mut exec = Exec::serial(&mut band, kernels::scalar());
        layernorm_into(&x, d, 2, &rsqrt, &rq, &mut serial, &mut exec);
        assert_eq!(serial.len(), x.len());
        for lanes in [1usize, 2, 3, 7] {
            let pool = LanePool::new(lanes);
            let mut pooled = Vec::new();
            layernorm_into(&x, d, 2, &rsqrt, &rq, &mut pooled, &mut Exec::pool(&pool));
            assert_eq!(pooled, serial, "lanes={lanes}");
        }
    }

    #[test]
    fn layernorm_into_reuses_the_output_buffer() {
        let rsqrt = mk_lut(-(1 << 20), 10, 6, false, (0..64i64).map(|i| 64 - i).collect());
        let rq = mk_lut(-(1 << 20), 12, 6, false, (0..64i64).map(|i| i - 32).collect());
        let d = 8;
        let x: Vec<i32> = (0..4 * d as i32).map(|i| (i * 11 % 37) - 18).collect();
        let mut band = BandScratch::default();
        let mut out = Vec::new();
        let mut exec = Exec::serial(&mut band, kernels::scalar());
        layernorm_into(&x, d, 2, &rsqrt, &rq, &mut out, &mut exec);
        let want = out.clone();
        let ptr = out.as_ptr();
        layernorm_into(&x, d, 2, &rsqrt, &rq, &mut out, &mut exec);
        assert_eq!(out, want);
        assert_eq!(out.as_ptr(), ptr, "steady-state layernorm must not reallocate");
    }

    /// Unfused reference for the fused GEMM+requant kernels: full matmul
    /// followed by a serial elementwise LUT pass (the pre-fusion shape).
    fn gemm_then_lut(g: &PackedGemm, x: &[i32], t: usize, rq: &LutTable) -> Vec<i32> {
        g.matmul_naive(x, t).iter().map(|&a| lut_i32(rq, a as i32)).collect()
    }

    #[test]
    fn fused_gemm_requant_matches_serial_epilogue() {
        let mut rng = Prng::new(0xF0);
        let rq = mk_lut(-(1 << 16), 9, 7, false, (0..128i64).map(|i| i * 3 - 192).collect());
        for &(t, ci, co) in &[(1usize, 1usize, 1usize), (5, 40, 9), (13, 70, 130), (16, 64, 192)] {
            let x: Vec<i32> = (0..t * ci)
                .map(|_| if rng.below(4) == 0 { 0 } else { rng.range_i64(-9, 9) as i32 })
                .collect();
            let w: Vec<i32> = (0..ci * co).map(|_| rng.range_i64(-50, 50) as i32).collect();
            let b: Vec<i64> = (0..co).map(|_| rng.range_i64(-4000, 4000)).collect();
            let g = PackedGemm::pack(w, ci, co, b);
            let want = gemm_then_lut(&g, &x, t, &rq);

            let mut band = BandScratch::default();
            let mut got = Vec::new();
            gemm_rq_into(&g, &x, t, &rq, &mut got, &mut Exec::serial(&mut band, kernels::scalar()));
            assert_eq!(got, want, "serial ({t},{ci},{co})");
            for lanes in [2usize, 3, 7] {
                let pool = LanePool::new(lanes);
                let mut got = Vec::new();
                gemm_rq_into(&g, &x, t, &rq, &mut got, &mut Exec::pool(&pool));
                assert_eq!(got, want, "lanes={lanes} ({t},{ci},{co})");
            }
        }
    }

    #[test]
    fn fused_gemm_requant_residual_add_matches() {
        let mut rng = Prng::new(0xF1);
        let rq = mk_lut(-(1 << 16), 9, 6, false, (0..64i64).map(|i| i * 5 - 160).collect());
        let (t, ci, co) = (9usize, 33usize, 70usize);
        let x: Vec<i32> = (0..t * ci).map(|_| rng.range_i64(-9, 9) as i32).collect();
        let w: Vec<i32> = (0..ci * co).map(|_| rng.range_i64(-50, 50) as i32).collect();
        let b: Vec<i64> = (0..co).map(|_| rng.range_i64(-4000, 4000)).collect();
        let g = PackedGemm::pack(w, ci, co, b);
        let residual: Vec<i32> = (0..t * co).map(|_| rng.range_i64(-1000, 1000) as i32).collect();
        let mut want = residual.clone();
        for (o, &l) in want.iter_mut().zip(gemm_then_lut(&g, &x, t, &rq).iter()) {
            *o = o.wrapping_add(l);
        }

        let mut band = BandScratch::default();
        let mut got = residual.clone();
        gemm_rq_add_into(&g, &x, t, &rq, &mut got, &mut Exec::serial(&mut band, kernels::scalar()));
        assert_eq!(got, want, "serial");
        for lanes in [2usize, 5] {
            let pool = LanePool::new(lanes);
            let mut got = residual.clone();
            gemm_rq_add_into(&g, &x, t, &rq, &mut got, &mut Exec::pool(&pool));
            assert_eq!(got, want, "lanes={lanes}");
        }
    }
}
