//! Integer kernels of the interpreter — the rust twin of
//! `python/compile/kernels/ref.py` / `model.LutExec`.
//!
//! Every kernel here is bit-exact with the numpy oracle: i64
//! accumulation in ascending index order, `as i32` wrapping narrowings
//! exactly where `LutExec._i32` narrows, PoT-indexed LUT lookups for the
//! non-linears. The `*_into` variants band output rows across
//! [`LanePool`] lanes and draw every working buffer from the lane's
//! [`LaneScratch`] (no per-call allocation); each row's arithmetic is
//! unchanged, so lane count never changes a single bit of the result.
//!
//! The `*_naive` variants preserve the pre-fabric scalar structure
//! (per-row scratch allocations, per-head probability matrix,
//! column-outer `R @ V`). They are the differential-testing oracle and
//! the baseline `benches/interpreter.rs` measures the fabric against.

use crate::lut::{AnyTable, LutTable, SegmentedTable};
use crate::runtime::fabric::scratch::SoftmaxScratch;
use crate::runtime::fabric::LanePool;

use super::bundle::BlockParams;

// ---------------------------------------------------------------------------
// integer LUT application — the rust twin of model.LutExec._lut / _seg
// ---------------------------------------------------------------------------

/// `LutExec._lut`: int32-domain PoT-indexed lookup.
#[inline]
pub(crate) fn lut_i32(t: &LutTable, x: i32) -> i32 {
    let alpha = t.alpha as i32;
    let diff = if t.inverted { alpha.wrapping_sub(x) } else { x.wrapping_sub(alpha) };
    let raw = diff >> t.shift;
    let hi = (1i32 << t.n_bits) - 1;
    t.entries[raw.clamp(0, hi) as usize] as i32
}

/// `LutExec._seg`: segmented lookup in the common (flat) output scale.
#[inline]
pub(crate) fn seg_i32(s: &SegmentedTable, x: i32) -> i32 {
    if x < s.pivot as i32 {
        lut_i32(&s.steep, x).wrapping_shl(s.ratio_log2())
    } else {
        lut_i32(&s.flat, x)
    }
}

#[inline]
pub(crate) fn any_i32(t: &AnyTable, x: i32) -> i32 {
    match t {
        AnyTable::Lut(l) => lut_i32(l, x),
        AnyTable::Segmented(s) => seg_i32(s, x),
    }
}

// ---------------------------------------------------------------------------
// LayerNorm
// ---------------------------------------------------------------------------

/// Integer LayerNorm (`LutExec.layernorm`): three passes per token row,
/// rows banded across the pool, centered-sum buffer from the lane
/// scratch, output into a caller-owned reusable buffer.
pub(crate) fn layernorm_into(
    x: &[i32],
    d: usize,
    guard: u32,
    rsqrt: &LutTable,
    rq: &LutTable,
    out: &mut Vec<i32>,
    pool: &LanePool,
) {
    debug_assert_eq!(x.len() % d, 0);
    // no clear(): every element of every row is written below, so
    // resize only pays for newly grown capacity
    out.resize(x.len(), 0);
    pool.par_chunks_mut(out.as_mut_slice(), d, |s, r0, band| {
        s.ln_c.resize(d, 0); // fully overwritten per row

        for (i, orow) in band.chunks_exact_mut(d).enumerate() {
            let row = &x[(r0 + i) * d..(r0 + i + 1) * d];
            let sum: i64 = row.iter().map(|&v| v as i64).sum();
            let mut v: i64 = 0;
            for (cj, &xv) in s.ln_c.iter_mut().zip(row) {
                // numpy: `ci * x` runs in int32 (wrapping) before the
                // int64 subtraction widens it
                *cj = (d as i32).wrapping_mul(xv) as i64 - sum;
                let cg = *cj >> guard;
                v += cg * cg;
            }
            let r = lut_i32(rsqrt, v as i32) as i64;
            for (o, &cj) in orow.iter_mut().zip(s.ln_c.iter()) {
                *o = lut_i32(rq, (cj * r) as i32);
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Softmax
// ---------------------------------------------------------------------------

/// Integer Softmax over one score row (`LutExec.softmax`): max-subtract,
/// inverted Exp LUT, (segmented) Recip, prob ReQuant.
pub(crate) fn softmax_row(
    exp: &LutTable,
    recip: &AnyTable,
    prob: &LutTable,
    scores: &[i64],
    probs: &mut [i32],
    scratch: &mut SoftmaxScratch,
) {
    debug_assert_eq!(scores.len(), scratch.sc.len());
    for (s, &a) in scratch.sc.iter_mut().zip(scores) {
        *s = a as i32;
    }
    let m = *scratch.sc.iter().max().unwrap();
    let mut tot: i64 = 0;
    for (ev, &s) in scratch.e.iter_mut().zip(scratch.sc.iter()) {
        *ev = lut_i32(exp, s.wrapping_sub(m));
        tot += *ev as i64;
    }
    let r = any_i32(recip, tot as i32);
    for (p, &ev) in probs.iter_mut().zip(scratch.e.iter()) {
        *p = lut_i32(prob, ev.wrapping_mul(r));
    }
}

// ---------------------------------------------------------------------------
// Attention
// ---------------------------------------------------------------------------

/// Fused multi-head attention over requantized `qkv` rows: per output
/// token `t1` (banded across the pool) and head, compute the score row,
/// softmax it, and accumulate `R @ V` with the zero-probability skip.
/// All per-row buffers come from the lane's scratch; the output goes
/// into a caller-owned reusable buffer.
///
/// Bit-exact with [`attention_naive`]: per output element the same i64
/// terms are summed in the same ascending-`t2` order (skipping a zero
/// probability adds nothing), and the `as i32` narrowing into the
/// `rv` requant LUT is unchanged.
pub(crate) fn attention_into(
    blk: &BlockParams,
    qkv: &[i32],
    t: usize,
    d: usize,
    h: usize,
    out: &mut Vec<i32>,
    pool: &LanePool,
) {
    let dh = d / h;
    // no clear(): `d % h == 0` (validated at bundle load), so the head
    // slices cover every element of every row — stale values never leak
    out.resize(t * d, 0);
    pool.par_chunks_mut(out.as_mut_slice(), d, |s, t1_0, band| {
        s.scores.resize(t, 0); // fully overwritten per (t1, head)
        s.prob.resize(t, 0); // ditto (softmax writes all t entries)
        s.rv.resize(dh, 0); // zeroed per head by fill(0) below
        s.softmax.reset(t);
        for (i, orow) in band.chunks_exact_mut(d).enumerate() {
            let t1 = t1_0 + i;
            let qrow = t1 * 3 * d;
            for hh in 0..h {
                let (qof, kof, vof) = (hh * dh, d + hh * dh, 2 * d + hh * dh);
                // DyMM 1: scores = Q @ K^T for this (t1, head)
                let q = &qkv[qrow + qof..qrow + qof + dh];
                for (t2, sc) in s.scores.iter_mut().enumerate() {
                    let k = &qkv[t2 * 3 * d + kof..t2 * 3 * d + kof + dh];
                    *sc = q.iter().zip(k).map(|(&a, &b)| a as i64 * b as i64).sum();
                }
                softmax_row(&blk.exp, &blk.recip, &blk.prob, &s.scores, &mut s.prob, &mut s.softmax);
                // DyMM 2: R @ V, t2-outer so V rows stream contiguously
                s.rv.fill(0);
                for (t2, &p) in s.prob.iter().enumerate() {
                    let p = p as i64;
                    if p != 0 {
                        let v = &qkv[t2 * 3 * d + vof..t2 * 3 * d + vof + dh];
                        for (a, &vv) in s.rv.iter_mut().zip(v) {
                            *a += p * vv as i64;
                        }
                    }
                }
                for (o, &acc) in orow[hh * dh..(hh + 1) * dh].iter_mut().zip(s.rv.iter()) {
                    *o = lut_i32(&blk.rv_rq, acc as i32);
                }
            }
        }
    });
}

/// The pre-fabric attention: head-outer, full `t x t` probability
/// matrix, column-outer `R @ V`, per-row softmax allocations. Kept as
/// the differential oracle / scalar baseline.
pub(crate) fn attention_naive(blk: &BlockParams, qkv: &[i32], t: usize, d: usize, h: usize) -> Vec<i32> {
    let dh = d / h;
    let mut a_q = vec![0i32; t * d];
    let mut scores = vec![0i64; t];
    let mut probs = vec![0i32; t * t];
    for hh in 0..h {
        let (qof, kof, vof) = (hh * dh, d + hh * dh, 2 * d + hh * dh);
        for t1 in 0..t {
            let q = &qkv[t1 * 3 * d + qof..t1 * 3 * d + qof + dh];
            for t2 in 0..t {
                let k = &qkv[t2 * 3 * d + kof..t2 * 3 * d + kof + dh];
                scores[t2] = q.iter().zip(k).map(|(&a, &b)| a as i64 * b as i64).sum();
            }
            let mut scratch = SoftmaxScratch::new(t); // per-row, like the old code
            softmax_row(
                &blk.exp,
                &blk.recip,
                &blk.prob,
                &scores,
                &mut probs[t1 * t..(t1 + 1) * t],
                &mut scratch,
            );
        }
        for t1 in 0..t {
            for c in 0..dh {
                let mut s: i64 = 0;
                for t2 in 0..t {
                    s += probs[t1 * t + t2] as i64 * qkv[t2 * 3 * d + vof + c] as i64;
                }
                a_q[t1 * d + hh * dh + c] = lut_i32(&blk.rv_rq, s as i32);
            }
        }
    }
    a_q
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_lut(alpha: i64, shift: u32, n_bits: u32, inverted: bool, entries: Vec<i64>) -> LutTable {
        LutTable {
            name: "t".into(),
            alpha,
            shift,
            n_bits,
            inverted,
            out_scale: 1.0,
            out_zp: 0,
            entries,
        }
    }

    #[test]
    fn lut_i32_matches_table_lookup_in_range() {
        let t = mk_lut(-8, 2, 2, false, vec![10, 20, 30, 40]);
        for x in -20i64..20 {
            assert_eq!(lut_i32(&t, x as i32) as i64, t.lookup(x), "x={x}");
        }
    }

    #[test]
    fn lut_i32_inverted_matches() {
        let t = mk_lut(0, 1, 2, true, vec![1, 2, 3, 4]);
        for x in -20i64..5 {
            assert_eq!(lut_i32(&t, x as i32) as i64, t.lookup(x), "x={x}");
        }
    }

    #[test]
    fn lut_i32_wraps_like_numpy_int32() {
        // an accumulator past i32::MAX wraps negative before indexing,
        // exactly as numpy's astype(int32) does in LutExec._lut
        let t = mk_lut(0, 4, 2, false, vec![7, 8, 9, 10]);
        let big: i64 = (1i64 << 31) + 5; // wraps to i32::MIN + 5
        let wrapped = big as i32;
        assert!(wrapped < 0);
        assert_eq!(lut_i32(&t, wrapped), 7); // clamps to index 0
    }

    #[test]
    fn seg_i32_selects_by_pivot_and_shifts() {
        let steep = LutTable { out_scale: 1.0, ..mk_lut(0, 2, 2, false, vec![100, 90, 80, 70]) };
        let flat = LutTable { out_scale: 0.25, alpha: 16, ..mk_lut(0, 2, 2, false, vec![5, 4, 3, 2]) };
        let s = SegmentedTable { name: "s".into(), pivot: 16, steep, flat };
        assert_eq!(seg_i32(&s, 0), 400); // 100 << 2
        assert_eq!(seg_i32(&s, 16), 5);
    }

    #[test]
    fn layernorm_rows_independent_of_lane_count() {
        let rsqrt = mk_lut(-(1 << 20), 10, 6, false, (0..64i64).map(|i| 64 - i).collect());
        let rq = mk_lut(-(1 << 20), 12, 6, false, (0..64i64).map(|i| i - 32).collect());
        let d = 16;
        let x: Vec<i32> = (0..5 * d as i32).map(|i| (i * 37 % 113) - 56).collect();
        let mut serial = Vec::new();
        layernorm_into(&x, d, 2, &rsqrt, &rq, &mut serial, &LanePool::serial());
        assert_eq!(serial.len(), x.len());
        for lanes in [2usize, 3, 7] {
            let mut pooled = Vec::new();
            layernorm_into(&x, d, 2, &rsqrt, &rq, &mut pooled, &LanePool::new(lanes));
            assert_eq!(pooled, serial, "lanes={lanes}");
        }
    }

    #[test]
    fn layernorm_into_reuses_the_output_buffer() {
        let rsqrt = mk_lut(-(1 << 20), 10, 6, false, (0..64i64).map(|i| 64 - i).collect());
        let rq = mk_lut(-(1 << 20), 12, 6, false, (0..64i64).map(|i| i - 32).collect());
        let d = 8;
        let x: Vec<i32> = (0..4 * d as i32).map(|i| (i * 11 % 37) - 18).collect();
        let pool = LanePool::serial();
        let mut out = Vec::new();
        layernorm_into(&x, d, 2, &rsqrt, &rq, &mut out, &pool);
        let want = out.clone();
        let ptr = out.as_ptr();
        layernorm_into(&x, d, 2, &rsqrt, &rq, &mut out, &pool);
        assert_eq!(out, want);
        assert_eq!(out.as_ptr(), ptr, "steady-state layernorm must not reallocate");
    }
}
