//! Bundle loading + validation: parse the weight/LUT JSON written by
//! `python -m compile.export` into a ready-to-execute [`QuantViT`].
//!
//! Everything the run-time kernels index is validated here, so a
//! malformed bundle is a load error, not an executor-thread panic. Weight
//! matrices are re-packed into [`PackedGemm`] column panels once, at
//! load — the blocked kernel never touches the JSON layout again.

use std::path::Path;

use crate::lut::{AnyTable, LutTable};
use crate::runtime::fabric::gemm::PackedGemm;
use crate::util::json::Json;

/// One encoder block's integer parameters + tables.
pub(crate) struct BlockParams {
    pub(crate) qkv: PackedGemm,
    pub(crate) proj: PackedGemm,
    pub(crate) mm1: PackedGemm,
    pub(crate) mm2: PackedGemm,
    pub(crate) ln1_guard: u32,
    pub(crate) ln2_guard: u32,
    pub(crate) ln1_rsqrt: LutTable,
    pub(crate) ln1_rq: LutTable,
    pub(crate) qkv_rq: LutTable,
    pub(crate) exp: LutTable,
    pub(crate) recip: AnyTable,
    pub(crate) prob: LutTable,
    pub(crate) rv_rq: LutTable,
    pub(crate) proj_rq: LutTable,
    pub(crate) ln2_rsqrt: LutTable,
    pub(crate) ln2_rq: LutTable,
    pub(crate) gelu: LutTable,
    pub(crate) mm2_rq: LutTable,
}

impl BlockParams {
    /// Resident bytes of one encoder block's immutable parameters.
    fn footprint_bytes(&self) -> usize {
        self.qkv.footprint_bytes()
            + self.proj.footprint_bytes()
            + self.mm1.footprint_bytes()
            + self.mm2.footprint_bytes()
            + self.ln1_rsqrt.footprint_bytes()
            + self.ln1_rq.footprint_bytes()
            + self.qkv_rq.footprint_bytes()
            + self.exp.footprint_bytes()
            + self.recip.footprint_bytes()
            + self.prob.footprint_bytes()
            + self.rv_rq.footprint_bytes()
            + self.proj_rq.footprint_bytes()
            + self.ln2_rsqrt.footprint_bytes()
            + self.ln2_rq.footprint_bytes()
            + self.gelu.footprint_bytes()
            + self.mm2_rq.footprint_bytes()
    }
}

/// A fully-loaded quantized ViT, ready to execute.
pub struct QuantViT {
    pub model: String,
    pub precision: String,
    pub tokens: usize,
    pub patch_dim: usize,
    pub dim: usize,
    pub depth: usize,
    pub heads: usize,
    pub hidden: usize,
    pub num_classes: usize,
    pub(crate) in_scale: f64,
    pub(crate) in_qmin: i64,
    pub(crate) in_qmax: i64,
    pub(crate) logit_scale: f64,
    /// Head bias: float32 values widened to f64 (numpy adds them in f64).
    pub(crate) head_bias: Vec<f64>,
    pub(crate) pe: PackedGemm,
    pub(crate) pe_rq: LutTable,
    pub(crate) blocks: Vec<BlockParams>,
    pub(crate) ln_f_guard: u32,
    pub(crate) ln_f_rsqrt: LutTable,
    pub(crate) ln_f_rq: LutTable,
    pub(crate) head_w: Vec<i32>,
}

fn ints_i32(v: &Json, key: &str, expect: usize) -> crate::Result<Vec<i32>> {
    let arr = v
        .req(key)
        .map_err(|e| anyhow::anyhow!(e))?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("bundle '{key}' is not an array"))?;
    anyhow::ensure!(arr.len() == expect, "bundle '{key}': {} values, expected {expect}", arr.len());
    arr.iter()
        .map(|x| x.as_i64().map(|v| v as i32).ok_or_else(|| anyhow::anyhow!("bad int in '{key}'")))
        .collect()
}

fn ints_i64(v: &Json, key: &str, expect: usize) -> crate::Result<Vec<i64>> {
    let arr = v
        .req(key)
        .map_err(|e| anyhow::anyhow!(e))?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("bundle '{key}' is not an array"))?;
    anyhow::ensure!(arr.len() == expect, "bundle '{key}': {} values, expected {expect}", arr.len());
    arr.iter()
        .map(|x| x.as_i64().ok_or_else(|| anyhow::anyhow!("bad int in '{key}'")))
        .collect()
}

fn usize_field(v: &Json, key: &str) -> crate::Result<usize> {
    v.req(key)
        .map_err(|e| anyhow::anyhow!(e))?
        .as_i64()
        .map(|x| x as usize)
        .ok_or_else(|| anyhow::anyhow!("bundle '{key}' is not an integer"))
}

impl QuantViT {
    /// Parse a bundle JSON written by `compile/export.py`.
    pub fn load(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("bundle {}: {e}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("bundle parse: {e}"))?;
        let format = v.get("format").and_then(|f| f.as_str()).unwrap_or("?");
        anyhow::ensure!(format == "hgpipe-bundle-v1", "unsupported bundle format '{format}'");

        let cfg = v.req("cfg").map_err(|e| anyhow::anyhow!(e))?;
        let tokens = usize_field(cfg, "tokens")?;
        let patch_dim = usize_field(cfg, "patch_dim")?;
        let dim = usize_field(cfg, "dim")?;
        let depth = usize_field(cfg, "depth")?;
        let heads = usize_field(cfg, "heads")?;
        let hidden = usize_field(cfg, "hidden")?;
        let num_classes = usize_field(cfg, "num_classes")?;
        anyhow::ensure!(heads > 0 && dim % heads == 0, "dim {dim} not divisible by heads {heads}");

        let input = v.req("input").map_err(|e| anyhow::anyhow!(e))?;
        let head = v.req("head").map_err(|e| anyhow::anyhow!(e))?;
        let weights = v.req("weights").map_err(|e| anyhow::anyhow!(e))?;
        let guards = v.req("guards").map_err(|e| anyhow::anyhow!(e))?;
        let luts = v
            .req("luts")
            .map_err(|e| anyhow::anyhow!(e))?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("bundle 'luts' is not an object"))?;

        // validate at load time what lut_i32 will index at run time
        fn check(t: &LutTable) -> crate::Result<()> {
            let depth = 1usize << t.n_bits;
            anyhow::ensure!(
                t.entries.len() == depth,
                "lut '{}': {} entries, expected {depth}",
                t.name,
                t.entries.len()
            );
            anyhow::ensure!(t.shift < 32, "lut '{}': shift {} out of i32 range", t.name, t.shift);
            Ok(())
        }
        let table = |name: &str| -> crate::Result<AnyTable> {
            let t = luts.get(name).ok_or_else(|| anyhow::anyhow!("bundle missing lut '{name}'"))?;
            let t = AnyTable::from_json(t).map_err(|e| anyhow::anyhow!("lut '{name}': {e}"))?;
            match &t {
                AnyTable::Lut(l) => check(l)?,
                AnyTable::Segmented(s) => {
                    check(&s.steep)?;
                    check(&s.flat)?;
                }
            }
            Ok(t)
        };
        let plain = |name: &str| -> crate::Result<LutTable> {
            match table(name)? {
                AnyTable::Lut(t) => Ok(t),
                AnyTable::Segmented(_) => anyhow::bail!("lut '{name}': expected plain table"),
            }
        };
        let guard = |name: &str| -> crate::Result<u32> {
            guards
                .get(name)
                .and_then(|g| g.as_i64())
                .map(|g| g as u32)
                .ok_or_else(|| anyhow::anyhow!("bundle missing guard '{name}'"))
        };
        let gemm = |wk: &str, bk: &str, ci: usize, co: usize| -> crate::Result<PackedGemm> {
            let w = ints_i32(weights, wk, ci * co)?;
            Ok(PackedGemm::pack(w, ci, co, ints_i64(weights, bk, co)?))
        };

        let mut blocks = Vec::with_capacity(depth);
        for i in 0..depth {
            let p = |n: &str| format!("b{i}.{n}");
            blocks.push(BlockParams {
                qkv: gemm(&p("qkv_w"), &p("qkv_b"), dim, 3 * dim)?,
                proj: gemm(&p("proj_w"), &p("proj_b"), dim, dim)?,
                mm1: gemm(&p("mm1_w"), &p("mm1_b"), dim, hidden)?,
                mm2: gemm(&p("mm2_w"), &p("mm2_b"), hidden, dim)?,
                ln1_guard: guard(&p("ln1"))?,
                ln2_guard: guard(&p("ln2"))?,
                ln1_rsqrt: plain(&p("ln1.rsqrt"))?,
                ln1_rq: plain(&p("ln1.rq"))?,
                qkv_rq: plain(&p("qkv"))?,
                exp: plain(&p("attn.exp"))?,
                recip: table(&p("attn.recip"))?,
                prob: plain(&p("attn.prob"))?,
                rv_rq: plain(&p("rv"))?,
                proj_rq: plain(&p("proj"))?,
                ln2_rsqrt: plain(&p("ln2.rsqrt"))?,
                ln2_rq: plain(&p("ln2.rq"))?,
                gelu: plain(&p("gelu"))?,
                mm2_rq: plain(&p("mm2"))?,
            });
        }

        let bias_f64 = head
            .req("bias")
            .map_err(|e| anyhow::anyhow!(e))?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("head bias not an array"))?
            .iter()
            .map(|x| x.as_f64().ok_or_else(|| anyhow::anyhow!("bad head bias")))
            .collect::<crate::Result<Vec<f64>>>()?;
        anyhow::ensure!(bias_f64.len() == num_classes, "head bias length mismatch");

        Ok(Self {
            model: v.get("model").and_then(|m| m.as_str()).unwrap_or("?").to_string(),
            precision: v.get("precision").and_then(|m| m.as_str()).unwrap_or("?").to_string(),
            tokens,
            patch_dim,
            dim,
            depth,
            heads,
            hidden,
            num_classes,
            in_scale: input
                .req("scale")
                .map_err(|e| anyhow::anyhow!(e))?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("input scale"))?,
            in_qmin: input
                .req("qmin")
                .map_err(|e| anyhow::anyhow!(e))?
                .as_i64()
                .ok_or_else(|| anyhow::anyhow!("input qmin"))?,
            in_qmax: input
                .req("qmax")
                .map_err(|e| anyhow::anyhow!(e))?
                .as_i64()
                .ok_or_else(|| anyhow::anyhow!("input qmax"))?,
            logit_scale: head
                .req("logit_scale")
                .map_err(|e| anyhow::anyhow!(e))?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("logit scale"))?,
            head_bias: bias_f64,
            pe: gemm("pe_w", "pe_b", patch_dim, dim)?,
            pe_rq: plain("pe")?,
            blocks,
            ln_f_guard: guard("ln_f")?,
            ln_f_rsqrt: plain("ln_f.rsqrt")?,
            ln_f_rq: plain("ln_f.rq")?,
            head_w: ints_i32(weights, "head_w", dim * num_classes)?,
        })
    }

    pub fn tokens_per_image(&self) -> usize {
        self.tokens * self.patch_dim
    }

    /// Resident bytes of the immutable model: every packed GEMM panel
    /// (`pe`, per-block `qkv/proj/mm1/mm2`), every requant/non-linear
    /// LUT, the head weights and bias. This is the per-*artifact* cost
    /// replicas share behind one `Arc` — per-replica scratch and fabric
    /// state are deliberately excluded (see `LanePool::scratch_footprint`
    /// for that half).
    pub fn footprint_bytes(&self) -> usize {
        let blocks: usize = self.blocks.iter().map(BlockParams::footprint_bytes).sum();
        self.pe.footprint_bytes()
            + self.pe_rq.footprint_bytes()
            + blocks
            + self.ln_f_rsqrt.footprint_bytes()
            + self.ln_f_rq.footprint_bytes()
            + self.head_w.len() * std::mem::size_of::<i32>()
            + self.head_bias.len() * std::mem::size_of::<f64>()
    }

    /// Input quantization — `QuantParams.quantize` (round half away from
    /// zero, computed in f64 exactly as numpy does over the f32 tokens).
    #[inline]
    pub(crate) fn quantize_in(&self, x: f32) -> i32 {
        let xf = x as f64;
        let q = if xf < 0.0 {
            -((-xf / self.in_scale + 0.5).floor())
        } else {
            (xf / self.in_scale + 0.5).floor()
        };
        (q as i64).clamp(self.in_qmin, self.in_qmax) as i32
    }
}
