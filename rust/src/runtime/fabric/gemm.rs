//! Register-blocked integer GEMM over a load-time-packed weight matrix.
//!
//! The interpreter's hot loop is `acc = x @ W + b` with `x: (t, ci) i32`,
//! `W: (ci, co) i32` and exact i64 accumulation. The naive row-major walk
//! touches `W` with stride `co` per k step; [`PackedGemm`] instead
//! re-packs `W` once at bundle load into column *panels* of width
//! [`TILE_CO`], so every kernel streams each panel linearly (the k loop
//! advances by one contiguous `nbe`-wide row) while a [`TILE_CO`]-wide
//! i64 output tile stays register/L1-resident — the classic
//! output-stationary blocking, here in integer arithmetic.
//!
//! On top of the panel layout sit three row kernels, chosen per output
//! row by an activation-density check ([`PackedGemm::row_is_sparse`]):
//!
//! * [`rows4`](PackedGemm::rows4_into) — the register-blocked
//!   microkernel: **4 output rows sharing each panel-row load** through
//!   the backend's `axpy4` (8-wide unrolled multiply-add chains on the
//!   scalar oracle, widening-multiply vectors on SIMD backends).
//! * a single-row dense kernel (the backend's `axpy`) for the 1–3-row
//!   remainder of a dense run.
//! * the original zero-skip scalar kernel ([`PackedGemm::row_into`]) for
//!   **sparse** rows: quantized activations — GELU outputs especially —
//!   can be mostly zero, and skipping a whole panel row then beats the
//!   dense unroll. The crossover is [`SPARSE_NUM`]/[`SPARSE_DEN`].
//!
//! Bit-exactness: for every output element every kernel adds exactly
//! the terms `x[r,k] * W[k,c]` for `k = 0..ci` in ascending k, the same
//! order as the naive triple loop — and two's-complement i64 addition is
//! associative anyway — so results are identical to the scalar reference
//! on every input, including wrap-around corner cases. The zero skip
//! contributes nothing by construction (`0 * w == 0`).
//!
//! The panel-row inner loop itself (`o[j] += a * w[j]`) lives in the
//! [`Kernels`] vtable (`kernels::axpy`/`axpy4`): the pool's selected
//! backend — scalar oracle or SIMD — is threaded into every row kernel,
//! so all three dispatch arms (microkernel, dense remainder, zero-skip)
//! hit the same vectorized code. [`Self::matmul_naive`] stays a pure
//! scalar walk over the row-major weights, independent of the vtable.

use super::LanePool;
use crate::runtime::kernels::Kernels;

/// Output-column panel width. 64 i64 accumulators = one 512-byte hot
/// tile; panels of `ci x 64` i32 weights stay well inside L2 for every
/// layer of the networks this repo serves (max `ci` = 768 for deit-tiny's
/// MLP, a 192 KiB panel).
pub const TILE_CO: usize = 64;

/// A row whose zero fraction is at least `SPARSE_NUM / SPARSE_DEN` takes
/// the zero-skip scalar kernel instead of the dense unroll: at ~3/8
/// zeros the skipped panel rows pay for the lost straight-line
/// scheduling.
pub const SPARSE_NUM: usize = 3;
/// See [`SPARSE_NUM`].
pub const SPARSE_DEN: usize = 8;

/// A weight matrix packed for the blocked kernels, plus its bias row.
///
/// The naive reference kernel ([`Self::matmul_naive`]) — the
/// differential-testing oracle and the scalar baseline the interpreter
/// bench measures speedups against — needs the original row-major
/// layout; that copy is reconstructed lazily on first use so serving
/// paths (which never call the oracle) pay no memory for it.
#[derive(Debug)]
pub struct PackedGemm {
    ci: usize,
    co: usize,
    /// Column-panel-major: for each panel `cb` (width `nbe`), `ci`
    /// contiguous rows of `nbe` weights each.
    panels: Vec<i32>,
    /// Row-major `(ci, co)` weights, unpacked on first oracle use.
    raw: std::sync::OnceLock<Vec<i32>>,
    bias: Vec<i64>,
}

impl PackedGemm {
    /// Pack a row-major `(ci, co)` weight matrix into column panels.
    pub fn pack(raw: Vec<i32>, ci: usize, co: usize, bias: Vec<i64>) -> Self {
        assert_eq!(raw.len(), ci * co, "weight shape mismatch");
        assert_eq!(bias.len(), co, "bias shape mismatch");
        let mut panels = Vec::with_capacity(ci * co);
        let mut cb = 0;
        while cb < co {
            let nbe = TILE_CO.min(co - cb);
            for k in 0..ci {
                panels.extend_from_slice(&raw[k * co + cb..k * co + cb + nbe]);
            }
            cb += nbe;
        }
        Self { ci, co, panels, raw: std::sync::OnceLock::new(), bias }
    }

    pub fn ci(&self) -> usize {
        self.ci
    }

    pub fn co(&self) -> usize {
        self.co
    }

    /// The row-major weights, reconstructed from the panels once on
    /// first call (exact inverse of [`Self::pack`]'s layout transform).
    pub fn raw(&self) -> &[i32] {
        self.raw.get_or_init(|| {
            let mut raw = vec![0i32; self.ci * self.co];
            let mut poff = 0usize;
            let mut cb = 0usize;
            while cb < self.co {
                let nbe = TILE_CO.min(self.co - cb);
                for k in 0..self.ci {
                    raw[k * self.co + cb..k * self.co + cb + nbe]
                        .copy_from_slice(&self.panels[poff + k * nbe..poff + (k + 1) * nbe]);
                }
                poff += self.ci * nbe;
                cb += nbe;
            }
            raw
        })
    }

    pub fn bias(&self) -> &[i64] {
        &self.bias
    }

    /// Resident bytes of the packed weight panels + bias row, plus the
    /// lazily-materialized row-major oracle copy if some caller forced
    /// it ([`Self::raw`] — tests and the naive reference path only).
    /// This is the immutable per-matrix share of a model artifact's
    /// memory footprint; scratch is accounted separately (it is
    /// per-replica, not per-artifact).
    pub fn footprint_bytes(&self) -> usize {
        self.panels.len() * std::mem::size_of::<i32>()
            + self.bias.len() * std::mem::size_of::<i64>()
            + self.raw.get().map_or(0, |r| r.len() * std::mem::size_of::<i32>())
    }

    /// The activation-density check: should this row take the zero-skip
    /// scalar kernel instead of the dense unroll?
    #[inline]
    pub fn row_is_sparse(xrow: &[i32]) -> bool {
        let zeros = xrow.iter().filter(|&&v| v == 0).count();
        zeros * SPARSE_DEN >= xrow.len() * SPARSE_NUM
    }

    /// One output row, zero-skip: `orow = bias + xrow @ W`. The
    /// sparse-row kernel: a zero activation skips its whole panel row;
    /// the surviving panel rows still go through the backend's
    /// `axpy` (bit-identical — each output element receives exactly one
    /// product per nonzero `k` either way).
    pub fn row_into(&self, xrow: &[i32], orow: &mut [i64], kern: &Kernels) {
        debug_assert_eq!(xrow.len(), self.ci);
        debug_assert_eq!(orow.len(), self.co);
        orow.copy_from_slice(&self.bias);
        let mut poff = 0usize;
        let mut cb = 0usize;
        while cb < self.co {
            let nbe = TILE_CO.min(self.co - cb);
            let otile = &mut orow[cb..cb + nbe];
            for (k, &xr) in xrow.iter().enumerate() {
                if xr != 0 {
                    let wrow = &self.panels[poff + k * nbe..poff + (k + 1) * nbe];
                    (kern.axpy)(xr, wrow, otile);
                }
            }
            poff += self.ci * nbe;
            cb += nbe;
        }
    }

    /// One output row, dense (no zero skip) — the 1–3-row remainder of
    /// a dense run.
    fn row_into_dense(&self, xrow: &[i32], orow: &mut [i64], kern: &Kernels) {
        debug_assert_eq!(xrow.len(), self.ci);
        debug_assert_eq!(orow.len(), self.co);
        orow.copy_from_slice(&self.bias);
        let mut poff = 0usize;
        let mut cb = 0usize;
        while cb < self.co {
            let nbe = TILE_CO.min(self.co - cb);
            let otile = &mut orow[cb..cb + nbe];
            for (k, &xr) in xrow.iter().enumerate() {
                let wrow = &self.panels[poff + k * nbe..poff + (k + 1) * nbe];
                (kern.axpy)(xr, wrow, otile);
            }
            poff += self.ci * nbe;
            cb += nbe;
        }
    }

    /// The register-blocked microkernel: four output rows at once via
    /// the backend's `axpy4`. `o` is the four rows, contiguous
    /// (`4 * co` values). Each packed panel row is read once and
    /// multiplied into all four accumulator tiles.
    fn rows4_into(
        &self,
        x0: &[i32],
        x1: &[i32],
        x2: &[i32],
        x3: &[i32],
        o: &mut [i64],
        kern: &Kernels,
    ) {
        let co = self.co;
        debug_assert_eq!(o.len(), 4 * co);
        let (o0, rest) = o.split_at_mut(co);
        let (o1, rest) = rest.split_at_mut(co);
        let (o2, o3) = rest.split_at_mut(co);
        o0.copy_from_slice(&self.bias);
        o1.copy_from_slice(&self.bias);
        o2.copy_from_slice(&self.bias);
        o3.copy_from_slice(&self.bias);
        let mut poff = 0usize;
        let mut cb = 0usize;
        while cb < co {
            let nbe = TILE_CO.min(co - cb);
            let t0 = &mut o0[cb..cb + nbe];
            let t1 = &mut o1[cb..cb + nbe];
            let t2 = &mut o2[cb..cb + nbe];
            let t3 = &mut o3[cb..cb + nbe];
            for k in 0..self.ci {
                let wrow = &self.panels[poff + k * nbe..poff + (k + 1) * nbe];
                (kern.axpy4)([x0[k], x1[k], x2[k], x3[k]], wrow, t0, t1, t2, t3);
            }
            poff += self.ci * nbe;
            cb += nbe;
        }
    }

    /// One lane band of output rows (`band = rows [r0, r0 + n)` of the
    /// full output, contiguous): partition the band's rows into dense
    /// runs (microkernel in groups of 4, dense single-row for the
    /// remainder) and sparse rows (zero-skip), by the per-row density
    /// check.
    pub(crate) fn band_into(&self, x: &[i32], r0: usize, band: &mut [i64], kern: &Kernels) {
        let (ci, co) = (self.ci, self.co);
        debug_assert_eq!(band.len() % co, 0);
        let rows = band.len() / co;
        let xrow = |r: usize| &x[(r0 + r) * ci..(r0 + r + 1) * ci];
        let mut i = 0usize;
        while i < rows {
            if Self::row_is_sparse(xrow(i)) {
                self.row_into(xrow(i), &mut band[i * co..(i + 1) * co], kern);
                i += 1;
                continue;
            }
            let mut run = 1usize;
            while run < 4 && i + run < rows && !Self::row_is_sparse(xrow(i + run)) {
                run += 1;
            }
            if run == 4 {
                self.rows4_into(
                    xrow(i),
                    xrow(i + 1),
                    xrow(i + 2),
                    xrow(i + 3),
                    &mut band[i * co..(i + 4) * co],
                    kern,
                );
            } else {
                for j in 0..run {
                    self.row_into_dense(
                        xrow(i + j),
                        &mut band[(i + j) * co..(i + j + 1) * co],
                        kern,
                    );
                }
            }
            i += run;
        }
    }

    /// Full `t`-row matmul into a caller-owned buffer (resized to
    /// `t * co`, capacity reused), output rows banded across the pool's
    /// lanes. The serving path — no allocation once `out` has warmed up.
    pub fn matmul_into(&self, x: &[i32], t: usize, out: &mut Vec<i64>, pool: &LanePool) {
        assert_eq!(x.len(), t * self.ci, "input shape mismatch");
        // no clear(): every output row starts from a bias copy, so stale
        // values from the previous (possibly different-shape) matmul are
        // fully overwritten — resize only zero-fills newly grown tail
        out.resize(t * self.co, 0);
        let kern = pool.kernels();
        pool.par_chunks_mut(out.as_mut_slice(), self.co, |_s, r0, band| {
            self.band_into(x, r0, band, kern);
        });
    }

    /// [`Self::matmul_into`] into a fresh vec (tests and one-shot use).
    pub fn matmul(&self, x: &[i32], t: usize, pool: &LanePool) -> Vec<i64> {
        let mut out = Vec::new();
        self.matmul_into(x, t, &mut out, pool);
        out
    }

    /// The pre-fabric scalar kernel, kept verbatim as the oracle/baseline.
    pub fn matmul_naive(&self, x: &[i32], t: usize) -> Vec<i64> {
        assert_eq!(x.len(), t * self.ci, "input shape mismatch");
        let (ci, co) = (self.ci, self.co);
        let raw = self.raw();
        let mut out = vec![0i64; t * co];
        for r in 0..t {
            let orow = &mut out[r * co..(r + 1) * co];
            orow.copy_from_slice(&self.bias);
            for k in 0..ci {
                let xv = x[r * ci + k] as i64;
                if xv != 0 {
                    let wrow = &raw[k * co..(k + 1) * co];
                    for (o, &wv) in orow.iter_mut().zip(wrow) {
                        *o += xv * wv as i64;
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn random_case(rng: &mut Prng, t: usize, ci: usize, co: usize) -> (Vec<i32>, PackedGemm) {
        let x: Vec<i32> = (0..t * ci)
            .map(|_| if rng.below(5) == 0 { 0 } else { rng.range_i64(-7, 7) as i32 })
            .collect();
        let w: Vec<i32> = (0..ci * co).map(|_| rng.range_i64(-100, 100) as i32).collect();
        let b: Vec<i64> = (0..co).map(|_| rng.range_i64(-1_000_000_000, 1_000_000_000)).collect();
        (x, PackedGemm::pack(w, ci, co, b))
    }

    #[test]
    fn blocked_matches_naive_on_randomized_shapes() {
        // shapes straddle the TILE_CO boundary and include t / dims not
        // divisible by the tile size or the 4-row microkernel, plus the
        // real bundle shapes
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 5, 2),
            (7, 64, 65),
            (5, 100, 129),
            (2, 65, 63),
            (16, 192, 64),
            (16, 64, 192),
            (4, 256, 64),
            (16, 64, 256),
            (9, 1, 64),
            (1, 129, 128),
            (6, 40, 9),
        ];
        let pool = LanePool::serial();
        let mut rng = Prng::new(0xFAB);
        for &(t, ci, co) in &shapes {
            let (x, g) = random_case(&mut rng, t, ci, co);
            assert_eq!(g.matmul(&x, t, &pool), g.matmul_naive(&x, t), "shape ({t},{ci},{co})");
        }
    }

    #[test]
    fn blocked_matches_naive_under_lane_pool() {
        let mut rng = Prng::new(7);
        for lanes in [2usize, 3, 7] {
            let (x, g) = random_case(&mut rng, 13, 70, 130);
            assert_eq!(
                g.matmul(&x, 13, &LanePool::new(lanes)),
                g.matmul_naive(&x, 13),
                "lanes={lanes}"
            );
        }
    }

    #[test]
    fn density_dispatch_agrees_with_naive_at_every_sparsity() {
        // sweep activation sparsity through the dense/sparse crossover so
        // the microkernel, the dense remainder and the zero-skip fallback
        // all run — and all agree with the oracle
        let pool = LanePool::serial();
        let mut rng = Prng::new(0xD15E);
        for &(t, ci, co) in &[(9usize, 33usize, 70usize), (4, 64, 64), (6, 100, 129), (16, 192, 64)]
        {
            for &zero_pct in &[0u64, 20, 45, 80, 100] {
                let x: Vec<i32> = (0..t * ci)
                    .map(|_| {
                        if rng.below(100) < zero_pct {
                            0
                        } else {
                            rng.range_i64(-9, 9) as i32
                        }
                    })
                    .collect();
                let w: Vec<i32> = (0..ci * co).map(|_| rng.range_i64(-50, 50) as i32).collect();
                let b: Vec<i64> = (0..co).map(|_| rng.range_i64(-1000, 1000)).collect();
                let g = PackedGemm::pack(w, ci, co, b);
                assert_eq!(
                    g.matmul(&x, t, &pool),
                    g.matmul_naive(&x, t),
                    "shape ({t},{ci},{co}) zeros {zero_pct}%"
                );
            }
        }
    }

    #[test]
    fn mixed_sparse_dense_rows_break_runs_correctly() {
        // alternating all-zero (sparse) and all-nonzero (dense) rows force
        // every run length 1..4 through the band partitioner
        let pool = LanePool::serial();
        let (ci, co) = (24usize, 40usize);
        let mut rng = Prng::new(42);
        let w: Vec<i32> = (0..ci * co).map(|_| rng.range_i64(-30, 30) as i32).collect();
        let b: Vec<i64> = (0..co).map(|_| rng.range_i64(-500, 500)).collect();
        let g = PackedGemm::pack(w, ci, co, b);
        // patterns: 1 = dense row, 0 = all-zero row
        for pattern in [
            vec![1, 0, 1, 1, 0, 1, 1, 1, 0, 1, 1, 1, 1, 0, 1],
            vec![0, 0, 0, 0],
            vec![1, 1, 1, 1, 1, 1, 1, 1, 1],
            vec![1],
            vec![0, 1],
        ] {
            let t = pattern.len();
            let x: Vec<i32> = (0..t * ci)
                .map(|i| if pattern[i / ci] == 0 { 0 } else { rng.range_i64(-5, 5) as i32 })
                .collect();
            for lanes in [1usize, 3] {
                let p = if lanes == 1 { pool.clone() } else { LanePool::new(lanes) };
                assert_eq!(
                    g.matmul(&x, t, &p),
                    g.matmul_naive(&x, t),
                    "pattern {pattern:?} lanes {lanes}"
                );
            }
        }
    }

    #[test]
    fn density_check_thresholds() {
        assert!(PackedGemm::row_is_sparse(&[0, 0, 0, 0]));
        assert!(!PackedGemm::row_is_sparse(&[1, 2, 3, 4]));
        // exactly at the 3/8 boundary counts as sparse
        assert!(PackedGemm::row_is_sparse(&[0, 0, 0, 1, 1, 1, 1, 1]));
        assert!(!PackedGemm::row_is_sparse(&[0, 0, 1, 1, 1, 1, 1, 1]));
    }

    #[test]
    fn extreme_magnitudes_agree() {
        // products at the i32*i32 extreme (|p| ~ 2^62, still inside i64)
        // accumulate identically in all kernels; the interpreter later
        // narrows `as i32`, so agreement must hold at full magnitude
        let w = vec![i32::MAX, i32::MIN, -1, 1];
        let b = vec![1i64 << 40, -(1i64 << 40)];
        let g = PackedGemm::pack(w, 2, 2, b);
        let x = vec![i32::MAX, 1, -3, 5];
        let blocked = g.matmul(&x, 2, &LanePool::serial());
        let naive = g.matmul_naive(&x, 2);
        assert_eq!(blocked, naive);
        assert!(blocked.iter().any(|&v| v.abs() > (1i64 << 60)));
    }

    #[test]
    fn raw_reconstruction_inverts_packing() {
        let mut rng = Prng::new(99);
        for &(ci, co) in &[(5usize, 7usize), (64, 64), (3, 129), (100, 65), (1, 1)] {
            let w: Vec<i32> = (0..ci * co).map(|_| rng.range_i64(-50, 50) as i32).collect();
            let g = PackedGemm::pack(w.clone(), ci, co, vec![0i64; co]);
            assert_eq!(g.raw(), &w[..], "({ci},{co})");
        }
    }

    #[test]
    fn bias_only_when_input_all_zero() {
        let g = PackedGemm::pack(vec![3; 6], 2, 3, vec![11, 22, 33]);
        assert_eq!(g.matmul(&[0, 0], 1, &LanePool::serial()), vec![11, 22, 33]);
    }

    #[test]
    fn matmul_into_reuses_the_output_buffer() {
        let mut rng = Prng::new(5);
        let (x, g) = random_case(&mut rng, 8, 32, 96);
        let pool = LanePool::serial();
        let mut out = Vec::new();
        g.matmul_into(&x, 8, &mut out, &pool);
        let want = out.clone();
        let cap = out.capacity();
        let ptr = out.as_ptr();
        for _ in 0..5 {
            g.matmul_into(&x, 8, &mut out, &pool);
            assert_eq!(out, want);
        }
        assert_eq!(out.capacity(), cap);
        assert_eq!(out.as_ptr(), ptr, "steady-state matmul must not reallocate its output");
    }
}
