//! Cache-blocked integer GEMM over a load-time-packed weight matrix.
//!
//! The interpreter's hot loop is `acc = x @ W + b` with `x: (t, ci) i32`,
//! `W: (ci, co) i32` and exact i64 accumulation. The naive row-major walk
//! touches `W` with stride `co` per k step; [`PackedGemm`] instead
//! re-packs `W` once at bundle load into column *panels* of width
//! [`TILE_CO`], so the kernel streams each panel linearly (the k loop
//! advances by one contiguous `nbe`-wide row) while a [`TILE_CO`]-wide
//! i64 output tile stays register/L1-resident — the classic
//! output-stationary blocking, here in integer arithmetic.
//!
//! Bit-exactness: for every output element the packed kernel adds exactly
//! the terms `x[r,k] * W[k,c]` for `k = 0..ci` in ascending k, the same
//! order as the naive triple loop — and two's-complement i64 addition is
//! associative anyway — so results are identical to the scalar reference
//! on every input, including wrap-around corner cases.
//!
//! The zero skip (`x[r,k] == 0` contributes nothing) is kept from the
//! naive kernel: quantized activations — GELU outputs especially — are
//! sparse, and skipping a zero row of the panel is free.

use super::LanePool;

/// Output-column panel width. 64 i64 accumulators = one 512-byte hot
/// tile; panels of `ci x 64` i32 weights stay well inside L2 for every
/// layer of the networks this repo serves (max `ci` = 768 for deit-tiny's
/// MLP, a 192 KiB panel).
pub const TILE_CO: usize = 64;

/// A weight matrix packed for the blocked kernel, plus its bias row.
///
/// The naive reference kernel ([`Self::matmul_naive`]) — the
/// differential-testing oracle and the scalar baseline the interpreter
/// bench measures speedups against — needs the original row-major
/// layout; that copy is reconstructed lazily on first use so serving
/// paths (which never call the oracle) pay no memory for it.
#[derive(Debug)]
pub struct PackedGemm {
    ci: usize,
    co: usize,
    /// Column-panel-major: for each panel `cb` (width `nbe`), `ci`
    /// contiguous rows of `nbe` weights each.
    panels: Vec<i32>,
    /// Row-major `(ci, co)` weights, unpacked on first oracle use.
    raw: std::sync::OnceLock<Vec<i32>>,
    bias: Vec<i64>,
}

impl PackedGemm {
    /// Pack a row-major `(ci, co)` weight matrix into column panels.
    pub fn pack(raw: Vec<i32>, ci: usize, co: usize, bias: Vec<i64>) -> Self {
        assert_eq!(raw.len(), ci * co, "weight shape mismatch");
        assert_eq!(bias.len(), co, "bias shape mismatch");
        let mut panels = Vec::with_capacity(ci * co);
        let mut cb = 0;
        while cb < co {
            let nbe = TILE_CO.min(co - cb);
            for k in 0..ci {
                panels.extend_from_slice(&raw[k * co + cb..k * co + cb + nbe]);
            }
            cb += nbe;
        }
        Self { ci, co, panels, raw: std::sync::OnceLock::new(), bias }
    }

    pub fn ci(&self) -> usize {
        self.ci
    }

    pub fn co(&self) -> usize {
        self.co
    }

    /// The row-major weights, reconstructed from the panels once on
    /// first call (exact inverse of [`Self::pack`]'s layout transform).
    pub fn raw(&self) -> &[i32] {
        self.raw.get_or_init(|| {
            let mut raw = vec![0i32; self.ci * self.co];
            let mut poff = 0usize;
            let mut cb = 0usize;
            while cb < self.co {
                let nbe = TILE_CO.min(self.co - cb);
                for k in 0..self.ci {
                    raw[k * self.co + cb..k * self.co + cb + nbe]
                        .copy_from_slice(&self.panels[poff + k * nbe..poff + (k + 1) * nbe]);
                }
                poff += self.ci * nbe;
                cb += nbe;
            }
            raw
        })
    }

    pub fn bias(&self) -> &[i64] {
        &self.bias
    }

    /// One output row, blocked: `orow = bias + xrow @ W`.
    pub fn row_into(&self, xrow: &[i32], orow: &mut [i64]) {
        debug_assert_eq!(xrow.len(), self.ci);
        debug_assert_eq!(orow.len(), self.co);
        orow.copy_from_slice(&self.bias);
        let mut poff = 0usize;
        let mut cb = 0usize;
        while cb < self.co {
            let nbe = TILE_CO.min(self.co - cb);
            let otile = &mut orow[cb..cb + nbe];
            for (k, &xr) in xrow.iter().enumerate() {
                let xv = xr as i64;
                if xv != 0 {
                    let wrow = &self.panels[poff + k * nbe..poff + (k + 1) * nbe];
                    for (o, &wv) in otile.iter_mut().zip(wrow) {
                        *o += xv * wv as i64;
                    }
                }
            }
            poff += self.ci * nbe;
            cb += nbe;
        }
    }

    /// Full `t`-row matmul, output rows banded across the pool's lanes.
    pub fn matmul(&self, x: &[i32], t: usize, pool: &LanePool) -> Vec<i64> {
        assert_eq!(x.len(), t * self.ci, "input shape mismatch");
        let mut out = vec![0i64; t * self.co];
        pool.par_chunks_mut(&mut out, self.co, |r0, band| {
            for (i, orow) in band.chunks_exact_mut(self.co).enumerate() {
                let r = r0 + i;
                self.row_into(&x[r * self.ci..(r + 1) * self.ci], orow);
            }
        });
        out
    }

    /// The pre-fabric scalar kernel, kept verbatim as the oracle/baseline.
    pub fn matmul_naive(&self, x: &[i32], t: usize) -> Vec<i64> {
        assert_eq!(x.len(), t * self.ci, "input shape mismatch");
        let (ci, co) = (self.ci, self.co);
        let raw = self.raw();
        let mut out = vec![0i64; t * co];
        for r in 0..t {
            let orow = &mut out[r * co..(r + 1) * co];
            orow.copy_from_slice(&self.bias);
            for k in 0..ci {
                let xv = x[r * ci + k] as i64;
                if xv != 0 {
                    let wrow = &raw[k * co..(k + 1) * co];
                    for (o, &wv) in orow.iter_mut().zip(wrow) {
                        *o += xv * wv as i64;
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn random_case(rng: &mut Prng, t: usize, ci: usize, co: usize) -> (Vec<i32>, PackedGemm) {
        let x: Vec<i32> = (0..t * ci)
            .map(|_| if rng.below(5) == 0 { 0 } else { rng.range_i64(-7, 7) as i32 })
            .collect();
        let w: Vec<i32> = (0..ci * co).map(|_| rng.range_i64(-100, 100) as i32).collect();
        let b: Vec<i64> = (0..co).map(|_| rng.range_i64(-1_000_000_000, 1_000_000_000)).collect();
        (x, PackedGemm::pack(w, ci, co, b))
    }

    #[test]
    fn blocked_matches_naive_on_randomized_shapes() {
        // shapes straddle the TILE_CO boundary and include t / dims not
        // divisible by the tile size, plus the real bundle shapes
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 5, 2),
            (7, 64, 65),
            (5, 100, 129),
            (2, 65, 63),
            (16, 192, 64),
            (16, 64, 192),
            (4, 256, 64),
            (16, 64, 256),
            (9, 1, 64),
            (1, 129, 128),
        ];
        let mut rng = Prng::new(0xFAB);
        for &(t, ci, co) in &shapes {
            let (x, g) = random_case(&mut rng, t, ci, co);
            assert_eq!(
                g.matmul(&x, t, &LanePool::serial()),
                g.matmul_naive(&x, t),
                "shape ({t},{ci},{co})"
            );
        }
    }

    #[test]
    fn blocked_matches_naive_under_lane_pool() {
        let mut rng = Prng::new(7);
        for lanes in [2usize, 3, 7] {
            let (x, g) = random_case(&mut rng, 13, 70, 130);
            assert_eq!(
                g.matmul(&x, 13, &LanePool::new(lanes)),
                g.matmul_naive(&x, 13),
                "lanes={lanes}"
            );
        }
    }

    #[test]
    fn extreme_magnitudes_agree() {
        // products at the i32*i32 extreme (|p| ~ 2^62, still inside i64)
        // accumulate identically in both kernels; the interpreter later
        // narrows `as i32`, so agreement must hold at full magnitude
        let w = vec![i32::MAX, i32::MIN, -1, 1];
        let b = vec![1i64 << 40, -(1i64 << 40)];
        let g = PackedGemm::pack(w, 2, 2, b);
        let x = vec![i32::MAX, 1, -3, 5];
        let blocked = g.matmul(&x, 2, &LanePool::serial());
        let naive = g.matmul_naive(&x, 2);
        assert_eq!(blocked, naive);
        assert!(blocked.iter().any(|&v| v.abs() > (1i64 << 60)));
    }

    #[test]
    fn raw_reconstruction_inverts_packing() {
        let mut rng = Prng::new(99);
        for &(ci, co) in &[(5usize, 7usize), (64, 64), (3, 129), (100, 65), (1, 1)] {
            let w: Vec<i32> = (0..ci * co).map(|_| rng.range_i64(-50, 50) as i32).collect();
            let g = PackedGemm::pack(w.clone(), ci, co, vec![0i64; co]);
            assert_eq!(g.raw(), &w[..], "({ci},{co})");
        }
    }

    #[test]
    fn bias_only_when_input_all_zero() {
        let g = PackedGemm::pack(vec![3; 6], 2, 3, vec![11, 22, 33]);
        assert_eq!(g.matmul(&[0, 0], 1, &LanePool::serial()), vec![11, 22, 33]);
    }
}
